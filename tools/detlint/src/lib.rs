//! `detlint` — the determinism-contract static analysis pass.
//!
//! Every speedup this repository ships (sharded scoring, the `WorkerPool`,
//! block kernels, the shard store + score cache) rests on one claim:
//! parallel, blocked, and cached paths are **bit-identical** to the serial
//! reference, and refresh/chunk schedules depend only on `(step, seed)`.
//! This crate makes the source-level half of that contract machine-checked.
//! It is a line/token-level scanner over `rust/src/**` — deliberately not a
//! full parser (the dev container is offline and std-only), so each rule is
//! a documented token heuristic plus a dynamic-analysis backstop (Miri /
//! ThreadSanitizer CI jobs cover what tokens cannot prove).
//!
//! # Rules
//!
//! | rule | contract |
//! |------|----------|
//! | `nondeterministic-iteration` | no `HashMap`/`HashSet` in `rust/src`: their iteration order is seeded per-process, so anything that iterates one can leak nondeterminism into schedules or merged results. Use `BTreeMap`/`BTreeSet`. |
//! | `wallclock-in-logic` | `Instant::now`/`SystemTime` reads live only in `util/timer.rs` and `util/bench.rs`, the two auditable wall-clock modules — nothing outside them may read a clock that could feed a schedule. |
//! | `unsafe-needs-safety` | every `unsafe` token is immediately preceded by (or carries) a `// SAFETY:` comment explaining the invariant, as `runtime/pool.rs` models. |
//! | `unordered-float-reduction` | no `.sum::<f32>()` / same-line `: f32` sums / `f32` folds outside `runtime/kernels.rs` and `runtime/layers.rs`, where reduction order **is** the documented contract. f32 addition is non-associative; an innocent "parallelize this fold" refactor elsewhere silently breaks bit-identity. |
//! | `panic-in-library` | `.unwrap()`/`.expect(` in `rust/src` is governed by a committed per-file baseline (`detlint.baseline.json`) that may only ratchet down: existing hits are grandfathered, new ones fail. |
//!
//! Violations are suppressible only via an explicit, reasoned marker on the
//! same line or the line directly above:
//!
//! ```text
//! // detlint: allow(unordered-float-reduction) — sequential one-pass sum
//! ```
//!
//! A marker without a reason is itself a violation (`allow-needs-reason`),
//! and every marker is reported in a summary table so grandfathered escapes
//! stay visible.
//!
//! Comments and string literals are stripped (with line structure
//! preserved) before rule matching, so prose mentioning `HashMap` or
//! `.unwrap()` does not count; the `SAFETY:`/allow-marker scans run on the
//! raw text, since they *are* comments.

use std::collections::BTreeMap;
use std::path::Path;

/// Rule names, as they appear in allow markers and reports.
pub const NONDET_ITERATION: &str = "nondeterministic-iteration";
pub const WALLCLOCK: &str = "wallclock-in-logic";
pub const UNSAFE_SAFETY: &str = "unsafe-needs-safety";
pub const FLOAT_REDUCTION: &str = "unordered-float-reduction";
pub const PANIC_LIBRARY: &str = "panic-in-library";
pub const ALLOW_REASON: &str = "allow-needs-reason";

/// Every rule a marker may name.
pub const ALL_RULES: [&str; 5] =
    [NONDET_ITERATION, WALLCLOCK, UNSAFE_SAFETY, FLOAT_REDUCTION, PANIC_LIBRARY];

/// Files (relative to the scan root) where wall-clock reads are the point.
const WALLCLOCK_EXEMPT: [&str; 2] = ["util/timer.rs", "util/bench.rs"];

/// Files whose reduction order is a documented, test-pinned contract.
const FLOAT_EXEMPT: [&str; 2] = ["runtime/kernels.rs", "runtime/layers.rs"];

/// One rule violation at a source location.
#[derive(Debug, Clone)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl Violation {
    pub fn render(&self) -> String {
        format!("{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// One `// detlint: allow(...)` marker, for the summary table.
#[derive(Debug, Clone)]
pub struct AllowMarker {
    pub file: String,
    pub line: usize,
    pub rules: Vec<String>,
    pub reason: String,
    /// Did the marker actually suppress a match? Stale markers are
    /// reported so they get cleaned up rather than accumulating.
    pub used: bool,
}

/// Scan output for a whole tree.
#[derive(Debug, Default)]
pub struct Report {
    pub violations: Vec<Violation>,
    pub allows: Vec<AllowMarker>,
    /// Unsuppressed `.unwrap()`/`.expect(` occurrences per file
    /// (the `panic-in-library` counts the baseline governs).
    pub panic_counts: BTreeMap<String, usize>,
    pub files_scanned: usize,
}

/// Replace comments and string/char-literal contents with spaces,
/// preserving the line structure, so token rules never fire on prose.
/// Handles line comments, nested block comments, plain and raw strings
/// (`r"…"`, `r#"…"#`, `b`-prefixed), escapes, char literals, and leaves
/// lifetimes (`'env`) untouched.
pub fn strip_comments_and_strings(src: &str) -> String {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut out = String::with_capacity(src.len());
    let blank = |c: char| if c == '\n' { '\n' } else { ' ' };
    let mut i = 0;
    while i < n {
        let c = b[i];
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            while i < n && b[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            continue;
        }
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 1;
            out.push_str("  ");
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    out.push_str("  ");
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    out.push_str("  ");
                    i += 2;
                } else {
                    out.push(blank(b[i]));
                    i += 1;
                }
            }
            continue;
        }
        // raw (and byte-raw) strings: r"…", r#"…"#, br"…"
        if c == 'r' || (c == 'b' && i + 1 < n && b[i + 1] == 'r') {
            let ident_before = i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_');
            if !ident_before {
                let mut j = i + if c == 'b' { 2 } else { 1 };
                let mut hashes = 0usize;
                while j < n && b[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && b[j] == '"' {
                    for &ch in &b[i..=j] {
                        out.push(blank(ch));
                    }
                    i = j + 1;
                    while i < n {
                        if b[i] == '"' {
                            let mut k = i + 1;
                            let mut h = 0usize;
                            while k < n && h < hashes && b[k] == '#' {
                                h += 1;
                                k += 1;
                            }
                            if h == hashes {
                                for _ in i..k {
                                    out.push(' ');
                                }
                                i = k;
                                break;
                            }
                        }
                        out.push(blank(b[i]));
                        i += 1;
                    }
                    continue;
                }
            }
        }
        if c == '"' {
            out.push(' ');
            i += 1;
            while i < n {
                if b[i] == '\\' && i + 1 < n {
                    out.push(' ');
                    out.push(blank(b[i + 1]));
                    i += 2;
                    continue;
                }
                if b[i] == '"' {
                    out.push(' ');
                    i += 1;
                    break;
                }
                out.push(blank(b[i]));
                i += 1;
            }
            continue;
        }
        if c == '\'' {
            if i + 1 < n && b[i + 1] == '\\' {
                // escaped char literal: scan to the closing quote
                out.push_str("  ");
                i += 2;
                while i < n && b[i] != '\'' {
                    out.push(blank(b[i]));
                    i += 1;
                }
                if i < n {
                    out.push(' ');
                    i += 1;
                }
                continue;
            }
            if i + 2 < n && b[i + 2] == '\'' && b[i + 1] != '\'' {
                out.push_str("   ");
                i += 3;
                continue;
            }
            out.push('\''); // a lifetime, not a literal
            i += 1;
            continue;
        }
        out.push(c);
        i += 1;
    }
    out
}

fn is_ident_byte(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

/// Word-boundary substring match (byte-wise; tokens are ASCII).
pub fn has_token(line: &str, tok: &str) -> bool {
    let l = line.as_bytes();
    let t = tok.as_bytes();
    if t.is_empty() || l.len() < t.len() {
        return false;
    }
    let mut from = 0;
    while let Some(p) = find_from(l, t, from) {
        let before_ok = p == 0 || !is_ident_byte(l[p - 1]);
        let after = p + t.len();
        let after_ok = after >= l.len() || !is_ident_byte(l[after]);
        if before_ok && after_ok {
            return true;
        }
        from = p + 1;
    }
    false
}

fn find_from(hay: &[u8], needle: &[u8], from: usize) -> Option<usize> {
    if from >= hay.len() || hay.len() - from < needle.len() {
        return None;
    }
    (from..=hay.len() - needle.len()).find(|&i| &hay[i..i + needle.len()] == needle)
}

/// Count non-overlapping occurrences of `needle` in `hay`.
pub fn count_occurrences(hay: &str, needle: &str) -> usize {
    let mut count = 0;
    let mut from = 0;
    while let Some(p) = find_from(hay.as_bytes(), needle.as_bytes(), from) {
        count += 1;
        from = p + needle.len();
    }
    count
}

/// Parse a `detlint: allow(rule, …) — reason` marker out of a raw line.
fn parse_marker(raw: &str) -> Option<(Vec<String>, String)> {
    let tag = "detlint: allow(";
    let start = raw.find(tag)?;
    let rest = &raw[start + tag.len()..];
    let close = rest.find(')')?;
    let rules: Vec<String> =
        rest[..close].split(',').map(|r| r.trim().to_string()).filter(|r| !r.is_empty()).collect();
    let reason = rest[close + 1..]
        .trim_start_matches(|c: char| c.is_whitespace() || c == '\u{2014}' || c == '-' || c == ':')
        .trim()
        .to_string();
    Some((rules, reason))
}

/// The per-line allow state assembled in a first pass over the file.
struct Markers {
    /// marker index covering each line (same line or the one below it).
    by_line: Vec<Option<usize>>,
    list: Vec<AllowMarker>,
}

impl Markers {
    fn allows(&mut self, line_idx: usize, rule: &str) -> bool {
        let Some(m) = self.by_line.get(line_idx).copied().flatten() else {
            return false;
        };
        if self.list[m].rules.iter().any(|r| r == rule) {
            self.list[m].used = true;
            return true;
        }
        false
    }
}

/// Scan one file's text. `rel` is the path reported in findings (use the
/// path relative to the repository root, with forward slashes).
pub fn scan_file(rel: &str, text: &str, report: &mut Report) {
    let cleaned = strip_comments_and_strings(text);
    let raw_lines: Vec<&str> = text.lines().collect();
    let clean_lines: Vec<&str> = cleaned.lines().collect();
    let nlines = raw_lines.len();

    // pass 1: markers
    let mut markers = Markers { by_line: vec![None; nlines + 1], list: Vec::new() };
    for (idx, raw) in raw_lines.iter().enumerate() {
        let Some((rules, reason)) = parse_marker(raw) else {
            continue;
        };
        let line = idx + 1;
        if reason.is_empty() {
            let msg = "allow marker without a reason — append `— <why>`".to_string();
            let v = Violation { file: rel.to_string(), line, rule: ALLOW_REASON, msg };
            report.violations.push(v);
        }
        for rule in &rules {
            if !ALL_RULES.contains(&rule.as_str()) {
                let msg = format!("allow marker names unknown rule {rule:?}");
                let v = Violation { file: rel.to_string(), line, rule: ALLOW_REASON, msg };
                report.violations.push(v);
            }
        }
        let m = markers.list.len();
        markers.list.push(AllowMarker { file: rel.to_string(), line, rules, reason, used: false });
        markers.by_line[idx] = Some(m);
        if idx + 1 < markers.by_line.len() {
            markers.by_line[idx + 1] = Some(m);
        }
    }

    // pass 2: token rules over the cleaned lines
    let wallclock_exempt = WALLCLOCK_EXEMPT.iter().any(|f| rel.ends_with(f));
    let float_exempt = FLOAT_EXEMPT.iter().any(|f| rel.ends_with(f));
    let mut panics = 0usize;
    for (idx, clean) in clean_lines.iter().enumerate() {
        let line = idx + 1;
        let mut push = |markers: &mut Markers, rule: &'static str, msg: &str| {
            if !markers.allows(idx, rule) {
                let msg = msg.to_string();
                report.violations.push(Violation { file: rel.to_string(), line, rule, msg });
            }
        };

        if has_token(clean, "HashMap") || has_token(clean, "HashSet") {
            let msg = "nondeterministic iteration order; use BTreeMap/BTreeSet";
            push(&mut markers, NONDET_ITERATION, msg);
        }

        if !wallclock_exempt && (clean.contains("Instant::now") || has_token(clean, "SystemTime")) {
            let msg = "wall-clock read outside util/timer.rs|util/bench.rs; use util::timer";
            push(&mut markers, WALLCLOCK, msg);
        }

        if has_token(clean, "unsafe") && !unsafe_is_documented(&raw_lines, idx) {
            let msg = "`unsafe` without an immediately preceding `// SAFETY:` comment";
            push(&mut markers, UNSAFE_SAFETY, msg);
        }

        if !float_exempt {
            let hit = clean.contains(".sum::<f32>()")
                || (clean.contains(".sum(") && clean.contains(": f32"))
                || clean.contains(".fold(0.0f32")
                || clean.contains(".fold(0.0_f32")
                || clean.contains(".fold(0f32");
            if hit {
                let msg = "unordered f32 reduction outside kernels.rs/layers.rs";
                push(&mut markers, FLOAT_REDUCTION, msg);
            }
        }

        let hits = count_occurrences(clean, ".unwrap()") + count_occurrences(clean, ".expect(");
        if hits > 0 && !markers.allows(idx, PANIC_LIBRARY) {
            panics += hits;
        }
    }
    if panics > 0 {
        report.panic_counts.insert(rel.to_string(), panics);
    }
    report.allows.append(&mut markers.list);
    report.files_scanned += 1;
}

/// Is the `unsafe` on raw line `idx` documented? True when the line itself
/// carries `SAFETY:` or the contiguous `//` comment block directly above
/// it contains `SAFETY:` (the `runtime/pool.rs` model).
fn unsafe_is_documented(raw_lines: &[&str], idx: usize) -> bool {
    if raw_lines[idx].contains("SAFETY:") {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let t = raw_lines[j].trim_start();
        if !t.starts_with("//") {
            return false;
        }
        if t.contains("SAFETY:") {
            return true;
        }
    }
    false
}

/// Recursively collect `.rs` files under `dir`, sorted by relative path so
/// reports and baselines are deterministic.
fn collect_rs_files(dir: &Path, rel: &str, out: &mut Vec<(String, std::path::PathBuf)>) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    let mut names: Vec<_> = entries.flatten().map(|e| e.path()).collect();
    names.sort();
    for path in names {
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or_default().to_string();
        let child = if rel.is_empty() { name.clone() } else { format!("{rel}/{name}") };
        if path.is_dir() {
            collect_rs_files(&path, &child, out);
        } else if name.ends_with(".rs") {
            out.push((child, path));
        }
    }
}

/// Scan every `.rs` file under `dir`. Reported paths are
/// `<prefix>/<path-relative-to-dir>`.
pub fn scan_tree(dir: &Path, prefix: &str) -> std::io::Result<Report> {
    let mut files = Vec::new();
    collect_rs_files(dir, "", &mut files);
    if files.is_empty() {
        return Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            format!("no .rs files under {}", dir.display()),
        ));
    }
    let mut report = Report::default();
    for (rel, path) in files {
        let text = std::fs::read_to_string(&path)?;
        let full = if prefix.is_empty() { rel } else { format!("{prefix}/{rel}") };
        scan_file(&full, &text, &mut report);
    }
    Ok(report)
}

/// Parse the flat `{"path": count, …}` baseline object. A missing file is
/// an empty baseline (every panic counts as new).
pub fn parse_baseline(text: &str) -> Result<BTreeMap<String, usize>, String> {
    let mut out = BTreeMap::new();
    let b: Vec<char> = text.chars().collect();
    let mut i = 0;
    let n = b.len();
    let skip_ws = |i: &mut usize| {
        while *i < n && b[*i].is_whitespace() {
            *i += 1;
        }
    };
    skip_ws(&mut i);
    if i >= n || b[i] != '{' {
        return Err("baseline: expected a JSON object".to_string());
    }
    i += 1;
    loop {
        skip_ws(&mut i);
        if i < n && b[i] == '}' {
            return Ok(out);
        }
        if i >= n || b[i] != '"' {
            return Err(format!("baseline: expected a key string at char {i}"));
        }
        i += 1;
        let mut key = String::new();
        while i < n && b[i] != '"' {
            if b[i] == '\\' && i + 1 < n {
                i += 1;
            }
            key.push(b[i]);
            i += 1;
        }
        i += 1; // closing quote
        skip_ws(&mut i);
        if i >= n || b[i] != ':' {
            return Err(format!("baseline: expected ':' after key {key:?}"));
        }
        i += 1;
        skip_ws(&mut i);
        let start = i;
        while i < n && b[i].is_ascii_digit() {
            i += 1;
        }
        if start == i {
            return Err(format!("baseline: expected a count for key {key:?}"));
        }
        let count: usize = b[start..i]
            .iter()
            .collect::<String>()
            .parse()
            .map_err(|e| format!("baseline: bad count for {key:?}: {e}"))?;
        out.insert(key, count);
        skip_ws(&mut i);
        if i < n && b[i] == ',' {
            i += 1;
            continue;
        }
        if i < n && b[i] == '}' {
            return Ok(out);
        }
        return Err(format!("baseline: expected ',' or '}}' at char {i}"));
    }
}

/// Serialize a baseline deterministically (sorted keys, one per line).
pub fn baseline_json(counts: &BTreeMap<String, usize>) -> String {
    let mut s = String::from("{\n");
    let mut first = true;
    for (k, v) in counts {
        if !first {
            s.push_str(",\n");
        }
        first = false;
        s.push_str(&format!("  \"{k}\": {v}"));
    }
    s.push_str("\n}\n");
    s
}

/// Outcome of comparing fresh panic counts against the committed baseline.
#[derive(Debug, Default)]
pub struct BaselineCheck {
    /// Files whose count grew — hard failures.
    pub regressions: Vec<String>,
    /// Files whose count shrank — the baseline can ratchet down.
    pub ratchets: Vec<String>,
}

/// Compare per-file panic counts against the committed baseline. New or
/// grown counts are regressions; shrunken counts invite a ratchet
/// (re-run with `--write-baseline` and commit the smaller numbers).
pub fn check_baseline(
    counts: &BTreeMap<String, usize>,
    baseline: &BTreeMap<String, usize>,
) -> BaselineCheck {
    let mut out = BaselineCheck::default();
    for (file, &have) in counts {
        let allowed = baseline.get(file).copied().unwrap_or(0);
        match have.cmp(&allowed) {
            std::cmp::Ordering::Greater => {
                out.regressions.push(format!("{file}: {have} panic sites > baseline {allowed}"));
            }
            std::cmp::Ordering::Less => {
                out.ratchets.push(format!("{file}: {have} panic sites < baseline {allowed}"));
            }
            std::cmp::Ordering::Equal => {}
        }
    }
    for (file, &allowed) in baseline {
        if !counts.contains_key(file) {
            out.ratchets.push(format!("{file}: 0 panic sites < baseline {allowed}"));
        }
    }
    out
}

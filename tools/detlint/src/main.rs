//! CLI for the determinism-contract linter. See the library docs for the
//! rule set. Exit codes: 0 clean, 1 violations or baseline regressions,
//! 2 usage/IO errors.
//!
//! ```text
//! cargo run -p detlint                  # lint rust/src against the baseline
//! cargo run -p detlint -- --write-baseline   # ratchet the panic baseline
//! cargo run -p detlint -- --root PATH   # lint another checkout
//! ```

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::process::ExitCode;

use detlint::{baseline_json, check_baseline, parse_baseline, scan_tree, Report};

fn usage() -> ExitCode {
    eprintln!("usage: detlint [--root REPO_ROOT] [--write-baseline]");
    ExitCode::from(2)
}

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut write_baseline = false;
    let mut args = std::env::args().skip(1);
    // not a `for` loop: `--root` consumes the following argument too
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(p) => root = Some(PathBuf::from(p)),
                None => return usage(),
            },
            "--write-baseline" => write_baseline = true,
            "--help" | "-h" => {
                println!("determinism-contract linter over rust/src (see tools/detlint)");
                return usage();
            }
            _ => return usage(),
        }
    }
    // default root: this crate lives at <repo>/tools/detlint
    let default_root = || PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("..");
    let root = root.unwrap_or_else(default_root);
    let src = root.join("rust").join("src");
    let report = match scan_tree(&src, "rust/src") {
        Ok(r) => r,
        Err(e) => {
            eprintln!("detlint: cannot scan {}: {e}", src.display());
            return ExitCode::from(2);
        }
    };

    let baseline_path = root.join("detlint.baseline.json");
    if write_baseline {
        let json = baseline_json(&report.panic_counts);
        if let Err(e) = std::fs::write(&baseline_path, &json) {
            eprintln!("detlint: cannot write {}: {e}", baseline_path.display());
            return ExitCode::from(2);
        }
        let total: usize = report.panic_counts.values().sum();
        println!(
            "detlint: wrote {} ({} files, {total} grandfathered panic sites)",
            baseline_path.display(),
            report.panic_counts.len()
        );
        return ExitCode::SUCCESS;
    }

    let baseline: BTreeMap<String, usize> = match std::fs::read_to_string(&baseline_path) {
        Ok(text) => match parse_baseline(&text) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("detlint: {}: {e}", baseline_path.display());
                return ExitCode::from(2);
            }
        },
        Err(_) => {
            eprintln!(
                "detlint: no baseline at {} — every panic site counts as new \
                 (run with --write-baseline to grandfather the current tree)",
                baseline_path.display()
            );
            BTreeMap::new()
        }
    };

    let check = check_baseline(&report.panic_counts, &baseline);
    render(&report, &check.regressions, &check.ratchets);

    if report.violations.is_empty() && check.regressions.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn render(report: &Report, regressions: &[String], ratchets: &[String]) {
    for v in &report.violations {
        println!("{}", v.render());
    }
    for r in regressions {
        println!("[panic-in-library] {r}");
    }

    if !report.allows.is_empty() {
        println!("\nallow markers ({}):", report.allows.len());
        println!("{:<44} {:>5}  {:<28} reason", "file", "line", "rule(s)");
        for m in &report.allows {
            let stale = if m.used { "" } else { "  [STALE: suppresses nothing]" };
            let rules = m.rules.join(",");
            println!("{:<44} {:>5}  {rules:<28} {}{stale}", m.file, m.line, m.reason);
        }
    }

    if !ratchets.is_empty() {
        println!("\nbaseline can ratchet down ({} files):", ratchets.len());
        for r in ratchets {
            println!("  {r}");
        }
        println!("  -> re-run with --write-baseline and commit the smaller counts");
    }

    let total: usize = report.panic_counts.values().sum();
    println!(
        "\ndetlint: {} files, {} violation(s), {} allow marker(s), \
         {total} grandfathered panic site(s), {} baseline regression(s)",
        report.files_scanned,
        report.violations.len(),
        report.allows.len(),
        regressions.len()
    );
}

//! Regression pins for the linter itself: every rule gets a fixture pair
//! (violation / allow-marker) plus baseline-ratchet decrease/increase
//! coverage, so heuristic changes can never silently weaken the gate.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use detlint::{
    baseline_json, check_baseline, count_occurrences, has_token, parse_baseline, scan_file,
    scan_tree, strip_comments_and_strings, Report,
};

fn fixtures_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests").join("fixtures")
}

fn scan_fixtures() -> Report {
    scan_tree(&fixtures_dir(), "fixtures").expect("fixtures scan")
}

fn rules_in(report: &Report, file: &str) -> Vec<(&'static str, usize)> {
    report
        .violations
        .iter()
        .filter(|v| v.file.ends_with(file))
        .map(|v| (v.rule, v.line))
        .collect()
}

#[test]
fn nondeterministic_iteration_fires_per_use_site() {
    let r = scan_fixtures();
    let hits = rules_in(&r, "bad_iteration.rs");
    assert_eq!(hits.len(), 3, "use + two construction sites: {hits:?}");
    assert!(hits.iter().all(|(rule, _)| *rule == "nondeterministic-iteration"));
}

#[test]
fn reasoned_allow_markers_suppress_and_are_tabulated() {
    let r = scan_fixtures();
    assert!(rules_in(&r, "allowed_iteration.rs").is_empty(), "markers must suppress");
    let allows: Vec<_> =
        r.allows.iter().filter(|m| m.file.ends_with("allowed_iteration.rs")).collect();
    assert_eq!(allows.len(), 2);
    assert!(allows.iter().all(|m| m.used && !m.reason.is_empty()));
}

#[test]
fn wallclock_reads_fire_outside_timer_and_bench() {
    let r = scan_fixtures();
    let hits = rules_in(&r, "bad_wallclock.rs");
    assert_eq!(hits.len(), 3, "use + Instant::now + SystemTime::now: {hits:?}");
    assert!(hits.iter().all(|(rule, _)| *rule == "wallclock-in-logic"));
}

#[test]
fn wallclock_rule_exempts_the_sanctioned_modules() {
    let mut r = Report::default();
    let src = "fn t() { let _ = std::time::Instant::now(); }\n";
    scan_file("rust/src/util/timer.rs", src, &mut r);
    scan_file("rust/src/util/bench.rs", src, &mut r);
    assert!(r.violations.is_empty(), "{:?}", r.violations);
}

#[test]
fn unsafe_requires_an_adjacent_safety_comment() {
    let r = scan_fixtures();
    let bad = rules_in(&r, "bad_unsafe.rs");
    assert_eq!(bad, vec![("unsafe-needs-safety", 3)]);
    assert!(rules_in(&r, "good_unsafe.rs").is_empty(), "SAFETY block must satisfy the rule");
    // the ISSUE 9 SIMD-intrinsic shape: one SAFETY comment over a whole
    // core::arch tile body must satisfy the rule (and trip nothing else)
    assert!(rules_in(&r, "simd_unsafe.rs").is_empty(), "SAFETY'd intrinsic block must pass");
}

#[test]
fn float_reductions_fire_outside_kernel_files_and_respect_allows() {
    let r = scan_fixtures();
    let hits = rules_in(&r, "bad_float.rs");
    assert_eq!(hits.len(), 3, "turbofish + ascribed + fold, minus allow + f64: {hits:?}");
    assert!(hits.iter().all(|(rule, _)| *rule == "unordered-float-reduction"));
}

#[test]
fn float_reductions_are_the_contract_inside_kernel_files() {
    let mut r = Report::default();
    let src = "fn s(v: &[f32]) -> f32 { v.iter().sum::<f32>() }\n";
    scan_file("rust/src/runtime/kernels.rs", src, &mut r);
    scan_file("rust/src/runtime/layers.rs", src, &mut r);
    assert!(r.violations.is_empty());
}

#[test]
fn panic_sites_count_code_not_prose_and_respect_allows() {
    let r = scan_fixtures();
    let panics: BTreeMap<_, _> = r
        .panic_counts
        .iter()
        .map(|(k, v)| (k.rsplit('/').next().unwrap().to_string(), *v))
        .collect();
    assert_eq!(panics.get("panics.rs"), Some(&3), "all counts: {panics:?}");
    assert_eq!(panics.get("clean.rs"), None);
    assert_eq!(panics.get("bad_wallclock.rs"), None);
}

#[test]
fn clean_file_passes_every_rule() {
    let r = scan_fixtures();
    assert!(rules_in(&r, "clean.rs").is_empty());
}

#[test]
fn reasonless_and_unknown_rule_markers_are_violations() {
    let r = scan_fixtures();
    let hits = rules_in(&r, "bad_marker.rs");
    assert!(hits.contains(&("allow-needs-reason", 2)), "reasonless marker: {hits:?}");
    assert!(hits.contains(&("allow-needs-reason", 5)), "unknown rule: {hits:?}");
    assert!(hits.contains(&("nondeterministic-iteration", 11)), "unsuppressed use: {hits:?}");
    let stale: Vec<_> = r
        .allows
        .iter()
        .filter(|m| m.file.ends_with("bad_marker.rs") && !m.used)
        .map(|m| m.line)
        .collect();
    // the unknown-rule marker and the wallclock marker both suppress nothing
    assert_eq!(stale, vec![5, 8]);
}

#[test]
fn baseline_ratchet_decrease_is_ok_increase_fails() {
    let mut counts = BTreeMap::new();
    counts.insert("rust/src/a.rs".to_string(), 3usize);
    counts.insert("rust/src/new.rs".to_string(), 1usize);

    // equal baseline: clean
    let mut base = counts.clone();
    let ok = check_baseline(&counts, &base);
    assert!(ok.regressions.is_empty() && ok.ratchets.is_empty());

    // counts fell below the baseline: no failure, but a ratchet invitation
    base.insert("rust/src/a.rs".to_string(), 5);
    base.insert("rust/src/gone.rs".to_string(), 2);
    let down = check_baseline(&counts, &base);
    assert!(down.regressions.is_empty());
    assert_eq!(down.ratchets.len(), 2, "{:?}", down.ratchets);

    // counts grew past the baseline (or appeared unbaselined): failure
    base.insert("rust/src/a.rs".to_string(), 2);
    base.remove("rust/src/new.rs");
    let up = check_baseline(&counts, &base);
    assert_eq!(up.regressions.len(), 2, "{:?}", up.regressions);
}

#[test]
fn baseline_json_roundtrips_deterministically() {
    let mut counts = BTreeMap::new();
    counts.insert("rust/src/b.rs".to_string(), 12usize);
    counts.insert("rust/src/a.rs".to_string(), 7usize);
    let json = baseline_json(&counts);
    assert_eq!(parse_baseline(&json).expect("roundtrip"), counts);
    assert_eq!(json, baseline_json(&parse_baseline(&json).expect("again")));
    assert!(parse_baseline("[]").is_err());
    assert!(parse_baseline("{\"x\": -1}").is_err());
    assert_eq!(parse_baseline("{}").expect("empty"), BTreeMap::new());
}

#[test]
fn stripper_preserves_lines_and_blanks_prose() {
    let src = "let a = \"HashMap\"; // HashSet\nlet b = 1; /* multi\nline SystemTime */ let c;\n";
    let out = strip_comments_and_strings(src);
    assert_eq!(out.lines().count(), src.lines().count());
    assert!(!out.contains("HashMap") && !out.contains("HashSet"));
    assert!(!out.contains("SystemTime"));
    assert!(out.contains("let a =") && out.contains("let c;"));

    let raw = "let r = r#\"unsafe .unwrap()\"#; let l: &'static str = \"x\";\n";
    let out = strip_comments_and_strings(raw);
    assert!(!out.contains("unsafe") && !out.contains(".unwrap()"));
    assert!(out.contains("'static"), "lifetimes survive: {out}");

    let chars = "let q = 'a'; let esc = '\\n'; let quote = '\"'; let h = HashMap::new();\n";
    let out = strip_comments_and_strings(chars);
    assert!(has_token(&out, "HashMap"), "code after char literals survives: {out}");
    assert!(!out.contains('"'), "quote char literal must not open a string: {out}");
}

#[test]
fn token_matching_respects_identifier_boundaries() {
    assert!(has_token("use std::collections::HashMap;", "HashMap"));
    assert!(!has_token("struct MyHashMapLike;", "HashMap"));
    assert!(!has_token("let unsafely = 1;", "unsafe"));
    assert!(has_token("unsafe { x }", "unsafe"));
    assert_eq!(count_occurrences("a.unwrap().unwrap()", ".unwrap()"), 2);
    assert_eq!(count_occurrences("a.unwrap_or(0)", ".unwrap()"), 0);
    assert_eq!(count_occurrences("a.expect_err(\"e\")", ".expect("), 0);
}

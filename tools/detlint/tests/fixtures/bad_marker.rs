// Fixture: allow-needs-reason — a reasonless marker and an unknown rule.
// detlint: allow(nondeterministic-iteration)
use std::collections::HashMap;

// detlint: allow(no-such-rule) — the rule name is wrong
fn nothing() {}

// detlint: allow(wallclock-in-logic) — stale: suppresses nothing below
fn also_nothing() {}

fn uses(m: &HashMap<u32, u32>) -> usize {
    m.len()
}

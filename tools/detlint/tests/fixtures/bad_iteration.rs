// Fixture: nondeterministic-iteration violations (one per use site).
use std::collections::HashMap;

fn exec_counts() -> HashMap<String, u64> {
    let mut m = HashMap::new();
    m.insert("train_step".to_string(), 1);
    m
}

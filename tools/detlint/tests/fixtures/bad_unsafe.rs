// Fixture: unsafe-needs-safety — an unsafe block with no SAFETY comment.
fn erase(x: &mut [u8]) {
    unsafe {
        std::ptr::write_bytes(x.as_mut_ptr(), 0, x.len());
    }
}

// Fixture: the same pattern, suppressed by reasoned allow markers.
// detlint: allow(nondeterministic-iteration) — never iterated, key-lookup only
use std::collections::HashSet;

// detlint: allow(nondeterministic-iteration) — contains() is order-free
fn lookup_only(s: &HashSet<u64>) -> bool {
    s.contains(&7)
}

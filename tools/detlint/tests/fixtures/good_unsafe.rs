// Fixture: unsafe-needs-safety satisfied — the runtime/pool.rs model.
fn erase(x: &mut [u8]) {
    // SAFETY: the pointer and length come from the same live slice, so the
    // write stays in bounds; u8 has no drop glue or validity invariants.
    unsafe {
        std::ptr::write_bytes(x.as_mut_ptr(), 0, x.len());
    }
}

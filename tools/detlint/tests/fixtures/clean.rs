// Fixture: a file the linter must pass untouched. Decoys live only in
// comments and strings: HashMap, Instant::now, unsafe, .unwrap()
use std::collections::BTreeMap;

/* block comment decoys: HashSet SystemTime .expect( */
fn deterministic(m: &BTreeMap<u32, u32>) -> f64 {
    let doc = "prose HashMap and .sum::<f32>() stay prose";
    let raw = r#"raw-string decoy: unsafe { HashSet } .unwrap()"#;
    let lifetime_test: &'static str = "still fine";
    let total: f64 = m.values().map(|&v| v as f64).sum();
    total + (doc.len() + raw.len() + lifetime_test.len()) as f64
}

// Fixture: panic-in-library — three countable sites, one suppressed, and
// decoys in comments/strings that must NOT count: .unwrap() .expect(
fn three_sites(v: &[i32]) -> i32 {
    let a: i32 = "7".parse().unwrap();
    let b = v.first().expect("non-empty");
    let c = v.last().unwrap(); // trailing comment with .unwrap() decoy
    let _s = "string decoy: .unwrap() .expect(";
    // detlint: allow(panic-in-library) — mutex poisoning is already fatal
    let d = v.first().unwrap();
    a + b + c + d
}

// Fixture: unordered-float-reduction — f32 reductions outside the kernel
// files where reduction order is the documented contract.
fn turbofish(v: &[f32]) -> f32 {
    v.iter().sum::<f32>()
}

fn ascribed(v: &[f32]) -> f32 {
    let total: f32 = v.iter().sum();
    total
}

fn folded(v: &[f32]) -> f32 {
    v.iter().fold(0.0f32, |a, &b| a + b)
}

fn allowed(v: &[f32]) -> f32 {
    // detlint: allow(unordered-float-reduction) — sequential one-pass sum
    let total: f32 = v.iter().sum();
    total
}

fn f64_is_fine(v: &[f32]) -> f64 {
    let total: f64 = v.iter().map(|&x| x as f64).sum();
    total
}

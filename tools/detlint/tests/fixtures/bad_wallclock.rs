// Fixture: wallclock-in-logic violations — raw clock reads outside the
// sanctioned util/timer.rs / util/bench.rs modules.
use std::time::SystemTime;

fn schedule_salt() -> u128 {
    let t0 = std::time::Instant::now();
    let _ = t0;
    SystemTime::now().elapsed().map(|d| d.as_nanos()).unwrap_or(0)
}

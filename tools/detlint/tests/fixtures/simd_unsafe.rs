// Fixture: unsafe-needs-safety satisfied on the SIMD-intrinsic shape —
// the runtime/kernels.rs `mod simd` model: a `core::arch` tile body where
// one SAFETY comment covers a whole intrinsic block (loads, arithmetic,
// stores), not one comment per intrinsic call.
#[cfg(target_arch = "x86_64")]
use core::arch::x86_64::{_mm_add_ps, _mm_loadu_ps, _mm_mul_ps, _mm_set1_ps, _mm_storeu_ps};

#[cfg(target_arch = "x86_64")]
fn axpy_tile(alpha: f32, x: &[f32], y: &mut [f32]) {
    let xs = &x[..8];
    let ys = &mut y[..8];
    // SAFETY: SSE2 is unconditionally available on x86_64 (baseline ABI).
    // Every `loadu`/`storeu` reads or writes 4 f32s through the pointer of
    // a slice bounds-checked to exactly 8 elements (offsets 0 and 4), so
    // all accesses stay in bounds; the `u` variants carry no alignment
    // requirement.
    unsafe {
        let ab = _mm_set1_ps(alpha);
        let lo = _mm_add_ps(_mm_loadu_ps(ys.as_ptr()), _mm_mul_ps(ab, _mm_loadu_ps(xs.as_ptr())));
        let hi = _mm_add_ps(
            _mm_loadu_ps(ys.as_ptr().add(4)),
            _mm_mul_ps(ab, _mm_loadu_ps(xs.as_ptr().add(4))),
        );
        _mm_storeu_ps(ys.as_mut_ptr(), lo);
        _mm_storeu_ps(ys.as_mut_ptr().add(4), hi);
    }
}

//! Vendored std-only stand-in for the `xla` PJRT bindings.
//!
//! The build environment has no network access and no PJRT runtime, so this
//! crate provides the exact API surface `isample::runtime` consumes:
//!
//! * [`Literal`] — a fully functional host tensor (f32/s32 arrays and
//!   tuples). Everything that never touches device execution (parameter
//!   init, checkpoints, host round-trips) works for real.
//! * [`HloModuleProto`] / [`XlaComputation`] / [`PjRtClient`] /
//!   [`PjRtLoadedExecutable`] — load and "compile" HLO text artifacts;
//!   [`PjRtLoadedExecutable::execute`] returns a descriptive error because
//!   no PJRT runtime is linked. Callers gate on artifact availability, so
//!   builds and the artifact-free test/bench suite stay green.
//!
//! All types are plain data and therefore `Send + Sync`, which is what
//! allows the engine to share executables across scoring worker threads.

use std::borrow::Borrow;
use std::fmt;

/// Error type for all stub operations.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn new(msg: impl Into<String>) -> Self {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the stub supports (all the manifest uses).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Array payload of a [`Literal`].
#[derive(Debug, Clone, PartialEq)]
enum Payload {
    F32(Vec<f32>),
    S32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host tensor: dims + typed data, or a tuple of literals.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    payload: Payload,
}

/// Shape of an array literal.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
    element_type: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_type(&self) -> ElementType {
        self.element_type
    }
}

/// Element types that can move in and out of a [`Literal`].
pub trait NativeType: Copy + 'static {
    const ELEMENT_TYPE: ElementType;
    #[doc(hidden)]
    fn vec1_literal(v: &[Self]) -> Literal;
    #[doc(hidden)]
    fn extract(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;

    fn vec1_literal(v: &[Self]) -> Literal {
        Literal { dims: vec![v.len() as i64], payload: Payload::F32(v.to_vec()) }
    }

    fn extract(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.payload {
            Payload::F32(v) => Ok(v.clone()),
            other => Err(Error::new(format!("literal is not f32: {}", payload_kind(other)))),
        }
    }
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;

    fn vec1_literal(v: &[Self]) -> Literal {
        Literal { dims: vec![v.len() as i64], payload: Payload::S32(v.to_vec()) }
    }

    fn extract(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.payload {
            Payload::S32(v) => Ok(v.clone()),
            other => Err(Error::new(format!("literal is not s32: {}", payload_kind(other)))),
        }
    }
}

fn payload_kind(p: &Payload) -> &'static str {
    match p {
        Payload::F32(_) => "f32 array",
        Payload::S32(_) => "s32 array",
        Payload::Tuple(_) => "tuple",
    }
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        T::vec1_literal(v)
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(x: T) -> Literal {
        let mut lit = T::vec1_literal(&[x]);
        lit.dims.clear();
        lit
    }

    /// Tuple literal (what executables return).
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal { dims: vec![], payload: Payload::Tuple(elements) }
    }

    /// Number of array elements (0 for tuples).
    pub fn element_count(&self) -> usize {
        match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::S32(v) => v.len(),
            Payload::Tuple(_) => 0,
        }
    }

    /// Same data, new dims; the element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.payload, Payload::Tuple(_)) {
            return Err(Error::new("cannot reshape a tuple literal"));
        }
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error::new(format!(
                "reshape to {:?} ({} elements) does not match {} elements",
                dims,
                n,
                self.element_count()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), payload: self.payload.clone() })
    }

    /// Shape of an array literal; errors on tuples.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        let element_type = match &self.payload {
            Payload::F32(_) => ElementType::F32,
            Payload::S32(_) => ElementType::S32,
            Payload::Tuple(_) => return Err(Error::new("tuple literal has no array shape")),
        };
        Ok(ArrayShape { dims: self.dims.clone(), element_type })
    }

    /// Copy the elements out as `Vec<T>`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.payload {
            Payload::Tuple(v) => Ok(v),
            other => Err(Error::new(format!(
                "expected a tuple literal, got {}",
                payload_kind(&other)
            ))),
        }
    }
}

/// An HLO module held as text (the AOT artifact format).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    name: String,
    text: String,
}

impl HloModuleProto {
    /// Read an `.hlo.txt` artifact; validates the `HloModule` header.
    pub fn from_text_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("reading {path}: {e}")))?;
        let name = text
            .lines()
            .find_map(|l| l.trim().strip_prefix("HloModule "))
            .map(|rest| rest.split([',', ' ']).next().unwrap_or("").to_string())
            .ok_or_else(|| {
                Error::new(format!("{path}: not HLO text (missing `HloModule` header)"))
            })?;
        Ok(Self { name, text })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn text(&self) -> &str {
        &self.text
    }
}

/// A computation ready for compilation.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    module: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { module: proto.clone() }
    }

    pub fn name(&self) -> &str {
        self.module.name()
    }
}

/// Stub PJRT client; "cpu" platform only.
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub".to_string()
    }

    pub fn compile(&self, computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable { name: computation.name().to_string() })
    }
}

/// A "compiled" executable. Execution requires a real PJRT runtime, which
/// this stub does not link, so [`execute`](Self::execute) always errors.
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable {
    name: String,
}

impl PjRtLoadedExecutable {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(format!(
            "cannot execute HLO module {:?}: this build links the vendored std-only `xla` \
             stub (no PJRT runtime); rebuild against real PJRT bindings to run AOT artifacts",
            self.name
        )))
    }
}

/// A device buffer (host-backed in the stub).
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec1_reshape_roundtrip() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let shaped = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(shaped.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(shaped.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(lit.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn scalar_has_rank_zero() {
        let s = Literal::scalar(0.25f32);
        assert!(s.array_shape().unwrap().dims().is_empty());
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![0.25]);
    }

    #[test]
    fn i32_and_type_mismatch() {
        let lit = Literal::vec1(&[3i32, 1, 4]);
        assert_eq!(lit.array_shape().unwrap().element_type(), ElementType::S32);
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![3, 1, 4]);
        assert!(lit.to_vec::<f32>().is_err());
    }

    #[test]
    fn tuple_decomposes() {
        let t = Literal::tuple(vec![Literal::scalar(1.0f32), Literal::vec1(&[2i32])]);
        let parts = t.clone().to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(t.array_shape().is_err());
        assert!(Literal::scalar(1.0f32).to_tuple().is_err());
    }

    #[test]
    fn execute_is_gated_with_clear_error() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "cpu-stub");
        let dir = std::env::temp_dir().join(format!("xla_stub_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.hlo.txt");
        std::fs::write(&path, "HloModule toy, entry_computation_layout={()->f32[]}\n").unwrap();
        let proto = HloModuleProto::from_text_file(path.to_str().unwrap()).unwrap();
        assert_eq!(proto.name(), "toy");
        assert!(proto.text().contains("HloModule"));
        let exe = client.compile(&XlaComputation::from_proto(&proto)).unwrap();
        let err = exe.execute::<&Literal>(&[]).unwrap_err();
        assert!(err.to_string().contains("PJRT"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_files_are_rejected() {
        let dir = std::env::temp_dir().join(format!("xla_stub_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.txt");
        std::fs::write(&path, "not an hlo module").unwrap();
        assert!(HloModuleProto::from_text_file(path.to_str().unwrap()).is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn everything_is_send_sync() {
        fn check<T: Send + Sync>() {}
        check::<Literal>();
        check::<PjRtClient>();
        check::<PjRtLoadedExecutable>();
        check::<PjRtBuffer>();
        check::<Error>();
    }
}

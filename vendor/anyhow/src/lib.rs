//! Vendored std-only stand-in for the `anyhow` crate.
//!
//! The build environment has no network access, so this repository carries
//! a minimal implementation of the subset the codebase uses: the [`Error`]
//! type with context chaining, the [`Context`] extension trait for
//! `Result`/`Option`, the [`Result`] alias and the [`anyhow!`]/[`bail!`]/
//! [`ensure!`] macros. Semantics mirror the real crate where it matters:
//!
//! * `Display` prints the outermost message only; `{:#}` (alternate) prints
//!   the whole chain joined by `": "`.
//! * `Debug` prints the outermost message plus a `Caused by:` list, which
//!   is what `fn main() -> anyhow::Result<()>` shows on error.
//! * Any `std::error::Error + Send + Sync + 'static` converts via `?`.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>`, with the error type defaulted like the real
/// crate so `anyhow::Result<T, E>` also works.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error chain: outermost context first, root cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single displayable message.
    pub fn msg(message: impl fmt::Display) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, context: impl fmt::Display) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages in the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what lets the blanket conversions below coexist (same trick as the
// real crate).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

mod private {
    /// Anything that can become an [`Error`](crate::Error): either a std
    /// error or an `Error` itself (so `.context()` chains).
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: private::IntoError> Context<T, E> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn context_chains_and_formats() {
        let r: Result<()> = Err(io_err()).context("opening config");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing thing");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("missing thing"), "{dbg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e:#}"), "missing key");
        assert_eq!(Some(3).context("fine").unwrap(), 3);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{:#}", inner().unwrap_err()), "missing thing");
    }

    #[test]
    fn double_context_orders_outermost_first() {
        let r: Result<()> = Err(io_err()).context("inner").context("outer");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner: missing thing");
        assert_eq!(e.root_cause(), "missing thing");
        assert_eq!(e.chain().count(), 3);
    }

    #[test]
    fn macros() {
        fn fails(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            bail!("unconditional {}", 7);
        }
        assert_eq!(format!("{}", fails(false).unwrap_err()), "flag was false");
        assert_eq!(format!("{}", fails(true).unwrap_err()), "unconditional 7");
        let e = anyhow!("x = {}", 1);
        assert_eq!(format!("{e}"), "x = 1");
    }
}

//! Quickstart: train a small MLP on synthetic data with the paper's
//! importance-sampling pipeline and compare against uniform SGD at an equal
//! step budget.
//!
//! Runs out of the box — with AOT artifacts it uses the PJRT engine,
//! without them it falls back to the pure-rust native backend. An
//! optional argument sets the data-parallel batch-compute worker count
//! (default: one per core; results are bit-identical for any count):
//!
//! ```bash
//! cargo run --release --example quickstart -- [train_workers]
//! ```

use isample::coordinator::trainer::{Trainer, TrainerConfig};
use isample::data::synthetic::SyntheticImages;
use isample::runtime::{backend, default_train_workers};

fn main() -> anyhow::Result<()> {
    let train_workers: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or_else(default_train_workers);
    let backend = backend::autodetect("artifacts")?;
    println!("backend: {} | train workers: {train_workers}", backend.name());

    // synthetic "image" classification set matching mlp10 (64 features, 10 classes)
    let split = SyntheticImages::builder(64, 10).samples(8_192).test_samples(2_048).seed(1).split();

    for cfg in [
        TrainerConfig::uniform("mlp10").with_steps(600).with_train_workers(train_workers),
        TrainerConfig::upper_bound("mlp10")
            .with_steps(600)
            .with_presample(384)
            .with_tau_th(1.2)
            .with_train_workers(train_workers),
    ] {
        let name = cfg.strategy.name();
        let mut trainer = Trainer::new(backend.as_ref(), cfg)?;
        let report = trainer.run(&split.train, Some(&split.test))?;
        println!(
            "{name:>12}: {} steps in {:.1}s | train loss {:.4} | test err {:.4} | IS on at step {:?} | tau {:.2}",
            report.steps,
            report.wall_secs,
            report.final_train_loss,
            report.final_test_err,
            report.is_switch_step,
            trainer.tau.tau(),
        );
        println!("{}", trainer.timers.report());
    }
    Ok(())
}

//! The §4.1 ablation (Figs. 1 & 2): how much variance does each sampling
//! scheme remove, and how well do the cheap scores (loss / Eq.-20 upper
//! bound) track the ideal gradient-norm probabilities?
//!
//! ```bash
//! cargo run --release --example variance_ablation -- [model=mlp10] [--full]
//! ```
//! `mlp10` runs in seconds (on PJRT or the native fallback backend);
//! `cnn100` is the paper's actual ablation model (PJRT artifacts only).

use isample::figures::runner::{fig1_variance, fig2_correlation, FigOptions};
use isample::runtime::backend;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).cloned().unwrap_or_else(|| "mlp10".into());
    let quick = !args.iter().any(|a| a == "--full");

    let backend = backend::autodetect("artifacts")?;
    println!("backend: {}", backend.name());
    let opts = FigOptions {
        budget_secs: 0.0, // figs 1/2 are step-based, not budget-based
        out_dir: "results".into(),
        seeds: vec![42],
        quick,
        model: Some(model),
        ..FigOptions::default()
    };
    fig1_variance(backend.as_ref(), &opts)?;
    fig2_correlation(backend.as_ref(), &opts)?;
    println!("CSVs under results/fig1/ and results/fig2/");
    Ok(())
}

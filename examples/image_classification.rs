//! Image classification (the paper's §4.2 workload on the CIFAR stand-in):
//! trains the convnet with every strategy at a fixed wall-clock budget and
//! prints the equal-time comparison the paper's Fig. 3 plots.
//!
//! With AOT artifacts the PJRT convnets run; without them the native
//! backend runs its layer-IR stand-ins (mlp10 and the conv10 convnet).
//!
//! ```bash
//! cargo run --release --example image_classification -- [budget_secs] [model] [train_workers]
//! ```

use isample::figures::runner::{fig3_image, FigOptions};
use isample::runtime::{backend, default_train_workers};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let budget: f64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(45.0);
    let model = args.get(2).cloned();
    let train_workers: usize =
        args.get(3).map(|s| s.parse()).transpose()?.unwrap_or_else(default_train_workers);

    let backend = backend::autodetect("artifacts")?;
    println!("backend: {} | train workers: {train_workers}", backend.name());
    let opts = FigOptions {
        budget_secs: budget,
        out_dir: "results".into(),
        seeds: vec![42],
        quick: budget < 30.0,
        model,
        train_workers,
        ..FigOptions::default()
    };
    fig3_image(backend.as_ref(), &opts)?;
    println!("CSV series under results/fig3_*/ (one file per strategy+seed, plus summary.csv)");
    Ok(())
}

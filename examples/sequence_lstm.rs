//! Sequence classification (§4.4, permuted pixel-by-pixel stand-in): the
//! generality check — the same Algorithm 1 pipeline, no
//! architecture-specific changes, on a sequence model. Prints the Fig.-5
//! comparison (where the paper shows loss-based sampling actively *hurts*).
//!
//! With AOT artifacts the paper's `lstm` runs on PJRT; without them the
//! native backend runs `seq64`, its EmbeddingBag layer-IR sequence net,
//! over the same permuted-raster dataset — so this example works out of
//! the box.
//!
//! ```bash
//! cargo run --release --example sequence_lstm -- [budget_secs]
//! ```

use isample::figures::runner::{fig5_lstm, FigOptions};
use isample::runtime::backend;

fn main() -> anyhow::Result<()> {
    let budget: f64 = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(40.0);
    let backend = backend::autodetect("artifacts")?;
    let opts = FigOptions {
        budget_secs: budget,
        out_dir: "results".into(),
        seeds: vec![42],
        quick: budget < 30.0,
        model: None,
        ..FigOptions::default()
    };
    fig5_lstm(backend.as_ref(), &opts)?;
    println!("CSV series under results/fig5/");
    Ok(())
}

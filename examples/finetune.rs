//! Fine-tuning (§4.3, MIT67 stand-in): frozen-backbone features, trainable
//! head, b=16 / B=48 / τ_th=2 — the regime where importance sampling wins
//! the hardest because most samples are handled correctly almost
//! immediately. Prints the Fig.-4 comparison.
//!
//! The `finetune` model is PJRT-only (needs AOT artifacts); the autodetect
//! fallback reports a clear error listing native models otherwise.
//!
//! ```bash
//! cargo run --release --example finetune -- [budget_secs]
//! ```

use isample::figures::runner::{fig4_finetune, FigOptions};
use isample::runtime::backend;

fn main() -> anyhow::Result<()> {
    let budget: f64 = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(40.0);
    let backend = backend::autodetect("artifacts")?;
    let opts = FigOptions {
        budget_secs: budget,
        out_dir: "results".into(),
        seeds: vec![42],
        quick: budget < 30.0,
        model: None,
        ..FigOptions::default()
    };
    fig4_finetune(backend.as_ref(), &opts)?;
    println!("CSV series under results/fig4/");
    Ok(())
}

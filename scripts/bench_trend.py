#!/usr/bin/env python3
"""Compare BENCH_*.json throughput metrics between two CI runs.

Usage: bench_trend.py CURRENT_DIR PREVIOUS_DIR

Both directories hold per-artifact subdirectories of BENCH_*.json files
(the layout `actions/download-artifact` and `gh run download` produce).
Every metric whose name ends in `steps_per_sec` or `rows_per_sec` is
compared by (artifact-relative path, metric name); a drop larger than
BENCH_TREND_MAX_REGRESSION (fraction, default 0.25) fails the job.

A markdown table goes to $GITHUB_STEP_SUMMARY (when set) and stdout.
Missing previous data — first run on a branch, renamed artifacts, new
metrics — is reported and skipped, never failed: the gate only fires on
a genuine current-vs-previous regression.
"""

import json
import os
import sys
from pathlib import Path

THROUGHPUT_SUFFIXES = ("steps_per_sec", "rows_per_sec")


def collect(root):
    """{(relative file path, metric name): value} for all BENCH_*.json."""
    metrics = {}
    root = Path(root)
    if not root.is_dir():
        return metrics
    for path in sorted(root.rglob("BENCH_*.json")):
        try:
            doc = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"warning: skipping unreadable {path}: {e}")
            continue
        rel = path.relative_to(root).as_posix()
        for name, value in doc.get("metrics", {}).items():
            if name.endswith(THROUGHPUT_SUFFIXES) and isinstance(value, (int, float)):
                metrics[(rel, name)] = float(value)
    return metrics


def main():
    if len(sys.argv) != 3:
        sys.exit(f"usage: {sys.argv[0]} CURRENT_DIR PREVIOUS_DIR")
    current = collect(sys.argv[1])
    previous = collect(sys.argv[2])
    threshold = float(os.environ.get("BENCH_TREND_MAX_REGRESSION", "0.25"))

    lines = ["# Bench trend", ""]
    if not current:
        lines.append("No `BENCH_*.json` artifacts in the current run — nothing to compare.")
        emit(lines)
        return
    if not previous:
        lines.append(
            f"No previous successful run to compare against — "
            f"recorded {len(current)} throughput metric(s) as the new baseline."
        )
        emit(lines)
        return

    lines += [
        f"Regression threshold: **{threshold:.0%}** "
        f"(`BENCH_TREND_MAX_REGRESSION`)",
        "",
        "| artifact | metric | previous | current | change | status |",
        "|---|---|---:|---:|---:|---|",
    ]
    regressions = []
    for key in sorted(current):
        rel, name = key
        cur = current[key]
        prev = previous.get(key)
        if prev is None:
            lines.append(f"| {rel} | {name} | — | {cur:,.1f} | — | new |")
            continue
        if prev <= 0.0:
            lines.append(f"| {rel} | {name} | {prev:,.1f} | {cur:,.1f} | — | skipped |")
            continue
        change = (cur - prev) / prev
        if change < -threshold:
            status = "REGRESSION"
            regressions.append(f"{rel}:{name} {prev:,.1f} -> {cur:,.1f} ({change:+.1%})")
        else:
            status = "ok"
        lines.append(
            f"| {rel} | {name} | {prev:,.1f} | {cur:,.1f} | {change:+.1%} | {status} |"
        )
    gone = sorted(set(previous) - set(current))
    for rel, name in gone:
        lines.append(f"| {rel} | {name} | {previous[(rel, name)]:,.1f} | — | — | removed |")

    lines.append("")
    if regressions:
        lines.append(f"**{len(regressions)} metric(s) regressed more than {threshold:.0%}:**")
        lines += [f"- `{r}`" for r in regressions]
    else:
        lines.append(f"All {len(current)} throughput metric(s) within the threshold.")
    emit(lines)
    if regressions:
        sys.exit(1)


def emit(lines):
    text = "\n".join(lines) + "\n"
    print(text)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a", encoding="utf-8") as f:
            f.write(text)


if __name__ == "__main__":
    main()

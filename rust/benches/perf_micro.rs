//! §Perf micro benchmarks: every stage of the L3 hot path in isolation,
//! plus the PJRT entry points. These are the numbers tracked in
//! EXPERIMENTS.md §Perf (before/after for each optimization iteration).
//!
//! `cargo bench --bench perf_micro` — add `-- --filter NAME` to run a
//! subset, `--target-ms N` to change per-bench time (the
//! `ISAMPLE_BENCH_TARGET_MS` env var caps it too — CI's quick mode).
//!
//! The `score/` section measures serial-vs-sharded presample scoring on
//! the pure-rust [`NativeScorer`] (no artifacts needed), asserts the
//! parallel path is bit-identical to serial, and writes the
//! serial/parallel throughput comparison to `BENCH_scoring.json`
//! (`--out-json PATH` to relocate) — the per-PR perf trajectory artifact.
//!
//! The `train/` section runs real end-to-end Algorithm-1 training on the
//! native CPU backend (uniform and upper-bound at equal step counts, on
//! both the mlp10 MLP and the conv10 layer-IR convnet)
//! across a `--train-workers` scaling sweep (1/2/4/cores by default;
//! `--train-workers N` narrows it to {1, N} — CI's worker matrix),
//! asserts every parallel run is bit-identical to serial (trajectory
//! digest + final-state checksum), and writes per-worker-count steps/sec
//! to `BENCH_train.json` (`--out-json-train PATH`, `--train-steps N`;
//! `ISAMPLE_BENCH_TARGET_MS` also scales the default step count so the
//! CI smoke matrix stays inside the old single-job budget) — the
//! training-throughput trajectory artifact, now a scaling curve.
//!
//! PJRT engine benches run only when AOT artifacts are present.

use std::time::Duration;

use isample::config::Args;
use isample::coordinator::pipeline::gather_rows;
use isample::coordinator::resample::{AliasSampler, CumulativeSampler};
use isample::coordinator::sampler::resample_from_scores;
use isample::coordinator::tau::TauEstimator;
use isample::coordinator::trainer::{Trainer, TrainerConfig};
use isample::data::synthetic::SyntheticImages;
use isample::data::Dataset;
use isample::runtime::checkpoint::state_checksum;
use isample::runtime::score::{default_score_workers, NativeScorer, ScoreBackend, ScoreKind};
use isample::runtime::{default_train_workers, Engine, NativeEngine};
use isample::util::bench::{bench, black_box, target_from_env, BenchSuite};
use isample::util::digest::digest_f64;
use isample::util::rng::SplitMix64;
use isample::util::stats::normalize_probs;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))?;
    let filter = args.flag("filter").unwrap_or("").to_string();
    let default_ms = target_from_env(1500).as_millis() as u64;
    let target = Duration::from_millis(args.flag_u64("target-ms", default_ms)?);
    let run = |name: &str| filter.is_empty() || name.contains(&filter);

    let mut rng = SplitMix64::new(42);

    // ---------------- pure-rust pipeline stages ----------------
    let scores: Vec<f32> = (0..640).map(|i| 0.01 + ((i * 37) % 100) as f32 / 100.0).collect();
    let probs = normalize_probs(&scores);

    if run("sampler/alias_build_640") {
        bench("sampler/alias_build_640", target, || {
            black_box(AliasSampler::new(black_box(&probs)));
        });
    }
    if run("sampler/cdf_build_640") {
        bench("sampler/cdf_build_640", target, || {
            black_box(CumulativeSampler::new(black_box(&probs)));
        });
    }
    if run("sampler/alias_draw128_of_640") {
        let s = AliasSampler::new(&probs);
        bench("sampler/alias_draw128_of_640", target, || {
            black_box(s.sample(&mut rng, 128));
        });
    }
    if run("sampler/cdf_draw128_of_640") {
        let s = CumulativeSampler::new(&probs);
        bench("sampler/cdf_draw128_of_640", target, || {
            black_box(s.sample(&mut rng, 128));
        });
    }
    if run("sampler/full_resample_plan") {
        bench("sampler/full_resample_plan", target, || {
            black_box(resample_from_scores(black_box(&scores), 128, &mut rng, true));
        });
    }
    if run("tau/estimate_640") {
        bench("tau/estimate_640", target, || {
            black_box(TauEstimator::tau_from_scores(black_box(&scores)));
        });
    }

    // data generation (the producer side of the prefetch pipeline)
    let ds = SyntheticImages::builder(768, 100).samples(16_384).seed(1).augment(true).build();
    let idx640: Vec<usize> = (0..640).map(|i| i * 17 % 16_384).collect();
    if run("data/batch640_d768") {
        bench("data/batch640_d768", target, || {
            black_box(ds.batch(black_box(&idx640), 1));
        });
    }
    if run("data/gather128_from_640") {
        let (x, y) = ds.batch(&idx640, 1);
        let pb = isample::coordinator::pipeline::PrefetchedBatch {
            indices: idx640.clone(),
            x,
            y,
            epoch: 1,
        };
        let positions: Vec<usize> = (0..128).map(|i| (i * 5) % 640).collect();
        bench("data/gather128_from_640", target, || {
            black_box(gather_rows(black_box(&pb), black_box(&positions)));
        });
    }

    // ---------------- sharded presample scoring ----------------
    // B=640 at CIFAR-ish dims (§4.2 configuration), scored by the native
    // MLP so the serial/parallel comparison runs without artifacts. The
    // speedup metric in BENCH_scoring.json is the acceptance number.
    if run("score/") {
        let mut suite = BenchSuite::new();
        let scorer = NativeScorer::new(768, 256, 100, 42);
        let (xp, yp) = ds.batch(&idx640, 0);

        let serial_scores = ScoreBackend::Serial.score(&scorer, &xp, &yp, ScoreKind::UpperBound)?;
        let r_serial = bench("score/native_B640_serial", target, || {
            black_box(
                ScoreBackend::Serial
                    .score(black_box(&scorer), &xp, &yp, ScoreKind::UpperBound)
                    .unwrap(),
            );
        });
        suite.metric("rows", 640.0);
        suite.metric("serial_rows_per_sec", r_serial.rows_per_sec(640));

        let mut worker_counts = vec![2usize, 4];
        let avail = default_score_workers();
        if avail > 4 {
            worker_counts.push(avail);
        }
        for &workers in &worker_counts {
            let backend = ScoreBackend::from_workers(workers);
            let parallel_scores = backend.score(&scorer, &xp, &yp, ScoreKind::UpperBound)?;
            assert_eq!(
                parallel_scores, serial_scores,
                "parallel scoring must be bit-identical to serial ({workers} workers)"
            );
            let r = bench(&format!("score/native_B640_w{workers}"), target, || {
                black_box(
                    backend.score(black_box(&scorer), &xp, &yp, ScoreKind::UpperBound).unwrap(),
                );
            });
            let speedup = r_serial.mean_ns / r.mean_ns.max(1e-9);
            println!(
                "score: {workers} workers -> {:.2}x vs serial ({:.0} rows/s)",
                speedup,
                r.rows_per_sec(640)
            );
            suite.metric(&format!("speedup_w{workers}_vs_serial"), speedup);
            suite.metric(&format!("w{workers}_rows_per_sec"), r.rows_per_sec(640));
            suite.push(r);
        }
        suite.push(r_serial);
        suite.metric("available_parallelism", avail as f64);

        let out = args.flag("out-json").unwrap_or("BENCH_scoring.json");
        suite.write_json(out)?;
        println!("scoring bench results -> {out}");
    }

    // ---------------- native end-to-end training throughput ------------
    // Real Algorithm-1 runs on the pure-rust backend: uniform vs
    // upper-bound (warmup -> tau switch -> presample/score/resample) at an
    // equal step count, swept over --train-workers. Per-worker steps/sec
    // is the BENCH_train.json acceptance number (the scaling curve);
    // every parallel run must be bit-identical to the 1-worker run.
    if run("train/") {
        let mut suite = BenchSuite::new();
        let native = NativeEngine::with_default_models();
        // ISAMPLE_BENCH_TARGET_MS (or --target-ms) caps per-bench time;
        // scale the fixed-step training runs proportionally so CI's
        // quick mode shrinks this section too.
        let default_steps = ((300 * target.as_millis() as u64) / 1500).clamp(60, 300);
        let steps = args.flag_u64("train-steps", default_steps)?;
        let sweep: Vec<usize> = match args.flag("train-workers") {
            // explicit count: compare exactly serial vs that count
            Some(_) => {
                let n = args.flag_train_workers()?;
                if n == 1 {
                    vec![1]
                } else {
                    vec![1, n]
                }
            }
            None => {
                let mut v = vec![1, 2, 4, default_train_workers()];
                v.sort_unstable();
                v.dedup();
                v
            }
        };
        let split =
            SyntheticImages::builder(64, 10).samples(8_192).test_samples(1_024).seed(3).split();
        // Two architectures through the same harness: the mlp10 stand-in
        // (metric names unchanged for cross-PR comparability) and the
        // conv10 layer-IR convnet (metrics prefixed `conv10_`), so the
        // BENCH_train.json trajectory stops being MLP-only.
        for (prefix, model) in [("", "mlp10"), ("conv10_", "conv10")] {
            for (tag, base) in [
                ("uniform", TrainerConfig::uniform(model)),
                (
                    "upper_bound",
                    TrainerConfig::upper_bound(model).with_presample(384).with_tau_th(1.2),
                ),
            ] {
                // (trajectory digest, state checksum) of the serial run —
                // the reference every parallel worker count must reproduce
                let mut reference: Option<(u64, u64)> = None;
                let mut serial_sps = f64::NAN;
                for &workers in &sweep {
                    let cfg = base
                        .clone()
                        .with_steps(steps)
                        .with_seed(17)
                        .with_score_workers(args.flag_score_workers()?)
                        .with_train_workers(workers);
                    let mut trainer = Trainer::new(&native, cfg)?;
                    let report = trainer.run(&split.train, None)?;
                    let traj = digest_f64(report.log.rows.iter().map(|r| r.train_loss));
                    let state = state_checksum(&trainer.state)?;
                    if let Some(r) = reference {
                        assert_eq!(
                            (traj, state),
                            r,
                            "train/{model}/{tag}: {workers}-worker run diverged from serial"
                        );
                    } else {
                        reference = Some((traj, state));
                    }
                    let sps = report.steps as f64 / report.wall_secs.max(1e-9);
                    if workers == 1 {
                        serial_sps = sps;
                        let name = format!("{prefix}{tag}_final_train_loss");
                        suite.metric(&name, report.final_train_loss);
                    }
                    println!(
                        "train/native_{model}_{tag}_w{workers}: {} steps -> {sps:.1} steps/s \
                         ({:.2}x vs serial, final loss {:.4}, IS@{:?})",
                        report.steps,
                        sps / serial_sps.max(1e-9),
                        report.final_train_loss,
                        report.is_switch_step
                    );
                    suite.metric(&format!("{prefix}{tag}_w{workers}_steps_per_sec"), sps);
                    if workers > 1 {
                        suite.metric(
                            &format!("{prefix}{tag}_speedup_w{workers}_vs_serial"),
                            sps / serial_sps.max(1e-9),
                        );
                    }
                }
            }
        }
        suite.metric("train_steps", steps as f64);
        suite.metric("train_worker_counts", sweep.len() as f64);
        suite.metric("available_parallelism", default_train_workers() as f64);
        let out = args.flag("out-json-train").unwrap_or("BENCH_train.json");
        suite.write_json(out)?;
        println!("training bench results -> {out}");
    }

    // ---------------- PJRT entry points (need AOT artifacts) -----------
    let engine = match Engine::load(args.flag("artifacts").unwrap_or("artifacts")) {
        Ok(engine) => engine,
        Err(e) => {
            println!("skipping PJRT engine benches (no artifacts): {e:#}");
            return Ok(());
        }
    };
    for model in ["mlp10", "cnn100", "lstm"] {
        if engine.model_info(model).is_err() {
            continue;
        }
        engine.warmup(model)?; // exclude compile time from the numbers
        let info = engine.model_info(model)?.clone();
        let mut state = engine.init_state(model, 1)?;
        let d = info.feature_dim;
        let gen = SyntheticImages::builder(d, info.num_classes).samples(4096).seed(2).build();
        let bidx: Vec<usize> = (0..info.batch).collect();
        let (xb, yb) = gen.batch(&bidx, 0);
        let w = vec![1.0f32; info.batch];
        if run(&format!("engine/{model}/train_step")) {
            bench(&format!("engine/{model}/train_step_b{}", info.batch), target, || {
                black_box(engine.train_step(&mut state, &xb, &yb, &w, 0.01).unwrap());
            });
        }
        let bmax = *info.presample.iter().max().unwrap_or(&info.batch);
        let pidx: Vec<usize> = (0..bmax).collect();
        let (xp, yp) = gen.batch(&pidx, 0);
        if run(&format!("engine/{model}/fwd_scores")) {
            bench(&format!("engine/{model}/fwd_scores_B{bmax}"), target, || {
                black_box(engine.fwd_scores(&state, &xp, &yp).unwrap());
            });
        }
        if info.has_entry("grad_norms") && run(&format!("engine/{model}/grad_norms")) {
            bench(&format!("engine/{model}/grad_norms_B{bmax}"), target, || {
                black_box(engine.grad_norms(&state, &xp, &yp).unwrap());
            });
        }
    }

    Ok(())
}

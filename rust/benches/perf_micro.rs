//! §Perf micro benchmarks: every stage of the L3 hot path in isolation,
//! plus the PJRT entry points. These are the numbers tracked in
//! EXPERIMENTS.md §Perf (before/after for each optimization iteration).
//!
//! `cargo bench --bench perf_micro` — add `-- --filter NAME` to run a
//! subset, `--target-ms N` to change per-bench time (the
//! `ISAMPLE_BENCH_TARGET_MS` env var caps it too — CI's quick mode).
//!
//! The `kernels/` section compares the block-batched compute kernels
//! against the scalar-reference layer walk (forward+backward rows/sec per
//! model, plus the score-only fast path vs a full-scratch forward),
//! asserts the block path is **bit-identical** to the reference *and* at
//! least 2.5x faster on mlp10 and conv10 (the SIMD-era acceptance floor —
//! raised from ISSUE 5's autovectorizer-era 1.5x; gated on best-observed
//! iterations so runner noise cannot flake it), and writes
//! `BENCH_kernels.json` (`--out-json-kernels PATH`). Two extra legs ride
//! along under the `bench_trend.py` gate: `simd_vs_autovec` (the blocked
//! walk with dispatch pinned to the scalar tiles vs the explicit-SIMD
//! default — what the hand-written lanes buy over the autovectorizer) and
//! `bf16_score` (the bf16-storage scoring fast path vs the f32 one).
//!
//! The `score/` section measures serial-vs-sharded presample scoring on
//! the pure-rust [`NativeScorer`] (no artifacts needed), asserts the
//! parallel path is bit-identical to serial, and writes the
//! serial/parallel throughput comparison to `BENCH_scoring.json`
//! (`--out-json PATH` to relocate) — the per-PR perf trajectory artifact.
//!
//! The `train/` section runs real end-to-end Algorithm-1 training on the
//! native CPU backend (uniform and upper-bound at equal step counts, on
//! both the mlp10 MLP and the conv10 layer-IR convnet)
//! across a `--train-workers` scaling sweep (1/2/4/cores by default;
//! `--train-workers N` narrows it to {1, N} — CI's worker matrix),
//! asserts every parallel run is bit-identical to serial (trajectory
//! digest + final-state checksum), and writes per-worker-count steps/sec
//! to `BENCH_train.json` (`--out-json-train PATH`, `--train-steps N`;
//! `ISAMPLE_BENCH_TARGET_MS` also scales the default step count so the
//! CI smoke matrix stays inside the old single-job budget) — the
//! training-throughput trajectory artifact, now a scaling curve.
//!
//! The `streaming/` section exercises the out-of-core data plane: it
//! writes a 131k-sample pool to a shard store, streams it back with and
//! without readahead, and compares the staleness-cached presample pass
//! against full re-scoring (asserted at least 2x faster on best observed
//! iterations — the ISSUE 6 acceptance floor), writing
//! `BENCH_streaming.json` (`--out-json-streaming PATH`).
//!
//! The `dist/` section benchmarks the distributed coordinator (ISSUE 10):
//! fixed-seed training steps farmed out over the wire protocol at 1/2/4
//! workers vs the in-process serial engine (steps/sec each, digests
//! asserted bit-identical), plus a worker-killed-mid-run recovery leg
//! whose worst-case step time lands in `recovery_after_kill_ms` — all
//! written to `BENCH_distributed.json` (`--out-json-dist PATH`).
//!
//! The `sampler/scale` section sweeps the Fenwick resampler over pool
//! sizes n ∈ {1k, 131k, 1M}: full build vs a warm-cache 512-leaf
//! partial-update cycle vs a 128-draw plan, asserts the update path is at
//! least 5x the build path at n = 1M and that both maintenance cycles
//! grow sublinearly (at most 128x for 1000x the leaves), re-checks the
//! bitwise update==rebuild contract at every size, and writes
//! `BENCH_sampler.json` (`--out-json-sampler PATH`).
//!
//! PJRT engine benches run only when AOT artifacts are present.

use std::time::Duration;

use isample::config::Args;
use isample::coordinator::cache::ScoreCache;
use isample::coordinator::pipeline::gather_rows;
use isample::coordinator::resample::{AliasSampler, CumulativeSampler, FenwickSampler, SamplerKind};
use isample::coordinator::sampler::resample_from_scores;
use isample::coordinator::tau::TauEstimator;
use isample::coordinator::trainer::{Trainer, TrainerConfig};
use isample::data::shard;
use isample::data::synthetic::SyntheticImages;
use isample::data::Dataset;
use isample::dist::{DistEngine, FaultPlan};
use isample::runtime::checkpoint::state_checksum;
use isample::runtime::init::init_params;
use isample::runtime::kernels::MAX_BLOCK_ROWS;
use isample::runtime::score::{default_score_workers, NativeScorer, ScoreBackend, ScoreKind};
use isample::runtime::{
    default_train_workers, set_forced_kernel_path, Backend, BlockScratch, Engine, KernelPath,
    NativeEngine, NativeModelSpec,
};
use isample::util::bench::{bench, black_box, target_from_env, BenchSuite};
use isample::util::digest::digest_f64;
use isample::util::rng::SplitMix64;
use isample::util::stats::normalize_probs;
use isample::util::timer::Stopwatch;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))?;
    let filter = args.flag("filter").unwrap_or("").to_string();
    let default_ms = target_from_env(1500).as_millis() as u64;
    let target = Duration::from_millis(args.flag_u64("target-ms", default_ms)?);
    let run = |name: &str| filter.is_empty() || name.contains(&filter);

    let mut rng = SplitMix64::new(42);

    // ---------------- pure-rust pipeline stages ----------------
    let scores: Vec<f32> = (0..640).map(|i| 0.01 + ((i * 37) % 100) as f32 / 100.0).collect();
    let probs = normalize_probs(&scores);

    if run("sampler/alias_build_640") {
        bench("sampler/alias_build_640", target, || {
            black_box(AliasSampler::new(black_box(&probs)));
        });
    }
    if run("sampler/cdf_build_640") {
        bench("sampler/cdf_build_640", target, || {
            black_box(CumulativeSampler::new(black_box(&probs)));
        });
    }
    if run("sampler/alias_draw128_of_640") {
        let s = AliasSampler::new(&probs);
        bench("sampler/alias_draw128_of_640", target, || {
            black_box(s.sample(&mut rng, 128));
        });
    }
    if run("sampler/cdf_draw128_of_640") {
        let s = CumulativeSampler::new(&probs);
        bench("sampler/cdf_draw128_of_640", target, || {
            black_box(s.sample(&mut rng, 128));
        });
    }
    if run("sampler/fenwick_build_640") {
        bench("sampler/fenwick_build_640", target, || {
            black_box(FenwickSampler::new(black_box(&probs)));
        });
    }
    if run("sampler/fenwick_draw128_of_640") {
        let s = FenwickSampler::new(&probs);
        bench("sampler/fenwick_draw128_of_640", target, || {
            black_box(s.sample(&mut rng, 128));
        });
    }
    if run("sampler/full_resample_plan") {
        bench("sampler/full_resample_plan", target, || {
            black_box(resample_from_scores(black_box(&scores), 128, &mut rng, SamplerKind::Alias));
        });
    }
    if run("tau/estimate_640") {
        bench("tau/estimate_640", target, || {
            black_box(TauEstimator::tau_from_scores(black_box(&scores)));
        });
    }

    // ---------------- sampler scale sweep (ISSUE 8) ----------------
    // Fenwick build vs partial-update vs draw throughput at n ∈ {1k, 131k,
    // 1M}, written to BENCH_sampler.json (--out-json-sampler PATH). The
    // acceptance numbers: a warm-cache partial-update cycle (512 stale
    // leaves) beats a full rebuild by >= 5x at n = 1M on best observed
    // iterations, and per-cycle maintenance grows sublinearly in n — the
    // leaf count grows 1000x from 1k to 1M, the update and draw cycles may
    // grow by at most 128x. A bitwise update-vs-rebuild check rides along
    // at every size.
    if run("sampler/scale") {
        let mut suite = BenchSuite::new();
        let dirty = 512usize;
        let mut build_1m = f64::NAN;
        let mut upd_1k = f64::NAN;
        let mut upd_1m = f64::NAN;
        let mut draw_1k = f64::NAN;
        let mut draw_1m = f64::NAN;
        for &(n, tag) in &[(1_000usize, "1k"), (131_072, "131k"), (1_000_000, "1M")] {
            let weights: Vec<f32> =
                (0..n).map(|i| 0.01 + ((i * 37) % 1000) as f32 / 1000.0).collect();
            let r_build = bench(&format!("sampler/scale_fenwick_build_{tag}"), target, || {
                black_box(FenwickSampler::new(black_box(&weights)));
            });

            // bitwise update == rebuild at this size
            let stride = (n / dirty).max(1);
            let mut leaves = weights.clone();
            let mut mutated = FenwickSampler::new(&weights);
            for k in 0..dirty {
                let i = (k * stride) % n;
                let v = 0.25 + k as f32;
                leaves[i] = v;
                mutated.update(i, v);
            }
            let fresh = FenwickSampler::new(&leaves);
            assert_eq!(
                mutated.total_mass().to_bits(),
                fresh.total_mass().to_bits(),
                "sampler/scale_{tag}: updated tree diverged bitwise from a fresh build"
            );

            // warm-cache maintenance cycle: `dirty` scattered fresh scores
            let mut tree = FenwickSampler::new(&weights);
            let mut tick = 0u32;
            let r_update =
                bench(&format!("sampler/scale_fenwick_update{dirty}_{tag}"), target, || {
                    tick = tick.wrapping_add(1);
                    let base = 0.5 + (tick % 7) as f32;
                    for k in 0..dirty {
                        tree.update((k * stride) % n, base + k as f32 * 1e-3);
                    }
                    black_box(tree.total_mass());
                });
            let r_draw = bench(&format!("sampler/scale_fenwick_draw128_{tag}"), target, || {
                black_box(tree.sample(&mut rng, 128));
            });
            println!(
                "sampler/scale_{tag}: build {:.0} rows/s, update-cycle {:.0} rows/s, \
                 draw {:.0} rows/s",
                r_build.rows_per_sec(n),
                r_update.rows_per_sec(dirty),
                r_draw.rows_per_sec(128)
            );
            suite.metric(&format!("fenwick_build_{tag}_rows_per_sec"), r_build.rows_per_sec(n));
            suite.metric(
                &format!("fenwick_update_cycle_{tag}_rows_per_sec"),
                r_update.rows_per_sec(dirty),
            );
            suite.metric(&format!("fenwick_draw_{tag}_rows_per_sec"), r_draw.rows_per_sec(128));
            match tag {
                "1k" => {
                    upd_1k = r_update.min_ns;
                    draw_1k = r_draw.min_ns;
                }
                "1M" => {
                    build_1m = r_build.min_ns;
                    upd_1m = r_update.min_ns;
                    draw_1m = r_draw.min_ns;
                }
                _ => {}
            }
            suite.push(r_build);
            suite.push(r_update);
            suite.push(r_draw);
        }
        // acceptance floor: warm-cache partial updates vs full rebuild at
        // 1M, best observed iterations (noise-robust, like kernels/)
        let update_vs_build_best = build_1m / upd_1m.max(1e-9);
        println!(
            "sampler/scale: 1M update-cycle is {update_vs_build_best:.1}x the full build \
             (best observed)"
        );
        assert!(
            update_vs_build_best >= 5.0,
            "sampler/scale: {dirty}-leaf partial-update cycle at n=1M is only \
             {update_vs_build_best:.2}x a full rebuild (acceptance floor: 5x)"
        );
        // sublinearity: 1000x more leaves may cost at most 128x per cycle
        let update_growth = upd_1m / upd_1k.max(1e-9);
        let draw_growth = draw_1m / draw_1k.max(1e-9);
        assert!(
            update_growth <= 128.0,
            "sampler/scale: update cycle grew {update_growth:.1}x from 1k to 1M leaves \
             (sublinearity bound: 128x for 1000x the leaves)"
        );
        assert!(
            draw_growth <= 128.0,
            "sampler/scale: draw cycle grew {draw_growth:.1}x from 1k to 1M leaves \
             (sublinearity bound: 128x for 1000x the leaves)"
        );
        suite.metric("dirty_leaves", dirty as f64);
        suite.metric("update_vs_build_best_speedup_1M", update_vs_build_best);
        suite.metric("update_cycle_growth_1k_to_1M", update_growth);
        suite.metric("draw_cycle_growth_1k_to_1M", draw_growth);
        let out = args.flag("out-json-sampler").unwrap_or("BENCH_sampler.json");
        suite.write_json(out)?;
        println!("sampler bench results -> {out}");
    }

    // data generation (the producer side of the prefetch pipeline)
    let ds = SyntheticImages::builder(768, 100).samples(16_384).seed(1).augment(true).build();
    let idx640: Vec<usize> = (0..640).map(|i| i * 17 % 16_384).collect();
    if run("data/batch640_d768") {
        bench("data/batch640_d768", target, || {
            black_box(ds.batch(black_box(&idx640), 1));
        });
    }
    if run("data/gather128_from_640") {
        let (x, y) = ds.batch(&idx640, 1);
        let pb = isample::coordinator::pipeline::PrefetchedBatch {
            indices: idx640.clone(),
            x,
            y,
            epoch: 1,
        };
        let positions: Vec<usize> = (0..128).map(|i| (i * 5) % 640).collect();
        bench("data/gather128_from_640", target, || {
            black_box(gather_rows(black_box(&pb), black_box(&positions)));
        });
    }

    // ---------------- block compute kernels ----------------
    // Blocked vs scalar-reference rows/sec for the native layer walks
    // (acceptance: blocked fwd+bwd >= 2.5x the scalar reference on mlp10
    // and conv10 with the explicit-SIMD tiles — raised from ISSUE 5's
    // autovectorizer-era 1.5x; asserted here, recorded in
    // BENCH_kernels.json), the blocked walk on the scalar-tile dispatch
    // path (the `simd_vs_autovec` leg), the score-only fast path vs the
    // old full-scratch per-row forward, and the bf16-storage scoring fast
    // path vs the f32 one (the `bf16_score` leg). Outputs are
    // additionally asserted bit-identical (f32 legs) or path-invariant
    // (bf16), so this bench doubles as a kernel-correctness smoke.
    if run("kernels/") {
        let mut suite = BenchSuite::new();
        let native = NativeEngine::with_default_models();
        let mut kr = SplitMix64::new(7);
        for model_name in ["mlp10", "conv10"] {
            let m = native.layer_model(model_name)?.clone();
            let params = init_params(11, &m.param_specs());
            let rows = 256usize;
            let d = m.in_dim();
            let c = m.num_classes();
            let x: Vec<f32> = (0..rows * d).map(|_| kr.uniform_range(-1.0, 1.0) as f32).collect();
            let y: Vec<i32> = (0..rows).map(|i| (i % c) as i32).collect();
            let coeff = 1.0f32 / rows as f32;

            // forward+backward: the scalar reference row walk
            let mut s = m.scratch();
            let mut grads_ref = m.zero_grads();
            let r_scalar = bench(&format!("kernels/{model_name}/fwd_bwd_scalar"), target, || {
                for g in grads_ref.iter_mut() {
                    g.fill(0.0);
                }
                for r in 0..rows {
                    let xr = &x[r * d..(r + 1) * d];
                    m.forward_row(&params, xr, &mut s);
                    let yy = m.clamp_label(y[r]);
                    let gz = s.probs_mut();
                    gz[yy] -= 1.0;
                    for g in gz.iter_mut() {
                        *g *= coeff;
                    }
                    m.backward_row(&params, xr, &mut s, &mut grads_ref);
                }
                black_box(&grads_ref);
            });

            // forward+backward: the block-kernel walk (shared by the
            // default-dispatch and forced-scalar-tile legs)
            let mut bs = m.block_scratch();
            let mut grads_blk = m.zero_grads();
            let blocked_walk = |bs: &mut BlockScratch, grads: &mut Vec<Vec<f32>>| {
                for g in grads.iter_mut() {
                    g.fill(0.0);
                }
                let mut start = 0usize;
                while start < rows {
                    let b = (rows - start).min(MAX_BLOCK_ROWS);
                    let xb = &x[start * d..(start + b) * d];
                    m.forward_block(&params, xb, b, bs);
                    let pm = bs.probs_mut();
                    for r in 0..b {
                        let yy = m.clamp_label(y[start + r]);
                        let gz = &mut pm[r * c..(r + 1) * c];
                        gz[yy] -= 1.0;
                        for g in gz.iter_mut() {
                            *g *= coeff;
                        }
                    }
                    m.backward_block(&params, xb, b, bs, grads);
                    start += b;
                }
            };
            let r_block = bench(&format!("kernels/{model_name}/fwd_bwd_blocked"), target, || {
                blocked_walk(&mut bs, &mut grads_blk);
                black_box(&grads_blk);
            });
            assert_eq!(
                grads_blk, grads_ref,
                "kernels/{model_name}: block gradients must be bit-identical to scalar"
            );
            let speedup = r_scalar.mean_ns / r_block.mean_ns.max(1e-9);
            // Noise-robust acceptance gate: compare best observed
            // iterations. Contention on shared CI runners inflates means
            // but essentially never deflates minima, so a best-case ratio
            // under the floor is a genuine kernel regression — the gate
            // stays hard without going flaky in quick-mode smoke runs.
            let speedup_best = r_scalar.min_ns / r_block.min_ns.max(1e-9);
            println!(
                "kernels/{model_name}: blocked fwd+bwd {speedup:.2}x scalar \
                 (best {speedup_best:.2}x, {:.0} vs {:.0} rows/s)",
                r_block.rows_per_sec(rows),
                r_scalar.rows_per_sec(rows)
            );
            assert!(
                speedup_best >= 2.5,
                "kernels/{model_name}: blocked fwd+bwd best case is only {speedup_best:.2}x \
                 the scalar reference (mean {speedup:.2}x; acceptance floor: 2.5x)"
            );
            let sps_scalar = r_scalar.rows_per_sec(rows);
            let sps_block = r_block.rows_per_sec(rows);
            suite.metric(&format!("{model_name}_fwd_bwd_speedup_blocked_vs_scalar"), speedup);
            suite.metric(&format!("{model_name}_fwd_bwd_best_speedup"), speedup_best);
            suite.metric(&format!("{model_name}_fwd_bwd_scalar_rows_per_sec"), sps_scalar);
            suite.metric(&format!("{model_name}_fwd_bwd_blocked_rows_per_sec"), sps_block);

            // simd_vs_autovec leg: the same blocked walk with dispatch
            // pinned to the scalar tiles — what the explicit lanes buy
            // over the autovectorizer. Bit-identity across paths is the
            // tentpole contract, so the gradients must not move.
            set_forced_kernel_path(Some(KernelPath::Scalar));
            let r_autovec =
                bench(&format!("kernels/{model_name}/fwd_bwd_blocked_scalar_tiles"), target, || {
                    blocked_walk(&mut bs, &mut grads_blk);
                    black_box(&grads_blk);
                });
            set_forced_kernel_path(None);
            assert_eq!(
                grads_blk, grads_ref,
                "kernels/{model_name}: scalar-tile gradients must be bit-identical too"
            );
            let simd_vs_autovec = r_autovec.mean_ns / r_block.mean_ns.max(1e-9);
            let simd_vs_autovec_best = r_autovec.min_ns / r_block.min_ns.max(1e-9);
            println!(
                "kernels/{model_name}: SIMD tiles {simd_vs_autovec:.2}x the autovectorized \
                 scalar tiles (best {simd_vs_autovec_best:.2}x, {:.0} rows/s scalar tiles)",
                r_autovec.rows_per_sec(rows)
            );
            suite.metric(&format!("{model_name}_simd_vs_autovec_speedup"), simd_vs_autovec);
            suite.metric(
                &format!("{model_name}_simd_vs_autovec_best_speedup"),
                simd_vs_autovec_best,
            );
            suite.metric(
                &format!("{model_name}_fwd_bwd_scalar_tiles_rows_per_sec"),
                r_autovec.rows_per_sec(rows),
            );

            // score-only fast path vs the old full-scratch per-row forward
            let mut loss_b = vec![0.0f32; rows];
            let mut ub_b = vec![0.0f32; rows];
            let r_fast = bench(&format!("kernels/{model_name}/score_fastpath"), target, || {
                let mut start = 0usize;
                while start < rows {
                    let b = (rows - start).min(MAX_BLOCK_ROWS);
                    m.scores_block(
                        &params,
                        &x[start * d..(start + b) * d],
                        &y[start..start + b],
                        b,
                        &mut bs,
                        &mut loss_b[start..start + b],
                        &mut ub_b[start..start + b],
                    );
                    start += b;
                }
                black_box(&ub_b);
            });
            let r_slow = bench(&format!("kernels/{model_name}/score_full_scratch"), target, || {
                // the pre-kernel scorer body: fresh scratch, per-row walk
                let mut s2 = m.scratch();
                let mut out = Vec::with_capacity(rows);
                for r in 0..rows {
                    let (_, ub) = m.row_scores(&params, &x[r * d..(r + 1) * d], y[r], &mut s2);
                    out.push(ub);
                }
                black_box(&out);
            });
            let ub_ref: Vec<f32> = {
                let mut s2 = m.scratch();
                (0..rows)
                    .map(|r| m.row_scores(&params, &x[r * d..(r + 1) * d], y[r], &mut s2).1)
                    .collect()
            };
            assert_eq!(ub_b, ub_ref, "kernels/{model_name}: fast-path scores diverged");
            let score_speedup = r_slow.mean_ns / r_fast.mean_ns.max(1e-9);
            println!(
                "kernels/{model_name}: score fast path {score_speedup:.2}x full-scratch \
                 ({:.0} rows/s)",
                r_fast.rows_per_sec(rows)
            );
            let fast_rps = r_fast.rows_per_sec(rows);
            suite.metric(&format!("{model_name}_score_fastpath_speedup"), score_speedup);
            suite.metric(&format!("{model_name}_score_fastpath_rows_per_sec"), fast_rps);

            // bf16_score leg: the reduced-precision scoring fast path
            // (bf16 parameter storage, f32 accumulate) vs the f32 one.
            // Value fidelity is pinned by the library tests; here the
            // walk only has to be deterministic (two passes, same bits).
            let qp = m.quantize_params(&params);
            let mut loss_q = vec![0.0f32; rows];
            let mut ub_q = vec![0.0f32; rows];
            let r_bf16 = bench(&format!("kernels/{model_name}/score_bf16"), target, || {
                let mut start = 0usize;
                while start < rows {
                    let b = (rows - start).min(MAX_BLOCK_ROWS);
                    m.scores_block_bf16(
                        &qp,
                        &x[start * d..(start + b) * d],
                        &y[start..start + b],
                        b,
                        &mut bs,
                        &mut loss_q[start..start + b],
                        &mut ub_q[start..start + b],
                    );
                    start += b;
                }
                black_box(&ub_q);
            });
            let ub_q_ref = ub_q.clone();
            let mut start = 0usize;
            while start < rows {
                let b = (rows - start).min(MAX_BLOCK_ROWS);
                m.scores_block_bf16(
                    &qp,
                    &x[start * d..(start + b) * d],
                    &y[start..start + b],
                    b,
                    &mut bs,
                    &mut loss_q[start..start + b],
                    &mut ub_q[start..start + b],
                );
                start += b;
            }
            assert_eq!(ub_q, ub_q_ref, "kernels/{model_name}: bf16 scores must be deterministic");
            let bf16_speedup = r_fast.mean_ns / r_bf16.mean_ns.max(1e-9);
            println!(
                "kernels/{model_name}: bf16 score path {bf16_speedup:.2}x the f32 fast path \
                 ({:.0} rows/s)",
                r_bf16.rows_per_sec(rows)
            );
            suite.metric(&format!("{model_name}_bf16_score_speedup"), bf16_speedup);
            suite.metric(
                &format!("{model_name}_bf16_score_rows_per_sec"),
                r_bf16.rows_per_sec(rows),
            );

            suite.push(r_scalar);
            suite.push(r_block);
            suite.push(r_autovec);
            suite.push(r_fast);
            suite.push(r_slow);
            suite.push(r_bf16);
        }
        suite.metric("rows", 256.0);
        let out = args.flag("out-json-kernels").unwrap_or("BENCH_kernels.json");
        suite.write_json(out)?;
        println!("kernel bench results -> {out}");
    }

    // ---------------- sharded presample scoring ----------------
    // B=640 at CIFAR-ish dims (§4.2 configuration), scored by the native
    // MLP so the serial/parallel comparison runs without artifacts. The
    // speedup metric in BENCH_scoring.json is the acceptance number.
    if run("score/") {
        let mut suite = BenchSuite::new();
        let scorer = NativeScorer::new(768, 256, 100, 42);
        let (xp, yp) = ds.batch(&idx640, 0);

        let serial_scores = ScoreBackend::Serial.score(&scorer, &xp, &yp, ScoreKind::UpperBound)?;
        let r_serial = bench("score/native_B640_serial", target, || {
            black_box(
                ScoreBackend::Serial
                    .score(black_box(&scorer), &xp, &yp, ScoreKind::UpperBound)
                    .unwrap(),
            );
        });
        suite.metric("rows", 640.0);
        suite.metric("serial_rows_per_sec", r_serial.rows_per_sec(640));

        let mut worker_counts = vec![2usize, 4];
        let avail = default_score_workers();
        if avail > 4 {
            worker_counts.push(avail);
        }
        for &workers in &worker_counts {
            let backend = ScoreBackend::from_workers(workers);
            let parallel_scores = backend.score(&scorer, &xp, &yp, ScoreKind::UpperBound)?;
            assert_eq!(
                parallel_scores, serial_scores,
                "parallel scoring must be bit-identical to serial ({workers} workers)"
            );
            let r = bench(&format!("score/native_B640_w{workers}"), target, || {
                black_box(
                    backend.score(black_box(&scorer), &xp, &yp, ScoreKind::UpperBound).unwrap(),
                );
            });
            let speedup = r_serial.mean_ns / r.mean_ns.max(1e-9);
            println!(
                "score: {workers} workers -> {:.2}x vs serial ({:.0} rows/s)",
                speedup,
                r.rows_per_sec(640)
            );
            suite.metric(&format!("speedup_w{workers}_vs_serial"), speedup);
            suite.metric(&format!("w{workers}_rows_per_sec"), r.rows_per_sec(640));
            suite.push(r);
        }
        suite.push(r_serial);
        suite.metric("available_parallelism", avail as f64);

        let out = args.flag("out-json").unwrap_or("BENCH_scoring.json");
        suite.write_json(out)?;
        println!("scoring bench results -> {out}");
    }

    // ---------------- native end-to-end training throughput ------------
    // Real Algorithm-1 runs on the pure-rust backend: uniform vs
    // upper-bound (warmup -> tau switch -> presample/score/resample) at an
    // equal step count, swept over --train-workers. Per-worker steps/sec
    // is the BENCH_train.json acceptance number (the scaling curve);
    // every parallel run must be bit-identical to the 1-worker run.
    if run("train/") {
        let mut suite = BenchSuite::new();
        let native = NativeEngine::with_default_models();
        // ISAMPLE_BENCH_TARGET_MS (or --target-ms) caps per-bench time;
        // scale the fixed-step training runs proportionally so CI's
        // quick mode shrinks this section too.
        let default_steps = ((300 * target.as_millis() as u64) / 1500).clamp(60, 300);
        let steps = args.flag_u64("train-steps", default_steps)?;
        let sweep: Vec<usize> = match args.flag("train-workers") {
            // explicit count: compare exactly serial vs that count
            Some(_) => {
                let n = args.flag_train_workers()?;
                if n == 1 {
                    vec![1]
                } else {
                    vec![1, n]
                }
            }
            None => {
                let mut v = vec![1, 2, 4, default_train_workers()];
                v.sort_unstable();
                v.dedup();
                v
            }
        };
        let split =
            SyntheticImages::builder(64, 10).samples(8_192).test_samples(1_024).seed(3).split();
        // Two architectures through the same harness: the mlp10 stand-in
        // (metric names unchanged for cross-PR comparability) and the
        // conv10 layer-IR convnet (metrics prefixed `conv10_`), so the
        // BENCH_train.json trajectory stops being MLP-only.
        for (prefix, model) in [("", "mlp10"), ("conv10_", "conv10")] {
            for (tag, base) in [
                ("uniform", TrainerConfig::uniform(model)),
                (
                    "upper_bound",
                    TrainerConfig::upper_bound(model).with_presample(384).with_tau_th(1.2),
                ),
            ] {
                // (trajectory digest, state checksum) of the serial run —
                // the reference every parallel worker count must reproduce
                let mut reference: Option<(u64, u64)> = None;
                let mut serial_sps = f64::NAN;
                for &workers in &sweep {
                    let cfg = base
                        .clone()
                        .with_steps(steps)
                        .with_seed(17)
                        .with_score_workers(args.flag_score_workers()?)
                        .with_train_workers(workers);
                    let mut trainer = Trainer::new(&native, cfg)?;
                    let report = trainer.run(&split.train, None)?;
                    let traj = digest_f64(report.log.rows.iter().map(|r| r.train_loss));
                    let state = state_checksum(&trainer.state)?;
                    if let Some(r) = reference {
                        assert_eq!(
                            (traj, state),
                            r,
                            "train/{model}/{tag}: {workers}-worker run diverged from serial"
                        );
                    } else {
                        reference = Some((traj, state));
                    }
                    let sps = report.steps as f64 / report.wall_secs.max(1e-9);
                    if workers == 1 {
                        serial_sps = sps;
                        let name = format!("{prefix}{tag}_final_train_loss");
                        suite.metric(&name, report.final_train_loss);
                    }
                    println!(
                        "train/native_{model}_{tag}_w{workers}: {} steps -> {sps:.1} steps/s \
                         ({:.2}x vs serial, final loss {:.4}, IS@{:?})",
                        report.steps,
                        sps / serial_sps.max(1e-9),
                        report.final_train_loss,
                        report.is_switch_step
                    );
                    suite.metric(&format!("{prefix}{tag}_w{workers}_steps_per_sec"), sps);
                    if workers > 1 {
                        suite.metric(
                            &format!("{prefix}{tag}_speedup_w{workers}_vs_serial"),
                            sps / serial_sps.max(1e-9),
                        );
                    }
                }
            }
        }
        suite.metric("train_steps", steps as f64);
        suite.metric("train_worker_counts", sweep.len() as f64);
        suite.metric("available_parallelism", default_train_workers() as f64);
        let out = args.flag("out-json-train").unwrap_or("BENCH_train.json");
        suite.write_json(out)?;
        println!("training bench results -> {out}");
    }

    // ---------------- distributed coordinator scaling (ISSUE 10) --------
    // The multi-process engine over in-process thread workers (same wire
    // protocol, coordinator, leases and merge path as subprocess mode,
    // minus process spawn noise): steps/sec at 1/2/4 workers vs the
    // in-process serial engine, plus a recovery leg that kills a worker
    // mid-run under a short lease and reports the worst-case step time
    // (`recovery_after_kill_ms` — lease expiry + requeue + re-dispatch).
    // Every leg's trajectory digest and final state checksum must equal
    // the in-process serial run bit-for-bit; faults may only move time.
    if run("dist/") {
        let mut suite = BenchSuite::new();
        let dist_steps = ((120 * target.as_millis() as u64) / 1500).clamp(24, 120);
        let mk_local = || {
            let mut ne = NativeEngine::new();
            ne.register(NativeModelSpec::mlp("dgold", 32, 24, 4, 32, 64, vec![128]));
            ne
        };
        let pool = SyntheticImages::builder(32, 4).samples(2_048).seed(11).build();
        let b = 32usize;
        // drive `dist_steps` fixed-seed steps on any backend; returns the
        // loss digest, the final state checksum and per-step wall millis
        let drive = |backend: &dyn Backend| -> anyhow::Result<(u64, u64, Vec<f64>)> {
            let mut state = backend.init_state("dgold", 7)?;
            let w = vec![1.0f32; b];
            let mut losses = Vec::with_capacity(dist_steps as usize);
            let mut step_ms = Vec::with_capacity(dist_steps as usize);
            for step in 0..dist_steps {
                let mut r = SplitMix64::tensor_stream(0xD15C0, step);
                let idx: Vec<usize> = (0..b).map(|_| r.below(pool.len())).collect();
                let (x, y) = pool.batch(&idx, 0);
                let sw = Stopwatch::new();
                let out = backend.train_step(&mut state, &x, &y, &w, 0.1)?;
                step_ms.push(sw.elapsed_secs() * 1e3);
                losses.push(out.loss as f64);
            }
            let digest = digest_f64(losses.iter().copied());
            Ok((digest, state_checksum(&state)?, step_ms))
        };

        let serial_local = mk_local();
        let (serial_digest, serial_state, serial_ms) = drive(&serial_local)?;
        let serial_secs = serial_ms.iter().sum::<f64>() / 1e3;
        let serial_sps = dist_steps as f64 / serial_secs.max(1e-9);
        println!("dist/serial_inprocess: {dist_steps} steps -> {serial_sps:.1} steps/s");
        suite.metric("dist_serial_steps_per_sec", serial_sps);

        for workers in [1usize, 2, 4] {
            let engine = DistEngine::new(mk_local(), 2_000)?;
            engine.spawn_thread_workers(workers, &FaultPlan::parse("")?);
            engine.wait_for_workers(workers)?;
            let (digest, state, step_ms) = drive(&engine)?;
            assert_eq!(
                (digest, state),
                (serial_digest, serial_state),
                "dist/w{workers}: distributed run diverged from in-process serial"
            );
            let secs = step_ms.iter().sum::<f64>() / 1e3;
            let sps = dist_steps as f64 / secs.max(1e-9);
            println!(
                "dist/w{workers}: {dist_steps} steps -> {sps:.1} steps/s \
                 ({:.2}x vs in-process serial)",
                sps / serial_sps.max(1e-9)
            );
            suite.metric(&format!("dist_w{workers}_steps_per_sec"), sps);
        }

        // recovery leg: 2 workers under a short lease, worker 1 killed
        // mid-run; the worst step eats lease expiry + requeue, and the
        // digest still may not move. Named *_ms (not *_per_sec) on
        // purpose: recovery time is environment noise, tracked but not
        // regression-gated by bench_trend.
        let kill_at = dist_steps / 2;
        let engine = DistEngine::new(mk_local(), 200)?;
        engine.spawn_thread_workers(2, &FaultPlan::parse(&format!("kill@{kill_at}:1:0"))?);
        engine.wait_for_workers(2)?;
        let (digest, state, step_ms) = drive(&engine)?;
        assert_eq!(
            (digest, state),
            (serial_digest, serial_state),
            "dist/recovery: killed-worker run diverged from in-process serial"
        );
        let recovery_ms = step_ms.iter().copied().fold(0.0f64, f64::max);
        println!(
            "dist/recovery: worker killed at step {kill_at} under a 200ms lease; \
             worst step {recovery_ms:.1}ms, digest unchanged"
        );
        suite.metric("recovery_after_kill_ms", recovery_ms);
        suite.metric("dist_steps", dist_steps as f64);
        suite.metric("dist_lease_ms", 200.0);
        let out = args.flag("out-json-dist").unwrap_or("BENCH_distributed.json");
        suite.write_json(out)?;
        println!("distributed bench results -> {out}");
    }

    // ---------------- streaming data plane ----------------
    // The ISSUE 6 acceptance numbers: shard-store streaming throughput
    // (with and without pool readahead overlapping shard IO) and the
    // staleness-cached presample pass vs full re-scoring on a >= 100k
    // sample pool — asserted >= 2x on best observed iterations and
    // written to BENCH_streaming.json (--out-json-streaming PATH).
    if run("streaming/") {
        let mut suite = BenchSuite::new();
        let n = 131_072usize;
        let (d, c) = (64usize, 10usize);
        let pool = SyntheticImages::builder(d, c).samples(n).seed(21).build();
        let dir = std::env::temp_dir().join(format!("isample_stream_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();

        let sw = Stopwatch::new();
        shard::write_dataset(&dir, &pool, 4_096)?;
        let write_secs = sw.elapsed_secs();
        println!("streaming: wrote {n} samples in {write_secs:.2}s");
        suite.metric("pool_samples", n as f64);
        suite.metric("shard_write_rows_per_sec", n as f64 / write_secs.max(1e-9));

        // one full sequential pass in shard-sized batches, with the
        // resident set held far below the shard count so every shard is
        // streamed from disk; readahead overlaps the next shard's IO
        for (tag, readahead) in [("cold", 0usize), ("readahead", 2)] {
            let sds = {
                let s = shard::ShardedDataset::open(&dir)?.with_resident_shards(4);
                if readahead > 0 {
                    s.with_readahead(readahead)
                } else {
                    s
                }
            };
            let r = bench(&format!("streaming/pass_{tag}"), target, || {
                let mut start = 0usize;
                while start < n {
                    let len = (n - start).min(4_096);
                    let idx: Vec<usize> = (start..start + len).collect();
                    black_box(sds.batch(&idx, 0));
                    start += len;
                }
            });
            println!("streaming/pass_{tag}: {:.0} rows/s", r.rows_per_sec(n));
            suite.metric(&format!("stream_{tag}_rows_per_sec"), r.rows_per_sec(n));
            suite.push(r);
        }

        // cached vs full presample scoring. The pool stays fully resident
        // so both sides pay identical (minimal) IO and the comparison
        // isolates what --score-refresh-budget saves: the model passes.
        let sds = shard::ShardedDataset::open(&dir)?.with_resident_shards(n.div_ceil(4_096));
        let scorer = NativeScorer::new(d, 32, c, 42);
        let sb = ScoreBackend::from_workers(args.flag_score_workers()?);
        let big_b = 2_048usize;
        let mut cache = ScoreCache::new(n, Some(1_000_000_000));
        let mut start = 0usize;
        while start < n {
            let len = (n - start).min(8_192);
            let idx: Vec<usize> = (start..start + len).collect();
            let (x, y) = sds.batch(&idx, 0);
            let fresh = sb.score(&scorer, &x, &y, ScoreKind::UpperBound)?;
            let positions: Vec<usize> = (0..len).collect();
            cache.record(&idx, &positions, &fresh, 0);
            start += len;
        }
        // warm-cache correctness: cached lookups must equal fresh scores
        // bitwise (the scorer state has not changed since the warm pass)
        let check_idx: Vec<usize> = (0..big_b).map(|i| (i * 131) % n).collect();
        let (cx, cy) = sds.batch(&check_idx, 0);
        let check_fresh = sb.score(&scorer, &cx, &cy, ScoreKind::UpperBound)?;
        assert_eq!(
            cache.lookup(&check_idx),
            check_fresh,
            "streaming: cached scores diverged from a fresh full re-score"
        );

        let mut rng_full = SplitMix64::new(99);
        let r_full = bench(&format!("streaming/presample_full_B{big_b}"), target, || {
            let idx: Vec<usize> = (0..big_b).map(|_| rng_full.below(n)).collect();
            let (x, y) = sds.batch(&idx, 0);
            black_box(sb.score(&scorer, &x, &y, ScoreKind::UpperBound).unwrap());
        });
        let mut rng_cached = SplitMix64::new(99);
        let r_cached = bench(&format!("streaming/presample_cached_B{big_b}"), target, || {
            let idx: Vec<usize> = (0..big_b).map(|_| rng_cached.below(n)).collect();
            let (x, y) = sds.batch(&idx, 0);
            let stale = cache.stale_positions(&idx, 1);
            let fresh = sb.score_subset(&scorer, &x, &y, ScoreKind::UpperBound, &stale).unwrap();
            cache.record(&idx, &stale, &fresh, 1);
            black_box(cache.lookup(&idx));
        });
        let speedup = r_full.mean_ns / r_cached.mean_ns.max(1e-9);
        let speedup_best = r_full.min_ns / r_cached.min_ns.max(1e-9);
        println!(
            "streaming: cached presample pass {speedup:.2}x full re-score \
             (best {speedup_best:.2}x, {:.0} vs {:.0} rows/s)",
            r_cached.rows_per_sec(big_b),
            r_full.rows_per_sec(big_b)
        );
        assert!(
            speedup_best >= 2.0,
            "streaming: cached presample pass best case is only {speedup_best:.2}x full \
             re-scoring (mean {speedup:.2}x; acceptance floor: 2x at a {n}-sample pool)"
        );
        suite.metric("presample_rows", big_b as f64);
        suite.metric("presample_full_rows_per_sec", r_full.rows_per_sec(big_b));
        suite.metric("presample_cached_rows_per_sec", r_cached.rows_per_sec(big_b));
        suite.metric("cached_vs_full_speedup", speedup);
        suite.metric("cached_vs_full_best_speedup", speedup_best);
        suite.push(r_full);
        suite.push(r_cached);

        std::fs::remove_dir_all(&dir).ok();
        let out = args.flag("out-json-streaming").unwrap_or("BENCH_streaming.json");
        suite.write_json(out)?;
        println!("streaming bench results -> {out}");
    }

    // ---------------- PJRT entry points (need AOT artifacts) -----------
    let engine = match Engine::load(args.flag("artifacts").unwrap_or("artifacts")) {
        Ok(engine) => engine,
        Err(e) => {
            println!("skipping PJRT engine benches (no artifacts): {e:#}");
            return Ok(());
        }
    };
    for model in ["mlp10", "cnn100", "lstm"] {
        if engine.model_info(model).is_err() {
            continue;
        }
        engine.warmup(model)?; // exclude compile time from the numbers
        let info = engine.model_info(model)?.clone();
        let mut state = engine.init_state(model, 1)?;
        let d = info.feature_dim;
        let gen = SyntheticImages::builder(d, info.num_classes).samples(4096).seed(2).build();
        let bidx: Vec<usize> = (0..info.batch).collect();
        let (xb, yb) = gen.batch(&bidx, 0);
        let w = vec![1.0f32; info.batch];
        if run(&format!("engine/{model}/train_step")) {
            bench(&format!("engine/{model}/train_step_b{}", info.batch), target, || {
                black_box(engine.train_step(&mut state, &xb, &yb, &w, 0.01).unwrap());
            });
        }
        let bmax = *info.presample.iter().max().unwrap_or(&info.batch);
        let pidx: Vec<usize> = (0..bmax).collect();
        let (xp, yp) = gen.batch(&pidx, 0);
        if run(&format!("engine/{model}/fwd_scores")) {
            bench(&format!("engine/{model}/fwd_scores_B{bmax}"), target, || {
                black_box(engine.fwd_scores(&state, &xp, &yp).unwrap());
            });
        }
        if info.has_entry("grad_norms") && run(&format!("engine/{model}/grad_norms")) {
            bench(&format!("engine/{model}/grad_norms_B{bmax}"), target, || {
                black_box(engine.grad_norms(&state, &xp, &yp).unwrap());
            });
        }
    }

    Ok(())
}

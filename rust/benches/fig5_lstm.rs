//! End-to-end bench regenerating the LSTM sequence classification comparison (paper Fig. 5).
//!
//! `cargo bench --bench fig5_lstm` runs the harness in quick mode with a
//! small wall-clock budget and reports total harness time; pass
//! `-- --budget SECS [--full] [--seeds 1,2,3]` for the paper-scale run and
//! `-- --backend native` to run artifact-free on the native CPU engine.

use isample::config::Args;
use isample::figures::runner::{run_figure, FigOptions};
use isample::runtime::backend;
use isample::util::timer::Stopwatch;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"))?;
    let backend =
        backend::load(args.flag_backend()?, args.flag("artifacts").unwrap_or("artifacts"))?;
    let opts = FigOptions {
        budget_secs: args.flag_f64("budget", 6.0)?,
        out_dir: args.flag("out").unwrap_or("results/bench").into(),
        seeds: args.flag_u64_list("seeds", &[42])?,
        quick: !args.flag_bool("full"),
        model: args.flag("model").map(|s| s.to_string()),
        score_workers: args.flag_score_workers()?,
        train_workers: args.flag_train_workers()?,
        score_refresh_budget: args.flag_score_refresh_budget()?,
        sampler: args.flag_sampler()?,
        score_precision: args.flag_score_precision()?,
    };
    let sw = Stopwatch::new();
    run_figure(backend.as_ref(), "fig5", &opts)?;
    println!("bench fig5_lstm: harness completed in {:.1}s", sw.elapsed_secs());
    Ok(())
}

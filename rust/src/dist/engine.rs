//! [`DistEngine`] — the multi-process distributed [`Backend`].
//!
//! Wraps an in-process [`NativeEngine`] (model registry, fallback compute,
//! SGD update) and a [`Coordinator`] that farms chunk work out to worker
//! processes/threads. Every batch-level entry builds its chunk jobs from
//! the same worker-count-independent planners the native backend uses
//! ([`train_chunk_plan`] / [`grad_chunk_plan`]), scatters them, fills any
//! unserved chunk with the identical in-process per-chunk body, and merges
//! **in fixed chunk order** — so N worker processes, any fault pattern,
//! and the pure in-process path all produce the same bits.
//!
//! Degradation ladder: remote workers → per-chunk in-process fallback
//! (expired leases, lost workers) → fully in-process when every remote
//! worker is gone. Downgrades and recoveries are logged as events the
//! trainer drains into its metrics log.

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};
use xla::Literal;

use super::coordinator::{Coordinator, Round};
use super::fault::FaultPlan;
use super::wire::{WorkReply, WorkRequest};
use crate::runtime::backend::Backend;
use crate::runtime::engine::{ModelState, StepOutput};
use crate::runtime::layers::LayerModel;
use crate::runtime::manifest::ModelInfo;
use crate::runtime::native::{self, grad_chunk_plan, train_chunk_plan, NativeEngine};
use crate::runtime::score::{ScoreKind, ScorePrecision};
use crate::runtime::tensor::HostTensor;

/// The distributed backend. See the module docs.
pub struct DistEngine {
    local: Arc<NativeEngine>,
    coord: Coordinator,
    /// Whether the last round ran fully in-process (drives one-shot
    /// degradation/recovery events instead of per-step spam).
    degraded: AtomicBool,
}

impl DistEngine {
    /// Wrap `local` and start a coordinator with the given chunk lease.
    pub fn new(local: NativeEngine, lease_ms: u64) -> Result<Self> {
        Ok(Self {
            local: Arc::new(local),
            coord: Coordinator::new(lease_ms)?,
            degraded: AtomicBool::new(false),
        })
    }

    pub fn coordinator(&self) -> &Coordinator {
        &self.coord
    }

    /// Attach `n` in-thread workers sharing this engine's model registry.
    pub fn spawn_thread_workers(&self, n: usize, plan: &FaultPlan) {
        self.coord.spawn_thread_workers(n, Arc::clone(&self.local), plan);
    }

    /// Spawn `n` worker processes of `program` (the `isample` binary).
    pub fn spawn_process_workers(&self, n: usize, program: &Path, plan: &FaultPlan) -> Result<()> {
        self.coord.spawn_process_workers(n, program, plan)
    }

    /// Block (bounded) until `n` workers have registered.
    pub fn wait_for_workers(&self, n: usize) -> Result<()> {
        self.coord.wait_for_workers(n)
    }

    /// Scatter chunk jobs, fill unserved chunks via `local` (the
    /// in-process twin of the remote body), and return every chunk's
    /// reply in chunk order.
    fn scatter<F>(
        &self,
        round: &Round<'_>,
        jobs: &[WorkRequest],
        mut local: F,
    ) -> Result<Vec<WorkReply>>
    where
        F: FnMut(usize) -> Result<WorkReply>,
    {
        let slots = self.coord.execute(round, jobs);
        let total = slots.len();
        let mut fallback = 0usize;
        let mut filled = Vec::with_capacity(total);
        for (i, slot) in slots.into_iter().enumerate() {
            match slot {
                Some(reply) => filled.push(reply),
                None => {
                    fallback += 1;
                    filled.push(local(i)?);
                }
            }
        }
        if fallback > 0 {
            self.coord.count_local_chunks(fallback as u64);
        }
        if fallback == total {
            if !self.degraded.swap(true, Ordering::SeqCst) {
                self.coord.note(format!(
                    "step {}: all remote workers lost; continuing on the in-process engine",
                    round.step
                ));
            }
        } else {
            if fallback > 0 {
                self.coord.note(format!(
                    "step {}: {fallback} of {total} chunks fell back to the in-process engine",
                    round.step
                ));
            }
            if self.degraded.swap(false, Ordering::SeqCst) {
                self.coord.note(format!("step {}: remote workers restored", round.step));
            }
        }
        Ok(filled)
    }
}

/// Batch-shape validation (the [`NativeEngine`] contract, restated here
/// because chunk jobs are sliced before the local engine ever sees them).
fn check_batch(model: &LayerModel, x: &HostTensor, y: &[i32]) -> Result<usize> {
    let d = model.in_dim();
    if x.shape.len() != 2 || x.shape[1] != d {
        bail!("x shape {:?} does not match model expectation [n, {d}]", x.shape);
    }
    let n = x.shape[0];
    if n == 0 {
        bail!("empty batch");
    }
    if y.len() != n {
        bail!("y length {} != batch {n}", y.len());
    }
    Ok(n)
}

/// Copy one chunk's rows into a standalone tensor (what travels the wire,
/// and what the in-process fallback computes on — identical inputs).
fn chunk_tensor(x: &HostTensor, d: usize, start: usize, len: usize) -> HostTensor {
    HostTensor::new(vec![len, d], x.data[start * d..(start + len) * d].to_vec())
}

/// Validate a merged gradient buffer against the model's parameter specs
/// (a defense line against a wrong-shaped remote reply).
fn check_grads(model: &LayerModel, grads: &[Vec<f32>]) -> Result<()> {
    if grads.len() != model.num_param_tensors()
        || grads.iter().zip(model.param_elems()).any(|(g, &n)| g.len() != n)
    {
        bail!("dist: remote gradient buffers do not match the model's parameter shapes");
    }
    Ok(())
}

impl Backend for DistEngine {
    fn name(&self) -> &'static str {
        "dist"
    }

    fn model_info(&self, model: &str) -> Result<&ModelInfo> {
        self.local.model_info(model)
    }

    fn supports(&self, model: &str, entry: &str, batch: usize) -> Result<bool> {
        self.local.supports(model, entry, batch)
    }

    fn prepare(&self, model: &str, entry: &str, batch: usize) -> Result<()> {
        self.local.prepare(model, entry, batch)
    }

    fn init_state(&self, model: &str, seed: u64) -> Result<ModelState> {
        self.local.init_state(model, seed)
    }

    fn set_train_workers(&self, workers: usize) {
        self.local.set_train_workers(workers);
    }

    fn train_workers(&self) -> usize {
        NativeEngine::train_workers(&self.local)
    }

    fn set_score_precision(&self, precision: ScorePrecision) {
        self.local.set_score_precision(precision);
    }

    fn scores_sharded_internally(&self, _kind: ScoreKind) -> bool {
        // Scoring parallelism is the coordinator's job: chunked fan-out to
        // worker processes. An outer `--score-workers` shard layer would
        // only serialize on the coordinator's round lock.
        true
    }

    fn drain_events(&self) -> Vec<String> {
        self.coord.drain_events()
    }

    fn train_step(
        &self,
        state: &mut ModelState,
        x: &HostTensor,
        y: &[i32],
        w: &[f32],
        lr: f32,
    ) -> Result<StepOutput> {
        let info = self.local.model_info(&state.model)?;
        let model = self.local.layer_model(&state.model)?;
        let n = check_batch(model, x, y)?;
        if w.len() != n {
            bail!("w length {} != batch {n}", w.len());
        }
        let nt = info.params.len();
        let mut params = native::host_tensors(&state.params, nt, "parameter")?;
        let mut mom = native::host_tensors(&state.mom, nt, "momentum")?;
        let inv_n = 1.0 / n as f32;
        let d = x.shape[1];
        let plan = grad_chunk_plan(n);
        let jobs: Vec<WorkRequest> = plan
            .iter()
            .map(|&(start, len)| WorkRequest::Grad {
                dim: d as u32,
                x: x.data[start * d..(start + len) * d].to_vec(),
                y: y[start..start + len].to_vec(),
                w: Some(w[start..start + len].to_vec()),
                scale: inv_n,
            })
            .collect();
        let round = Round {
            step: state.step,
            version: state.step + 1,
            model: &state.model,
            params: &params,
        };
        let replies = self.scatter(&round, &jobs, |i| {
            let (start, len) = plan[i];
            let t = chunk_tensor(x, d, start, len);
            let out = native::grad_chunk(
                model,
                &params,
                &t,
                &y[start..start + len],
                Some(&w[start..start + len]),
                inv_n,
            )?;
            Ok(WorkReply::Grad {
                grads: out.grads,
                weighted_loss: out.weighted_loss,
                loss: out.loss,
                scores: out.scores,
            })
        })?;
        // Fixed-order merge, seeded with chunk 0 — the exact reduction of
        // the in-process `batch_pass`.
        let mut loss_vec: Vec<f32> = Vec::with_capacity(n);
        let mut scores: Vec<f32> = Vec::with_capacity(n);
        let mut merged: Option<(Vec<Vec<f32>>, f64)> = None;
        for (i, reply) in replies.into_iter().enumerate() {
            let WorkReply::Grad { grads, weighted_loss, loss, scores: sc } = reply else {
                bail!("dist: mismatched reply type for a gradient chunk");
            };
            let len = plan[i].1;
            if loss.len() != len || sc.len() != len {
                bail!("dist: chunk {i} returned {} rows, expected {len}", loss.len());
            }
            check_grads(model, &grads)?;
            loss_vec.extend_from_slice(&loss);
            scores.extend_from_slice(&sc);
            match merged.as_mut() {
                None => merged = Some((grads, weighted_loss)),
                Some((acc, wl)) => {
                    for (gt, ot) in acc.iter_mut().zip(&grads) {
                        for (gv, &ov) in gt.iter_mut().zip(ot) {
                            *gv += ov;
                        }
                    }
                    *wl += weighted_loss;
                }
            }
        }
        let (grads, weighted_loss) = merged.context("chunk plan is never empty")?;
        native::sgd_update(
            &mut params,
            &mut mom,
            &grads,
            lr,
            self.local.momentum,
            self.local.weight_decay,
        );
        state.params = native::lits_from(info, &params)?;
        state.mom = native::lits_from(info, &mom)?;
        state.step += 1;
        Ok(StepOutput { loss: weighted_loss as f32, loss_vec, scores })
    }

    fn fwd_scores(
        &self,
        state: &ModelState,
        x: &HostTensor,
        y: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let info = self.local.model_info(&state.model)?;
        let model = self.local.layer_model(&state.model)?;
        let n = check_batch(model, x, y)?;
        let params = native::host_tensors(&state.params, info.params.len(), "parameter")?;
        let d = x.shape[1];
        let precision = self.local.score_precision();
        let plan = train_chunk_plan(n);
        let jobs: Vec<WorkRequest> = plan
            .iter()
            .map(|&(start, len)| WorkRequest::Score {
                dim: d as u32,
                x: x.data[start * d..(start + len) * d].to_vec(),
                y: y[start..start + len].to_vec(),
                precision: precision.code(),
            })
            .collect();
        let round = Round {
            step: state.step,
            version: state.step + 1,
            model: &state.model,
            params: &params,
        };
        // bf16 shadow for local fallbacks, built at most once per call
        // (`quantize_params` is pure, so laziness is bit-invisible).
        let mut qp: Option<Vec<Vec<u16>>> = None;
        let replies = self.scatter(&round, &jobs, |i| {
            let (start, len) = plan[i];
            let t = chunk_tensor(x, d, start, len);
            if precision == ScorePrecision::Bf16 && qp.is_none() {
                qp = Some(model.quantize_params(&params));
            }
            let (loss, sc) =
                native::score_chunk(model, &params, qp.as_deref(), &t, &y[start..start + len])?;
            Ok(WorkReply::Score { loss, scores: sc })
        })?;
        let mut loss_vec: Vec<f32> = Vec::with_capacity(n);
        let mut scores: Vec<f32> = Vec::with_capacity(n);
        for (i, reply) in replies.into_iter().enumerate() {
            let WorkReply::Score { loss, scores: sc } = reply else {
                bail!("dist: mismatched reply type for a score chunk");
            };
            let len = plan[i].1;
            if loss.len() != len || sc.len() != len {
                bail!("dist: chunk {i} returned {} rows, expected {len}", loss.len());
            }
            loss_vec.extend_from_slice(&loss);
            scores.extend_from_slice(&sc);
        }
        Ok((loss_vec, scores))
    }

    fn eval_metrics(&self, state: &ModelState, x: &HostTensor, y: &[i32]) -> Result<(f64, i64)> {
        let info = self.local.model_info(&state.model)?;
        let model = self.local.layer_model(&state.model)?;
        let n = check_batch(model, x, y)?;
        let params = native::host_tensors(&state.params, info.params.len(), "parameter")?;
        let d = x.shape[1];
        let plan = train_chunk_plan(n);
        let jobs: Vec<WorkRequest> = plan
            .iter()
            .map(|&(start, len)| WorkRequest::Eval {
                dim: d as u32,
                x: x.data[start * d..(start + len) * d].to_vec(),
                y: y[start..start + len].to_vec(),
            })
            .collect();
        let round = Round {
            step: state.step,
            version: state.step + 1,
            model: &state.model,
            params: &params,
        };
        let replies = self.scatter(&round, &jobs, |i| {
            let (start, len) = plan[i];
            let t = chunk_tensor(x, d, start, len);
            let (sum_loss, correct) =
                native::eval_chunk(model, &params, &t, &y[start..start + len])?;
            Ok(WorkReply::Eval { sum_loss, correct })
        })?;
        // fixed-order (chunk index) merge: bit-identical for any workers
        let mut sum_loss = 0.0f64;
        let mut correct = 0i64;
        for reply in replies {
            let WorkReply::Eval { sum_loss: l, correct: k } = reply else {
                bail!("dist: mismatched reply type for an eval chunk");
            };
            sum_loss += l;
            correct += k;
        }
        Ok((sum_loss, correct))
    }

    fn grad_norms(&self, state: &ModelState, x: &HostTensor, y: &[i32]) -> Result<Vec<f32>> {
        let info = self.local.model_info(&state.model)?;
        let model = self.local.layer_model(&state.model)?;
        let n = check_batch(model, x, y)?;
        let params = native::host_tensors(&state.params, info.params.len(), "parameter")?;
        let d = x.shape[1];
        let plan = train_chunk_plan(n);
        let jobs: Vec<WorkRequest> = plan
            .iter()
            .map(|&(start, len)| WorkRequest::GradNorm {
                dim: d as u32,
                x: x.data[start * d..(start + len) * d].to_vec(),
                y: y[start..start + len].to_vec(),
            })
            .collect();
        let round = Round {
            step: state.step,
            version: state.step + 1,
            model: &state.model,
            params: &params,
        };
        let replies = self.scatter(&round, &jobs, |i| {
            let (start, len) = plan[i];
            let t = chunk_tensor(x, d, start, len);
            let norms = native::grad_norm_chunk(model, &params, &t, &y[start..start + len])?;
            Ok(WorkReply::GradNorm { norms })
        })?;
        let mut out: Vec<f32> = Vec::with_capacity(n);
        for (i, reply) in replies.into_iter().enumerate() {
            let WorkReply::GradNorm { norms } = reply else {
                bail!("dist: mismatched reply type for a grad-norm chunk");
            };
            if norms.len() != plan[i].1 {
                bail!("dist: chunk {i} returned {} norms, expected {}", norms.len(), plan[i].1);
            }
            out.extend_from_slice(&norms);
        }
        Ok(out)
    }

    fn grad(
        &self,
        model: &str,
        params: &[Literal],
        x: &HostTensor,
        y: &[i32],
    ) -> Result<(Vec<Literal>, f32)> {
        // Host-composed SVRG substrate runs in-process: it evaluates
        // arbitrary (snapshot) parameters, not the trainer state the
        // version protocol tracks.
        self.local.grad(model, params, x, y)
    }

    fn weighted_grad(
        &self,
        state: &ModelState,
        x: &HostTensor,
        y: &[i32],
        w: &[f32],
    ) -> Result<(Vec<Literal>, f32)> {
        self.local.weighted_grad(state, x, y, w)
    }
}

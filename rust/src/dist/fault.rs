//! Deterministic fault injection for the distributed data plane.
//!
//! A [`FaultPlan`] is a finite list of `(step, worker, chunk) -> fault`
//! triples, parsed from a compact spec string (the `ISAMPLE_FAULT_PLAN`
//! environment variable, or the `--fault-plan` flag a worker process is
//! spawned with). The plan is consulted by the *worker* right before it
//! computes a chunk, and is a pure function of the work order's
//! coordinates — never of wall-clock time, scheduling, or randomness — so
//! a fixed seed plus a fixed plan replays the exact same fault sequence
//! on every run. Faults perturb scheduling only (which worker computes
//! which chunk, and when); the merged results are bit-identical to a
//! fault-free run by the chunk-plan invariant.

use anyhow::{bail, Context, Result};

/// Environment variable holding the default fault-plan spec.
pub const ENV_FAULT_PLAN: &str = "ISAMPLE_FAULT_PLAN";

/// What a worker does when its fault trigger matches a work order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Die mid-lease: a worker process exits abruptly (status 17); a
    /// worker thread returns and never reconnects.
    Kill,
    /// Sleep this long before computing the chunk. Below the lease this
    /// only delays the reply; above it the coordinator requeues the chunk
    /// and drops the connection.
    Stall { ms: u64 },
    /// Compute nothing and never reply; the coordinator's lease expires,
    /// the chunk is requeued, and the connection is dropped.
    DropReply,
}

/// One trigger: fire `kind` when worker `worker` receives `chunk` of step
/// `step`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultAction {
    pub step: u64,
    pub worker: u32,
    pub chunk: u32,
    pub kind: FaultKind,
}

/// A deterministic fault schedule (empty by default).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    actions: Vec<FaultAction>,
}

impl FaultPlan {
    /// Parse a spec: comma-separated `kind@step:worker:chunk` entries,
    /// where `kind` is `kill`, `drop`, or `stall` (which takes a fourth
    /// `:ms` field) — e.g. `kill@3:1:0,stall@5:0:2:250,drop@7:2:1`.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut actions = Vec::new();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let (kind, coords) = entry.split_once('@').with_context(|| {
                format!("fault plan entry {entry:?}: expected kind@step:worker:chunk")
            })?;
            let fields = coords
                .split(':')
                .map(|f| {
                    f.trim().parse::<u64>().with_context(|| {
                        format!("fault plan entry {entry:?}: bad number {f:?}")
                    })
                })
                .collect::<Result<Vec<u64>>>()?;
            let (step, worker, chunk, rest) = match fields.as_slice() {
                [s, w, c, rest @ ..] => (*s, *w as u32, *c as u32, rest),
                _ => bail!("fault plan entry {entry:?}: expected step:worker:chunk"),
            };
            let kind = match (kind, rest) {
                ("kill", []) => FaultKind::Kill,
                ("drop", []) => FaultKind::DropReply,
                ("stall", [ms]) => FaultKind::Stall { ms: *ms },
                ("stall", []) => bail!("fault plan entry {entry:?}: stall needs a :ms field"),
                _ => bail!("fault plan entry {entry:?}: unknown kind {kind:?} or extra fields"),
            };
            actions.push(FaultAction { step, worker, chunk, kind });
        }
        Ok(Self { actions })
    }

    /// The plan named by [`ENV_FAULT_PLAN`] (empty when unset).
    pub fn from_env() -> Result<Self> {
        match std::env::var(ENV_FAULT_PLAN) {
            Ok(spec) => Self::parse(&spec),
            Err(_) => Ok(Self::default()),
        }
    }

    /// Serialize back to the spec grammar `parse` accepts (used to hand a
    /// coordinator-side plan to spawned worker processes).
    pub fn to_spec(&self) -> String {
        self.actions
            .iter()
            .map(|a| {
                let at = format!("{}:{}:{}", a.step, a.worker, a.chunk);
                match a.kind {
                    FaultKind::Kill => format!("kill@{at}"),
                    FaultKind::DropReply => format!("drop@{at}"),
                    FaultKind::Stall { ms } => format!("stall@{at}:{ms}"),
                }
            })
            .collect::<Vec<_>>()
            .join(",")
    }

    /// The fault (if any) scheduled for this work order.
    pub fn at(&self, step: u64, worker: u32, chunk: u32) -> Option<FaultKind> {
        self.actions
            .iter()
            .find(|a| a.step == step && a.worker == worker && a.chunk == chunk)
            .map(|a| a.kind)
    }

    pub fn is_empty(&self) -> bool {
        self.actions.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_fires_and_roundtrips() -> Result<()> {
        let plan = FaultPlan::parse("kill@3:1:0, stall@5:0:2:250 ,drop@7:2:1")?;
        assert!(!plan.is_empty());
        assert_eq!(plan.at(3, 1, 0), Some(FaultKind::Kill));
        assert_eq!(plan.at(5, 0, 2), Some(FaultKind::Stall { ms: 250 }));
        assert_eq!(plan.at(7, 2, 1), Some(FaultKind::DropReply));
        assert_eq!(plan.at(3, 1, 1), None);
        assert_eq!(plan.at(4, 1, 0), None);
        let respec = plan.to_spec();
        assert_eq!(FaultPlan::parse(&respec)?, plan);
        assert_eq!(respec, "kill@3:1:0,stall@5:0:2:250,drop@7:2:1");
        Ok(())
    }

    #[test]
    fn empty_specs_mean_no_faults() -> Result<()> {
        for spec in ["", "  ", ","] {
            let plan = FaultPlan::parse(spec)?;
            assert!(plan.is_empty());
            assert_eq!(plan.to_spec(), "");
        }
        assert_eq!(FaultPlan::default().at(0, 0, 0), None);
        Ok(())
    }

    #[test]
    fn bad_specs_are_rejected_with_context() {
        for spec in
            ["kill", "kill@1:2", "boom@1:2:3", "stall@1:2:3", "kill@1:2:3:4", "kill@a:2:3"]
        {
            let err = match FaultPlan::parse(spec) {
                Err(e) => format!("{e:#}"),
                Ok(_) => String::new(),
            };
            assert!(err.contains("fault plan entry"), "{spec:?} -> {err:?}");
        }
    }
}

//! The coordinator side of the distributed data plane.
//!
//! One [`Coordinator`] owns a localhost TCP listener, a registry of worker
//! connections (each registered through a `Hello` handshake), and the
//! scatter/gather engine behind [`execute`](Coordinator::execute): chunks
//! are leased to workers from a shared in-order queue, replies land in
//! per-chunk slots, and any failure — a missed heartbeat, an expired
//! lease, a broken socket, a protocol mismatch — requeues the chunk and
//! drops the whole connection (framing can no longer be trusted mid
//! request/reply). Dropped workers recover by reconnecting with backoff
//! and re-registering; chunks nobody completed are reported as `None`
//! slots for the caller's in-process fallback.
//!
//! Determinism: *which* worker computes a chunk (or whether it falls back
//! locally) is pure scheduling. Every reply is a pure function of the
//! round's parameters and the chunk's rows, the chunk plan depends only on
//! the batch size, and the caller merges replies in fixed chunk order — so
//! any worker count, fault pattern, or lease outcome produces the same
//! bits as the serial in-process path.
//!
//! Deadlines are carried by the sockets themselves
//! (`set_read_timeout`/`set_write_timeout` = the lease), never by clock
//! reads — the repo's wallclock-in-logic lint stays intact.

use std::collections::VecDeque;
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::fault::FaultPlan;
use super::wire::{self, Msg, WorkReply, WorkRequest};
use super::worker::{run_worker, WorkerConfig};
use crate::runtime::native::NativeEngine;

/// Poison-tolerant lock: a panicking holder must not wedge the data plane
/// (the robustness layer exists precisely for misbehaving participants).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// One registered worker connection.
struct Conn {
    id: u32,
    stream: TcpStream,
    /// Parameter version last sent over this connection (0 = none yet);
    /// `SetState` is re-sent only when the round's version differs.
    sent_version: u64,
    sent_model: String,
}

/// The model content one scatter/gather round runs against. `version`
/// uniquely identifies the parameter content (callers use
/// `state.step + 1`), which is what lets workers cache the last
/// `SetState` across the round's chunks.
pub struct Round<'a> {
    pub step: u64,
    pub version: u64,
    pub model: &'a str,
    pub params: &'a [Vec<f32>],
}

/// Spawns/attaches workers and farms chunk work out to them. Dropping the
/// coordinator shuts the data plane down: registered workers get a
/// `Shutdown`, worker threads are joined, worker processes are reaped.
pub struct Coordinator {
    addr: String,
    registry: Arc<Mutex<Vec<Conn>>>,
    accept: Option<JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
    lease_ms: u64,
    /// Robustness events (worker losses, requeues, degradation), drained
    /// into the trainer's metrics log.
    events: Mutex<Vec<String>>,
    remote_chunks: AtomicU64,
    local_chunks: AtomicU64,
    requeued: AtomicU64,
    worker_losses: AtomicU64,
    /// Serializes rounds: one scatter/gather owns the registry at a time.
    exec: Mutex<()>,
    threads: Mutex<Vec<JoinHandle<()>>>,
    children: Mutex<Vec<Child>>,
    /// Cooperative stop flag shared with in-thread workers.
    stop_workers: Arc<AtomicBool>,
}

impl Coordinator {
    /// Bind an ephemeral localhost listener and start accepting workers.
    /// `lease_ms` (clamped to ≥ 1) is both the heartbeat deadline and the
    /// per-chunk reply lease, carried by the connection's socket timeouts.
    pub fn new(lease_ms: u64) -> Result<Self> {
        let lease_ms = lease_ms.max(1);
        let listener =
            TcpListener::bind("127.0.0.1:0").context("dist: binding coordinator listener")?;
        let addr = listener.local_addr().context("dist: coordinator address")?.to_string();
        let registry: Arc<Mutex<Vec<Conn>>> = Arc::new(Mutex::new(Vec::new()));
        let shutdown = Arc::new(AtomicBool::new(false));
        let reg = Arc::clone(&registry);
        let stop = Arc::clone(&shutdown);
        let accept = thread::Builder::new()
            .name("dist-accept".to_string())
            .spawn(move || accept_loop(listener, reg, stop, lease_ms))
            .context("dist: spawning accept thread")?;
        Ok(Self {
            addr,
            registry,
            accept: Some(accept),
            shutdown,
            lease_ms,
            events: Mutex::new(Vec::new()),
            remote_chunks: AtomicU64::new(0),
            local_chunks: AtomicU64::new(0),
            requeued: AtomicU64::new(0),
            worker_losses: AtomicU64::new(0),
            exec: Mutex::new(()),
            threads: Mutex::new(Vec::new()),
            children: Mutex::new(Vec::new()),
            stop_workers: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The listener address workers dial (`127.0.0.1:<port>`).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    pub fn lease_ms(&self) -> u64 {
        self.lease_ms
    }

    /// Currently registered (idle) worker connections.
    pub fn worker_count(&self) -> usize {
        lock(&self.registry).len()
    }

    /// Poll (bounded, ~10 s) until `n` workers are registered.
    pub fn wait_for_workers(&self, n: usize) -> Result<()> {
        for _ in 0..2_000u32 {
            if self.worker_count() >= n {
                return Ok(());
            }
            thread::sleep(Duration::from_millis(5));
        }
        bail!("dist: timed out waiting for {n} workers (have {})", self.worker_count());
    }

    /// Attach `n` in-thread workers sharing `engine` (the test/bench
    /// harness form; same protocol, no process boundary).
    pub fn spawn_thread_workers(&self, n: usize, engine: Arc<NativeEngine>, plan: &FaultPlan) {
        let mut threads = lock(&self.threads);
        for id in 0..n as u32 {
            let engine = Arc::clone(&engine);
            let addr = self.addr.clone();
            let cfg = WorkerConfig {
                worker_id: id,
                fault_plan: plan.clone(),
                stop: Some(Arc::clone(&self.stop_workers)),
                ..WorkerConfig::default()
            };
            let spawned = thread::Builder::new()
                .name(format!("dist-worker-{id}"))
                .spawn(move || {
                    let _ = run_worker(&engine, &addr, &cfg);
                });
            if let Ok(handle) = spawned {
                threads.push(handle);
            }
        }
    }

    /// Spawn `n` worker processes: `program worker --connect <addr>
    /// --worker-id <id> [--fault-plan <spec>]` — the same binary in
    /// worker mode. Children are killed and reaped on drop.
    pub fn spawn_process_workers(&self, n: usize, program: &Path, plan: &FaultPlan) -> Result<()> {
        let mut children = lock(&self.children);
        for id in 0..n as u32 {
            let mut cmd = Command::new(program);
            cmd.arg("worker")
                .arg("--connect")
                .arg(&self.addr)
                .arg("--worker-id")
                .arg(id.to_string())
                .stdin(Stdio::null())
                .stdout(Stdio::null())
                .stderr(Stdio::null());
            if !plan.is_empty() {
                cmd.arg("--fault-plan").arg(plan.to_spec());
            }
            let child =
                cmd.spawn().with_context(|| format!("dist: spawning worker process {id}"))?;
            children.push(child);
        }
        Ok(())
    }

    /// Record a robustness event (drained by the trainer into its log).
    pub fn note(&self, msg: String) {
        lock(&self.events).push(msg);
    }

    /// Take every event recorded since the last drain.
    pub fn drain_events(&self) -> Vec<String> {
        std::mem::take(&mut *lock(&self.events))
    }

    /// Chunks completed by remote workers.
    pub fn remote_chunks(&self) -> u64 {
        self.remote_chunks.load(Ordering::SeqCst)
    }

    /// Chunks that fell back to in-process compute.
    pub fn local_chunks(&self) -> u64 {
        self.local_chunks.load(Ordering::SeqCst)
    }

    /// Chunks requeued after a lease expiry or disconnect.
    pub fn requeued_chunks(&self) -> u64 {
        self.requeued.load(Ordering::SeqCst)
    }

    /// Connections dropped (heartbeat misses + mid-chunk losses).
    pub fn worker_losses(&self) -> u64 {
        self.worker_losses.load(Ordering::SeqCst)
    }

    pub(crate) fn count_local_chunks(&self, n: u64) {
        self.local_chunks.fetch_add(n, Ordering::SeqCst);
    }

    /// Farm `jobs` (one per chunk, in chunk order) out to the registered
    /// workers. Returns one slot per chunk **in chunk order**; `None`
    /// means no worker completed that chunk before its lease expired (or
    /// none were alive) and the caller must compute it in-process. The
    /// scatter is work-stealing — chunk→worker assignment is pure
    /// scheduling — while every reply is a pure function of (params,
    /// chunk rows), so any completion pattern merges to the same bits.
    pub fn execute(&self, round: &Round<'_>, jobs: &[WorkRequest]) -> Vec<Option<WorkReply>> {
        let mut slots: Vec<Option<WorkReply>> = Vec::new();
        slots.resize_with(jobs.len(), || None);
        if jobs.is_empty() {
            return slots;
        }
        let _serial = lock(&self.exec);
        let conns: Vec<Conn> = std::mem::take(&mut *lock(&self.registry));
        // Heartbeat gate: only workers that answer a ping within the
        // deadline are leased chunks this round.
        let mut live: Vec<Conn> = Vec::new();
        for mut conn in conns {
            if heartbeat(&mut conn, round.step).is_ok() {
                live.push(conn);
            } else {
                self.worker_losses.fetch_add(1, Ordering::SeqCst);
                self.note(format!(
                    "worker {} missed its heartbeat at step {} and was dropped",
                    conn.id, round.step
                ));
            }
        }
        if live.is_empty() {
            return slots;
        }
        let queue: Mutex<VecDeque<u32>> = Mutex::new((0..jobs.len() as u32).collect());
        let results = Mutex::new(slots);
        let survivors: Mutex<Vec<Conn>> = Mutex::new(Vec::new());
        thread::scope(|s| {
            for mut conn in live {
                let queue = &queue;
                let results = &results;
                let survivors = &survivors;
                s.spawn(move || loop {
                    let chunk = match lock(queue).pop_front() {
                        Some(c) => c,
                        None => {
                            lock(survivors).push(conn);
                            return;
                        }
                    };
                    match dispatch(&mut conn, round, &jobs[chunk as usize], chunk) {
                        Ok(reply) => {
                            lock(results)[chunk as usize] = Some(reply);
                            self.remote_chunks.fetch_add(1, Ordering::SeqCst);
                        }
                        Err(e) => {
                            // Requeue first so an idle peer can pick the
                            // chunk up, then drop the connection — the
                            // worker re-registers via a fresh handshake.
                            lock(queue).push_front(chunk);
                            self.requeued.fetch_add(1, Ordering::SeqCst);
                            self.worker_losses.fetch_add(1, Ordering::SeqCst);
                            self.note(format!(
                                "worker {} lost at step {} ({e:#}); chunk {chunk} requeued",
                                conn.id, round.step
                            ));
                            return;
                        }
                    }
                });
            }
        });
        lock(&self.registry).append(&mut lock(&survivors));
        results.into_inner().unwrap_or_else(|e| e.into_inner())
    }
}

/// Lease one chunk to a connection: sync parameters if stale, send the
/// work order, await the reply under the socket deadline. Any failure
/// invalidates the connection as a whole — mid-exchange framing cannot be
/// resynced — so the caller requeues and drops it.
fn dispatch(
    conn: &mut Conn,
    round: &Round<'_>,
    job: &WorkRequest,
    chunk: u32,
) -> Result<WorkReply> {
    if conn.sent_version != round.version || conn.sent_model != round.model {
        wire::write_set_state(&mut conn.stream, round.version, round.model, round.params)?;
        conn.sent_version = round.version;
        conn.sent_model = round.model.to_string();
    }
    wire::write_work(&mut conn.stream, round.version, round.step, chunk, job)?;
    match wire::read_frame(&mut conn.stream)? {
        Msg::Reply { chunk: got, out } if got == chunk => Ok(out),
        Msg::Reply { chunk: got, .. } => bail!("reply for chunk {got} while awaiting {chunk}"),
        _ => bail!("unexpected message while awaiting chunk {chunk}"),
    }
}

/// Ping/pong under the socket deadline; the nonce (the step) must echo.
fn heartbeat(conn: &mut Conn, nonce: u64) -> Result<()> {
    wire::write_frame(&mut conn.stream, &Msg::Ping { nonce })?;
    match wire::read_frame(&mut conn.stream)? {
        Msg::Pong { nonce: got } if got == nonce => Ok(()),
        _ => bail!("bad heartbeat reply"),
    }
}

fn accept_loop(
    listener: TcpListener,
    registry: Arc<Mutex<Vec<Conn>>>,
    shutdown: Arc<AtomicBool>,
    lease_ms: u64,
) {
    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(_) => {
                if shutdown.load(Ordering::SeqCst) {
                    return;
                }
                thread::sleep(Duration::from_millis(1));
                continue;
            }
        };
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        if let Some(conn) = handshake(stream, lease_ms) {
            lock(&registry).push(conn);
        }
    }
}

/// Read the dialer's `Hello` under the lease deadline and arm both socket
/// deadlines; a dialer that never completes the handshake is dropped
/// without wedging later accepts.
fn handshake(mut stream: TcpStream, lease_ms: u64) -> Option<Conn> {
    let deadline = Some(Duration::from_millis(lease_ms));
    stream.set_read_timeout(deadline).ok()?;
    stream.set_write_timeout(deadline).ok()?;
    let _ = stream.set_nodelay(true);
    match wire::read_frame(&mut stream) {
        Ok(Msg::Hello { worker_id }) => {
            Some(Conn { id: worker_id, stream, sent_version: 0, sent_model: String::new() })
        }
        _ => None,
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.stop_workers.store(true, Ordering::SeqCst);
        for conn in lock(&self.registry).iter_mut() {
            let _ = wire::write_frame(&mut conn.stream, &Msg::Shutdown);
        }
        lock(&self.registry).clear();
        // Unblock the accept loop (it checks the flag after every accept),
        // then join it so no late registration can slip past the sweep.
        let _ = TcpStream::connect(&self.addr);
        if let Some(handle) = self.accept.take() {
            let _ = handle.join();
        }
        // A worker may have re-registered between the first sweep and the
        // accept join; shut those down too before joining worker threads.
        for conn in lock(&self.registry).iter_mut() {
            let _ = wire::write_frame(&mut conn.stream, &Msg::Shutdown);
        }
        lock(&self.registry).clear();
        let threads: Vec<JoinHandle<()>> = lock(&self.threads).drain(..).collect();
        for handle in threads {
            let _ = handle.join();
        }
        let children: Vec<Child> = lock(&self.children).drain(..).collect();
        for mut child in children {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

//! The worker side of the distributed data plane.
//!
//! A worker is the same binary in `worker` mode (or an in-process thread in
//! the test/bench harnesses): it dials the coordinator, sends `Hello`, and
//! then serves a strict request/reply loop — `Ping`→`Pong`, `SetState`
//! (cache the parameter content for the coming chunks), `Work`→`Reply`,
//! `Shutdown`→exit. Chunk compute goes through the exact per-chunk bodies
//! the in-process engine uses (`runtime::native::{grad_chunk, score_chunk,
//! eval_chunk, grad_norm_chunk}`), so a remote chunk is bit-identical to
//! the same chunk computed locally.
//!
//! Robustness: a broken or timed-out connection sends the worker into a
//! bounded exponential-backoff reconnect loop (the coordinator drops a
//! worker's socket whenever a lease expires; re-registering through a
//! fresh `Hello` is the recovery path). The [`FaultPlan`] hook fires
//! deterministically on (step, worker, chunk) work orders — see
//! [`super::fault`].

use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::fault::{FaultKind, FaultPlan};
use super::wire::{self, Msg, WorkReply, WorkRequest};
use crate::runtime::layers::LayerModel;
use crate::runtime::native::{self, NativeEngine};
use crate::runtime::score::ScorePrecision;
use crate::runtime::tensor::HostTensor;

/// Worker identity, fault schedule and reconnect policy.
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    pub worker_id: u32,
    pub fault_plan: FaultPlan,
    /// Process mode: a `Kill` fault exits the process with status 17 — an
    /// abrupt death the coordinator only observes as a broken socket.
    /// Thread mode leaves this false and lets the worker thread end.
    pub exit_on_kill: bool,
    /// Reconnect attempts before giving up (backoff doubles from
    /// `backoff_base_ms` up to `backoff_cap_ms`).
    pub max_reconnect_attempts: u32,
    pub backoff_base_ms: u64,
    pub backoff_cap_ms: u64,
    /// Cooperative stop flag for in-thread workers, checked between
    /// reconnect attempts so a coordinator teardown never waits out the
    /// whole backoff schedule. Process workers leave it `None`.
    pub stop: Option<Arc<AtomicBool>>,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        Self {
            worker_id: 0,
            fault_plan: FaultPlan::default(),
            exit_on_kill: false,
            max_reconnect_attempts: 8,
            backoff_base_ms: 50,
            backoff_cap_ms: 2_000,
            stop: None,
        }
    }
}

/// Why one connection's serve loop ended.
enum ServeExit {
    /// A `Kill` fault fired.
    Killed,
    /// The coordinator said `Shutdown`.
    Shutdown,
    /// The socket broke (coordinator gone, or it dropped us on an expired
    /// lease) — reconnect with backoff.
    Disconnected,
}

/// Last `SetState` received on this connection: the parameter content all
/// following work orders run against.
struct HeldState {
    version: u64,
    model: String,
    params: Vec<Vec<f32>>,
    /// bf16 shadow of `params`, built once per version on the first bf16
    /// score chunk (`quantize_params` is a pure function of the
    /// parameters, so caching is bit-invisible).
    qparams: Option<Vec<Vec<u16>>>,
}

/// Dial the coordinator and serve until shutdown, a kill fault, or the
/// reconnect budget runs out. The engine provides the model registry; the
/// parameters always come over the wire.
pub fn run_worker(engine: &NativeEngine, addr: &str, cfg: &WorkerConfig) -> Result<()> {
    let mut attempt = 0u32;
    loop {
        if stopped(cfg) {
            return Ok(());
        }
        if let Ok(stream) = TcpStream::connect(addr) {
            attempt = 0;
            match serve(engine, stream, cfg) {
                Ok(ServeExit::Shutdown) => return Ok(()),
                Ok(ServeExit::Killed) => {
                    if cfg.exit_on_kill {
                        std::process::exit(17);
                    }
                    return Ok(());
                }
                Ok(ServeExit::Disconnected) | Err(_) => {}
            }
        }
        attempt += 1;
        if attempt > cfg.max_reconnect_attempts {
            bail!(
                "worker {}: no coordinator after {} reconnect attempts",
                cfg.worker_id,
                attempt - 1
            );
        }
        let backoff = (cfg.backoff_base_ms << (attempt - 1).min(6)).min(cfg.backoff_cap_ms);
        sleep_interruptibly(backoff, cfg);
    }
}

fn stopped(cfg: &WorkerConfig) -> bool {
    cfg.stop.as_ref().is_some_and(|s| s.load(Ordering::SeqCst))
}

/// Backoff sleep in small slices so the stop flag cuts it short.
fn sleep_interruptibly(ms: u64, cfg: &WorkerConfig) {
    let mut left = ms;
    while left > 0 && !stopped(cfg) {
        let slice = left.min(10);
        thread::sleep(Duration::from_millis(slice));
        left -= slice;
    }
}

fn serve(engine: &NativeEngine, mut stream: TcpStream, cfg: &WorkerConfig) -> Result<ServeExit> {
    let _ = stream.set_nodelay(true);
    wire::write_frame(&mut stream, &Msg::Hello { worker_id: cfg.worker_id })?;
    let mut held: Option<HeldState> = None;
    loop {
        let msg = match wire::read_frame(&mut stream) {
            Ok(m) => m,
            Err(_) => return Ok(ServeExit::Disconnected),
        };
        match msg {
            Msg::Ping { nonce } => wire::write_frame(&mut stream, &Msg::Pong { nonce })?,
            Msg::Shutdown => return Ok(ServeExit::Shutdown),
            Msg::SetState { version, model, params } => {
                held = Some(HeldState { version, model, params, qparams: None });
            }
            Msg::Work { version, step, chunk, req } => {
                match cfg.fault_plan.at(step, cfg.worker_id, chunk) {
                    Some(FaultKind::Kill) => return Ok(ServeExit::Killed),
                    Some(FaultKind::Stall { ms }) => thread::sleep(Duration::from_millis(ms)),
                    Some(FaultKind::DropReply) => continue,
                    None => {}
                }
                let state = held
                    .as_mut()
                    .with_context(|| format!("worker {}: Work before SetState", cfg.worker_id))?;
                if state.version != version {
                    bail!(
                        "worker {}: work wants version {version} but holding {}",
                        cfg.worker_id,
                        state.version
                    );
                }
                let out = compute(engine, state, req)?;
                wire::write_frame(&mut stream, &Msg::Reply { chunk, out })?;
            }
            Msg::Hello { .. } | Msg::Pong { .. } | Msg::Reply { .. } => {
                bail!("worker {}: unexpected coordinator message", cfg.worker_id)
            }
        }
    }
}

/// Run one work order through the shared per-chunk bodies.
fn compute(engine: &NativeEngine, state: &mut HeldState, req: WorkRequest) -> Result<WorkReply> {
    let model = engine.layer_model(&state.model)?;
    match req {
        WorkRequest::Grad { dim, x, y, w, scale } => {
            let t = chunk_tensor(model, dim, x, y.len())?;
            let out = native::grad_chunk(model, &state.params, &t, &y, w.as_deref(), scale)?;
            Ok(WorkReply::Grad {
                grads: out.grads,
                weighted_loss: out.weighted_loss,
                loss: out.loss,
                scores: out.scores,
            })
        }
        WorkRequest::Score { dim, x, y, precision } => {
            let t = chunk_tensor(model, dim, x, y.len())?;
            let precision = ScorePrecision::from_code(precision)
                .with_context(|| format!("worker: unknown score precision code {precision}"))?;
            let qp = match precision {
                ScorePrecision::F32 => None,
                ScorePrecision::Bf16 => {
                    if state.qparams.is_none() {
                        state.qparams = Some(model.quantize_params(&state.params));
                    }
                    state.qparams.as_deref()
                }
            };
            let (loss, scores) = native::score_chunk(model, &state.params, qp, &t, &y)?;
            Ok(WorkReply::Score { loss, scores })
        }
        WorkRequest::Eval { dim, x, y } => {
            let t = chunk_tensor(model, dim, x, y.len())?;
            let (sum_loss, correct) = native::eval_chunk(model, &state.params, &t, &y)?;
            Ok(WorkReply::Eval { sum_loss, correct })
        }
        WorkRequest::GradNorm { dim, x, y } => {
            let t = chunk_tensor(model, dim, x, y.len())?;
            let norms = native::grad_norm_chunk(model, &state.params, &t, &y)?;
            Ok(WorkReply::GradNorm { norms })
        }
    }
}

/// Validate wire geometry against the model and wrap the rows in a tensor.
fn chunk_tensor(model: &LayerModel, dim: u32, x: Vec<f32>, rows: usize) -> Result<HostTensor> {
    let d = dim as usize;
    if d != model.in_dim() {
        bail!("wire: chunk dim {d} does not match model in_dim {}", model.in_dim());
    }
    if rows == 0 || x.len() != rows * d {
        bail!("wire: chunk geometry mismatch ({} floats, {rows} rows of dim {d})", x.len());
    }
    Ok(HostTensor::new(vec![rows, d], x))
}

//! Length-prefixed, std-only wire protocol for the distributed data plane.
//!
//! Framing: every message is a `u32` little-endian payload length followed
//! by the payload; the payload's first byte is the message tag. All scalars
//! are little-endian and floats travel as raw IEEE-754 bits (`to_bits` /
//! `from_bits`), so every value round-trips **bit-exactly** — the transport
//! can never perturb the repo's bit-determinism contract. No serde, no
//! bincode: the whole codec is the cursor below, and any decode error is
//! treated by both ends as a broken connection (there is no resync point
//! inside a stream).

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

/// Sanity cap on a single frame (256 MiB): a corrupt length prefix fails
/// fast instead of attempting a multi-gigabyte allocation.
pub const MAX_FRAME_BYTES: usize = 1 << 28;

/// Cap on decoded string fields (model names).
const MAX_STR_BYTES: usize = 1 << 16;

const TAG_HELLO: u8 = 1;
const TAG_PING: u8 = 2;
const TAG_PONG: u8 = 3;
const TAG_SET_STATE: u8 = 4;
const TAG_WORK: u8 = 5;
const TAG_REPLY: u8 = 6;
const TAG_SHUTDOWN: u8 = 7;

const REQ_GRAD: u8 = 1;
const REQ_SCORE: u8 = 2;
const REQ_EVAL: u8 = 3;
const REQ_GRAD_NORM: u8 = 4;

const REP_GRAD: u8 = 1;
const REP_SCORE: u8 = 2;
const REP_EVAL: u8 = 3;
const REP_GRAD_NORM: u8 = 4;

/// Every message either end can send. Workers send `Hello` once per
/// connection, then answer `Ping`/`SetState`/`Work`/`Shutdown`; the
/// coordinator sends everything else and reads `Pong`/`Reply`.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    Hello { worker_id: u32 },
    Ping { nonce: u64 },
    Pong { nonce: u64 },
    SetState { version: u64, model: String, params: Vec<Vec<f32>> },
    Work { version: u64, step: u64, chunk: u32, req: WorkRequest },
    Reply { chunk: u32, out: WorkReply },
    Shutdown,
}

/// One chunk of batch-level work: the chunk's rows (row-major `x`, labels
/// `y`) plus the entry-specific extras. `dim` is the feature dimension, so
/// the row count is `x.len() / dim`.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkRequest {
    Grad { dim: u32, x: Vec<f32>, y: Vec<i32>, w: Option<Vec<f32>>, scale: f32 },
    Score { dim: u32, x: Vec<f32>, y: Vec<i32>, precision: u8 },
    Eval { dim: u32, x: Vec<f32>, y: Vec<i32> },
    GradNorm { dim: u32, x: Vec<f32>, y: Vec<i32> },
}

/// A chunk's result, mirroring [`WorkRequest`] variant for variant.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkReply {
    Grad { grads: Vec<Vec<f32>>, weighted_loss: f64, loss: Vec<f32>, scores: Vec<f32> },
    Score { loss: Vec<f32>, scores: Vec<f32> },
    Eval { sum_loss: f64, correct: i64 },
    GradNorm { norms: Vec<f32> },
}

fn put_u8(b: &mut Vec<u8>, v: u8) {
    b.push(v);
}

fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(b: &mut Vec<u8>, v: f32) {
    put_u32(b, v.to_bits());
}

fn put_f64(b: &mut Vec<u8>, v: f64) {
    put_u64(b, v.to_bits());
}

fn put_str(b: &mut Vec<u8>, s: &str) {
    put_u32(b, s.len() as u32);
    b.extend_from_slice(s.as_bytes());
}

fn put_f32s(b: &mut Vec<u8>, v: &[f32]) {
    put_u32(b, v.len() as u32);
    for &x in v {
        put_f32(b, x);
    }
}

fn put_i32s(b: &mut Vec<u8>, v: &[i32]) {
    put_u32(b, v.len() as u32);
    for &x in v {
        put_u32(b, x as u32);
    }
}

fn put_mat(b: &mut Vec<u8>, m: &[Vec<f32>]) {
    put_u32(b, m.len() as u32);
    for t in m {
        put_f32s(b, t);
    }
}

/// Bounds-checked decode cursor; every take bails (never panics) on a
/// truncated or oversized field.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            bail!("wire: truncated frame ({} bytes left, {n} needed)", self.buf.len() - self.pos);
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn i64(&mut self) -> Result<i64> {
        Ok(self.u64()? as i64)
    }

    fn string(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        if n > MAX_STR_BYTES {
            bail!("wire: string field of {n} bytes exceeds the cap");
        }
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).context("wire: string field is not utf-8")
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let nb = n.checked_mul(4).context("wire: vector length overflow")?;
        let bytes = self.take(nb)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])))
            .collect())
    }

    fn i32s(&mut self) -> Result<Vec<i32>> {
        let n = self.u32()? as usize;
        let nb = n.checked_mul(4).context("wire: vector length overflow")?;
        let bytes = self.take(nb)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]) as i32)
            .collect())
    }

    fn mat(&mut self) -> Result<Vec<Vec<f32>>> {
        let n = self.u32()? as usize;
        if n > self.buf.len() - self.pos {
            bail!("wire: tensor count {n} exceeds the frame");
        }
        (0..n).map(|_| self.f32s()).collect()
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("wire: {} trailing bytes in frame", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

fn encode_set_state_into(b: &mut Vec<u8>, version: u64, model: &str, params: &[Vec<f32>]) {
    put_u8(b, TAG_SET_STATE);
    put_u64(b, version);
    put_str(b, model);
    put_mat(b, params);
}

fn encode_work_into(b: &mut Vec<u8>, version: u64, step: u64, chunk: u32, req: &WorkRequest) {
    put_u8(b, TAG_WORK);
    put_u64(b, version);
    put_u64(b, step);
    put_u32(b, chunk);
    put_req(b, req);
}

fn put_req(b: &mut Vec<u8>, req: &WorkRequest) {
    match req {
        WorkRequest::Grad { dim, x, y, w, scale } => {
            put_u8(b, REQ_GRAD);
            put_u32(b, *dim);
            put_f32s(b, x);
            put_i32s(b, y);
            match w {
                Some(w) => {
                    put_u8(b, 1);
                    put_f32s(b, w);
                }
                None => put_u8(b, 0),
            }
            put_f32(b, *scale);
        }
        WorkRequest::Score { dim, x, y, precision } => {
            put_u8(b, REQ_SCORE);
            put_u32(b, *dim);
            put_f32s(b, x);
            put_i32s(b, y);
            put_u8(b, *precision);
        }
        WorkRequest::Eval { dim, x, y } => {
            put_u8(b, REQ_EVAL);
            put_u32(b, *dim);
            put_f32s(b, x);
            put_i32s(b, y);
        }
        WorkRequest::GradNorm { dim, x, y } => {
            put_u8(b, REQ_GRAD_NORM);
            put_u32(b, *dim);
            put_f32s(b, x);
            put_i32s(b, y);
        }
    }
}

fn put_reply(b: &mut Vec<u8>, out: &WorkReply) {
    match out {
        WorkReply::Grad { grads, weighted_loss, loss, scores } => {
            put_u8(b, REP_GRAD);
            put_mat(b, grads);
            put_f64(b, *weighted_loss);
            put_f32s(b, loss);
            put_f32s(b, scores);
        }
        WorkReply::Score { loss, scores } => {
            put_u8(b, REP_SCORE);
            put_f32s(b, loss);
            put_f32s(b, scores);
        }
        WorkReply::Eval { sum_loss, correct } => {
            put_u8(b, REP_EVAL);
            put_f64(b, *sum_loss);
            put_u64(b, *correct as u64);
        }
        WorkReply::GradNorm { norms } => {
            put_u8(b, REP_GRAD_NORM);
            put_f32s(b, norms);
        }
    }
}

fn take_req(c: &mut Cursor<'_>) -> Result<WorkRequest> {
    match c.u8()? {
        REQ_GRAD => {
            let dim = c.u32()?;
            let x = c.f32s()?;
            let y = c.i32s()?;
            let w = match c.u8()? {
                0 => None,
                1 => Some(c.f32s()?),
                other => bail!("wire: bad option tag {other}"),
            };
            let scale = c.f32()?;
            Ok(WorkRequest::Grad { dim, x, y, w, scale })
        }
        REQ_SCORE => {
            let dim = c.u32()?;
            let x = c.f32s()?;
            let y = c.i32s()?;
            let precision = c.u8()?;
            Ok(WorkRequest::Score { dim, x, y, precision })
        }
        REQ_EVAL => {
            let dim = c.u32()?;
            let x = c.f32s()?;
            let y = c.i32s()?;
            Ok(WorkRequest::Eval { dim, x, y })
        }
        REQ_GRAD_NORM => {
            let dim = c.u32()?;
            let x = c.f32s()?;
            let y = c.i32s()?;
            Ok(WorkRequest::GradNorm { dim, x, y })
        }
        other => bail!("wire: unknown request tag {other}"),
    }
}

fn take_reply(c: &mut Cursor<'_>) -> Result<WorkReply> {
    match c.u8()? {
        REP_GRAD => {
            let grads = c.mat()?;
            let weighted_loss = c.f64()?;
            let loss = c.f32s()?;
            let scores = c.f32s()?;
            Ok(WorkReply::Grad { grads, weighted_loss, loss, scores })
        }
        REP_SCORE => {
            let loss = c.f32s()?;
            let scores = c.f32s()?;
            Ok(WorkReply::Score { loss, scores })
        }
        REP_EVAL => Ok(WorkReply::Eval { sum_loss: c.f64()?, correct: c.i64()? }),
        REP_GRAD_NORM => Ok(WorkReply::GradNorm { norms: c.f32s()? }),
        other => bail!("wire: unknown reply tag {other}"),
    }
}

impl Msg {
    fn encode(&self) -> Vec<u8> {
        let mut b = Vec::new();
        match self {
            Msg::Hello { worker_id } => {
                put_u8(&mut b, TAG_HELLO);
                put_u32(&mut b, *worker_id);
            }
            Msg::Ping { nonce } => {
                put_u8(&mut b, TAG_PING);
                put_u64(&mut b, *nonce);
            }
            Msg::Pong { nonce } => {
                put_u8(&mut b, TAG_PONG);
                put_u64(&mut b, *nonce);
            }
            Msg::SetState { version, model, params } => {
                encode_set_state_into(&mut b, *version, model, params);
            }
            Msg::Work { version, step, chunk, req } => {
                encode_work_into(&mut b, *version, *step, *chunk, req);
            }
            Msg::Reply { chunk, out } => {
                put_u8(&mut b, TAG_REPLY);
                put_u32(&mut b, *chunk);
                put_reply(&mut b, out);
            }
            Msg::Shutdown => put_u8(&mut b, TAG_SHUTDOWN),
        }
        b
    }
}

/// Decode one payload (without the length prefix).
pub fn decode(buf: &[u8]) -> Result<Msg> {
    let mut c = Cursor::new(buf);
    let msg = match c.u8()? {
        TAG_HELLO => Msg::Hello { worker_id: c.u32()? },
        TAG_PING => Msg::Ping { nonce: c.u64()? },
        TAG_PONG => Msg::Pong { nonce: c.u64()? },
        TAG_SET_STATE => {
            Msg::SetState { version: c.u64()?, model: c.string()?, params: c.mat()? }
        }
        TAG_WORK => Msg::Work {
            version: c.u64()?,
            step: c.u64()?,
            chunk: c.u32()?,
            req: take_req(&mut c)?,
        },
        TAG_REPLY => Msg::Reply { chunk: c.u32()?, out: take_reply(&mut c)? },
        TAG_SHUTDOWN => Msg::Shutdown,
        other => bail!("wire: unknown message tag {other}"),
    };
    c.done()?;
    Ok(msg)
}

fn write_payload(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME_BYTES {
        bail!("wire: frame of {} bytes exceeds the cap", payload.len());
    }
    w.write_all(&(payload.len() as u32).to_le_bytes()).context("wire: writing frame length")?;
    w.write_all(payload).context("wire: writing frame payload")?;
    w.flush().context("wire: flushing frame")?;
    Ok(())
}

/// Write one framed message.
pub fn write_frame(w: &mut impl Write, msg: &Msg) -> Result<()> {
    write_payload(w, &msg.encode())
}

/// Borrowed-field writer for the hot path: identical bytes to
/// `write_frame(&Msg::SetState { .. })` without cloning the parameters.
pub fn write_set_state(
    w: &mut impl Write,
    version: u64,
    model: &str,
    params: &[Vec<f32>],
) -> Result<()> {
    let mut b = Vec::new();
    encode_set_state_into(&mut b, version, model, params);
    write_payload(w, &b)
}

/// Borrowed-field writer for work orders (same bytes as
/// `write_frame(&Msg::Work { .. })` without cloning the chunk).
pub fn write_work(
    w: &mut impl Write,
    version: u64,
    step: u64,
    chunk: u32,
    req: &WorkRequest,
) -> Result<()> {
    let mut b = Vec::new();
    encode_work_into(&mut b, version, step, chunk, req);
    write_payload(w, &b)
}

/// Read one framed message (blocking; honors the stream's read timeout —
/// the coordinator's lease deadline rides on exactly this).
pub fn read_frame(r: &mut impl Read) -> Result<Msg> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len).context("wire: reading frame length")?;
    let len = u32::from_le_bytes(len) as usize;
    if len == 0 || len > MAX_FRAME_BYTES {
        bail!("wire: bad frame length {len}");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).context("wire: reading frame payload")?;
    decode(&payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: &Msg) -> Result<Msg> {
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, msg)?;
        read_frame(&mut &buf[..])
    }

    #[test]
    fn every_message_roundtrips_bit_exactly() -> Result<()> {
        let msgs = vec![
            Msg::Hello { worker_id: 3 },
            Msg::Ping { nonce: u64::MAX },
            Msg::Pong { nonce: 0 },
            Msg::SetState {
                version: 7,
                model: "mlp10".to_string(),
                params: vec![vec![1.5, -0.0, f32::MIN_POSITIVE], vec![]],
            },
            Msg::Work {
                version: 7,
                step: 11,
                chunk: 2,
                req: WorkRequest::Grad {
                    dim: 3,
                    x: vec![0.25; 6],
                    y: vec![-1, 2],
                    w: Some(vec![0.5, 2.0]),
                    scale: 0.125,
                },
            },
            Msg::Work {
                version: 8,
                step: 12,
                chunk: 0,
                req: WorkRequest::Score { dim: 2, x: vec![1.0, 2.0], y: vec![1], precision: 1 },
            },
            Msg::Work {
                version: 8,
                step: 12,
                chunk: 1,
                req: WorkRequest::Eval { dim: 1, x: vec![3.0], y: vec![0] },
            },
            Msg::Work {
                version: 8,
                step: 13,
                chunk: 4,
                req: WorkRequest::GradNorm { dim: 1, x: vec![4.0], y: vec![2] },
            },
            Msg::Reply {
                chunk: 9,
                out: WorkReply::Grad {
                    grads: vec![vec![1.0e-30, -2.5]],
                    weighted_loss: 0.1f64.sin(),
                    loss: vec![0.5],
                    scores: vec![0.25],
                },
            },
            Msg::Reply { chunk: 1, out: WorkReply::Score { loss: vec![], scores: vec![] } },
            Msg::Reply { chunk: 2, out: WorkReply::Eval { sum_loss: -4.25, correct: -3 } },
            Msg::Reply { chunk: 3, out: WorkReply::GradNorm { norms: vec![0.0, 1.0] } },
            Msg::Shutdown,
        ];
        for msg in &msgs {
            assert_eq!(&roundtrip(msg)?, msg);
        }
        Ok(())
    }

    #[test]
    fn borrowed_writers_match_the_owned_encoding() -> Result<()> {
        let params = vec![vec![1.0, 2.0], vec![3.0]];
        let mut a: Vec<u8> = Vec::new();
        write_set_state(&mut a, 5, "gold", &params)?;
        let mut b: Vec<u8> = Vec::new();
        write_frame(
            &mut b,
            &Msg::SetState { version: 5, model: "gold".to_string(), params: params.clone() },
        )?;
        assert_eq!(a, b);

        let req = WorkRequest::Eval { dim: 2, x: vec![1.0, 2.0], y: vec![1] };
        let mut a: Vec<u8> = Vec::new();
        write_work(&mut a, 5, 9, 3, &req)?;
        let mut b: Vec<u8> = Vec::new();
        write_frame(&mut b, &Msg::Work { version: 5, step: 9, chunk: 3, req })?;
        assert_eq!(a, b);
        Ok(())
    }

    #[test]
    fn corrupt_frames_error_instead_of_panicking() -> Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, &Msg::Ping { nonce: 1 })?;
        // truncated payload
        assert!(read_frame(&mut &buf[..buf.len() - 1]).is_err());
        // zero / oversized length prefixes
        assert!(read_frame(&mut &0u32.to_le_bytes()[..]).is_err());
        let huge = (MAX_FRAME_BYTES as u32 + 1).to_le_bytes();
        assert!(read_frame(&mut &huge[..]).is_err());
        // unknown tag and trailing garbage
        assert!(decode(&[99]).is_err());
        assert!(decode(&[TAG_SHUTDOWN, 0]).is_err());
        // truncated vector length inside a reply
        let mut b = vec![TAG_REPLY];
        put_u32(&mut b, 0);
        put_u8(&mut b, REP_GRAD_NORM);
        put_u32(&mut b, 1000);
        assert!(decode(&b).is_err());
        Ok(())
    }
}

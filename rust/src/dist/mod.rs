//! Fault-tolerant multi-process distribution of chunk compute.
//!
//! The process model: one **coordinator** (the trainer process) owns the
//! training loop, the sampler, and all state; N **workers** — the same
//! binary in `worker` mode, or in-thread twins in tests — dial it over
//! localhost TCP and serve chunk-sized work orders (gradient, score, eval
//! and gradient-norm chunks cut by the same planners the in-process
//! engine uses). Replies are merged **in fixed chunk order**, so any
//! worker count, any interleaving, and any fault pattern produce bits
//! identical to the serial in-process run.
//!
//! The layers, bottom-up:
//!
//! * [`wire`] — length-prefixed, std-only message codec (floats travel as
//!   IEEE-754 bit patterns; transport is bit-exact).
//! * [`fault`] — deterministic fault injection: kill/stall/drop-reply at
//!   `(step, worker, chunk)` triples, a pure function of the work order.
//! * [`worker`] — the serve loop plus bounded-backoff reconnect.
//! * [`coordinator`] — registry, heartbeats, chunk leases with
//!   requeue-on-timeout, per-round scatter/gather.
//! * [`engine`] — [`DistEngine`], the [`Backend`](crate::runtime::backend::Backend)
//!   that ties it together and degrades to the in-process engine when all
//!   workers are lost.

pub mod coordinator;
pub mod engine;
pub mod fault;
pub mod wire;
pub mod worker;

pub use coordinator::{Coordinator, Round};
pub use engine::DistEngine;
pub use fault::{FaultKind, FaultPlan, ENV_FAULT_PLAN};
pub use wire::{Msg, WorkReply, WorkRequest};
pub use worker::{run_worker, WorkerConfig};

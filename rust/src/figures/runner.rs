//! One harness per paper figure. Each writes CSVs under `results/<fig>/`
//! with the same series the paper plots, and prints a short summary with
//! the paper-vs-measured comparison hooks used by EXPERIMENTS.md.
//!
//! | harness | paper result                                             |
//! |---------|----------------------------------------------------------|
//! | fig1    | variance reduction vs uniform over training              |
//! | fig2    | p(loss)/p(ub) vs p(gradnorm) scatter + SSE               |
//! | fig3    | image classification wall-clock curves, all baselines    |
//! | fig4    | fine-tuning wall-clock curves                            |
//! | fig5    | LSTM sequence classification wall-clock curves           |
//! | fig6    | SVRG/Katyusha/SCSG comparison                            |
//! | fig7    | presample-size (B) ablation                              |

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::analysis::correlation::correlation_at_state;
use crate::analysis::variance::{measure_at_state, VarianceConfig};
use crate::baselines::svrg::{run_svrg, SvrgConfig};
use crate::coordinator::metrics::CsvSink;
use crate::coordinator::sampler::SamplerKind;
use crate::coordinator::trainer::{Trainer, TrainerConfig};
use crate::coordinator::StrategyKind;
use crate::data::finetune::FinetuneFeatures;
use crate::data::sequence::PermutedSequences;
use crate::data::synthetic::SyntheticImages;
use crate::data::{Dataset, Split};
use crate::runtime::pool::default_train_workers;
use crate::runtime::score::{default_score_workers, ScorePrecision};
use crate::runtime::Backend;

/// Shared options for every figure harness.
#[derive(Debug, Clone)]
pub struct FigOptions {
    /// wall-clock budget per training run (seconds)
    pub budget_secs: f64,
    pub out_dir: PathBuf,
    /// independent seeds to average over (paper: 3)
    pub seeds: Vec<u64>,
    /// smaller datasets / fewer checkpoints for smoke runs
    pub quick: bool,
    /// override the model used by figures that allow it
    pub model: Option<String>,
    /// presample scoring workers for every training run (1 = serial)
    pub score_workers: usize,
    /// batch-compute workers for every training run (bit-identical for
    /// any count — see `TrainerConfig::train_workers`)
    pub train_workers: usize,
    /// staleness budget for the cached-score legs of figures that sweep
    /// the score cache (fig7). `None` = the sweep's default budget; it
    /// never changes the full re-score legs.
    pub score_refresh_budget: Option<u64>,
    /// re-sampling backend for every training run (`--sampler`; default
    /// alias, the golden-pinned path — see `TrainerConfig::sampler`)
    pub sampler: SamplerKind,
    /// presample scoring precision for every training run
    /// (`--score-precision`; default f32, the golden-pinned path — see
    /// `TrainerConfig::score_precision`)
    pub score_precision: ScorePrecision,
}

impl Default for FigOptions {
    fn default() -> Self {
        Self {
            budget_secs: 60.0,
            out_dir: PathBuf::from("results"),
            seeds: vec![42],
            quick: false,
            model: None,
            score_workers: default_score_workers(),
            train_workers: default_train_workers(),
            score_refresh_budget: None,
            sampler: SamplerKind::Alias,
            score_precision: ScorePrecision::F32,
        }
    }
}

/// A dataset matched to a model's `feature_dim`/`num_classes`
/// (DESIGN.md §2).
pub enum AnyDataset {
    Images(SyntheticImages),
    Finetune(FinetuneFeatures),
    Sequences(PermutedSequences),
}

impl Dataset for AnyDataset {
    fn len(&self) -> usize {
        match self {
            AnyDataset::Images(d) => d.len(),
            AnyDataset::Finetune(d) => d.len(),
            AnyDataset::Sequences(d) => d.len(),
        }
    }

    fn feature_dim(&self) -> usize {
        match self {
            AnyDataset::Images(d) => d.feature_dim(),
            AnyDataset::Finetune(d) => d.feature_dim(),
            AnyDataset::Sequences(d) => d.feature_dim(),
        }
    }

    fn num_classes(&self) -> usize {
        match self {
            AnyDataset::Images(d) => d.num_classes(),
            AnyDataset::Finetune(d) => d.num_classes(),
            AnyDataset::Sequences(d) => d.num_classes(),
        }
    }

    fn label(&self, i: usize) -> i32 {
        match self {
            AnyDataset::Images(d) => d.label(i),
            AnyDataset::Finetune(d) => d.label(i),
            AnyDataset::Sequences(d) => d.label(i),
        }
    }

    fn write_features(&self, i: usize, epoch: u64, out: &mut [f32]) {
        match self {
            AnyDataset::Images(d) => d.write_features(i, epoch, out),
            AnyDataset::Finetune(d) => d.write_features(i, epoch, out),
            AnyDataset::Sequences(d) => d.write_features(i, epoch, out),
        }
    }
}

/// Build the matched train/test split for a model (DESIGN.md §2 table).
pub fn dataset_for(
    backend: &dyn Backend,
    model: &str,
    seed: u64,
    quick: bool,
) -> Result<Split<AnyDataset>> {
    let info = backend.model_info(model)?;
    let (d, c) = (info.feature_dim, info.num_classes);
    let scale = if quick { 4 } else { 1 };
    Ok(match model {
        "mlp10" | "mlp100" | "cnn10" | "cnn100" | "conv10" => {
            // The cnn/mlp100 workloads are tuned into the paper's regime:
            // training stays gradient-noise-limited for the whole budget
            // (CIFAR with a wideresnet never reaches ~zero train loss in
            // the paper's window either). 55% easy / 30% boundary / 15%
            // outliers with wider easy noise keeps a heavy informative
            // tail.
            let hard = model.starts_with("cnn") || model == "mlp100";
            let mut b = SyntheticImages::builder(d, c)
                .samples(16_384 / scale)
                .test_samples(2_048.min(4_096 / scale))
                .seed(seed)
                .augment(true);
            if hard {
                b = b.tiers(0.55, 0.30).noise(0.4, 1.5);
            }
            let s = b.split();
            Split { train: AnyDataset::Images(s.train), test: AnyDataset::Images(s.test) }
        }
        "finetune" => {
            let s = FinetuneFeatures::builder(d, c)
                .samples(5_360 / scale)
                .test_samples(1_340.min(1_340 / scale.min(2)))
                .seed(seed)
                .split();
            Split { train: AnyDataset::Finetune(s.train), test: AnyDataset::Finetune(s.test) }
        }
        "lstm" | "seq64" => {
            let s = PermutedSequences::builder(d, c)
                .samples(8_192 / scale)
                .test_samples(1_024)
                .seed(seed)
                .split();
            Split { train: AnyDataset::Sequences(s.train), test: AnyDataset::Sequences(s.test) }
        }
        _ => bail!("no dataset mapping for model {model:?}"),
    })
}

fn fig_dir(opts: &FigOptions, fig: &str) -> Result<PathBuf> {
    let dir = opts.out_dir.join(fig);
    std::fs::create_dir_all(&dir)?;
    Ok(dir)
}

/// The model a figure defaults to when the caller does not pick one: the
/// paper's architecture on PJRT, its native stand-in otherwise.
fn default_model(backend: &dyn Backend, pjrt: &str, native: &str) -> String {
    if backend.name() == "native" {
        native.into()
    } else {
        pjrt.into()
    }
}

/// One-line notice for a figure (or one strategy of it) gated off by
/// [`Backend::supports`] — announced instead of silently writing nothing.
fn skip_notice(backend: &dyn Backend, fig: &str, model: &str, entry: &str, batch: usize) {
    println!("SKIP {fig} {model}: {entry}@{batch} unsupported on backend {}", backend.name());
}

/// Like [`skip_notice`] for models absent from the backend's registry.
fn skip_unknown_model(backend: &dyn Backend, fig: &str, model: &str, entry: &str) {
    println!(
        "SKIP {fig} {model}: {entry} unsupported on backend {} (model not registered)",
        backend.name()
    );
}

/// True when `entry@batch` can run; prints the SKIP notice and returns
/// false otherwise. Unknown models count as unsupported, not as errors, so
/// `figure all` completes on any backend.
fn supported_or_skip(
    backend: &dyn Backend,
    fig: &str,
    model: &str,
    entry: &str,
    batch: usize,
) -> bool {
    if backend.supports(model, entry, batch).unwrap_or(false) {
        return true;
    }
    skip_notice(backend, fig, model, entry, batch);
    false
}

/// Dispatch by figure name.
pub fn run_figure(backend: &dyn Backend, name: &str, opts: &FigOptions) -> Result<()> {
    match name {
        "fig1" => fig1_variance(backend, opts),
        "fig2" => fig2_correlation(backend, opts),
        "fig3" => fig3_image(backend, opts),
        "fig4" => fig4_finetune(backend, opts),
        "fig5" => fig5_lstm(backend, opts),
        "fig6" => fig6_svrg(backend, opts),
        "fig7" => fig7_presample(backend, opts),
        "ablation" => ablation_extensions(backend, opts),
        "all" => {
            for f in ["fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7"] {
                run_figure(backend, f, opts)?;
            }
            Ok(())
        }
        _ => bail!("unknown figure {name:?} (fig1..fig7 or all)"),
    }
}

/// Fig 1: variance reduction vs uniform at checkpoints along a training
/// run, for loss / upper-bound / gradient-norm sampling.
pub fn fig1_variance(backend: &dyn Backend, opts: &FigOptions) -> Result<()> {
    let model = opts.model.clone().unwrap_or_else(|| default_model(backend, "cnn100", "mlp100"));
    let Ok(info) = backend.model_info(&model) else {
        skip_unknown_model(backend, "fig1", &model, "grad_norms");
        return Ok(());
    };
    let presample = *info.presample.iter().max().unwrap();
    if !supported_or_skip(backend, "fig1", &model, "grad_norms", presample) {
        return Ok(());
    }
    let dir = fig_dir(opts, "fig1")?;
    let split = dataset_for(backend, &model, 1, opts.quick)?;
    let vcfg = VarianceConfig {
        presample,
        batch: info.batch,
        repeats: if opts.quick { 3 } else { 10 },
        seed: 7,
    };
    let checkpoints = if opts.quick { 4 } else { 8 };
    let steps_between = if opts.quick { 50 } else { 300 };

    let mut sink = CsvSink::create(
        dir.join("variance.csv"),
        "model,step,uniform,loss,upper_bound,grad_norm,tau",
    )?;
    // train with uniform SGD (the paper measures along a normal training
    // trajectory) and measure at checkpoints
    let cfg = TrainerConfig::uniform(&model)
        .with_steps(steps_between as u64)
        .with_train_workers(opts.train_workers);
    let mut trainer = Trainer::new(backend, cfg)?;
    for ck in 0..=checkpoints {
        if ck > 0 {
            trainer.cfg.max_steps = Some(steps_between as u64);
            let _ = trainer.run(&split.train, None)?;
        }
        let step = ck as u64 * steps_between as u64;
        let p = measure_at_state(backend, &trainer.state, &split.train, &vcfg, step)?;
        println!(
            "fig1 [{model}] step {step}: loss {:.3} upper-bound {:.3} grad-norm {:.3} (uniform=1, tau {:.2})",
            p.loss, p.upper_bound, p.grad_norm, p.tau
        );
        sink.row(&model, &[step as f64, p.uniform, p.loss, p.upper_bound, p.grad_norm, p.tau])?;
    }
    Ok(())
}

/// Fig 2: scatter of p(loss), p(upper-bound) against p(gradient-norm) on a
/// trained network + the SSE numbers quoted in §4.1.
pub fn fig2_correlation(backend: &dyn Backend, opts: &FigOptions) -> Result<()> {
    let model = opts.model.clone().unwrap_or_else(|| default_model(backend, "cnn100", "mlp100"));
    let Ok(info) = backend.model_info(&model) else {
        skip_unknown_model(backend, "fig2", &model, "grad_norms");
        return Ok(());
    };
    let chunk = *info.presample.iter().max().unwrap();
    if !supported_or_skip(backend, "fig2", &model, "grad_norms", chunk) {
        return Ok(());
    }
    let dir = fig_dir(opts, "fig2")?;
    let split = dataset_for(backend, &model, 1, opts.quick)?;

    // train to a reasonable state first (paper uses a trained wideresnet)
    let steps = if opts.quick { 200 } else { 2_000 };
    let cfg =
        TrainerConfig::uniform(&model).with_steps(steps).with_train_workers(opts.train_workers);
    let mut trainer = Trainer::new(backend, cfg)?;
    let _ = trainer.run(&split.train, None)?;

    let total = if opts.quick { 2_048 } else { 16_384 };
    let rep = correlation_at_state(backend, &trainer.state, &split.train, total, chunk, 7)?;

    let mut sink = CsvSink::create(dir.join("scatter.csv"), "tag,p_gradnorm,p_loss,p_upper_bound")?;
    for (gn, lo, ub) in &rep.points {
        sink.row(&model, &[*gn as f64, *lo as f64, *ub as f64])?;
    }
    let mut summary = CsvSink::create(
        dir.join("summary.csv"),
        "model,sse_loss,sse_upper_bound,spearman_loss,spearman_ub,pearson_loss,pearson_ub",
    )?;
    summary.row(
        &model,
        &[
            rep.sse_loss,
            rep.sse_upper_bound,
            rep.spearman_loss,
            rep.spearman_upper_bound,
            rep.pearson_loss,
            rep.pearson_upper_bound,
        ],
    )?;
    println!(
        "fig2 [{model}]: SSE loss {:.4} vs upper-bound {:.4} (paper: 0.017 vs 0.002); spearman {:.3} vs {:.3}",
        rep.sse_loss, rep.sse_upper_bound, rep.spearman_loss, rep.spearman_upper_bound
    );
    Ok(())
}

/// Run one strategy config for every seed; write per-run CSVs; return the
/// across-seed mean (final train loss, final test err). Strategies whose
/// scoring entry the backend cannot run (e.g. no baked artifact at the
/// requested presample B) announce a one-line `SKIP` and drop out instead
/// of leaving an unexplained hole in `summary.csv`.
fn run_strategies(
    backend: &dyn Backend,
    dir: &Path,
    fig: &str,
    model: &str,
    configs: Vec<(String, TrainerConfig)>,
    opts: &FigOptions,
) -> Result<()> {
    let info = backend.model_info(model)?;
    if !supported_or_skip(backend, fig, model, "train_step", info.batch) {
        return Ok(());
    }
    let mut summary = CsvSink::create(
        dir.join("summary.csv"),
        "strategy,seeds,final_train_loss,final_test_err,steps_per_sec,switch_step",
    )?;
    for (tag, cfg) in configs {
        // one scoring-requirement policy with Trainer::new (never drifts)
        if let Some((entry, b)) = cfg.scoring_requirement(info) {
            if !supported_or_skip(backend, fig, model, entry, b) {
                continue;
            }
        }
        let mut losses = vec![];
        let mut errs = vec![];
        let mut sps = vec![];
        let mut switch = f64::NAN;
        for &seed in &opts.seeds {
            let split = dataset_for(backend, model, seed, opts.quick)?;
            let mut c = cfg
                .clone()
                .with_seed(seed)
                .with_score_workers(opts.score_workers)
                .with_train_workers(opts.train_workers)
                .with_sampler(opts.sampler)
                .with_score_precision(opts.score_precision);
            c.eval_every_secs = (opts.budget_secs / 12.0).max(1.0);
            let mut trainer = Trainer::new(backend, c)?;
            let report = trainer.run(&split.train, Some(&split.test))?;
            report.log.to_csv(dir.join(format!("{tag}_seed{seed}.csv")))?;
            losses.push(report.final_train_loss);
            errs.push(report.final_test_err);
            sps.push(report.steps as f64 / report.wall_secs.max(1e-9));
            if let Some(s) = report.is_switch_step {
                switch = s as f64;
            }
            println!(
                "  {tag} seed {seed}: {} steps, train loss {:.4}, test err {:.4}, IS@{:?}",
                report.steps, report.final_train_loss, report.final_test_err,
                report.is_switch_step
            );
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        summary.row(
            &tag,
            &[opts.seeds.len() as f64, mean(&losses), mean(&errs), mean(&sps), switch],
        )?;
    }
    Ok(())
}

/// Fig 3: image classification (CIFAR-10/100 stand-ins) — uniform vs loss
/// vs upper-bound vs Loshchilov-Hutter vs Schaul, equal wall-clock. On the
/// native backend the default pair covers two architectures: the mlp10
/// stand-in and the conv10 small convnet (layer-IR Conv1d scenario).
pub fn fig3_image(backend: &dyn Backend, opts: &FigOptions) -> Result<()> {
    let models: Vec<String> = match &opts.model {
        Some(m) => vec![m.clone()],
        None if backend.name() == "native" => vec!["mlp10".into(), "conv10".into()],
        None => vec!["cnn10".into(), "cnn100".into()],
    };
    for model in models {
        if backend.model_info(&model).is_err() {
            skip_unknown_model(backend, "fig3", &model, "train_step");
            continue;
        }
        println!("fig3 [{model}] budget {}s x{} seeds", opts.budget_secs, opts.seeds.len());
        let dir = fig_dir(opts, &format!("fig3_{model}"))?;
        let budget = opts.budget_secs;
        // §4.2: B=640, tau_th=1.5, lr 0.1 /5 at 40%/80% of the time budget
        let mk = |mut c: TrainerConfig| {
            c.presample = 640;
            c.tau_th = 1.5;
            c.lr_milestones = vec![(0.4, 0.2), (0.8, 0.2)];
            c.with_budget(budget)
        };
        let configs = vec![
            ("uniform".into(), mk(TrainerConfig::uniform(&model))),
            ("loss".into(), mk(TrainerConfig::loss(&model))),
            ("upper-bound".into(), mk(TrainerConfig::upper_bound(&model))),
            ("loshchilov-hutter".into(), mk(TrainerConfig::loshchilov_hutter(&model))),
            ("schaul".into(), mk(TrainerConfig::schaul(&model))),
        ];
        run_strategies(backend, &dir, "fig3", &model, configs, opts)?;
    }
    Ok(())
}

/// Fig 4: fine-tuning (MIT67 stand-in) — uniform vs loss vs upper-bound.
pub fn fig4_finetune(backend: &dyn Backend, opts: &FigOptions) -> Result<()> {
    let model = "finetune";
    if backend.model_info(model).is_err() {
        skip_unknown_model(backend, "fig4", model, "train_step");
        return Ok(());
    }
    println!("fig4 [{model}] budget {}s", opts.budget_secs);
    let dir = fig_dir(opts, "fig4")?;
    // §4.3: b=16, B=48, lr 1e-3, tau_th = 2 (designated by Eq. 26)
    let mk = |mut c: TrainerConfig| {
        c.presample = 48;
        c.tau_th = 2.0;
        c.base_lr = 1e-3;
        c.lr_milestones = vec![];
        c.with_budget(opts.budget_secs)
    };
    let configs = vec![
        ("uniform".into(), mk(TrainerConfig::uniform(model))),
        ("loss".into(), mk(TrainerConfig::loss(model))),
        ("upper-bound".into(), mk(TrainerConfig::upper_bound(model))),
    ];
    run_strategies(backend, &dir, "fig4", model, configs, opts)
}

/// Fig 5: pixel-by-pixel sequence classification — the paper's LSTM on
/// PJRT, the seq64 EmbeddingBag sequence net (layer-IR scenario) on the
/// native backend, both over the same permuted-raster dataset.
pub fn fig5_lstm(backend: &dyn Backend, opts: &FigOptions) -> Result<()> {
    let model = opts.model.clone().unwrap_or_else(|| default_model(backend, "lstm", "seq64"));
    if backend.model_info(&model).is_err() {
        skip_unknown_model(backend, "fig5", &model, "train_step");
        return Ok(());
    }
    println!("fig5 [{model}] budget {}s", opts.budget_secs);
    let dir = fig_dir(opts, "fig5")?;
    // §4.4: b=32, B=128, tau_th=1.8, Adam in the paper — we keep SGD+mom
    // with a smaller lr (documented deviation; same comparison protocol).
    let mk = |mut c: TrainerConfig| {
        c.presample = 128;
        c.tau_th = 1.8;
        c.base_lr = 0.05;
        c.lr_milestones = vec![];
        c.with_budget(opts.budget_secs)
    };
    let configs = vec![
        ("uniform".into(), mk(TrainerConfig::uniform(&model))),
        ("loss".into(), mk(TrainerConfig::loss(&model))),
        ("upper-bound".into(), mk(TrainerConfig::upper_bound(&model))),
    ];
    run_strategies(backend, &dir, "fig5", &model, configs, opts)
}

/// Fig 6 (App. C): SVRG / Katyusha / SCSG vs SGD-uniform vs upper-bound.
pub fn fig6_svrg(backend: &dyn Backend, opts: &FigOptions) -> Result<()> {
    let model = opts.model.clone().unwrap_or_else(|| default_model(backend, "cnn10", "mlp10"));
    let Ok(info) = backend.model_info(&model) else {
        skip_unknown_model(backend, "fig6", &model, "train_step");
        return Ok(());
    };
    if !supported_or_skip(backend, "fig6", &model, "train_step", info.batch) {
        return Ok(());
    }
    println!("fig6 [{model}] budget {}s", opts.budget_secs);
    let dir = fig_dir(opts, "fig6")?;
    let budget = opts.budget_secs;
    let seed = opts.seeds[0];
    let split = dataset_for(backend, &model, seed, opts.quick)?;

    // SGD strategies via the trainer
    let sgd_cfgs = vec![
        ("uniform".to_string(), TrainerConfig::uniform(&model).with_budget(budget)),
        (
            "upper-bound".to_string(),
            TrainerConfig::upper_bound(&model).with_presample(640).with_budget(budget),
        ),
    ];
    let mut summary = CsvSink::create(
        dir.join("summary.csv"),
        "method,steps,final_train_loss,final_test_err",
    )?;
    for (tag, cfg) in sgd_cfgs {
        if let Some((entry, b)) = cfg.scoring_requirement(info) {
            if !supported_or_skip(backend, "fig6", &model, entry, b) {
                continue;
            }
        }
        let cfg = cfg
            .with_seed(seed)
            .with_score_workers(opts.score_workers)
            .with_train_workers(opts.train_workers);
        let mut trainer = Trainer::new(backend, cfg)?;
        let report = trainer.run(&split.train, Some(&split.test))?;
        report.log.to_csv(dir.join(format!("{tag}.csv")))?;
        summary.row(&tag, &[report.steps as f64, report.final_train_loss, report.final_test_err])?;
        println!(
            "  {tag}: {} steps, train loss {:.4}, test err {:.4}",
            report.steps, report.final_train_loss, report.final_test_err
        );
    }

    // SVRG family (snapshot + inner gradients shard over the same pool);
    // it runs on the `grad` entry — announce and stop instead of erroring
    // mid-figure when the backend cannot execute it
    if !supported_or_skip(backend, "fig6", &model, "grad", info.batch) {
        return Ok(());
    }
    for cfg in [
        SvrgConfig::svrg(&model).with_budget(budget).with_train_workers(opts.train_workers),
        SvrgConfig::katyusha(&model).with_budget(budget).with_train_workers(opts.train_workers),
        SvrgConfig::scsg(&model, 1024).with_budget(budget).with_train_workers(opts.train_workers),
    ] {
        let report = run_svrg(backend, &cfg, &split.train, Some(&split.test))?;
        report.log.to_csv(dir.join(format!("{}.csv", report.name)))?;
        summary.row(
            report.name,
            &[report.steps as f64, report.final_train_loss, report.final_test_err],
        )?;
        println!(
            "  {}: {} steps, train loss {:.4}, test err {:.4}",
            report.name, report.steps, report.final_train_loss, report.final_test_err
        );
    }
    Ok(())
}

/// Extension ablation (paper §5 future work): τ-adaptive learning rate on
/// top of the upper-bound sampler, vs the paper's main algorithm, vs
/// uniform. Writes results/ablation/summary.csv.
pub fn ablation_extensions(backend: &dyn Backend, opts: &FigOptions) -> Result<()> {
    let model = opts.model.clone().unwrap_or_else(|| default_model(backend, "cnn100", "mlp100"));
    if backend.model_info(&model).is_err() {
        skip_unknown_model(backend, "ablation", &model, "train_step");
        return Ok(());
    }
    println!("ablation [{model}] budget {}s", opts.budget_secs);
    let dir = fig_dir(opts, "ablation")?;
    let mk = |c: TrainerConfig| {
        c.with_presample(640).with_tau_th(1.5).with_budget(opts.budget_secs)
    };
    let configs = vec![
        ("uniform".to_string(), mk(TrainerConfig::uniform(&model))),
        ("upper-bound".to_string(), mk(TrainerConfig::upper_bound(&model))),
        (
            "upper-bound+adaptive-lr".to_string(),
            mk(TrainerConfig::upper_bound(&model)).with_adaptive_lr(2.0),
        ),
    ];
    run_strategies(backend, &dir, "ablation", &model, configs, opts)
}

/// Fig 7 (App. D): ablation on the presample size B.
pub fn fig7_presample(backend: &dyn Backend, opts: &FigOptions) -> Result<()> {
    let model = opts.model.clone().unwrap_or_else(|| default_model(backend, "cnn10", "mlp10"));
    let Ok(info) = backend.model_info(&model) else {
        skip_unknown_model(backend, "fig7", &model, "train_step");
        return Ok(());
    };
    println!("fig7 [{model}] budget {}s", opts.budget_secs);
    let dir = fig_dir(opts, "fig7")?;
    let mut configs = vec![(
        "uniform".to_string(),
        TrainerConfig::uniform(&model).with_budget(opts.budget_secs),
    )];
    for &b in &info.presample {
        configs.push((
            format!("B{b}"),
            TrainerConfig::upper_bound(&model)
                .with_presample(b)
                .with_tau_th(1.5)
                .with_budget(opts.budget_secs),
        ));
    }
    // the cached half of the sweep: same B ladder, but presample scores
    // are served from the staleness cache for up to k steps, so the
    // presample-cost curve shows what `--score-refresh-budget` buys
    let k = opts.score_refresh_budget.unwrap_or(50);
    for &b in &info.presample {
        configs.push((
            format!("B{b}_cached{k}"),
            TrainerConfig::upper_bound(&model)
                .with_presample(b)
                .with_tau_th(1.5)
                .with_score_refresh_budget(Some(k))
                .with_budget(opts.budget_secs),
        ));
    }
    run_strategies(backend, &dir, "fig7", &model, configs, opts)
}

//! One harness per paper figure; each writes a CSV under results/.
pub mod runner;

//! Metrics: per-step rows, wall-clock curves and CSV sinks.
//!
//! The paper's protocol compares methods at *equal wall-clock time*
//! (§4.2), so every row carries elapsed seconds; the figure harnesses plot
//! loss/error against that column rather than against steps.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

/// One logged observation.
#[derive(Debug, Clone)]
pub struct Row {
    pub step: u64,
    pub secs: f64,
    pub train_loss: f64,
    /// smoothed τ (Eq. 26); 0 before the first observation
    pub tau: f64,
    /// whether importance sampling was active this step
    pub is_active: bool,
    pub lr: f64,
    /// NaN when no eval was run at this row
    pub test_loss: f64,
    pub test_err: f64,
}

/// In-memory metrics log; the figure harnesses read it, `to_csv` persists.
#[derive(Debug, Default, Clone)]
pub struct MetricsLog {
    pub rows: Vec<Row>,
    /// (phase, total seconds) pairs from the trainer's PhaseTimers
    pub phase_seconds: Vec<(String, f64)>,
    /// Operational events: `(step, message)` pairs drained from the
    /// backend (worker losses, chunk requeues, degradation to in-process
    /// compute) plus trainer-side notes. Events describe *scheduling*,
    /// never results — a run with events is still bit-identical to one
    /// without.
    pub events: Vec<(u64, String)>,
}

impl MetricsLog {
    pub fn push(&mut self, row: Row) {
        self.rows.push(row);
    }

    /// Record an operational event at `step`.
    pub fn note(&mut self, step: u64, msg: String) {
        self.events.push((step, msg));
    }

    pub fn last_train_loss(&self) -> Option<f64> {
        self.rows.last().map(|r| r.train_loss)
    }

    /// Latest row that actually carries an evaluation.
    pub fn last_eval(&self) -> Option<&Row> {
        self.rows.iter().rev().find(|r| !r.test_err.is_nan())
    }

    /// Smoothed train loss over the trailing `k` rows.
    pub fn trailing_train_loss(&self, k: usize) -> Option<f64> {
        if self.rows.is_empty() {
            return None;
        }
        let tail = &self.rows[self.rows.len().saturating_sub(k)..];
        Some(tail.iter().map(|r| r.train_loss).sum::<f64>() / tail.len() as f64)
    }

    /// First step at which importance sampling switched on, if ever.
    pub fn is_switch_on_step(&self) -> Option<u64> {
        self.rows.iter().find(|r| r.is_active).map(|r| r.step)
    }

    pub fn to_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {:?}", path.as_ref()))?;
        writeln!(f, "step,secs,train_loss,tau,is_active,lr,test_loss,test_err")?;
        for r in &self.rows {
            writeln!(
                f,
                "{},{:.3},{:.6},{:.4},{},{:.6},{:.6},{:.6}",
                r.step,
                r.secs,
                r.train_loss,
                r.tau,
                r.is_active as u8,
                r.lr,
                r.test_loss,
                r.test_err
            )?;
        }
        // events ride along as comment lines so the numeric shape of the
        // CSV (header + one line per row) is unchanged for event-free runs
        for (step, msg) in &self.events {
            writeln!(f, "# event,{step},{msg}")?;
        }
        Ok(())
    }
}

/// A generic CSV sink for the figure harnesses (header + f64 rows with an
/// optional string tag column).
pub struct CsvSink {
    file: std::fs::File,
}

impl CsvSink {
    pub fn create(path: impl AsRef<Path>, header: &str) -> Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent).ok();
        }
        let mut file = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {:?}", path.as_ref()))?;
        writeln!(file, "{header}")?;
        Ok(Self { file })
    }

    pub fn row(&mut self, tag: &str, values: &[f64]) -> Result<()> {
        let mut line = String::from(tag);
        for v in values {
            line.push(',');
            line.push_str(&format!("{v:.6}"));
        }
        writeln!(self.file, "{line}")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(step: u64, active: bool, err: f64) -> Row {
        Row {
            step,
            secs: step as f64 * 0.1,
            train_loss: 2.0 / (step + 1) as f64,
            tau: 1.0,
            is_active: active,
            lr: 0.1,
            test_loss: f64::NAN,
            test_err: err,
        }
    }

    #[test]
    fn log_queries() {
        let mut log = MetricsLog::default();
        log.push(row(0, false, f64::NAN));
        log.push(row(1, false, 0.5));
        log.push(row(2, true, f64::NAN));
        assert_eq!(log.is_switch_on_step(), Some(2));
        assert_eq!(log.last_eval().unwrap().step, 1);
        assert!(log.trailing_train_loss(2).unwrap() < 1.0);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let dir = std::env::temp_dir().join(format!("isample_csv_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.csv");
        let mut log = MetricsLog::default();
        log.push(row(0, false, 0.9));
        log.push(row(1, true, 0.8));
        log.to_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("step,secs"));
        assert!(lines[2].contains(",1,")); // is_active column
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn events_ride_csv_as_comment_lines() -> Result<()> {
        let dir = std::env::temp_dir().join(format!("isample_csv_ev_{}", std::process::id()));
        std::fs::create_dir_all(&dir)?;
        let path = dir.join("m.csv");
        let mut log = MetricsLog::default();
        log.push(row(0, false, 0.9));
        log.note(7, "worker 1 lost; chunk 3 requeued".to_string());
        log.to_csv(&path)?;
        let text = std::fs::read_to_string(&path)?;
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "header + row + event comment");
        assert_eq!(lines[2], "# event,7,worker 1 lost; chunk 3 requeued");
        std::fs::remove_dir_all(&dir).ok();
        Ok(())
    }

    #[test]
    fn csv_sink_writes_tagged_rows() {
        let dir = std::env::temp_dir().join(format!("isample_sink_{}", std::process::id()));
        let path = dir.join("fig.csv");
        let mut sink = CsvSink::create(&path, "method,x,y").unwrap();
        sink.row("uniform", &[1.0, 2.0]).unwrap();
        sink.row("upper-bound", &[1.0, 0.5]).unwrap();
        drop(sink);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("uniform,1.000000,2.000000"));
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Loss-history stores for the history-based baselines (§2.2, §4.2):
//! online batch selection (Loshchilov & Hutter, 2015) and proportional
//! prioritized sampling (Schaul et al., 2015).
//!
//! Both keep a per-sample record of the most recently observed loss and
//! sample the next batch from it; both suffer the staleness problem the
//! paper criticizes (values age as the model moves), which is exactly the
//! behaviour the Fig-3 comparison needs to reproduce.

use crate::util::rng::SplitMix64;

use super::resample::AliasSampler;

/// Latest-loss store with staleness accounting.
#[derive(Debug, Clone)]
pub struct LossHistory {
    losses: Vec<f32>,
    last_update_step: Vec<u64>,
    /// Optimistic initial loss for never-seen samples (max priority, as in
    /// Schaul et al.: new transitions get max priority).
    init_loss: f32,
}

impl LossHistory {
    pub fn new(n: usize, init_loss: f32) -> Self {
        Self { losses: vec![init_loss; n], last_update_step: vec![0; n], init_loss }
    }

    pub fn len(&self) -> usize {
        self.losses.len()
    }

    pub fn is_empty(&self) -> bool {
        self.losses.is_empty()
    }

    pub fn loss(&self, i: usize) -> f32 {
        self.losses[i]
    }

    pub fn losses(&self) -> &[f32] {
        &self.losses
    }

    pub fn record(&mut self, indices: &[usize], losses: &[f32], step: u64) {
        debug_assert_eq!(indices.len(), losses.len());
        for (&i, &l) in indices.iter().zip(losses) {
            self.losses[i] = l;
            self.last_update_step[i] = step;
        }
    }

    pub fn record_all(&mut self, losses: &[f32], step: u64) {
        debug_assert_eq!(losses.len(), self.losses.len());
        self.losses.copy_from_slice(losses);
        for s in self.last_update_step.iter_mut() {
            *s = step;
        }
    }

    /// Mean age (in steps) of the stored values at `now` — the staleness
    /// metric surfaced in the metrics log.
    pub fn mean_staleness(&self, now: u64) -> f64 {
        if self.losses.is_empty() {
            return 0.0;
        }
        self.last_update_step.iter().map(|&s| (now - s) as f64).sum::<f64>()
            / self.losses.len() as f64
    }

    pub fn reset(&mut self) {
        for l in self.losses.iter_mut() {
            *l = self.init_loss;
        }
    }
}

/// Online batch selection (Loshchilov & Hutter 2015): rank the stored
/// losses in decreasing order and pick rank r with probability
/// `p_r ∝ exp(-log(s)/N · r)` so the max/min probability ratio is `s`.
/// Every `recompute_every` steps the caller refreshes *all* losses (the
/// expensive full pass the paper criticizes); every `sort_every` steps the
/// rank order is rebuilt.
pub struct LoshchilovHutter {
    pub history: LossHistory,
    /// max/min selection probability ratio (paper grid: 1, 10, 100).
    pub s: f64,
    /// full loss-recompute period in steps (paper grid: 600/1200/3600).
    pub recompute_every: u64,
    /// rank-order rebuild period.
    pub sort_every: u64,
    /// indices sorted by decreasing stored loss.
    order: Vec<usize>,
    /// rank-distribution sampler (over ranks, not indices).
    rank_sampler: AliasSampler,
    last_sort_step: u64,
}

impl LoshchilovHutter {
    pub fn new(n: usize, s: f64, recompute_every: u64, sort_every: u64) -> Self {
        let history = LossHistory::new(n, f32::MAX / 2.0);
        let order: Vec<usize> = (0..n).collect();
        let rank_sampler = AliasSampler::new(&rank_probs(n, s));
        Self { history, s, recompute_every, sort_every, order, rank_sampler, last_sort_step: 0 }
    }

    /// True when the trainer should refresh every stored loss this step.
    pub fn needs_recompute(&self, step: u64) -> bool {
        step > 0 && step % self.recompute_every == 0
    }

    /// Record a full loss refresh *and resort immediately*: after the
    /// expensive recompute the fresh values must drive selection now, not
    /// up to `sort_every` steps later on the stale rank order.
    pub fn record_all(&mut self, losses: &[f32], step: u64) {
        self.history.record_all(losses, step);
        self.resort(step);
    }

    fn resort(&mut self, step: u64) {
        let losses = &self.history;
        self.order.sort_by(|&a, &b| {
            losses.loss(b).partial_cmp(&losses.loss(a)).unwrap_or(std::cmp::Ordering::Equal)
        });
        self.last_sort_step = step;
    }

    fn maybe_sort(&mut self, step: u64) {
        if step >= self.last_sort_step + self.sort_every || step == 0 {
            self.resort(step);
        }
    }

    /// Select `b` dataset indices for this step.
    pub fn select(&mut self, b: usize, step: u64, rng: &mut SplitMix64) -> Vec<usize> {
        self.maybe_sort(step);
        (0..b).map(|_| self.order[self.rank_sampler.draw(rng)]).collect()
    }

    pub fn observe(&mut self, indices: &[usize], losses: &[f32], step: u64) {
        self.history.record(indices, losses, step);
    }
}

/// `p_r ∝ exp(-log(s)/N * r)` over ranks r = 0..N-1.
fn rank_probs(n: usize, s: f64) -> Vec<f32> {
    let lam = s.ln() / n as f64;
    let raw: Vec<f64> = (0..n).map(|r| (-lam * r as f64).exp()).collect();
    let total: f64 = raw.iter().sum();
    raw.into_iter().map(|p| (p / total) as f32).collect()
}

/// Proportional prioritized sampling (Schaul et al. 2015):
/// `p_i ∝ (loss_i + eps)^alpha`, importance-corrected with
/// `w_i = (N p_i)^(-beta)`, normalized by `max w` for stability.
pub struct SchaulProportional {
    pub history: LossHistory,
    pub alpha: f64,
    pub beta: f64,
    pub eps: f64,
    /// Rebuild the alias table only every `refresh_every` steps — building
    /// is O(N) and the distribution drifts slowly (staleness is inherent to
    /// the method anyway).
    pub refresh_every: u64,
    sampler: Option<AliasSampler>,
    probs: Vec<f32>,
    last_refresh: u64,
}

impl SchaulProportional {
    pub fn new(n: usize, alpha: f64, beta: f64, refresh_every: u64) -> Self {
        Self {
            // optimistic init: max priority for unseen samples
            history: LossHistory::new(n, 10.0),
            alpha,
            beta,
            eps: 1e-6,
            refresh_every,
            sampler: None,
            probs: vec![],
            last_refresh: 0,
        }
    }

    fn refresh(&mut self, step: u64) {
        let raw: Vec<f32> = self
            .history
            .losses()
            .iter()
            .map(|&l| ((l.max(0.0) as f64 + self.eps).powf(self.alpha)) as f32)
            .collect();
        self.probs = crate::util::stats::normalize_probs(&raw);
        self.sampler = Some(AliasSampler::new(&self.probs));
        self.last_refresh = step;
    }

    /// Select `b` indices and their bias-correction weights.
    pub fn select(&mut self, b: usize, step: u64, rng: &mut SplitMix64) -> (Vec<usize>, Vec<f32>) {
        if self.sampler.is_none() || step >= self.last_refresh + self.refresh_every {
            self.refresh(step);
        }
        let sampler = self.sampler.as_ref().unwrap();
        let idx: Vec<usize> = (0..b).map(|_| sampler.draw(rng)).collect();
        let n = self.history.len() as f64;
        let mut w: Vec<f32> = idx
            .iter()
            .map(|&i| ((n * self.probs[i] as f64).max(1e-12)).powf(-self.beta) as f32)
            .collect();
        let wmax = w.iter().cloned().fold(f32::MIN, f32::max).max(1e-12);
        for wi in w.iter_mut() {
            *wi /= wmax;
        }
        (idx, w)
    }

    pub fn observe(&mut self, indices: &[usize], losses: &[f32], step: u64) {
        self.history.record(indices, losses, step);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_probs_ratio_is_s() {
        let p = rank_probs(100, 10.0);
        let ratio = p[0] as f64 / p[99] as f64;
        // p_0/p_{N-1} = exp(log(s) * (N-1)/N) ~ s
        assert!((ratio - 10.0f64.powf(0.99)).abs() < 0.05, "ratio {ratio}");
        // detlint: allow(unordered-float-reduction) — test tolerance 1e-5 absorbs order
        let total: f32 = p.iter().sum();
        assert!((total - 1.0).abs() < 1e-5);
    }

    #[test]
    fn lh_prefers_high_loss_samples() {
        let mut lh = LoshchilovHutter::new(100, 100.0, 600, 10);
        // sample 7 has a huge loss, everyone else tiny
        let losses: Vec<f32> = (0..100).map(|i| if i == 7 { 5.0 } else { 0.01 }).collect();
        lh.history.record_all(&losses, 0);
        let mut rng = SplitMix64::new(3);
        let picks = lh.select(2000, 0, &mut rng);
        let hits = picks.iter().filter(|&&i| i == 7).count();
        assert!(hits > 50, "high-loss sample picked only {hits}/2000");
    }

    #[test]
    fn lh_recompute_schedule() {
        let lh = LoshchilovHutter::new(10, 10.0, 600, 10);
        assert!(!lh.needs_recompute(0));
        assert!(!lh.needs_recompute(599));
        assert!(lh.needs_recompute(600));
        assert!(lh.needs_recompute(1200));
    }

    #[test]
    fn lh_resorts_after_observation() {
        let mut lh = LoshchilovHutter::new(10, 100.0, 600, 1);
        let mut rng = SplitMix64::new(5);
        let mut losses = vec![0.01f32; 10];
        losses[3] = 9.0;
        lh.observe(&(0..10).collect::<Vec<_>>(), &losses, 1);
        let picks = lh.select(500, 2, &mut rng);
        let hits = picks.iter().filter(|&&i| i == 3).count();
        assert!(hits > 100, "{hits}");
        // now sample 3 becomes easy, 8 becomes hard; after sort_every the
        // preference must flip
        losses[3] = 0.01;
        losses[8] = 9.0;
        lh.observe(&(0..10).collect::<Vec<_>>(), &losses, 3);
        let picks = lh.select(2000, 5, &mut rng);
        let hits8 = picks.iter().filter(|&&i| i == 8).count();
        let hits3 = picks.iter().filter(|&&i| i == 3).count();
        // 8 now holds rank 0; 3 ties with the other easy samples. With
        // s=100, n=10 adjacent ranks differ by 100^(1/10) ≈ 1.58x.
        assert!(
            hits8 as f64 > hits3 as f64 * 1.2,
            "preference did not flip: hits8={hits8} hits3={hits3}"
        );
    }

    #[test]
    fn record_all_resorts_immediately() {
        // sort_every is huge: without the forced resort bundled into
        // record_all, a full recompute would keep selecting from the stale
        // rank order for up to sort_every further steps.
        let mut lh = LoshchilovHutter::new(50, 100.0, 600, 1_000_000);
        let mut rng = SplitMix64::new(8);
        let mut losses = vec![0.01f32; 50];
        losses[4] = 9.0;
        lh.record_all(&losses, 0);
        let picks = lh.select(1000, 1, &mut rng);
        let hits4 = picks.iter().filter(|&&i| i == 4).count();
        assert!(hits4 > 30, "initial hot sample under-selected: {hits4}");
        // the recompute flips the hot sample from 4 to 31 at step 10; on
        // the stale ranking sample 31 sits near rank 31 (~5/1000 picks),
        // freshly resorted it holds rank 0 (~93/1000 with s=100, n=50)
        losses[4] = 0.01;
        losses[31] = 9.0;
        lh.record_all(&losses, 10);
        let picks = lh.select(1000, 10, &mut rng);
        let hits31 = picks.iter().filter(|&&i| i == 31).count();
        assert!(hits31 > 60, "fresh recompute did not drive selection: hits31={hits31}");
    }

    #[test]
    fn schaul_weights_bounded_and_biased_toward_high_loss() {
        let mut sp = SchaulProportional::new(50, 1.0, 0.5, 1);
        let losses: Vec<f32> = (0..50).map(|i| if i < 5 { 4.0 } else { 0.05 }).collect();
        sp.history.record_all(&losses, 0);
        let mut rng = SplitMix64::new(1);
        let (idx, w) = sp.select(3000, 1, &mut rng);
        let hot = idx.iter().filter(|&&i| i < 5).count();
        assert!(hot > 1500, "hot picks {hot}/3000");
        assert!(w.iter().all(|&wi| wi > 0.0 && wi <= 1.0 + 1e-6));
        // high-probability samples get the *smallest* weights
        let w_hot: Vec<f32> = idx.iter().zip(&w).filter(|(&i, _)| i < 5).map(|(_, &w)| w).collect();
        let w_cold: Vec<f32> =
            idx.iter().zip(&w).filter(|(&i, _)| i >= 5).map(|(_, &w)| w).collect();
        if !w_hot.is_empty() && !w_cold.is_empty() {
            assert!(
                crate::util::stats::mean(&w_hot) < crate::util::stats::mean(&w_cold),
                "bias correction inverted"
            );
        }
    }

    #[test]
    fn staleness_accounting() {
        let mut h = LossHistory::new(4, 1.0);
        h.record(&[0, 1], &[0.5, 0.6], 10);
        assert_eq!(h.mean_staleness(10), 5.0); // (0+0+10+10)/4
        assert_eq!(h.loss(0), 0.5);
        h.reset();
        assert_eq!(h.loss(0), 1.0);
    }

    #[test]
    fn schaul_alpha_zero_is_uniform() {
        let mut sp = SchaulProportional::new(40, 0.0, 0.5, 1);
        let losses: Vec<f32> = (0..40).map(|i| i as f32).collect();
        sp.history.record_all(&losses, 0);
        let mut rng = SplitMix64::new(9);
        let (idx, w) = sp.select(8000, 1, &mut rng);
        let mut counts = vec![0usize; 40];
        for &i in &idx {
            counts[i] += 1;
        }
        let (min, max) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(*max < min * 2, "alpha=0 should be ~uniform: {min}..{max}");
        assert!(w.iter().all(|&wi| (wi - 1.0).abs() < 1e-5));
    }
}

//! Sampling strategies — who decides what the next training batch is.
//!
//! * [`StrategyKind::Uniform`] — plain SGD (the paper's `uniform`).
//! * [`StrategyKind::Presample`] — Algorithm 1: presample B uniformly,
//!   score, resample b ∝ score with importance weights. The score is the
//!   Eq.-20 `UpperBound` (the paper's method), the raw `Loss` (the common
//!   heuristic baseline) or the true `GradNorm` (the expensive oracle).
//! * [`StrategyKind::LoshchilovHutter`] / [`StrategyKind::Schaul`] — the
//!   history-based published baselines of §4.2.

use crate::util::rng::SplitMix64;
use crate::util::stats::normalize_probs;

use super::resample::{
    importance_weights, rebuild_policy, AliasSampler, CumulativeSampler, FenwickSampler,
};
use super::tau::mixture;

pub use super::resample::SamplerKind;

// `ScoreKind` is owned by the scoring subsystem (`runtime::score`) since
// the sharded-scoring refactor; re-exported here so existing paths keep
// working.
pub use crate::runtime::score::ScoreKind;

/// Strategy configuration (data only — the trainer owns engine access).
#[derive(Debug, Clone)]
pub enum StrategyKind {
    Uniform,
    Presample { score: ScoreKind },
    LoshchilovHutter { s: f64, recompute_every: u64, sort_every: u64 },
    Schaul { alpha: f64, beta: f64, refresh_every: u64 },
}

impl StrategyKind {
    pub fn name(&self) -> String {
        match self {
            StrategyKind::Uniform => "uniform".into(),
            StrategyKind::Presample { score } => score.name().into(),
            StrategyKind::LoshchilovHutter { .. } => "loshchilov-hutter".into(),
            StrategyKind::Schaul { .. } => "schaul".into(),
        }
    }

    /// Parse a CLI name like `uniform`, `upper-bound`, `loss`,
    /// `gradient-norm`, `loshchilov-hutter`, `schaul`.
    pub fn parse(name: &str) -> Option<StrategyKind> {
        Some(match name {
            "uniform" => StrategyKind::Uniform,
            "upper-bound" | "upper_bound" | "ub" => {
                StrategyKind::Presample { score: ScoreKind::UpperBound }
            }
            "loss" => StrategyKind::Presample { score: ScoreKind::Loss },
            "gradient-norm" | "grad-norm" | "gradient_norm" => {
                StrategyKind::Presample { score: ScoreKind::GradNorm }
            }
            "loshchilov-hutter" | "lh" | "online-batch-selection" => {
                StrategyKind::LoshchilovHutter { s: 100.0, recompute_every: 1200, sort_every: 20 }
            }
            "schaul" | "prioritized" => {
                StrategyKind::Schaul { alpha: 1.0, beta: 0.5, refresh_every: 50 }
            }
            _ => return None,
        })
    }
}

/// The outcome of resampling a presample batch: positions *within the
/// presample* (so feature rows can be gathered without regenerating data),
/// plus the matching importance weights.
#[derive(Debug, Clone)]
pub struct ResamplePlan {
    /// positions in 0..B (NOT dataset indices)
    pub positions: Vec<usize>,
    pub weights: Vec<f32>,
    /// the normalized probability vector used (for analysis/τ)
    pub probs: Vec<f32>,
}

/// Resample `b` positions from `scores` (Alg. 1 lines 7–9) with the given
/// backend. `Fenwick` here builds a fresh (presample-sized) tree so all
/// three backends share one interface for tests and benches; the trainer's
/// incremental pool-sized path lives in [`LiveResampler`].
pub fn resample_from_scores(
    scores: &[f32],
    b: usize,
    rng: &mut SplitMix64,
    kind: SamplerKind,
) -> ResamplePlan {
    let probs = normalize_probs(scores);
    let positions = match kind {
        SamplerKind::Alias => AliasSampler::new(&probs).sample(rng, b),
        SamplerKind::Cumulative => CumulativeSampler::new(&probs).sample(rng, b),
        SamplerKind::Fenwick => FenwickSampler::new(&probs).sample(rng, b),
    };
    let weights = importance_weights(&probs, &positions);
    ResamplePlan { positions, weights, probs }
}

/// A training batch drawn from the live pool distribution: dataset (pool)
/// indices — NOT presample positions — plus unbiased mixture importance
/// weights.
#[derive(Debug, Clone)]
pub struct PoolPlan {
    /// indices into the full training pool (0..n)
    pub indices: Vec<usize>,
    /// w_i = 1 / (n · p_mix(i)); bounded by 1/λ
    pub weights: Vec<f32>,
}

/// The live cached-score resampler behind `--sampler fenwick` (ISSUE 8
/// tentpole): a pool-sized [`FenwickSampler`] kept in sync with the
/// [`super::cache::ScoreCache`] so a warm-cache cycle pays O(stale ·
/// log² n) sampler maintenance instead of an O(B) rebuild, and the
/// score-proportional distribution over the *whole pool* stays live
/// between refreshes ("Biggest Losers", PAPERS.md).
///
/// Batches are drawn from the λ-mixture `p_mix = λ·u + (1−λ)·p_score`
/// (see [`mixture`]) with matching unbiased weights `1/(n · p_mix)`.
/// Every draw consumes exactly two rng values (one branch uniform + one
/// for the chosen component), so trajectories are a pure function of
/// (seed, score stream) — staged updates apply via [`Self::commit`]
/// through the bitwise-neutral [`rebuild_policy`].
pub struct LiveResampler {
    tree: FenwickSampler,
    seed: u64,
    /// (pool index, fresh score) pairs staged since the last commit
    pending: Vec<(usize, f32)>,
}

impl LiveResampler {
    /// A live distribution over `n` pool samples, initially all-zero
    /// (drawing before any score lands falls back to uniform).
    pub fn new(n: usize, seed: u64) -> Self {
        Self { tree: FenwickSampler::new(&vec![0.0f32; n]), seed, pending: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.tree.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tree.is_empty()
    }

    /// Stage one freshly scored pool sample for the next [`Self::commit`].
    pub fn stage(&mut self, pool_index: usize, score: f32) {
        self.pending.push((pool_index, score));
    }

    /// Apply all staged updates. The [`rebuild_policy`] — a pure function
    /// of (step, seed, dirty-count, n) — picks bulk rebuild vs per-leaf
    /// `update()`s; both produce bit-identical trees, so the choice only
    /// moves cost. Returns `true` when a bulk rebuild ran.
    pub fn commit(&mut self, step: u64) -> bool {
        let rebuilt =
            rebuild_policy::should_rebuild(step, self.seed, self.pending.len(), self.tree.len());
        if rebuilt {
            let pending = std::mem::take(&mut self.pending);
            self.tree.rebuild_with(&pending);
        } else {
            for (i, s) in self.pending.drain(..) {
                self.tree.update(i, s);
            }
        }
        rebuilt
    }

    /// Draw a `b`-sample batch of pool indices from the λ-mixture, with
    /// unbiased importance weights. A degenerate (all-zero) tree draws
    /// pure uniform with unit weights.
    pub fn plan(&self, b: usize, lambda: f64, rng: &mut SplitMix64) -> PoolPlan {
        let n = self.tree.len();
        let total = self.tree.total_mass();
        let degenerate = !(total > 0.0) || !total.is_finite();
        let lam = if degenerate { 1.0 } else { lambda.clamp(mixture::LAMBDA_FLOOR, 1.0) };
        let mut indices = Vec::with_capacity(b);
        for _ in 0..b {
            // Both arms consume one value after the branch uniform, so a
            // draw always advances the stream by exactly two.
            let i = if rng.uniform() < lam { rng.below(n) } else { self.tree.draw(rng) };
            indices.push(i);
        }
        let weights = indices
            .iter()
            .map(|&i| {
                let p_score = if degenerate { 0.0 } else { self.tree.weight(i) / total };
                let q = mixture::mix_prob(lam, n, p_score);
                let w = (1.0 / (n as f64 * q)) as f32;
                if q > 0.0 && w.is_finite() {
                    w
                } else {
                    eprintln!(
                        "invariant failure: mixture weight for pool index {i} \
                         (q = {q:e}) is not finite; saturating to 0"
                    );
                    0.0
                }
            })
            .collect();
        PoolPlan { indices, weights }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn parse_names_roundtrip() {
        for name in ["uniform", "upper-bound", "loss", "gradient-norm", "lh", "schaul"] {
            assert!(StrategyKind::parse(name).is_some(), "{name}");
        }
        assert!(StrategyKind::parse("bogus").is_none());
    }

    #[test]
    fn resample_plan_invariants() {
        check("resample invariants", 200, |g| {
            let scores = g.scores(2..256);
            let b = g.usize_in(1..64);
            let kind = [SamplerKind::Alias, SamplerKind::Cumulative, SamplerKind::Fenwick]
                [g.usize_in(0..3)];
            let plan = resample_from_scores(&scores, b, &mut g.rng, kind);
            assert_eq!(plan.positions.len(), b);
            assert_eq!(plan.weights.len(), b);
            // probabilities are a distribution
            let total: f64 = plan.probs.iter().map(|&p| p as f64).sum();
            assert!((total - 1.0).abs() < 1e-4, "prob sum {total}");
            // w_i * B * p_i == 1 for every drawn position (unbiasedness)
            let big_b = plan.probs.len() as f64;
            for (&pos, &w) in plan.positions.iter().zip(&plan.weights) {
                let prod = w as f64 * big_b * plan.probs[pos] as f64;
                assert!((prod - 1.0).abs() < 1e-4, "w*B*p = {prod}");
            }
        });
    }

    #[test]
    fn uniform_scores_degenerate_to_unit_weights() {
        let mut rng = SplitMix64::new(4);
        let plan = resample_from_scores(&[1.0; 64], 16, &mut rng, SamplerKind::Alias);
        assert!(plan.weights.iter().all(|&w| (w - 1.0).abs() < 1e-5));
    }

    #[test]
    fn live_resampler_unscored_pool_draws_uniform_unit_weights() {
        let mut live = LiveResampler::new(128, 9);
        let mut rng = SplitMix64::new(2);
        let plan = live.plan(64, 0.3, &mut rng);
        assert_eq!(plan.indices.len(), 64);
        assert!(plan.indices.iter().all(|&i| i < 128));
        assert!(plan.weights.iter().all(|&w| (w - 1.0).abs() < 1e-6), "{:?}", plan.weights);
    }

    #[test]
    fn live_resampler_commit_paths_are_bit_identical() {
        // per-leaf update vs bulk rebuild must yield identical plans; we
        // force each path with dirty counts on either side of the policy
        // threshold and compare against a third tree built directly.
        let n = 512;
        let updates: Vec<(usize, f32)> = (0..40).map(|k| (k * 11 % n, 0.5 + k as f32)).collect();

        // `a`: one staged score per commit — dirty=1, 1·log²(512) < 512 and
        // step 3 misses the seed-1 periodic slot, so every commit takes the
        // per-leaf update path.
        let mut a = LiveResampler::new(n, 1);
        for &(i, s) in &updates {
            a.stage(i, s);
            assert!(!a.commit(3));
        }
        // `b`: all 40 at once — 40·log²(512) ≥ 512 forces the bulk rebuild.
        let mut b = LiveResampler::new(n, 1);
        for &(i, s) in &updates {
            b.stage(i, s);
        }
        assert!(b.commit(1));
        let mut r1 = SplitMix64::new(77);
        let mut r2 = SplitMix64::new(77);
        let p1 = a.plan(256, 0.2, &mut r1);
        let p2 = b.plan(256, 0.2, &mut r2);
        assert_eq!(p1.indices, p2.indices);
        for (w1, w2) in p1.weights.iter().zip(&p2.weights) {
            assert_eq!(w1.to_bits(), w2.to_bits());
        }
    }

    #[test]
    fn live_resampler_mixture_weights_are_unbiased_over_pool() {
        // E_q[w · f] over mixture draws must match the pool mean of f.
        let n = 200;
        let f: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).cos() + 3.0).collect();
        let mut live = LiveResampler::new(n, 5);
        for i in 0..n {
            live.stage(i, 0.1 + (i % 13) as f32);
        }
        live.commit(0);
        let mut rng = SplitMix64::new(21);
        let mut acc = 0.0f64;
        let draws = 400_000;
        let plan = live.plan(draws, 0.35, &mut rng);
        for (&i, &w) in plan.indices.iter().zip(&plan.weights) {
            acc += w as f64 * f[i];
        }
        let est = acc / draws as f64;
        let truth: f64 = f.iter().sum::<f64>() / n as f64;
        assert!((est - truth).abs() < 0.02, "estimate {est} vs {truth}");
    }
}

//! Sampling strategies — who decides what the next training batch is.
//!
//! * [`StrategyKind::Uniform`] — plain SGD (the paper's `uniform`).
//! * [`StrategyKind::Presample`] — Algorithm 1: presample B uniformly,
//!   score, resample b ∝ score with importance weights. The score is the
//!   Eq.-20 `UpperBound` (the paper's method), the raw `Loss` (the common
//!   heuristic baseline) or the true `GradNorm` (the expensive oracle).
//! * [`StrategyKind::LoshchilovHutter`] / [`StrategyKind::Schaul`] — the
//!   history-based published baselines of §4.2.

use crate::util::rng::SplitMix64;
use crate::util::stats::normalize_probs;

use super::resample::{importance_weights, AliasSampler, CumulativeSampler};

// `ScoreKind` is owned by the scoring subsystem (`runtime::score`) since
// the sharded-scoring refactor; re-exported here so existing paths keep
// working.
pub use crate::runtime::score::ScoreKind;

/// Strategy configuration (data only — the trainer owns engine access).
#[derive(Debug, Clone)]
pub enum StrategyKind {
    Uniform,
    Presample { score: ScoreKind },
    LoshchilovHutter { s: f64, recompute_every: u64, sort_every: u64 },
    Schaul { alpha: f64, beta: f64, refresh_every: u64 },
}

impl StrategyKind {
    pub fn name(&self) -> String {
        match self {
            StrategyKind::Uniform => "uniform".into(),
            StrategyKind::Presample { score } => score.name().into(),
            StrategyKind::LoshchilovHutter { .. } => "loshchilov-hutter".into(),
            StrategyKind::Schaul { .. } => "schaul".into(),
        }
    }

    /// Parse a CLI name like `uniform`, `upper-bound`, `loss`,
    /// `gradient-norm`, `loshchilov-hutter`, `schaul`.
    pub fn parse(name: &str) -> Option<StrategyKind> {
        Some(match name {
            "uniform" => StrategyKind::Uniform,
            "upper-bound" | "upper_bound" | "ub" => {
                StrategyKind::Presample { score: ScoreKind::UpperBound }
            }
            "loss" => StrategyKind::Presample { score: ScoreKind::Loss },
            "gradient-norm" | "grad-norm" | "gradient_norm" => {
                StrategyKind::Presample { score: ScoreKind::GradNorm }
            }
            "loshchilov-hutter" | "lh" | "online-batch-selection" => {
                StrategyKind::LoshchilovHutter { s: 100.0, recompute_every: 1200, sort_every: 20 }
            }
            "schaul" | "prioritized" => {
                StrategyKind::Schaul { alpha: 1.0, beta: 0.5, refresh_every: 50 }
            }
            _ => return None,
        })
    }
}

/// The outcome of resampling a presample batch: positions *within the
/// presample* (so feature rows can be gathered without regenerating data),
/// plus the matching importance weights.
#[derive(Debug, Clone)]
pub struct ResamplePlan {
    /// positions in 0..B (NOT dataset indices)
    pub positions: Vec<usize>,
    pub weights: Vec<f32>,
    /// the normalized probability vector used (for analysis/τ)
    pub probs: Vec<f32>,
}

/// Resample `b` positions from `scores` (Alg. 1 lines 7–9).
/// `use_alias` picks the O(1)-per-draw backend.
pub fn resample_from_scores(
    scores: &[f32],
    b: usize,
    rng: &mut SplitMix64,
    use_alias: bool,
) -> ResamplePlan {
    let probs = normalize_probs(scores);
    let positions = if use_alias {
        AliasSampler::new(&probs).sample(rng, b)
    } else {
        CumulativeSampler::new(&probs).sample(rng, b)
    };
    let weights = importance_weights(&probs, &positions);
    ResamplePlan { positions, weights, probs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn parse_names_roundtrip() {
        for name in ["uniform", "upper-bound", "loss", "gradient-norm", "lh", "schaul"] {
            assert!(StrategyKind::parse(name).is_some(), "{name}");
        }
        assert!(StrategyKind::parse("bogus").is_none());
    }

    #[test]
    fn resample_plan_invariants() {
        check("resample invariants", 200, |g| {
            let scores = g.scores(2..256);
            let b = g.usize_in(1..64);
            let use_alias = g.bool();
            let plan = resample_from_scores(&scores, b, &mut g.rng, use_alias);
            assert_eq!(plan.positions.len(), b);
            assert_eq!(plan.weights.len(), b);
            // probabilities are a distribution
            let total: f64 = plan.probs.iter().map(|&p| p as f64).sum();
            assert!((total - 1.0).abs() < 1e-4, "prob sum {total}");
            // w_i * B * p_i == 1 for every drawn position (unbiasedness)
            let big_b = plan.probs.len() as f64;
            for (&pos, &w) in plan.positions.iter().zip(&plan.weights) {
                let prod = w as f64 * big_b * plan.probs[pos] as f64;
                assert!((prod - 1.0).abs() < 1e-4, "w*B*p = {prod}");
            }
        });
    }

    #[test]
    fn uniform_scores_degenerate_to_unit_weights() {
        let mut rng = SplitMix64::new(4);
        let plan = resample_from_scores(&[1.0; 64], 16, &mut rng, true);
        assert!(plan.weights.iter().all(|&w| (w - 1.0).abs() < 1e-5));
    }

}

//! The training coordinator — Algorithm 1 of the paper, plus the uniform
//! and history-based baselines, under the paper's fixed wall-clock
//! protocol.
//!
//! ```text
//! repeat
//!   if τ > τ_th:                         (importance sampling active)
//!     U  <- B uniformly presampled points          (prefetch pipeline)
//!     g  <- ĝ scores of U                          (fwd_scores artifact)
//!     G  <- b points resampled from U with p ∝ g   (alias sampler)
//!     w  <- 1/(B g_i)                              (unbiased weights)
//!     θ  <- sgd_step(w, G)                          (train_step artifact)
//!   else:                                 (uniform warmup)
//!     U  <- b uniform points
//!     θ  <- sgd_step(1, U)
//!     g  <- scores of U                   (free: same forward pass)
//!   τ <- a_τ τ + (1-a_τ) (1 - ||g-u||²/Σg²)^(-1/2)  (Eq. 26)
//! until budget exhausted
//! ```
//!
//! The trainer runs against any [`Backend`] — the PJRT engine (AOT
//! artifacts) or the native CPU engine (`--backend native`, artifact-free).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use anyhow::{bail, Result};

use crate::data::Dataset;
use crate::runtime::pool::default_train_workers;
use crate::runtime::score::{default_score_workers, BackendScorer, ScoreBackend, ScorePrecision};
use crate::runtime::{Backend, ModelInfo, ModelState};
use crate::util::rng::SplitMix64;
use crate::util::timer::{PhaseTimers, Stopwatch};

/// Time an expression into `$timers` under `$phase` without closing over
/// `self` (the expression may itself borrow `self` mutably). Wall-clock
/// reads go through [`crate::util::timer::Stopwatch`] — the one audited
/// clock module (detlint `wallclock-in-logic`) — and only ever feed the
/// perf profile, never a schedule.
macro_rules! timed {
    ($timers:expr, $phase:expr, $e:expr) => {{
        let __sw = crate::util::timer::Stopwatch::new();
        let __out = $e;
        $timers.record($phase, __sw.elapsed());
        __out
    }};
}

use super::cache::ScoreCache;
use super::history::{LoshchilovHutter, SchaulProportional};
use super::metrics::{MetricsLog, Row};
use super::pipeline::{gather_rows, PipelineStats, PrefetchedBatch, Prefetcher};
use super::sampler::{resample_from_scores, LiveResampler, SamplerKind, ScoreKind, StrategyKind};
use super::tau::{mixture, TauEstimator};

/// The score backend for one presample pass. Forward-pass kinds (loss,
/// upper bound) chunk across `score_workers` scoped threads as before;
/// on the native backend each chunk's `fwd_scores` call is the
/// **score-only block forward** (`LayerModel::scores_block`: no gradient
/// scratch, pooled arenas), so the Eq.-6 selection overhead is pure
/// forward cost. The backend itself reports when a kind's scoring pass is
/// already sharded across its own compute
/// ([`Backend::scores_sharded_internally`]): the native grad-norm oracle
/// over a multi-worker train pool, or the distributed engine's chunk
/// fan-out to worker processes. There the backend's layer is the *only*
/// real parallel one — outer score threads would merely funnel their
/// chunks into it and block, adding dispatch overhead without adding
/// parallelism — so the outer layer goes serial and the backend shards
/// the full presample itself. Either layering produces bit-identical
/// scores; this is purely a scheduling choice.
fn score_backend(backend: &dyn Backend, score_workers: usize, kind: ScoreKind) -> ScoreBackend {
    if backend.scores_sharded_internally(kind) {
        ScoreBackend::Serial
    } else {
        ScoreBackend::from_workers(score_workers)
    }
}

/// Where training batches come from: a background prefetch pipeline
/// (multi-core) or inline synchronous assembly (`prefetch_threads = 0`,
/// the single-core fast path — §Perf iter 6).
///
/// **Augmentation-epoch contract** (same in both modes): all sources of a
/// run share one `draws` counter; a batch's epoch is `draws-so-far / n`,
/// i.e. the epoch advances with the *total* samples drawn across the small
/// batch and the presample, exactly as the prefetch pipeline counts them.
pub enum BatchSource<'a, D: Dataset> {
    Sync { dataset: &'a D, batch: usize, rng: SplitMix64, draws: &'a AtomicU64 },
    Prefetched(Prefetcher<'a>),
}

impl<'a, D: Dataset> BatchSource<'a, D> {
    pub fn sync(dataset: &'a D, batch: usize, seed: u64, draws: &'a AtomicU64) -> Self {
        // same stream as prefetch worker 0, so sync and 1-worker runs align
        let rng = SplitMix64::tensor_stream(seed ^ 0xF33D, (batch * 1000) as u64);
        BatchSource::Sync { dataset, batch, rng, draws }
    }

    pub fn prefetched(p: Prefetcher<'a>) -> Self {
        BatchSource::Prefetched(p)
    }

    pub fn next(&mut self) -> PrefetchedBatch {
        match self {
            BatchSource::Sync { dataset, batch, rng, draws } => {
                let n = dataset.len();
                let first_draw = draws.fetch_add(*batch as u64, Ordering::Relaxed);
                let epoch = first_draw / n as u64;
                let indices: Vec<usize> = (0..*batch).map(|_| rng.below(n)).collect();
                let (x, y) = dataset.batch(&indices, epoch);
                PrefetchedBatch { indices, x, y, epoch }
            }
            BatchSource::Prefetched(p) => p.next(),
        }
    }
}

/// Everything configurable about one training run.
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    pub model: String,
    pub strategy: StrategyKind,
    /// presample size B (Alg. 1). Must match a baked fwd_scores artifact.
    pub presample: usize,
    /// τ threshold above which importance sampling switches on.
    pub tau_th: f64,
    /// EMA retention a_τ of Alg. 1 line 17.
    pub a_tau: f64,
    pub base_lr: f32,
    /// (progress fraction, multiplier) — multiplier applies from that
    /// fraction of the budget (or of max_steps) onward. Mirrors the paper's
    /// wall-clock learning-rate schedule (§4.2).
    pub lr_milestones: Vec<(f64, f32)>,
    /// wall-clock budget; None = run to max_steps.
    pub budget_secs: Option<f64>,
    pub max_steps: Option<u64>,
    /// evaluate on the test split every this many seconds (0 = never).
    pub eval_every_secs: f64,
    pub seed: u64,
    /// Re-sampling backend (`--sampler`). `Alias` (default, golden-pinned)
    /// and `Cumulative` rebuild a presample-sized distribution every
    /// cycle; `Fenwick` keeps a *pool-sized* live distribution with
    /// O(log n) partial updates fed by the score cache and draws training
    /// batches from the λ-mixture `λ·u + (1−λ)·p_score` with unbiased
    /// weights (ISSUE 8) — its τ-gate observes the mixture's variance
    /// reduction (`tau::mixture::tau_mixture`) instead of the pure-score
    /// Eq. 26 value.
    pub sampler: SamplerKind,
    pub prefetch_depth: usize,
    /// Prefetch worker count. NOTE: with more than one worker the batch
    /// arrival order is nondeterministic (by design — it is a racy queue);
    /// set to 1 for bit-reproducible runs.
    pub prefetch_threads: usize,
    /// Presample scoring worker threads (`runtime::score`). 1 = serial.
    /// Unlike prefetching, parallel scoring is bit-identical to serial for
    /// a fixed seed (chunks merge in presample order), so this is safe to
    /// raise on reproducibility-sensitive runs. The threaded backend only
    /// engages when `B / score_workers` chunk sizes have baked artifacts;
    /// otherwise it transparently falls back to the serial full-B pass.
    pub score_workers: usize,
    /// Staleness budget (in steps) for the per-sample score cache
    /// (`coordinator::cache`). `None` = unlimited refresh budget: every
    /// presampled row is re-scored every cycle (the paper's Alg. 1 and the
    /// golden-pinned behavior). `Some(k)` serves cached scores for up to
    /// `k` steps of age and re-scores only older rows, trading score
    /// freshness for presample throughput; `Some(0)` is bitwise equivalent
    /// to `None`. Refresh schedules depend only on (step, seed).
    pub score_refresh_budget: Option<u64>,
    /// Batch-compute worker threads for the training-side entries
    /// (`train_step`, `grad`, `weighted_grad`, `grad_norms`,
    /// `eval_metrics`) of backends that shard batches (native; PJRT runs
    /// whole-batch artifacts and ignores it). Like `score_workers` — and
    /// unlike `prefetch_threads` — any value is bit-identical to serial:
    /// the chunk plan and merge order are fixed by the batch size alone
    /// (`runtime::native::train_chunk_plan`). Applied to the backend at
    /// [`Trainer::new`].
    pub train_workers: usize,
    /// Presample scoring precision (`--score-precision`): `Bf16` walks
    /// bf16-stored parameters in the scoring forward (half the weight
    /// streaming; score *ranking* preserved to within the pinned overlap
    /// threshold) while training, eval and the gradient-norm oracle stay
    /// f32. `F32` (default) keeps scoring bit-identical to the training
    /// forward — the golden-pinned behavior. Applied to the backend at
    /// [`Trainer::new`]; PJRT ignores it (artifacts are baked at f32).
    pub score_precision: ScorePrecision,
    /// record a metrics row every `log_every` steps.
    pub log_every: u64,
    /// The paper's §5 future-work extension: when importance sampling is
    /// active, scale the learning rate by min(τ, cap) — the linear-scaling
    /// rule applied to the τ-equivalent batch-size increase ("increasing
    /// the learning rate proportionally to the batch increment"). 0 = off
    /// (the paper's main algorithm).
    pub adaptive_lr_cap: f64,
}

impl TrainerConfig {
    /// Paper defaults for a model; strategy = the paper's upper-bound.
    pub fn upper_bound(model: &str) -> Self {
        Self::base(model, StrategyKind::Presample { score: ScoreKind::UpperBound })
    }

    pub fn uniform(model: &str) -> Self {
        Self::base(model, StrategyKind::Uniform)
    }

    pub fn loss(model: &str) -> Self {
        Self::base(model, StrategyKind::Presample { score: ScoreKind::Loss })
    }

    pub fn grad_norm(model: &str) -> Self {
        Self::base(model, StrategyKind::Presample { score: ScoreKind::GradNorm })
    }

    pub fn loshchilov_hutter(model: &str) -> Self {
        Self::base(
            model,
            StrategyKind::LoshchilovHutter { s: 100.0, recompute_every: 1200, sort_every: 20 },
        )
    }

    pub fn schaul(model: &str) -> Self {
        Self::base(model, StrategyKind::Schaul { alpha: 1.0, beta: 0.5, refresh_every: 50 })
    }

    pub fn base(model: &str, strategy: StrategyKind) -> Self {
        Self {
            model: model.to_string(),
            strategy,
            presample: 0, // 0 = use the model's default (largest baked B if unset)
            tau_th: 1.5,
            a_tau: 0.9,
            base_lr: 0.1,
            lr_milestones: vec![(0.4, 0.2), (0.8, 0.2)],
            budget_secs: None,
            max_steps: Some(2_000),
            eval_every_secs: 0.0,
            seed: 42,
            sampler: SamplerKind::Alias,
            // Default: synchronous batch assembly. On multi-core machines
            // set prefetch_threads >= 1 to overlap data generation with the
            // device; on this single-core testbed worker threads only add
            // contention (~40 ms/step measured — EXPERIMENTS.md §Perf
            // iter 6), so 0 is the right default.
            prefetch_depth: 2,
            prefetch_threads: 0,
            score_workers: default_score_workers(),
            score_refresh_budget: None,
            train_workers: default_train_workers(),
            score_precision: ScorePrecision::F32,
            log_every: 10,
            adaptive_lr_cap: 0.0,
        }
    }

    pub fn with_budget(mut self, secs: f64) -> Self {
        self.budget_secs = Some(secs);
        self.max_steps = None;
        self
    }

    pub fn with_steps(mut self, steps: u64) -> Self {
        self.max_steps = Some(steps);
        self
    }

    pub fn with_presample(mut self, b: usize) -> Self {
        self.presample = b;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_lr(mut self, lr: f32) -> Self {
        self.base_lr = lr;
        self
    }

    pub fn with_tau_th(mut self, t: f64) -> Self {
        self.tau_th = t;
        self
    }

    pub fn with_eval_every(mut self, secs: f64) -> Self {
        self.eval_every_secs = secs;
        self
    }

    /// Enable the §5 τ-adaptive learning rate (see `adaptive_lr_cap`).
    pub fn with_adaptive_lr(mut self, cap: f64) -> Self {
        self.adaptive_lr_cap = cap;
        self
    }

    /// Set the presample scoring worker count (see `score_workers`).
    pub fn with_score_workers(mut self, workers: usize) -> Self {
        self.score_workers = workers.max(1);
        self
    }

    /// Set the score-cache staleness budget (see `score_refresh_budget`).
    pub fn with_score_refresh_budget(mut self, budget: Option<u64>) -> Self {
        self.score_refresh_budget = budget;
        self
    }

    /// Set the batch-compute worker count (see `train_workers`).
    pub fn with_train_workers(mut self, workers: usize) -> Self {
        self.train_workers = workers.max(1);
        self
    }

    /// Set the re-sampling backend (see `sampler`).
    pub fn with_sampler(mut self, sampler: SamplerKind) -> Self {
        self.sampler = sampler;
        self
    }

    /// Set the presample scoring precision (see `score_precision`).
    pub fn with_score_precision(mut self, precision: ScorePrecision) -> Self {
        self.score_precision = precision;
        self
    }

    /// The scoring entry (and batch size) this strategy needs beyond
    /// `train_step`, with `presample == 0` resolved to the model's largest
    /// advertised B — the exact resolution [`Trainer::new`] applies. One
    /// policy shared by the trainer's fail-fast check and the figure
    /// harnesses' `SKIP` gates, so the two can never drift.
    pub fn scoring_requirement(&self, info: &ModelInfo) -> Option<(&'static str, usize)> {
        let default_b = info.presample.iter().copied().max().unwrap_or(info.batch);
        match &self.strategy {
            StrategyKind::Presample { score } => {
                let b = if self.presample == 0 { default_b } else { self.presample };
                Some((score.entry(), b))
            }
            StrategyKind::LoshchilovHutter { .. } => Some(("fwd_scores", info.batch)),
            _ => None,
        }
    }
}

/// Result of one run.
#[derive(Debug, Clone)]
pub struct Report {
    pub log: MetricsLog,
    pub steps: u64,
    pub wall_secs: f64,
    pub final_train_loss: f64,
    pub final_test_loss: f64,
    pub final_test_err: f64,
    /// step at which importance sampling first switched on (None = never)
    pub is_switch_step: Option<u64>,
    pub strategy: String,
}

/// The coordinator. Owns the model state; borrows the execution backend.
pub struct Trainer<'e> {
    pub backend: &'e dyn Backend,
    pub cfg: TrainerConfig,
    pub state: ModelState,
    pub tau: TauEstimator,
    pub timers: PhaseTimers,
    rng: SplitMix64,
    presample: usize,
    batch: usize,
}

impl<'e> Trainer<'e> {
    pub fn new(backend: &'e dyn Backend, mut cfg: TrainerConfig) -> Result<Self> {
        // tune the backend's data-parallel batch compute for this run
        // (bit-identical for any count, so safe on every strategy)
        backend.set_train_workers(cfg.train_workers.max(1));
        // scoring precision only touches fwd_scores; training stays f32
        backend.set_score_precision(cfg.score_precision);
        let info = backend.model_info(&cfg.model)?;
        let batch = info.batch;
        let eval_batch = info.eval_batch;
        if cfg.presample == 0 {
            cfg.presample = info.presample.iter().copied().max().unwrap_or(batch);
        }
        if let Some((entry, b)) = cfg.scoring_requirement(info) {
            // fail fast if the backend cannot run the strategy's scoring
            // entry (PJRT: no baked artifact; native: always fine)
            if !backend.supports(&cfg.model, entry, b)? {
                bail!(
                    "{} backend cannot run {entry} at batch {b} for model {:?}",
                    backend.name(),
                    cfg.model
                );
            }
        }
        if let (StrategyKind::Presample { score }, Some(_)) =
            (&cfg.strategy, cfg.score_refresh_budget)
        {
            // a finite budget re-scores arbitrary-size stale subsets, so
            // the backend must score any batch size (native does; PJRT
            // only its baked artifact sizes)
            if !backend.supports(&cfg.model, score.entry(), 1)? {
                bail!(
                    "--score-refresh-budget needs a backend that scores arbitrary batch \
                     sizes; {} cannot run {} at batch 1 for model {:?}",
                    backend.name(),
                    score.entry(),
                    cfg.model
                );
            }
        }
        // Warm the entries this strategy will execute so the first training
        // step is not a compile stall inside the measured budget (all
        // strategies then compare on pure steady-state wall-clock).
        backend.prepare(&cfg.model, "train_step", batch)?;
        backend.prepare(&cfg.model, "eval_metrics", eval_batch)?;
        match &cfg.strategy {
            StrategyKind::Presample { score } => {
                backend.prepare(&cfg.model, score.entry(), cfg.presample)?;
            }
            StrategyKind::LoshchilovHutter { .. } => {
                backend.prepare(&cfg.model, "fwd_scores", batch)?;
            }
            _ => {}
        }
        let state = backend.init_state(&cfg.model, cfg.seed)?;
        // Warm the chunk-sized scoring entries the threaded backend will hit
        // (when B / score_workers is supported); otherwise it transparently
        // falls back to the serial full-B pass warmed above.
        if let StrategyKind::Presample { score } = &cfg.strategy {
            let sb = score_backend(backend, cfg.score_workers, *score);
            let scorer = BackendScorer { backend, state: &state };
            if let Some(chunks) = sb.plan(&scorer, cfg.presample, *score) {
                for (_, len) in chunks {
                    backend.prepare(&cfg.model, score.entry(), len)?;
                }
            }
        }
        let rng = SplitMix64::tensor_stream(cfg.seed ^ 0x7, 1);
        Ok(Self {
            backend,
            tau: TauEstimator::new(cfg.a_tau),
            state,
            rng,
            presample: cfg.presample,
            batch,
            timers: PhaseTimers::default(),
            cfg,
        })
    }

    /// Learning rate at a given progress fraction.
    fn lr_at(&self, progress: f64) -> f32 {
        let mut lr = self.cfg.base_lr;
        for &(frac, mult) in &self.cfg.lr_milestones {
            if progress >= frac {
                lr *= mult;
            }
        }
        lr
    }

    /// Evaluate on the *whole* test set (no augmentation), full shards
    /// first. The tail (`test.len() % eval_batch`) is not dropped: backends
    /// that evaluate arbitrary batch sizes (native) get an exact partial
    /// shard; fixed-artifact backends (PJRT) get a wrapped full shard — as
    /// `recompute_all_losses` pads — whose aggregate is weighted by
    /// `rem / eval_batch` so every sample contributes with ~unit weight.
    pub fn evaluate<D: Dataset + ?Sized>(&mut self, test: &D) -> Result<(f64, f64)> {
        let info = self.backend.model_info(&self.cfg.model)?;
        let eb = info.eval_batch;
        let n = test.len();
        if n == 0 {
            bail!("cannot evaluate on an empty test set");
        }
        let shards = n / eb;
        let rem = n % eb;
        let mut sum_loss = 0.0;
        let mut correct = 0.0f64;
        let mut seen = 0usize;
        for s in 0..shards {
            let indices: Vec<usize> = (s * eb..(s + 1) * eb).collect();
            let (x, y) = test.batch(&indices, 0);
            let (l, c) = self.backend.eval_metrics(&self.state, &x, &y)?;
            sum_loss += l;
            correct += c as f64;
            seen += eb;
        }
        if rem > 0 {
            let start = shards * eb;
            if self.backend.supports(&self.cfg.model, "eval_metrics", rem)? {
                let indices: Vec<usize> = (start..n).collect();
                let (x, y) = test.batch(&indices, 0);
                let (l, c) = self.backend.eval_metrics(&self.state, &x, &y)?;
                sum_loss += l;
                correct += c as f64;
            } else {
                let indices: Vec<usize> = (0..eb).map(|k| (start + k) % n).collect();
                let (x, y) = test.batch(&indices, 0);
                let (l, c) = self.backend.eval_metrics(&self.state, &x, &y)?;
                let frac = rem as f64 / eb as f64;
                sum_loss += l * frac;
                correct += c as f64 * frac;
            }
            seen += rem;
        }
        Ok((sum_loss / seen as f64, 1.0 - correct / seen as f64))
    }

    /// Run the configured strategy on `train`, optionally evaluating on
    /// `test` along the way. The paper's protocol: fixed wall-clock budget,
    /// lr schedule keyed to elapsed time.
    pub fn run<D: Dataset + Sync>(&mut self, train: &D, test: Option<&D>) -> Result<Report> {
        if train.feature_dim() != self.backend.model_info(&self.cfg.model)?.feature_dim {
            bail!(
                "dataset feature_dim {} != model feature_dim {}",
                train.feature_dim(),
                self.backend.model_info(&self.cfg.model)?.feature_dim
            );
        }
        let stop = AtomicBool::new(false);
        let stats_small = PipelineStats::default();
        let stats_large = PipelineStats::default();
        let draws = AtomicU64::new(0);
        let needs_large = matches!(self.cfg.strategy, StrategyKind::Presample { .. });
        let (depth, threads) = (self.cfg.prefetch_depth, self.cfg.prefetch_threads);
        let (batch, presample, seed) = (self.batch, self.presample, self.cfg.seed);

        if threads == 0 {
            // synchronous mode: on single-core machines the worker threads
            // cannot overlap with device compute and only add contention
            // (§Perf iter 6); assemble batches inline instead. Both sources
            // share `draws` so augmentation epochs advance exactly as in
            // prefetched mode (see the BatchSource docs).
            let mut small = BatchSource::sync(train, batch, seed, &draws);
            let mut large =
                needs_large.then(|| BatchSource::sync(train, presample, seed ^ 0xB16, &draws));
            return self.run_inner(train, test, &mut small, large.as_mut());
        }
        std::thread::scope(|s| {
            let mut small = BatchSource::prefetched(Prefetcher::spawn(
                s, train, batch, depth, threads, seed, &stop, &stats_small, &draws,
            ));
            let mut large = if needs_large {
                Some(BatchSource::prefetched(Prefetcher::spawn(
                    s,
                    train,
                    presample,
                    depth,
                    threads,
                    seed ^ 0xB16,
                    &stop,
                    &stats_large,
                    &draws,
                )))
            } else {
                None
            };
            let report = self.run_inner(train, test, &mut small, large.as_mut());
            if let BatchSource::Prefetched(p) = &small {
                p.shutdown();
            }
            if let Some(BatchSource::Prefetched(p)) = &large {
                p.shutdown();
            }
            report
        })
    }

    fn run_inner<D: Dataset + Sync>(
        &mut self,
        train: &D,
        test: Option<&D>,
        small: &mut BatchSource<D>,
        mut large_src: Option<&mut BatchSource<D>>,
    ) -> Result<Report> {
        let sw = Stopwatch::new();
        let mut log = MetricsLog::default();
        let mut last_eval = -f64::INFINITY;
        let mut step: u64 = 0;
        // the exact step importance sampling first switched on — recorded
        // here, not reconstructed from the (log_every-quantized) rows
        let mut switch_step: Option<u64> = None;
        let strategy = self.cfg.strategy.clone();

        // history-based baselines carry per-dataset state
        let mut lh: Option<LoshchilovHutter> = match &strategy {
            StrategyKind::LoshchilovHutter { s, recompute_every, sort_every } => Some(
                LoshchilovHutter::new(train.len(), *s, *recompute_every, *sort_every),
            ),
            _ => None,
        };
        let mut schaul: Option<SchaulProportional> = match &strategy {
            StrategyKind::Schaul { alpha, beta, refresh_every } => {
                Some(SchaulProportional::new(train.len(), *alpha, *beta, *refresh_every))
            }
            _ => None,
        };
        // staleness-aware score cache: with the default unlimited budget
        // every row is stale every cycle and this is a pass-through
        let mut cache: Option<ScoreCache> = match &strategy {
            StrategyKind::Presample { .. } => {
                Some(ScoreCache::new(train.len(), self.cfg.score_refresh_budget))
            }
            _ => None,
        };
        // `--sampler fenwick`: the pool-sized live distribution (ISSUE 8).
        // Fresh scores recorded into the cache also land here as O(log n)
        // partial updates, so resampling never rebuilds from scratch.
        let mut live: Option<LiveResampler> = match &strategy {
            StrategyKind::Presample { .. } if self.cfg.sampler == SamplerKind::Fenwick => {
                Some(LiveResampler::new(train.len(), self.cfg.seed))
            }
            _ => None,
        };

        loop {
            // -- termination ---------------------------------------------------
            let elapsed = sw.elapsed_secs();
            if let Some(budget) = self.cfg.budget_secs {
                if elapsed >= budget {
                    break;
                }
            }
            if let Some(max) = self.cfg.max_steps {
                if step >= max {
                    break;
                }
            }
            let progress = match (self.cfg.budget_secs, self.cfg.max_steps) {
                (Some(b), _) => elapsed / b,
                (None, Some(m)) => step as f64 / m as f64,
                _ => 0.0,
            };
            let lr = self.lr_at(progress);

            // -- one step ------------------------------------------------------
            let is_active;
            let loss;
            match &strategy {
                StrategyKind::Uniform => {
                    is_active = false;
                    let b = timed!(self.timers, "data", small.next());
                    let out = timed!(
                        self.timers,
                        "step",
                        self.backend.train_step(
                            &mut self.state,
                            &b.x,
                            &b.y,
                            &vec![1.0; b.y.len()],
                            lr,
                        )
                    )?;
                    // free scores: log τ for observability (uniform never acts on it)
                    self.tau.update(&out.scores);
                    loss = out.loss as f64;
                }
                StrategyKind::Presample { score } => {
                    let tau_on = self.tau.observations() > 0 && self.tau.tau() > self.cfg.tau_th;
                    if tau_on {
                        is_active = true;
                        let pb = timed!(
                            self.timers,
                            "data",
                            large_src.as_deref_mut().expect("presample source").next()
                        );
                        // Sharded scoring behind the staleness cache: only
                        // rows whose cached score aged past the refresh
                        // budget are re-scored (all of them when the budget
                        // is unlimited, which keeps this bit-identical to
                        // the uncached full re-score). Chunks fan out to
                        // score_workers scoped threads (or, for grad norms
                        // on a backend that shards internally, to the train
                        // worker pool) and merge in presample order, so the
                        // scores (and therefore the resampled indices)
                        // are bit-identical to the serial path.
                        let scores = timed!(self.timers, "score", {
                            let scorer =
                                BackendScorer { backend: self.backend, state: &self.state };
                            let cache = cache.as_mut().expect("presample score cache");
                            let stale = cache.stale_positions(&pb.indices, step);
                            score_backend(self.backend, self.cfg.score_workers, *score)
                                .score_subset(&scorer, &pb.x, &pb.y, *score, &stale)
                                .map(|fresh| {
                                    if let Some(live) = live.as_mut() {
                                        // only stale positions touch the
                                        // live tree: O(stale · log² n)
                                        for (&p, &v) in stale.iter().zip(&fresh) {
                                            live.stage(pb.indices[p], v);
                                        }
                                    }
                                    cache.record(&pb.indices, &stale, &fresh, step);
                                    cache.lookup(&pb.indices)
                                })
                        })?;
                        // fenwick: mixture draws over the whole pool; the
                        // gate observes the mixture's variance reduction
                        let mix_lambda =
                            live.is_some().then(|| mixture::optimal_lambda(&scores));
                        let (x, y, weights) = match (live.as_mut(), mix_lambda) {
                            (Some(live), Some(lam)) => {
                                let plan = timed!(self.timers, "resample", {
                                    live.commit(step);
                                    live.plan(self.batch, lam, &mut self.rng)
                                });
                                let (x, y) =
                                    timed!(self.timers, "data", train.batch(&plan.indices, pb.epoch));
                                (x, y, plan.weights)
                            }
                            _ => {
                                let plan = timed!(
                                    self.timers,
                                    "resample",
                                    resample_from_scores(
                                        &scores,
                                        self.batch,
                                        &mut self.rng,
                                        self.cfg.sampler,
                                    )
                                );
                                let (x, y) = gather_rows(&pb, &plan.positions);
                                (x, y, plan.weights)
                            }
                        };
                        // §5 extension: linear-scaling rule on the
                        // τ-equivalent batch increase (off when cap = 0)
                        let step_lr = if self.cfg.adaptive_lr_cap > 0.0 {
                            lr * self.tau.tau().clamp(1.0, self.cfg.adaptive_lr_cap) as f32
                        } else {
                            lr
                        };
                        let out = timed!(
                            self.timers,
                            "step",
                            self.backend.train_step(&mut self.state, &x, &y, &weights, step_lr)
                        )?;
                        match mix_lambda {
                            Some(lam) => {
                                self.tau.update_raw(mixture::tau_mixture(&scores, lam));
                            }
                            None => {
                                self.tau.update(&scores);
                            }
                        }
                        loss = out.loss as f64;
                    } else {
                        is_active = false;
                        let b = timed!(self.timers, "data", small.next());
                        let out = timed!(
                            self.timers,
                            "step",
                            self.backend.train_step(
                                &mut self.state,
                                &b.x,
                                &b.y,
                                &vec![1.0; b.y.len()],
                                lr,
                            )
                        )?;
                        // Alg. 1 line 15: scores from the warmup step are free.
                        match live.as_mut() {
                            Some(live) => {
                                // fenwick: warmup scores seed the live pool
                                // distribution, and the gate consistently
                                // observes the *mixture* variance reduction
                                for (&i, &v) in b.indices.iter().zip(&out.scores) {
                                    live.stage(i, v);
                                }
                                let lam = mixture::optimal_lambda(&out.scores);
                                self.tau.update_raw(mixture::tau_mixture(&out.scores, lam));
                            }
                            None => {
                                self.tau.update(&out.scores);
                            }
                        }
                        loss = out.loss as f64;
                    }
                }
                StrategyKind::LoshchilovHutter { .. } => {
                    is_active = true;
                    let h = lh.as_mut().unwrap();
                    if h.needs_recompute(step) {
                        let losses = self.recompute_all_losses(train)?;
                        // records *and* resorts: the fresh ranking must
                        // drive selection immediately, not sort_every later
                        h.record_all(&losses, step);
                    }
                    let idx = h.select(self.batch, step, &mut self.rng);
                    let (x, y) = timed!(self.timers, "data", train.batch(&idx, 0));
                    let out = timed!(
                        self.timers,
                        "step",
                        self.backend.train_step(&mut self.state, &x, &y, &vec![1.0; y.len()], lr)
                    )?;
                    h.observe(&idx, &out.loss_vec, step);
                    self.tau.update(&out.scores);
                    loss = out.loss as f64;
                }
                StrategyKind::Schaul { .. } => {
                    is_active = true;
                    let h = schaul.as_mut().unwrap();
                    let (idx, w) = h.select(self.batch, step, &mut self.rng);
                    let (x, y) = timed!(self.timers, "data", train.batch(&idx, 0));
                    let out = timed!(
                        self.timers,
                        "step",
                        self.backend.train_step(&mut self.state, &x, &y, &w, lr)
                    )?;
                    h.observe(&idx, &out.loss_vec, step);
                    self.tau.update(&out.scores);
                    loss = out.loss as f64;
                }
            }
            step += 1;
            if is_active && switch_step.is_none() {
                switch_step = Some(step);
            }
            // operational events (worker losses, chunk requeues, fallback
            // to in-process compute) describe scheduling, never results —
            // log them for the postmortem and move on
            for ev in self.backend.drain_events() {
                log.note(step, ev);
            }

            // -- logging / eval -------------------------------------------------
            let mut row_due = step % self.cfg.log_every.max(1) == 0 || step == 1;
            let mut test_loss = f64::NAN;
            let mut test_err = f64::NAN;
            if let Some(t) = test {
                let now = sw.elapsed_secs();
                if self.cfg.eval_every_secs > 0.0 && now - last_eval >= self.cfg.eval_every_secs
                {
                    let (l, e) = timed!(self.timers, "eval", self.evaluate(t))?;
                    test_loss = l;
                    test_err = e;
                    last_eval = now;
                    row_due = true;
                }
            }
            if row_due {
                log.push(Row {
                    step,
                    secs: sw.elapsed_secs(),
                    train_loss: loss,
                    tau: self.tau.tau(),
                    is_active,
                    lr: lr as f64,
                    test_loss,
                    test_err,
                });
            }
        }

        // run-end cache accounting (only interesting under a finite
        // staleness budget; the unlimited default re-scores everything)
        if let Some(cache) = cache.as_ref().filter(|c| c.budget().is_some()) {
            if let Some(rate) = cache.hit_rate() {
                let (scored, reused) = cache.counters();
                log.note(
                    step,
                    format!(
                        "score cache served {reused} of {} lookups ({:.1}%)",
                        scored + reused,
                        rate * 100.0
                    ),
                );
            }
        }

        // final eval
        let (final_test_loss, final_test_err) = match test {
            Some(t) => timed!(self.timers, "eval", self.evaluate(t))?,
            None => (f64::NAN, f64::NAN),
        };
        let final_train_loss = log.trailing_train_loss(10).unwrap_or(f64::NAN);
        if let Some(last) = log.rows.last_mut() {
            if last.test_err.is_nan() {
                last.test_loss = final_test_loss;
                last.test_err = final_test_err;
            }
        }
        for (name, dur, _) in self.timers.phases() {
            log.phase_seconds.push((name.clone(), dur.as_secs_f64()));
        }
        Ok(Report {
            steps: step,
            wall_secs: sw.elapsed_secs(),
            final_train_loss,
            final_test_loss,
            final_test_err,
            is_switch_step: switch_step,
            strategy: self.cfg.strategy.name(),
            log,
        })
    }

    /// Full loss refresh over the dataset (the expensive pass of the
    /// Loshchilov-Hutter baseline), in training-batch shards.
    fn recompute_all_losses<D: Dataset + ?Sized>(&mut self, train: &D) -> Result<Vec<f32>> {
        let n = train.len();
        let b = self.batch;
        let mut out = vec![0.0f32; n];
        let mut start = 0;
        while start < n {
            let indices: Vec<usize> = (0..b).map(|k| (start + k) % n).collect();
            let (x, y) = train.batch(&indices, 0);
            let (loss, _) =
                timed!(self.timers, "recompute", self.backend.fwd_scores(&self.state, &x, &y))?;
            let take = b.min(n - start);
            out[start..start + take].copy_from_slice(&loss[..take]);
            start += take;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::{PipelineStats, Prefetcher};
    use crate::data::synthetic::SyntheticImages;
    use crate::runtime::NativeEngine;

    #[test]
    fn gradnorm_scoring_avoids_double_sharding() {
        // Once grad_norms is internally data-parallel on the native
        // backend its pool is the only real parallel layer, so the outer
        // score layer goes serial instead of funneling chunks into the
        // same pool; with a serial inner layer — or for forward-pass
        // scoring (serial per chunk) — the threaded outer layer stays.
        let ne = NativeEngine::with_default_models().with_train_workers(8);
        let threaded = ScoreBackend::Threaded { workers: 8 };
        assert_eq!(score_backend(&ne, 8, ScoreKind::GradNorm), ScoreBackend::Serial);
        assert_eq!(score_backend(&ne, 8, ScoreKind::UpperBound), threaded);
        assert_eq!(score_backend(&ne, 8, ScoreKind::Loss), threaded);
        ne.set_train_workers(2); // inner pool still governs: stay serial
        assert_eq!(score_backend(&ne, 8, ScoreKind::GradNorm), ScoreBackend::Serial);
        ne.set_train_workers(1); // inner layer inline: outer threads win
        assert_eq!(score_backend(&ne, 8, ScoreKind::GradNorm), threaded);
    }

    #[test]
    fn sync_sources_share_one_draw_counter() {
        // Epoch = total draws across *all* sources / n — the same
        // accounting the prefetch pipeline uses (satellite of ISSUE 2).
        let ds = SyntheticImages::builder(16, 4).samples(64).seed(1).build();
        let draws = AtomicU64::new(0);
        let mut small = BatchSource::sync(&ds, 32, 7, &draws);
        let mut large = BatchSource::sync(&ds, 64, 7 ^ 0xB16, &draws);
        assert_eq!(small.next().epoch, 0); // draws 0..32
        assert_eq!(large.next().epoch, 0); // draws 32..96 start at 32 < 64
        assert_eq!(small.next().epoch, 1); // draws start at 96 >= 64
        assert_eq!(large.next().epoch, 2); // draws start at 128
        assert_eq!(draws.load(Ordering::Relaxed), 192);
    }

    #[test]
    fn sync_mode_matches_single_worker_prefetch_stream() {
        // Both modes must produce the same (indices, epoch) sequence for a
        // single uniform source: the sync rng stream is prefetch worker 0's
        // and both derive epochs from the shared draw counter.
        let ds = SyntheticImages::builder(16, 4).samples(128).seed(2).build();
        let sync_draws = AtomicU64::new(0);
        let mut sync = BatchSource::sync(&ds, 32, 9, &sync_draws);
        let sync_batches: Vec<(Vec<usize>, u64)> = (0..8)
            .map(|_| {
                let b = sync.next();
                (b.indices, b.epoch)
            })
            .collect();

        let stop = AtomicBool::new(false);
        let stats = PipelineStats::default();
        let draws = AtomicU64::new(0);
        std::thread::scope(|s| {
            let p = Prefetcher::spawn(s, &ds, 32, 1, 1, 9, &stop, &stats, &draws);
            let mut pre = BatchSource::<SyntheticImages>::prefetched(p);
            for (k, expect) in sync_batches.iter().enumerate() {
                let b = pre.next();
                assert_eq!(&b.indices, &expect.0, "batch {k} indices diverged");
                assert_eq!(b.epoch, expect.1, "batch {k} epoch diverged");
            }
            if let BatchSource::Prefetched(p) = &pre {
                p.shutdown();
            }
        });
    }
}

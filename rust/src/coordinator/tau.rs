//! The variance-reduction estimator τ (Eq. 23–26) — the paper's switch for
//! *when importance sampling is worth its cost*.
//!
//! Given presample scores g_i ∝ ĝ_i (normalized to a distribution), the
//! variance reduction relative to uniform is equivalent to increasing the
//! batch size by τ where
//!
//! ```text
//! 1/τ = sqrt( 1 - ||g - u||² / Σ g_i² )          (Eq. 26)
//! ```
//!
//! Algorithm 1 line 17 smooths τ with an EMA (`a_tau`) and switches
//! importance sampling on when τ > τ_th. The paper's guaranteed-speedup
//! condition is `B + 3b < 3 τ b` (§3.3), i.e. τ_th = (B + 3b) / (3b); in
//! practice smaller thresholds already pay off (§4.2 uses 1.5).

use crate::util::stats::{normalize_probs, Ema};

/// Upper clamp for a single τ observation: with B ≤ 4096 presamples the
/// theoretical max is √B ≈ 64 when all mass sits on one sample; anything
/// above is fp noise from a near-singular distribution.
const TAU_CLAMP: f64 = 1e3;

#[derive(Debug, Clone)]
pub struct TauEstimator {
    ema: Ema,
    /// latest smoothed value
    tau: f64,
    /// latest raw (unsmoothed) observation
    last_raw: f64,
    observations: u64,
}

impl TauEstimator {
    /// `a_tau` is the EMA retention factor of Algorithm 1 (paper default in
    /// the released code: 0.9).
    pub fn new(a_tau: f64) -> Self {
        assert!((0.0..1.0).contains(&a_tau), "a_tau must be in [0,1)");
        Self { ema: Ema::new(a_tau), tau: 0.0, last_raw: 0.0, observations: 0 }
    }

    /// Eq. 26 for one score vector (un-normalized scores accepted).
    pub fn tau_from_scores(scores: &[f32]) -> f64 {
        let g = normalize_probs(scores);
        let n = g.len();
        if n == 0 {
            return 1.0;
        }
        let u = 1.0 / n as f64;
        let mut dist2 = 0.0f64;
        let mut sumsq = 0.0f64;
        for &gi in &g {
            let gi = gi as f64;
            dist2 += (gi - u) * (gi - u);
            sumsq += gi * gi;
        }
        if sumsq <= 0.0 {
            return 1.0;
        }
        let inv_tau_sq = 1.0 - dist2 / sumsq; // = 1/τ² by Eq. 25–26
        if inv_tau_sq <= 0.0 {
            return TAU_CLAMP;
        }
        (1.0 / inv_tau_sq.sqrt()).clamp(1.0, TAU_CLAMP)
    }

    /// Feed one presample's scores; returns the smoothed τ.
    pub fn update(&mut self, scores: &[f32]) -> f64 {
        self.last_raw = Self::tau_from_scores(scores);
        self.tau = self.ema.update(self.last_raw);
        self.observations += 1;
        self.tau
    }

    pub fn tau(&self) -> f64 {
        self.tau
    }

    pub fn last_raw(&self) -> f64 {
        self.last_raw
    }

    pub fn observations(&self) -> u64 {
        self.observations
    }
}

/// The paper's cost model (§3.3), assuming the backward pass costs twice
/// the forward pass: scoring B forwards + b forward+backwards, against
/// uniform's B-sample-equivalent progress.
pub mod cost_model {
    /// Guaranteed speedup condition: `B + 3b < 3 τ b`.
    pub fn guaranteed_speedup(presample: usize, batch: usize, tau: f64) -> bool {
        (presample + 3 * batch) as f64 / (3.0 * batch as f64) < tau
    }

    /// The τ threshold above which speedup is guaranteed: (B + 3b) / (3b).
    pub fn tau_threshold(presample: usize, batch: usize) -> f64 {
        (presample + 3 * batch) as f64 / (3.0 * batch as f64)
    }

    /// Maximum achievable variance reduction with presample B and batch b
    /// (§3.3): 1/b² − 1/B².
    pub fn max_variance_reduction(presample: usize, batch: usize) -> f64 {
        1.0 / (batch * batch) as f64 - 1.0 / (presample * presample) as f64
    }

    /// Best-case time-per-equal-variance ratio (B + 3b)/(3B): < 1 means
    /// importance sampling can win.
    pub fn max_speedup_ratio(presample: usize, batch: usize) -> f64 {
        (presample + 3 * batch) as f64 / (3.0 * presample as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    #[test]
    fn uniform_scores_give_tau_one() {
        let t = TauEstimator::tau_from_scores(&[0.5; 64]);
        assert!((t - 1.0).abs() < 1e-9, "tau {t}");
    }

    #[test]
    fn concentrated_scores_give_large_tau() {
        let mut scores = vec![1e-6f32; 64];
        scores[7] = 1.0;
        let t = TauEstimator::tau_from_scores(&scores);
        assert!(t > 7.0, "tau {t}"); // ~sqrt(64)=8 at full concentration
    }

    #[test]
    fn tau_monotone_in_concentration() {
        // mixing a peaked distribution toward uniform must not increase tau
        let n = 128;
        let mut prev = f64::INFINITY;
        for mix in [0.0f32, 0.25, 0.5, 0.75, 1.0] {
            let scores: Vec<f32> = (0..n)
                .map(|i| {
                    let peaked = if i == 0 { 1.0 } else { 0.001 };
                    let uniform = 1.0 / n as f32;
                    (1.0 - mix) * peaked + mix * uniform
                })
                .collect();
            let t = TauEstimator::tau_from_scores(&scores);
            assert!(t <= prev + 1e-9, "tau not monotone: {t} after {prev}");
            prev = t;
        }
    }

    #[test]
    fn ema_smoothing_and_observation_count() {
        let mut est = TauEstimator::new(0.9);
        let peaked: Vec<f32> = (0..32).map(|i| if i == 0 { 1.0 } else { 0.01 }).collect();
        let first = est.update(&peaked);
        assert_eq!(est.observations(), 1);
        assert!((first - est.last_raw()).abs() < 1e-12, "first sample initializes EMA");
        let mut prev = est.tau();
        for _ in 0..50 {
            est.update(&[1.0; 32]); // uniform: raw tau = 1
            assert!(est.tau() <= prev + 1e-12);
            prev = est.tau();
        }
        assert!((est.tau() - 1.0).abs() < 0.05, "EMA should approach 1, got {}", est.tau());
    }

    #[test]
    fn paper_threshold_examples() {
        // §4.2: B=640, b=128 -> tau_th for guaranteed speedup = (640+384)/384 ≈ 2.67
        let th = cost_model::tau_threshold(640, 128);
        assert!((th - 1024.0 / 384.0).abs() < 1e-12);
        // §4.4: B=128, b=32 -> (128+96)/96 ≈ 2.33 (paper quotes 2.33)
        let th2 = cost_model::tau_threshold(128, 32);
        assert!((th2 - 2.3333).abs() < 1e-3);
        assert!(cost_model::guaranteed_speedup(640, 128, 3.0));
        assert!(!cost_model::guaranteed_speedup(640, 128, 2.0));
    }

    #[test]
    fn property_tau_bounds() {
        // 1 <= tau <= sqrt(B) for any non-negative score vector
        check("tau in [1, sqrt(B)]", 300, |g: &mut Gen| {
            let scores = g.scores(1..256);
            let t = TauEstimator::tau_from_scores(&scores);
            let bound = (scores.len() as f64).sqrt() + 1e-6;
            assert!(t >= 1.0 - 1e-12, "tau {t} < 1");
            assert!(t <= bound, "tau {t} > sqrt(B) {bound}");
        });
    }

    #[test]
    fn property_scale_invariance() {
        // tau(c * scores) == tau(scores): the estimator sees a distribution
        check("tau scale invariant", 200, |g: &mut Gen| {
            let scores = g.scores(2..128);
            let c = g.f32_in(0.001..1000.0);
            let scaled: Vec<f32> = scores.iter().map(|&s| s * c).collect();
            let a = TauEstimator::tau_from_scores(&scores);
            let b = TauEstimator::tau_from_scores(&scaled);
            assert!((a - b).abs() < 1e-6 * a.max(1.0), "{a} vs {b}");
        });
    }
}

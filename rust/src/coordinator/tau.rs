//! The variance-reduction estimator τ (Eq. 23–26) — the paper's switch for
//! *when importance sampling is worth its cost*.
//!
//! Given presample scores g_i ∝ ĝ_i (normalized to a distribution), the
//! variance reduction relative to uniform is equivalent to increasing the
//! batch size by τ where
//!
//! ```text
//! 1/τ = sqrt( 1 - ||g - u||² / Σ g_i² )          (Eq. 26)
//! ```
//!
//! Algorithm 1 line 17 smooths τ with an EMA (`a_tau`) and switches
//! importance sampling on when τ > τ_th. The paper's guaranteed-speedup
//! condition is `B + 3b < 3 τ b` (§3.3), i.e. τ_th = (B + 3b) / (3b); in
//! practice smaller thresholds already pay off (§4.2 uses 1.5).

use crate::util::stats::{normalize_probs, Ema};

/// Upper clamp for a single τ observation on B scores: all mass on one of
/// B samples gives exactly τ = √B, so anything above √B is fp noise from a
/// near-singular distribution. (Fixed in ISSUE 8: this used to be a flat
/// `1e3`, which the near-singular branch returned verbatim — violating the
/// property-pinned `τ ≤ √B` bound for any B < 10⁶.)
fn tau_cap(n: usize) -> f64 {
    (n as f64).sqrt().max(1.0)
}

#[derive(Debug, Clone)]
pub struct TauEstimator {
    ema: Ema,
    /// latest smoothed value
    tau: f64,
    /// latest raw (unsmoothed) observation
    last_raw: f64,
    observations: u64,
}

impl TauEstimator {
    /// `a_tau` is the EMA retention factor of Algorithm 1 (paper default in
    /// the released code: 0.9).
    pub fn new(a_tau: f64) -> Self {
        assert!((0.0..1.0).contains(&a_tau), "a_tau must be in [0,1)");
        Self { ema: Ema::new(a_tau), tau: 0.0, last_raw: 0.0, observations: 0 }
    }

    /// Eq. 26 for one score vector (un-normalized scores accepted).
    pub fn tau_from_scores(scores: &[f32]) -> f64 {
        Self::tau_from_distribution(&normalize_probs(scores))
    }

    /// Eq. 26 for an already-normalized distribution `g`. Public so the
    /// near-singular guard can be exercised directly (a well-normalized
    /// `g` satisfies Σg ≈ 1, which keeps `1/τ²` positive in exact
    /// arithmetic; the guard exists for fp pathology).
    pub fn tau_from_distribution(g: &[f32]) -> f64 {
        let n = g.len();
        if n == 0 {
            return 1.0;
        }
        let cap = tau_cap(n);
        let u = 1.0 / n as f64;
        let mut dist2 = 0.0f64;
        let mut sumsq = 0.0f64;
        for &gi in g {
            let gi = gi as f64;
            dist2 += (gi - u) * (gi - u);
            sumsq += gi * gi;
        }
        if sumsq <= 0.0 {
            return 1.0;
        }
        let inv_tau_sq = 1.0 - dist2 / sumsq; // = 1/τ² by Eq. 25–26
        if inv_tau_sq <= 0.0 {
            // near-singular: all mass effectively on one sample ⇒ τ = √n
            return cap;
        }
        (1.0 / inv_tau_sq.sqrt()).clamp(1.0, cap)
    }

    /// Feed one presample's scores; returns the smoothed τ.
    pub fn update(&mut self, scores: &[f32]) -> f64 {
        self.update_raw(Self::tau_from_scores(scores))
    }

    /// Feed one externally computed raw τ observation (the mixture-aware
    /// gate feeds [`mixture::tau_mixture`] here); returns the smoothed τ.
    pub fn update_raw(&mut self, raw: f64) -> f64 {
        self.last_raw = raw;
        self.tau = self.ema.update(self.last_raw);
        self.observations += 1;
        self.tau
    }

    pub fn tau(&self) -> f64 {
        self.tau
    }

    pub fn last_raw(&self) -> f64 {
        self.last_raw
    }

    pub fn observations(&self) -> u64 {
        self.observations
    }
}

/// Mixture-aware importance sampling (ISSUE 8 tentpole), after *Exploring
/// Variance Reduction in Importance Sampling for Efficient DNN Training*
/// (Kutsuna, PAPERS.md): draw from the mixture
///
/// ```text
/// p_mix(i) = λ · 1/n + (1 − λ) · p_score(i)
/// ```
///
/// instead of pure `p_score`. Mixing toward uniform (a) bounds every
/// probability away from zero, so importance weights `1/(n · p_mix)` are
/// bounded by `1/λ` and the degenerate/near-singular edge cases cannot
/// produce unbounded weights, and (b) hedges against a noisy or stale
/// score signal — Kutsuna's analysis shows an *optimal* interior λ when
/// the scores only approximate the true per-sample gradient norms.
pub mod mixture {
    use super::TauEstimator;
    use crate::util::stats::normalize_probs;

    /// Lower clamp for λ: keeps every mixture probability ≥ λ/n, hence
    /// every importance weight ≤ 1/λ = 20, no matter how concentrated or
    /// corrupt the score vector is.
    pub const LAMBDA_FLOOR: f64 = 0.05;

    /// Moment-based estimate of the optimal mixing weight λ* from one
    /// presample's scores.
    ///
    /// For the normalized scores g the squared coefficient of variation
    /// is c_v² = Var(g)/Mean(g)² = n·Σg² − 1 = τ² − 1 (Eq. 26), and the
    /// variance-minimizing shrinkage weight toward uniform for a signal
    /// with that dispersion is λ* = 1/(1 + c_v²) = 1/τ² — the moment form
    /// of Kutsuna's optimal-mixing estimate. Uninformative scores (τ→1)
    /// give λ→1 (pure uniform); a strongly concentrated signal drives λ
    /// to the [`LAMBDA_FLOOR`].
    pub fn optimal_lambda(scores: &[f32]) -> f64 {
        let tau = TauEstimator::tau_from_scores(scores);
        (1.0 / (tau * tau)).clamp(LAMBDA_FLOOR, 1.0)
    }

    /// Mixture probability of one index given its score-proportional
    /// probability `p_score` (in [0, 1]).
    #[inline]
    pub fn mix_prob(lambda: f64, n: usize, p_score: f64) -> f64 {
        lambda / n as f64 + (1.0 - lambda) * p_score
    }

    /// Variance-reduction factor of the λ-mixture against uniform:
    /// τ_mix = √(V_u / V_mix) with V_q = Σ g_i²/q_i (the second moment of
    /// the importance-weighted estimator under proposal q) and V_u =
    /// n·Σg². Reduces to Eq. 26's τ at λ = 0 and to exactly 1 at λ = 1;
    /// clamped to [1, √n]. The τ-gate feeds this (not the pure-score τ)
    /// when the mixture path is active, so the switch compares the
    /// variance reduction *actually achievable by the mixture* against
    /// uniform.
    pub fn tau_mixture(scores: &[f32], lambda: f64) -> f64 {
        let g = normalize_probs(scores);
        let n = g.len();
        if n == 0 {
            return 1.0;
        }
        let cap = (n as f64).sqrt().max(1.0);
        let mut sumsq = 0.0f64;
        let mut v_mix = 0.0f64;
        for &gi in &g {
            let gi = gi as f64;
            sumsq += gi * gi;
            let q = mix_prob(lambda, n, gi);
            if q > 0.0 {
                v_mix += gi * gi / q;
            }
        }
        if sumsq <= 0.0 || v_mix <= 0.0 {
            return 1.0;
        }
        let v_u = n as f64 * sumsq;
        (v_u / v_mix).sqrt().clamp(1.0, cap)
    }
}

/// The paper's cost model (§3.3), assuming the backward pass costs twice
/// the forward pass: scoring B forwards + b forward+backwards, against
/// uniform's B-sample-equivalent progress.
pub mod cost_model {
    /// Guaranteed speedup condition: `B + 3b < 3 τ b`.
    pub fn guaranteed_speedup(presample: usize, batch: usize, tau: f64) -> bool {
        (presample + 3 * batch) as f64 / (3.0 * batch as f64) < tau
    }

    /// The τ threshold above which speedup is guaranteed: (B + 3b) / (3b).
    pub fn tau_threshold(presample: usize, batch: usize) -> f64 {
        (presample + 3 * batch) as f64 / (3.0 * batch as f64)
    }

    /// Maximum achievable variance reduction with presample B and batch b
    /// (§3.3): 1/b² − 1/B².
    pub fn max_variance_reduction(presample: usize, batch: usize) -> f64 {
        1.0 / (batch * batch) as f64 - 1.0 / (presample * presample) as f64
    }

    /// Best-case time-per-equal-variance ratio (B + 3b)/(3B): < 1 means
    /// importance sampling can win.
    pub fn max_speedup_ratio(presample: usize, batch: usize) -> f64 {
        (presample + 3 * batch) as f64 / (3.0 * presample as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    #[test]
    fn uniform_scores_give_tau_one() {
        let t = TauEstimator::tau_from_scores(&[0.5; 64]);
        assert!((t - 1.0).abs() < 1e-9, "tau {t}");
    }

    #[test]
    fn concentrated_scores_give_large_tau() {
        let mut scores = vec![1e-6f32; 64];
        scores[7] = 1.0;
        let t = TauEstimator::tau_from_scores(&scores);
        assert!(t > 7.0, "tau {t}"); // ~sqrt(64)=8 at full concentration
    }

    #[test]
    fn tau_monotone_in_concentration() {
        // mixing a peaked distribution toward uniform must not increase tau
        let n = 128;
        let mut prev = f64::INFINITY;
        for mix in [0.0f32, 0.25, 0.5, 0.75, 1.0] {
            let scores: Vec<f32> = (0..n)
                .map(|i| {
                    let peaked = if i == 0 { 1.0 } else { 0.001 };
                    let uniform = 1.0 / n as f32;
                    (1.0 - mix) * peaked + mix * uniform
                })
                .collect();
            let t = TauEstimator::tau_from_scores(&scores);
            assert!(t <= prev + 1e-9, "tau not monotone: {t} after {prev}");
            prev = t;
        }
    }

    #[test]
    fn ema_smoothing_and_observation_count() {
        let mut est = TauEstimator::new(0.9);
        let peaked: Vec<f32> = (0..32).map(|i| if i == 0 { 1.0 } else { 0.01 }).collect();
        let first = est.update(&peaked);
        assert_eq!(est.observations(), 1);
        assert!((first - est.last_raw()).abs() < 1e-12, "first sample initializes EMA");
        let mut prev = est.tau();
        for _ in 0..50 {
            est.update(&[1.0; 32]); // uniform: raw tau = 1
            assert!(est.tau() <= prev + 1e-12);
            prev = est.tau();
        }
        assert!((est.tau() - 1.0).abs() < 0.05, "EMA should approach 1, got {}", est.tau());
    }

    #[test]
    fn paper_threshold_examples() {
        // §4.2: B=640, b=128 -> tau_th for guaranteed speedup = (640+384)/384 ≈ 2.67
        let th = cost_model::tau_threshold(640, 128);
        assert!((th - 1024.0 / 384.0).abs() < 1e-12);
        // §4.4: B=128, b=32 -> (128+96)/96 ≈ 2.33 (paper quotes 2.33)
        let th2 = cost_model::tau_threshold(128, 32);
        assert!((th2 - 2.3333).abs() < 1e-3);
        assert!(cost_model::guaranteed_speedup(640, 128, 3.0));
        assert!(!cost_model::guaranteed_speedup(640, 128, 2.0));
    }

    #[test]
    fn property_tau_bounds() {
        // 1 <= tau <= sqrt(B) for any non-negative score vector
        check("tau in [1, sqrt(B)]", 300, |g: &mut Gen| {
            let scores = g.scores(1..256);
            let t = TauEstimator::tau_from_scores(&scores);
            let bound = (scores.len() as f64).sqrt() + 1e-6;
            assert!(t >= 1.0 - 1e-12, "tau {t} < 1");
            assert!(t <= bound, "tau {t} > sqrt(B) {bound}");
        });
    }

    #[test]
    fn one_hot_scores_give_tau_sqrt_n() {
        // ISSUE 8 regression: with all mass on one of n samples, τ = √n
        // exactly. The near-singular branch used to return 1e3, blowing
        // through the τ ≤ √B bound for any B < 10⁶.
        let n = 64;
        let mut scores = vec![0.0f32; n];
        scores[13] = 5.0;
        let t = TauEstimator::tau_from_scores(&scores);
        let cap = (n as f64).sqrt();
        assert!(t <= cap + 1e-12, "tau {t} exceeds sqrt(n) {cap}");
        assert!((t - cap).abs() < 1e-6, "one-hot tau {t} should be ~sqrt(n) {cap}");
    }

    #[test]
    fn near_singular_branch_clamps_to_sqrt_n() {
        // Exercise the inv_tau_sq <= 0 guard directly: a (pathological)
        // "distribution" with Σg < 1/2 makes dist2 exceed sumsq, which is
        // what fp cancellation produces in the wild. The guard must clamp
        // to √n, not the old 1e3 constant.
        let g = [0.2f32, 0.1];
        let t = TauEstimator::tau_from_distribution(&g);
        assert!((t - 2.0f64.sqrt()).abs() < 1e-12, "near-singular tau {t} != sqrt(2)");
    }

    #[test]
    fn mixture_lambda_limits() {
        // uniform scores: τ = 1 ⇒ λ* = 1 (pure uniform sampling)
        let l = mixture::optimal_lambda(&[0.5; 64]);
        assert!((l - 1.0).abs() < 1e-9, "lambda {l}");
        // one-hot: τ = 8 ⇒ 1/τ² = 1/64 clamps to the floor
        let mut scores = vec![0.0f32; 64];
        scores[0] = 1.0;
        let l = mixture::optimal_lambda(&scores);
        assert!((l - mixture::LAMBDA_FLOOR).abs() < 1e-12, "lambda {l}");
        // mild concentration: interior λ
        let scores: Vec<f32> = (0..64).map(|i| 1.0 + (i % 4) as f32).collect();
        let l = mixture::optimal_lambda(&scores);
        assert!(l > mixture::LAMBDA_FLOOR && l < 1.0, "lambda {l} not interior");
    }

    #[test]
    fn mixture_tau_endpoints_and_monotonicity() {
        let scores: Vec<f32> = (0..128).map(|i| 0.05 + ((i * 13) % 11) as f32).collect();
        // λ=0 recovers Eq. 26's τ (same quantity, different algebra: the
        // fp gap is bounded by the f32 normalization error)
        let t0 = mixture::tau_mixture(&scores, 0.0);
        let t_eq26 = TauEstimator::tau_from_scores(&scores);
        assert!((t0 - t_eq26).abs() < 1e-3 * t_eq26, "{t0} vs {t_eq26}");
        // λ=1 is uniform: no variance reduction
        let t1 = mixture::tau_mixture(&scores, 1.0);
        assert!((t1 - 1.0).abs() < 1e-9, "tau_mixture at lambda=1: {t1}");
        // more uniform mixing can only shrink the variance-reduction factor
        let mut prev = f64::INFINITY;
        for l in [0.0, 0.1, 0.3, 0.6, 1.0] {
            let t = mixture::tau_mixture(&scores, l);
            assert!(t <= prev + 1e-9, "tau_mixture not monotone at lambda={l}");
            prev = t;
        }
    }

    #[test]
    fn mixture_weights_bounded_by_inverse_lambda() {
        // p_mix >= λ/n ⇒ w = 1/(n·p_mix) <= 1/λ, even for one-hot scores
        let mut scores = vec![0.0f32; 256];
        scores[7] = 1.0;
        let l = mixture::optimal_lambda(&scores);
        let probs = crate::util::stats::normalize_probs(&scores);
        for &p in &probs {
            let q = mixture::mix_prob(l, probs.len(), p as f64);
            let w = 1.0 / (probs.len() as f64 * q);
            assert!(w <= 1.0 / l + 1e-9, "weight {w} exceeds 1/lambda {}", 1.0 / l);
        }
    }

    #[test]
    fn property_scale_invariance() {
        // tau(c * scores) == tau(scores): the estimator sees a distribution
        check("tau scale invariant", 200, |g: &mut Gen| {
            let scores = g.scores(2..128);
            let c = g.f32_in(0.001..1000.0);
            let scaled: Vec<f32> = scores.iter().map(|&s| s * c).collect();
            let a = TauEstimator::tau_from_scores(&scores);
            let b = TauEstimator::tau_from_scores(&scaled);
            assert!((a - b).abs() < 1e-6 * a.max(1.0), "{a} vs {b}");
        });
    }
}

//! Staleness-aware per-sample score cache (ISSUE 6 tentpole).
//!
//! The presample pass re-scores every candidate from scratch each cycle,
//! so its cost grows with the pool even though *Accelerating Deep Learning
//! by Focusing on the Biggest Losers* (PAPERS.md) shows stale per-sample
//! scores stay a usable selection signal for many steps, and *Biased
//! Importance Sampling for Deep Neural Network Training* grounds sampling
//! from an approximate score distribution. [`ScoreCache`] keeps one score
//! and one step stamp per pool sample: each presample cycle only the rows
//! whose cached score is **older than the refresh budget** (or that were
//! never scored) go back through the model; everything else samples from
//! the cached distribution.
//!
//! Budget semantics (`--score-refresh-budget`):
//!
//! * `inf` / unset → [`ScoreCache::new`] with `budget = None`: an
//!   unlimited refresh budget, i.e. every row is re-scored every cycle.
//!   This is bit-identical to the pre-cache trainer (the enforced golden
//!   contract) because the partial re-score path degenerates to the full
//!   one when every position is stale.
//! * `Some(k)` → a cached score is served for up to `k` steps of age;
//!   rows older than `k` are re-scored. `Some(0)` is therefore bitwise
//!   equivalent to `None`: any score from an earlier step has age ≥ 1.
//!
//! Determinism contract (ROADMAP): [`ScoreCache::stale_positions`] is a
//! pure function of the stamp table and the step counter, and stamps only
//! ever change through [`ScoreCache::record`] — so the refresh schedule is
//! a function of (step, seed) alone, never of score values, wall-clock
//! time, or worker count.

/// Stamp value for "never scored".
const NEVER: u64 = u64::MAX;

/// Per-sample cached scores with step-stamped ages over a fixed-size pool.
#[derive(Debug, Clone)]
pub struct ScoreCache {
    budget: Option<u64>,
    scores: Vec<f32>,
    stamp: Vec<u64>,
    scored: u64,
    reused: u64,
}

impl ScoreCache {
    /// Cache for a pool of `n` samples. `budget = None` means an unlimited
    /// refresh budget (re-score everything each cycle); `Some(k)` serves
    /// cached scores for up to `k` steps of age.
    pub fn new(n: usize, budget: Option<u64>) -> Self {
        Self { budget, scores: vec![0.0; n], stamp: vec![NEVER; n], scored: 0, reused: 0 }
    }

    pub fn budget(&self) -> Option<u64> {
        self.budget
    }

    /// Positions within `indices` (NOT pool indices) whose cached score is
    /// missing or older than the budget at `step`, in position order.
    pub fn stale_positions(&self, indices: &[usize], step: u64) -> Vec<usize> {
        match self.budget {
            None => (0..indices.len()).collect(),
            Some(k) => indices
                .iter()
                .enumerate()
                .filter(|&(_, &i)| {
                    let s = self.stamp[i];
                    s == NEVER || step.saturating_sub(s) > k
                })
                .map(|(p, _)| p)
                .collect(),
        }
    }

    /// Store freshly computed scores: `fresh[j]` is the score of sample
    /// `indices[positions[j]]`, stamped at `step`. Duplicate pool indices
    /// in one presample batch are harmless — their rows are identical, so
    /// every write carries the same bits.
    pub fn record(&mut self, indices: &[usize], positions: &[usize], fresh: &[f32], step: u64) {
        assert_eq!(positions.len(), fresh.len(), "one fresh score per stale position");
        for (&p, &v) in positions.iter().zip(fresh) {
            let i = indices[p];
            self.scores[i] = v;
            self.stamp[i] = step;
        }
        self.scored += positions.len() as u64;
        self.reused += (indices.len() - positions.len()) as u64;
    }

    /// Cached score for every index of a presample batch, in batch order.
    /// Call after [`record`](Self::record) so no entry is missing.
    pub fn lookup(&self, indices: &[usize]) -> Vec<f32> {
        indices
            .iter()
            .map(|&i| {
                debug_assert_ne!(self.stamp[i], NEVER, "lookup of a never-scored sample {i}");
                self.scores[i]
            })
            .collect()
    }

    /// Lifetime counters: (rows re-scored, rows served from cache).
    pub fn counters(&self) -> (u64, u64) {
        (self.scored, self.reused)
    }

    /// Fraction of requested rows served from cache over the run's
    /// lifetime; `None` before the first presample cycle.
    pub fn hit_rate(&self) -> Option<f64> {
        let total = self.scored + self.reused;
        (total > 0).then(|| self.reused as f64 / total as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlimited_budget_marks_every_position_stale() {
        let cache = ScoreCache::new(8, None);
        assert_eq!(cache.stale_positions(&[3, 3, 7], 42), vec![0, 1, 2]);
    }

    #[test]
    fn finite_budget_refreshes_only_aged_out_samples() {
        let mut cache = ScoreCache::new(10, Some(3));
        let batch = [1usize, 4, 7];
        assert_eq!(cache.stale_positions(&batch, 10), vec![0, 1, 2], "cold cache");
        cache.record(&batch, &[0, 1, 2], &[0.5, 1.5, 2.5], 10);
        // within budget: age 3 == k is still fresh
        assert!(cache.stale_positions(&batch, 13).is_empty());
        assert_eq!(cache.lookup(&batch), vec![0.5, 1.5, 2.5]);
        // age 4 > k: everything recorded at step 10 ages out together
        assert_eq!(cache.stale_positions(&batch, 14), vec![0, 1, 2]);
        // mixed batch: sample 2 was never scored
        cache.record(&batch, &[0, 1, 2], &[0.5, 1.5, 2.5], 14);
        assert_eq!(cache.stale_positions(&[1, 2, 4], 15), vec![1]);
        assert_eq!(cache.counters(), (6, 0));
        assert_eq!(cache.hit_rate(), Some(0.0));
        // a partial refresh serves the other rows from cache
        cache.record(&[1, 2, 4], &[1], &[9.0], 15);
        assert_eq!(cache.counters(), (7, 2));
        assert_eq!(ScoreCache::new(4, Some(1)).hit_rate(), None);
    }

    #[test]
    fn zero_budget_behaves_like_unlimited() {
        let mut zero = ScoreCache::new(6, Some(0));
        let none = ScoreCache::new(6, None);
        let batch = [0usize, 2, 2, 5];
        assert_eq!(zero.stale_positions(&batch, 1), none.stale_positions(&batch, 1));
        zero.record(&batch, &[0, 1, 2, 3], &[1.0, 2.0, 2.0, 3.0], 1);
        // one step later every entry has age 1 > 0 again
        assert_eq!(zero.stale_positions(&batch, 2), none.stale_positions(&batch, 2));
    }

    #[test]
    fn duplicate_indices_resolve_to_one_consistent_score() {
        let mut cache = ScoreCache::new(4, Some(5));
        let batch = [2usize, 2, 1];
        cache.record(&batch, &[0, 1, 2], &[7.0, 7.0, 3.0], 0);
        assert_eq!(cache.lookup(&batch), vec![7.0, 7.0, 3.0]);
    }
}

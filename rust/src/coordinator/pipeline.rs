//! The streaming data pipeline: worker threads assemble uniform presample
//! batches ahead of the trainer, with a bounded channel providing
//! backpressure so workers can never run unboundedly ahead of the consumer.
//!
//! Training-step execution stays on the coordinator thread; *data
//! generation* (feature synthesis + augmentation) is parallelized here —
//! exactly the part that would otherwise steal time from the device in a
//! naive loop. Presample *scoring* is parallelized separately by
//! `runtime::score::ScoreBackend`, which reuses this module's scoped-worker
//! idiom on the now `Send + Sync` engine.
//!
//! Workers are **scoped** (`std::thread::scope`), so datasets are borrowed,
//! not `Arc`ed, and a crashed worker surfaces at join time instead of
//! silently starving the trainer.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::thread::Scope;

use crate::data::Dataset;
use crate::runtime::HostTensor;
use crate::util::rng::SplitMix64;

/// A uniformly-sampled batch, ready for device upload.
pub struct PrefetchedBatch {
    /// dataset indices, in row order
    pub indices: Vec<usize>,
    pub x: HostTensor,
    pub y: Vec<i32>,
    /// the augmentation epoch the features were generated with
    pub epoch: u64,
}

/// Shared pipeline counters (exposed for tests and perf accounting).
#[derive(Default)]
pub struct PipelineStats {
    pub produced: AtomicU64,
    pub consumed: AtomicU64,
    /// producer-side blocked sends (backpressure engagements)
    pub backpressured: AtomicU64,
}

/// A scoped prefetcher producing batches of a fixed size.
pub struct Prefetcher<'sc> {
    rx: Receiver<PrefetchedBatch>,
    stop: &'sc AtomicBool,
    stats: &'sc PipelineStats,
    pub batch_size: usize,
}

impl<'sc> Prefetcher<'sc> {
    /// Spawn `threads` workers on the scope, each producing `batch_size`
    /// uniform batches into a channel of capacity `depth`.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn<'env, D>(
        scope: &'sc Scope<'sc, 'env>,
        dataset: &'env D,
        batch_size: usize,
        depth: usize,
        threads: usize,
        seed: u64,
        stop: &'env AtomicBool,
        stats: &'env PipelineStats,
        draws: &'env AtomicU64,
    ) -> Prefetcher<'sc>
    where
        D: Dataset + Sync,
        'env: 'sc,
    {
        let (tx, rx) = std::sync::mpsc::sync_channel::<PrefetchedBatch>(depth.max(1));
        for worker in 0..threads.max(1) {
            let tx: SyncSender<PrefetchedBatch> = tx.clone();
            scope.spawn(move || {
                let mut rng =
                    SplitMix64::tensor_stream(seed ^ 0xF33D, (batch_size * 1000 + worker) as u64);
                let n = dataset.len();
                while !stop.load(Ordering::Relaxed) {
                    let first_draw = draws.fetch_add(batch_size as u64, Ordering::Relaxed);
                    let epoch = first_draw / n as u64;
                    let indices: Vec<usize> = (0..batch_size).map(|_| rng.below(n)).collect();
                    let (x, y) = dataset.batch(&indices, epoch);
                    let batch = PrefetchedBatch { indices, x, y, epoch };
                    // try_send first so we can count backpressure engagements
                    match tx.try_send(batch) {
                        Ok(()) => {}
                        Err(TrySendError::Full(b)) => {
                            stats.backpressured.fetch_add(1, Ordering::Relaxed);
                            if tx.send(b).is_err() {
                                return; // consumer gone
                            }
                        }
                        Err(TrySendError::Disconnected(_)) => return,
                    }
                    stats.produced.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
        Prefetcher { rx, stop, stats, batch_size }
    }

    /// Blocking: the next prefetched batch.
    pub fn next(&self) -> PrefetchedBatch {
        let b = self.rx.recv().expect("all prefetch workers died");
        self.stats.consumed.fetch_add(1, Ordering::Relaxed);
        b
    }

    /// Signal workers to stop (also triggered by dropping the prefetcher).
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
        // drain so producers blocked on a full channel wake up and exit
        while self.rx.try_recv().is_ok() {}
    }
}

impl Drop for Prefetcher<'_> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Gather the rows of a resample plan out of a presample batch
/// (resampled indices are positions *within* the presample, so no dataset
/// regeneration — and no augmentation drift — happens here).
pub fn gather_rows(batch: &PrefetchedBatch, positions: &[usize]) -> (HostTensor, Vec<i32>) {
    let d = batch.x.shape[1];
    let mut x = HostTensor::zeros(vec![positions.len(), d]);
    let mut y = Vec::with_capacity(positions.len());
    for (row, &p) in positions.iter().enumerate() {
        x.data[row * d..(row + 1) * d].copy_from_slice(batch.x.row(p));
        y.push(batch.y[p]);
    }
    (x, y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticImages;

    fn with_prefetcher<R>(
        threads: usize,
        depth: usize,
        f: impl FnOnce(&Prefetcher, &PipelineStats) -> R,
    ) -> R {
        let ds = SyntheticImages::builder(16, 4).samples(256).seed(1).build();
        let stop = AtomicBool::new(false);
        let stats = PipelineStats::default();
        let draws = AtomicU64::new(0);
        std::thread::scope(|s| {
            let p = Prefetcher::spawn(s, &ds, 32, depth, threads, 7, &stop, &stats, &draws);
            let r = f(&p, &stats);
            p.shutdown();
            r
        })
    }

    #[test]
    fn produces_valid_batches() {
        with_prefetcher(2, 4, |p, _| {
            for _ in 0..10 {
                let b = p.next();
                assert_eq!(b.x.shape, vec![32, 16]);
                assert_eq!(b.y.len(), 32);
                assert_eq!(b.indices.len(), 32);
                assert!(b.indices.iter().all(|&i| i < 256));
                assert!(b.y.iter().all(|&c| (0..4).contains(&c)));
            }
        });
    }

    #[test]
    fn backpressure_engages_with_slow_consumer() {
        with_prefetcher(2, 2, |p, stats| {
            std::thread::sleep(std::time::Duration::from_millis(100));
            // consume a couple to let producers cycle
            let _ = p.next();
            let _ = p.next();
            std::thread::sleep(std::time::Duration::from_millis(50));
            assert!(
                stats.backpressured.load(Ordering::Relaxed) > 0,
                "expected blocked sends with a slow consumer"
            );
            // bounded: can never have produced unboundedly more than consumed
            let produced = stats.produced.load(Ordering::Relaxed);
            let consumed = stats.consumed.load(Ordering::Relaxed);
            assert!(produced <= consumed + 2 + 2 + 1, "produced {produced} consumed {consumed}");
        });
    }

    #[test]
    fn shutdown_terminates_workers_quickly() {
        let sw = crate::util::timer::Stopwatch::new();
        with_prefetcher(4, 2, |p, _| {
            let _ = p.next();
        });
        // scope join must not hang on blocked producers
        assert!(sw.elapsed() < std::time::Duration::from_secs(5));
    }

    #[test]
    fn gather_rows_matches_presample() {
        with_prefetcher(1, 2, |p, _| {
            let b = p.next();
            let (x, y) = gather_rows(&b, &[3, 3, 0, 31]);
            assert_eq!(x.shape, vec![4, 16]);
            assert_eq!(x.row(0), b.x.row(3));
            assert_eq!(x.row(1), b.x.row(3));
            assert_eq!(x.row(2), b.x.row(0));
            assert_eq!(y[3], b.y[31]);
        });
    }

    #[test]
    fn epochs_advance_with_draws() {
        // dataset of 256, batch 32: epoch must reach >=1 within 9 batches
        with_prefetcher(1, 1, |p, _| {
            let mut max_epoch = 0;
            for _ in 0..12 {
                max_epoch = max_epoch.max(p.next().epoch);
            }
            assert!(max_epoch >= 1, "epoch never advanced: {max_epoch}");
        });
    }
}

//! Layer-3 coordinator — the paper's system contribution.
//!
//! * [`trainer`] — Algorithm 1 (warmup → τ-gated importance sampling) and
//!   every baseline strategy, under the fixed wall-clock protocol.
//! * [`sampler`] / [`resample`] — presample-B / resample-b machinery with
//!   unbiased importance weights.
//! * [`tau`] — the Eq.-26 variance-reduction estimator and cost model.
//! * [`cache`] — staleness-aware per-sample score cache behind
//!   `--score-refresh-budget`; refresh schedules depend only on
//!   (step, seed).
//! * [`history`] — loss-history stores for the published baselines.
//! * [`pipeline`] — threaded batch prefetch with bounded-channel
//!   backpressure; training steps stay on the coordinator thread while
//!   presample scoring shards across workers (`runtime::score`).
//! * [`metrics`] — wall-clock metric rows and CSV sinks.

pub mod cache;
pub mod history;
pub mod metrics;
pub mod pipeline;
pub mod resample;
pub mod sampler;
pub mod tau;
pub mod trainer;

pub use cache::ScoreCache;
pub use sampler::{ScoreKind, StrategyKind};
pub use tau::TauEstimator;
pub use trainer::{Report, Trainer, TrainerConfig};

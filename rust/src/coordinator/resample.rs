//! Weighted re-sampling with replacement (§3.3: "pre-sample a large batch
//! ... and re-sample a smaller batch with replacement").
//!
//! Three interchangeable backends (see README §Sampler for the table):
//! * [`CumulativeSampler`] — prefix sums + binary search; O(B) build,
//!   O(log B) per draw. Simple, branch-predictable baseline.
//! * [`AliasSampler`] — Vose's alias method; O(B) build, O(1) per draw.
//!   The hot-path default.
//! * [`FenwickSampler`] — binary-indexed tree over f64 weights; O(n)
//!   build, O(log n) per draw (prefix-sum descent), and O(log² n)
//!   [`FenwickSampler::update`] of a single weight. The only backend that
//!   supports partial updates, which is what keeps a pool-sized live
//!   distribution affordable between score-cache refreshes ("Biggest
//!   Losers", PAPERS.md).
//!
//! All backends consume a probability/weight vector (non-negative) and a
//! [`SplitMix64`] stream; identical draw sequences are *not* guaranteed
//! across backends (they consume different numbers of uniforms), but all
//! are exact samplers of the given distribution.
//!
//! Degenerate-input contract: an all-zero (or fully clamped-negative)
//! weight vector makes every backend fall back to the **uniform**
//! distribution. Before ISSUE 8 the cumulative backend built an all-zero
//! CDF instead, so `partition_point` ran off the end and every draw
//! returned the last index.
//!
//! Determinism contract for partial updates: [`FenwickSampler::update`]
//! recomputes each touched tree node from its children in exactly the
//! build loop's addition order, so an updated tree is **bitwise equal** to
//! a tree freshly built from the same leaves. The amortized
//! [`rebuild_policy`] may therefore choose bulk rebuild vs per-position
//! updates on cost alone — the choice can never change sampled indices —
//! and the policy itself is a pure function of (step, seed, dirty-count,
//! n), never of score values, keeping refresh schedules replayable.

use crate::util::rng::SplitMix64;

/// Which re-sampling backend the trainer uses (`--sampler`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SamplerKind {
    /// Vose alias table, rebuilt from scratch every cycle (default;
    /// golden-trajectory pinned).
    Alias,
    /// CDF + binary search, rebuilt from scratch every cycle.
    Cumulative,
    /// Pool-sized Fenwick tree with O(log n) partial updates and
    /// λ-mixture draws (see `coordinator::sampler::LiveResampler`).
    Fenwick,
}

impl SamplerKind {
    pub fn parse(name: &str) -> Option<Self> {
        match name {
            "alias" => Some(Self::Alias),
            "cumulative" | "cdf" => Some(Self::Cumulative),
            "fenwick" => Some(Self::Fenwick),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Alias => "alias",
            Self::Cumulative => "cumulative",
            Self::Fenwick => "fenwick",
        }
    }
}

/// Prefix-sum sampler.
pub struct CumulativeSampler {
    cdf: Vec<f64>,
    total: f64,
}

impl CumulativeSampler {
    pub fn new(probs: &[f32]) -> Self {
        assert!(!probs.is_empty(), "empty probability vector");
        let n = probs.len();
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for &p in probs {
            acc += p.max(0.0) as f64;
            cdf.push(acc);
        }
        if !(acc > 0.0) || !acc.is_finite() {
            // Degenerate: all-zero mass. Fall back to the uniform CDF so
            // draws cover every index (the old all-zero CDF pinned every
            // draw to the last index).
            for (i, c) in cdf.iter_mut().enumerate() {
                *c = (i + 1) as f64 / n as f64;
            }
            acc = 1.0;
        }
        Self { total: acc, cdf }
    }

    #[inline]
    pub fn draw(&self, rng: &mut SplitMix64) -> usize {
        // u in (0, total]: strictly positive so zero-probability prefixes
        // (cdf entries equal to 0) can never be selected, and == total maps
        // to the first bucket whose cdf reaches the total.
        let u = (1.0 - rng.uniform()) * self.total;
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }

    pub fn sample(&self, rng: &mut SplitMix64, k: usize) -> Vec<usize> {
        (0..k).map(|_| self.draw(rng)).collect()
    }
}

/// Vose alias sampler: O(1) per draw.
pub struct AliasSampler {
    prob: Vec<f64>,
    alias: Vec<usize>,
}

impl AliasSampler {
    pub fn new(probs: &[f32]) -> Self {
        let n = probs.len();
        assert!(n > 0, "empty probability vector");
        let total: f64 = probs.iter().map(|&p| p.max(0.0) as f64).sum();
        let scaled: Vec<f64> = if total > 0.0 {
            probs.iter().map(|&p| p.max(0.0) as f64 * n as f64 / total).collect()
        } else {
            vec![1.0; n] // degenerate: uniform
        };

        let mut prob = vec![0.0; n];
        let mut alias = vec![0usize; n];
        let mut small: Vec<usize> = Vec::with_capacity(n);
        let mut large: Vec<usize> = Vec::with_capacity(n);
        let mut rem = scaled;
        for (i, &p) in rem.iter().enumerate() {
            if p < 1.0 {
                small.push(i)
            } else {
                large.push(i)
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            prob[s] = rem[s];
            alias[s] = l;
            rem[l] = (rem[l] + rem[s]) - 1.0;
            if rem[l] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // leftovers (fp residue on either stack) saturate to probability 1
        for i in large.into_iter().chain(small) {
            prob[i] = 1.0;
            alias[i] = i;
        }
        Self { prob, alias }
    }

    #[inline]
    pub fn draw(&self, rng: &mut SplitMix64) -> usize {
        let n = self.prob.len();
        let i = rng.below(n);
        if rng.uniform() < self.prob[i] {
            i
        } else {
            self.alias[i]
        }
    }

    pub fn sample(&self, rng: &mut SplitMix64, k: usize) -> Vec<usize> {
        (0..k).map(|_| self.draw(rng)).collect()
    }
}

/// Fenwick (binary-indexed) tree sampler over f64 weights (ISSUE 8
/// tentpole).
///
/// `tree[j]` (1-indexed) stores the sum of the `lsb(j)` leaves ending at
/// leaf `j-1`; a weight change therefore touches only the O(log n) nodes
/// whose range covers it. Draws walk the implicit prefix sums from the
/// root down (O(log n)), so the structure supports a *pool-sized* live
/// distribution where only the score-cache-stale positions pay per cycle.
///
/// Bitwise update≡rebuild: [`Self::update`] recomputes every touched node
/// from scratch in the exact child order the build loop uses (O(log² n)
/// instead of the classical O(log n) delta propagation). f64 addition is
/// deterministic for a fixed operand order, so a mutated tree and a
/// freshly built tree over the same leaves are indistinguishable — down
/// to the bit pattern of every node and hence every drawn index.
pub struct FenwickSampler {
    /// 1-indexed implicit tree; `tree[0]` unused.
    tree: Vec<f64>,
    /// raw leaf weights (clamped non-negative on the way in)
    leaf: Vec<f64>,
}

#[inline]
fn lsb(j: usize) -> usize {
    j & j.wrapping_neg()
}

impl FenwickSampler {
    pub fn new(weights: &[f32]) -> Self {
        assert!(!weights.is_empty(), "empty weight vector");
        let leaf: Vec<f64> = weights.iter().map(|&w| sanitize_weight(w)).collect();
        let mut s = Self { tree: Vec::new(), leaf };
        s.rebuild();
        s
    }

    pub fn len(&self) -> usize {
        self.leaf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.leaf.is_empty()
    }

    /// Current (possibly zero) weight of leaf `i`.
    #[inline]
    pub fn weight(&self, i: usize) -> f64 {
        self.leaf[i]
    }

    /// Total mass — the full-range prefix sum, O(log n).
    pub fn total_mass(&self) -> f64 {
        let mut j = self.leaf.len();
        let mut acc = 0.0f64;
        while j > 0 {
            acc += self.tree[j];
            j -= lsb(j);
        }
        acc
    }

    /// Full O(n) rebuild of every tree node from the current leaves.
    pub fn rebuild(&mut self) {
        let n = self.leaf.len();
        self.tree = vec![0.0; n + 1];
        for j in 1..=n {
            self.tree[j] = self.leaf[j - 1];
        }
        for j in 1..=n {
            let p = j + lsb(j);
            if p <= n {
                self.tree[p] += self.tree[j];
            }
        }
    }

    /// Overwrite the given leaves, then do one full rebuild. Bitwise
    /// equivalent to calling [`Self::update`] per entry; the
    /// [`rebuild_policy`] picks whichever is cheaper.
    pub fn rebuild_with(&mut self, updates: &[(usize, f32)]) {
        for &(i, w) in updates {
            self.leaf[i] = sanitize_weight(w);
        }
        self.rebuild();
    }

    /// Set leaf `i` to `w`, repairing the O(log n) covering nodes.
    ///
    /// Each node is recomputed from its children in build order (cost
    /// O(log n) per node, O(log² n) total) rather than delta-patched,
    /// which is what buys the bitwise update≡rebuild guarantee.
    pub fn update(&mut self, i: usize, w: f32) {
        let n = self.leaf.len();
        assert!(i < n, "leaf index {i} out of bounds for {n} leaves");
        self.leaf[i] = sanitize_weight(w);
        let mut j = i + 1;
        while j <= n {
            self.recompute_node(j);
            j += lsb(j);
        }
    }

    /// tree[j] = leaf[j-1] + tree[j - r/2] + tree[j - r/4] + ... + tree[j-1]
    /// with r = lsb(j) — the children in ascending-index order, exactly
    /// mirroring the build loop's accumulation sequence.
    fn recompute_node(&mut self, j: usize) {
        let r = lsb(j);
        let mut acc = self.leaf[j - 1];
        let mut h = r >> 1;
        while h > 0 {
            acc += self.tree[j - h];
            h >>= 1;
        }
        self.tree[j] = acc;
    }

    /// Draw one index ∝ leaf weights via prefix-sum descent; falls back to
    /// uniform when the total mass is degenerate (all-zero contract shared
    /// with the other backends). Consumes exactly one `rng` value.
    pub fn draw(&self, rng: &mut SplitMix64) -> usize {
        let n = self.leaf.len();
        let total = self.total_mass();
        if !(total > 0.0) || !total.is_finite() {
            return rng.below(n);
        }
        // u in (0, total]: zero-weight leaves satisfy prefix(i) == prefix(i+1)
        // and the strict `<` below can never step past a prefix into them.
        let u = (1.0 - rng.uniform()) * total;
        let mut pos = 0usize;
        let mut rem = u;
        let mut k = 1usize;
        while (k << 1) <= n {
            k <<= 1;
        }
        while k > 0 {
            let next = pos + k;
            if next <= n && self.tree[next] < rem {
                rem -= self.tree[next];
                pos = next;
            }
            k >>= 1;
        }
        pos.min(n - 1)
    }

    pub fn sample(&self, rng: &mut SplitMix64, k: usize) -> Vec<usize> {
        (0..k).map(|_| self.draw(rng)).collect()
    }
}

/// Negative, NaN and infinite scores all collapse to weight 0 so a corrupt
/// score can never poison the tree's prefix sums.
#[inline]
fn sanitize_weight(w: f32) -> f64 {
    let v = w as f64;
    if v.is_finite() && v > 0.0 {
        v
    } else {
        0.0
    }
}

/// Amortized-rebuild policy for the live Fenwick distribution.
///
/// Because [`FenwickSampler::update`] and [`FenwickSampler::rebuild_with`]
/// are bitwise identical on the resulting tree, this is purely a cost
/// decision — but to keep refresh schedules replayable under the detlint
/// determinism contract it is a **pure function of (step, seed,
/// dirty-count, n)** and must never look at score values.
pub mod rebuild_policy {
    /// Steps between forced full rebuilds (phase-offset by seed). A
    /// periodic O(n) pass bounds any drift in *when* rebuilds happen
    /// across runs with different staleness patterns.
    pub const REBUILD_PERIOD: u64 = 1024;

    /// `true` ⇒ bulk-rebuild this cycle; `false` ⇒ apply `dirty`
    /// per-position updates. Rebuild wins once `dirty · log²(n)` work
    /// meets the O(n) rebuild cost, plus on the periodic step schedule.
    pub fn should_rebuild(step: u64, seed: u64, dirty: usize, n: usize) -> bool {
        if n == 0 || dirty == 0 {
            return false;
        }
        if dirty >= n {
            return true;
        }
        let log2 = (usize::BITS - n.leading_zeros()) as usize;
        if dirty.saturating_mul(log2 * log2) >= n {
            return true;
        }
        step % REBUILD_PERIOD == seed % REBUILD_PERIOD
    }
}

/// Importance weights for a resampled index set: w_i = 1 / (B * p_i)
/// (Eq. 2 with the unbiasedness condition w = 1/(N p); here N = B, the
/// presample size). Zero-probability entries can never be drawn by a
/// correct sampler, so the weight should never be evaluated for them —
/// but a corrupt (index, probability) pair must not poison the weighted
/// gradient reduction with inf/NaN in release builds (ISSUE 8): such a
/// weight saturates to 0 (the draw drops out of the batch mean) and logs
/// one invariant-failure line.
pub fn importance_weights(probs: &[f32], drawn: &[usize]) -> Vec<f32> {
    let b_total = probs.len() as f64;
    drawn
        .iter()
        .map(|&i| {
            let p = probs[i] as f64;
            let w = (1.0 / (b_total * p)) as f32;
            if p > 0.0 && w.is_finite() {
                w
            } else {
                eprintln!(
                    "invariant failure: importance weight for drawn index {i} \
                     (p = {p:e}) is not finite; saturating to 0"
                );
                0.0
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::normalize_probs;

    fn empirical(probs: &[f32], draws: usize, kind: SamplerKind) -> Vec<f64> {
        let mut rng = SplitMix64::new(42);
        let mut counts = vec![0usize; probs.len()];
        match kind {
            SamplerKind::Alias => {
                let s = AliasSampler::new(probs);
                for _ in 0..draws {
                    counts[s.draw(&mut rng)] += 1;
                }
            }
            SamplerKind::Cumulative => {
                let s = CumulativeSampler::new(probs);
                for _ in 0..draws {
                    counts[s.draw(&mut rng)] += 1;
                }
            }
            SamplerKind::Fenwick => {
                let s = FenwickSampler::new(probs);
                for _ in 0..draws {
                    counts[s.draw(&mut rng)] += 1;
                }
            }
        }
        counts.iter().map(|&c| c as f64 / draws as f64).collect()
    }

    const ALL_KINDS: [SamplerKind; 3] =
        [SamplerKind::Alias, SamplerKind::Cumulative, SamplerKind::Fenwick];

    #[test]
    fn sampler_kind_parse_roundtrip() {
        for kind in ALL_KINDS {
            assert_eq!(SamplerKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(SamplerKind::parse("cdf"), Some(SamplerKind::Cumulative));
        assert_eq!(SamplerKind::parse("vose"), None);
    }

    #[test]
    fn all_backends_match_target_distribution() {
        let probs = normalize_probs(&[1.0, 2.0, 3.0, 4.0, 0.0, 10.0]);
        for kind in ALL_KINDS {
            let emp = empirical(&probs, 200_000, kind);
            for (e, &p) in emp.iter().zip(&probs) {
                assert!((e - p as f64).abs() < 0.01, "backend {}: {e} vs {p}", kind.name());
            }
        }
    }

    #[test]
    fn zero_probability_never_drawn() {
        let probs = normalize_probs(&[0.0, 1.0, 0.0, 1.0]);
        let mut rng = SplitMix64::new(7);
        let s = AliasSampler::new(&probs);
        for _ in 0..10_000 {
            let i = s.draw(&mut rng);
            assert!(i == 1 || i == 3);
        }
        let c = CumulativeSampler::new(&probs);
        for _ in 0..10_000 {
            let i = c.draw(&mut rng);
            assert!(i == 1 || i == 3);
        }
        let f = FenwickSampler::new(&probs);
        for _ in 0..10_000 {
            let i = f.draw(&mut rng);
            assert!(i == 1 || i == 3);
        }
    }

    #[test]
    fn single_element() {
        let mut rng = SplitMix64::new(1);
        assert_eq!(AliasSampler::new(&[1.0]).draw(&mut rng), 0);
        assert_eq!(CumulativeSampler::new(&[1.0]).draw(&mut rng), 0);
        assert_eq!(FenwickSampler::new(&[1.0]).draw(&mut rng), 0);
    }

    #[test]
    fn degenerate_all_zero_becomes_uniform_alias() {
        let s = AliasSampler::new(&[0.0, 0.0, 0.0]);
        let mut rng = SplitMix64::new(3);
        let mut seen = [false; 3];
        for _ in 0..100 {
            seen[s.draw(&mut rng)] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn degenerate_all_zero_becomes_uniform_all_backends() {
        // ISSUE 8 satellite: the cumulative backend used to build an
        // all-zero CDF and return the *last* index on every draw. All
        // three backends now share the uniform fallback.
        for kind in ALL_KINDS {
            let emp = empirical(&[0.0, 0.0, 0.0, 0.0], 40_000, kind);
            for (i, &e) in emp.iter().enumerate() {
                assert!(
                    (e - 0.25).abs() < 0.02,
                    "backend {} index {i}: frequency {e} not ~uniform",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn fenwick_update_matches_fresh_build_bitwise() {
        let v1: Vec<f32> = (0..37).map(|i| (i % 5) as f32 + 0.25).collect();
        let mut v2 = v1.clone();
        v2[3] = 9.5;
        v2[17] = 0.0;
        v2[36] = 0.125;

        let mut mutated = FenwickSampler::new(&v1);
        for &i in &[3usize, 17, 36] {
            mutated.update(i, v2[i]);
        }
        let mut bulk = FenwickSampler::new(&v1);
        bulk.rebuild_with(&[(3, v2[3]), (17, v2[17]), (36, v2[36])]);
        let fresh = FenwickSampler::new(&v2);

        assert_eq!(mutated.total_mass().to_bits(), fresh.total_mass().to_bits());
        assert_eq!(bulk.total_mass().to_bits(), fresh.total_mass().to_bits());
        let mut r1 = SplitMix64::new(99);
        let mut r2 = SplitMix64::new(99);
        let mut r3 = SplitMix64::new(99);
        for _ in 0..5_000 {
            let a = mutated.draw(&mut r1);
            let b = fresh.draw(&mut r2);
            let c = bulk.draw(&mut r3);
            assert_eq!(a, b);
            assert_eq!(a, c);
        }
    }

    #[test]
    fn fenwick_update_to_all_zero_falls_back_to_uniform() {
        let mut s = FenwickSampler::new(&[1.0, 2.0, 3.0]);
        for i in 0..3 {
            s.update(i, 0.0);
        }
        let mut rng = SplitMix64::new(5);
        let mut seen = [false; 3];
        for _ in 0..100 {
            seen[s.draw(&mut rng)] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn fenwick_sanitizes_corrupt_weights() {
        let mut s = FenwickSampler::new(&[1.0, f32::NAN, -3.0, f32::INFINITY]);
        assert_eq!(s.weight(1), 0.0);
        assert_eq!(s.weight(2), 0.0);
        assert_eq!(s.weight(3), 0.0);
        s.update(2, f32::NEG_INFINITY);
        assert_eq!(s.weight(2), 0.0);
        assert!(s.total_mass().is_finite());
        let mut rng = SplitMix64::new(17);
        for _ in 0..1_000 {
            assert_eq!(s.draw(&mut rng), 0);
        }
    }

    #[test]
    fn rebuild_policy_pure_and_bounded() {
        use rebuild_policy::{should_rebuild, REBUILD_PERIOD};
        // nothing dirty: never rebuild, even on the periodic step
        assert!(!should_rebuild(REBUILD_PERIOD, 0, 0, 1 << 20));
        // everything dirty: always rebuild
        assert!(should_rebuild(1, 0, 1 << 20, 1 << 20));
        // periodic forced rebuild fires on the seed-offset step
        let seed = 7u64;
        assert!(should_rebuild(seed + REBUILD_PERIOD, seed, 1, 1 << 20));
        assert!(!should_rebuild(seed + REBUILD_PERIOD + 1, seed, 1, 1 << 20));
        // monotone in dirty for fixed (step, seed, n)
        let mut prev = false;
        for dirty in [0usize, 1, 100, 10_000, 1 << 20] {
            let d = should_rebuild(3, 0, dirty, 1 << 20);
            assert!(d || !prev, "rebuild decision flipped true->false at dirty={dirty}");
            prev = d;
        }
    }

    #[test]
    fn importance_weights_are_unbiased() {
        // E_p[w * f] must equal mean(f) when w = 1/(B p): check empirically.
        let f: Vec<f64> = (0..64).map(|i| (i as f64).sin() + 2.0).collect();
        let scores: Vec<f32> = (0..64).map(|i| 0.1 + (i % 7) as f32).collect();
        let probs = normalize_probs(&scores);
        let s = AliasSampler::new(&probs);
        let mut rng = SplitMix64::new(11);
        let draws: Vec<usize> = s.sample(&mut rng, 400_000);
        let w = importance_weights(&probs, &draws);
        let est: f64 = draws
            .iter()
            .zip(&w)
            .map(|(&i, &wi)| wi as f64 * f[i])
            .sum::<f64>()
            / draws.len() as f64;
        let truth: f64 = f.iter().sum::<f64>() / f.len() as f64;
        assert!((est - truth).abs() < 0.01, "estimate {est} vs {truth}");
    }

    #[test]
    fn uniform_probs_give_unit_weights() {
        let probs = vec![1.0 / 8.0; 8];
        let w = importance_weights(&probs, &[0, 3, 7]);
        for wi in w {
            assert!((wi - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn corrupt_probability_saturates_to_finite_weight() {
        // ISSUE 8 satellite: a corrupt (position, probability) pair must
        // never reach the trainer as a non-finite weight. Zero, negative
        // and f32-overflow-small probabilities all saturate to 0.
        let probs = [0.0f32, -1.0, 1e-40, 0.5];
        let w = importance_weights(&probs, &[0, 1, 2, 3]);
        assert!(w.iter().all(|wi| wi.is_finite()), "weights {w:?}");
        assert_eq!(w[0], 0.0);
        assert_eq!(w[1], 0.0);
        assert_eq!(w[2], 0.0, "1/(B·1e-40) overflows f32 and must saturate");
        assert!(w[3] > 0.0);
    }
}

//! Weighted re-sampling with replacement (§3.3: "pre-sample a large batch
//! ... and re-sample a smaller batch with replacement").
//!
//! Two interchangeable backends:
//! * [`CumulativeSampler`] — prefix sums + binary search; O(B) build,
//!   O(log B) per draw. Simple, branch-predictable baseline.
//! * [`AliasSampler`] — Vose's alias method; O(B) build, O(1) per draw.
//!   The hot-path default (see EXPERIMENTS.md §Perf for the measured
//!   crossover).
//!
//! Both consume a probability vector (non-negative, summing to ~1) and a
//! [`SplitMix64`] stream; identical draw sequences are *not* guaranteed
//! across backends (they consume different numbers of uniforms), but both
//! are exact samplers of the given distribution.

use crate::util::rng::SplitMix64;

/// Prefix-sum sampler.
pub struct CumulativeSampler {
    cdf: Vec<f64>,
    total: f64,
}

impl CumulativeSampler {
    pub fn new(probs: &[f32]) -> Self {
        assert!(!probs.is_empty(), "empty probability vector");
        let mut cdf = Vec::with_capacity(probs.len());
        let mut acc = 0.0f64;
        for &p in probs {
            acc += p.max(0.0) as f64;
            cdf.push(acc);
        }
        Self { total: acc, cdf }
    }

    #[inline]
    pub fn draw(&self, rng: &mut SplitMix64) -> usize {
        // u in (0, total]: strictly positive so zero-probability prefixes
        // (cdf entries equal to 0) can never be selected, and == total maps
        // to the first bucket whose cdf reaches the total.
        let u = (1.0 - rng.uniform()) * self.total.max(f64::MIN_POSITIVE);
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }

    pub fn sample(&self, rng: &mut SplitMix64, k: usize) -> Vec<usize> {
        (0..k).map(|_| self.draw(rng)).collect()
    }
}

/// Vose alias sampler: O(1) per draw.
pub struct AliasSampler {
    prob: Vec<f64>,
    alias: Vec<usize>,
}

impl AliasSampler {
    pub fn new(probs: &[f32]) -> Self {
        let n = probs.len();
        assert!(n > 0, "empty probability vector");
        let total: f64 = probs.iter().map(|&p| p.max(0.0) as f64).sum();
        let scaled: Vec<f64> = if total > 0.0 {
            probs.iter().map(|&p| p.max(0.0) as f64 * n as f64 / total).collect()
        } else {
            vec![1.0; n] // degenerate: uniform
        };

        let mut prob = vec![0.0; n];
        let mut alias = vec![0usize; n];
        let mut small: Vec<usize> = Vec::with_capacity(n);
        let mut large: Vec<usize> = Vec::with_capacity(n);
        let mut rem = scaled;
        for (i, &p) in rem.iter().enumerate() {
            if p < 1.0 {
                small.push(i)
            } else {
                large.push(i)
            }
        }
        while !small.is_empty() && !large.is_empty() {
            let s = small.pop().unwrap();
            let l = *large.last().unwrap();
            prob[s] = rem[s];
            alias[s] = l;
            rem[l] = (rem[l] + rem[s]) - 1.0;
            if rem[l] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // leftovers (fp residue on either stack) saturate to probability 1
        for i in large.into_iter().chain(small) {
            prob[i] = 1.0;
            alias[i] = i;
        }
        Self { prob, alias }
    }

    #[inline]
    pub fn draw(&self, rng: &mut SplitMix64) -> usize {
        let n = self.prob.len();
        let i = rng.below(n);
        if rng.uniform() < self.prob[i] {
            i
        } else {
            self.alias[i]
        }
    }

    pub fn sample(&self, rng: &mut SplitMix64, k: usize) -> Vec<usize> {
        (0..k).map(|_| self.draw(rng)).collect()
    }
}

/// Importance weights for a resampled index set: w_i = 1 / (B * p_i)
/// (Eq. 2 with the unbiasedness condition w = 1/(N p); here N = B, the
/// presample size). Zero-probability entries can never be drawn, so the
/// weight is never evaluated for them.
pub fn importance_weights(probs: &[f32], drawn: &[usize]) -> Vec<f32> {
    let b_total = probs.len() as f64;
    drawn
        .iter()
        .map(|&i| {
            let p = probs[i] as f64;
            debug_assert!(p > 0.0, "drew a zero-probability index");
            (1.0 / (b_total * p)) as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::normalize_probs;

    fn empirical(probs: &[f32], draws: usize, alias: bool) -> Vec<f64> {
        let mut rng = SplitMix64::new(42);
        let mut counts = vec![0usize; probs.len()];
        if alias {
            let s = AliasSampler::new(probs);
            for _ in 0..draws {
                counts[s.draw(&mut rng)] += 1;
            }
        } else {
            let s = CumulativeSampler::new(probs);
            for _ in 0..draws {
                counts[s.draw(&mut rng)] += 1;
            }
        }
        counts.iter().map(|&c| c as f64 / draws as f64).collect()
    }

    #[test]
    fn both_backends_match_target_distribution() {
        let probs = normalize_probs(&[1.0, 2.0, 3.0, 4.0, 0.0, 10.0]);
        for alias in [false, true] {
            let emp = empirical(&probs, 200_000, alias);
            for (e, &p) in emp.iter().zip(&probs) {
                assert!((e - p as f64).abs() < 0.01, "backend alias={alias}: {e} vs {p}");
            }
        }
    }

    #[test]
    fn zero_probability_never_drawn() {
        let probs = normalize_probs(&[0.0, 1.0, 0.0, 1.0]);
        let mut rng = SplitMix64::new(7);
        let s = AliasSampler::new(&probs);
        for _ in 0..10_000 {
            let i = s.draw(&mut rng);
            assert!(i == 1 || i == 3);
        }
        let c = CumulativeSampler::new(&probs);
        for _ in 0..10_000 {
            let i = c.draw(&mut rng);
            assert!(i == 1 || i == 3);
        }
    }

    #[test]
    fn single_element() {
        let mut rng = SplitMix64::new(1);
        assert_eq!(AliasSampler::new(&[1.0]).draw(&mut rng), 0);
        assert_eq!(CumulativeSampler::new(&[1.0]).draw(&mut rng), 0);
    }

    #[test]
    fn degenerate_all_zero_becomes_uniform_alias() {
        let s = AliasSampler::new(&[0.0, 0.0, 0.0]);
        let mut rng = SplitMix64::new(3);
        let mut seen = [false; 3];
        for _ in 0..100 {
            seen[s.draw(&mut rng)] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn importance_weights_are_unbiased() {
        // E_p[w * f] must equal mean(f) when w = 1/(B p): check empirically.
        let f: Vec<f64> = (0..64).map(|i| (i as f64).sin() + 2.0).collect();
        let scores: Vec<f32> = (0..64).map(|i| 0.1 + (i % 7) as f32).collect();
        let probs = normalize_probs(&scores);
        let s = AliasSampler::new(&probs);
        let mut rng = SplitMix64::new(11);
        let draws: Vec<usize> = s.sample(&mut rng, 400_000);
        let w = importance_weights(&probs, &draws);
        let est: f64 = draws
            .iter()
            .zip(&w)
            .map(|(&i, &wi)| wi as f64 * f[i])
            .sum::<f64>()
            / draws.len() as f64;
        let truth: f64 = f.iter().sum::<f64>() / f.len() as f64;
        assert!((est - truth).abs() < 0.01, "estimate {est} vs {truth}");
    }

    #[test]
    fn uniform_probs_give_unit_weights() {
        let probs = vec![1.0 / 8.0; 8];
        let w = importance_weights(&probs, &[0, 3, 7]);
        for wi in w {
            assert!((wi - 1.0).abs() < 1e-6);
        }
    }
}

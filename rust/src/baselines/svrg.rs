//! Stochastic variance-reduced gradient baselines (Appendix C, Fig. 6).
//!
//! The paper compares its importance sampling against SVRG (Johnson &
//! Zhang 2013), Katyusha-accelerated SVRG (Allen-Zhu 2017) and the
//! mini-batch SCSG (Lei et al. 2017), and finds them *all* slower than
//! plain SGD with momentum in the deep-learning regime because the control
//! variate requires expensive (full- or large-batch) gradient snapshots.
//! This module reproduces all three so Fig. 6 can be regenerated.
//!
//! Plain SVRG/SCSG use the fused `svrg_step` artifact; Katyusha's
//! three-point coupling is composed host-side from `grad` artifacts plus the
//! [`vecmath`] helpers (these baselines are not hot paths — their losing
//! wall-clock behaviour is the result being reproduced).

use anyhow::{bail, Result};
use xla::Literal;

use crate::coordinator::metrics::{MetricsLog, Row};
use crate::data::Dataset;
use crate::runtime::engine::clone_literals;
use crate::runtime::pool::default_train_workers;
use crate::runtime::{Backend, HostTensor};
use crate::util::rng::SplitMix64;
use crate::util::timer::Stopwatch;

/// Host-side parameter-vector arithmetic for composing optimizers that the
/// AOT artifacts don't fuse (Katyusha's coupling).
pub mod vecmath {
    use super::*;

    pub fn to_host(lits: &[Literal]) -> Result<Vec<HostTensor>> {
        lits.iter().map(HostTensor::from_literal).collect()
    }

    pub fn to_literals(ts: &[HostTensor]) -> Result<Vec<Literal>> {
        ts.iter().map(HostTensor::to_literal).collect()
    }

    /// out = a*x + b*y (elementwise over the whole parameter list).
    pub fn lincomb2(a: f32, x: &[HostTensor], b: f32, y: &[HostTensor]) -> Vec<HostTensor> {
        x.iter()
            .zip(y)
            .map(|(xt, yt)| {
                let data =
                    xt.data.iter().zip(&yt.data).map(|(&xv, &yv)| a * xv + b * yv).collect();
                HostTensor::new(xt.shape.clone(), data)
            })
            .collect()
    }

    /// out = a*x + b*y + c*z.
    pub fn lincomb3(
        a: f32,
        x: &[HostTensor],
        b: f32,
        y: &[HostTensor],
        c: f32,
        z: &[HostTensor],
    ) -> Vec<HostTensor> {
        x.iter()
            .zip(y)
            .zip(z)
            .map(|((xt, yt), zt)| {
                let data = xt
                    .data
                    .iter()
                    .zip(&yt.data)
                    .zip(&zt.data)
                    .map(|((&xv, &yv), &zv)| a * xv + b * yv + c * zv)
                    .collect();
                HostTensor::new(xt.shape.clone(), data)
            })
            .collect()
    }

    /// x -= lr * g, in place.
    pub fn axpy_neg(x: &mut [HostTensor], lr: f32, g: &[HostTensor]) {
        for (xt, gt) in x.iter_mut().zip(g) {
            for (xv, &gv) in xt.data.iter_mut().zip(&gt.data) {
                *xv -= lr * gv;
            }
        }
    }

    /// a - b + c over parameter lists (the SVRG control variate).
    pub fn control_variate(
        a: &[HostTensor],
        b: &[HostTensor],
        c: &[HostTensor],
    ) -> Vec<HostTensor> {
        lincomb3(1.0, a, -1.0, b, 1.0, c)
    }
}

#[derive(Debug, Clone)]
pub enum SvrgVariant {
    /// Full-batch snapshot gradient every `inner_steps` (Johnson & Zhang).
    Svrg,
    /// Large-batch snapshot that grows by `growth` each outer loop (SCSG,
    /// Lei et al.) — "the most suitable for Deep Learning" per the paper.
    Scsg { large_batch: usize, growth: f64 },
    /// Katyusha momentum (Allen-Zhu): negative momentum toward the snapshot.
    Katyusha { tau1: f32, tau2: f32 },
}

impl SvrgVariant {
    pub fn name(&self) -> &'static str {
        match self {
            SvrgVariant::Svrg => "svrg",
            SvrgVariant::Scsg { .. } => "scsg",
            SvrgVariant::Katyusha { .. } => "katyusha",
        }
    }
}

#[derive(Debug, Clone)]
pub struct SvrgConfig {
    pub model: String,
    pub variant: SvrgVariant,
    /// inner steps per snapshot (m in SVRG literature)
    pub inner_steps: usize,
    pub lr: f32,
    pub budget_secs: Option<f64>,
    pub max_outer: Option<usize>,
    pub seed: u64,
    pub log_every: u64,
    /// Batch-compute workers for every `grad`/`svrg_step`/`eval_metrics`
    /// call (see `TrainerConfig::train_workers`); the snapshot passes are
    /// exactly the large-batch work data parallelism pays off on.
    pub train_workers: usize,
}

impl SvrgConfig {
    pub fn svrg(model: &str) -> Self {
        Self {
            model: model.into(),
            variant: SvrgVariant::Svrg,
            inner_steps: 200,
            lr: 0.05,
            budget_secs: None,
            max_outer: Some(3),
            seed: 42,
            log_every: 10,
            train_workers: default_train_workers(),
        }
    }

    pub fn scsg(model: &str, large_batch: usize) -> Self {
        Self { variant: SvrgVariant::Scsg { large_batch, growth: 1.5 }, ..Self::svrg(model) }
    }

    pub fn katyusha(model: &str) -> Self {
        Self { variant: SvrgVariant::Katyusha { tau1: 0.4, tau2: 0.3 }, ..Self::svrg(model) }
    }

    pub fn with_budget(mut self, secs: f64) -> Self {
        self.budget_secs = Some(secs);
        self.max_outer = None;
        self
    }

    /// Set the batch-compute worker count (see `train_workers`).
    pub fn with_train_workers(mut self, workers: usize) -> Self {
        self.train_workers = workers.max(1);
        self
    }
}

pub struct SvrgReport {
    pub log: MetricsLog,
    pub steps: u64,
    pub wall_secs: f64,
    pub final_train_loss: f64,
    pub final_test_err: f64,
    pub name: &'static str,
}

/// Run an SVRG-family optimizer on `train`.
pub fn run_svrg<D: Dataset>(
    backend: &dyn Backend,
    cfg: &SvrgConfig,
    train: &D,
    test: Option<&D>,
) -> Result<SvrgReport> {
    backend.set_train_workers(cfg.train_workers.max(1));
    let info = backend.model_info(&cfg.model)?;
    let b = info.batch;
    let mut rng = SplitMix64::tensor_stream(cfg.seed ^ 0x5A46, 3);
    let mut params = backend.init_state(&cfg.model, cfg.seed)?.params;
    let sw = Stopwatch::new();
    let mut log = MetricsLog::default();
    let mut steps: u64 = 0;
    let mut outer = 0usize;
    let mut scsg_large = match &cfg.variant {
        SvrgVariant::Scsg { large_batch, .. } => *large_batch,
        _ => 0,
    };
    // Katyusha sequences
    let mut kat_z: Option<Vec<HostTensor>> = None;
    let mut kat_y: Option<Vec<HostTensor>> = None;

    let exhausted = |sw: &Stopwatch, outer: usize| -> bool {
        if let Some(bud) = cfg.budget_secs {
            if sw.elapsed_secs() >= bud {
                return true;
            }
        }
        if let Some(max) = cfg.max_outer {
            if outer >= max {
                return true;
            }
        }
        false
    };

    let mut last_loss = f64::NAN;
    while !exhausted(&sw, outer) {
        // ---- snapshot: mu = gradient over the snapshot set ----------------
        let snap = clone_literals(&params)?;
        let snapshot_samples = match &cfg.variant {
            SvrgVariant::Svrg | SvrgVariant::Katyusha { .. } => train.len(),
            SvrgVariant::Scsg { .. } => scsg_large.min(train.len()),
        };
        let mu =
            mean_grad_over(backend, &cfg.model, &params, train, snapshot_samples, b, &mut rng)?;
        let mu_host = vecmath::to_host(&mu)?;

        // ---- inner loop ----------------------------------------------------
        let inner = match &cfg.variant {
            // SCSG: E[inner] ~ large/b (geometric in the paper; fixed
            // expectation here for determinism)
            SvrgVariant::Scsg { .. } => (scsg_large / b).max(1),
            _ => cfg.inner_steps,
        };
        for _ in 0..inner {
            if let Some(bud) = cfg.budget_secs {
                if sw.elapsed_secs() >= bud {
                    break;
                }
            }
            let indices: Vec<usize> = (0..b).map(|_| rng.below(train.len())).collect();
            let (x, y) = train.batch(&indices, 0);
            match &cfg.variant {
                SvrgVariant::Svrg | SvrgVariant::Scsg { .. } => {
                    let loss =
                        backend.svrg_step(&cfg.model, &mut params, &snap, &mu, &x, &y, cfg.lr)?;
                    last_loss = loss as f64;
                }
                SvrgVariant::Katyusha { tau1, tau2 } => {
                    // Katyusha-lite coupling:
                    //   x_k  = tau1 z + tau2 x~ + (1-tau1-tau2) y
                    //   g~   = grad_b(x_k) - grad_b(x~) + mu
                    //   z'   = z - (lr/tau1) g~
                    //   y'   = x_k - lr g~
                    let x_host = vecmath::to_host(&params)?;
                    let z = kat_z.get_or_insert_with(|| x_host.clone());
                    let yv = kat_y.get_or_insert_with(|| x_host.clone());
                    let snap_host = vecmath::to_host(&snap)?;
                    let xk =
                        vecmath::lincomb3(*tau1, z, *tau2, &snap_host, 1.0 - tau1 - tau2, yv);
                    let xk_lits = vecmath::to_literals(&xk)?;
                    let (g_cur, loss) = backend.grad(&cfg.model, &xk_lits, &x, &y)?;
                    let (g_snap, _) = backend.grad(&cfg.model, &snap, &x, &y)?;
                    let g = vecmath::control_variate(
                        &vecmath::to_host(&g_cur)?,
                        &vecmath::to_host(&g_snap)?,
                        &mu_host,
                    );
                    vecmath::axpy_neg(z, cfg.lr / tau1, &g);
                    let mut ynew = xk;
                    vecmath::axpy_neg(&mut ynew, cfg.lr, &g);
                    params = vecmath::to_literals(&ynew)?;
                    *yv = ynew;
                    last_loss = loss as f64;
                }
            }
            steps += 1;
            if steps % cfg.log_every.max(1) == 0 {
                log.push(Row {
                    step: steps,
                    secs: sw.elapsed_secs(),
                    train_loss: last_loss,
                    tau: 0.0,
                    is_active: false,
                    lr: cfg.lr as f64,
                    test_loss: f64::NAN,
                    test_err: f64::NAN,
                });
            }
        }
        if let SvrgVariant::Scsg { growth, .. } = &cfg.variant {
            scsg_large = ((scsg_large as f64) * growth) as usize;
        }
        outer += 1;
    }

    // final eval
    let (test_loss, test_err) = match test {
        Some(t) => eval(backend, &cfg.model, &params, t)?,
        None => (f64::NAN, f64::NAN),
    };
    if let Some(r) = log.rows.last_mut() {
        r.test_loss = test_loss;
        r.test_err = test_err;
    }
    Ok(SvrgReport {
        steps,
        wall_secs: sw.elapsed_secs(),
        final_train_loss: log.trailing_train_loss(10).unwrap_or(last_loss),
        final_test_err: test_err,
        name: cfg.variant.name(),
        log,
    })
}

/// Mean gradient over `count` samples of the dataset, in batch-`b` shards.
fn mean_grad_over<D: Dataset>(
    backend: &dyn Backend,
    model: &str,
    params: &[Literal],
    train: &D,
    count: usize,
    b: usize,
    rng: &mut SplitMix64,
) -> Result<Vec<Literal>> {
    let shards = (count / b).max(1);
    let mut acc: Option<Vec<HostTensor>> = None;
    for _ in 0..shards {
        let indices: Vec<usize> = (0..b).map(|_| rng.below(train.len())).collect();
        let (x, y) = train.batch(&indices, 0);
        let (g, _) = backend.grad(model, params, &x, &y)?;
        let gh = vecmath::to_host(&g)?;
        acc = Some(match acc {
            None => gh,
            Some(a) => vecmath::lincomb2(1.0, &a, 1.0, &gh),
        });
    }
    let scale = 1.0 / shards as f32;
    let mean: Vec<HostTensor> = acc
        .unwrap()
        .into_iter()
        .map(|t| {
            let data = t.data.iter().map(|&v| v * scale).collect();
            HostTensor::new(t.shape, data)
        })
        .collect();
    vecmath::to_literals(&mean)
}

/// Whole-test-set evaluation with the same tail handling as
/// `Trainer::evaluate`: exact partial shard when the backend supports it,
/// wrapped shard weighted by `rem / eval_batch` otherwise — so the SVRG
/// rows of fig6 are computed over the same test set as the SGD rows.
fn eval<D: Dataset>(
    backend: &dyn Backend,
    model: &str,
    params: &[Literal],
    test: &D,
) -> Result<(f64, f64)> {
    let info = backend.model_info(model)?;
    let eb = info.eval_batch;
    let n = test.len();
    if n == 0 {
        bail!("cannot evaluate on an empty test set");
    }
    let state = crate::runtime::ModelState {
        model: model.to_string(),
        params: clone_literals(params)?,
        mom: vec![],
        step: 0,
    };
    let shards = n / eb;
    let rem = n % eb;
    let mut sum_loss = 0.0;
    let mut correct = 0.0f64;
    for s in 0..shards {
        let indices: Vec<usize> = (s * eb..(s + 1) * eb).collect();
        let (x, y) = test.batch(&indices, 0);
        let (l, c) = backend.eval_metrics(&state, &x, &y)?;
        sum_loss += l;
        correct += c as f64;
    }
    if rem > 0 {
        let start = shards * eb;
        if backend.supports(model, "eval_metrics", rem)? {
            let indices: Vec<usize> = (start..n).collect();
            let (x, y) = test.batch(&indices, 0);
            let (l, c) = backend.eval_metrics(&state, &x, &y)?;
            sum_loss += l;
            correct += c as f64;
        } else {
            let indices: Vec<usize> = (0..eb).map(|k| (start + k) % n).collect();
            let (x, y) = test.batch(&indices, 0);
            let (l, c) = backend.eval_metrics(&state, &x, &y)?;
            let frac = rem as f64 / eb as f64;
            sum_loss += l * frac;
            correct += c as f64 * frac;
        }
    }
    Ok((sum_loss / n as f64, 1.0 - correct / n as f64))
}

#[cfg(test)]
mod tests {
    use super::vecmath::*;
    use super::SvrgVariant;
    use crate::runtime::HostTensor;

    fn t(v: &[f32]) -> HostTensor {
        HostTensor::new(vec![v.len()], v.to_vec())
    }

    #[test]
    fn lincomb_and_axpy() {
        let x = vec![t(&[1.0, 2.0])];
        let y = vec![t(&[10.0, 20.0])];
        let z = vec![t(&[100.0, 200.0])];
        let l2 = lincomb2(2.0, &x, 0.5, &y);
        assert_eq!(l2[0].data, vec![7.0, 14.0]);
        let l3 = lincomb3(1.0, &x, -1.0, &y, 1.0, &z);
        assert_eq!(l3[0].data, vec![91.0, 182.0]);
        let mut m = vec![t(&[1.0, 1.0])];
        axpy_neg(&mut m, 0.5, &[t(&[2.0, 4.0])]);
        assert_eq!(m[0].data, vec![0.0, -1.0]);
        let cv = control_variate(&x, &y, &z);
        assert_eq!(cv[0].data, vec![91.0, 182.0]);
    }

    #[test]
    fn literal_roundtrip() {
        let ts = vec![t(&[1.5, -2.5, 0.0])];
        let lits = to_literals(&ts).unwrap();
        let back = to_host(&lits).unwrap();
        assert_eq!(back, ts);
    }

    #[test]
    fn variant_names() {
        assert_eq!(SvrgVariant::Svrg.name(), "svrg");
        assert_eq!(SvrgVariant::Scsg { large_batch: 512, growth: 1.5 }.name(), "scsg");
        assert_eq!(SvrgVariant::Katyusha { tau1: 0.4, tau2: 0.3 }.name(), "katyusha");
    }
}

//! SVRG-family baselines (Appendix C).
pub mod svrg;

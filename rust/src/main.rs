//! `isample` — CLI for the importance-sampling training system.
//!
//! ```text
//! isample train <model> [--backend native|pjrt] [--strategy upper-bound]
//!                       [--steps N | --budget SECS] [--presample B]
//!                       [--tau-th X] [--lr F] [--seed S]
//!                       [--out results/run.csv] [--checkpoint path.ckpt]
//! isample figure <fig1..fig7|all> [--backend native|pjrt] [--budget SECS]
//!                                 [--seeds 1,2,3] [--quick] [--model NAME]
//! isample selfcheck                      # manifest numerics vs live execution
//! isample info [--backend native|pjrt]   # list models + artifacts
//! isample worker --connect HOST:PORT     # internal: distributed chunk worker
//! ```

use anyhow::{bail, Context, Result};
use isample::config::Args;
use isample::coordinator::trainer::{Trainer, TrainerConfig};
use isample::coordinator::StrategyKind;
use isample::dist::{DistEngine, FaultPlan, WorkerConfig};
use isample::figures::runner::{dataset_for, run_figure, FigOptions};
use isample::runtime::{backend, checkpoint, Backend, Engine, NativeEngine};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let artifacts = args.flag("artifacts").unwrap_or("artifacts").to_string();
    match args.command.as_str() {
        "train" => cmd_train(&args, &artifacts),
        "figure" => cmd_figure(&args, &artifacts),
        "selfcheck" => cmd_selfcheck(&artifacts),
        "info" => cmd_info(&args, &artifacts),
        "worker" => cmd_worker(&args),
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?}; try `isample help`"),
    }
}

const HELP: &str = r#"isample — Deep Learning with Importance Sampling (ICML 2018) reproduction

USAGE:
  isample train <model> [--strategy S] [--steps N | --budget SECS] [flags]
  isample figure <fig1..fig7|all> [--budget SECS] [--seeds 1,2,3] [--quick] [--model M]
  isample selfcheck
  isample info

BACKENDS  --backend pjrt (default; executes AOT artifacts from --artifacts DIR)
          --backend native (pure-rust layer-IR engine; no artifacts needed)
MODELS    pjrt: mlp10 cnn10 cnn100 finetune lstm
          native: mlp10 mlp100 conv10 seq64 (MLP / conv / sequence stacks)
STRATEGY  uniform loss upper-bound gradient-norm loshchilov-hutter schaul
FLAGS     --presample B  --tau-th X  --a-tau X  --lr F  --seed S
          --sampler alias|cumulative|fenwick (resampling backend; fenwick =
                             O(log n) partial updates + λ-mixture draws)
          --score-workers N (presample scoring threads; default = cores)
          --train-workers N (batch-compute threads, native backend;
                             default = cores; bit-identical for any N)
          --score-refresh-budget K|inf (serve cached presample scores for up
                             to K steps of age; inf = re-score every cycle)
          --score-precision f32|bf16 (presample scoring precision; bf16 =
                             cheaper scoring, ranking-fidelity contract)
          --dist-workers N (spawn N worker processes of this binary and farm
                             chunk work over localhost TCP; native backend;
                             bit-identical to serial for any N, including
                             mid-run worker loss)
          --dist-timeout-ms MS (per-chunk lease before a silent worker is
                             dropped and its chunk requeued; default 2000)
          --eval-every SECS  --out PATH  --checkpoint PATH  --artifacts DIR

WORKER    isample worker --connect HOST:PORT [--worker-id N] [--fault-plan SPEC]
          (internal: spawned by --dist-workers; SPEC also read from
           ISAMPLE_FAULT_PLAN, e.g. kill@3:1:0,stall@5:0:2:250,drop@7:2:1)
"#;

fn cmd_train(args: &Args, artifacts: &str) -> Result<()> {
    let model = args.positional.first().context("usage: isample train <model>")?.clone();
    let strategy_name = args.flag("strategy").unwrap_or("upper-bound");
    let strategy = StrategyKind::parse(strategy_name)
        .with_context(|| format!("unknown strategy {strategy_name:?}"))?;
    let dist_workers = args.flag_dist_workers()?;
    let backend: Box<dyn Backend> = if dist_workers > 0 {
        if args.flag("backend").is_some_and(|b| b != "native") {
            bail!("--dist-workers shards the native engine; use --backend native or drop the flag");
        }
        let engine =
            DistEngine::new(NativeEngine::with_default_models(), args.flag_dist_timeout_ms()?)?;
        let exe = std::env::current_exe().context("locating the isample binary to spawn workers")?;
        engine.spawn_process_workers(dist_workers, &exe, &FaultPlan::from_env()?)?;
        engine.wait_for_workers(dist_workers)?;
        println!(
            "distributed: {dist_workers} worker process(es) connected to {}",
            engine.coordinator().addr()
        );
        Box::new(engine)
    } else {
        backend::load(args.flag_backend()?, artifacts)?
    };
    let mut cfg = TrainerConfig::base(&model, strategy);
    cfg.presample = args.flag_usize("presample", 0)?;
    cfg.tau_th = args.flag_f64("tau-th", cfg.tau_th)?;
    cfg.a_tau = args.flag_f64("a-tau", cfg.a_tau)?;
    cfg.base_lr = args.flag_f64("lr", cfg.base_lr as f64)? as f32;
    cfg.seed = args.flag_u64("seed", cfg.seed)?;
    cfg.sampler = args.flag_sampler()?;
    cfg.score_workers = args.flag_score_workers()?;
    cfg.score_refresh_budget = args.flag_score_refresh_budget()?;
    cfg.train_workers = args.flag_train_workers()?;
    cfg.score_precision = args.flag_score_precision()?;
    cfg.eval_every_secs = args.flag_f64("eval-every", 10.0)?;
    if let Some(b) = args.flag("budget") {
        cfg = cfg.with_budget(b.parse().context("--budget")?);
    } else {
        cfg = cfg.with_steps(args.flag_u64("steps", 1000)?);
    }

    let quick = args.flag_bool("quick");
    let split = dataset_for(backend.as_ref(), &model, cfg.seed, quick)?;
    println!(
        "training {model} on {} with {} (B={}, tau_th={})",
        backend.name(),
        cfg.strategy.name(),
        cfg.presample,
        cfg.tau_th
    );
    let mut trainer = Trainer::new(backend.as_ref(), cfg)?;
    let report = trainer.run(&split.train, Some(&split.test))?;
    println!(
        "done: {} steps in {:.1}s | train loss {:.4} | test err {:.4} | IS on at {:?}",
        report.steps,
        report.wall_secs,
        report.final_train_loss,
        report.final_test_err,
        report.is_switch_step
    );
    println!("{}", trainer.timers.report());
    for (step, msg) in &report.log.events {
        println!("event @{step}: {msg}");
    }
    if let Some(out) = args.flag("out") {
        report.log.to_csv(out)?;
        println!("metrics -> {out}");
    }
    if let Some(ckpt) = args.flag("checkpoint") {
        checkpoint::save(&trainer.state, ckpt)?;
        println!("checkpoint -> {ckpt}");
    }
    Ok(())
}

fn cmd_figure(args: &Args, artifacts: &str) -> Result<()> {
    let fig = args.positional.first().context("usage: isample figure <fig1..fig7|all>")?;
    let backend = backend::load(args.flag_backend()?, artifacts)?;
    let opts = FigOptions {
        budget_secs: args.flag_f64("budget", 60.0)?,
        out_dir: args.flag("out").unwrap_or("results").into(),
        seeds: args.flag_u64_list("seeds", &[42])?,
        quick: args.flag_bool("quick"),
        model: args.flag("model").map(|s| s.to_string()),
        score_workers: args.flag_score_workers()?,
        train_workers: args.flag_train_workers()?,
        score_refresh_budget: args.flag_score_refresh_budget()?,
        sampler: args.flag_sampler()?,
        score_precision: args.flag_score_precision()?,
    };
    run_figure(backend.as_ref(), fig, &opts)
}

/// Execute the manifest selfcheck: init params by the manifest RNG recipe,
/// run fwd_scores + one train_step, compare against the numbers Python
/// computed at AOT time.
fn cmd_selfcheck(artifacts: &str) -> Result<()> {
    let engine = Engine::load(artifacts)?;
    let models: Vec<String> = engine.manifest.models.keys().cloned().collect();
    let mut failed = 0;
    for model in &models {
        match isample::runtime::selfcheck::run(&engine, model) {
            Ok(rep) => println!("{model}: OK ({rep})"),
            Err(e) => {
                failed += 1;
                println!("{model}: FAILED — {e:#}");
            }
        }
    }
    if failed > 0 {
        bail!("{failed} selfchecks failed");
    }
    Ok(())
}

/// Internal entry point for the processes `--dist-workers` spawns: connect
/// to the coordinator and serve chunk work until told to shut down. Faults
/// come from `--fault-plan` or, failing that, the `ISAMPLE_FAULT_PLAN`
/// environment variable (CI's deterministic injection channel).
fn cmd_worker(args: &Args) -> Result<()> {
    let addr = args
        .flag("connect")
        .context("usage: isample worker --connect HOST:PORT [--worker-id N] [--fault-plan SPEC]")?;
    let fault_plan = match args.flag("fault-plan") {
        Some(spec) => FaultPlan::parse(spec)?,
        None => FaultPlan::from_env()?,
    };
    let cfg = WorkerConfig {
        worker_id: args.flag_u64("worker-id", 0)? as u32,
        fault_plan,
        exit_on_kill: true,
        ..WorkerConfig::default()
    };
    let engine = NativeEngine::with_default_models();
    isample::dist::run_worker(&engine, addr, &cfg)
}

fn cmd_info(args: &Args, artifacts: &str) -> Result<()> {
    if args.flag_backend()? == "native" {
        let native = NativeEngine::with_default_models();
        println!("platform: native (pure-rust CPU; any batch size, no artifacts)");
        for name in native.model_names() {
            let info = isample::runtime::Backend::model_info(&native, &name)?;
            println!(
                "{name}: D={} C={} b={} eval_b={} B={:?} params={} ({} tensors)",
                info.feature_dim,
                info.num_classes,
                info.batch,
                info.eval_batch,
                info.presample,
                info.total_param_elements(),
                info.num_params(),
            );
        }
        return Ok(());
    }
    let engine = Engine::load(artifacts)?;
    println!("platform: {}", engine.platform());
    for (name, info) in &engine.manifest.models {
        println!(
            "{name}: D={} C={} b={} eval_b={} B={:?} params={} ({} tensors)",
            info.feature_dim,
            info.num_classes,
            info.batch,
            info.eval_batch,
            info.presample,
            info.total_param_elements(),
            info.num_params(),
        );
        for e in &info.entries {
            println!("    {}@{} <- {}", e.entry, e.batch, e.file);
        }
    }
    Ok(())
}

//! Fig.-2 analysis: how well do the cheap statistics (loss, Eq.-20 upper
//! bound) predict the ideal sampling probabilities (∝ true gradient norm)?
//!
//! The paper plots p(loss) and p(upper-bound) against p(gradient-norm) for
//! 16 384 samples from a trained network and reports the sum of squared
//! errors: 0.017 for the loss vs 0.002 for the upper bound — an order of
//! magnitude. This module reproduces the scatter points and both SSE
//! numbers (plus rank correlations, a scale-free summary).

use anyhow::Result;

use crate::data::Dataset;
use crate::runtime::{Backend, ModelState};
use crate::util::rng::SplitMix64;
use crate::util::stats::{normalize_probs, pearson, spearman, sse};

/// Scatter points + summary statistics for one model state.
#[derive(Debug, Clone)]
pub struct CorrelationReport {
    /// (p_gradnorm, p_loss, p_upperbound) per sample — the Fig-2 scatter.
    pub points: Vec<(f32, f32, f32)>,
    pub sse_loss: f64,
    pub sse_upper_bound: f64,
    pub spearman_loss: f64,
    pub spearman_upper_bound: f64,
    pub pearson_loss: f64,
    pub pearson_upper_bound: f64,
}

/// Compute the Fig-2 correlation over `total` samples (processed in chunks
/// whose sizes match baked artifacts). Probabilities are normalized within
/// each chunk of `chunk` samples, mirroring the paper's per-batch
/// normalization, then pooled.
pub fn correlation_at_state<D: Dataset>(
    backend: &dyn Backend,
    state: &ModelState,
    data: &D,
    total: usize,
    chunk: usize,
    seed: u64,
) -> Result<CorrelationReport> {
    let mut rng = SplitMix64::tensor_stream(seed ^ 0xC0_77E1, 5);
    let chunks = (total / chunk).max(1);
    let mut points = Vec::with_capacity(chunks * chunk);

    for _ in 0..chunks {
        let indices: Vec<usize> = (0..chunk).map(|_| rng.below(data.len())).collect();
        let (x, y) = data.batch(&indices, 0);
        let (loss, ub) = backend.fwd_scores(state, &x, &y)?;
        let gn = backend.grad_norms(state, &x, &y)?;
        let p_loss = normalize_probs(&loss);
        let p_ub = normalize_probs(&ub);
        let p_gn = normalize_probs(&gn);
        for i in 0..chunk {
            points.push((p_gn[i], p_loss[i], p_ub[i]));
        }
    }

    let gn: Vec<f32> = points.iter().map(|p| p.0).collect();
    let lo: Vec<f32> = points.iter().map(|p| p.1).collect();
    let ub: Vec<f32> = points.iter().map(|p| p.2).collect();
    Ok(CorrelationReport {
        sse_loss: sse(&lo, &gn),
        sse_upper_bound: sse(&ub, &gn),
        spearman_loss: spearman(&lo, &gn),
        spearman_upper_bound: spearman(&ub, &gn),
        pearson_loss: pearson(&lo, &gn),
        pearson_upper_bound: pearson(&ub, &gn),
        points,
    })
}

#[cfg(test)]
mod tests {
    use crate::util::stats::{normalize_probs, sse};

    #[test]
    fn sse_of_identical_distributions_is_zero() {
        let p = normalize_probs(&[1.0, 2.0, 3.0]);
        assert_eq!(sse(&p, &p), 0.0);
    }
}

//! Fig.-1 analysis: measured variance reduction of each sampling scheme.
//!
//! Protocol (§4.1): at checkpoints along a training run, take a large batch
//! of B = 1024 samples, compute the batch gradient G_B, then resample b =
//! 128 samples with each scheme and measure `||G_b - G_B||₂` (averaged over
//! `repeats` resamplings), normalized by the distance uniform sampling
//! achieves. Lower = more variance reduction; the paper's result is
//! upper-bound ≈ gradient-norm ≪ loss, with loss *hurting* early.
//!
//! The gradient distance is computed exactly, via the `grad` artifact on
//! the resampled batch (weighted estimator) against the large-batch mean
//! gradient — not an approximation.

use anyhow::Result;

use crate::baselines::svrg::vecmath;
use crate::coordinator::sampler::resample_from_scores;
use crate::data::Dataset;
use crate::runtime::{Backend, HostTensor, ModelState};
use crate::util::rng::SplitMix64;

/// One checkpoint's measurement for every scheme, normalized by uniform.
#[derive(Debug, Clone)]
pub struct VariancePoint {
    pub step: u64,
    /// ||G_b − G_B|| for each scheme, ÷ the uniform value
    pub uniform: f64,
    pub loss: f64,
    pub upper_bound: f64,
    pub grad_norm: f64,
    /// the τ estimate at this checkpoint (from upper-bound scores)
    pub tau: f64,
}

/// Configuration of the Fig-1 measurement.
#[derive(Debug, Clone)]
pub struct VarianceConfig {
    pub presample: usize,
    pub batch: usize,
    pub repeats: usize,
    pub seed: u64,
}

impl Default for VarianceConfig {
    fn default() -> Self {
        Self { presample: 1024, batch: 128, repeats: 10, seed: 7 }
    }
}

/// Measure variance reduction for all schemes at the current model state.
pub fn measure_at_state<D: Dataset>(
    backend: &dyn Backend,
    state: &ModelState,
    data: &D,
    cfg: &VarianceConfig,
    step: u64,
) -> Result<VariancePoint> {
    let mut rng = SplitMix64::tensor_stream(cfg.seed ^ step, 11);
    let b_large = cfg.presample;
    let indices: Vec<usize> = (0..b_large).map(|_| rng.below(data.len())).collect();
    let (x, y) = data.batch(&indices, 0);

    // large-batch mean gradient G_B (via the per-sample-weighted grad:
    // the `grad` entry averages uniformly, which is exactly G_B)
    let (gb, _) = grad_of_subset(backend, state, &x, &y, &(0..b_large).collect::<Vec<_>>(), None)?;

    // scores for each scheme
    let (loss_scores, ub_scores) = backend.fwd_scores(state, &x, &y)?;
    let gn_scores = backend.grad_norms(state, &x, &y)?;
    let tau = crate::coordinator::tau::TauEstimator::tau_from_scores(&ub_scores);

    let mut dist = |scores: Option<&[f32]>| -> Result<f64> {
        let mut total = 0.0;
        for _ in 0..cfg.repeats {
            let (positions, weights) = match scores {
                None => {
                    let pos: Vec<usize> = (0..cfg.batch).map(|_| rng.below(b_large)).collect();
                    let w = vec![1.0f32; cfg.batch];
                    (pos, w)
                }
                Some(s) => {
                    let plan = resample_from_scores(s, cfg.batch, &mut rng, true);
                    (plan.positions, plan.weights)
                }
            };
            let (g, _) = grad_of_subset(backend, state, &x, &y, &positions, Some(&weights))?;
            total += l2_dist_params(&g, &gb);
        }
        Ok(total / cfg.repeats as f64)
    };

    let d_uniform = dist(None)?;
    let d_loss = dist(Some(&loss_scores))?;
    let d_ub = dist(Some(&ub_scores))?;
    let d_gn = dist(Some(&gn_scores))?;

    let norm = d_uniform.max(1e-12);
    Ok(VariancePoint {
        step,
        uniform: 1.0,
        loss: d_loss / norm,
        upper_bound: d_ub / norm,
        grad_norm: d_gn / norm,
        tau,
    })
}

/// Weighted mean gradient over selected rows of a presample batch, computed
/// with the `train_step`-equivalent weighting through the `grad` entry by
/// gathering rows. Returns host tensors (flattened per-parameter).
fn grad_of_subset(
    backend: &dyn Backend,
    state: &ModelState,
    x: &HostTensor,
    y: &[i32],
    positions: &[usize],
    weights: Option<&[f32]>,
) -> Result<(Vec<HostTensor>, f32)> {
    let info = backend.model_info(&state.model)?;
    let b = info.batch;
    let d = x.shape[1];
    // process in b-sized chunks and average the chunk gradients
    let mut acc: Option<Vec<HostTensor>> = None;
    let mut chunks = 0.0f32;
    let mut loss_total = 0.0f32;
    let mut start = 0;
    while start < positions.len() {
        let take = b.min(positions.len() - start);
        // pad the final chunk by repeating its first entries with weight 0
        let mut xs = HostTensor::zeros(vec![b, d]);
        let mut ys = vec![0i32; b];
        let mut ws = vec![0.0f32; b];
        for k in 0..b {
            let src = if k < take { positions[start + k] } else { positions[start] };
            xs.data[k * d..(k + 1) * d].copy_from_slice(x.row(src));
            ys[k] = y[src];
            ws[k] = if k < take {
                weights.map(|w| w[start + k]).unwrap_or(1.0)
            } else {
                0.0
            };
        }
        // weighted gradient = d/dθ (1/b) Σ w_i loss_i, which is what a
        // train_step applies; we recover it through `grad` on a synthetic
        // batch by scaling rows is not possible — so use weighted_grad:
        let g = backend.weighted_grad(state, &xs, &ys, &ws)?;
        loss_total += g.1;
        let gh = vecmath::to_host(&g.0)?;
        acc = Some(match acc {
            None => gh,
            Some(a) => vecmath::lincomb2(1.0, &a, 1.0, &gh),
        });
        chunks += 1.0;
        start += take;
    }
    let scale = 1.0 / chunks;
    let mean = acc
        .unwrap()
        .into_iter()
        .map(|t| {
            let data = t.data.iter().map(|&v| v * scale).collect();
            HostTensor::new(t.shape, data)
        })
        .collect();
    Ok((mean, loss_total * scale))
}

/// L2 distance between two parameter-shaped gradient lists.
fn l2_dist_params(a: &[HostTensor], b: &[HostTensor]) -> f64 {
    let mut acc = 0.0f64;
    for (ta, tb) in a.iter().zip(b) {
        for (&va, &vb) in ta.data.iter().zip(&tb.data) {
            let d = va as f64 - vb as f64;
            acc += d * d;
        }
    }
    acc.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l2_dist_params_basic() {
        let a = vec![HostTensor::new(vec![2], vec![1.0, 2.0])];
        let b = vec![HostTensor::new(vec![2], vec![4.0, 6.0])];
        assert!((l2_dist_params(&a, &b) - 5.0).abs() < 1e-12);
        assert_eq!(l2_dist_params(&a, &a), 0.0);
    }
}

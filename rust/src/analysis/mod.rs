//! Fig-1/Fig-2 analyses: variance reduction and score correlation.
pub mod correlation;
pub mod variance;

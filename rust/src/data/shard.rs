//! Out-of-core streaming shard store (ISSUE 6 tentpole).
//!
//! The synthetic generators are virtual — features are recomputed from
//! `(seed, index)` on every access — which is cheap for small pools but
//! makes "millions of samples" experiments pay full generation cost per
//! presample cycle. This module materializes any [`Dataset`] once into a
//! directory of fixed-size binary shards and streams it back with a
//! bounded resident set, so pools far larger than RAM train through the
//! exact same `Dataset` trait the rest of the pipeline already uses.
//!
//! On-disk layout (all integers little-endian):
//!
//! * `manifest.json` — `version`, `feature_dim`, `num_classes`, `samples`,
//!   `shard_len` (rows per full shard) and `shards` (file count), parsed
//!   with the vendored [`crate::util::json`] parser.
//! * `shard-NNNNN.bin` — `rows * feature_dim` f32 feature values followed
//!   by `rows` i32 labels, where `rows` is `shard_len` for every shard but
//!   a possibly-short tail.
//!
//! Streaming is handled by [`ShardedDataset`]: shards load lazily on first
//! touch, an LRU set of at most `resident_shards` stays decoded in memory,
//! and (optionally) a small [`WorkerPool`] readahead overlaps the *next*
//! shard's disk IO with scoring and training on the current one via
//! [`WorkerPool::submit`]. Determinism contract: returned features and
//! labels are a pure function of the on-disk bytes and the sample index —
//! eviction and readahead reorder IO, never results.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{bail, Context, Result};

use super::Dataset;
use crate::runtime::{HostTensor, WorkerPool};
use crate::util::json::Json;

/// Current on-disk format version (bump on layout changes).
pub const SHARD_FORMAT_VERSION: usize = 1;

/// Default bound on decoded shards kept in memory.
pub const DEFAULT_RESIDENT_SHARDS: usize = 4;

fn shard_path(dir: &Path, s: usize) -> PathBuf {
    dir.join(format!("shard-{s:05}.bin"))
}

/// One decoded shard: `rows * feature_dim` features + `rows` labels.
struct ShardData {
    x: Vec<f32>,
    y: Vec<i32>,
}

/// Incremental writer: buffers one shard worth of rows, flushing each full
/// shard to its own file; [`ShardWriter::finish`] writes the tail shard and
/// the manifest. Use [`write_dataset`] for the whole-dataset one-liner.
pub struct ShardWriter {
    dir: PathBuf,
    feature_dim: usize,
    num_classes: usize,
    shard_len: usize,
    features: Vec<f32>,
    labels: Vec<i32>,
    samples: usize,
    shards: usize,
}

impl ShardWriter {
    pub fn create(
        dir: impl AsRef<Path>,
        feature_dim: usize,
        num_classes: usize,
        shard_len: usize,
    ) -> Result<Self> {
        if feature_dim == 0 || shard_len == 0 {
            bail!("shard store: feature_dim and shard_len must be positive");
        }
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating shard dir {}", dir.display()))?;
        Ok(Self {
            dir,
            feature_dim,
            num_classes,
            shard_len,
            features: Vec::with_capacity(shard_len * feature_dim),
            labels: Vec::with_capacity(shard_len),
            samples: 0,
            shards: 0,
        })
    }

    /// Append one sample; flushes a shard file whenever `shard_len` rows
    /// have accumulated.
    pub fn push(&mut self, features: &[f32], label: i32) -> Result<()> {
        if features.len() != self.feature_dim {
            bail!(
                "shard store: sample has {} features, manifest says {}",
                features.len(),
                self.feature_dim
            );
        }
        self.features.extend_from_slice(features);
        self.labels.push(label);
        self.samples += 1;
        if self.labels.len() == self.shard_len {
            self.flush_shard()?;
        }
        Ok(())
    }

    fn flush_shard(&mut self) -> Result<()> {
        if self.labels.is_empty() {
            return Ok(());
        }
        let mut bytes = Vec::with_capacity(4 * (self.features.len() + self.labels.len()));
        for v in &self.features {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for v in &self.labels {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let path = shard_path(&self.dir, self.shards);
        std::fs::write(&path, &bytes)
            .with_context(|| format!("writing shard {}", path.display()))?;
        self.shards += 1;
        self.features.clear();
        self.labels.clear();
        Ok(())
    }

    /// Flush the partial tail shard and write `manifest.json`.
    pub fn finish(mut self) -> Result<()> {
        self.flush_shard()?;
        let manifest = format!(
            "{{\"version\":{},\"feature_dim\":{},\"num_classes\":{},\
             \"samples\":{},\"shard_len\":{},\"shards\":{}}}\n",
            SHARD_FORMAT_VERSION,
            self.feature_dim,
            self.num_classes,
            self.samples,
            self.shard_len,
            self.shards
        );
        let path = self.dir.join("manifest.json");
        std::fs::write(&path, manifest)
            .with_context(|| format!("writing manifest {}", path.display()))
    }
}

/// Materialize `ds` (at augmentation epoch 0) into `dir` as a shard store.
pub fn write_dataset<D: Dataset + ?Sized>(
    dir: impl AsRef<Path>,
    ds: &D,
    shard_len: usize,
) -> Result<()> {
    let mut w = ShardWriter::create(dir, ds.feature_dim(), ds.num_classes(), shard_len)?;
    let mut row = vec![0.0f32; ds.feature_dim()];
    for i in 0..ds.len() {
        ds.write_features(i, 0, &mut row);
        w.push(&row, ds.label(i))?;
    }
    w.finish()
}

/// Shared lazy-loading state: the resident map plus an in-flight set so
/// concurrent readers (trainer, prefetch workers, readahead jobs) never
/// decode the same shard twice. `BTreeMap`/`BTreeSet` by determinism
/// contract (tools/detlint `nondeterministic-iteration`): eviction scans
/// `resident`, and a seeded-hash iteration order would let the *victim
/// choice* — and therefore IO timing — vary run to run; key order makes
/// the tick tie-break deterministic by construction.
struct CacheState {
    resident: BTreeMap<usize, Resident>,
    inflight: BTreeSet<usize>,
    tick: u64,
}

struct Resident {
    data: Arc<ShardData>,
    tick: u64,
}

struct ShardCache {
    state: Mutex<CacheState>,
    ready: Condvar,
}

impl ShardCache {
    fn is_known(&self, s: usize) -> bool {
        let st = lock(self);
        st.resident.contains_key(&s) || st.inflight.contains(&s)
    }
}

/// Cache-state lock that shrugs off poisoning: the state is a plain
/// LRU map, valid after any panic unwound past it.
fn lock(cache: &ShardCache) -> std::sync::MutexGuard<'_, CacheState> {
    cache.state.lock().unwrap_or_else(|e| e.into_inner())
}

/// Streaming [`Dataset`] over a directory written by [`ShardWriter`].
///
/// At most `resident_shards` decoded shards stay in memory (least-recently
/// used shards are evicted first); everything else is re-read from disk on
/// demand. `epoch` is ignored by [`Dataset::write_features`] — shard files
/// hold *pre-materialized* rows, mirroring the paper's 1.5M pre-augmented
/// CIFAR images, so augmentation must happen before [`write_dataset`].
pub struct ShardedDataset {
    dir: PathBuf,
    feature_dim: usize,
    num_classes: usize,
    samples: usize,
    shard_len: usize,
    shards: usize,
    resident_budget: usize,
    cache: Arc<ShardCache>,
    readahead: Option<Arc<WorkerPool>>,
}

impl ShardedDataset {
    /// Open a store, validating the manifest and every shard file's size
    /// up front so streaming itself cannot hit malformed data.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading shard manifest {}", mpath.display()))?;
        let m = Json::parse(&text)
            .with_context(|| format!("parsing shard manifest {}", mpath.display()))?;
        let field = |k: &str| -> Result<usize> {
            m.req(k)?
                .as_usize()
                .with_context(|| format!("manifest key {k:?} must be a number"))
        };
        let version = field("version")?;
        if version != SHARD_FORMAT_VERSION {
            bail!("shard store {}: unsupported format version {version}", dir.display());
        }
        let ds = Self {
            feature_dim: field("feature_dim")?,
            num_classes: field("num_classes")?,
            samples: field("samples")?,
            shard_len: field("shard_len")?,
            shards: field("shards")?,
            resident_budget: DEFAULT_RESIDENT_SHARDS,
            cache: Arc::new(ShardCache {
                state: Mutex::new(CacheState {
                    resident: BTreeMap::new(),
                    inflight: BTreeSet::new(),
                    tick: 0,
                }),
                ready: Condvar::new(),
            }),
            readahead: None,
            dir,
        };
        if ds.feature_dim == 0 || ds.shard_len == 0 {
            bail!("shard store {}: zero feature_dim or shard_len", ds.dir.display());
        }
        let want = ds.samples.div_ceil(ds.shard_len);
        if ds.shards != want {
            bail!(
                "shard store {}: manifest lists {} shards, {} samples at shard_len {} need {}",
                ds.dir.display(),
                ds.shards,
                ds.samples,
                ds.shard_len,
                want
            );
        }
        for s in 0..ds.shards {
            let path = shard_path(&ds.dir, s);
            let meta = std::fs::metadata(&path)
                .with_context(|| format!("missing shard file {}", path.display()))?;
            let rows = ds.shard_rows(s);
            let expect = (rows * ds.feature_dim * 4 + rows * 4) as u64;
            if meta.len() != expect {
                bail!(
                    "shard file {}: {} bytes on disk, expected {expect}",
                    path.display(),
                    meta.len()
                );
            }
        }
        Ok(ds)
    }

    /// Bound the decoded-shard LRU (minimum 1).
    pub fn with_resident_shards(mut self, n: usize) -> Self {
        self.resident_budget = n.max(1);
        self
    }

    /// Enable background readahead of the next sequential shard on a small
    /// worker pool — overlaps shard IO with scoring/training. Purely a
    /// throughput knob; results are unaffected.
    pub fn with_readahead(mut self, workers: usize) -> Self {
        self.readahead = Some(Arc::new(WorkerPool::new(workers.max(1))));
        self
    }

    fn shard_rows(&self, s: usize) -> usize {
        if s + 1 == self.shards && self.samples % self.shard_len != 0 {
            self.samples % self.shard_len
        } else {
            self.shard_len
        }
    }

    fn try_fetch(&self, s: usize) -> Result<Arc<ShardData>> {
        let (d, budget) = (self.feature_dim, self.resident_budget);
        let data = try_fetch_shard(&self.cache, &self.dir, s, self.shard_rows(s), d, budget)?;
        if let Some(pool) = &self.readahead {
            let next = s + 1;
            if next < self.shards && !self.cache.is_known(next) {
                let cache = Arc::clone(&self.cache);
                let dir = self.dir.clone();
                let rows = self.shard_rows(next);
                pool.submit(move || {
                    // background readahead is advisory: a failure here is
                    // retried — and surfaced — by the foreground fetch
                    let _ = try_fetch_shard(&cache, &dir, next, rows, d, budget);
                });
            }
        }
        Ok(data)
    }

    /// Infallible fetch for the infallible [`Dataset`] accessors; batch
    /// assembly goes through [`Dataset::try_batch`] instead, which
    /// surfaces IO failures as errors.
    fn fetch(&self, s: usize) -> Arc<ShardData> {
        self.try_fetch(s).unwrap_or_else(|e| panic!("shard store: {e:#}"))
    }
}

/// Load shard `s` through the cache: return the resident copy, wait on a
/// concurrent loader, or read + decode the file and insert it (evicting
/// least-recently-used shards beyond `budget`). The store was fully
/// size-validated at [`ShardedDataset::open`] time, so a read failure here
/// means the files changed underneath us: the in-flight marker is removed
/// and every waiter woken *before* the descriptive `Err` surfaces, so
/// concurrent and later fetches retry (and fail loudly themselves) instead
/// of deadlocking on a loader that never finished.
fn try_fetch_shard(
    cache: &ShardCache,
    dir: &Path,
    s: usize,
    rows: usize,
    d: usize,
    budget: usize,
) -> Result<Arc<ShardData>> {
    let mut st = lock(cache);
    loop {
        if st.resident.contains_key(&s) {
            st.tick += 1;
            let tick = st.tick;
            if let Some(e) = st.resident.get_mut(&s) {
                e.tick = tick;
                return Ok(Arc::clone(&e.data));
            }
        }
        if st.inflight.contains(&s) {
            st = cache.ready.wait(st).unwrap_or_else(|e| e.into_inner());
            continue;
        }
        st.inflight.insert(s);
        break;
    }
    drop(st);
    let data = match read_shard_file(&shard_path(dir, s), rows, d) {
        Ok(data) => Arc::new(data),
        Err(e) => {
            let mut st = lock(cache);
            st.inflight.remove(&s);
            drop(st);
            cache.ready.notify_all();
            return Err(e.context(format!("shard {s} became unreadable after open")));
        }
    };
    let mut st = lock(cache);
    st.tick += 1;
    let tick = st.tick;
    st.resident.insert(s, Resident { data: Arc::clone(&data), tick });
    st.inflight.remove(&s);
    while st.resident.len() > budget {
        let victim = st
            .resident
            .iter()
            .filter(|e| *e.0 != s)
            .min_by_key(|e| e.1.tick)
            .map(|e| *e.0);
        match victim {
            Some(k) => {
                st.resident.remove(&k);
            }
            None => break,
        }
    }
    drop(st);
    cache.ready.notify_all();
    Ok(data)
}

fn read_shard_file(path: &Path, rows: usize, d: usize) -> Result<ShardData> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading shard {}", path.display()))?;
    let split = rows * d * 4;
    if bytes.len() != split + rows * 4 {
        bail!("shard {}: {} bytes, expected {}", path.display(), bytes.len(), split + rows * 4);
    }
    let mut x = Vec::with_capacity(rows * d);
    for c in bytes[..split].chunks_exact(4) {
        x.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
    let mut y = Vec::with_capacity(rows);
    for c in bytes[split..].chunks_exact(4) {
        y.push(i32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
    Ok(ShardData { x, y })
}

impl Dataset for ShardedDataset {
    fn len(&self) -> usize {
        self.samples
    }

    fn feature_dim(&self) -> usize {
        self.feature_dim
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn label(&self, i: usize) -> i32 {
        assert!(i < self.samples, "sample {i} out of range ({})", self.samples);
        let shard = self.fetch(i / self.shard_len);
        shard.y[i % self.shard_len]
    }

    fn write_features(&self, i: usize, _epoch: u64, out: &mut [f32]) {
        assert!(i < self.samples, "sample {i} out of range ({})", self.samples);
        let shard = self.fetch(i / self.shard_len);
        let r = i % self.shard_len;
        out.copy_from_slice(&shard.x[r * self.feature_dim..(r + 1) * self.feature_dim]);
    }

    /// Batch assembly that surfaces shard read failures (a file truncated
    /// or deleted after open-time validation) as errors instead of panics.
    fn try_batch(&self, indices: &[usize], _epoch: u64) -> Result<(HostTensor, Vec<i32>)> {
        let d = self.feature_dim;
        let mut x = HostTensor::zeros(vec![indices.len(), d]);
        let mut y = Vec::with_capacity(indices.len());
        for (row, &i) in indices.iter().enumerate() {
            if i >= self.samples {
                bail!("sample {i} out of range ({})", self.samples);
            }
            let shard = self.try_fetch(i / self.shard_len)?;
            let r = i % self.shard_len;
            x.data[row * d..(row + 1) * d].copy_from_slice(&shard.x[r * d..(r + 1) * d]);
            y.push(shard.y[r]);
        }
        Ok((x, y))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticImages;

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("isample_shard_{tag}_{}", std::process::id()))
    }

    fn roundtrip(ds: &SyntheticImages, sharded: &ShardedDataset) {
        assert_eq!(sharded.len(), ds.len());
        assert_eq!(sharded.feature_dim(), ds.feature_dim());
        assert_eq!(sharded.num_classes(), ds.num_classes());
        let mut want = vec![0.0f32; ds.feature_dim()];
        let mut got = vec![0.0f32; ds.feature_dim()];
        for i in 0..ds.len() {
            assert_eq!(sharded.label(i), ds.label(i), "label {i}");
            ds.write_features(i, 0, &mut want);
            sharded.write_features(i, 7, &mut got); // epoch must be ignored
            assert_eq!(got, want, "features {i}");
        }
    }

    #[test]
    fn shard_roundtrip_is_bitwise_with_a_short_tail() {
        let ds = SyntheticImages::builder(16, 4).samples(1_000).seed(9).build();
        let dir = tmp_dir("tail");
        write_dataset(&dir, &ds, 128).unwrap(); // 7 full shards + 104-row tail
        let sharded = ShardedDataset::open(&dir).unwrap().with_resident_shards(2);
        roundtrip(&ds, &sharded);
        // batch assembly goes through the same path
        let (x, y) = sharded.batch(&[0, 131, 999], 0);
        let (wx, wy) = ds.batch(&[0, 131, 999], 0);
        assert_eq!(x.data, wx.data);
        assert_eq!(y, wy);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn eviction_and_readahead_never_change_results() {
        let ds = SyntheticImages::builder(8, 3).samples(300).seed(4).build();
        let dir = tmp_dir("evict");
        write_dataset(&dir, &ds, 32).unwrap();
        // resident budget 1 forces constant eviction; readahead races it
        let sharded =
            ShardedDataset::open(&dir).unwrap().with_resident_shards(1).with_readahead(2);
        roundtrip(&ds, &sharded);
        roundtrip(&ds, &sharded); // second pass re-reads evicted shards
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn open_rejects_truncated_shards() {
        let ds = SyntheticImages::builder(8, 3).samples(64).seed(1).build();
        let dir = tmp_dir("trunc");
        write_dataset(&dir, &ds, 32).unwrap();
        let victim = dir.join("shard-00001.bin");
        let bytes = std::fs::read(&victim).unwrap();
        std::fs::write(&victim, &bytes[..bytes.len() - 4]).unwrap();
        let err = ShardedDataset::open(&dir).unwrap_err().to_string();
        assert!(err.contains("bytes on disk"), "unexpected error: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mid_read_truncation_surfaces_an_error_and_recovers() -> Result<()> {
        let ds = SyntheticImages::builder(8, 3).samples(96).seed(6).build();
        let dir = tmp_dir("midread");
        write_dataset(&dir, &ds, 32)?; // 3 shards of 32 rows
        let sharded = ShardedDataset::open(&dir)?.with_resident_shards(1);
        let (_, y) = sharded.try_batch(&[0, 1], 0)?;
        assert_eq!(y.len(), 2);
        // the last shard changes underneath us after open's validation
        let victim = shard_path(&dir, 2);
        let bytes = std::fs::read(&victim)?;
        std::fs::write(&victim, &bytes[..bytes.len() - 4])?;
        // twice: a failed load must clear its in-flight marker, or the
        // second attempt would wait forever on a loader that never finished
        for attempt in 0..2 {
            let err = match sharded.try_batch(&[64, 65], 0) {
                Err(e) => format!("{e:#}"),
                Ok(_) => String::new(),
            };
            assert!(err.contains("shard 2 became unreadable"), "attempt {attempt}: got {err:?}");
        }
        // untouched shards keep working through the same cache
        let (_, y) = sharded.try_batch(&[33], 0)?;
        assert_eq!(y, vec![ds.label(33)]);
        std::fs::remove_dir_all(&dir).ok();
        Ok(())
    }
}

//! Synthetic image classification — the CIFAR-10/100 stand-in (§4.2).
//!
//! Each class has a Gaussian prototype vector; each sample is generated
//! deterministically from `(seed, index)` as one of three difficulty tiers
//! (DESIGN.md §2):
//!
//! * **Easy** (default 70%): `prototype + small noise` — learned quickly,
//!   gradients collapse early (the "could be ignored" mass of the paper).
//! * **Boundary** (20%): convex mix of the true prototype and a confuser
//!   class — stays informative for many epochs.
//! * **Outlier** (10%): heavy noise over the prototype — keeps producing
//!   large gradients essentially forever.
//!
//! This explicit tier control is what makes the generator a faithful test
//! bed for importance sampling: the *dispersion* of per-sample gradient
//! norms — the only property Alg. 1 exploits — is reproduced by
//! construction, without the original pixels.

use super::{Dataset, Split, Tier};
use crate::util::rng::SplitMix64;

#[derive(Debug, Clone)]
pub struct SyntheticImagesBuilder {
    feature_dim: usize,
    num_classes: usize,
    samples: usize,
    test_samples: usize,
    seed: u64,
    easy_frac: f64,
    boundary_frac: f64,
    easy_noise: f64,
    outlier_noise: f64,
    augment: bool,
}

impl SyntheticImagesBuilder {
    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n;
        self
    }

    pub fn test_samples(mut self, n: usize) -> Self {
        self.test_samples = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Fractions of easy/boundary samples (the remainder is outliers).
    pub fn tiers(mut self, easy: f64, boundary: f64) -> Self {
        assert!(easy >= 0.0 && boundary >= 0.0 && easy + boundary <= 1.0);
        self.easy_frac = easy;
        self.boundary_frac = boundary;
        self
    }

    pub fn noise(mut self, easy: f64, outlier: f64) -> Self {
        self.easy_noise = easy;
        self.outlier_noise = outlier;
        self
    }

    /// Enable the deterministic augmentation stream (per-epoch jitter), the
    /// stand-in for the paper's 1.5M pre-augmented CIFAR images.
    pub fn augment(mut self, on: bool) -> Self {
        self.augment = on;
        self
    }

    pub fn build(self) -> SyntheticImages {
        SyntheticImages::new(self, 0)
    }

    /// Build a train/test split (test uses a disjoint index space and no
    /// augmentation).
    pub fn split(self) -> Split<SyntheticImages> {
        let mut test_builder = self.clone();
        test_builder.samples = self.test_samples;
        test_builder.augment = false;
        let train = SyntheticImages::new(self, 0);
        // index-space offset decorrelates test samples from train samples
        let test = SyntheticImages::new(test_builder, 0x7E57_0000_0000_0000);
        Split { train, test }
    }
}

pub struct SyntheticImages {
    cfg: SyntheticImagesBuilder,
    /// `num_classes * feature_dim` prototype matrix.
    prototypes: Vec<f32>,
    index_offset: u64,
    /// Materialized base features (`samples * feature_dim`), built once at
    /// construction when the dataset fits the cache budget. Turns the batch
    /// hot path into a memcpy (+ per-epoch jitter); §Perf L3 optimization.
    cache: Option<Vec<f32>>,
}

impl SyntheticImages {
    pub fn builder(feature_dim: usize, num_classes: usize) -> SyntheticImagesBuilder {
        SyntheticImagesBuilder {
            feature_dim,
            num_classes,
            samples: 16_384,
            test_samples: 2_048,
            seed: 0,
            easy_frac: 0.7,
            boundary_frac: 0.2,
            easy_noise: 0.25,
            outlier_noise: 1.5,
            augment: false,
        }
    }

    fn new(cfg: SyntheticImagesBuilder, index_offset: u64) -> Self {
        // Prototypes: unit-ish Gaussian directions, one per class, from a
        // dedicated stream so sample streams never alias them.
        let mut rng = SplitMix64::tensor_stream(cfg.seed, u64::MAX);
        let mut prototypes = Vec::with_capacity(cfg.num_classes * cfg.feature_dim);
        while prototypes.len() < cfg.num_classes * cfg.feature_dim {
            let (a, b) = rng.normal_pair();
            prototypes.push(a as f32);
            prototypes.push(b as f32);
        }
        prototypes.truncate(cfg.num_classes * cfg.feature_dim);
        let mut ds = Self { cfg, prototypes, index_offset, cache: None };
        let bytes = ds.cfg.samples * ds.cfg.feature_dim * 4;
        if bytes <= CACHE_BUDGET_BYTES {
            let d = ds.cfg.feature_dim;
            let mut cache = vec![0.0f32; ds.cfg.samples * d];
            for i in 0..ds.cfg.samples {
                ds.generate_features(i, &mut cache[i * d..(i + 1) * d]);
            }
            ds.cache = Some(cache);
        }
        ds
    }

    fn sample_rng(&self, i: usize) -> SplitMix64 {
        SplitMix64::tensor_stream(
            self.cfg.seed ^ 0xDA7A_5E7,
            self.index_offset.wrapping_add(i as u64),
        )
    }

    fn prototype(&self, class: usize) -> &[f32] {
        let d = self.cfg.feature_dim;
        &self.prototypes[class * d..(class + 1) * d]
    }

    fn tier_of(&self, rng: &mut SplitMix64) -> Tier {
        let u = rng.uniform();
        if u < self.cfg.easy_frac {
            Tier::Easy
        } else if u < self.cfg.easy_frac + self.cfg.boundary_frac {
            Tier::Boundary
        } else {
            Tier::Outlier
        }
    }
}

impl Dataset for SyntheticImages {
    fn len(&self) -> usize {
        self.cfg.samples
    }

    fn feature_dim(&self) -> usize {
        self.cfg.feature_dim
    }

    fn num_classes(&self) -> usize {
        self.cfg.num_classes
    }

    fn label(&self, i: usize) -> i32 {
        // label is the first draw of the sample stream
        let mut rng = self.sample_rng(i);
        rng.below(self.cfg.num_classes) as i32
    }

    fn tier(&self, i: usize) -> Option<Tier> {
        let mut rng = self.sample_rng(i);
        let _class = rng.below(self.cfg.num_classes);
        Some(self.tier_of(&mut rng))
    }

    fn write_features(&self, i: usize, epoch: u64, out: &mut [f32]) {
        let d = self.cfg.feature_dim;
        debug_assert_eq!(out.len(), d);
        match &self.cache {
            Some(c) => out.copy_from_slice(&c[i * d..(i + 1) * d]),
            None => self.generate_features(i, out),
        }
        if self.cfg.augment && epoch > 0 {
            super::augment::jitter(self.cfg.seed, self.index_offset + i as u64, epoch, out);
        }
    }
}

/// Datasets whose base features fit under this budget are materialized at
/// construction (16384 x 768 f32 = 48 MiB comfortably qualifies).
const CACHE_BUDGET_BYTES: usize = 256 << 20;

impl SyntheticImages {
    /// Generate the (un-augmented) base features of sample `i`.
    fn generate_features(&self, i: usize, out: &mut [f32]) {
        let d = self.cfg.feature_dim;
        let mut rng = self.sample_rng(i);
        let class = rng.below(self.cfg.num_classes);
        let tier = self.tier_of(&mut rng);
        let proto = self.prototype(class);

        let (noise, mix): (f64, Option<(usize, f64)>) = match tier {
            Tier::Easy => (self.cfg.easy_noise, None),
            Tier::Outlier => (self.cfg.outlier_noise, None),
            Tier::Boundary => {
                // confuser class and mixing coefficient in [0.35, 0.5]:
                // closer to 0.5 = closer to the decision boundary.
                let confuser = {
                    let c = rng.below(self.cfg.num_classes - 1);
                    if c >= class {
                        c + 1
                    } else {
                        c
                    }
                };
                let alpha = rng.uniform_range(0.35, 0.5);
                (self.cfg.easy_noise, Some((confuser, alpha)))
            }
        };

        let confuser_proto = mix.map(|(c, a)| (self.prototype(c), a));
        let mut k = 0;
        while k < d {
            let (n1, n2) = rng.fast_normal_pair();
            for (off, n) in [(0usize, n1), (1usize, n2)] {
                let j = k + off;
                if j >= d {
                    break;
                }
                let base = match confuser_proto {
                    Some((cp, a)) => {
                        proto[j] as f64 * (1.0 - a) + cp[j] as f64 * a
                    }
                    None => proto[j] as f64,
                };
                out[j] = (base + n * noise) as f32;
            }
            k += 2;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn ds() -> SyntheticImages {
        SyntheticImages::builder(64, 10).samples(2000).seed(7).build()
    }

    #[test]
    fn deterministic_per_index() {
        let d = ds();
        let mut a = vec![0.0; 64];
        let mut b = vec![0.0; 64];
        d.write_features(123, 0, &mut a);
        d.write_features(123, 0, &mut b);
        assert_eq!(a, b);
        assert_eq!(d.label(123), d.label(123));
    }

    #[test]
    fn different_indices_differ() {
        let d = ds();
        let mut a = vec![0.0; 64];
        let mut b = vec![0.0; 64];
        d.write_features(1, 0, &mut a);
        d.write_features(2, 0, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn labels_cover_all_classes() {
        let d = ds();
        let mut seen = vec![false; 10];
        for i in 0..500 {
            seen[d.label(i) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn tier_mix_roughly_matches_config() {
        let d = ds();
        let mut counts = [0usize; 3];
        let n = 4000;
        let d2 = SyntheticImages::builder(64, 10).samples(n).seed(7).build();
        for i in 0..n {
            match d2.tier(i).unwrap() {
                Tier::Easy => counts[0] += 1,
                Tier::Boundary => counts[1] += 1,
                Tier::Outlier => counts[2] += 1,
            }
        }
        let f = |c: usize| c as f64 / n as f64;
        assert!((f(counts[0]) - 0.7).abs() < 0.05, "easy {}", f(counts[0]));
        assert!((f(counts[1]) - 0.2).abs() < 0.05, "boundary {}", f(counts[1]));
        assert!((f(counts[2]) - 0.1).abs() < 0.05, "outlier {}", f(counts[2]));
        drop(d);
    }

    #[test]
    fn easy_samples_cluster_near_prototype() {
        // mean distance-to-prototype must be clearly smaller for easy
        // samples than for outliers — the heavy-tail construction.
        let d = ds();
        let mut buf = vec![0.0f32; 64];
        let (mut easy, mut outlier) = (vec![], vec![]);
        for i in 0..2000 {
            let class = d.label(i) as usize;
            d.write_features(i, 0, &mut buf);
            let dist = stats::l2_dist(&buf, d.prototype(class)) as f32;
            match d.tier(i).unwrap() {
                Tier::Easy => easy.push(dist),
                Tier::Outlier => outlier.push(dist),
                _ => {}
            }
        }
        assert!(stats::mean(&easy) * 2.0 < stats::mean(&outlier));
    }

    #[test]
    fn split_is_disjoint_and_unaugmented() {
        let split = SyntheticImages::builder(32, 5)
            .samples(100)
            .test_samples(50)
            .seed(1)
            .augment(true)
            .split();
        assert_eq!(split.train.len(), 100);
        assert_eq!(split.test.len(), 50);
        let mut a = vec![0.0; 32];
        let mut b = vec![0.0; 32];
        split.train.write_features(0, 0, &mut a);
        split.test.write_features(0, 0, &mut b);
        assert_ne!(a, b, "train/test index spaces must be disjoint");
        // test set ignores epochs (no augmentation)
        split.test.write_features(0, 3, &mut a);
        assert_eq!(a, b);
    }

    #[test]
    fn augmentation_changes_with_epoch_but_is_deterministic() {
        let d = SyntheticImages::builder(32, 5).samples(10).seed(2).augment(true).build();
        let mut e0 = vec![0.0; 32];
        let mut e1 = vec![0.0; 32];
        let mut e1b = vec![0.0; 32];
        d.write_features(3, 0, &mut e0);
        d.write_features(3, 1, &mut e1);
        d.write_features(3, 1, &mut e1b);
        assert_ne!(e0, e1);
        assert_eq!(e1, e1b);
    }
}

//! Fine-tuning features — the MIT67 stand-in (§4.3).
//!
//! The paper fine-tunes an ImageNet ResNet-50 on 67 indoor-scene classes.
//! We simulate the *output of the frozen backbone*: class-structured latent
//! vectors pushed through a fixed random projection + ReLU (the "backbone"),
//! yielding features that are (a) mostly linearly separable — the
//! fine-tuning regime where most samples are handled correctly almost
//! immediately, giving importance sampling its biggest win — and (b)
//! non-Gaussian, thanks to the ReLU.
//!
//! Difficulty mix mirrors `synthetic.rs` but with a *larger* easy fraction
//! (85%), matching the paper's observation that fine-tuning disperses
//! scores extremely fast (τ crosses the threshold within minutes).

use super::{Dataset, Split, Tier};
use crate::util::rng::SplitMix64;

#[derive(Clone, Copy)]
pub struct FinetuneFeaturesBuilder {
    latent_dim: usize,
    feature_dim: usize,
    num_classes: usize,
    samples: usize,
    test_samples: usize,
    seed: u64,
    easy_frac: f64,
    boundary_frac: f64,
}

impl FinetuneFeaturesBuilder {
    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n;
        self
    }

    pub fn test_samples(mut self, n: usize) -> Self {
        self.test_samples = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn build(self) -> FinetuneFeatures {
        FinetuneFeatures::new(self, 0)
    }

    pub fn split(self) -> Split<FinetuneFeatures> {
        let mut tb = self;
        tb.samples = self.test_samples;
        let train = FinetuneFeatures::new(self, 0);
        let test = FinetuneFeatures::new(tb, 0x7E57_0000_0000_0000);
        Split { train, test }
    }
}

pub struct FinetuneFeatures {
    cfg: FinetuneFeaturesBuilder,
    /// `num_classes * latent_dim` class centers.
    centers: Vec<f32>,
    /// `latent_dim * feature_dim` frozen backbone projection.
    backbone: Vec<f32>,
    index_offset: u64,
    /// Materialized features (no augmentation stream on this dataset, so
    /// the cache is exact); §Perf L3 optimization.
    cache: Option<Vec<f32>>,
}

impl FinetuneFeatures {
    pub fn builder(feature_dim: usize, num_classes: usize) -> FinetuneFeaturesBuilder {
        FinetuneFeaturesBuilder {
            latent_dim: 32,
            feature_dim,
            num_classes,
            samples: 5_360, // ~80 images/class, like MIT67's train split
            test_samples: 1_340,
            seed: 0,
            easy_frac: 0.85,
            boundary_frac: 0.10,
        }
    }

    fn new(cfg: FinetuneFeaturesBuilder, index_offset: u64) -> Self {
        let mut rng = SplitMix64::tensor_stream(cfg.seed ^ 0xF17E, u64::MAX);
        let mut centers = Vec::with_capacity(cfg.num_classes * cfg.latent_dim);
        while centers.len() < cfg.num_classes * cfg.latent_dim {
            let (a, b) = rng.normal_pair();
            // spread centers out: scale 2 keeps classes mostly separable
            centers.push(2.0 * a as f32);
            centers.push(2.0 * b as f32);
        }
        centers.truncate(cfg.num_classes * cfg.latent_dim);

        let mut backbone = Vec::with_capacity(cfg.latent_dim * cfg.feature_dim);
        let scale = (1.0 / cfg.latent_dim as f64).sqrt();
        while backbone.len() < cfg.latent_dim * cfg.feature_dim {
            let (a, b) = rng.normal_pair();
            backbone.push((a * scale) as f32);
            backbone.push((b * scale) as f32);
        }
        backbone.truncate(cfg.latent_dim * cfg.feature_dim);
        let mut ds = Self { cfg, centers, backbone, index_offset, cache: None };
        if ds.cfg.samples * ds.cfg.feature_dim * 4 <= 256 << 20 {
            let d = ds.cfg.feature_dim;
            let mut cache = vec![0.0f32; ds.cfg.samples * d];
            for i in 0..ds.cfg.samples {
                ds.generate_features(i, &mut cache[i * d..(i + 1) * d]);
            }
            ds.cache = Some(cache);
        }
        ds
    }

    fn sample_rng(&self, i: usize) -> SplitMix64 {
        SplitMix64::tensor_stream(
            self.cfg.seed ^ 0xF1_7E5A,
            self.index_offset.wrapping_add(i as u64),
        )
    }

    fn center(&self, class: usize) -> &[f32] {
        let d = self.cfg.latent_dim;
        &self.centers[class * d..(class + 1) * d]
    }
}

impl Dataset for FinetuneFeatures {
    fn len(&self) -> usize {
        self.cfg.samples
    }

    fn feature_dim(&self) -> usize {
        self.cfg.feature_dim
    }

    fn num_classes(&self) -> usize {
        self.cfg.num_classes
    }

    fn label(&self, i: usize) -> i32 {
        let mut rng = self.sample_rng(i);
        rng.below(self.cfg.num_classes) as i32
    }

    fn tier(&self, i: usize) -> Option<Tier> {
        let mut rng = self.sample_rng(i);
        let _ = rng.below(self.cfg.num_classes);
        let u = rng.uniform();
        Some(if u < self.cfg.easy_frac {
            Tier::Easy
        } else if u < self.cfg.easy_frac + self.cfg.boundary_frac {
            Tier::Boundary
        } else {
            Tier::Outlier
        })
    }

    fn write_features(&self, i: usize, _epoch: u64, out: &mut [f32]) {
        if let Some(c) = &self.cache {
            let d = self.cfg.feature_dim;
            out.copy_from_slice(&c[i * d..(i + 1) * d]);
            return;
        }
        self.generate_features(i, out);
    }
}

impl FinetuneFeatures {
    fn generate_features(&self, i: usize, out: &mut [f32]) {
        let ld = self.cfg.latent_dim;
        let fd = self.cfg.feature_dim;
        debug_assert_eq!(out.len(), fd);
        let mut rng = self.sample_rng(i);
        let class = rng.below(self.cfg.num_classes);
        let u = rng.uniform();
        let (noise, mix) = if u < self.cfg.easy_frac {
            (0.4, None)
        } else if u < self.cfg.easy_frac + self.cfg.boundary_frac {
            let confuser = {
                let c = rng.below(self.cfg.num_classes - 1);
                if c >= class {
                    c + 1
                } else {
                    c
                }
            };
            (0.4, Some((confuser, rng.uniform_range(0.35, 0.5))))
        } else {
            (2.0, None)
        };

        // latent vector
        let mut latent = vec![0.0f32; ld];
        let center = self.center(class);
        let confuser = mix.map(|(c, a)| (self.center(c), a));
        let mut k = 0;
        while k < ld {
            let (n1, n2) = rng.normal_pair();
            for (off, n) in [(0usize, n1), (1usize, n2)] {
                let j = k + off;
                if j >= ld {
                    break;
                }
                let base = match confuser {
                    Some((cp, a)) => center[j] as f64 * (1.0 - a) + cp[j] as f64 * a,
                    None => center[j] as f64,
                };
                latent[j] = (base + n * noise) as f32;
            }
            k += 2;
        }

        // frozen backbone: ReLU(latent @ backbone)
        for (j, o) in out.iter_mut().enumerate() {
            let mut acc = 0.0f64;
            for (l, &lv) in latent.iter().enumerate() {
                acc += lv as f64 * self.backbone[l * fd + j] as f64;
            }
            *o = (acc.max(0.0)) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let ds = FinetuneFeatures::builder(512, 67).samples(200).seed(3).build();
        assert_eq!(ds.feature_dim(), 512);
        assert_eq!(ds.num_classes(), 67);
        let mut a = vec![0.0; 512];
        let mut b = vec![0.0; 512];
        ds.write_features(10, 0, &mut a);
        ds.write_features(10, 5, &mut b); // no augmentation: epoch ignored
        assert_eq!(a, b);
    }

    #[test]
    fn features_are_relu_nonnegative() {
        let ds = FinetuneFeatures::builder(128, 10).samples(50).seed(4).build();
        let mut v = vec![0.0; 128];
        for i in 0..50 {
            ds.write_features(i, 0, &mut v);
            assert!(v.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn same_class_samples_are_closer_than_cross_class() {
        let ds = FinetuneFeatures::builder(128, 5).samples(500).seed(5).build();
        let mut by_class: Vec<Vec<Vec<f32>>> = vec![vec![]; 5];
        let mut buf = vec![0.0; 128];
        for i in 0..200 {
            ds.write_features(i, 0, &mut buf);
            by_class[ds.label(i) as usize].push(buf.clone());
        }
        let d = |a: &[f32], b: &[f32]| crate::util::stats::l2_dist(a, b);
        let within = d(&by_class[0][0], &by_class[0][1]);
        let across = d(&by_class[0][0], &by_class[1][0]);
        assert!(within < across, "within {within} !< across {across}");
    }

    #[test]
    fn split_sizes() {
        let s = FinetuneFeatures::builder(64, 10).samples(100).test_samples(40).split();
        assert_eq!(s.train.len(), 100);
        assert_eq!(s.test.len(), 40);
    }
}

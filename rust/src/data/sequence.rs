//! Permuted sequence classification — the pixel-by-pixel MNIST stand-in
//! (§4.4).
//!
//! The paper classifies randomly permuted 784-step MNIST rasters with an
//! LSTM; the permutation destroys locality so the network must integrate
//! information over long ranges. We synthesize 1-D "rasters" of length T
//! whose class identity is encoded in *global* structure (a class-specific
//! sinusoid mixture + pulse pattern), then apply a fixed random permutation
//! of the time steps — the same construction at CPU-tractable scale (T=64
//! by default vs 784).

use super::{Dataset, Split, Tier};
use crate::util::rng::SplitMix64;

#[derive(Clone, Copy)]
pub struct PermutedSequencesBuilder {
    timesteps: usize,
    num_classes: usize,
    samples: usize,
    test_samples: usize,
    seed: u64,
    easy_frac: f64,
    boundary_frac: f64,
}

impl PermutedSequencesBuilder {
    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n;
        self
    }

    pub fn test_samples(mut self, n: usize) -> Self {
        self.test_samples = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Fractions of easy/boundary samples (the remainder is outliers) —
    /// the same difficulty-tier control `SyntheticImages` exposes, so
    /// sequence tasks can be tuned into the paper's
    /// informative-minority regime.
    pub fn tiers(mut self, easy: f64, boundary: f64) -> Self {
        assert!(easy >= 0.0 && boundary >= 0.0 && easy + boundary <= 1.0);
        self.easy_frac = easy;
        self.boundary_frac = boundary;
        self
    }

    pub fn build(self) -> PermutedSequences {
        PermutedSequences::new(self, 0)
    }

    pub fn split(self) -> Split<PermutedSequences> {
        let mut tb = self;
        tb.samples = self.test_samples;
        let train = PermutedSequences::new(self, 0);
        let test = PermutedSequences::new(tb, 0x7E57_0000_0000_0000);
        Split { train, test }
    }
}

pub struct PermutedSequences {
    cfg: PermutedSequencesBuilder,
    /// The fixed permutation applied to every sequence.
    perm: Vec<usize>,
    /// Per-class (freq1, freq2, phase, pulse_pos) signatures.
    signatures: Vec<(f64, f64, f64, usize)>,
    index_offset: u64,
}

impl PermutedSequences {
    pub fn builder(timesteps: usize, num_classes: usize) -> PermutedSequencesBuilder {
        PermutedSequencesBuilder {
            timesteps,
            num_classes,
            samples: 8_192,
            test_samples: 1_024,
            seed: 0,
            easy_frac: 0.7,
            boundary_frac: 0.2,
        }
    }

    fn new(cfg: PermutedSequencesBuilder, index_offset: u64) -> Self {
        // fixed permutation, shared by train and test (paper: "we fix a
        // permutation matrix for all the pixels")
        let mut prng = SplitMix64::tensor_stream(cfg.seed ^ 0x9E9, u64::MAX);
        let mut perm: Vec<usize> = (0..cfg.timesteps).collect();
        prng.shuffle(&mut perm);

        let signatures = (0..cfg.num_classes)
            .map(|c| {
                let f1 = 1.0 + (c % 5) as f64;
                let f2 = 2.0 + (c / 5) as f64 * 1.5;
                let phase = prng.uniform_range(0.0, std::f64::consts::TAU);
                let pulse = prng.below(cfg.timesteps);
                (f1, f2, phase, pulse)
            })
            .collect();
        Self { cfg, perm, signatures, index_offset }
    }

    fn sample_rng(&self, i: usize) -> SplitMix64 {
        SplitMix64::tensor_stream(
            self.cfg.seed ^ 0x5E9_1D,
            self.index_offset.wrapping_add(i as u64),
        )
    }

    /// Unpermuted raster for `class` with per-sample jitter drawn from rng.
    fn raster(&self, class: usize, rng: &mut SplitMix64, noise: f64, out: &mut [f32]) {
        let t = self.cfg.timesteps;
        let (f1, f2, phase, pulse) = self.signatures[class];
        let fjit = rng.uniform_range(-0.05, 0.05);
        let pjit = rng.uniform_range(-0.3, 0.3);
        for (k, o) in out.iter_mut().enumerate().take(t) {
            let x = k as f64 / t as f64 * std::f64::consts::TAU;
            let mut v = ((f1 + fjit) * x + phase + pjit).sin() * 0.6
                + ((f2 + fjit) * x).cos() * 0.4;
            if k == pulse || k == (pulse + 3) % t {
                v += 1.5;
            }
            *o = v as f32;
        }
        let mut k = 0;
        while k < t {
            let (a, b) = rng.normal_pair();
            out[k] += (a * noise) as f32;
            if k + 1 < t {
                out[k + 1] += (b * noise) as f32;
            }
            k += 2;
        }
    }
}

impl Dataset for PermutedSequences {
    fn len(&self) -> usize {
        self.cfg.samples
    }

    fn feature_dim(&self) -> usize {
        self.cfg.timesteps
    }

    fn num_classes(&self) -> usize {
        self.cfg.num_classes
    }

    fn label(&self, i: usize) -> i32 {
        let mut rng = self.sample_rng(i);
        rng.below(self.cfg.num_classes) as i32
    }

    fn tier(&self, i: usize) -> Option<Tier> {
        let mut rng = self.sample_rng(i);
        let _ = rng.below(self.cfg.num_classes);
        let u = rng.uniform();
        Some(if u < self.cfg.easy_frac {
            Tier::Easy
        } else if u < self.cfg.easy_frac + self.cfg.boundary_frac {
            Tier::Boundary
        } else {
            Tier::Outlier
        })
    }

    fn write_features(&self, i: usize, _epoch: u64, out: &mut [f32]) {
        let t = self.cfg.timesteps;
        debug_assert_eq!(out.len(), t);
        let mut rng = self.sample_rng(i);
        let class = rng.below(self.cfg.num_classes);
        let u = rng.uniform();
        let noise = if u < self.cfg.easy_frac {
            0.1
        } else if u < self.cfg.easy_frac + self.cfg.boundary_frac {
            0.45
        } else {
            0.9
        };
        let mut raster = vec![0.0f32; t];
        self.raster(class, &mut rng, noise, &mut raster);
        // the fixed global permutation
        for (k, o) in out.iter_mut().enumerate() {
            *o = raster[self.perm[k]];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_permutation_shared() {
        let s = PermutedSequences::builder(64, 10).samples(100).seed(1).split();
        let mut a = vec![0.0; 64];
        let mut b = vec![0.0; 64];
        s.train.write_features(5, 0, &mut a);
        s.train.write_features(5, 9, &mut b);
        assert_eq!(a, b); // epoch-independent
        assert_eq!(s.train.perm, s.test.perm); // paper: one fixed permutation
    }

    #[test]
    fn permutation_is_nontrivial() {
        let ds = PermutedSequences::builder(64, 10).samples(10).seed(1).build();
        assert_ne!(ds.perm, (0..64).collect::<Vec<_>>());
        let mut sorted = ds.perm.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn class_signal_is_separable() {
        // nearest-centroid on rasters should beat chance comfortably
        let ds = PermutedSequences::builder(64, 4).samples(400).seed(2).build();
        let mut centroids = vec![vec![0.0f64; 64]; 4];
        let mut counts = [0usize; 4];
        let mut buf = vec![0.0f32; 64];
        for i in 0..200 {
            ds.write_features(i, 0, &mut buf);
            let c = ds.label(i) as usize;
            counts[c] += 1;
            for (j, &v) in buf.iter().enumerate() {
                centroids[c][j] += v as f64;
            }
        }
        for (c, cent) in centroids.iter_mut().enumerate() {
            for v in cent.iter_mut() {
                *v /= counts[c].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 200..400 {
            ds.write_features(i, 0, &mut buf);
            let best = (0..4)
                .min_by(|&a, &b| {
                    let da: f64 = buf
                        .iter()
                        .zip(&centroids[a])
                        .map(|(&x, &m)| (x as f64 - m).powi(2))
                        .sum();
                    let db: f64 = buf
                        .iter()
                        .zip(&centroids[b])
                        .map(|(&x, &m)| (x as f64 - m).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == ds.label(i) as usize {
                correct += 1;
            }
        }
        assert!(correct > 120, "nearest centroid only got {correct}/200");
    }
}

//! Dataset substrate.
//!
//! The paper evaluates on CIFAR-10/100 (+1.5M pre-augmented images), MIT67
//! fine-tuning features and pixel-by-pixel permuted MNIST. None of those
//! files exist in this environment, so this module implements synthetic
//! equivalents that preserve the property importance sampling exploits:
//! **heavy-tailed per-sample difficulty** (most samples become "properly
//! handled" early; a minority keeps producing large gradients). See
//! DESIGN.md §2 for the substitution argument.
//!
//! All generators are deterministic functions of `(seed, index)` — datasets
//! are *virtual* (nothing is materialized), which is also how the paper's
//! method works "on infinite datasets in a true online fashion" (§4.2).
//! The [`shard`] module is the out-of-core complement: it materializes any
//! generator once into a directory of binary shards and streams it back
//! through the same [`Dataset`] trait with a bounded resident set.

pub mod augment;
pub mod finetune;
pub mod sequence;
pub mod shard;
pub mod synthetic;

use anyhow::Result;

use crate::runtime::HostTensor;

/// Difficulty tier assigned to each sample by the generators. The tier mix
/// is what gives the score distribution its heavy tail.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Clean prototype + small noise: learned in the first epochs.
    Easy,
    /// Mixture of two class prototypes: lives near the decision boundary.
    Boundary,
    /// Heavy noise / partially corrupted: keeps large gradients for long.
    Outlier,
}

/// A deterministic, index-addressable supervised dataset.
pub trait Dataset {
    /// Number of samples.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Per-sample feature width (must match the model's `feature_dim`).
    fn feature_dim(&self) -> usize;
    fn num_classes(&self) -> usize;
    /// Label of sample `i`.
    fn label(&self, i: usize) -> i32;
    /// Write the features of sample `i` into `out` (len = feature_dim).
    /// `epoch` keys the deterministic augmentation stream (0 = none).
    fn write_features(&self, i: usize, epoch: u64, out: &mut [f32]);

    /// Difficulty tier, when the generator knows it (analysis only — the
    /// training pipeline never peeks).
    fn tier(&self, _i: usize) -> Option<Tier> {
        None
    }

    /// Assemble a batch for an index set.
    fn batch(&self, indices: &[usize], epoch: u64) -> (HostTensor, Vec<i32>) {
        let d = self.feature_dim();
        let mut x = HostTensor::zeros(vec![indices.len(), d]);
        let mut y = Vec::with_capacity(indices.len());
        for (row, &i) in indices.iter().enumerate() {
            self.write_features(i, epoch, &mut x.data[row * d..(row + 1) * d]);
            y.push(self.label(i));
        }
        (x, y)
    }

    /// Fallible batch assembly. In-memory generators cannot fail, so the
    /// default wraps [`batch`](Self::batch); out-of-core stores (the
    /// [`shard`] module) override it to surface IO failures — a shard file
    /// truncated *after* open-time validation — as descriptive errors
    /// instead of panics.
    fn try_batch(&self, indices: &[usize], epoch: u64) -> Result<(HostTensor, Vec<i32>)> {
        Ok(self.batch(indices, epoch))
    }
}

/// Train/test pair produced by every generator.
pub struct Split<D> {
    pub train: D,
    pub test: D,
}

#[cfg(test)]
mod tests {
    use super::synthetic::SyntheticImages;
    use super::*;

    #[test]
    fn batch_assembly_shapes() {
        let ds = SyntheticImages::builder(32, 4).samples(100).seed(3).build();
        let (x, y) = ds.batch(&[0, 5, 99], 0);
        assert_eq!(x.shape, vec![3, 32]);
        assert_eq!(y.len(), 3);
        assert!(y.iter().all(|&c| (0..4).contains(&c)));
    }
}

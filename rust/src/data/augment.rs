//! Deterministic augmentation stream.
//!
//! The paper pre-augments CIFAR into 1.5M images so that history-based
//! baselines (which key off a fixed sample identity) remain applicable.
//! Our generators instead key a small jitter off `(seed, index, epoch)`:
//! the same sample re-visited in a later epoch is a *slightly different*
//! view — exactly what random crops/flips do — while staying fully
//! deterministic and storage-free.

use crate::util::rng::SplitMix64;

/// Magnitude of the per-epoch jitter relative to the feature scale.
pub const JITTER_STD: f64 = 0.08;

/// Jitter hits one feature in `JITTER_STRIDE` per view (§Perf: additive
/// noise on a strided subset gives the same decorrelation-across-epochs
/// effect at a quarter of the RNG cost; the stride *offset* varies per
/// view so all features get perturbed across epochs).
pub const JITTER_STRIDE: usize = 4;

/// Fraction of features randomly zeroed per view (cutout-like).
pub const DROP_FRAC: f64 = 0.05;

/// Apply the epoch-keyed jitter in place.
pub fn jitter(seed: u64, sample_key: u64, epoch: u64, features: &mut [f32]) {
    let mut rng = SplitMix64::new(
        seed ^ 0xA46_0000 ^ sample_key.rotate_left(17) ^ epoch.wrapping_mul(0x9E37_79B9),
    );
    let d = features.len();
    // additive Gaussian jitter on a strided subset (offset varies per view)
    let offset = rng.below(JITTER_STRIDE);
    let mut k = offset;
    while k < d {
        let (a, b) = rng.fast_normal_pair();
        features[k] += (a * JITTER_STD) as f32;
        let k2 = k + JITTER_STRIDE;
        if k2 < d {
            features[k2] += (b * JITTER_STD) as f32;
        }
        k += 2 * JITTER_STRIDE;
    }
    // cutout: zero a contiguous run of DROP_FRAC features
    let run = ((d as f64 * DROP_FRAC) as usize).max(1);
    let start = rng.below(d.saturating_sub(run).max(1));
    for v in features.iter_mut().skip(start).take(run) {
        *v = 0.0;
    }
    // horizontal-flip stand-in: reverse with probability 1/2
    if rng.next_u64() & 1 == 1 {
        features.reverse();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_key() {
        let mut a = vec![1.0f32; 32];
        let mut b = vec![1.0f32; 32];
        jitter(1, 2, 3, &mut a);
        jitter(1, 2, 3, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn varies_across_epochs_and_samples() {
        let base = vec![1.0f32; 32];
        let mut e1 = base.clone();
        let mut e2 = base.clone();
        let mut s2 = base.clone();
        jitter(1, 2, 1, &mut e1);
        jitter(1, 2, 2, &mut e2);
        jitter(1, 3, 1, &mut s2);
        assert_ne!(e1, e2);
        assert_ne!(e1, s2);
    }

    #[test]
    fn cutout_zeroes_a_run() {
        let mut v = vec![10.0f32; 100];
        jitter(9, 9, 9, &mut v);
        let zeros = v.iter().filter(|&&x| x == 0.0).count();
        assert!(zeros >= 5, "expected a cutout run, got {zeros} zeros");
    }

    #[test]
    fn perturbation_is_bounded() {
        let mut v = vec![0.0f32; 64];
        jitter(4, 4, 4, &mut v);
        // all non-cutout values within ~6 sigma
        assert!(v.iter().all(|&x| x.abs() < (6.0 * JITTER_STD) as f32 + 1e-6));
    }

    #[test]
    fn all_features_perturbed_across_epochs() {
        // the stride offset rotates, so over many epochs every position
        // must see noise at some point
        let mut touched = vec![false; 32];
        for epoch in 1..50 {
            let mut v = vec![0.0f32; 32];
            jitter(9, 1, epoch, &mut v);
            for (t, &x) in touched.iter_mut().zip(&v) {
                if x != 0.0 {
                    *t = true;
                }
            }
        }
        assert!(touched.iter().all(|&t| t), "{touched:?}");
    }
}

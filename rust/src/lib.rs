//! # isample — Deep Learning with Importance Sampling
//!
//! A full-system reproduction of *"Not All Samples Are Created Equal: Deep
//! Learning with Importance Sampling"* (Katharopoulos & Fleuret, ICML 2018)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`) — Pallas kernel fusing the
//!   per-sample loss with the Eq.-20 gradient-norm upper bound.
//! * **L2** (`python/compile/model.py`) — JAX models + training/scoring
//!   entry points, AOT-lowered to HLO text by `make artifacts`.
//! * **L3** (this crate) — the paper's *system* contribution: the
//!   importance-sampling data pipeline (Algorithm 1), the variance-reduction
//!   estimator τ (Eq. 26), baselines, analyses and benchmarks, all running
//!   over the PJRT CPU client with Python never on the hot path.

pub mod analysis;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod figures;
pub mod runtime;
pub mod util;

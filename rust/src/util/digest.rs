//! Tiny order-sensitive digests (FNV-1a over 64-bit words) for pinning
//! bit-exact trajectories and states — the golden artifacts of the
//! determinism contract (`--score-workers` / `--train-workers` must never
//! change a result). No hashing crates exist offline, so the repo carries
//! the 15-line classic. Not cryptographic; collision resistance is
//! irrelevant here — a digest only ever compares two runs of the same
//! shape, where any divergence flips bits long before it finds an FNV
//! collision.

/// FNV-1a offset basis (the digest of an empty stream).
pub const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;

/// FNV-1a over a stream of 64-bit words (little-endian byte order).
pub fn fnv1a64(words: impl IntoIterator<Item = u64>) -> u64 {
    fnv1a64_from(FNV_OFFSET, words)
}

/// Continue an FNV-1a digest from a prior state — streaming form, so
/// composite structures can be hashed part by part without materializing
/// one big word buffer: `fnv1a64_from(fnv1a64(a), b) == fnv1a64(a ++ b)`.
pub fn fnv1a64_from(state: u64, words: impl IntoIterator<Item = u64>) -> u64 {
    let mut h = state;
    for w in words {
        for b in w.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// Digest of an f32 slice by bit pattern, order-sensitive — equal digests
/// ⇔ bitwise-equal vectors (up to FNV collisions).
pub fn digest_f32(vals: &[f32]) -> u64 {
    fnv1a64(vals.iter().map(|v| v.to_bits() as u64))
}

/// Digest of an f64 stream by bit pattern, order-sensitive (loss
/// trajectories are logged as f64).
pub fn digest_f64(vals: impl IntoIterator<Item = f64>) -> u64 {
    fnv1a64(vals.into_iter().map(f64::to_bits))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_stream_is_the_offset_basis() {
        assert_eq!(fnv1a64([]), FNV_OFFSET);
        assert_eq!(digest_f32(&[]), FNV_OFFSET);
    }

    #[test]
    fn digests_are_order_and_value_sensitive() {
        let a = digest_f32(&[1.0, 2.0, 3.0]);
        assert_eq!(a, digest_f32(&[1.0, 2.0, 3.0]));
        assert_ne!(a, digest_f32(&[1.0, 3.0, 2.0]));
        assert_ne!(a, digest_f32(&[1.0, 2.0]));
        assert_ne!(a, digest_f32(&[1.0, 2.0, 3.0000002]));
    }

    #[test]
    fn streaming_form_composes() {
        let all = fnv1a64([1, 2, 3, 4]);
        assert_eq!(fnv1a64_from(fnv1a64([1, 2]), [3, 4]), all);
        assert_eq!(fnv1a64_from(fnv1a64_from(fnv1a64([1]), [2, 3]), [4]), all);
    }

    #[test]
    fn f32_digest_distinguishes_signed_zero_and_f64_matches_bits() {
        // bitwise, not value, comparison: -0.0 != 0.0 here by design
        assert_ne!(digest_f32(&[0.0]), digest_f32(&[-0.0]));
        assert_eq!(digest_f64([1.5]), fnv1a64([1.5f64.to_bits()]));
    }
}

//! Minimal JSON parser for `artifacts/manifest.json`.
//!
//! The build environment has no network access and no `serde_json` in the
//! vendored crate set, so this repository carries its own small, strict
//! recursive-descent parser. It supports the full JSON grammar (objects,
//! arrays, strings with escapes, numbers incl. exponents, bool, null) which
//! is all the AOT manifest needs.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that errors with the missing key name.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError { pos: 0, msg: format!("missing key {key:?}") })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn usize_array(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn f64_array(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte {:?}", c as char))),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("eof in string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("eof in escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs are not needed by the manifest;
                            // map unpaired surrogates to U+FFFD.
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence starting at c.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "version": 1, "format": "hlo-text",
          "models": {"mlp10": {"batch": 128, "presample": [384, 640, 1024],
            "params": [{"name": "w0", "shape": [64, 128], "init": "glorot_uniform"}],
            "selfcheck": {"mean_loss": 2.3127534389, "loss_head": [2.1, -0.5e-3]}}}
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        let m = v.get("models").unwrap().get("mlp10").unwrap();
        assert_eq!(m.get("presample").unwrap().usize_array().unwrap(), vec![384, 640, 1024]);
        let p0 = &m.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p0.get("name").unwrap().as_str(), Some("w0"));
        let sc = m.get("selfcheck").unwrap();
        assert!((sc.get("mean_loss").unwrap().as_f64().unwrap() - 2.3127534389).abs() < 1e-12);
        assert_eq!(sc.get("loss_head").unwrap().f64_array().unwrap()[1], -0.5e-3);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\n\t\"\\ A é""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\ A é"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn numbers() {
        for (s, x) in [("0", 0.0), ("-12.5", -12.5), ("1e3", 1000.0), ("2.5E-2", 0.025)] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(x));
        }
    }

    #[test]
    fn nested_arrays() {
        let v = Json::parse("[[1,2],[3],[],[null,true,false]]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a.len(), 4);
        assert_eq!(a[0].usize_array().unwrap(), vec![1, 2]);
        assert_eq!(a[3].as_arr().unwrap()[1], Json::Bool(true));
    }
}

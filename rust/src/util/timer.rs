//! Wall-clock timing: stopwatches, per-phase accumulators and the fixed
//! time-budget used by the paper's evaluation protocol (§4.2: "we use a
//! learning-rate schedule based on wall-clock time and fix the total seconds
//! available for training").
//!
//! This is one of the two sanctioned wall-clock modules (with
//! `util::bench`): the detlint `wallclock-in-logic` rule and the
//! `clippy.toml` disallowed-methods list both point here, so raw
//! `Instant::now()` / `SystemTime::now()` reads are allowed.
#![allow(clippy::disallowed_methods)]

use std::time::{Duration, Instant};

/// A restartable stopwatch.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Accumulates time across named phases of the training pipeline
/// (score / resample / step / eval / data). Used by the §Perf profile and
/// the pipeline-busyness metric.
#[derive(Debug, Default, Clone)]
pub struct PhaseTimers {
    phases: Vec<(String, Duration, u64)>,
}

impl PhaseTimers {
    pub fn record(&mut self, phase: &str, d: Duration) {
        if let Some(e) = self.phases.iter_mut().find(|(n, _, _)| n == phase) {
            e.1 += d;
            e.2 += 1;
        } else {
            self.phases.push((phase.to_string(), d, 1));
        }
    }

    pub fn time<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(phase, t0.elapsed());
        out
    }

    pub fn total(&self, phase: &str) -> Duration {
        self.phases
            .iter()
            .find(|(n, _, _)| n == phase)
            .map(|(_, d, _)| *d)
            .unwrap_or_default()
    }

    pub fn count(&self, phase: &str) -> u64 {
        self.phases.iter().find(|(n, _, _)| n == phase).map(|(_, _, c)| *c).unwrap_or(0)
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        let grand: f64 = self.phases.iter().map(|(_, d, _)| d.as_secs_f64()).sum();
        for (name, d, c) in &self.phases {
            let s = d.as_secs_f64();
            out.push_str(&format!(
                "{name:>12}: {s:>9.3}s  ({c:>7} calls, {:>9.1}us/call, {:>5.1}%)\n",
                s * 1e6 / (*c).max(1) as f64,
                100.0 * s / grand.max(1e-12),
            ));
        }
        out
    }

    pub fn phases(&self) -> &[(String, Duration, u64)] {
        &self.phases
    }
}

/// The paper's protocol: a fixed wall-clock budget; schedules key off
/// elapsed seconds rather than step counts.
#[derive(Debug, Clone, Copy)]
pub struct TimeBudget {
    sw: Stopwatch,
    budget: Duration,
}

impl TimeBudget {
    pub fn new(budget: Duration) -> Self {
        Self { sw: Stopwatch::new(), budget }
    }

    pub fn from_secs(secs: f64) -> Self {
        Self::new(Duration::from_secs_f64(secs))
    }

    pub fn exhausted(&self) -> bool {
        self.sw.elapsed() >= self.budget
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.sw.elapsed_secs()
    }

    /// Fraction of the budget consumed, in [0, 1].
    pub fn progress(&self) -> f64 {
        (self.sw.elapsed_secs() / self.budget.as_secs_f64()).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_timers_accumulate() {
        let mut t = PhaseTimers::default();
        t.record("step", Duration::from_millis(5));
        t.record("step", Duration::from_millis(7));
        t.record("score", Duration::from_millis(1));
        assert_eq!(t.total("step"), Duration::from_millis(12));
        assert_eq!(t.count("step"), 2);
        assert_eq!(t.count("nope"), 0);
        assert!(t.report().contains("step"));
    }

    #[test]
    fn budget_progress() {
        let b = TimeBudget::from_secs(1000.0);
        assert!(!b.exhausted());
        assert!(b.progress() < 0.01);
    }

    #[test]
    fn timed_closure_runs() {
        let mut t = PhaseTimers::default();
        let v = t.time("work", || 21 * 2);
        assert_eq!(v, 42);
        assert_eq!(t.count("work"), 1);
    }
}

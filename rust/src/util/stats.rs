//! Small statistics helpers used by the tau estimator, the Fig-1/Fig-2
//! analyses and the metrics pipeline. All f64 accumulation for stability.

/// Arithmetic mean; 0 for empty input.
pub fn mean(v: &[f32]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64
}

/// Population variance; 0 for empty input.
pub fn variance(v: &[f32]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let m = mean(v);
    v.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / v.len() as f64
}

/// L2 norm of a vector.
pub fn l2_norm(v: &[f32]) -> f64 {
    v.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt()
}

/// L2 distance between two equal-length vectors.
pub fn l2_dist(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x as f64 - y as f64).powi(2))
        .sum::<f64>()
        .sqrt()
}

/// Pearson correlation coefficient; NaN-free (returns 0 when degenerate).
pub fn pearson(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let (ma, mb) = (mean(a), mean(b));
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for (&x, &y) in a.iter().zip(b) {
        let (dx, dy) = (x as f64 - ma, y as f64 - mb);
        num += dx * dy;
        da += dx * dx;
        db += dy * dy;
    }
    if da == 0.0 || db == 0.0 {
        return 0.0;
    }
    num / (da * db).sqrt()
}

/// Ranks of the values (ties broken by index, like `np.argsort` twice).
pub fn ranks(v: &[f32]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..v.len()).collect();
    idx.sort_by(|&i, &j| v[i].partial_cmp(&v[j]).unwrap_or(std::cmp::Ordering::Equal));
    let mut out = vec![0.0; v.len()];
    for (rank, &i) in idx.iter().enumerate() {
        out[i] = rank as f64;
    }
    out
}

/// Spearman rank correlation.
pub fn spearman(a: &[f32], b: &[f32]) -> f64 {
    let ra: Vec<f32> = ranks(a).into_iter().map(|x| x as f32).collect();
    let rb: Vec<f32> = ranks(b).into_iter().map(|x| x as f32).collect();
    pearson(&ra, &rb)
}

/// Sum of squared errors between two vectors.
pub fn sse(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| (x as f64 - y as f64).powi(2)).sum()
}

/// Normalize a non-negative vector into a probability distribution.
/// All-zero input maps to the uniform distribution (paper: early training
/// has ~equal scores; uniform is the correct degenerate limit).
pub fn normalize_probs(scores: &[f32]) -> Vec<f32> {
    let sum: f64 = scores.iter().map(|&s| s.max(0.0) as f64).sum();
    let n = scores.len();
    if sum <= 0.0 || !sum.is_finite() {
        return vec![1.0 / n as f32; n];
    }
    scores.iter().map(|&s| (s.max(0.0) as f64 / sum) as f32).collect()
}

/// Exponential moving average helper.
#[derive(Debug, Clone, Copy)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    /// `alpha` is the *retention* factor: v <- alpha * v + (1-alpha) * x.
    pub fn new(alpha: f64) -> Self {
        Self { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => self.alpha * v + (1.0 - self.alpha) * x,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let v = [1.0f32, 2.0, 3.0, 4.0];
        assert!((mean(&v) - 2.5).abs() < 1e-12);
        assert!((variance(&v) - 1.25).abs() < 1e-12);
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert!((l2_dist(&[1.0, 1.0], &[4.0, 5.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_and_inverse() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [2.0f32, 4.0, 6.0, 8.0];
        let c = [8.0f32, 6.0, 4.0, 2.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-12);
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&a, &[1.0, 1.0, 1.0, 1.0]), 0.0);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        let b = [1.0f32, 8.0, 27.0, 64.0, 125.0]; // x^3: nonlinear, same order
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn normalize_handles_zeros_and_sums_to_one() {
        let p = normalize_probs(&[0.0, 0.0, 0.0]);
        assert_eq!(p, vec![1.0 / 3.0; 3]);
        let q = normalize_probs(&[1.0, 3.0]);
        assert!((q[0] - 0.25).abs() < 1e-7 && (q[1] - 0.75).abs() < 1e-7);
        // detlint: allow(unordered-float-reduction) — test tolerance 1e-6 absorbs order
        let s: f32 = normalize_probs(&[0.3, 0.1, 2.7, 0.0]).iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.9);
        assert_eq!(e.update(10.0), 10.0); // first sample initializes
        for _ in 0..200 {
            e.update(2.0);
        }
        assert!((e.get().unwrap() - 2.0).abs() < 1e-6);
    }
}

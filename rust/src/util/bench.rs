//! A small benchmarking kit (the vendored crate set has no `criterion`).
//!
//! `cargo bench` targets in `rust/benches/` are plain `harness = false`
//! binaries built on this module: auto-calibrated iteration counts, warmup,
//! mean/min/p50/p95 per-iteration timings, and a one-line criterion-style
//! report. Used both by the per-figure end-to-end benches and the §Perf
//! micro benches.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>12}/iter  (min {}, p50 {}, p95 {}, {} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.min_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            self.iters
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Benchmark a closure: warm up, auto-calibrate the iteration count to hit
/// `target` total time, then time each iteration individually.
pub fn bench<F: FnMut()>(name: &str, target: Duration, mut f: F) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let iters = (target.as_secs_f64() / once.as_secs_f64()).clamp(3.0, 10_000.0) as u64;

    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(f64::total_cmp);
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let pct = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        min_ns: samples[0],
        p50_ns: pct(0.5),
        p95_ns: pct(0.95),
    };
    println!("{}", r.report());
    r
}

/// Convenience: bench with the default 2-second target.
pub fn bench_default<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench(name, Duration::from_secs(2), f)
}

/// Guard against the optimizer deleting the benchmarked work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("spin", Duration::from_millis(50), || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(r.iters >= 3);
        assert!(r.min_ns <= r.mean_ns);
        assert!(r.p50_ns <= r.p95_ns);
        assert!(r.mean_ns > 0.0);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50us");
        assert_eq!(fmt_ns(2.5e6), "2.50ms");
        assert_eq!(fmt_ns(3.2e9), "3.200s");
    }
}

//! A small benchmarking kit (the vendored crate set has no `criterion`).
//!
//! `cargo bench` targets in `rust/benches/` are plain `harness = false`
//! binaries built on this module: auto-calibrated iteration counts, warmup,
//! mean/min/p50/p95 per-iteration timings, and a one-line criterion-style
//! report. Used both by the per-figure end-to-end benches and the §Perf
//! micro benches.
//!
//! Sanctioned wall-clock module (see `util::timer`): raw `Instant::now()`
//! reads are allowed here by detlint and `clippy.toml`.
#![allow(clippy::disallowed_methods)]

use std::path::Path;
use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    /// Throughput in rows/second for a benchmark processing `rows` rows
    /// per iteration.
    pub fn rows_per_sec(&self, rows: usize) -> f64 {
        rows as f64 * 1e9 / self.mean_ns.max(1e-9)
    }

    fn json_object(&self) -> String {
        format!(
            "{{\"name\":{:?},\"iters\":{},\"mean_ns\":{},\"min_ns\":{},\"p50_ns\":{},\"p95_ns\":{}}}",
            self.name,
            self.iters,
            json_num(self.mean_ns),
            json_num(self.min_ns),
            json_num(self.p50_ns),
            json_num(self.p95_ns),
        )
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>12}/iter  (min {}, p50 {}, p95 {}, {} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.min_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            self.iters
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Benchmark a closure: warm up, auto-calibrate the iteration count to hit
/// `target` total time, then time each iteration individually.
pub fn bench<F: FnMut()>(name: &str, target: Duration, mut f: F) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let iters = (target.as_secs_f64() / once.as_secs_f64()).clamp(3.0, 10_000.0) as u64;

    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(f64::total_cmp);
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let pct = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        min_ns: samples[0],
        p50_ns: pct(0.5),
        p95_ns: pct(0.95),
    };
    println!("{}", r.report());
    r
}

/// Convenience: bench with the default 2-second target.
pub fn bench_default<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench(name, Duration::from_secs(2), f)
}

/// Per-bench time target with an environment cap: the
/// `ISAMPLE_BENCH_TARGET_MS` variable (CI's bench-smoke quick mode)
/// overrides `default_ms`; an explicit `--target-ms` flag should override
/// both (callers check the flag first).
pub fn target_from_env(default_ms: u64) -> Duration {
    let ms = std::env::var("ISAMPLE_BENCH_TARGET_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(default_ms);
    Duration::from_millis(ms.max(1))
}

/// Render an f64 as a JSON number (non-finite values become null).
fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.3}")
    } else {
        "null".to_string()
    }
}

/// Collects [`BenchResult`]s plus named scalar metrics and renders them as
/// a small JSON document — the `BENCH_*.json` files CI uploads so the perf
/// trajectory is visible per PR.
#[derive(Debug, Default)]
pub struct BenchSuite {
    results: Vec<BenchResult>,
    metrics: Vec<(String, f64)>,
}

impl BenchSuite {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, r: BenchResult) {
        self.results.push(r);
    }

    /// Record a derived scalar (throughput, speedup, ...).
    pub fn metric(&mut self, name: &str, value: f64) {
        self.metrics.push((name.to_string(), value));
    }

    pub fn is_empty(&self) -> bool {
        self.results.is_empty() && self.metrics.is_empty()
    }

    pub fn to_json(&self) -> String {
        let results: Vec<String> = self.results.iter().map(BenchResult::json_object).collect();
        let metrics: Vec<String> =
            self.metrics.iter().map(|(k, v)| format!("{k:?}:{}", json_num(*v))).collect();
        format!(
            "{{\n  \"results\": [\n    {}\n  ],\n  \"metrics\": {{{}}}\n}}\n",
            results.join(",\n    "),
            metrics.join(",")
        )
    }

    pub fn write_json(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

/// Guard against the optimizer deleting the benchmarked work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("spin", Duration::from_millis(50), || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(r.iters >= 3);
        assert!(r.min_ns <= r.mean_ns);
        assert!(r.p50_ns <= r.p95_ns);
        assert!(r.mean_ns > 0.0);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50us");
        assert_eq!(fmt_ns(2.5e6), "2.50ms");
        assert_eq!(fmt_ns(3.2e9), "3.200s");
    }

    #[test]
    fn suite_emits_valid_json() {
        let mut suite = BenchSuite::new();
        assert!(suite.is_empty());
        let r = BenchResult {
            name: "score/serial".into(),
            iters: 10,
            mean_ns: 2e6,
            min_ns: 1.5e6,
            p50_ns: 1.9e6,
            p95_ns: 3e6,
        };
        assert!((r.rows_per_sec(640) - 640.0 / 2e-3).abs() < 1e-6);
        suite.push(r);
        suite.metric("speedup_w4_vs_serial", 2.5);
        suite.metric("bad", f64::NAN);
        let text = suite.to_json();
        let v = crate::util::json::Json::parse(&text).expect("suite JSON must parse");
        let results = v.req("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].req("name").unwrap().as_str(), Some("score/serial"));
        assert_eq!(results[0].req("iters").unwrap().as_usize(), Some(10));
        let metrics = v.req("metrics").unwrap();
        assert_eq!(metrics.req("speedup_w4_vs_serial").unwrap().as_f64(), Some(2.5));
        assert!(metrics.req("bad").unwrap().as_f64().is_none()); // null
    }

    #[test]
    fn env_capped_target() {
        // no env set in tests: the default passes through
        assert_eq!(target_from_env(1500), Duration::from_millis(1500));
    }
}

//! Substrate utilities the vendored crate set does not provide:
//! a deterministic RNG shared bit-for-bit with the Python AOT step, a JSON
//! parser for the artifact manifest, statistics helpers, timers, and a tiny
//! property-testing kit used by the coordinator invariants.

pub mod bench;
pub mod bf16;
pub mod digest;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;

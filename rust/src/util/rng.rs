//! SplitMix64 — the cross-language deterministic RNG.
//!
//! Parameter initialization happens here in rust at run time (Python never
//! runs on the request path), but the AOT self-check baked into
//! `artifacts/manifest.json` was computed by Python. Both sides therefore
//! implement the *same* SplitMix64 stream; `python/compile/rng.py` is the
//! twin of this file and the manifest records the contract:
//!
//! ```text
//! state += 0x9E3779B97F4A7C15
//! z = state
//! z = (z ^ (z >> 30)) * 0xBF58476D1CE4E5B9
//! z = (z ^ (z >> 27)) * 0x94D049BB133111EB
//! z ^ (z >> 31)
//! ```
//!
//! `uniform()` maps the top 53 bits to f64 in [0, 1). Tensor `i` of a model
//! draws from the stream seeded `seed + i * GOLDEN`; draws are row-major.

pub const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// Exact-u64 SplitMix64, bit-identical to `python/compile/rng.py`.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// The per-tensor stream: independent, order-insensitive across tensors.
    pub fn tensor_stream(seed: u64, tensor_index: u64) -> Self {
        Self::new(seed.wrapping_add(tensor_index.wrapping_mul(GOLDEN)))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GOLDEN);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// f64 in [0, 1): top 53 bits / 2^53 (same expression as python).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Integer in [0, n) by rejection-free modulo of a 53-bit draw.
    /// Bias is < 2^-40 for n < 2^13 — irrelevant for dataset indices.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.uniform() * n as f64) as usize).min(n - 1)
    }

    /// Standard normal via Box-Muller. NOTE: this consumes draws in the same
    /// order as python's `init_tensor` for `scaled_normal` only when used
    /// through [`crate::runtime::init`]; general sampling may buffer.
    pub fn normal_pair(&mut self) -> (f64, f64) {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        (r * theta.cos(), r * theta.sin())
    }

    /// Standard normal pair via Marsaglia's polar method — no sin/cos, ~1.27
    /// uniform pairs per output pair. **Not** draw-compatible with
    /// [`normal_pair`]; use only where no cross-language contract applies
    /// (dataset generation, augmentation). ~1.8x faster than Box-Muller on
    /// this CPU (see EXPERIMENTS.md §Perf).
    #[inline]
    pub fn fast_normal_pair(&mut self) -> (f64, f64) {
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                return (u * f, v * f);
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// `k` indices sampled uniformly *without* replacement from [0, n).
    /// Partial Fisher–Yates over an index vector; O(n) alloc, O(k) swaps.
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors_seed_zero() {
        // Same canonical vectors pinned by python/tests/test_aot.py.
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(r.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = SplitMix64::new(1234);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_covers_range() {
        let mut r = SplitMix64::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(9);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn swr_unique_and_in_range() {
        let mut r = SplitMix64::new(11);
        let s = r.sample_without_replacement(1000, 128);
        assert_eq!(s.len(), 128);
        let mut u = s.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 128);
        assert!(s.iter().all(|&i| i < 1000));
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(5);
        let mut vals = vec![];
        for _ in 0..20_000 {
            let (a, b) = r.normal_pair();
            vals.push(a);
            vals.push(b);
        }
        let n = vals.len() as f64;
        let mean = vals.iter().sum::<f64>() / n;
        let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}

//! Brain-float-16 storage conversions for the reduced-precision scoring
//! fast path.
//!
//! bf16 is the upper 16 bits of an IEEE-754 binary32 (1 sign, 8 exponent,
//! 7 mantissa bits): widening is an exact bit extension (`<< 16`, no
//! rounding) and narrowing rounds the dropped mantissa bits to nearest,
//! ties to even. Both directions are pure integer bit manipulation — no
//! architecture support needed, deterministic on every target.
//!
//! Consumed by `runtime::kernels::gemm_acc_bf16` & co: model parameters
//! are stored as `u16` bit patterns, widened on the fly inside the tile,
//! and accumulated in f32. Scoring through bf16 storage is NOT
//! bit-comparable to the f32 path (the storage rounding perturbs every
//! weight); the contract is score *ranking* fidelity, pinned by the
//! `bf16_` acceptance tests in `rust/tests/native_train.rs`.

/// Round an f32 to its nearest bf16 bit pattern (ties to even).
///
/// The rounding increment `0x7FFF + lsb` implements round-to-nearest-even
/// on the truncated mantissa, and a carry propagates cleanly through the
/// exponent field, so values beyond the bf16 finite range saturate to
/// ±infinity exactly like a hardware narrow. NaN is special-cased first:
/// the rounding carry could turn a signaling-NaN payload into infinity,
/// so NaNs instead quieten (top mantissa bit set) and keep their sign.
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = 0x7FFF + ((bits >> 16) & 1);
    ((bits + round) >> 16) as u16
}

/// Widen a bf16 bit pattern to the f32 it denotes (exact, no rounding).
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Narrow-then-widen an f32 through bf16 storage — the weight value the
/// bf16 kernels actually multiply with.
pub fn bf16_round_trip(x: f32) -> f32 {
    bf16_to_f32(f32_to_bf16(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exactly_representable_values_round_trip_bitwise() {
        for &x in &[0.0f32, -0.0, 1.0, -1.0, 2.5, -2.5, 0.15625, 256.0, -1.0e30] {
            let rt = bf16_round_trip(x);
            // every value above has at most 7 mantissa bits set
            assert_eq!(rt.to_bits(), x.to_bits(), "{x} -> {rt}");
        }
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::INFINITY)), f32::INFINITY);
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::NEG_INFINITY)), f32::NEG_INFINITY);
    }

    #[test]
    fn narrowing_rounds_ties_to_even() {
        // 0x3F80_8000 sits exactly between bf16 0x3F80 and 0x3F81: the
        // kept mantissa lsb is 0, so the tie resolves DOWN (to even).
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_8000)), 0x3F80);
        // 0x3F81_8000 is the next tie; kept lsb is 1, so it resolves UP.
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F81_8000)), 0x3F82);
        // one ulp either side of a tie rounds to nearest, not to even
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_7FFF)), 0x3F80);
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_8001)), 0x3F81);
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F81_7FFF)), 0x3F81);
        // sign is carried through the same integer path
        assert_eq!(f32_to_bf16(f32::from_bits(0xBF80_8000)), 0xBF80);
    }

    #[test]
    fn subnormals_narrow_through_the_same_integer_path() {
        // the smallest f32 subnormal is far below the smallest bf16
        // subnormal -> rounds to +0.0
        assert_eq!(f32_to_bf16(f32::from_bits(0x0000_0001)), 0x0000);
        assert_eq!(f32_to_bf16(f32::from_bits(0x8000_0001)), 0x8000);
        // an f32 subnormal on the bf16 subnormal grid survives exactly
        let sub = f32::from_bits(0x0001_0000);
        assert!(sub != 0.0 && !sub.is_normal());
        assert_eq!(bf16_round_trip(sub).to_bits(), sub.to_bits());
        // f32::MIN_POSITIVE (smallest normal) is bf16-representable
        assert_eq!(bf16_round_trip(f32::MIN_POSITIVE), f32::MIN_POSITIVE);
    }

    #[test]
    fn nan_narrows_to_a_quiet_nan_never_to_infinity() {
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        // a signaling NaN whose payload lives entirely in the dropped
        // bits would carry into the exponent (-> infinity) without the
        // special case; it must stay NaN and keep its sign
        for bits in [0x7F80_0001u32, 0xFF80_0001] {
            let narrowed = f32_to_bf16(f32::from_bits(bits));
            let widened = bf16_to_f32(narrowed);
            assert!(widened.is_nan(), "{bits:#010x} -> {narrowed:#06x}");
            assert_eq!(widened.is_sign_negative(), bits >> 31 == 1);
            // quiet bit is set in the narrowed pattern
            assert_ne!(narrowed & 0x0040, 0);
        }
    }

    #[test]
    fn finite_overflow_saturates_to_infinity() {
        // f32::MAX is closer to 2^128 than to the largest finite bf16
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::MAX)), f32::INFINITY);
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::MIN)), f32::NEG_INFINITY);
        // the largest finite bf16 itself round-trips
        let max_bf16 = bf16_to_f32(0x7F7F);
        assert_eq!(f32_to_bf16(max_bf16), 0x7F7F);
    }

    #[test]
    fn narrowing_error_is_within_one_part_in_256() {
        // 8-bit mantissa (implicit bit + 7 stored) -> relative error
        // bounded by 2^-8 for normal values
        for i in 0..1000 {
            let x = (i as f32 - 500.0) * 0.731 + 0.017;
            let rt = bf16_round_trip(x);
            assert!((rt - x).abs() <= x.abs() / 256.0, "{x} -> {rt}");
        }
    }
}

//! A tiny property-based testing kit (the vendored crate set has no
//! `proptest`, so the repository carries its own).
//!
//! A property is a closure over a [`Gen`] (a seeded source of random
//! structured values). [`check`] runs it across many generated cases and, on
//! failure, reports the *seed* that reproduces the failing case so it can be
//! replayed deterministically:
//!
//! ```no_run
//! use isample::util::prop::{check, Gen};
//! check("sorting is idempotent", 200, |g: &mut Gen| {
//!     let mut v = g.vec_f32(0..100, -1e3..1e3);
//!     v.sort_by(f32::total_cmp);
//!     let w = { let mut w = v.clone(); w.sort_by(f32::total_cmp); w };
//!     assert_eq!(v, w);
//! });
//! ```

use super::rng::SplitMix64;
use std::ops::Range;

/// Seeded generator of random structured values for property tests.
pub struct Gen {
    pub rng: SplitMix64,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { rng: SplitMix64::new(seed), seed }
    }

    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        assert!(r.start < r.end);
        r.start + self.rng.below(r.end - r.start)
    }

    pub fn f32_in(&mut self, r: Range<f32>) -> f32 {
        self.rng.uniform_range(r.start as f64, r.end as f64) as f32
    }

    pub fn f64_in(&mut self, r: Range<f64>) -> f64 {
        self.rng.uniform_range(r.start, r.end)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector with length drawn from `len` and elements from `vals`.
    pub fn vec_f32(&mut self, len: Range<usize>, vals: Range<f32>) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(vals.clone())).collect()
    }

    /// Non-negative score vector — the common sampler-test input. With
    /// probability ~1/8 a heavy-tailed outlier is injected, and with
    /// probability ~1/8 a run of exact zeros (degenerate regimes matter).
    pub fn scores(&mut self, len: Range<usize>) -> Vec<f32> {
        let mut v = self.vec_f32(len, 0.0..1.0);
        if !v.is_empty() && self.rng.below(8) == 0 {
            let i = self.rng.below(v.len());
            v[i] = self.f32_in(10.0..1000.0);
        }
        if !v.is_empty() && self.rng.below(8) == 0 {
            let i = self.rng.below(v.len());
            for x in v.iter_mut().take(i) {
                *x = 0.0;
            }
        }
        v
    }
}

/// Run `prop` for `cases` generated cases. Panics (with the reproducing
/// seed) if any case panics.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    for case in 0..cases {
        // Decorrelate case seeds; fixed base keeps CI deterministic.
        let seed = 0x5EED_0000_0000_0000u64.wrapping_add(case.wrapping_mul(0x9E37_79B9));
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed);
            prop(&mut g);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property {name:?} failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay(seed: u64, prop: impl Fn(&mut Gen)) {
    let mut g = Gen::new(seed);
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_respect_ranges() {
        check("ranges", 100, |g| {
            let u = g.usize_in(3..17);
            assert!((3..17).contains(&u));
            let f = g.f32_in(-2.0..5.0);
            assert!((-2.0..5.0).contains(&f));
            let v = g.vec_f32(0..9, 0.0..1.0);
            assert!(v.len() < 9);
            assert!(v.iter().all(|&x| (0.0..1.0).contains(&x)));
        });
    }

    #[test]
    fn scores_are_nonnegative() {
        check("scores nonneg", 200, |g| {
            let s = g.scores(1..64);
            assert!(s.iter().all(|&x| x >= 0.0));
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports_seed() {
        check("always fails eventually", 50, |g| {
            // fails whenever the generated value is large
            assert!(g.usize_in(0..100) < 90);
        });
    }

    #[test]
    fn replay_is_deterministic() {
        let seen = std::cell::RefCell::new(None);
        for _ in 0..2 {
            replay(0xDEAD_BEEF, |g| {
                let v = g.vec_f32(5..6, 0.0..1.0);
                let mut s = seen.borrow_mut();
                if let Some(prev) = s.as_ref() {
                    assert_eq!(prev, &v);
                } else {
                    *s = Some(v);
                }
            });
        }
    }
}

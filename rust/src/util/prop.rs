//! A tiny property-based testing kit (the vendored crate set has no
//! `proptest`, so the repository carries its own).
//!
//! A property is a closure over a [`Gen`] (a seeded source of random
//! structured values). [`check`] runs it across many generated cases and, on
//! failure, reports the *seed* that reproduces the failing case so it can be
//! replayed deterministically:
//!
//! ```no_run
//! use isample::util::prop::{check, Gen};
//! check("sorting is idempotent", 200, |g: &mut Gen| {
//!     let mut v = g.vec_f32(0..100, -1e3..1e3);
//!     v.sort_by(f32::total_cmp);
//!     let w = { let mut w = v.clone(); w.sort_by(f32::total_cmp); w };
//!     assert_eq!(v, w);
//! });
//! ```
//!
//! # Replaying a failing case
//!
//! A failure panics with `... failed on case N (seed 0x…)`. Two ways to
//! re-run exactly that case:
//!
//! 1. **In code** — call [`replay`] with the reported seed and the same
//!    property body: `replay(0x5eed_0000_1234_abcd, |g| { ... })`. Replay
//!    is exact: [`Gen`] is a pure function of the seed.
//! 2. **From the shell** — set `ISAMPLE_PROP_SEED` to the reported seed
//!    (hex `0x…` or decimal) and re-run the test. Every [`check`] in the
//!    process then runs *only* that seed (once) instead of its sweep, so
//!    scope the variable to a single `cargo test <test_name>` invocation:
//!
//!    ```text
//!    ISAMPLE_PROP_SEED=0x5eed000012345678 cargo test -q prop_name
//!    ```

use super::rng::SplitMix64;
use std::ops::Range;

/// Seeded generator of random structured values for property tests.
pub struct Gen {
    pub rng: SplitMix64,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { rng: SplitMix64::new(seed), seed }
    }

    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        assert!(r.start < r.end);
        r.start + self.rng.below(r.end - r.start)
    }

    pub fn f32_in(&mut self, r: Range<f32>) -> f32 {
        self.rng.uniform_range(r.start as f64, r.end as f64) as f32
    }

    pub fn f64_in(&mut self, r: Range<f64>) -> f64 {
        self.rng.uniform_range(r.start, r.end)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector with length drawn from `len` and elements from `vals`.
    pub fn vec_f32(&mut self, len: Range<usize>, vals: Range<f32>) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(vals.clone())).collect()
    }

    /// Non-negative score vector — the common sampler-test input. With
    /// probability ~1/8 a heavy-tailed outlier is injected, and with
    /// probability ~1/8 a run of exact zeros (degenerate regimes matter).
    pub fn scores(&mut self, len: Range<usize>) -> Vec<f32> {
        let mut v = self.vec_f32(len, 0.0..1.0);
        if !v.is_empty() && self.rng.below(8) == 0 {
            let i = self.rng.below(v.len());
            v[i] = self.f32_in(10.0..1000.0);
        }
        if !v.is_empty() && self.rng.below(8) == 0 {
            let i = self.rng.below(v.len());
            for x in v.iter_mut().take(i) {
                *x = 0.0;
            }
        }
        v
    }

    /// Non-negative importance-weight vector normalized to mean 1 (the
    /// scale Eq.-2 weights arrive at), with the same degenerate-regime
    /// injection as [`scores`](Self::scores) — heavy outliers and runs of
    /// exact zeros — plus, ~1/16 of the time, an *all-zero* vector (a
    /// fully masked batch), in which case no normalization applies.
    pub fn weights(&mut self, len: Range<usize>) -> Vec<f32> {
        let mut v = self.scores(len);
        if !v.is_empty() && self.rng.below(16) == 0 {
            v.iter_mut().for_each(|x| *x = 0.0);
        }
        // detlint: allow(unordered-float-reduction) — sequential slice iter, order is fixed
        let sum: f32 = v.iter().sum();
        if sum > 0.0 {
            let scale = v.len() as f32 / sum;
            for x in v.iter_mut() {
                *x *= scale;
            }
        }
        v
    }
}

/// Run `prop` for `cases` generated cases. Panics (with the reproducing
/// seed) if any case panics. With `ISAMPLE_PROP_SEED` set, runs the
/// property once on exactly that seed instead of the sweep (see the
/// module docs on replaying failures).
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    if let Some(seed) = replay_seed_from_env() {
        eprintln!("property {name:?}: replaying seed {seed:#x} (ISAMPLE_PROP_SEED)");
        run_case(name, "replay", seed, &prop);
        return;
    }
    for case in 0..cases {
        run_case(name, &format!("case {case}"), case_seed(case), &prop);
    }
}

/// The sweep's seed schedule: decorrelated per-case seeds off a fixed
/// base, so CI stays deterministic.
fn case_seed(case: u64) -> u64 {
    0x5EED_0000_0000_0000u64.wrapping_add(case.wrapping_mul(0x9E37_79B9))
}

/// One property case under the panic wrapper that reports the reproducing
/// seed — shared by the sweep and the env-var replay path, so both fail
/// with the same `property ... (seed ...)` context.
fn run_case<F>(name: &str, what: &str, seed: u64, prop: &F)
where
    F: Fn(&mut Gen) + std::panic::RefUnwindSafe,
{
    let result = std::panic::catch_unwind(|| {
        let mut g = Gen::new(seed);
        prop(&mut g);
    });
    if let Err(payload) = result {
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_else(|| "<non-string panic>".into());
        panic!("property {name:?} failed on {what} (seed {seed:#x}): {msg}");
    }
}

/// Replay a single failing case by seed.
pub fn replay(seed: u64, prop: impl Fn(&mut Gen)) {
    let mut g = Gen::new(seed);
    prop(&mut g);
}

/// Parse a replay seed: hex with an `0x`/`0X` prefix or decimal, with
/// optional `_` separators.
fn parse_replay_seed(v: &str) -> Option<u64> {
    let v = v.trim().replace('_', "");
    if let Some(hex) = v.strip_prefix("0x").or_else(|| v.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        v.parse().ok()
    }
}

fn replay_seed_from_env() -> Option<u64> {
    let v = std::env::var("ISAMPLE_PROP_SEED").ok()?;
    match parse_replay_seed(&v) {
        Some(seed) => Some(seed),
        // an explicitly-set but unparseable seed must fail loudly — a
        // silent fall-through to the normal sweep would let a typo look
        // like a successful replay of the failing case
        None => panic!("ISAMPLE_PROP_SEED set but unparseable: {v:?} (hex 0x… or decimal)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_respect_ranges() {
        check("ranges", 100, |g| {
            let u = g.usize_in(3..17);
            assert!((3..17).contains(&u));
            let f = g.f32_in(-2.0..5.0);
            assert!((-2.0..5.0).contains(&f));
            let v = g.vec_f32(0..9, 0.0..1.0);
            assert!(v.len() < 9);
            assert!(v.iter().all(|&x| (0.0..1.0).contains(&x)));
        });
    }

    #[test]
    fn scores_are_nonnegative() {
        check("scores nonneg", 200, |g| {
            let s = g.scores(1..64);
            assert!(s.iter().all(|&x| x >= 0.0));
        });
    }

    #[test]
    fn weights_are_nonnegative_and_mean_one_unless_all_zero() {
        let mut saw_zero_vector = false;
        check("weights generator", 400, |g| {
            let w = g.weights(1..64);
            assert!(!w.is_empty());
            assert!(w.iter().all(|&x| x >= 0.0 && x.is_finite()));
            // detlint: allow(unordered-float-reduction) — test tolerance 1e-3 absorbs order
            let sum: f32 = w.iter().sum();
            if sum > 0.0 {
                let mean = sum / w.len() as f32;
                assert!((mean - 1.0).abs() < 1e-3, "weights mean {mean} != 1");
            }
        });
        // the all-zero degenerate regime must actually occur in a sweep
        // (same seed schedule check() itself walks)
        for case in 0..400u64 {
            let mut g = Gen::new(case_seed(case));
            if g.weights(1..64).iter().all(|&x| x == 0.0) {
                saw_zero_vector = true;
                break;
            }
        }
        assert!(saw_zero_vector, "zero-weight injection never fired in 400 cases");
    }

    #[test]
    fn replay_seed_parsing() {
        assert_eq!(parse_replay_seed("0x5eed"), Some(0x5EED));
        assert_eq!(parse_replay_seed("0X5EED_0000"), Some(0x5EED_0000));
        assert_eq!(parse_replay_seed(" 1234 "), Some(1234));
        assert_eq!(parse_replay_seed("12_34"), Some(1234));
        assert_eq!(parse_replay_seed("not a seed"), None);
        assert_eq!(parse_replay_seed(""), None);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports_seed() {
        check("always fails eventually", 50, |g| {
            // fails whenever the generated value is large
            assert!(g.usize_in(0..100) < 90);
        });
    }

    #[test]
    fn replay_is_deterministic() {
        let seen = std::cell::RefCell::new(None);
        for _ in 0..2 {
            replay(0xDEAD_BEEF, |g| {
                let v = g.vec_f32(5..6, 0.0..1.0);
                let mut s = seen.borrow_mut();
                if let Some(prev) = s.as_ref() {
                    assert_eq!(prev, &v);
                } else {
                    *s = Some(v);
                }
            });
        }
    }
}

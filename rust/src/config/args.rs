//! Minimal CLI argument parser (the vendored crate set has no `clap`).
//!
//! Grammar: `isample <command> [positional...] [--flag value | --flag]`.
//! Flags may appear anywhere after the command; `--flag` with no value is
//! recorded as `"true"`. When the first argument is itself a flag the
//! command is empty — that is how the bench binaries are invoked
//! (`cargo bench --bench perf_micro -- --filter score/`).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let command = if it.peek().is_some_and(|a| a.starts_with("--")) {
            String::new()
        } else {
            it.next().unwrap_or_default()
        };
        let mut positional = vec![];
        let mut flags = BTreeMap::new();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("empty flag name");
                }
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if let Some(v) = it.next_if(|n| !n.starts_with("--")) {
                    flags.insert(name.to_string(), v);
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                positional.push(a);
            }
        }
        Ok(Args { command, positional, flags })
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} must be a number, got {v:?}")),
        }
    }

    pub fn flag_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} must be an integer, got {v:?}")),
        }
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> Result<usize> {
        Ok(self.flag_u64(name, default as u64)? as usize)
    }

    pub fn flag_bool(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
    }

    /// `--score-workers N` — presample scoring worker threads. Defaults to
    /// one per available core (`runtime::score::default_score_workers`);
    /// 1 forces the serial path; 0 is rejected.
    pub fn flag_score_workers(&self) -> Result<usize> {
        let n = self.flag_usize("score-workers", crate::runtime::score::default_score_workers())?;
        if n == 0 {
            bail!("--score-workers must be >= 1 (got 0)");
        }
        Ok(n)
    }

    /// `--train-workers N` — data-parallel batch-compute threads for the
    /// training-side entries of backends that shard batches (native).
    /// Defaults to one per core
    /// (`runtime::pool::default_train_workers`). Any value is
    /// bit-identical to serial (fixed chunk plan + ordered merge);
    /// 1 forces the inline path; 0 is rejected.
    pub fn flag_train_workers(&self) -> Result<usize> {
        let n = self.flag_usize("train-workers", crate::runtime::pool::default_train_workers())?;
        if n == 0 {
            bail!("--train-workers must be >= 1 (got 0)");
        }
        Ok(n)
    }

    /// `--dist-workers N` — number of out-of-process worker processes for
    /// the distributed engine (`dist::DistEngine`). `0` (or unset) keeps
    /// everything in-process; any `N >= 1` spawns `N` copies of this binary
    /// in worker mode and farms chunk work out over localhost TCP. Every
    /// value — including mid-run worker loss — is bit-identical to serial
    /// (fixed chunk plan, ordered merge).
    pub fn flag_dist_workers(&self) -> Result<usize> {
        self.flag_usize("dist-workers", 0)
    }

    /// `--dist-timeout-ms MS` — per-chunk lease in milliseconds for the
    /// distributed coordinator: a worker that does not answer a heartbeat
    /// or a chunk within the lease is dropped and its chunk requeued (or
    /// computed in-process). 0 is rejected — a zero lease would drop every
    /// worker before it could answer.
    pub fn flag_dist_timeout_ms(&self) -> Result<u64> {
        let ms = self.flag_u64("dist-timeout-ms", 2_000)?;
        if ms == 0 {
            bail!("--dist-timeout-ms must be >= 1 (got 0)");
        }
        Ok(ms)
    }

    /// `--score-refresh-budget K|inf` — staleness budget (in steps) for
    /// the presample score cache (`coordinator::cache`). `inf` (or unset)
    /// means an unlimited refresh budget: every presampled row is
    /// re-scored every cycle, bit-identical to the uncached trainer. An
    /// integer `K` serves cached scores for up to `K` steps of age and
    /// re-scores only older rows (`0` is bitwise equivalent to `inf`).
    pub fn flag_score_refresh_budget(&self) -> Result<Option<u64>> {
        match self.flag("score-refresh-budget") {
            None => Ok(None),
            Some(v) if v.eq_ignore_ascii_case("inf") || v == "∞" => Ok(None),
            Some(v) => {
                let k = v.parse().with_context(|| {
                    format!("--score-refresh-budget must be an integer or `inf`, got {v:?}")
                })?;
                Ok(Some(k))
            }
        }
    }

    /// `--backend native|pjrt` — which execution substrate to run on.
    /// `native` is the artifact-free pure-rust engine; `pjrt` (the default)
    /// executes AOT artifacts.
    pub fn flag_backend(&self) -> Result<&str> {
        match self.flag("backend").unwrap_or("pjrt") {
            b @ ("native" | "pjrt") => Ok(b),
            other => bail!("--backend must be `native` or `pjrt`, got {other:?}"),
        }
    }

    /// `--sampler alias|cumulative|fenwick` — re-sampling backend for the
    /// presample strategies. `alias` (default): O(1)-draw Vose table
    /// rebuilt every cycle (the golden-pinned path); `cumulative` (or
    /// `cdf`): O(log B) binary-search CDF; `fenwick`: pool-sized tree
    /// with O(log n) partial updates and λ-mixture draws
    /// (`coordinator::resample`).
    pub fn flag_sampler(&self) -> Result<crate::coordinator::resample::SamplerKind> {
        use crate::coordinator::resample::SamplerKind;
        match self.flag("sampler") {
            None => Ok(SamplerKind::Alias),
            Some(v) => SamplerKind::parse(v).ok_or_else(|| {
                anyhow::anyhow!("--sampler must be `alias`, `cumulative` or `fenwick`, got {v:?}")
            }),
        }
    }

    /// `--score-precision f32|bf16` — numeric precision of the presample
    /// scoring pass. `f32` (default): scoring is bit-identical to the
    /// training forward (the golden-pinned path). `bf16`: parameters are
    /// walked in bf16 storage — cheaper scoring, same score *ranking* to
    /// within the pinned overlap threshold, NOT bit-comparable to f32.
    /// Training numerics are always f32 either way.
    pub fn flag_score_precision(&self) -> Result<crate::runtime::score::ScorePrecision> {
        use crate::runtime::score::ScorePrecision;
        match self.flag("score-precision") {
            None => Ok(ScorePrecision::F32),
            Some(v) => ScorePrecision::parse(v).ok_or_else(|| {
                anyhow::anyhow!("--score-precision must be `f32` or `bf16`, got {v:?}")
            }),
        }
    }

    /// Comma-separated u64 list (for `--seeds 1,2,3`).
    pub fn flag_u64_list(&self, name: &str, default: &[u64]) -> Result<Vec<u64>> {
        match self.flags.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse().with_context(|| format!("bad --{name} entry {s:?}")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn parses_command_positional_flags() {
        let a = args("train mlp10 --strategy upper-bound --budget 60 --quick");
        assert_eq!(a.command, "train");
        assert_eq!(a.positional, vec!["mlp10"]);
        assert_eq!(a.flag("strategy"), Some("upper-bound"));
        assert_eq!(a.flag_f64("budget", 0.0).unwrap(), 60.0);
        assert!(a.flag_bool("quick"));
        assert!(!a.flag_bool("missing"));
    }

    #[test]
    fn equals_form_and_lists() {
        let a = args("figure fig3 --seeds=1,2,3 --budget=5.5");
        assert_eq!(a.flag_u64_list("seeds", &[42]).unwrap(), vec![1, 2, 3]);
        assert_eq!(a.flag_f64("budget", 0.0).unwrap(), 5.5);
        assert_eq!(a.flag_u64_list("other", &[42]).unwrap(), vec![42]);
    }

    #[test]
    fn bad_number_is_error() {
        let a = args("x --budget abc");
        assert!(a.flag_f64("budget", 0.0).is_err());
    }

    #[test]
    fn defaults() {
        let a = args("bench");
        assert_eq!(a.flag_usize("presample", 640).unwrap(), 640);
        assert_eq!(a.flag_u64("steps", 100).unwrap(), 100);
    }

    #[test]
    fn leading_flag_means_no_command() {
        // bench binaries are invoked flags-first: nothing may be swallowed
        let a = args("--filter score/ --out-json BENCH_scoring.json --target-ms 10");
        assert_eq!(a.command, "");
        assert!(a.positional.is_empty());
        assert_eq!(a.flag("filter"), Some("score/"));
        assert_eq!(a.flag("out-json"), Some("BENCH_scoring.json"));
        assert_eq!(a.flag_u64("target-ms", 1500).unwrap(), 10);
    }

    #[test]
    fn backend_flag() {
        assert_eq!(args("train").flag_backend().unwrap(), "pjrt");
        assert_eq!(args("train --backend native").flag_backend().unwrap(), "native");
        assert_eq!(args("train --backend=pjrt").flag_backend().unwrap(), "pjrt");
        assert!(args("train --backend tpu").flag_backend().is_err());
    }

    #[test]
    fn score_workers_flag() {
        assert_eq!(args("train --score-workers 4").flag_score_workers().unwrap(), 4);
        assert_eq!(args("train --score-workers=1").flag_score_workers().unwrap(), 1);
        assert!(args("train").flag_score_workers().unwrap() >= 1);
        assert!(args("train --score-workers 0").flag_score_workers().is_err());
        assert!(args("train --score-workers lots").flag_score_workers().is_err());
    }

    #[test]
    fn score_refresh_budget_flag() {
        let budget = |cmd: &str| args(cmd).flag_score_refresh_budget();
        assert_eq!(budget("train").unwrap(), None);
        assert_eq!(budget("train --score-refresh-budget inf").unwrap(), None);
        assert_eq!(budget("train --score-refresh-budget=INF").unwrap(), None);
        assert_eq!(budget("train --score-refresh-budget ∞").unwrap(), None);
        assert_eq!(budget("train --score-refresh-budget 64").unwrap(), Some(64));
        assert_eq!(budget("train --score-refresh-budget=0").unwrap(), Some(0));
        assert!(budget("train --score-refresh-budget soon").is_err());
    }

    #[test]
    fn sampler_flag() {
        use crate::coordinator::resample::SamplerKind;
        // written with `matches!` (not unwrap) to honor the detlint
        // panic-in-library ratchet on this file
        assert!(matches!(args("train").flag_sampler(), Ok(SamplerKind::Alias)));
        assert!(matches!(args("train --sampler alias").flag_sampler(), Ok(SamplerKind::Alias)));
        assert!(matches!(
            args("train --sampler=cumulative").flag_sampler(),
            Ok(SamplerKind::Cumulative)
        ));
        assert!(matches!(args("train --sampler cdf").flag_sampler(), Ok(SamplerKind::Cumulative)));
        assert!(matches!(args("train --sampler fenwick").flag_sampler(), Ok(SamplerKind::Fenwick)));
        assert!(args("train --sampler vose").flag_sampler().is_err());
    }

    #[test]
    fn score_precision_flag() {
        use crate::runtime::score::ScorePrecision;
        // `matches!` (not unwrap) honors the detlint ratchet on this file
        assert!(matches!(args("train").flag_score_precision(), Ok(ScorePrecision::F32)));
        assert!(matches!(
            args("train --score-precision f32").flag_score_precision(),
            Ok(ScorePrecision::F32)
        ));
        assert!(matches!(
            args("train --score-precision=bf16").flag_score_precision(),
            Ok(ScorePrecision::Bf16)
        ));
        assert!(args("train --score-precision fp16").flag_score_precision().is_err());
    }

    #[test]
    fn dist_flags() -> Result<()> {
        // `?`/`matches!` (not unwrap) honor the detlint ratchet on this file
        assert_eq!(args("train").flag_dist_workers()?, 0);
        assert_eq!(args("train --dist-workers 4").flag_dist_workers()?, 4);
        assert!(args("train --dist-workers some").flag_dist_workers().is_err());
        assert_eq!(args("train").flag_dist_timeout_ms()?, 2_000);
        assert_eq!(args("train --dist-timeout-ms=250").flag_dist_timeout_ms()?, 250);
        assert!(args("train --dist-timeout-ms 0").flag_dist_timeout_ms().is_err());
        assert!(args("train --dist-timeout-ms never").flag_dist_timeout_ms().is_err());
        Ok(())
    }

    #[test]
    fn train_workers_flag() {
        assert_eq!(args("train --train-workers 4").flag_train_workers().unwrap(), 4);
        assert_eq!(args("train --train-workers=1").flag_train_workers().unwrap(), 1);
        assert!(args("train").flag_train_workers().unwrap() >= 1);
        assert!(args("train --train-workers 0").flag_train_workers().is_err());
        assert!(args("train --train-workers many").flag_train_workers().is_err());
    }
}

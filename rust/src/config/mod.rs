//! CLI + config system.
pub mod args;
pub use args::Args;

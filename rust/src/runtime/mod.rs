//! Layer-3 runtime: loads the AOT artifacts produced by `make artifacts`
//! (HLO text + manifest), compiles them on the PJRT CPU client via the
//! `xla` crate, and exposes typed entry points over host tensors.
//!
//! Flow (see /opt/xla-example/load_hlo for the reference wiring):
//! `PjRtClient::cpu()` -> `HloModuleProto::from_text_file` ->
//! `XlaComputation::from_proto` -> `client.compile` -> `execute`.
//!
//! [`score`] adds the sharded presample-scoring subsystem: a
//! [`ScoreBackend`] that fans `fwd_scores` / `grad_norms` chunks out to
//! scoped worker threads and merges them in deterministic presample order.
//!
//! [`pool`] adds the persistent [`WorkerPool`] behind `--train-workers`:
//! the native backend shards every batch-level entry over it using a
//! worker-count-independent chunk plan ([`train_chunk_plan`]) with a
//! fixed-order merge, so parallel training is bit-identical to serial.
//!
//! [`backend`] abstracts the execution substrate behind the [`Backend`]
//! trait so the whole coordinator stack runs over either the PJRT engine
//! or [`native::NativeEngine`] — the artifact-free pure-rust CPU backend.
//!
//! [`layers`] is the native backend's model IR: a [`LayerModel`] stack
//! (Dense / Relu / Conv1d / GlobalAvgPool / EmbeddingBag) with a softmax
//! head, over which training, scoring (the paper's architecture-agnostic
//! last-layer upper bound), evaluation and the gradient-norm oracle are all
//! computed generically — MLPs, small convnets and token-sequence models
//! run through one code path.
//!
//! [`kernels`] holds the cache-blocked, fixed-lane-accumulator
//! microkernels behind the layer IR's block-batched entry points
//! (`forward_block` / `scores_block` / `backward_block`): whole worker
//! chunks walk the stack at once, amortizing weight traffic across rows,
//! while staying **bit-identical** to the per-row scalar reference walk —
//! so every determinism guarantee above survives the fast path unchanged.
//! The inner tiles dispatch at runtime between explicit-SIMD and scalar
//! twins ([`kernels::KernelPath`]; both produce the same bits), and a
//! bf16-storage scoring variant behind `--score-precision bf16`
//! ([`ScorePrecision`]) trades bit-comparability with the f32 walk for
//! cheaper presample scoring while preserving score *ranking*.

pub mod backend;
pub mod checkpoint;
pub mod engine;
pub mod init;
pub mod kernels;
pub mod layers;
pub mod manifest;
pub mod native;
pub mod pool;
pub mod score;
pub mod selfcheck;
pub mod tensor;

pub use backend::Backend;
pub use engine::{clone_literals, Engine, ModelState};
pub use kernels::{set_forced_kernel_path, simd_available, KernelPath, KERNEL_PATHS};
pub use layers::{BlockScratch, Layer, LayerModel};
pub use manifest::{InitKind, Manifest, ModelInfo};
pub use native::{train_chunk_plan, NativeEngine, NativeModelSpec};
pub use pool::{default_train_workers, ObjectPool, WorkerPool};
pub use score::{
    default_score_workers, BackendScorer, NativeScorer, RowChunk, SampleScorer, ScoreBackend,
    ScoreKind, ScorePrecision,
};
pub use tensor::HostTensor;

//! Layer-3 runtime: loads the AOT artifacts produced by `make artifacts`
//! (HLO text + manifest), compiles them on the PJRT CPU client via the
//! `xla` crate, and exposes typed entry points over host tensors.
//!
//! Flow (see /opt/xla-example/load_hlo for the reference wiring):
//! `PjRtClient::cpu()` -> `HloModuleProto::from_text_file` ->
//! `XlaComputation::from_proto` -> `client.compile` -> `execute`.

pub mod checkpoint;
pub mod engine;
pub mod init;
pub mod manifest;
pub mod selfcheck;
pub mod tensor;

pub use engine::{clone_literals, Engine, ModelState};
pub use manifest::{InitKind, Manifest, ModelInfo};
pub use tensor::HostTensor;

//! Layer-3 runtime: loads the AOT artifacts produced by `make artifacts`
//! (HLO text + manifest), compiles them on the PJRT CPU client via the
//! `xla` crate, and exposes typed entry points over host tensors.
//!
//! Flow (see /opt/xla-example/load_hlo for the reference wiring):
//! `PjRtClient::cpu()` -> `HloModuleProto::from_text_file` ->
//! `XlaComputation::from_proto` -> `client.compile` -> `execute`.
//!
//! [`score`] adds the sharded presample-scoring subsystem: a
//! [`ScoreBackend`] that fans `fwd_scores` / `grad_norms` chunks out to
//! scoped worker threads and merges them in deterministic presample order.

pub mod checkpoint;
pub mod engine;
pub mod init;
pub mod manifest;
pub mod score;
pub mod selfcheck;
pub mod tensor;

pub use engine::{clone_literals, Engine, ModelState};
pub use manifest::{InitKind, Manifest, ModelInfo};
pub use score::{
    default_score_workers, EngineScorer, NativeScorer, RowChunk, SampleScorer, ScoreBackend,
    ScoreKind,
};
pub use tensor::HostTensor;

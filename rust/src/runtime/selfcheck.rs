//! End-to-end numerics selfcheck: rust-initialized parameters + rust-built
//! inputs, executed through the compiled artifacts, must match the numbers
//! Python/jax computed at AOT time (baked into the manifest).
//!
//! This is the strongest cross-language guarantee in the repo: it pins the
//! SplitMix64 init contract, the input formula, the HLO round-trip and the
//! PJRT execution in one assertion.

use anyhow::{bail, Result};

use super::engine::Engine;
use super::tensor::HostTensor;

/// Deterministic integer-math inputs, the twin of python
/// `aot.synth_inputs`: `x[i,j] = ((i*D+j) % 97)/97 - 0.5`; `y[i] = i % C`.
pub fn synth_inputs(
    feature_dim: usize,
    num_classes: usize,
    batch: usize,
) -> (HostTensor, Vec<i32>) {
    let mut x = HostTensor::zeros(vec![batch, feature_dim]);
    for i in 0..batch {
        for j in 0..feature_dim {
            let idx = (i * feature_dim + j) % 97;
            x.data[i * feature_dim + j] = idx as f32 / 97.0 - 0.5;
        }
    }
    let y: Vec<i32> = (0..batch).map(|i| (i % num_classes) as i32).collect();
    (x, y)
}

fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

/// Run the selfcheck for one model; returns a short summary string.
pub fn run(engine: &Engine, model: &str) -> Result<String> {
    let info = engine.model_info(model)?.clone();
    let sc = &info.selfcheck;
    let mut state = engine.init_state(model, sc.seed)?;

    // 1. RNG contract: first 8 values of the first parameter tensor
    let p0 = HostTensor::from_literal(&state.params[0])?;
    for (k, &expect) in sc.param0_head.iter().enumerate() {
        let got = p0.data[k] as f64;
        if !close(got, expect, 1e-6) {
            bail!("param0[{k}] = {got} != {expect} (RNG contract broken)");
        }
    }

    // 2. fwd_scores numerics
    let (x, y) = synth_inputs(info.feature_dim, info.num_classes, sc.batch);
    let (loss, ghat) = engine.fwd_scores(&state, &x, &y)?;
    for (k, &expect) in sc.loss_head.iter().enumerate() {
        if !close(loss[k] as f64, expect, 2e-4) {
            bail!("loss[{k}] = {} != {expect}", loss[k]);
        }
    }
    for (k, &expect) in sc.ghat_head.iter().enumerate() {
        if !close(ghat[k] as f64, expect, 2e-4) {
            bail!("ghat[{k}] = {} != {expect}", ghat[k]);
        }
    }
    let mean_loss = loss.iter().map(|&v| v as f64).sum::<f64>() / loss.len() as f64;
    if !close(mean_loss, sc.mean_loss, 2e-4) {
        bail!("mean loss {mean_loss} != {}", sc.mean_loss);
    }

    // 3. one uniform train step at lr 0.01, then the loss again
    let w = vec![1.0f32; sc.batch];
    let out = engine.train_step(&mut state, &x, &y, &w, 0.01)?;
    if !close(out.loss as f64, sc.step_loss, 2e-4) {
        bail!("step loss {} != {}", out.loss, sc.step_loss);
    }
    let (loss2, _) = engine.fwd_scores(&state, &x, &y)?;
    let mean2 = loss2.iter().map(|&v| v as f64).sum::<f64>() / loss2.len() as f64;
    if !close(mean2, sc.mean_loss_after_step, 5e-4) {
        bail!("post-step mean loss {mean2} != {}", sc.mean_loss_after_step);
    }

    Ok(format!(
        "mean loss {mean_loss:.6} -> {mean2:.6} after one step; {} params checked",
        info.num_params()
    ))
}

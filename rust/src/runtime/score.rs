//! Sharded presample scoring — the hot path of Algorithm 1, parallelized.
//!
//! Importance sampling only pays off when scoring the large presample batch
//! `B` is much cheaper than training on it (§3.3 cost model), so scoring
//! throughput is the number this system lives or dies by. The seed scored
//! the whole presample serially on the coordinator thread; this module
//! makes scoring scale with cores:
//!
//! * [`SampleScorer`] — anything that can score a chunk of presample rows
//!   (per-sample loss, Eq.-20 upper bound, or true gradient norm).
//! * [`BackendScorer`] — scores through any [`Backend`]'s entry points
//!   (PJRT baked artifacts or the native CPU engine). Backends are `Sync`,
//!   so one backend serves all workers.
//! * [`NativeScorer`] — a deterministic pure-rust scorer over any
//!   [`LayerModel`] stack (no AOT artifacts required): MLPs, convnets and
//!   sequence models all score through the same block kernels shared with
//!   [`NativeEngine`](super::native::NativeEngine), so native training
//!   and native scoring are bit-identical on the same parameters, and the
//!   upper-bound score is the architecture-agnostic last-layer bound of
//!   `runtime::layers`. Loss/upper-bound scoring takes the **score-only
//!   fast path** (`scores_block` + pooled arenas): one block forward per
//!   sub-block, zero gradient scratch, zero per-call allocation beyond
//!   the output vector — optionally through bf16 parameter storage
//!   ([`ScorePrecision::Bf16`]), which halves the weight-streaming
//!   footprint at the cost of bit-comparability with the f32 walk.
//! * [`ScoreBackend`] — the serial path, plus a threaded backend that
//!   splits the batch into contiguous per-worker chunks, scores them on
//!   scoped worker threads (the same std-only idiom as
//!   `coordinator::pipeline`), and merges results back in presample order.
//!
//! **Determinism contract.** Scorers must be row-wise deterministic: a
//! row's score depends only on that row and the model state. Chunked
//! scoring then reproduces the serial score vector bit for bit, so the
//! downstream resampler draws *identical* indices for a fixed seed —
//! parallelism never changes the training trajectory.

use anyhow::{anyhow, bail, Result};

use super::backend::Backend;
use super::engine::ModelState;
use super::init;
use super::kernels::MAX_BLOCK_ROWS;
use super::layers::{BlockScratch, LayerModel};
use super::pool::ObjectPool;
use super::tensor::HostTensor;

/// Which per-sample statistic drives the presample distribution.
/// (Owned by the scoring subsystem; `coordinator::sampler` re-exports it.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScoreKind {
    /// The paper's Eq.-20 upper bound (`upper-bound` curves).
    UpperBound,
    /// Loss-proportional (`loss` curves).
    Loss,
    /// True per-sample gradient norm (`gradient-norm`; an order of
    /// magnitude more expensive — Fig 1/2 oracle).
    GradNorm,
}

impl ScoreKind {
    pub fn name(self) -> &'static str {
        match self {
            ScoreKind::UpperBound => "upper-bound",
            ScoreKind::Loss => "loss",
            ScoreKind::GradNorm => "gradient-norm",
        }
    }

    /// The engine entry point that computes this statistic.
    pub fn entry(self) -> &'static str {
        match self {
            ScoreKind::GradNorm => "grad_norms",
            _ => "fwd_scores",
        }
    }
}

/// Numeric storage precision of the presample scoring pass
/// (`--score-precision`). Training numerics are always f32; this only
/// affects the loss/upper-bound forward walk that *ranks* presample rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ScorePrecision {
    /// Full f32 walk — bit-identical to the training forward (default).
    #[default]
    F32,
    /// bf16 parameter storage widened to f32 inside the kernels: half the
    /// weight-streaming footprint, same score *ranking* to within the
    /// pinned overlap threshold (`bf16_` tests in
    /// `rust/tests/native_train.rs`). NOT bit-comparable to the f32 path —
    /// the storage rounding perturbs every weight.
    Bf16,
}

impl ScorePrecision {
    pub fn name(self) -> &'static str {
        match self {
            ScorePrecision::F32 => "f32",
            ScorePrecision::Bf16 => "bf16",
        }
    }

    /// Parse a `--score-precision` flag value.
    pub fn parse(s: &str) -> Option<ScorePrecision> {
        match s {
            "f32" => Some(ScorePrecision::F32),
            "bf16" => Some(ScorePrecision::Bf16),
            _ => None,
        }
    }

    /// Wire/atomic encoding of the precision (0 = f32, 1 = bf16) — the
    /// byte that travels in a distributed score work order and sits in
    /// `NativeEngine`'s interior-mutable precision cell.
    pub fn code(self) -> u8 {
        match self {
            ScorePrecision::F32 => 0,
            ScorePrecision::Bf16 => 1,
        }
    }

    /// Inverse of [`code`](Self::code); `None` on unknown bytes (a
    /// malformed wire frame, never a panic).
    pub fn from_code(code: u8) -> Option<ScorePrecision> {
        match code {
            0 => Some(ScorePrecision::F32),
            1 => Some(ScorePrecision::Bf16),
            _ => None,
        }
    }
}

/// Scoring workers to use when the user does not say: one per core.
pub fn default_score_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// A borrowed, contiguous block of presample rows — what the threaded
/// backend hands each worker, so sharding never copies feature data.
#[derive(Clone, Copy)]
pub struct RowChunk<'a> {
    pub data: &'a [f32],
    pub rows: usize,
    pub dim: usize,
}

impl<'a> RowChunk<'a> {
    pub fn new(data: &'a [f32], rows: usize, dim: usize) -> Self {
        assert_eq!(data.len(), rows * dim, "row chunk shape mismatch");
        Self { data, rows, dim }
    }

    /// View an entire 2-D host tensor as one chunk.
    pub fn from_tensor(x: &'a HostTensor) -> Self {
        assert_eq!(x.shape.len(), 2, "presample batch must be 2-D");
        Self::new(&x.data, x.shape[0], x.shape[1])
    }

    pub fn row(&self, r: usize) -> &'a [f32] {
        &self.data[r * self.dim..(r + 1) * self.dim]
    }

    /// Materialize as an owned tensor (the engine upload path needs one).
    pub fn to_tensor(&self) -> HostTensor {
        HostTensor::new(vec![self.rows, self.dim], self.data.to_vec())
    }
}

/// Anything that can score a chunk of presample rows.
///
/// Implementations must be **row-wise deterministic** (see the module
/// docs); `Sync` because chunks are scored from scoped worker threads.
pub trait SampleScorer: Sync {
    /// Score every row of `x`/`y`; returns one score per row, in row order.
    fn score_chunk(&self, x: &HostTensor, y: &[i32], kind: ScoreKind) -> Result<Vec<f32>>;

    /// Score a borrowed row block. The default materializes a tensor and
    /// defers to [`score_chunk`](Self::score_chunk) (what the engine needs
    /// for its upload path anyway); scorers that can work straight off the
    /// borrow — like [`NativeScorer`] — override this to keep the threaded
    /// hot path copy-free.
    fn score_rows(&self, x: RowChunk<'_>, y: &[i32], kind: ScoreKind) -> Result<Vec<f32>> {
        self.score_chunk(&x.to_tensor(), y, kind)
    }

    /// Whether a chunk of exactly `rows` rows can be scored (the engine
    /// needs a baked artifact at that batch size; native scorers take any).
    fn supports_rows(&self, rows: usize, kind: ScoreKind) -> bool;
}

/// Scores through a [`Backend`]'s entry points (PJRT or native).
pub struct BackendScorer<'a> {
    pub backend: &'a dyn Backend,
    pub state: &'a ModelState,
}

impl SampleScorer for BackendScorer<'_> {
    fn score_chunk(&self, x: &HostTensor, y: &[i32], kind: ScoreKind) -> Result<Vec<f32>> {
        match kind {
            ScoreKind::UpperBound => self.backend.fwd_scores(self.state, x, y).map(|o| o.1),
            ScoreKind::Loss => self.backend.fwd_scores(self.state, x, y).map(|o| o.0),
            ScoreKind::GradNorm => self.backend.grad_norms(self.state, x, y),
        }
    }

    fn supports_rows(&self, rows: usize, kind: ScoreKind) -> bool {
        self.backend.supports(&self.state.model, kind.entry(), rows).unwrap_or(false)
    }
}

/// A self-contained pure-rust scorer over any [`LayerModel`] stack: the
/// per-sample loss, the architecture-agnostic Eq.-20 upper bound and the
/// exact gradient-norm oracle are computed natively through the same layer
/// walk the training backend uses. Lets the scoring benches and the
/// determinism tests exercise the parallel path — and measure its speedup —
/// without AOT artifacts or a PJRT runtime.
pub struct NativeScorer {
    model: LayerModel,
    params: Vec<Vec<f32>>,
    /// bf16 narrowing of `params`, present iff the scorer was switched to
    /// [`ScorePrecision::Bf16`] — quantized once at construction, walked
    /// by every loss/upper-bound call thereafter.
    qparams: Option<Vec<Vec<u16>>>,
    /// Persistent block-walk arenas: worker threads check one out per
    /// `score_rows` call, so repeated scoring passes allocate nothing but
    /// their output vector (the score-only fast path never touches
    /// gradient scratch at all).
    arenas: ObjectPool<BlockScratch>,
}

impl NativeScorer {
    /// A freshly initialized two-layer MLP scorer (the bench/test default;
    /// parameters come from the shared `runtime::init` recipe).
    pub fn new(feature_dim: usize, hidden: usize, num_classes: usize, seed: u64) -> Self {
        let model = LayerModel::mlp(feature_dim, hidden, num_classes).expect("invalid mlp");
        let params = init::init_params(seed, &model.param_specs());
        Self { model, params, qparams: None, arenas: ObjectPool::new() }
    }

    /// A scorer over an explicit layer stack + host parameters — how the
    /// native training backend hands its live model state (of **any**
    /// architecture) to the scoring subsystem.
    pub fn from_model(model: LayerModel, params: Vec<Vec<f32>>) -> Result<Self> {
        model.check_params(&params)?;
        Ok(Self { model, params, qparams: None, arenas: ObjectPool::new() })
    }

    /// Switch the loss/upper-bound fast path to bf16 parameter storage
    /// (quantizes once, up front). Gradient-norm scoring always stays
    /// f32 — the oracle is training-grade by definition.
    pub fn with_precision(mut self, precision: ScorePrecision) -> Self {
        self.qparams = match precision {
            ScorePrecision::F32 => None,
            ScorePrecision::Bf16 => Some(self.model.quantize_params(&self.params)),
        };
        self
    }

    pub fn feature_dim(&self) -> usize {
        self.model.in_dim()
    }

    pub fn num_classes(&self) -> usize {
        self.model.num_classes()
    }
}

impl SampleScorer for NativeScorer {
    fn score_chunk(&self, x: &HostTensor, y: &[i32], kind: ScoreKind) -> Result<Vec<f32>> {
        if x.shape.len() != 2 {
            bail!("native scorer expects a 2-D batch, got {:?}", x.shape);
        }
        self.score_rows(RowChunk::from_tensor(x), y, kind)
    }

    fn score_rows(&self, x: RowChunk<'_>, y: &[i32], kind: ScoreKind) -> Result<Vec<f32>> {
        if x.dim != self.feature_dim() {
            bail!("native scorer expects {}-dim features, got {}", self.feature_dim(), x.dim);
        }
        if y.len() != x.rows {
            bail!("labels ({}) do not match rows ({})", y.len(), x.rows);
        }
        let (m, p) = (&self.model, &self.params);
        let mut arena = self.arenas.checkout_or(BlockScratch::new);
        let mut out = vec![0.0f32; x.rows];
        match kind {
            ScoreKind::Loss | ScoreKind::UpperBound => {
                // Score-only fast path: block forwards, no gradient
                // scratch. `scores_block` computes both per-row outputs;
                // the unwanted lane lands in the arena's spare buffer
                // instead of a per-call allocation.
                let mut spare = std::mem::take(&mut arena.tmp);
                spare.clear();
                spare.resize(x.rows, 0.0);
                let mut start = 0usize;
                while start < x.rows {
                    let rows = (x.rows - start).min(MAX_BLOCK_ROWS);
                    let xb = &x.data[start * x.dim..(start + rows) * x.dim];
                    let yb = &y[start..start + rows];
                    let spare_w = &mut spare[start..start + rows];
                    let out_w = &mut out[start..start + rows];
                    let (lw, uw) =
                        if kind == ScoreKind::Loss { (out_w, spare_w) } else { (spare_w, out_w) };
                    if let Some(qp) = &self.qparams {
                        m.scores_block_bf16(qp, xb, yb, rows, &mut arena, lw, uw);
                    } else {
                        m.scores_block(p, xb, yb, rows, &mut arena, lw, uw);
                    }
                    start += rows;
                }
                arena.tmp = spare;
            }
            ScoreKind::GradNorm => {
                // the exact per-sample norm via the generic layer walk (the
                // pre-layer-IR scorer substituted the upper bound here)
                for (r, o) in out.iter_mut().enumerate() {
                    *o = m.grad_norm_row(p, x.row(r), y[r], &mut arena);
                }
            }
        }
        self.arenas.put(arena);
        Ok(out)
    }

    fn supports_rows(&self, _rows: usize, _kind: ScoreKind) -> bool {
        true
    }
}

/// How a presample batch is scored.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScoreBackend {
    /// One call covering the whole batch, on the caller's thread.
    Serial,
    /// `workers` scoped threads, each scoring a contiguous chunk; falls
    /// back to the serial path when the scorer cannot handle the chunk
    /// sizes (e.g. no baked artifact at `B / workers`).
    Threaded { workers: usize },
}

impl ScoreBackend {
    /// `workers <= 1` is the serial path.
    pub fn from_workers(workers: usize) -> ScoreBackend {
        if workers <= 1 {
            ScoreBackend::Serial
        } else {
            ScoreBackend::Threaded { workers }
        }
    }

    pub fn workers(&self) -> usize {
        match self {
            ScoreBackend::Serial => 1,
            ScoreBackend::Threaded { workers } => (*workers).max(1),
        }
    }

    /// The `(start, len)` chunks this backend would score `rows` with, or
    /// `None` when it would run serially (one worker, or an unsupported
    /// chunk size).
    pub fn plan(
        &self,
        scorer: &dyn SampleScorer,
        rows: usize,
        kind: ScoreKind,
    ) -> Option<Vec<(usize, usize)>> {
        let workers = self.workers().min(rows.max(1));
        if workers <= 1 {
            return None;
        }
        let chunks = split_rows(rows, workers);
        if chunks.iter().all(|&(_, len)| scorer.supports_rows(len, kind)) {
            Some(chunks)
        } else {
            None
        }
    }

    /// Score a full presample batch. Bit-identical to the serial path for
    /// any row-wise deterministic scorer (see module docs).
    pub fn score(
        &self,
        scorer: &dyn SampleScorer,
        x: &HostTensor,
        y: &[i32],
        kind: ScoreKind,
    ) -> Result<Vec<f32>> {
        if x.shape.len() != 2 {
            bail!("presample batch must be 2-D, got shape {:?}", x.shape);
        }
        let rows = x.shape[0];
        if y.len() != rows {
            bail!("labels ({}) do not match presample rows ({rows})", y.len());
        }
        match self.plan(scorer, rows, kind) {
            None => {
                let scores = scorer.score_chunk(x, y, kind)?;
                if scores.len() != rows {
                    bail!("scorer returned {} scores for {rows} rows", scores.len());
                }
                Ok(scores)
            }
            Some(chunks) => score_chunks_threaded(scorer, x, y, kind, &chunks),
        }
    }

    /// Score only the rows at `positions` of a presample batch — the
    /// partial re-score path behind the staleness-aware score cache
    /// (`--score-refresh-budget`): returns one score per position, in
    /// position order. When `positions` is exactly `0..rows` the call
    /// degenerates to [`score`](Self::score) on the original tensor with
    /// no gather, which is what makes the infinite-budget configuration
    /// bit-identical to the uncached re-score-everything path.
    pub fn score_subset(
        &self,
        scorer: &dyn SampleScorer,
        x: &HostTensor,
        y: &[i32],
        kind: ScoreKind,
        positions: &[usize],
    ) -> Result<Vec<f32>> {
        if x.shape.len() != 2 {
            bail!("presample batch must be 2-D, got shape {:?}", x.shape);
        }
        let rows = x.shape[0];
        if y.len() != rows {
            bail!("labels ({}) do not match presample rows ({rows})", y.len());
        }
        if positions.is_empty() {
            return Ok(vec![]);
        }
        if positions.len() == rows && positions.iter().enumerate().all(|(i, &p)| i == p) {
            return self.score(scorer, x, y, kind);
        }
        let d = x.shape[1];
        let mut gx = HostTensor::zeros(vec![positions.len(), d]);
        let mut gy = Vec::with_capacity(positions.len());
        for (r, &p) in positions.iter().enumerate() {
            if p >= rows {
                bail!("subset position {p} out of range ({rows} presample rows)");
            }
            gx.data[r * d..(r + 1) * d].copy_from_slice(x.row(p));
            gy.push(y[p]);
        }
        self.score(scorer, &gx, &gy, kind)
    }
}

/// Split `rows` into `workers` contiguous chunks, balanced to within one
/// row, in row order; zero rows (or zero workers) yield an empty plan.
/// This is the shared chunk planner: the threaded scoring backend plans
/// one chunk per worker with it, and the native training backend's
/// worker-count-independent plan
/// ([`train_chunk_plan`](super::native::train_chunk_plan)) reuses it so
/// train-side sharding follows the exact same geometry.
pub fn split_rows(rows: usize, workers: usize) -> Vec<(usize, usize)> {
    if rows == 0 || workers == 0 {
        return vec![];
    }
    let base = rows / workers;
    let rem = rows % workers;
    let mut out = Vec::with_capacity(workers);
    let mut start = 0;
    for w in 0..workers {
        let len = base + usize::from(w < rem);
        if len > 0 {
            out.push((start, len));
            start += len;
        }
    }
    out
}

/// Score chunks concurrently on scoped worker threads and merge the
/// results back in presample order. Workers receive borrowed [`RowChunk`]
/// views — no feature data is copied by the sharding itself (thread spawn
/// is the only per-call overhead; at presample scale it is dwarfed by the
/// scoring work, and scoped threads keep the backend allocation-free and
/// borrowing, matching the `coordinator::pipeline` idiom).
fn score_chunks_threaded(
    scorer: &dyn SampleScorer,
    x: &HostTensor,
    y: &[i32],
    kind: ScoreKind,
    chunks: &[(usize, usize)],
) -> Result<Vec<f32>> {
    let d = x.shape[1];
    let results: Vec<Result<Vec<f32>>> = std::thread::scope(|s| {
        let handles: Vec<_> = chunks
            .iter()
            .map(|&(start, len)| {
                s.spawn(move || {
                    let view = RowChunk::new(&x.data[start * d..(start + len) * d], len, d);
                    scorer.score_rows(view, &y[start..start + len], kind)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("score worker panicked"))))
            .collect()
    });
    let mut out = vec![0.0f32; x.shape[0]];
    for (&(start, len), chunk) in chunks.iter().zip(results) {
        let scores = chunk?;
        if scores.len() != len {
            bail!("scorer returned {} scores for a {len}-row chunk", scores.len());
        }
        out[start..start + len].copy_from_slice(&scores);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::Backend;
    use crate::runtime::engine::Engine;
    use crate::runtime::native::NativeEngine;

    fn toy_batch(rows: usize, d: usize, classes: usize) -> (HostTensor, Vec<i32>) {
        let mut x = HostTensor::zeros(vec![rows, d]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = ((i * 31 + 7) % 113) as f32 / 113.0 - 0.5;
        }
        let y: Vec<i32> = (0..rows).map(|i| (i % classes) as i32).collect();
        (x, y)
    }

    #[test]
    fn engine_and_state_are_shareable_across_threads() {
        fn check<T: Send + Sync>() {}
        check::<Engine>();
        check::<ModelState>();
        check::<NativeScorer>();
        check::<NativeEngine>(); // owns a WorkerPool behind Mutex/Atomic
    }

    #[test]
    fn split_rows_is_balanced_and_ordered() {
        assert!(split_rows(0, 4).is_empty());
        assert!(split_rows(16, 0).is_empty());
        for (rows, workers) in [(640, 4), (641, 4), (7, 3), (5, 8), (1, 2)] {
            let chunks = split_rows(rows, workers);
            let total: usize = chunks.iter().map(|&(_, len)| len).sum();
            assert_eq!(total, rows, "{rows}/{workers}");
            let mut next = 0;
            for &(start, len) in &chunks {
                assert_eq!(start, next);
                assert!(len > 0);
                next = start + len;
            }
            let lens: Vec<usize> = chunks.iter().map(|&(_, len)| len).collect();
            let (min, max) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(max - min <= 1, "unbalanced {lens:?}");
        }
    }

    #[test]
    fn parallel_scores_are_bit_identical_to_serial() {
        let scorer = NativeScorer::new(24, 16, 5, 3);
        let (x, y) = toy_batch(101, 24, 5);
        for kind in [ScoreKind::UpperBound, ScoreKind::Loss, ScoreKind::GradNorm] {
            let serial = ScoreBackend::Serial.score(&scorer, &x, &y, kind).unwrap();
            assert_eq!(serial.len(), 101);
            assert!(serial.iter().all(|s| s.is_finite()));
            for workers in [2, 3, 4, 9, 200] {
                let backend = ScoreBackend::from_workers(workers);
                let par = backend.score(&scorer, &x, &y, kind).unwrap();
                assert_eq!(par, serial, "workers={workers} kind={}", kind.name());
            }
        }
    }

    #[test]
    fn bf16_scorer_is_deterministic_and_tracks_the_f32_values() -> anyhow::Result<()> {
        let full = NativeScorer::new(24, 16, 5, 3);
        let bf = NativeScorer::new(24, 16, 5, 3).with_precision(ScorePrecision::Bf16);
        let (x, y) = toy_batch(101, 24, 5);
        for kind in [ScoreKind::UpperBound, ScoreKind::Loss] {
            let serial = ScoreBackend::Serial.score(&bf, &x, &y, kind)?;
            assert!(serial.iter().all(|s| s.is_finite()));
            // sharding stays bit-identical on the bf16 path too
            for workers in [2, 9] {
                let par = ScoreBackend::from_workers(workers).score(&bf, &x, &y, kind)?;
                assert_eq!(par, serial, "workers={workers} kind={}", kind.name());
            }
            // values track the f32 walk to within storage rounding
            let reference = ScoreBackend::Serial.score(&full, &x, &y, kind)?;
            let mean_dev = serial
                .iter()
                .zip(&reference)
                .map(|(b, f)| ((b - f).abs() / f.abs().max(1e-3)) as f64)
                .sum::<f64>()
                / serial.len() as f64;
            assert!(mean_dev < 0.1, "kind={} mean relative deviation {mean_dev}", kind.name());
        }
        // the gradient-norm oracle ignores score precision entirely
        let gn_full = ScoreBackend::Serial.score(&full, &x, &y, ScoreKind::GradNorm)?;
        let gn_bf = ScoreBackend::Serial.score(&bf, &x, &y, ScoreKind::GradNorm)?;
        assert_eq!(gn_bf, gn_full);
        Ok(())
    }

    #[test]
    fn score_precision_flag_round_trips() {
        assert_eq!(ScorePrecision::default(), ScorePrecision::F32);
        for p in [ScorePrecision::F32, ScorePrecision::Bf16] {
            assert_eq!(ScorePrecision::parse(p.name()), Some(p));
            assert_eq!(ScorePrecision::from_code(p.code()), Some(p));
        }
        assert_eq!(ScorePrecision::parse("fp16"), None);
        assert_eq!(ScorePrecision::from_code(2), None);
    }

    #[test]
    fn backend_scorer_parallel_matches_serial_on_native_backend() {
        // The scorer the trainer actually uses when running natively:
        // chunked scoring through the backend must be bit-identical to the
        // serial full-batch pass for every score kind.
        let ne = NativeEngine::with_default_models();
        let state = ne.init_state("mlp10", 11).unwrap();
        let scorer = BackendScorer { backend: &ne, state: &state };
        let (x, y) = toy_batch(97, 64, 10);
        for kind in [ScoreKind::UpperBound, ScoreKind::Loss, ScoreKind::GradNorm] {
            let serial = ScoreBackend::Serial.score(&scorer, &x, &y, kind).unwrap();
            assert!(serial.iter().all(|s| s.is_finite()));
            for workers in [2, 5, 16] {
                let sb = ScoreBackend::from_workers(workers);
                let par = sb.score(&scorer, &x, &y, kind).unwrap();
                assert_eq!(par, serial, "workers={workers} kind={}", kind.name());
            }
        }
    }

    #[test]
    fn unsupported_chunks_fall_back_to_serial() {
        /// Accepts only full batches — like an engine with a single baked
        /// artifact size.
        struct FullOnly {
            inner: NativeScorer,
            full: usize,
        }
        impl SampleScorer for FullOnly {
            fn score_chunk(&self, x: &HostTensor, y: &[i32], kind: ScoreKind) -> Result<Vec<f32>> {
                assert_eq!(x.shape[0], self.full, "must never receive a partial chunk");
                self.inner.score_chunk(x, y, kind)
            }
            fn supports_rows(&self, rows: usize, _kind: ScoreKind) -> bool {
                rows == self.full
            }
        }
        let inner = NativeScorer::new(8, 8, 3, 1);
        let (x, y) = toy_batch(64, 8, 3);
        let reference = ScoreBackend::Serial.score(&inner, &x, &y, ScoreKind::UpperBound).unwrap();
        let gated = FullOnly { inner, full: 64 };
        let backend = ScoreBackend::from_workers(4);
        assert!(backend.plan(&gated, 64, ScoreKind::UpperBound).is_none());
        let out = backend.score(&gated, &x, &y, ScoreKind::UpperBound).unwrap();
        assert_eq!(out, reference);
    }

    #[test]
    fn worker_errors_propagate() {
        struct Failing;
        impl SampleScorer for Failing {
            fn score_chunk(&self, _: &HostTensor, _: &[i32], _: ScoreKind) -> Result<Vec<f32>> {
                bail!("scorer exploded")
            }
            fn supports_rows(&self, _: usize, _: ScoreKind) -> bool {
                true
            }
        }
        let (x, y) = toy_batch(16, 4, 2);
        let err = ScoreBackend::from_workers(4)
            .score(&Failing, &x, &y, ScoreKind::Loss)
            .unwrap_err();
        assert!(format!("{err:#}").contains("exploded"));
    }

    #[test]
    fn backend_construction() {
        assert_eq!(ScoreBackend::from_workers(0), ScoreBackend::Serial);
        assert_eq!(ScoreBackend::from_workers(1), ScoreBackend::Serial);
        assert_eq!(ScoreBackend::from_workers(4), ScoreBackend::Threaded { workers: 4 });
        assert_eq!(ScoreBackend::from_workers(4).workers(), 4);
        assert!(default_score_workers() >= 1);
        assert_eq!(ScoreKind::GradNorm.entry(), "grad_norms");
        assert_eq!(ScoreKind::UpperBound.entry(), "fwd_scores");
    }

    #[test]
    fn native_scorer_shape_checks() {
        let scorer = NativeScorer::new(8, 4, 3, 1);
        assert_eq!(scorer.feature_dim(), 8);
        assert_eq!(scorer.num_classes(), 3);
        let (x, y) = toy_batch(4, 6, 3); // wrong feature dim
        assert!(scorer.score_chunk(&x, &y, ScoreKind::Loss).is_err());
        let (x, _) = toy_batch(4, 8, 3);
        assert!(scorer.score_chunk(&x, &[0, 1], ScoreKind::Loss).is_err());
    }
}

//! Typed view of `artifacts/manifest.json` — the contract between the
//! Python AOT step and the rust coordinator.
//!
//! The manifest records, per model: the parameter tree (name/shape/init
//! kind, in stream order), every lowered entry point with its batch size and
//! argument shapes, and a `selfcheck` block of expected numerics computed by
//! Python at build time (asserted by `rust/tests/selfcheck.rs`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Parameter initialization kinds — must mirror `python/compile/rng.py`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InitKind {
    Zeros,
    GlorotUniform,
    ScaledNormal,
    LstmBias,
}

impl InitKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "zeros" => InitKind::Zeros,
            "glorot_uniform" => InitKind::GlorotUniform,
            "scaled_normal" => InitKind::ScaledNormal,
            "lstm_bias" => InitKind::LstmBias,
            _ => bail!("unknown init kind {s:?}"),
        })
    }
}

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: InitKind,
}

impl ParamSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct EntryInfo {
    pub entry: String,
    pub batch: usize,
    pub file: String,
    pub args: Vec<ArgSpec>,
}

/// Expected numerics computed by Python at AOT time (fixed seed + formula
/// inputs). Lets rust assert, end to end, that artifact execution matches
/// what jax computed — without Python at run time.
#[derive(Debug, Clone)]
pub struct Selfcheck {
    pub seed: u64,
    pub batch: usize,
    pub loss_head: Vec<f64>,
    pub ghat_head: Vec<f64>,
    pub mean_loss: f64,
    pub step_loss: f64,
    pub mean_loss_after_step: f64,
    pub param0_head: Vec<f64>,
}

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub feature_dim: usize,
    pub num_classes: usize,
    pub batch: usize,
    pub eval_batch: usize,
    pub presample: Vec<usize>,
    pub params: Vec<ParamSpec>,
    pub entries: Vec<EntryInfo>,
    pub selfcheck: Selfcheck,
}

impl ModelInfo {
    pub fn entry(&self, entry: &str, batch: usize) -> Result<&EntryInfo> {
        self.entries
            .iter()
            .find(|e| e.entry == entry && e.batch == batch)
            .with_context(|| {
                let have: Vec<String> = self
                    .entries
                    .iter()
                    .map(|e| format!("{}@{}", e.entry, e.batch))
                    .collect();
                format!(
                    "model {:?} has no artifact for entry {entry:?} at batch {batch} (have: {})",
                    self.name,
                    have.join(", ")
                )
            })
    }

    pub fn has_entry(&self, entry: &str) -> bool {
        self.entries.iter().any(|e| e.entry == entry)
    }

    pub fn num_params(&self) -> usize {
        self.params.len()
    }

    pub fn total_param_elements(&self) -> usize {
        self.params.iter().map(|p| p.elements()).sum()
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub momentum: f64,
    pub weight_decay: f64,
    pub models: BTreeMap<String, ModelInfo>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;
        Self::from_json(dir, &root)
    }

    pub fn from_json(dir: PathBuf, root: &Json) -> Result<Self> {
        if root.req("format")?.as_str() != Some("hlo-text") {
            bail!("manifest format is not hlo-text");
        }
        let mut models = BTreeMap::new();
        for (name, m) in root.req("models")?.as_obj().context("models not an object")? {
            models.insert(name.clone(), parse_model(name, m)?);
        }
        Ok(Manifest {
            dir,
            momentum: root.req("momentum")?.as_f64().context("momentum")?,
            weight_decay: root.req("weight_decay")?.as_f64().context("weight_decay")?,
            models,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models.get(name).with_context(|| {
            format!(
                "unknown model {name:?}; manifest has: {}",
                self.models.keys().cloned().collect::<Vec<_>>().join(", ")
            )
        })
    }

    pub fn artifact_path(&self, e: &EntryInfo) -> PathBuf {
        self.dir.join(&e.file)
    }
}

fn parse_model(name: &str, m: &Json) -> Result<ModelInfo> {
    let params = m
        .req("params")?
        .as_arr()
        .context("params not an array")?
        .iter()
        .map(|p| {
            Ok(ParamSpec {
                name: p.req("name")?.as_str().context("param name")?.to_string(),
                shape: p.req("shape")?.usize_array().context("param shape")?,
                init: InitKind::parse(p.req("init")?.as_str().context("param init")?)?,
            })
        })
        .collect::<Result<Vec<_>>>()?;

    let entries = m
        .req("entries")?
        .as_arr()
        .context("entries not an array")?
        .iter()
        .map(|e| {
            Ok(EntryInfo {
                entry: e.req("entry")?.as_str().context("entry name")?.to_string(),
                batch: e.req("batch")?.as_usize().context("entry batch")?,
                file: e.req("file")?.as_str().context("entry file")?.to_string(),
                args: e
                    .req("args")?
                    .as_arr()
                    .context("entry args")?
                    .iter()
                    .map(|a| {
                        Ok(ArgSpec {
                            shape: a.req("shape")?.usize_array().context("arg shape")?,
                            dtype: a.req("dtype")?.as_str().context("arg dtype")?.to_string(),
                        })
                    })
                    .collect::<Result<Vec<_>>>()?,
            })
        })
        .collect::<Result<Vec<_>>>()?;

    let sc = m.req("selfcheck")?;
    let selfcheck = Selfcheck {
        seed: sc.req("seed")?.as_usize().context("seed")? as u64,
        batch: sc.req("batch")?.as_usize().context("batch")?,
        loss_head: sc.req("loss_head")?.f64_array().context("loss_head")?,
        ghat_head: sc.req("ghat_head")?.f64_array().context("ghat_head")?,
        mean_loss: sc.req("mean_loss")?.as_f64().context("mean_loss")?,
        step_loss: sc.req("step_loss")?.as_f64().context("step_loss")?,
        mean_loss_after_step: sc
            .req("mean_loss_after_step")?
            .as_f64()
            .context("mean_loss_after_step")?,
        param0_head: sc.req("param0_head")?.f64_array().context("param0_head")?,
    };

    Ok(ModelInfo {
        name: name.to_string(),
        feature_dim: m.req("feature_dim")?.as_usize().context("feature_dim")?,
        num_classes: m.req("num_classes")?.as_usize().context("num_classes")?,
        batch: m.req("batch")?.as_usize().context("batch")?,
        eval_batch: m.req("eval_batch")?.as_usize().context("eval_batch")?,
        presample: m.req("presample")?.usize_array().context("presample")?,
        params,
        entries,
        selfcheck,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_manifest() -> Json {
        Json::parse(
            r#"{
            "version": 1, "format": "hlo-text", "momentum": 0.9, "weight_decay": 0.0005,
            "models": {"m": {
                "feature_dim": 4, "num_classes": 3, "batch": 8, "eval_batch": 16,
                "presample": [16, 32],
                "params": [{"name": "w0", "shape": [4, 3], "init": "glorot_uniform"},
                           {"name": "b0", "shape": [3], "init": "zeros"}],
                "entries": [{"entry": "fwd_scores", "batch": 8, "file": "m_fwd_scores_b8.hlo.txt",
                             "args": [{"shape": [4, 3], "dtype": "float32"},
                                      {"shape": [3], "dtype": "float32"},
                                      {"shape": [8, 4], "dtype": "float32"},
                                      {"shape": [8], "dtype": "int32"}]}],
                "selfcheck": {"seed": 42, "batch": 8,
                    "loss_head": [1.0, 1.1, 1.2, 1.3], "ghat_head": [0.9, 0.9, 0.9, 0.9],
                    "mean_loss": 1.1, "step_loss": 1.1, "mean_loss_after_step": 1.05,
                    "param0_head": [0.1, -0.2, 0.3, 0.0, 0.0, 0.1, 0.2, -0.1]}
            }}}"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_model_info() {
        let m = Manifest::from_json(PathBuf::from("/tmp"), &mini_manifest()).unwrap();
        assert_eq!(m.momentum, 0.9);
        let info = m.model("m").unwrap();
        assert_eq!(info.num_params(), 2);
        assert_eq!(info.total_param_elements(), 15);
        assert_eq!(info.params[0].init, InitKind::GlorotUniform);
        let e = info.entry("fwd_scores", 8).unwrap();
        assert_eq!(e.args.len(), 4);
        assert_eq!(e.args[3].dtype, "int32");
        assert!(info.entry("fwd_scores", 99).is_err());
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn unknown_init_kind_rejected() {
        assert!(InitKind::parse("bogus").is_err());
        assert_eq!(InitKind::parse("lstm_bias").unwrap(), InitKind::LstmBias);
    }
}

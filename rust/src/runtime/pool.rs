//! A shared, persistent worker pool for batch-level data parallelism.
//!
//! The sharded scoring backend (`runtime::score`) spawns scoped threads per
//! call — fine at presample scale, where one scoring pass dwarfs thread
//! spawn. The training hot path is different: `train_step` runs thousands
//! of times per budget on batches an order of magnitude smaller than `B`,
//! so per-call spawns would eat the parallel win. [`WorkerPool`] spawns its
//! threads **once** (per [`NativeEngine`](super::native::NativeEngine),
//! lazily) and feeds them jobs over a channel for the life of the engine.
//!
//! [`WorkerPool::run`] executes a batch of tasks that may borrow from the
//! caller's stack and returns their outputs **in task order**. It provides
//! the scoped-thread guarantee on persistent threads: `run` does not return
//! until every submitted task has completed (it collects exactly one
//! completion per task, and panics are caught inside the job wrapper and
//! re-raised on the caller after the barrier), so no borrow handed to a
//! task can outlive the call. That guarantee is what makes the contained
//! lifetime erasure in `run` sound.
//!
//! Determinism note: which worker executes which task is scheduling-
//! dependent, but outputs are keyed by task index and reassembled in task
//! order, so callers that reduce outputs in that fixed order are
//! bit-identical for every worker count — the contract `runtime::native`
//! builds on.
//!
//! [`ObjectPool`] is the companion piece for the *memory* side of the hot
//! loop: a free-list of chunk-sized arenas (block scratch, partial
//! gradients) that persists across steps, so the per-chunk closures the
//! engine fans out allocate nothing in steady state.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Training-side workers to use when the user does not say: one per core
/// — delegating to
/// [`default_score_workers`](super::score::default_score_workers) so the
/// two defaults can never drift apart.
pub fn default_train_workers() -> usize {
    super::score::default_score_workers()
}

/// A unit of work submitted to [`WorkerPool::run`]; may borrow from the
/// caller's stack for the duration of that call.
pub type Task<'env, T> = Box<dyn FnOnce() -> T + Send + 'env>;

/// A type-erased job as the worker threads see it.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Persistent worker threads fed over a shared channel. See module docs.
pub struct WorkerPool {
    workers: usize,
    /// `Mutex` (not a bare `Sender`) so the pool is `Sync` on every
    /// toolchain; `run` clones the sender once per call.
    tx: Mutex<Option<Sender<Job>>>,
    handles: Vec<JoinHandle<()>>,
    /// Advisory jobs whose panic [`submit`](Self::submit) swallowed.
    panicked: Arc<AtomicUsize>,
}

impl WorkerPool {
    /// Spawn `workers.max(1)` threads that idle on the job channel.
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..workers)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || worker_loop(&rx))
            })
            .collect();
        Self { workers, tx: Mutex::new(Some(tx)), handles, panicked: Arc::new(AtomicUsize::new(0)) }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// How many advisory jobs queued through [`submit`](Self::submit) have
    /// panicked so far. Diagnostics only: `run` task panics are re-raised
    /// on the caller instead and never counted here.
    pub fn panicked_jobs(&self) -> usize {
        self.panicked.load(Ordering::Relaxed)
    }

    /// Fire-and-forget: queue a self-contained (`'static`) job on the pool
    /// and return immediately. The streaming shard store uses this for
    /// readahead — overlapping the next shard's disk IO with scoring and
    /// training on the current one. A panic inside the job is swallowed
    /// (the job is advisory; whoever needs its result will redo the work
    /// synchronously and surface the real error), and a pool that is
    /// already shutting down silently drops the job for the same reason.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        let Some(tx) = self.tx.lock().unwrap().clone() else {
            return;
        };
        let panicked = Arc::clone(&self.panicked);
        let job: Job = Box::new(move || {
            if catch_unwind(AssertUnwindSafe(job)).is_err() {
                panicked.fetch_add(1, Ordering::Relaxed);
            }
        });
        let _ = tx.send(job);
    }

    /// Run every task to completion on the pool and return the outputs in
    /// task order. Blocks until all tasks are done; a panicking task is
    /// re-raised here (after the barrier, so borrows stay sound and the
    /// pool stays usable).
    pub fn run<'env, T: Send + 'env>(&self, tasks: Vec<Task<'env, T>>) -> Vec<T> {
        let n = tasks.len();
        let tx = self.tx.lock().unwrap().clone().expect("worker pool already shut down");
        let (rtx, rrx) = channel::<(usize, std::thread::Result<T>)>();
        for (i, task) in tasks.into_iter().enumerate() {
            let rtx = rtx.clone();
            let job: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                let out = catch_unwind(AssertUnwindSafe(task));
                // the receiver outlives the send (run() is still in its
                // collection loop); a failed send can only mean the caller
                // already panicked, so drop the result on the floor
                let _ = rtx.send((i, out));
            });
            // SAFETY: `run` neither returns nor unwinds before the loop
            // below has received one completion per submitted task, and
            // workers drop a job as soon as it finishes — so nothing
            // borrowed by `job` outlives this call. This is the
            // std::thread::scope guarantee, provided by the completion
            // barrier instead of a join; invariant violations inside the
            // window abort instead of unwinding (see [`die`]).
            let job = unsafe { std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(job) };
            if tx.send(job).is_err() {
                die("job channel closed mid-submission");
            }
        }
        drop(rtx);
        let mut slots: Vec<Option<std::thread::Result<T>>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, out) = match rrx.recv() {
                Ok(v) => v,
                Err(_) => die("completion channel closed mid-barrier"),
            };
            slots[i] = Some(out);
        }
        // barrier passed: every borrow is released; now surface any panic
        let mut outs = Vec::with_capacity(n);
        let mut panicked: Option<Box<dyn std::any::Any + Send>> = None;
        for slot in slots {
            match slot.expect("completion barrier left an empty slot") {
                Ok(v) => outs.push(v),
                Err(p) => panicked = Some(p),
            }
        }
        if let Some(p) = panicked {
            resume_unwind(p);
        }
        outs
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // closing the channel makes every idle worker's recv() fail -> exit
        self.tx.lock().unwrap().take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// A lock-guarded free-list of reusable scratch objects — how the native
/// engine and scorer keep their chunk-sized arenas (block scratch buffers,
/// partial-gradient buffers) alive **across** steps instead of allocating
/// them inside the step loop.
///
/// Checkout/put cost one short `Mutex` lock each — noise at chunk
/// granularity — and the pool's size is bounded by the peak number of
/// chunks in flight (each worker returns its object before taking the next
/// task), so a warm engine reaches a fixed working set and stops
/// allocating. Objects carry no model-specific invariants; users re-`ensure`
/// shapes on checkout, so one pool safely serves every registered model.
#[derive(Debug, Default)]
pub struct ObjectPool<T> {
    items: Mutex<Vec<T>>,
}

impl<T> ObjectPool<T> {
    pub fn new() -> Self {
        Self { items: Mutex::new(Vec::new()) }
    }

    /// Pop a pooled object, or build a fresh one with `mk` when the pool
    /// is momentarily empty (first use, or more chunks in flight than ever
    /// before).
    pub fn checkout_or(&self, mk: impl FnOnce() -> T) -> T {
        let pooled = self.items.lock().unwrap().pop();
        pooled.unwrap_or_else(mk)
    }

    /// Return an object to the free-list for the next checkout.
    pub fn put(&self, item: T) {
        self.items.lock().unwrap().push(item);
    }

    /// Objects currently idle in the pool (diagnostics/tests).
    pub fn idle(&self) -> usize {
        self.items.lock().unwrap().len()
    }
}

/// Invariant-violation guard for the windows where tasks queued on the
/// pool still borrow the caller's stack: unwinding out of [`WorkerPool::run`]
/// there would free frames live jobs reference (use-after-free), so a
/// broken channel — unreachable today, but cheap to guard — is fatal.
fn die(msg: &str) -> ! {
    eprintln!("WorkerPool invariant violated: {msg}; aborting");
    std::process::abort();
}

fn worker_loop(rx: &Mutex<Receiver<Job>>) {
    loop {
        // hold the lock only for the dequeue, never while running the job
        let job = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        match job {
            Ok(job) => job(),
            Err(_) => break, // pool dropped
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outputs_come_back_in_task_order() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.workers(), 4);
        let tasks: Vec<Task<usize>> =
            (0..17).map(|i| Box::new(move || i * i) as Task<usize>).collect();
        let out = pool.run(tasks);
        assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn tasks_may_borrow_from_the_caller() {
        let pool = WorkerPool::new(3);
        let data: Vec<u64> = (0..1000).collect();
        let chunks: Vec<&[u64]> = data.chunks(137).collect();
        let tasks: Vec<Task<u64>> =
            chunks.iter().map(|c| Box::new(move || c.iter().sum()) as Task<u64>).collect();
        let total: u64 = pool.run(tasks).iter().sum();
        assert_eq!(total, data.iter().sum::<u64>());
    }

    #[test]
    fn empty_task_list_is_a_noop() {
        let pool = WorkerPool::new(2);
        let out: Vec<u32> = pool.run(vec![]);
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_pool_still_completes_many_tasks() {
        let pool = WorkerPool::new(1);
        let tasks: Vec<Task<usize>> = (0..8).map(|i| Box::new(move || i) as Task<usize>).collect();
        assert_eq!(pool.run(tasks), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn panics_propagate_and_the_pool_survives() {
        let pool = WorkerPool::new(2);
        let tasks: Vec<Task<u32>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("task exploded")),
            Box::new(|| 3),
        ];
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| pool.run(tasks)));
        assert!(caught.is_err(), "panic must cross the barrier");
        // the pool must keep working afterwards
        let ok: Vec<Task<u32>> = vec![Box::new(|| 7), Box::new(|| 9)];
        assert_eq!(pool.run(ok), vec![7, 9]);
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.workers(), 1);
        let tasks: Vec<Task<u8>> = vec![Box::new(|| 5)];
        assert_eq!(pool.run(tasks), vec![5]);
    }

    #[test]
    fn default_train_workers_is_positive() {
        assert!(default_train_workers() >= 1);
    }

    #[test]
    fn object_pool_recycles_instead_of_rebuilding() {
        let pool: ObjectPool<Vec<u8>> = ObjectPool::new();
        assert_eq!(pool.idle(), 0);
        let mut a = pool.checkout_or(|| Vec::with_capacity(64));
        assert_eq!(a.capacity(), 64); // built fresh: pool was empty
        a.push(7);
        pool.put(a);
        assert_eq!(pool.idle(), 1);
        let b = pool.checkout_or(Vec::new);
        // recycled, not rebuilt: the capacity (and stale content — callers
        // re-ensure shapes) came back from the free-list
        assert!(b.capacity() >= 64 && b[0] == 7);
        assert_eq!(pool.idle(), 0);
    }

    #[test]
    fn object_pool_is_shareable_across_threads() {
        let pool: ObjectPool<Vec<u64>> = ObjectPool::new();
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let p = &pool;
                s.spawn(move || {
                    for i in 0..50 {
                        let mut v = p.checkout_or(Vec::new);
                        v.push(t * 1000 + i);
                        p.put(v);
                    }
                });
            }
        });
        // every checkout was matched by a put; the pool's working set is
        // bounded by the peak concurrency (4 threads)
        assert!((1..=4).contains(&pool.idle()));
    }
}

//! Parameter initialization — the rust twin of `python/compile/rng.py`'s
//! `init_tensor`. Bit-compatible draws (SplitMix64 + identical f64 math)
//! so the manifest selfcheck can pin exact expected values.

use anyhow::Result;

use crate::runtime::engine::ModelState;
use crate::runtime::manifest::{InitKind, ModelInfo, ParamSpec};
use crate::runtime::tensor::HostTensor;
use crate::util::rng::SplitMix64;

/// fan_in/fan_out, matching python: 2-D is (rows, cols); 4-D is HWIO conv
/// with receptive-field scaling; anything else degenerates to (n, n).
pub fn fans(shape: &[usize]) -> (usize, usize) {
    match shape.len() {
        2 => (shape[0], shape[1]),
        4 => {
            let rf = shape[0] * shape[1];
            (shape[2] * rf, shape[3] * rf)
        }
        _ => {
            let n: usize = shape.iter().product();
            (n, n)
        }
    }
}

/// Generate one parameter tensor (row-major) exactly as python's
/// `rng.init_tensor(seed, tensor_index, shape, kind)` does.
pub fn init_tensor(seed: u64, tensor_index: u64, shape: &[usize], kind: InitKind) -> Vec<f32> {
    let n: usize = shape.iter().product();
    match kind {
        InitKind::Zeros => vec![0.0; n],
        InitKind::LstmBias => {
            // shape = (4H,): gate order [i, f, g, o]; forget gate biased to 1.
            let mut out = vec![0.0f32; n];
            let h = n / 4;
            for v in out.iter_mut().skip(h).take(h) {
                *v = 1.0;
            }
            out
        }
        InitKind::GlorotUniform => {
            let (fan_in, fan_out) = fans(shape);
            let a = (6.0 / (fan_in + fan_out) as f64).sqrt();
            let mut rng = SplitMix64::tensor_stream(seed, tensor_index);
            (0..n).map(|_| rng.uniform_range(-a, a) as f32).collect()
        }
        InitKind::ScaledNormal => {
            let (fan_in, _) = fans(shape);
            let std = (2.0 / fan_in as f64).sqrt();
            let mut rng = SplitMix64::tensor_stream(seed, tensor_index);
            let mut vals = Vec::with_capacity(n + 1);
            while vals.len() < n {
                // Box-Muller in the same draw order as python (both outputs).
                let (a, b) = rng.normal_pair();
                vals.push((a * std) as f32);
                vals.push((b * std) as f32);
            }
            vals.truncate(n);
            vals
        }
    }
}

/// Initialize every parameter of a model, in manifest order.
pub fn init_params(seed: u64, specs: &[ParamSpec]) -> Vec<Vec<f32>> {
    specs
        .iter()
        .enumerate()
        .map(|(i, p)| init_tensor(seed, i as u64, &p.shape, p.init))
        .collect()
}

/// Build a fresh [`ModelState`] (params + zeroed momentum) per a model's
/// parameter specs — the one init recipe shared by every backend, so
/// cross-backend checkpoints can never drift apart.
pub fn init_state(info: &ModelInfo, seed: u64) -> Result<ModelState> {
    let mut params = Vec::with_capacity(info.params.len());
    let mut mom = Vec::with_capacity(info.params.len());
    for (i, p) in info.params.iter().enumerate() {
        let data = init_tensor(seed, i as u64, &p.shape, p.init);
        params.push(HostTensor::new(p.shape.clone(), data).to_literal()?);
        mom.push(HostTensor::zeros(p.shape.clone()).to_literal()?);
    }
    Ok(ModelState { model: info.name.clone(), params, mom, step: 0 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glorot_bounds_and_spread() {
        let t = init_tensor(7, 0, &[64, 128], InitKind::GlorotUniform);
        let a = (6.0f64 / (64 + 128) as f64).sqrt() as f32;
        assert_eq!(t.len(), 64 * 128);
        assert!(t.iter().all(|&x| (-a..=a).contains(&x)));
        let std = crate::util::stats::variance(&t).sqrt() as f32;
        assert!(std > a / 4.0, "degenerate init std={std}");
    }

    #[test]
    fn lstm_bias_gates() {
        let t = init_tensor(7, 3, &[256], InitKind::LstmBias);
        assert!(t[64..128].iter().all(|&x| x == 1.0));
        assert!(t[..64].iter().all(|&x| x == 0.0));
        assert!(t[128..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn scaled_normal_moments() {
        let t = init_tensor(7, 1, &[3, 3, 16, 32], InitKind::ScaledNormal);
        let fan_in = 3 * 3 * 16;
        let std = (2.0f64 / fan_in as f64).sqrt();
        let got = crate::util::stats::variance(&t).sqrt();
        assert!((got - std).abs() < std * 0.15, "std {got} vs {std}");
        assert!(crate::util::stats::mean(&t).abs() < std * 0.1);
    }

    #[test]
    fn conv_fans_use_receptive_field() {
        assert_eq!(fans(&[3, 3, 16, 32]), (144, 288));
        assert_eq!(fans(&[64, 128]), (64, 128));
        assert_eq!(fans(&[5]), (5, 5));
    }

    #[test]
    fn deterministic_and_stream_separated() {
        let a = init_tensor(42, 0, &[10, 10], InitKind::GlorotUniform);
        let b = init_tensor(42, 0, &[10, 10], InitKind::GlorotUniform);
        let c = init_tensor(42, 1, &[10, 10], InitKind::GlorotUniform);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}

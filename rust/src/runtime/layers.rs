//! The layered model IR of the native backend — what makes the paper's
//! upper-bound score architecture-agnostic in this codebase.
//!
//! The paper's central quantity (Eq. 1–2 / Eq. 20) is the gradient of the
//! loss with respect to the **last layer's pre-activations**: for softmax
//! cross-entropy that is `probs − onehot(y)`, whatever network produced the
//! logits. Its norm upper-bounds the per-sample gradient norm up to an
//! architecture-dependent constant, which is why one score drives
//! importance sampling for image CNNs, fine-tuning and sequence models
//! alike. This module encodes that: a [`LayerModel`] is an ordered stack of
//! [`Layer`]s with a softmax cross-entropy head, and the loss, the
//! upper-bound score ([`row_score`]), the exact per-sample gradient norm
//! ([`LayerModel::grad_norm_row`]) and a provable per-row dominance factor
//! ([`LayerModel::grad_norm_bound_factor`]) are all computed generically
//! over the stack — one implementation, any architecture.
//!
//! Layer variants:
//!
//! | variant           | params (shape, init)                | backward cost        |
//! |-------------------|-------------------------------------|----------------------|
//! | [`Layer::Dense`]  | `W [in,out]` glorot, `b [out]` zeros| `O(in·out)`          |
//! | [`Layer::Relu`]   | —                                   | `O(n)` mask          |
//! | [`Layer::Conv1d`] | `W [k,1,ic,oc]` glorot, `b [oc]`    | `O(t_out·k·ic·oc)`   |
//! | [`Layer::GlobalAvgPool`] | —                            | `O(n)`               |
//! | [`Layer::EmbeddingBag`]  | `E [rows,dim]` glorot        | `O(T·dim)`           |
//!
//! **Determinism contract.** Every forward/backward walk visits rows,
//! layers and tensor elements in a fixed order, so per-row outputs are pure
//! functions of `(params, row)` — the property the sharded scoring and
//! data-parallel training reductions build their bit-identity guarantee on.
//!
//! **Block-batched hot path.** The per-row walk
//! ([`LayerModel::forward_row`] / [`LayerModel::backward_row`]) is the
//! readable *scalar reference*; the engines execute whole worker chunks at
//! once through [`LayerModel::forward_block`] /
//! [`LayerModel::scores_block`] / [`LayerModel::backward_block`], built on
//! the cache-blocked microkernels of [`super::kernels`]. The kernels keep
//! every output element's f32 accumulation chain identical to the scalar
//! walk (lanes only across independent elements, reductions strictly
//! sequential), so the block path is **bit-identical** to the reference —
//! per-row results are a pure function of `(params, row)` regardless of
//! block size, chunk plan or worker count. `rust/tests/props.rs` pins
//! this.
//!
//! **MLP bit-compatibility.** A `[Dense, Relu, Dense]` stack reproduces the
//! pre-refactor fused two-layer MLP arithmetic operation for operation
//! (same accumulation order in the matmuls, same softmax, same masked
//! backward), so the PR 3 golden trajectories for `mlp10`/`mlp100` are
//! preserved bit for bit — and because the kernels are bit-identical to
//! that walk, they are preserved across the block-kernel refactor too.
//!
//! **bf16 scoring fast path.** Sample selection only needs score *ranking*
//! fidelity, so the presample pass can run over narrowed parameters:
//! [`LayerModel::quantize_params`] rounds a spec-shaped f32 parameter list
//! to bf16 storage once, and [`LayerModel::forward_block_bf16`] /
//! [`LayerModel::scores_block_bf16`] walk the same block path through the
//! bf16-storage kernels (f32 activations and accumulation, parameters
//! widened on the fly — half the parameter memory traffic). The bf16
//! scores are NOT bit-comparable to the f32 path (storage rounds every
//! parameter once) but are themselves fully deterministic: bit-identical
//! across kernel dispatch paths, block splits and worker counts. The
//! `bf16_` acceptance tests in `rust/tests/native_train.rs` pin the
//! ranking-fidelity contract (sampled-index overlap vs f32).

use anyhow::{bail, Context, Result};

use crate::util::bf16::{bf16_to_f32, f32_to_bf16};

use super::kernels;
use super::manifest::{InitKind, ParamSpec};

/// One layer of a [`LayerModel`] stack. Activations are flat row-major
/// `f32` buffers; layers that interpret them as `[time, channels]` signals
/// (`Conv1d`, `GlobalAvgPool`) document their layout inline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Layer {
    /// Fully connected: `out = in · W + b` (`W [in, out]` row-major).
    Dense { out_dim: usize },
    /// Elementwise `max(0, x)`.
    Relu,
    /// Valid 1-D convolution over a `[time, in_ch]` row-major signal:
    /// `out[t, o] = b[o] + Σ_{k, c} in[t·stride + k, c] · W[k, c, o]`,
    /// producing `[(time − kernel)/stride + 1, out_ch]`.
    Conv1d { in_ch: usize, out_ch: usize, kernel: usize, stride: usize },
    /// Mean over time of a `[time, channels]` signal → `[channels]`.
    GlobalAvgPool { channels: usize },
    /// Token-sequence bag: each of the `T` input scalars is quantized into
    /// one of `vocab` bins over `[lo, hi)` (jointly with its position when
    /// `positional`, giving `T · vocab` embedding rows), the selected
    /// embedding rows are averaged, and the mean is scaled by `gain`
    /// (`gain = T` recovers sum pooling; a plain mean attenuates the
    /// activations by `1/T`, which buries the signal under deep-glorot
    /// init). Not differentiable w.r.t. its *input* (quantization), so it
    /// must be the first layer of a stack whose inputs need no gradient.
    EmbeddingBag { vocab: usize, dim: usize, lo: f32, hi: f32, positional: bool, gain: f32 },
}

/// Quantize one input scalar into a `vocab`-bin token over `[lo, hi)`.
fn bag_token(v: f32, vocab: usize, lo: f32, hi: f32) -> usize {
    let f = (v - lo) / (hi - lo) * vocab as f32;
    if !f.is_finite() || f <= 0.0 {
        return 0;
    }
    (f as usize).min(vocab - 1)
}

/// Embedding row selected by position `p` holding value `v`.
fn bag_row(p: usize, v: f32, vocab: usize, lo: f32, hi: f32, positional: bool) -> usize {
    let tok = bag_token(v, vocab, lo, hi);
    if positional {
        p * vocab + tok
    } else {
        tok
    }
}

/// `gin[i] = Σ_o W[i, o] · gout[o]` — the dense input gradient, shared by
/// the accumulate and norm walks so their numerics cannot drift.
fn dense_input_grad(w: &[f32], gout: &[f32], gin: &mut [f32], out_dim: usize) {
    for (i, gi) in gin.iter_mut().enumerate() {
        let row = &w[i * out_dim..(i + 1) * out_dim];
        *gi = row.iter().zip(gout).map(|(&wv, &g)| wv * g).sum();
    }
}

/// Geometry of one [`Layer::Conv1d`]; hosts the backward kernels shared by
/// the accumulate and norm walks.
struct Conv1dGeom {
    in_ch: usize,
    out_ch: usize,
    kernel: usize,
    stride: usize,
}

impl Conv1dGeom {
    /// Accumulate `gW += x ⊗ g` (window-summed) and `gb += g` for one row.
    fn param_grads(&self, input: &[f32], gout: &[f32], gw: &mut [f32], gb: &mut [f32]) {
        let t_out = gout.len() / self.out_ch;
        for t in 0..t_out {
            let g = &gout[t * self.out_ch..(t + 1) * self.out_ch];
            for (gbv, &gv) in gb.iter_mut().zip(g) {
                *gbv += gv;
            }
            for k in 0..self.kernel {
                let x0 = (t * self.stride + k) * self.in_ch;
                for c in 0..self.in_ch {
                    let xv = input[x0 + c];
                    if xv != 0.0 {
                        let w0 = (k * self.in_ch + c) * self.out_ch;
                        for (g2, &gv) in gw[w0..w0 + self.out_ch].iter_mut().zip(g) {
                            *g2 += xv * gv;
                        }
                    }
                }
            }
        }
    }

    /// `gin += Wᵀ · g`, scattered back through the conv windows.
    fn input_grad(&self, w: &[f32], gout: &[f32], gin: &mut [f32]) {
        let t_out = gout.len() / self.out_ch;
        for t in 0..t_out {
            let g = &gout[t * self.out_ch..(t + 1) * self.out_ch];
            for k in 0..self.kernel {
                let x0 = (t * self.stride + k) * self.in_ch;
                for c in 0..self.in_ch {
                    let w0 = (k * self.in_ch + c) * self.out_ch;
                    let row = &w[w0..w0 + self.out_ch];
                    let dv: f32 = row.iter().zip(g).map(|(&wv, &gv)| wv * gv).sum();
                    gin[x0 + c] += dv;
                }
            }
        }
    }
}

/// `gin[t, c] = gout[c] / t_in` — the mean-pool input gradient.
fn pool_input_grad(gout: &[f32], gin: &mut [f32], channels: usize) {
    let t_in = gin.len() / channels;
    let inv = 1.0 / t_in as f32;
    for t in 0..t_in {
        let x0 = t * channels;
        for (gi, &gv) in gin[x0..x0 + channels].iter_mut().zip(gout) {
            *gi = gv * inv;
        }
    }
}

/// Relu mask: pass `gout` through where the forward output was positive.
fn relu_input_grad(output: &[f32], gout: &[f32], gin: &mut [f32]) {
    for ((gi, &ov), &gv) in gin.iter_mut().zip(output).zip(gout) {
        *gi = if ov > 0.0 { gv } else { 0.0 };
    }
}

impl Layer {
    /// Output dimension for an `in_dim`-dimensional input; errors when the
    /// layer cannot consume such an input.
    fn out_dim(&self, in_dim: usize) -> Result<usize> {
        match *self {
            Layer::Dense { out_dim } => {
                if out_dim == 0 {
                    bail!("dense layer needs out_dim >= 1");
                }
                Ok(out_dim)
            }
            Layer::Relu => Ok(in_dim),
            Layer::Conv1d { in_ch, out_ch, kernel, stride } => {
                if in_ch == 0 || out_ch == 0 || kernel == 0 || stride == 0 {
                    bail!("conv1d needs in_ch, out_ch, kernel, stride >= 1");
                }
                if in_dim % in_ch != 0 {
                    bail!("conv1d input dim {in_dim} is not divisible by in_ch {in_ch}");
                }
                let t_in = in_dim / in_ch;
                if t_in < kernel {
                    bail!("conv1d signal length {t_in} is shorter than kernel {kernel}");
                }
                Ok(((t_in - kernel) / stride + 1) * out_ch)
            }
            Layer::GlobalAvgPool { channels } => {
                if channels == 0 || in_dim % channels != 0 {
                    bail!("global-avg-pool input dim {in_dim} is not divisible by {channels}");
                }
                Ok(channels)
            }
            Layer::EmbeddingBag { vocab, dim, lo, hi, gain, .. } => {
                if vocab == 0 || dim == 0 {
                    bail!("embedding bag needs vocab, dim >= 1");
                }
                if !(hi > lo) || !gain.is_finite() || gain <= 0.0 {
                    bail!("embedding bag needs hi > lo and a positive finite gain");
                }
                Ok(dim)
            }
        }
    }

    /// This layer's parameter tensors (name/shape/init), in the order the
    /// flat parameter list stores them.
    fn param_specs(&self, in_dim: usize, idx: usize) -> Vec<ParamSpec> {
        let w = format!("l{idx}.w");
        let b = format!("l{idx}.b");
        match *self {
            Layer::Dense { out_dim } => vec![
                ParamSpec { name: w, shape: vec![in_dim, out_dim], init: InitKind::GlorotUniform },
                ParamSpec { name: b, shape: vec![out_dim], init: InitKind::Zeros },
            ],
            // HWIO with a singleton W axis, so `init::fans` applies the
            // conv receptive-field scaling to the glorot bound.
            Layer::Conv1d { in_ch, out_ch, kernel, .. } => vec![
                ParamSpec {
                    name: w,
                    shape: vec![kernel, 1, in_ch, out_ch],
                    init: InitKind::GlorotUniform,
                },
                ParamSpec { name: b, shape: vec![out_ch], init: InitKind::Zeros },
            ],
            Layer::EmbeddingBag { vocab, dim, positional, .. } => {
                let rows = if positional { in_dim * vocab } else { vocab };
                vec![ParamSpec {
                    name: format!("l{idx}.emb"),
                    shape: vec![rows, dim],
                    init: InitKind::GlorotUniform,
                }]
            }
            Layer::Relu | Layer::GlobalAvgPool { .. } => vec![],
        }
    }

    fn num_param_tensors(&self) -> usize {
        match self {
            Layer::Dense { .. } | Layer::Conv1d { .. } => 2,
            Layer::EmbeddingBag { .. } => 1,
            Layer::Relu | Layer::GlobalAvgPool { .. } => 0,
        }
    }

    /// Forward one row. `out` is pre-sized to this layer's output dim.
    fn forward(&self, params: &[Vec<f32>], input: &[f32], out: &mut [f32]) {
        match *self {
            Layer::Dense { out_dim } => {
                let (w, b) = (&params[0], &params[1]);
                out.copy_from_slice(b);
                for (i, &xi) in input.iter().enumerate() {
                    let row = &w[i * out_dim..(i + 1) * out_dim];
                    for (o, &wv) in out.iter_mut().zip(row) {
                        *o += xi * wv;
                    }
                }
            }
            Layer::Relu => {
                for (o, &v) in out.iter_mut().zip(input) {
                    *o = v.max(0.0);
                }
            }
            Layer::Conv1d { in_ch, out_ch, kernel, stride } => {
                let (w, b) = (&params[0], &params[1]);
                let t_out = out.len() / out_ch;
                for t in 0..t_out {
                    let os = &mut out[t * out_ch..(t + 1) * out_ch];
                    os.copy_from_slice(b);
                    for k in 0..kernel {
                        let x0 = (t * stride + k) * in_ch;
                        for c in 0..in_ch {
                            let xv = input[x0 + c];
                            let w0 = (k * in_ch + c) * out_ch;
                            for (o, &wv) in os.iter_mut().zip(&w[w0..w0 + out_ch]) {
                                *o += xv * wv;
                            }
                        }
                    }
                }
            }
            Layer::GlobalAvgPool { channels } => {
                let t_in = input.len() / channels;
                out.fill(0.0);
                for t in 0..t_in {
                    let x0 = t * channels;
                    for (o, &v) in out.iter_mut().zip(&input[x0..x0 + channels]) {
                        *o += v;
                    }
                }
                let inv = 1.0 / t_in as f32;
                for o in out.iter_mut() {
                    *o *= inv;
                }
            }
            Layer::EmbeddingBag { vocab, dim, lo, hi, positional, gain } => {
                let e = &params[0];
                out.fill(0.0);
                for (p, &v) in input.iter().enumerate() {
                    let row = bag_row(p, v, vocab, lo, hi, positional);
                    for (o, &ev) in out.iter_mut().zip(&e[row * dim..(row + 1) * dim]) {
                        *o += ev;
                    }
                }
                let scale = gain / input.len() as f32;
                for o in out.iter_mut() {
                    *o *= scale;
                }
            }
        }
    }

    /// Backward one row: accumulate this layer's parameter gradients into
    /// `grads` (the per-coefficient scaling is already folded into `gout`)
    /// and, when `gin` is given (pre-zeroed, input-sized), the gradient
    /// w.r.t. the layer's input. `output` is this layer's forward output
    /// (only `Relu` reads it). Accumulation order is fixed — see the
    /// module-level determinism contract.
    fn backward(
        &self,
        params: &[Vec<f32>],
        input: &[f32],
        output: &[f32],
        gout: &[f32],
        grads: &mut [Vec<f32>],
        gin: Option<&mut Vec<f32>>,
    ) {
        match *self {
            Layer::Dense { out_dim } => {
                let (gw, gb) = grads.split_at_mut(1);
                for (i, &xi) in input.iter().enumerate() {
                    if xi != 0.0 {
                        let row = &mut gw[0][i * out_dim..(i + 1) * out_dim];
                        for (g, &gv) in row.iter_mut().zip(gout) {
                            *g += xi * gv;
                        }
                    }
                }
                for (g, &gv) in gb[0].iter_mut().zip(gout) {
                    *g += gv;
                }
                if let Some(gin) = gin {
                    dense_input_grad(&params[0], gout, gin, out_dim);
                }
            }
            Layer::Relu => {
                if let Some(gin) = gin {
                    relu_input_grad(output, gout, gin);
                }
            }
            Layer::Conv1d { in_ch, out_ch, kernel, stride } => {
                let geom = Conv1dGeom { in_ch, out_ch, kernel, stride };
                {
                    let (gw, gb) = grads.split_at_mut(1);
                    geom.param_grads(input, gout, &mut gw[0], &mut gb[0]);
                }
                if let Some(gin) = gin {
                    geom.input_grad(&params[0], gout, gin);
                }
            }
            Layer::GlobalAvgPool { channels } => {
                if let Some(gin) = gin {
                    pool_input_grad(gout, gin, channels);
                }
            }
            Layer::EmbeddingBag { vocab, dim, lo, hi, positional, gain } => {
                let scale = gain / input.len() as f32;
                for (p, &v) in input.iter().enumerate() {
                    let row = bag_row(p, v, vocab, lo, hi, positional);
                    for (ge, &gv) in grads[0][row * dim..(row + 1) * dim].iter_mut().zip(gout) {
                        *ge += scale * gv;
                    }
                }
                // quantization: zero gradient w.r.t. the input almost
                // everywhere (the layer is gated to the front of a stack)
                if let Some(gin) = gin {
                    gin.fill(0.0);
                }
            }
        }
    }

    /// Forward a whole `rows`-row block at once (row-major `input`/`out`)
    /// through the cache-blocked kernels — bit-identical per row to
    /// [`forward`](Self::forward); see `runtime::kernels`. `patch` is this
    /// layer's persistent im2col buffer (`Conv1d` only; the backward pass
    /// re-reads it).
    fn forward_block(
        &self,
        params: &[Vec<f32>],
        input: &[f32],
        rows: usize,
        out: &mut [f32],
        patch: &mut Vec<f32>,
    ) {
        match *self {
            Layer::Dense { out_dim } => {
                let in_dim = input.len() / rows;
                let (w, b) = (&params[0], &params[1]);
                kernels::bias_init(b, rows, out);
                kernels::gemm_acc(input, rows, in_dim, w, out_dim, out);
            }
            Layer::Relu => {
                for (o, &v) in out.iter_mut().zip(input) {
                    *o = v.max(0.0);
                }
            }
            Layer::Conv1d { in_ch, out_ch, kernel, stride } => {
                let in_dim = input.len() / rows;
                let t_out = out.len() / rows / out_ch;
                let (w, b) = (&params[0], &params[1]);
                kernels::im2col(input, rows, in_dim, in_ch, kernel, stride, t_out, patch);
                let rt = rows * t_out;
                kernels::bias_init(b, rt, out);
                kernels::gemm_acc(patch, rt, kernel * in_ch, w, out_ch, out);
            }
            // gather/scatter layers: per-row walk (already unit-stride)
            Layer::GlobalAvgPool { .. } | Layer::EmbeddingBag { .. } => {
                let in_dim = input.len() / rows;
                let out_dim = out.len() / rows;
                for r in 0..rows {
                    self.forward(
                        params,
                        &input[r * in_dim..][..in_dim],
                        &mut out[r * out_dim..][..out_dim],
                    );
                }
            }
        }
    }

    /// Forward a block over **bf16-storage parameters** — the
    /// reduced-precision scoring fast path. Activations and accumulation
    /// stay f32; parameters are widened on the fly inside the kernels (an
    /// exact bit extension), so the walk order and scratch layout match
    /// [`forward_block`](Self::forward_block) exactly. Param-free layers
    /// run their ordinary (bit-identical) f32 block walk.
    fn forward_block_bf16(
        &self,
        params: &[Vec<u16>],
        input: &[f32],
        rows: usize,
        out: &mut [f32],
        patch: &mut Vec<f32>,
    ) {
        match *self {
            Layer::Dense { out_dim } => {
                let in_dim = input.len() / rows;
                let (w, b) = (&params[0], &params[1]);
                kernels::bias_init_bf16(b, rows, out);
                kernels::gemm_acc_bf16(input, rows, in_dim, w, out_dim, out);
            }
            Layer::Relu => {
                for (o, &v) in out.iter_mut().zip(input) {
                    *o = v.max(0.0);
                }
            }
            Layer::Conv1d { in_ch, out_ch, kernel, stride } => {
                let in_dim = input.len() / rows;
                let t_out = out.len() / rows / out_ch;
                let (w, b) = (&params[0], &params[1]);
                kernels::im2col(input, rows, in_dim, in_ch, kernel, stride, t_out, patch);
                let rt = rows * t_out;
                kernels::bias_init_bf16(b, rt, out);
                kernels::gemm_acc_bf16(patch, rt, kernel * in_ch, w, out_ch, out);
            }
            // param-free gather layer: the f32 walk IS the bf16 walk
            Layer::GlobalAvgPool { .. } => {
                let in_dim = input.len() / rows;
                let out_dim = out.len() / rows;
                for r in 0..rows {
                    self.forward(
                        &[],
                        &input[r * in_dim..][..in_dim],
                        &mut out[r * out_dim..][..out_dim],
                    );
                }
            }
            Layer::EmbeddingBag { vocab, dim, lo, hi, positional, gain } => {
                let e = &params[0];
                let in_dim = input.len() / rows;
                let scale = gain / in_dim as f32;
                for (r, inp) in input.chunks_exact(in_dim).enumerate() {
                    let out_r = &mut out[r * dim..][..dim];
                    out_r.fill(0.0);
                    for (p, &v) in inp.iter().enumerate() {
                        let row = bag_row(p, v, vocab, lo, hi, positional);
                        for (o, &eb) in out_r.iter_mut().zip(&e[row * dim..(row + 1) * dim]) {
                            *o += bf16_to_f32(eb);
                        }
                    }
                    for o in out_r.iter_mut() {
                        *o *= scale;
                    }
                }
            }
        }
    }

    /// Backward a whole block: accumulate this layer's parameter gradients
    /// into `grads` and, when `gin` is given (pre-zeroed, `rows × in_dim`),
    /// the gradient w.r.t. the layer's input block. Bit-identical to
    /// running [`backward`](Self::backward) row by row in index order (see
    /// `runtime::kernels`, including the zero-activation-skip note).
    /// `patch` must hold this layer's im2col patches from the matching
    /// `forward_block`; `gpatch` is shared col2im staging.
    #[allow(clippy::too_many_arguments)]
    fn backward_block(
        &self,
        params: &[Vec<f32>],
        input: &[f32],
        output: &[f32],
        gout: &[f32],
        rows: usize,
        grads: &mut [Vec<f32>],
        gin: Option<&mut [f32]>,
        patch: &[f32],
        gpatch: &mut Vec<f32>,
    ) {
        match *self {
            Layer::Dense { out_dim } => {
                let in_dim = input.len() / rows;
                let (gw, gb) = grads.split_at_mut(1);
                kernels::gemm_at_b_acc(input, gout, rows, in_dim, out_dim, &mut gw[0]);
                kernels::bias_acc(gout, rows, out_dim, &mut gb[0]);
                if let Some(gin) = gin {
                    kernels::gemm_b_wt(gout, &params[0], rows, in_dim, out_dim, gin);
                }
            }
            Layer::Relu => {
                if let Some(gin) = gin {
                    relu_input_grad(output, gout, gin);
                }
            }
            Layer::Conv1d { in_ch, out_ch, kernel, stride } => {
                let in_dim = input.len() / rows;
                let t_out = gout.len() / rows / out_ch;
                let rt = rows * t_out;
                let kc = kernel * in_ch;
                {
                    let (gw, gb) = grads.split_at_mut(1);
                    kernels::gemm_at_b_acc(patch, gout, rt, kc, out_ch, &mut gw[0]);
                    kernels::bias_acc(gout, rt, out_ch, &mut gb[0]);
                }
                if let Some(gin) = gin {
                    // gemm_b_wt assigns every element: fix the length only
                    if gpatch.len() != rt * kc {
                        gpatch.clear();
                        gpatch.resize(rt * kc, 0.0);
                    }
                    kernels::gemm_b_wt(gout, &params[0], rt, kc, out_ch, gpatch);
                    kernels::col2im_acc(gpatch, rows, in_dim, in_ch, kernel, stride, t_out, gin);
                }
            }
            Layer::GlobalAvgPool { channels } => {
                if let Some(gin) = gin {
                    let in_dim = gin.len() / rows;
                    for (r, ginr) in gin.chunks_exact_mut(in_dim).enumerate() {
                        pool_input_grad(&gout[r * channels..][..channels], ginr, channels);
                    }
                }
            }
            Layer::EmbeddingBag { vocab, dim, lo, hi, positional, gain } => {
                let in_dim = input.len() / rows;
                let scale = gain / in_dim as f32;
                for (r, inp) in input.chunks_exact(in_dim).enumerate() {
                    let gr = &gout[r * dim..][..dim];
                    for (p, &v) in inp.iter().enumerate() {
                        let row = bag_row(p, v, vocab, lo, hi, positional);
                        for (ge, &gv) in grads[0][row * dim..(row + 1) * dim].iter_mut().zip(gr) {
                            *ge += scale * gv;
                        }
                    }
                }
                // gin (if any) keeps its pre-zeroed value: quantization has
                // zero input gradient almost everywhere
            }
        }
    }

    /// Squared norm of this layer's per-row parameter gradient, plus `gin`
    /// when requested (same contract as [`backward`](Self::backward)).
    /// Dense and embedding norms are exact closed forms; conv materializes
    /// its (small) weight-gradient into `wscratch` because overlapping
    /// windows make the norm non-separable.
    fn grad_sq_norm(
        &self,
        params: &[Vec<f32>],
        input: &[f32],
        output: &[f32],
        gout: &[f32],
        gin: Option<&mut Vec<f32>>,
        wscratch: &mut Vec<f32>,
    ) -> f32 {
        match *self {
            Layer::Dense { out_dim } => {
                // ‖x ⊗ g‖²_F = ‖x‖²‖g‖² and ‖gb‖² = ‖g‖², so the layer
                // contributes ‖g‖²·(1 + ‖x‖²) — the Eq.-20 decomposition.
                let g2: f32 = gout.iter().map(|g| g * g).sum();
                let x2: f32 = input.iter().map(|v| v * v).sum();
                if let Some(gin) = gin {
                    dense_input_grad(&params[0], gout, gin, out_dim);
                }
                g2 * (1.0 + x2)
            }
            Layer::Relu => {
                if let Some(gin) = gin {
                    relu_input_grad(output, gout, gin);
                }
                0.0
            }
            Layer::Conv1d { in_ch, out_ch, kernel, stride } => {
                // overlapping windows make the conv weight-grad norm
                // non-separable: materialize gW and gb into the reusable
                // scratch (no per-row allocation) and square-sum it
                let geom = Conv1dGeom { in_ch, out_ch, kernel, stride };
                let wlen = params[0].len();
                wscratch.clear();
                wscratch.resize(wlen + out_ch, 0.0);
                {
                    let (gw, gb) = wscratch.split_at_mut(wlen);
                    geom.param_grads(input, gout, gw, gb);
                }
                let n2: f32 = wscratch.iter().map(|g| g * g).sum();
                if let Some(gin) = gin {
                    geom.input_grad(&params[0], gout, gin);
                }
                n2
            }
            Layer::GlobalAvgPool { channels } => {
                if let Some(gin) = gin {
                    pool_input_grad(gout, gin, channels);
                }
                0.0
            }
            Layer::EmbeddingBag { vocab, dim: _, lo, hi, positional, gain } => {
                // gE[row] = (gain/T)·count_row·gout, so the norm is exactly
                // (gain/T)²·Σ count²·‖gout‖². A positional bag hits one
                // distinct row per position (Σ count² = T); a plain bag
                // histograms its vocab occupancy into the reusable scratch
                // — either way no per-row allocation on the oracle path.
                let t = input.len();
                let scale = gain / t as f32;
                let g2: f32 = gout.iter().map(|g| g * g).sum();
                let sum_c2: f32 = if positional {
                    t as f32
                } else {
                    wscratch.clear();
                    wscratch.resize(vocab, 0.0);
                    for &v in input {
                        wscratch[bag_token(v, vocab, lo, hi)] += 1.0;
                    }
                    wscratch.iter().map(|c| c * c).sum()
                };
                if let Some(gin) = gin {
                    gin.fill(0.0);
                }
                scale * scale * sum_c2 * g2
            }
        }
    }
}

/// Softmax cross-entropy loss of one row from its softmax probs — the one
/// formula every native entry (scoring, training, evaluation) uses, so
/// their numerics can never drift apart.
pub(crate) fn row_loss(probs: &[f32], y: usize) -> f32 {
    -(probs[y] + 1e-12).ln()
}

/// The paper's Eq.-20 upper-bound score `‖probs − onehot(y)‖₂` of one row:
/// the norm of the loss gradient at the last layer's pre-activations —
/// computed here, once, for **any** layer stack.
pub fn row_score(probs: &[f32], y: usize) -> f32 {
    let mut norm2 = 0.0f32;
    for (k, &p) in probs.iter().enumerate() {
        let g = if k == y { p - 1.0 } else { p };
        norm2 += g * g;
    }
    norm2.sqrt()
}

/// In-place softmax — bit-identical to the pre-refactor fused MLP head.
fn softmax_in_place(z: &mut [f32]) {
    let max = z.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut denom = 0.0f32;
    for p in z.iter_mut() {
        *p = (*p - max).exp();
        denom += *p;
    }
    for p in z.iter_mut() {
        *p /= denom;
    }
}

/// Reusable buffers for one row's **scalar-reference** forward/backward
/// walk ([`LayerModel::forward_row`] / [`LayerModel::backward_row`] — the
/// readable spec the block kernels are asserted bit-identical against).
/// The buffers are meaningful only between a `forward_row` and the calls
/// that consume it. The engines' hot paths use [`BlockScratch`] instead.
pub struct Scratch {
    /// `acts[i]` = output of `layers[i]`; the last entry holds the logits,
    /// then (after the softmax head) the probabilities, then — once the
    /// caller seeds the backward pass — the scaled softmax gradient.
    acts: Vec<Vec<f32>>,
    /// Ping-pong buffers for the inter-layer gradient.
    ga: Vec<f32>,
    gb: Vec<f32>,
}

impl Scratch {
    /// The softmax probabilities of the last `forward_row`.
    pub fn probs(&self) -> &[f32] {
        self.acts.last().expect("layer stacks are non-empty")
    }

    /// Mutable view of the probabilities — how the training path turns
    /// them into the (coefficient-scaled) softmax gradient in place before
    /// [`LayerModel::backward_row`].
    pub fn probs_mut(&mut self) -> &mut [f32] {
        self.acts.last_mut().expect("layer stacks are non-empty")
    }
}

/// Reusable buffers for a **block-batched** forward/backward walk over a
/// whole worker chunk of rows at once (callers bound their block size by
/// [`kernels::MAX_BLOCK_ROWS`]; any row count is numerically equivalent —
/// see the module docs). One `BlockScratch` per in-flight chunk keeps the
/// hot path allocation-free; the engines and scorers keep warm arenas in a
/// [`super::pool::ObjectPool`] so nothing is allocated per step. Buffers
/// are meaningful only between a [`LayerModel::forward_block`] and the
/// calls that consume it.
#[derive(Debug, Default)]
pub struct BlockScratch {
    /// `acts[i]` = output block of `layers[i]` (`rows × dims[i+1]`,
    /// row-major); the last entry holds the logits, then (after the
    /// softmax head) the probabilities, then — once the caller seeds the
    /// backward pass — the scaled softmax-gradient block.
    acts: Vec<Vec<f32>>,
    /// Per-layer im2col patch buffers (`Conv1d` layers only), filled by
    /// the forward pass and re-read by the backward pass.
    patch: Vec<Vec<f32>>,
    /// Ping-pong buffers for the inter-layer gradient block.
    ga: Vec<f32>,
    gb: Vec<f32>,
    /// col2im staging for the conv input gradient.
    gpatch: Vec<f32>,
    /// Conv weight-gradient / bag-histogram scratch of the gradient-norm
    /// oracle, reused across rows.
    wscratch: Vec<f32>,
    /// Spare per-row output lane (the scorer's unwanted loss/score side).
    pub(crate) tmp: Vec<f32>,
}

impl BlockScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Lay the activation/patch lists out for `model` at `rows` rows.
    /// Buffers only reallocate when they grow past capacity, so a warm
    /// arena is allocation-free — including when reused across models.
    fn ensure(&mut self, model: &LayerModel, rows: usize) {
        let nl = model.layers.len();
        self.acts.resize_with(nl, Vec::new);
        self.patch.resize_with(nl, Vec::new);
        for (a, &d) in self.acts.iter_mut().zip(&model.dims[1..]) {
            let want = rows * d;
            if a.len() != want {
                a.clear();
                a.resize(want, 0.0);
            }
        }
    }

    /// The softmax probability block of the last
    /// [`LayerModel::forward_block`] (`rows × num_classes`, row-major).
    pub fn probs(&self) -> &[f32] {
        self.acts.last().expect("layer stacks are non-empty")
    }

    /// Mutable view of the probability block — how the training path seeds
    /// the (coefficient-scaled) softmax gradient in place before
    /// [`LayerModel::backward_block`].
    pub fn probs_mut(&mut self) -> &mut [f32] {
        self.acts.last_mut().expect("layer stacks are non-empty")
    }
}

/// An ordered layer stack with a softmax cross-entropy head — the model IR
/// every native entry point (`train_step`, `fwd_scores`, `grad_norms`,
/// `eval_metrics`, …) walks. See the module docs.
#[derive(Debug, Clone)]
pub struct LayerModel {
    layers: Vec<Layer>,
    /// `dims[0]` = input dim; `dims[i + 1]` = output dim of `layers[i]`.
    dims: Vec<usize>,
    /// Index of each layer's first tensor in the flat parameter list.
    param_start: Vec<usize>,
    /// Element count of every parameter tensor, in flat list order.
    param_elems: Vec<usize>,
    /// First layer owning parameters: the backward walk computes no input
    /// gradient below it.
    first_param_layer: usize,
}

impl LayerModel {
    pub fn new(in_dim: usize, layers: Vec<Layer>) -> Result<Self> {
        if in_dim == 0 {
            bail!("layer model needs in_dim >= 1");
        }
        if layers.is_empty() {
            bail!("layer model needs at least one layer");
        }
        if !matches!(layers.last(), Some(Layer::Dense { .. })) {
            bail!("layer stacks must end in a Dense layer (the softmax head)");
        }
        if layers.iter().skip(1).any(|l| matches!(l, Layer::EmbeddingBag { .. })) {
            bail!("EmbeddingBag is input quantization and must be the first layer");
        }
        let mut dims = Vec::with_capacity(layers.len() + 1);
        dims.push(in_dim);
        for (i, layer) in layers.iter().enumerate() {
            let d = layer.out_dim(dims[i]).with_context(|| format!("layer {i} ({layer:?})"))?;
            dims.push(d);
        }
        let head = dims[dims.len() - 1];
        if head < 2 {
            bail!("softmax head needs >= 2 classes, got {head}");
        }
        let mut param_start = Vec::with_capacity(layers.len());
        let mut param_elems = Vec::new();
        let mut first_param_layer = usize::MAX;
        let mut n = 0;
        for (i, layer) in layers.iter().enumerate() {
            param_start.push(n);
            let specs = layer.param_specs(dims[i], i);
            if !specs.is_empty() && first_param_layer == usize::MAX {
                first_param_layer = i;
            }
            n += specs.len();
            param_elems.extend(specs.iter().map(|s| s.elements()));
        }
        Ok(Self { layers, dims, param_start, param_elems, first_param_layer })
    }

    /// The two-layer MLP stack — the pre-refactor native architecture.
    pub fn mlp(feature_dim: usize, hidden: usize, num_classes: usize) -> Result<Self> {
        Self::new(
            feature_dim,
            vec![
                Layer::Dense { out_dim: hidden },
                Layer::Relu,
                Layer::Dense { out_dim: num_classes },
            ],
        )
    }

    pub fn in_dim(&self) -> usize {
        self.dims[0]
    }

    pub fn num_classes(&self) -> usize {
        // dims is never empty: new() seeds it with in_dim
        self.dims[self.dims.len() - 1]
    }

    pub fn layers(&self) -> &[Layer] {
        &self.layers
    }

    /// Activation dimensions: `dims()[0]` is the input, `dims()[i + 1]`
    /// the output of layer `i`.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn num_param_tensors(&self) -> usize {
        self.param_elems.len()
    }

    pub fn total_param_elements(&self) -> usize {
        self.param_elems.iter().sum()
    }

    /// Every parameter tensor (name/shape/init) in flat list order — the
    /// manifest-shaped description init, checkpointing and the SGD update
    /// iterate over.
    pub fn param_specs(&self) -> Vec<ParamSpec> {
        self.layers
            .iter()
            .enumerate()
            .flat_map(|(i, layer)| layer.param_specs(self.dims[i], i))
            .collect()
    }

    /// Check a flat host-parameter list against this model's specs.
    pub fn check_params(&self, params: &[Vec<f32>]) -> Result<()> {
        if params.len() != self.param_elems.len() {
            bail!(
                "layer model expects {} parameter tensors, got {}",
                self.param_elems.len(),
                params.len()
            );
        }
        for (i, (p, &want)) in params.iter().zip(&self.param_elems).enumerate() {
            if p.len() != want {
                bail!("parameter tensor {i} has {} elements, expected {want}", p.len());
            }
        }
        Ok(())
    }

    /// Fresh zero-filled gradient buffers, one per parameter tensor.
    pub fn zero_grads(&self) -> Vec<Vec<f32>> {
        self.param_elems.iter().map(|&n| vec![0.0; n]).collect()
    }

    /// Element counts of every parameter tensor, in flat list order —
    /// what pooled partial-gradient buffers are resized against.
    pub fn param_elems(&self) -> &[usize] {
        &self.param_elems
    }

    /// Fresh scalar-reference walk buffers (see [`Scratch`]).
    pub fn scratch(&self) -> Scratch {
        Scratch {
            acts: self.dims[1..].iter().map(|&d| vec![0.0; d]).collect(),
            ga: Vec::new(),
            gb: Vec::new(),
        }
    }

    /// Fresh block-walk buffers (see [`BlockScratch`]); engines keep them
    /// pooled per worker, sized lazily on first use.
    pub fn block_scratch(&self) -> BlockScratch {
        BlockScratch::new()
    }

    /// Labels outside `0..num_classes` clamp to the last class (the same
    /// tolerance the pre-refactor engine applied).
    pub fn clamp_label(&self, y: i32) -> usize {
        (y as usize).min(self.num_classes() - 1)
    }

    fn layer_params<'p>(&self, params: &'p [Vec<f32>], i: usize) -> &'p [Vec<f32>] {
        let start = self.param_start[i];
        &params[start..start + self.layers[i].num_param_tensors()]
    }

    fn layer_params_bf16<'p>(&self, params: &'p [Vec<u16>], i: usize) -> &'p [Vec<u16>] {
        let start = self.param_start[i];
        &params[start..start + self.layers[i].num_param_tensors()]
    }

    /// Narrow a spec-shaped f32 parameter list to bf16 storage (one
    /// round-to-nearest-even per element, [`crate::util::bf16`]) — the
    /// parameter form the reduced-precision scoring fast path walks.
    /// Quantize once per parameter version, score many blocks.
    pub fn quantize_params(&self, params: &[Vec<f32>]) -> Vec<Vec<u16>> {
        params.iter().map(|t| t.iter().map(|&v| f32_to_bf16(v)).collect()).collect()
    }

    /// Forward one row: fills `scratch.acts` layer by layer and applies the
    /// softmax head in place, leaving the probabilities in
    /// [`Scratch::probs`]. Callers must pass `in_dim` features and
    /// spec-shaped params (checked by the engine entry points).
    pub fn forward_row(&self, params: &[Vec<f32>], x: &[f32], scratch: &mut Scratch) {
        debug_assert_eq!(x.len(), self.dims[0]);
        for (i, layer) in self.layers.iter().enumerate() {
            let (prev, rest) = scratch.acts.split_at_mut(i);
            let input: &[f32] = if i == 0 { x } else { &prev[i - 1] };
            layer.forward(self.layer_params(params, i), input, &mut rest[0]);
        }
        softmax_in_place(scratch.probs_mut());
    }

    /// Loss and Eq.-20 upper-bound score of one row — the scoring entry
    /// shared by `fwd_scores`, the native scorer and the warmup path.
    pub fn row_scores(
        &self,
        params: &[Vec<f32>],
        x: &[f32],
        y: i32,
        scratch: &mut Scratch,
    ) -> (f32, f32) {
        self.forward_row(params, x, scratch);
        let yy = self.clamp_label(y);
        let probs = scratch.probs();
        (row_loss(probs, yy), row_score(probs, yy))
    }

    /// Backward one row, accumulating into `grads` (flat tensor list, same
    /// order as [`param_specs`](Self::param_specs)). The caller must have
    /// run [`forward_row`](Self::forward_row) on the same row and turned
    /// the probabilities in [`Scratch::probs_mut`] into the scaled softmax
    /// gradient (`probs[y] -= 1`, then `*= coeff`).
    pub fn backward_row(
        &self,
        params: &[Vec<f32>],
        x: &[f32],
        scratch: &mut Scratch,
        grads: &mut [Vec<f32>],
    ) {
        let last = self.layers.len() - 1;
        scratch.ga.clear();
        scratch.ga.extend_from_slice(&scratch.acts[last]);
        let mut cur: &mut Vec<f32> = &mut scratch.ga;
        let mut next: &mut Vec<f32> = &mut scratch.gb;
        for i in (0..self.layers.len()).rev() {
            let layer = &self.layers[i];
            let input: &[f32] = if i == 0 { x } else { &scratch.acts[i - 1] };
            let output: &[f32] = &scratch.acts[i];
            let start = self.param_start[i];
            let g = &mut grads[start..start + layer.num_param_tensors()];
            let p = self.layer_params(params, i);
            if i > self.first_param_layer {
                next.clear();
                next.resize(self.dims[i], 0.0);
                layer.backward(p, input, output, cur, g, Some(&mut *next));
                std::mem::swap(&mut cur, &mut next);
            } else {
                layer.backward(p, input, output, cur, g, None);
            }
        }
    }

    /// Forward a whole block of `rows` rows (`x` is `rows × in_dim`,
    /// row-major) through the cache-blocked kernels, leaving the softmax
    /// probability block in [`BlockScratch::probs`]. Bit-identical per row
    /// to [`forward_row`](Self::forward_row) — see `runtime::kernels` — so
    /// per-row outputs never depend on how a batch is blocked.
    pub fn forward_block(&self, params: &[Vec<f32>], x: &[f32], rows: usize, s: &mut BlockScratch) {
        debug_assert_eq!(x.len(), rows * self.dims[0]);
        s.ensure(self, rows);
        let BlockScratch { acts, patch, .. } = s;
        for (i, layer) in self.layers.iter().enumerate() {
            let (prev, rest) = acts.split_at_mut(i);
            let input: &[f32] = if i == 0 { x } else { &prev[i - 1] };
            let p = self.layer_params(params, i);
            layer.forward_block(p, input, rows, &mut rest[0], &mut patch[i]);
        }
        let c = self.num_classes();
        for p in acts.last_mut().expect("layer stacks are non-empty").chunks_exact_mut(c) {
            softmax_in_place(p);
        }
    }

    /// Loss + Eq.-20 upper-bound score of every row of a block — the
    /// **score-only fast path**: one block forward, no gradient scratch
    /// touched at all. Writes `out_loss[r]` / `out_score[r]` for
    /// `r < rows`; bit-identical per row to
    /// [`row_scores`](Self::row_scores).
    #[allow(clippy::too_many_arguments)]
    pub fn scores_block(
        &self,
        params: &[Vec<f32>],
        x: &[f32],
        y: &[i32],
        rows: usize,
        s: &mut BlockScratch,
        out_loss: &mut [f32],
        out_score: &mut [f32],
    ) {
        debug_assert!(y.len() >= rows && out_loss.len() >= rows && out_score.len() >= rows);
        self.forward_block(params, x, rows, s);
        let c = self.num_classes();
        for (r, prow) in s.probs().chunks_exact(c).enumerate() {
            let yy = self.clamp_label(y[r]);
            out_loss[r] = row_loss(prow, yy);
            out_score[r] = row_score(prow, yy);
        }
    }

    /// [`forward_block`](Self::forward_block) against bf16-stored
    /// parameters (from [`quantize_params`](Self::quantize_params)):
    /// weights widen to f32 lane-by-lane inside the kernels, activations
    /// and the softmax stay f32. Bit-identical across block splits and
    /// kernel dispatch paths, but NOT bit-comparable to the f32 walk —
    /// the storage rounding perturbs every weight. See the module doc.
    pub fn forward_block_bf16(
        &self,
        params: &[Vec<u16>],
        x: &[f32],
        rows: usize,
        s: &mut BlockScratch,
    ) {
        debug_assert_eq!(x.len(), rows * self.dims[0]);
        s.ensure(self, rows);
        let BlockScratch { acts, patch, .. } = s;
        for (i, layer) in self.layers.iter().enumerate() {
            let (prev, rest) = acts.split_at_mut(i);
            let input: &[f32] = if i == 0 { x } else { &prev[i - 1] };
            let p = self.layer_params_bf16(params, i);
            layer.forward_block_bf16(p, input, rows, &mut rest[0], &mut patch[i]);
        }
        let c = self.num_classes();
        if let Some(last) = acts.last_mut() {
            for p in last.chunks_exact_mut(c) {
                softmax_in_place(p);
            }
        }
    }

    /// [`scores_block`](Self::scores_block) through bf16 parameter
    /// storage — the reduced-precision presample scoring fast path. Same
    /// score-only contract (no gradient scratch touched); ranking
    /// fidelity vs the f32 path is pinned by the `bf16_` acceptance
    /// tests in `rust/tests/native_train.rs`.
    #[allow(clippy::too_many_arguments)]
    pub fn scores_block_bf16(
        &self,
        params: &[Vec<u16>],
        x: &[f32],
        y: &[i32],
        rows: usize,
        s: &mut BlockScratch,
        out_loss: &mut [f32],
        out_score: &mut [f32],
    ) {
        debug_assert!(y.len() >= rows && out_loss.len() >= rows && out_score.len() >= rows);
        self.forward_block_bf16(params, x, rows, s);
        let c = self.num_classes();
        for (r, prow) in s.probs().chunks_exact(c).enumerate() {
            let yy = self.clamp_label(y[r]);
            out_loss[r] = row_loss(prow, yy);
            out_score[r] = row_score(prow, yy);
        }
    }

    /// Accumulate the loss sum + correct-prediction count of a block —
    /// the eval-side twin of [`scores_block`](Self::scores_block),
    /// sharing its score-only fast path (one block forward, no gradient
    /// scratch). Accumulates into the caller's running sums so the f64
    /// loss chain stays strictly per-row sequential across block
    /// boundaries — bit-for-bit the historical `eval_metrics` walk,
    /// including its resolve-ties-to-the-LAST-maximal-class argmax.
    #[allow(clippy::too_many_arguments)]
    pub fn eval_block(
        &self,
        params: &[Vec<f32>],
        x: &[f32],
        y: &[i32],
        rows: usize,
        s: &mut BlockScratch,
        sum_loss: &mut f64,
        correct: &mut i64,
    ) {
        debug_assert!(y.len() >= rows);
        self.forward_block(params, x, rows, s);
        let c = self.num_classes();
        for (r, prow) in s.probs().chunks_exact(c).enumerate() {
            let yy = self.clamp_label(y[r]);
            *sum_loss += row_loss(prow, yy) as f64;
            let pred = prow
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(k, _)| k)
                .unwrap_or(0);
            if pred == yy {
                *correct += 1;
            }
        }
    }

    /// Backward a whole block, accumulating into `grads` (flat tensor
    /// list, same order as [`param_specs`](Self::param_specs)). The caller
    /// must have run [`forward_block`](Self::forward_block) on the same
    /// rows and turned the probability block in
    /// [`BlockScratch::probs_mut`] into the scaled softmax gradient
    /// (`probs[r][y_r] -= 1`, then `*= coeff_r`, per row). Bit-identical
    /// to the row-by-row [`backward_row`](Self::backward_row) walk in row
    /// order, for any block split of a batch.
    pub fn backward_block(
        &self,
        params: &[Vec<f32>],
        x: &[f32],
        rows: usize,
        s: &mut BlockScratch,
        grads: &mut [Vec<f32>],
    ) {
        let last = self.layers.len() - 1;
        let BlockScratch { acts, patch, ga, gb, gpatch, .. } = s;
        ga.clear();
        ga.extend_from_slice(&acts[last]);
        let mut cur: &mut Vec<f32> = ga;
        let mut next: &mut Vec<f32> = gb;
        for i in (0..self.layers.len()).rev() {
            let layer = &self.layers[i];
            let input: &[f32] = if i == 0 { x } else { &acts[i - 1] };
            let output: &[f32] = &acts[i];
            let start = self.param_start[i];
            let g = &mut grads[start..start + layer.num_param_tensors()];
            let p = self.layer_params(params, i);
            if i > self.first_param_layer {
                next.clear();
                next.resize(rows * self.dims[i], 0.0);
                let gin = Some(&mut next[..]);
                layer.backward_block(p, input, output, cur, rows, g, gin, &patch[i], gpatch);
                std::mem::swap(&mut cur, &mut next);
            } else {
                layer.backward_block(p, input, output, cur, rows, g, None, &patch[i], gpatch);
            }
        }
    }

    /// Exact per-sample gradient norm of one row — the expensive
    /// "gradient-norm" oracle, generic over the stack. Forwards through
    /// the (bit-identical) block path at `rows = 1` and walks the
    /// per-layer closed-form norms; `s` supplies every buffer, so pooled
    /// arenas keep the oracle allocation-free too.
    pub fn grad_norm_row(
        &self,
        params: &[Vec<f32>],
        x: &[f32],
        y: i32,
        s: &mut BlockScratch,
    ) -> f32 {
        self.forward_block(params, x, 1, s);
        let yy = self.clamp_label(y);
        s.probs_mut()[yy] -= 1.0;
        let last = self.layers.len() - 1;
        let BlockScratch { acts, ga, gb, wscratch, .. } = s;
        ga.clear();
        ga.extend_from_slice(&acts[last]);
        let mut cur: &mut Vec<f32> = ga;
        let mut next: &mut Vec<f32> = gb;
        let mut total = 0.0f32;
        for i in (0..self.layers.len()).rev() {
            let layer = &self.layers[i];
            let input: &[f32] = if i == 0 { x } else { &acts[i - 1] };
            let output: &[f32] = &acts[i];
            let p = self.layer_params(params, i);
            if i > self.first_param_layer {
                next.clear();
                next.resize(self.dims[i], 0.0);
                total += layer.grad_sq_norm(p, input, output, cur, Some(&mut *next), wscratch);
                std::mem::swap(&mut cur, &mut next);
            } else {
                total += layer.grad_sq_norm(p, input, output, cur, None, wscratch);
            }
        }
        total.sqrt()
    }

    /// A provable per-row dominance factor `ρ` with
    /// `‖∇θ loss‖ ≤ ρ · ‖probs − onehot(y)‖`: the paper's Eq.-1/2 claim
    /// that the last-layer score upper-bounds the gradient norm up to an
    /// architecture-dependent constant, made checkable. Derived from
    /// per-layer operator-norm bounds (Frobenius norms over Cauchy-Schwarz;
    /// conv additionally pays a `⌈kernel/stride⌉` window-overlap factor),
    /// evaluated at this row's activations in f64.
    pub fn grad_norm_bound_factor(&self, params: &[Vec<f32>], x: &[f32]) -> Result<f64> {
        self.check_params(params)?;
        if x.len() != self.dims[0] {
            bail!("row has {} features, model expects {}", x.len(), self.dims[0]);
        }
        let mut scratch = self.scratch();
        self.forward_row(params, x, &mut scratch);
        let frob2 = |t: &[f32]| t.iter().map(|&v| v as f64 * v as f64).sum::<f64>();
        // amp² bounds ‖g_layer‖² / ‖gz‖² going down the stack
        let mut amp2 = 1.0f64;
        let mut total = 0.0f64;
        for (i, layer) in self.layers.iter().enumerate().rev() {
            let input: &[f32] = if i == 0 { x } else { &scratch.acts[i - 1] };
            let in2 = frob2(input);
            let p = self.layer_params(params, i);
            match *layer {
                Layer::Dense { .. } => {
                    total += amp2 * (1.0 + in2);
                    amp2 *= frob2(&p[0]);
                }
                Layer::Relu | Layer::GlobalAvgPool { .. } => {} // contractions
                Layer::Conv1d { out_ch, kernel, stride, .. } => {
                    let t_out = (self.dims[i + 1] / out_ch) as f64;
                    let overlap = kernel.div_ceil(stride) as f64;
                    total += amp2 * (t_out + overlap * in2);
                    amp2 *= overlap * frob2(&p[0]);
                }
                Layer::EmbeddingBag { gain, .. } => {
                    total += amp2 * gain as f64 * gain as f64;
                }
            }
        }
        Ok(total.sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::init::init_params;

    fn conv_stack() -> LayerModel {
        let layers = vec![
            Layer::Conv1d { in_ch: 2, out_ch: 3, kernel: 3, stride: 2 },
            Layer::Relu,
            Layer::GlobalAvgPool { channels: 3 },
            Layer::Dense { out_dim: 4 },
        ];
        LayerModel::new(24, layers).unwrap()
    }

    fn seq_stack() -> LayerModel {
        let bag = Layer::EmbeddingBag {
            vocab: 4,
            dim: 5,
            lo: -1.0,
            hi: 1.0,
            positional: true,
            gain: 8.0,
        };
        LayerModel::new(8, vec![bag, Layer::Dense { out_dim: 3 }]).unwrap()
    }

    #[test]
    fn dims_and_param_specs_chain_through_the_stack() {
        let m = LayerModel::mlp(6, 5, 3).unwrap();
        assert_eq!(m.dims(), &[6, 5, 5, 3]);
        assert_eq!(m.num_classes(), 3);
        let specs = m.param_specs();
        let shapes: Vec<Vec<usize>> = specs.iter().map(|s| s.shape.clone()).collect();
        assert_eq!(shapes, vec![vec![6, 5], vec![5], vec![5, 3], vec![3]]);

        let c = conv_stack();
        // 24 = [12 time, 2 ch] -> conv k3 s2 -> [5, 3] -> pool -> 3 -> 4
        assert_eq!(c.dims(), &[24, 15, 15, 3, 4]);
        assert_eq!(c.param_specs()[0].shape, vec![3, 1, 2, 3]);

        let s = seq_stack();
        assert_eq!(s.dims(), &[8, 5, 3]);
        assert_eq!(s.param_specs()[0].shape, vec![8 * 4, 5]); // positional rows
    }

    #[test]
    fn invalid_stacks_are_rejected() {
        let head = Layer::Dense { out_dim: 3 };
        assert!(LayerModel::new(8, vec![]).is_err());
        assert!(LayerModel::new(8, vec![Layer::Relu]).is_err()); // no dense head
        assert!(LayerModel::new(8, vec![Layer::Dense { out_dim: 1 }]).is_err()); // 1 class
        // signal shorter than kernel
        let short = vec![Layer::Conv1d { in_ch: 1, out_ch: 2, kernel: 5, stride: 1 }, head];
        assert!(LayerModel::new(4, short).is_err());
        // in_dim not divisible by channels
        let ragged = vec![Layer::GlobalAvgPool { channels: 2 }, head];
        assert!(LayerModel::new(7, ragged).is_err());
        // embedding mid-stack
        let bag = Layer::EmbeddingBag {
            vocab: 4,
            dim: 3,
            lo: 0.0,
            hi: 1.0,
            positional: false,
            gain: 1.0,
        };
        assert!(LayerModel::new(6, vec![Layer::Relu, bag, head]).is_err());
    }

    #[test]
    fn bag_token_quantizes_and_clamps() {
        assert_eq!(bag_token(-5.0, 4, -1.0, 1.0), 0);
        assert_eq!(bag_token(-1.0, 4, -1.0, 1.0), 0);
        assert_eq!(bag_token(-0.4, 4, -1.0, 1.0), 1);
        assert_eq!(bag_token(0.1, 4, -1.0, 1.0), 2);
        assert_eq!(bag_token(0.99, 4, -1.0, 1.0), 3);
        assert_eq!(bag_token(7.0, 4, -1.0, 1.0), 3);
        assert_eq!(bag_token(f32::NAN, 4, -1.0, 1.0), 0);
    }

    #[test]
    fn forward_produces_probabilities_for_every_stack() {
        for m in [LayerModel::mlp(6, 5, 3).unwrap(), conv_stack(), seq_stack()] {
            let params = init_params(7, &m.param_specs());
            let mut s = m.scratch();
            let x: Vec<f32> = (0..m.in_dim()).map(|i| (i as f32 * 0.37).sin()).collect();
            m.forward_row(&params, &x, &mut s);
            let probs = s.probs();
            assert_eq!(probs.len(), m.num_classes());
            let sum: f32 = probs.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "probs sum {sum}");
            assert!(probs.iter().all(|p| p.is_finite() && *p >= 0.0));
            let (loss, score) = m.row_scores(&params, &x, 1, &mut s);
            assert!(loss.is_finite() && loss > 0.0);
            assert!(score.is_finite() && score >= 0.0);
        }
    }

    #[test]
    fn grad_norm_is_bounded_by_score_times_dominance_factor() {
        for m in [LayerModel::mlp(6, 5, 3).unwrap(), conv_stack(), seq_stack()] {
            let params = init_params(3, &m.param_specs());
            let mut s = m.scratch();
            let mut bs = m.block_scratch();
            for r in 0..8 {
                let x: Vec<f32> =
                    (0..m.in_dim()).map(|i| ((i + r * 13) as f32 * 0.61).cos()).collect();
                let y = (r % m.num_classes()) as i32;
                let (_, ub) = m.row_scores(&params, &x, y, &mut s);
                let gn = m.grad_norm_row(&params, &x, y, &mut bs);
                let rho = m.grad_norm_bound_factor(&params, &x).unwrap();
                // the head's bias gradient alone is the score, so gn >= ub
                assert!(gn >= ub - 1e-5, "gn {gn} < ub {ub}");
                assert!(
                    (gn as f64) <= rho * ub as f64 * 1.001 + 1e-6,
                    "gn {gn} exceeds rho {rho} * ub {ub}"
                );
            }
        }
    }

    #[test]
    fn block_walk_is_bit_identical_to_the_scalar_reference() {
        // The core kernel-refactor claim, on a fixed case per stack kind:
        // forward probabilities, scores and accumulated gradients of the
        // block path equal the per-row scalar reference walk bit for bit,
        // for every split of the batch into blocks. (rust/tests/props.rs
        // sweeps random shapes; this is the quick in-module pin.)
        for m in [LayerModel::mlp(6, 5, 3).unwrap(), conv_stack(), seq_stack()] {
            let params = init_params(9, &m.param_specs());
            let n = 7usize; // crosses the 4-row tile edge
            let d = m.in_dim();
            let c = m.num_classes();
            let x: Vec<f32> = (0..n * d).map(|i| ((i * 7 + 3) as f32 * 0.23).sin()).collect();
            let y: Vec<i32> = (0..n).map(|i| (i % c) as i32).collect();
            let coeff: Vec<f32> = (0..n).map(|r| 0.1 + 0.3 * (r % 3) as f32).collect();

            // scalar reference: row-by-row walk
            let mut s = m.scratch();
            let mut probs_ref = Vec::new();
            let mut grads_ref = m.zero_grads();
            for r in 0..n {
                let xr = &x[r * d..(r + 1) * d];
                m.forward_row(&params, xr, &mut s);
                probs_ref.extend_from_slice(s.probs());
                let yy = m.clamp_label(y[r]);
                let gz = s.probs_mut();
                gz[yy] -= 1.0;
                for g in gz.iter_mut() {
                    *g *= coeff[r];
                }
                m.backward_row(&params, xr, &mut s, &mut grads_ref);
            }

            // block path, over several block splits of the same batch
            for blocks in [vec![n], vec![4, n - 4], vec![1; n]] {
                let mut bs = m.block_scratch();
                let mut grads = m.zero_grads();
                let mut probs = Vec::new();
                let mut start = 0usize;
                for rows in blocks {
                    let xb = &x[start * d..(start + rows) * d];
                    m.forward_block(&params, xb, rows, &mut bs);
                    probs.extend_from_slice(bs.probs());
                    let pm = bs.probs_mut();
                    for r in 0..rows {
                        let yy = m.clamp_label(y[start + r]);
                        let gz = &mut pm[r * c..(r + 1) * c];
                        gz[yy] -= 1.0;
                        for g in gz.iter_mut() {
                            *g *= coeff[start + r];
                        }
                    }
                    m.backward_block(&params, xb, rows, &mut bs, &mut grads);
                    start += rows;
                }
                assert_eq!(probs, probs_ref, "probs diverged");
                assert_eq!(grads, grads_ref, "gradients diverged");
            }

            // the score-only fast path agrees with row_scores bit for bit
            let mut bs = m.block_scratch();
            let mut bl = vec![0.0f32; n];
            let mut bu = vec![0.0f32; n];
            m.scores_block(&params, &x, &y, n, &mut bs, &mut bl, &mut bu);
            for r in 0..n {
                let (l, u) = m.row_scores(&params, &x[r * d..(r + 1) * d], y[r], &mut s);
                assert_eq!((bl[r], bu[r]), (l, u), "row {r} scores diverged");
            }
        }
    }

    #[test]
    fn bf16_scores_track_the_f32_walk_within_storage_rounding() {
        // The bf16 fast path perturbs every weight by at most one part in
        // 256, so per-row losses and Eq.-20 scores stay close to the f32
        // walk — close in value here, close in *ranking* in the
        // train-level acceptance test (rust/tests/native_train.rs).
        for m in [LayerModel::mlp(6, 5, 3).unwrap(), conv_stack(), seq_stack()] {
            let params = init_params(9, &m.param_specs());
            let qp = m.quantize_params(&params);
            let n = 7usize;
            let d = m.in_dim();
            let c = m.num_classes();
            let x: Vec<f32> = (0..n * d).map(|i| ((i * 7 + 3) as f32 * 0.23).sin()).collect();
            let y: Vec<i32> = (0..n).map(|i| (i % c) as i32).collect();

            let mut bs = m.block_scratch();
            let mut fl = vec![0.0f32; n];
            let mut fu = vec![0.0f32; n];
            m.scores_block(&params, &x, &y, n, &mut bs, &mut fl, &mut fu);
            let mut ql = vec![0.0f32; n];
            let mut qu = vec![0.0f32; n];
            m.scores_block_bf16(&qp, &x, &y, n, &mut bs, &mut ql, &mut qu);

            for r in 0..n {
                assert!(ql[r].is_finite() && qu[r].is_finite() && qu[r] >= 0.0);
                let dl = (ql[r] - fl[r]).abs();
                let du = (qu[r] - fu[r]).abs();
                assert!(dl <= 0.15 * fl[r].abs() + 0.02, "row {r} loss {} vs {}", ql[r], fl[r]);
                assert!(du <= 0.15 * fu[r].abs() + 0.02, "row {r} score {} vs {}", qu[r], fu[r]);
            }
        }
    }

    #[test]
    fn bf16_block_walk_is_invariant_to_block_splits() {
        // Same blocking-invariance contract as the f32 path: bf16 scores
        // of a batch never depend on how the batch is split into blocks.
        for m in [LayerModel::mlp(6, 5, 3).unwrap(), conv_stack(), seq_stack()] {
            let params = init_params(11, &m.param_specs());
            let qp = m.quantize_params(&params);
            let n = 7usize;
            let d = m.in_dim();
            let c = m.num_classes();
            let x: Vec<f32> = (0..n * d).map(|i| ((i * 5 + 1) as f32 * 0.31).cos()).collect();
            let y: Vec<i32> = (0..n).map(|i| (i % c) as i32).collect();

            let mut bs = m.block_scratch();
            let mut rl = vec![0.0f32; n];
            let mut ru = vec![0.0f32; n];
            m.scores_block_bf16(&qp, &x, &y, n, &mut bs, &mut rl, &mut ru);

            for blocks in [vec![4, n - 4], vec![1; n]] {
                let mut sl = vec![0.0f32; n];
                let mut su = vec![0.0f32; n];
                let mut start = 0usize;
                for rows in blocks {
                    m.scores_block_bf16(
                        &qp,
                        &x[start * d..(start + rows) * d],
                        &y[start..start + rows],
                        rows,
                        &mut bs,
                        &mut sl[start..start + rows],
                        &mut su[start..start + rows],
                    );
                    start += rows;
                }
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
                assert_eq!(bits(&sl), bits(&rl), "losses diverged across block split");
                assert_eq!(bits(&su), bits(&ru), "scores diverged across block split");
            }
        }
    }

    #[test]
    fn eval_block_matches_the_per_row_reference() {
        for m in [LayerModel::mlp(6, 5, 3).unwrap(), conv_stack(), seq_stack()] {
            let params = init_params(5, &m.param_specs());
            let n = 7usize;
            let d = m.in_dim();
            let c = m.num_classes();
            let x: Vec<f32> = (0..n * d).map(|i| ((i * 3 + 2) as f32 * 0.47).sin()).collect();
            let y: Vec<i32> = (0..n).map(|i| ((i + 1) % c) as i32).collect();

            // per-row reference: scalar forward, f64 loss sum, last-max argmax
            let mut s = m.scratch();
            let mut ref_loss = 0.0f64;
            let mut ref_correct = 0i64;
            for r in 0..n {
                m.forward_row(&params, &x[r * d..(r + 1) * d], &mut s);
                let prow = s.probs();
                let yy = m.clamp_label(y[r]);
                ref_loss += row_loss(prow, yy) as f64;
                let pred = prow
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                    .map(|(k, _)| k)
                    .unwrap_or(0);
                if pred == yy {
                    ref_correct += 1;
                }
            }

            // whole batch and a split both reproduce it exactly: the
            // accumulator signature keeps the f64 chain per-row
            // sequential regardless of block boundaries
            let mut bs = m.block_scratch();
            let (mut l, mut k) = (0.0f64, 0i64);
            m.eval_block(&params, &x, &y, n, &mut bs, &mut l, &mut k);
            assert_eq!((l, k), (ref_loss, ref_correct));
            let (mut l, mut k) = (0.0f64, 0i64);
            m.eval_block(&params, &x[..4 * d], &y[..4], 4, &mut bs, &mut l, &mut k);
            m.eval_block(&params, &x[4 * d..], &y[4..], n - 4, &mut bs, &mut l, &mut k);
            assert_eq!((l, k), (ref_loss, ref_correct));
        }
    }

    #[test]
    fn backward_accumulates_into_the_right_tensors() {
        // one row, coeff 1: gradient of the head bias must be exactly gz
        let m = conv_stack();
        let params = init_params(5, &m.param_specs());
        let mut s = m.scratch();
        let x: Vec<f32> = (0..24).map(|i| (i as f32 * 0.29).sin()).collect();
        m.forward_row(&params, &x, &mut s);
        let yy = m.clamp_label(2);
        let gz: Vec<f32> = {
            let p = s.probs_mut();
            p[yy] -= 1.0;
            p.to_vec()
        };
        let mut grads = m.zero_grads();
        m.backward_row(&params, &x, &mut s, &mut grads);
        assert_eq!(grads.len(), m.num_param_tensors());
        let head_bias = grads.last().unwrap();
        assert_eq!(head_bias.as_slice(), gz.as_slice());
        // conv weight grads received something
        assert!(grads[0].iter().any(|&g| g != 0.0));
    }
}

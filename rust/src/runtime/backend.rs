//! The [`Backend`] trait — one execution substrate interface for the whole
//! coordinator stack.
//!
//! Two implementations exist:
//!
//! * [`Engine`] (PJRT) — executes AOT-lowered HLO artifacts; entry points
//!   exist only at the batch sizes that were baked by `make artifacts`.
//! * [`NativeEngine`] — a pure-rust
//!   forward/backward/SGD implementation over `runtime::layers` model
//!   stacks (MLPs, small convnets, embedding-bag sequence models); every
//!   entry works at any batch size and needs no artifacts at all, which is
//!   what lets `cargo test` run real Algorithm-1 training end to end.
//!
//! The trait is deliberately shaped after the engine's entry points
//! (`train_step`, `fwd_scores`, `eval_metrics`, `grad_norms`, `grad`,
//! `weighted_grad`, `svrg_step`) so the trainer, the scoring subsystem, the
//! figure harnesses and the SVRG baselines all run unchanged over
//! `&dyn Backend`. Capability differences are expressed through
//! [`supports`](Backend::supports) (PJRT: is there a baked artifact at this
//! batch size? native: is the entry implemented?) and
//! [`prepare`](Backend::prepare) (PJRT: compile now, outside the measured
//! budget; native: no-op). For the native backend, `supports` reflects its
//! layer-model registry — `mlp10`/`mlp100`/`conv10`/`seq64` by default —
//! so the figure harnesses can gate (and announce) per-architecture
//! scenarios uniformly across backends.

use std::path::Path;

use anyhow::{bail, Result};
use xla::Literal;

use super::engine::{Engine, ModelState, StepOutput};
use super::manifest::ModelInfo;
use super::native::NativeEngine;
use super::score::{ScoreKind, ScorePrecision};
use super::tensor::HostTensor;

/// An execution substrate for training, scoring and evaluation.
///
/// `Sync` because the sharded scoring backend (`runtime::score`) calls
/// `fwd_scores` / `grad_norms` from scoped worker threads while the
/// coordinator keeps exclusive ownership of the mutable [`ModelState`].
pub trait Backend: Sync {
    /// Short backend identifier: `"pjrt"` or `"native"`.
    fn name(&self) -> &'static str;

    /// Static description of a model (shapes, default batch sizes, params).
    fn model_info(&self, model: &str) -> Result<&ModelInfo>;

    /// Whether `entry` can execute at exactly `batch` rows. Errors only on
    /// unknown models; an unsupported batch size is `Ok(false)`.
    fn supports(&self, model: &str, entry: &str, batch: usize) -> Result<bool>;

    /// Make `entry@batch` ready to execute (PJRT compiles and caches the
    /// artifact so the first training step is not a compile stall inside
    /// the measured budget; native backends have nothing to do).
    fn prepare(&self, model: &str, entry: &str, batch: usize) -> Result<()>;

    /// Initialize a fresh model state per the model's parameter specs.
    fn init_state(&self, model: &str, seed: u64) -> Result<ModelState>;

    /// Set the data-parallel worker count for batch-level compute
    /// (`train_step`, `grad`, `weighted_grad`, `grad_norms`,
    /// `eval_metrics` — `--train-workers`). Interior-mutable so a shared
    /// backend can be retuned per run. Backends that cannot shard a batch
    /// (PJRT executes the whole batch as one artifact call) ignore it.
    /// Implementations must keep any worker count bit-identical to serial
    /// — parallelism may never change a trajectory.
    fn set_train_workers(&self, _workers: usize) {}

    /// The current batch-compute worker count (1 = serial).
    fn train_workers(&self) -> usize {
        1
    }

    /// Set the numeric precision of the presample scoring pass
    /// (`--score-precision`). Only `fwd_scores` is affected — training,
    /// eval and the gradient-norm oracle always run f32. Interior-mutable
    /// like [`set_train_workers`](Self::set_train_workers). Backends
    /// without a reduced-precision walk (PJRT artifacts are baked at f32)
    /// ignore it and keep scoring in f32.
    fn set_score_precision(&self, _precision: ScorePrecision) {}

    /// One weighted SGD+momentum step (Eq. 2). Updates `state` in place and
    /// returns the weighted mean loss plus the per-sample loss and Eq.-20
    /// score vectors the forward pass produced for free (Alg. 1 line 15).
    fn train_step(
        &self,
        state: &mut ModelState,
        x: &HostTensor,
        y: &[i32],
        w: &[f32],
        lr: f32,
    ) -> Result<StepOutput>;

    /// One forward pass: (per-sample loss, Eq.-20 upper-bound scores).
    fn fwd_scores(
        &self,
        state: &ModelState,
        x: &HostTensor,
        y: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>)>;

    /// Evaluation shard: (sum of losses, number of correct predictions).
    fn eval_metrics(&self, state: &ModelState, x: &HostTensor, y: &[i32]) -> Result<(f64, i64)>;

    /// True per-sample gradient norms (the expensive Fig-1/2 oracle).
    fn grad_norms(&self, state: &ModelState, x: &HostTensor, y: &[i32]) -> Result<Vec<f32>>;

    /// Mean minibatch gradient at arbitrary params (SVRG substrate):
    /// (grads in param order, mean loss).
    fn grad(
        &self,
        model: &str,
        params: &[Literal],
        x: &HostTensor,
        y: &[i32],
    ) -> Result<(Vec<Literal>, f32)>;

    /// Gradient of the re-weighted loss `(1/b) Σ wᵢ·lossᵢ` — the exact
    /// estimator a weighted SGD step applies (Fig-1 analysis substrate).
    fn weighted_grad(
        &self,
        state: &ModelState,
        x: &HostTensor,
        y: &[i32],
        w: &[f32],
    ) -> Result<(Vec<Literal>, f32)>;

    /// One SVRG inner step: `params <- params - lr (g(params) - g(snap) + mu)`;
    /// returns the minibatch loss at the *current* params. The default is
    /// composed host-side from two [`grad`](Self::grad) calls; backends with
    /// a fused artifact (PJRT's `svrg_step` entry) override it.
    #[allow(clippy::too_many_arguments)]
    fn svrg_step(
        &self,
        model: &str,
        params: &mut Vec<Literal>,
        snap: &[Literal],
        mu: &[Literal],
        x: &HostTensor,
        y: &[i32],
        lr: f32,
    ) -> Result<f32> {
        let (g_cur, loss) = self.grad(model, params, x, y)?;
        let (g_snap, _) = self.grad(model, snap, x, y)?;
        let n = params.len();
        if g_cur.len() != n || g_snap.len() != n || mu.len() != n {
            bail!("svrg_step: parameter/gradient list lengths disagree");
        }
        let mut next = Vec::with_capacity(params.len());
        for (((p, gc), gs), m) in params.iter().zip(&g_cur).zip(&g_snap).zip(mu) {
            let pt = HostTensor::from_literal(p)?;
            let gct = HostTensor::from_literal(gc)?;
            let gst = HostTensor::from_literal(gs)?;
            let mt = HostTensor::from_literal(m)?;
            let data: Vec<f32> = pt
                .data
                .iter()
                .zip(&gct.data)
                .zip(&gst.data)
                .zip(&mt.data)
                .map(|(((&pv, &gcv), &gsv), &mv)| pv - lr * (gcv - gsv + mv))
                .collect();
            next.push(HostTensor::new(pt.shape, data).to_literal()?);
        }
        *params = next;
        Ok(loss)
    }

    /// Whether a `kind` scoring pass already fans out across this
    /// backend's own compute shards (distributed chunk fan-out, an
    /// internally parallel oracle). When true the trainer runs its outer
    /// `--score-workers` shard layer serially instead of stacking a second
    /// parallelism layer on the same resources. Sharding is a scheduling
    /// choice only — results are bit-identical either way.
    fn scores_sharded_internally(&self, _kind: ScoreKind) -> bool {
        false
    }

    /// Drain operational events (worker losses, chunk requeues,
    /// degradation to in-process compute) accumulated since the last call.
    /// Events describe *scheduling*, never results — the trainer logs them
    /// without acting on them. Backends with no event stream return none.
    fn drain_events(&self) -> Vec<String> {
        Vec::new()
    }
}

impl Backend for Engine {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn model_info(&self, model: &str) -> Result<&ModelInfo> {
        self.manifest.model(model)
    }

    fn supports(&self, model: &str, entry: &str, batch: usize) -> Result<bool> {
        Ok(self.manifest.model(model)?.entry(entry, batch).is_ok())
    }

    fn prepare(&self, model: &str, entry: &str, batch: usize) -> Result<()> {
        Engine::executable(self, model, entry, batch).map(|_| ())
    }

    fn init_state(&self, model: &str, seed: u64) -> Result<ModelState> {
        Engine::init_state(self, model, seed)
    }

    fn train_step(
        &self,
        state: &mut ModelState,
        x: &HostTensor,
        y: &[i32],
        w: &[f32],
        lr: f32,
    ) -> Result<StepOutput> {
        Engine::train_step(self, state, x, y, w, lr)
    }

    fn fwd_scores(
        &self,
        state: &ModelState,
        x: &HostTensor,
        y: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        Engine::fwd_scores(self, state, x, y)
    }

    fn eval_metrics(&self, state: &ModelState, x: &HostTensor, y: &[i32]) -> Result<(f64, i64)> {
        Engine::eval_metrics(self, state, x, y)
    }

    fn grad_norms(&self, state: &ModelState, x: &HostTensor, y: &[i32]) -> Result<Vec<f32>> {
        Engine::grad_norms(self, state, x, y)
    }

    fn grad(
        &self,
        model: &str,
        params: &[Literal],
        x: &HostTensor,
        y: &[i32],
    ) -> Result<(Vec<Literal>, f32)> {
        Engine::grad(self, model, params, x, y)
    }

    fn weighted_grad(
        &self,
        state: &ModelState,
        x: &HostTensor,
        y: &[i32],
        w: &[f32],
    ) -> Result<(Vec<Literal>, f32)> {
        Engine::weighted_grad(self, state, x, y, w)
    }

    #[allow(clippy::too_many_arguments)]
    fn svrg_step(
        &self,
        model: &str,
        params: &mut Vec<Literal>,
        snap: &[Literal],
        mu: &[Literal],
        x: &HostTensor,
        y: &[i32],
        lr: f32,
    ) -> Result<f32> {
        Engine::svrg_step(self, model, params, snap, mu, x, y, lr)
    }
}

/// Build the backend selected by a `--backend` flag value.
/// `"native"` needs no artifacts; `"pjrt"` loads `artifacts_dir`.
pub fn load(kind: &str, artifacts_dir: &str) -> Result<Box<dyn Backend>> {
    match kind {
        "native" => Ok(Box::new(NativeEngine::with_default_models())),
        "pjrt" => Ok(Box::new(Engine::load(artifacts_dir)?)),
        other => bail!("unknown backend {other:?} (expected `native` or `pjrt`)"),
    }
}

/// Prefer the PJRT engine when an artifact manifest is present; otherwise
/// fall back to the artifact-free native CPU backend (how the examples run
/// out of the box).
pub fn autodetect(artifacts_dir: &str) -> Result<Box<dyn Backend>> {
    if Path::new(artifacts_dir).join("manifest.json").exists() {
        load("pjrt", artifacts_dir)
    } else {
        load("native", artifacts_dir)
    }
}

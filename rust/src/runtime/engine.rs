//! The PJRT execution engine: loads AOT artifacts (HLO text), compiles them
//! once on the CPU PJRT client, and exposes typed entry points
//! (`fwd_scores`, `train_step`, `eval_metrics`, `grad_norms`, `grad`,
//! `svrg_step`) over host tensors.
//!
//! Design notes:
//! * Executables are compiled lazily and cached per (model, entry, batch).
//! * Model parameters live as `xla::Literal`s (host buffers on the CPU
//!   plugin) inside [`ModelState`]; `train_step` swaps them wholesale from
//!   the executable's output tuple, so the steady-state hot loop does no
//!   re-encoding of parameters.
//! * The engine is `Send + Sync`: the executable cache and perf counters
//!   sit behind mutexes, and compiled executables are `Arc`-shared, so the
//!   sharded scoring backend (`runtime::score`) can run `fwd_scores` /
//!   `grad_norms` chunks concurrently from scoped worker threads while the
//!   coordinator keeps exclusive ownership of the mutable [`ModelState`].

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::init;
use super::manifest::{EntryInfo, Manifest, ModelInfo};
use super::tensor::{
    f32_scalar_literal, f32_vec_literal, i32_vec_literal, literal_to_f32_scalar,
    literal_to_f32_vec, literal_to_i32_scalar, HostTensor,
};

/// Parameters + optimizer slots for one model instance.
pub struct ModelState {
    pub model: String,
    pub params: Vec<Literal>,
    pub mom: Vec<Literal>,
    pub step: u64,
}

impl ModelState {
    /// Deep-copy the parameter literals (snapshots for SVRG / checkpoints).
    pub fn clone_params(&self) -> Result<Vec<Literal>> {
        clone_literals(&self.params)
    }

    /// Pull every parameter back to `Vec<f32>` (checkpointing, analysis).
    pub fn params_to_host(&self) -> Result<Vec<Vec<f32>>> {
        self.params.iter().map(literal_to_f32_vec).collect()
    }
}

/// Everything one `train_step` execution returns besides the new state.
#[derive(Debug, Clone)]
pub struct StepOutput {
    /// Weighted mean loss of the step.
    pub loss: f32,
    /// Per-sample (unweighted) losses from the step's forward pass.
    pub loss_vec: Vec<f32>,
    /// Per-sample Eq.-20 upper-bound scores from the same forward pass.
    pub scores: Vec<f32>,
}

/// Deep-copy literals via host round-trip (Literal is not Clone).
pub fn clone_literals(lits: &[Literal]) -> Result<Vec<Literal>> {
    lits.iter()
        .map(|l| {
            let t = HostTensor::from_literal(l)?;
            t.to_literal()
        })
        .collect()
}

type ExeKey = (String, String, usize);

pub struct Engine {
    client: PjRtClient,
    pub manifest: Manifest,
    /// `BTreeMap`, not `HashMap`: the determinism contract (tools/detlint,
    /// `nondeterministic-iteration`) bans seeded-hash iteration order in
    /// `rust/src` so no schedule or merged result can depend on it.
    exes: Mutex<BTreeMap<ExeKey, Arc<PjRtLoadedExecutable>>>,
    /// Executions performed, per entry name (perf accounting).
    exec_counts: Mutex<BTreeMap<String, u64>>,
}

impl Engine {
    /// Create a CPU PJRT client and load the artifact manifest.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            manifest,
            exes: Mutex::new(BTreeMap::new()),
            exec_counts: Mutex::new(BTreeMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn model_info(&self, model: &str) -> Result<&ModelInfo> {
        self.manifest.model(model)
    }

    /// Compile (or fetch from cache) the executable for an entry point.
    /// Concurrent callers racing on an uncached key may compile it twice;
    /// both get a working executable and the cache keeps one (benign).
    pub fn executable(
        &self,
        model: &str,
        entry: &str,
        batch: usize,
    ) -> Result<Arc<PjRtLoadedExecutable>> {
        let key = (model.to_string(), entry.to_string(), batch);
        if let Some(exe) = self.exes.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let info = self.manifest.model(model)?;
        let e = info.entry(entry, batch)?;
        let path = self.manifest.artifact_path(e);
        let proto = HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {model}/{entry}@{batch}"))?;
        let exe = Arc::new(exe);
        self.exes.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Eagerly compile every artifact of a model (startup warmup so the
    /// first training step isn't a compile stall).
    pub fn warmup(&self, model: &str) -> Result<usize> {
        let entries: Vec<(String, usize)> = self
            .manifest
            .model(model)?
            .entries
            .iter()
            .map(|e| (e.entry.clone(), e.batch))
            .collect();
        for (entry, batch) in &entries {
            self.executable(model, entry, *batch)?;
        }
        Ok(entries.len())
    }

    /// Execute an entry point; returns the decomposed output tuple.
    pub fn run(
        &self,
        model: &str,
        entry: &str,
        batch: usize,
        args: &[&Literal],
    ) -> Result<Vec<Literal>> {
        let exe = self.executable(model, entry, batch)?;
        *self.exec_counts.lock().unwrap().entry(entry.to_string()).or_insert(0) += 1;
        let outs = exe
            .execute::<&Literal>(args)
            .with_context(|| format!("executing {model}/{entry}@{batch}"))?;
        let tuple = outs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {model}/{entry}@{batch}"))?;
        tuple.to_tuple().context("decomposing output tuple")
    }

    pub fn exec_count(&self, entry: &str) -> u64 {
        self.exec_counts.lock().unwrap().get(entry).copied().unwrap_or(0)
    }

    /// Initialize a fresh model state per the manifest init specs.
    pub fn init_state(&self, model: &str, seed: u64) -> Result<ModelState> {
        init::init_state(self.manifest.model(model)?, seed)
    }

    fn check_batch_inputs(
        &self,
        info: &ModelInfo,
        e: &EntryInfo,
        x: &HostTensor,
        y: &[i32],
    ) -> Result<()> {
        if x.shape != [e.batch, info.feature_dim] {
            bail!(
                "x shape {:?} does not match {}/{}@{} expectation [{}, {}]",
                x.shape,
                info.name,
                e.entry,
                e.batch,
                e.batch,
                info.feature_dim
            );
        }
        if y.len() != e.batch {
            bail!("y length {} != batch {}", y.len(), e.batch);
        }
        Ok(())
    }

    /// One forward pass: per-sample loss + Eq.-20 upper-bound scores.
    /// Batch size is inferred from `x` and must match a baked artifact.
    pub fn fwd_scores(
        &self,
        state: &ModelState,
        x: &HostTensor,
        y: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let info = self.manifest.model(&state.model)?;
        let batch = x.shape[0];
        let e = info.entry("fwd_scores", batch)?;
        self.check_batch_inputs(info, e, x, y)?;

        let xl = x.to_literal()?;
        let yl = i32_vec_literal(y);
        let mut args: Vec<&Literal> = state.params.iter().collect();
        args.push(&xl);
        args.push(&yl);
        let out = self.run(&state.model, "fwd_scores", batch, &args)?;
        if out.len() != 2 {
            bail!("fwd_scores returned {} outputs, expected 2", out.len());
        }
        Ok((literal_to_f32_vec(&out[0])?, literal_to_f32_vec(&out[1])?))
    }

    /// One weighted SGD+momentum step (Eq. 2). Updates `state` in place.
    /// Returns the weighted mean loss plus the per-sample loss and Eq.-20
    /// score vectors that the step's forward pass produced "for free"
    /// (Algorithm 1 line 15) — the warmup phase feeds them straight into
    /// the τ estimator without a second forward pass.
    pub fn train_step(
        &self,
        state: &mut ModelState,
        x: &HostTensor,
        y: &[i32],
        w: &[f32],
        lr: f32,
    ) -> Result<StepOutput> {
        let info = self.manifest.model(&state.model)?;
        let batch = x.shape[0];
        let e = info.entry("train_step", batch)?;
        self.check_batch_inputs(info, e, x, y)?;
        if w.len() != batch {
            bail!("w length {} != batch {}", w.len(), batch);
        }

        let n = info.num_params();
        let xl = x.to_literal()?;
        let yl = i32_vec_literal(y);
        let wl = f32_vec_literal(w);
        let lrl = f32_scalar_literal(lr);
        let mut args: Vec<&Literal> = Vec::with_capacity(2 * n + 4);
        args.extend(state.params.iter());
        args.extend(state.mom.iter());
        args.push(&xl);
        args.push(&yl);
        args.push(&wl);
        args.push(&lrl);

        let mut out = self.run(&state.model, "train_step", batch, &args)?;
        if out.len() != 2 * n + 3 {
            bail!("train_step returned {} outputs, expected {}", out.len(), 2 * n + 3);
        }
        let loss = literal_to_f32_scalar(&out[2 * n])?;
        let loss_vec = literal_to_f32_vec(&out[2 * n + 1])?;
        let scores = literal_to_f32_vec(&out[2 * n + 2])?;
        out.truncate(2 * n);
        let mom = out.split_off(n);
        state.params = out;
        state.mom = mom;
        state.step += 1;
        Ok(StepOutput { loss, loss_vec, scores })
    }

    /// Evaluation shard: (sum of losses, number of correct predictions).
    pub fn eval_metrics(
        &self,
        state: &ModelState,
        x: &HostTensor,
        y: &[i32],
    ) -> Result<(f64, i64)> {
        let batch = x.shape[0];
        let xl = x.to_literal()?;
        let yl = i32_vec_literal(y);
        let mut args: Vec<&Literal> = state.params.iter().collect();
        args.push(&xl);
        args.push(&yl);
        let out = self.run(&state.model, "eval_metrics", batch, &args)?;
        Ok((literal_to_f32_scalar(&out[0])? as f64, literal_to_i32_scalar(&out[1])? as i64))
    }

    /// True per-sample gradient norms (the expensive Fig-1/2 oracle).
    pub fn grad_norms(&self, state: &ModelState, x: &HostTensor, y: &[i32]) -> Result<Vec<f32>> {
        let batch = x.shape[0];
        let xl = x.to_literal()?;
        let yl = i32_vec_literal(y);
        let mut args: Vec<&Literal> = state.params.iter().collect();
        args.push(&xl);
        args.push(&yl);
        let out = self.run(&state.model, "grad_norms", batch, &args)?;
        literal_to_f32_vec(&out[0])
    }

    /// Mean minibatch gradient (SVRG substrate): (grads, mean loss).
    pub fn grad(
        &self,
        model: &str,
        params: &[Literal],
        x: &HostTensor,
        y: &[i32],
    ) -> Result<(Vec<Literal>, f32)> {
        let info = self.manifest.model(model)?;
        let n = info.num_params();
        let batch = x.shape[0];
        let xl = x.to_literal()?;
        let yl = i32_vec_literal(y);
        let mut args: Vec<&Literal> = params.iter().collect();
        args.push(&xl);
        args.push(&yl);
        let mut out = self.run(model, "grad", batch, &args)?;
        let loss = literal_to_f32_scalar(&out[n])?;
        out.truncate(n);
        Ok((out, loss))
    }

    /// Gradient of the re-weighted loss d/dθ (1/b) Σ wᵢ·lossᵢ — the exact
    /// estimator a weighted SGD step applies (Fig-1 analysis substrate).
    pub fn weighted_grad(
        &self,
        state: &ModelState,
        x: &HostTensor,
        y: &[i32],
        w: &[f32],
    ) -> Result<(Vec<Literal>, f32)> {
        let info = self.manifest.model(&state.model)?;
        let n = info.num_params();
        let batch = x.shape[0];
        let xl = x.to_literal()?;
        let yl = i32_vec_literal(y);
        let wl = f32_vec_literal(w);
        let mut args: Vec<&Literal> = state.params.iter().collect();
        args.push(&xl);
        args.push(&yl);
        args.push(&wl);
        let mut out = self.run(&state.model, "weighted_grad", batch, &args)?;
        let loss = literal_to_f32_scalar(&out[n])?;
        out.truncate(n);
        Ok((out, loss))
    }

    /// One SVRG inner step: params <- params - lr (g(params) - g(snap) + mu).
    /// Returns the minibatch loss at the *current* params.
    #[allow(clippy::too_many_arguments)]
    pub fn svrg_step(
        &self,
        model: &str,
        params: &mut Vec<Literal>,
        snap: &[Literal],
        mu: &[Literal],
        x: &HostTensor,
        y: &[i32],
        lr: f32,
    ) -> Result<f32> {
        let info = self.manifest.model(model)?;
        let n = info.num_params();
        let batch = x.shape[0];
        let xl = x.to_literal()?;
        let yl = i32_vec_literal(y);
        let lrl = f32_scalar_literal(lr);
        let mut args: Vec<&Literal> = Vec::with_capacity(3 * n + 3);
        args.extend(params.iter());
        args.extend(snap.iter());
        args.extend(mu.iter());
        args.push(&xl);
        args.push(&yl);
        args.push(&lrl);
        let mut out = self.run(model, "svrg_step", batch, &args)?;
        let loss = literal_to_f32_scalar(&out[n])?;
        out.truncate(n);
        *params = out;
        Ok(loss)
    }
}

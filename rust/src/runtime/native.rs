//! [`NativeEngine`] — the pure-rust CPU training backend.
//!
//! Implements every entry point the coordinator uses (`train_step`,
//! `fwd_scores`, `eval_metrics`, `grad_norms`, `grad`, `weighted_grad`) for
//! the two-layer MLP family, with SGD + momentum + weight decay matching
//! the Eq.-2 update the AOT artifacts bake. No artifacts, no PJRT runtime:
//! this is what lets the full Algorithm-1 pipeline — warmup, τ switch,
//! presample/score/resample, weighted update — run and be tested end to
//! end in any build of this repo.
//!
//! Design points:
//!
//! * Parameters live in the same [`ModelState`] (`xla::Literal` tensors) as
//!   the PJRT engine's, so checkpointing, SVRG snapshots and the analysis
//!   vecmath work identically across backends.
//! * The per-row forward pass is *shared* with
//!   [`NativeScorer`](super::score::NativeScorer)
//!   ([`mlp_row_forward`](super::score::mlp_row_forward)), so native
//!   training, native scoring and the sharded scoring benches are
//!   bit-identical on the same parameters.
//! * Every entry accepts any batch size ≥ 1 — [`Backend::supports`] is
//!   unconditional — which is why the trainer can evaluate exact partial
//!   test shards and the resampler can use any presample B natively.
//! * Determinism: row accumulation order is fixed (serial over rows, row
//!   index ascending), so a fixed seed reproduces a training trajectory bit
//!   for bit regardless of `--score-workers`.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};
use xla::Literal;

use super::backend::Backend;
use super::engine::{ModelState, StepOutput};
use super::init;
use super::manifest::{InitKind, ModelInfo, ParamSpec, Selfcheck};
use super::score::{mlp_row_forward, row_loss, row_score, NativeScorer};
use super::tensor::{literal_to_f32_vec, HostTensor};

/// Entries the native backend implements (any batch size).
const NATIVE_ENTRIES: &[&str] =
    &["train_step", "fwd_scores", "eval_metrics", "grad_norms", "grad", "weighted_grad"];

/// Architecture + default batch geometry of one native MLP model.
#[derive(Debug, Clone)]
pub struct NativeModelSpec {
    pub name: String,
    pub feature_dim: usize,
    pub hidden: usize,
    pub num_classes: usize,
    /// default training batch b
    pub batch: usize,
    /// default evaluation shard size
    pub eval_batch: usize,
    /// presample sizes B advertised to the B-ablation harnesses (any size
    /// actually works natively; the max is the trainer's default)
    pub presample: Vec<usize>,
}

impl NativeModelSpec {
    pub fn mlp(
        name: &str,
        feature_dim: usize,
        hidden: usize,
        num_classes: usize,
        batch: usize,
        eval_batch: usize,
        presample: Vec<usize>,
    ) -> Self {
        assert!(feature_dim > 0 && hidden > 0 && num_classes > 1 && batch > 0 && eval_batch > 0);
        Self {
            name: name.to_string(),
            feature_dim,
            hidden,
            num_classes,
            batch,
            eval_batch,
            presample,
        }
    }

    /// The manifest-shaped description of this model. Entries are empty —
    /// native capability is expressed by [`Backend::supports`], not by an
    /// artifact inventory — and the selfcheck block is inert (selfchecks
    /// pin the *cross-language* contract, which only PJRT exercises).
    fn to_model_info(&self) -> ModelInfo {
        let (d, h, c) = (self.feature_dim, self.hidden, self.num_classes);
        ModelInfo {
            name: self.name.clone(),
            feature_dim: d,
            num_classes: c,
            batch: self.batch,
            eval_batch: self.eval_batch,
            presample: self.presample.clone(),
            params: vec![
                ParamSpec { name: "w1".into(), shape: vec![d, h], init: InitKind::GlorotUniform },
                ParamSpec { name: "b1".into(), shape: vec![h], init: InitKind::Zeros },
                ParamSpec { name: "w2".into(), shape: vec![h, c], init: InitKind::GlorotUniform },
                ParamSpec { name: "b2".into(), shape: vec![c], init: InitKind::Zeros },
            ],
            entries: vec![],
            selfcheck: Selfcheck {
                seed: 0,
                batch: 0,
                loss_head: vec![],
                ghat_head: vec![],
                mean_loss: f64::NAN,
                step_loss: f64::NAN,
                mean_loss_after_step: f64::NAN,
                param0_head: vec![],
            },
        }
    }
}

struct NativeModel {
    spec: NativeModelSpec,
    info: ModelInfo,
}

/// The pure-rust training backend. See the module docs.
pub struct NativeEngine {
    models: BTreeMap<String, NativeModel>,
    /// SGD momentum (Eq. 2); matches the AOT manifest default.
    pub momentum: f32,
    /// L2 weight decay applied inside `train_step` (not in `grad`).
    pub weight_decay: f32,
}

impl Default for NativeEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl NativeEngine {
    /// An empty registry (register specs with [`register`](Self::register)).
    pub fn new() -> Self {
        Self { models: BTreeMap::new(), momentum: 0.9, weight_decay: 5e-4 }
    }

    /// The stock registry: `mlp10` mirrors the PJRT mlp10 geometry
    /// (64 features / 10 classes — the CIFAR-10 stand-in head) and
    /// `mlp100` the CIFAR-100-ish §4.2 configuration (768 features /
    /// 100 classes, b = 128, B up to 1024).
    pub fn with_default_models() -> Self {
        let mut ne = Self::new();
        ne.register(NativeModelSpec::mlp("mlp10", 64, 128, 10, 128, 256, vec![384, 640, 1024]));
        ne.register(NativeModelSpec::mlp("mlp100", 768, 256, 100, 128, 512, vec![640, 1024]));
        ne
    }

    /// Add (or replace) a model.
    pub fn register(&mut self, spec: NativeModelSpec) -> &mut Self {
        let info = spec.to_model_info();
        self.models.insert(spec.name.clone(), NativeModel { spec, info });
        self
    }

    pub fn model_names(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    fn model(&self, name: &str) -> Result<&NativeModel> {
        self.models.get(name).with_context(|| {
            format!(
                "unknown native model {name:?}; registered: {}",
                self.models.keys().cloned().collect::<Vec<_>>().join(", ")
            )
        })
    }

    /// A [`NativeScorer`] over the state's current parameters — scores are
    /// bit-identical to this backend's `fwd_scores` (shared row forward).
    pub fn scorer(&self, state: &ModelState) -> Result<NativeScorer> {
        let m = self.model(&state.model)?;
        let (d, h, c) = (m.spec.feature_dim, m.spec.hidden, m.spec.num_classes);
        let [w1, b1, w2, b2] = host4(&state.params, "parameter")?;
        NativeScorer::from_params(d, h, c, w1, b1, w2, b2)
    }

    fn check_batch(&self, m: &NativeModel, x: &HostTensor, y: &[i32]) -> Result<usize> {
        if x.shape.len() != 2 || x.shape[1] != m.spec.feature_dim {
            bail!(
                "x shape {:?} does not match native model {:?} expectation [n, {}]",
                x.shape,
                m.spec.name,
                m.spec.feature_dim
            );
        }
        let n = x.shape[0];
        if n == 0 {
            bail!("empty batch");
        }
        if y.len() != n {
            bail!("y length {} != batch {n}", y.len());
        }
        Ok(n)
    }
}

/// Pull the four MLP tensors (w1, b1, w2, b2) of a literal list to host.
fn host4(lits: &[Literal], what: &str) -> Result<[Vec<f32>; 4]> {
    if lits.len() != 4 {
        bail!("native MLP expects 4 {what} tensors, got {}", lits.len());
    }
    Ok([
        literal_to_f32_vec(&lits[0])?,
        literal_to_f32_vec(&lits[1])?,
        literal_to_f32_vec(&lits[2])?,
        literal_to_f32_vec(&lits[3])?,
    ])
}

/// Rebuild the literal list from host tensors, in manifest param order.
fn lits4(info: &ModelInfo, tensors: [Vec<f32>; 4]) -> Result<Vec<Literal>> {
    info.params
        .iter()
        .zip(tensors)
        .map(|(spec, data)| HostTensor::new(spec.shape.clone(), data).to_literal())
        .collect()
}

/// Everything one weighted forward+backward pass over a batch produces.
struct BatchPass {
    /// gradients in param order (w1, b1, w2, b2)
    grads: [Vec<f32>; 4],
    loss_vec: Vec<f32>,
    scores: Vec<f32>,
    /// `Σ coeffᵢ·lossᵢ` — the weighted mean loss when `coeff = w/n`.
    weighted_loss: f64,
}

/// Forward + backward over every row. `coeff[i]` scales row `i`'s
/// contribution to the accumulated gradients (`1/n` for a mean gradient,
/// `wᵢ/n` for the weighted estimators of Eq. 2). Rows accumulate serially
/// in index order — the determinism contract of the module docs.
fn backward_pass(
    spec: &NativeModelSpec,
    p: &[Vec<f32>; 4],
    x: &HostTensor,
    y: &[i32],
    coeff: &[f32],
) -> BatchPass {
    let (d, h, c) = (spec.feature_dim, spec.hidden, spec.num_classes);
    let n = x.shape[0];
    let [w1, b1, w2, b2] = p;
    let zeros = |len: usize| vec![0.0f32; len];
    let mut grads = [zeros(d * h), zeros(h), zeros(h * c), zeros(c)];
    let mut loss_vec = Vec::with_capacity(n);
    let mut scores = Vec::with_capacity(n);
    let mut weighted_loss = 0.0f64;
    let mut dh = vec![0.0f32; h];
    for r in 0..n {
        let xr = x.row(r);
        let (hid, probs) = mlp_row_forward(w1, b1, w2, b2, xr, h, c);
        let yy = (y[r] as usize).min(c - 1);
        let loss = row_loss(&probs, yy);
        let score = row_score(&probs, yy);
        let mut gz = probs;
        gz[yy] -= 1.0;
        loss_vec.push(loss);
        scores.push(score);
        let cf = coeff[r];
        weighted_loss += cf as f64 * loss as f64;
        if cf == 0.0 {
            continue;
        }
        for g in gz.iter_mut() {
            *g *= cf;
        }
        // layer 2: gW2 += h ⊗ gz, gb2 += gz
        for (j, &hj) in hid.iter().enumerate() {
            if hj != 0.0 {
                let row = &mut grads[2][j * c..(j + 1) * c];
                for (gw, &g) in row.iter_mut().zip(&gz) {
                    *gw += hj * g;
                }
            }
        }
        for (gb, &g) in grads[3].iter_mut().zip(&gz) {
            *gb += g;
        }
        // back through relu: dh = (gz · W2ᵀ) ∘ [h > 0]
        for (j, dhj) in dh.iter_mut().enumerate() {
            *dhj = if hid[j] > 0.0 {
                let row = &w2[j * c..(j + 1) * c];
                row.iter().zip(&gz).map(|(&wv, &g)| wv * g).sum()
            } else {
                0.0
            };
        }
        // layer 1: gW1 += x ⊗ dh, gb1 += dh
        for (i, &xi) in xr.iter().enumerate() {
            if xi != 0.0 {
                let row = &mut grads[0][i * h..(i + 1) * h];
                for (gw, &dv) in row.iter_mut().zip(&dh) {
                    *gw += xi * dv;
                }
            }
        }
        for (gb, &dv) in grads[1].iter_mut().zip(&dh) {
            *gb += dv;
        }
    }
    BatchPass { grads, loss_vec, scores, weighted_loss }
}

impl Backend for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn model_info(&self, model: &str) -> Result<&ModelInfo> {
        Ok(&self.model(model)?.info)
    }

    fn supports(&self, model: &str, entry: &str, batch: usize) -> Result<bool> {
        self.model(model)?;
        Ok(batch >= 1 && NATIVE_ENTRIES.contains(&entry))
    }

    fn prepare(&self, model: &str, entry: &str, batch: usize) -> Result<()> {
        if !self.supports(model, entry, batch)? {
            bail!("native backend does not implement {entry:?} (model {model:?})");
        }
        Ok(())
    }

    fn init_state(&self, model: &str, seed: u64) -> Result<ModelState> {
        init::init_state(&self.model(model)?.info, seed)
    }

    fn train_step(
        &self,
        state: &mut ModelState,
        x: &HostTensor,
        y: &[i32],
        w: &[f32],
        lr: f32,
    ) -> Result<StepOutput> {
        let m = self.model(&state.model)?;
        let n = self.check_batch(m, x, y)?;
        if w.len() != n {
            bail!("w length {} != batch {n}", w.len());
        }
        let params = host4(&state.params, "parameter")?;
        let mut mom = host4(&state.mom, "momentum")?;
        let inv_n = 1.0 / n as f32;
        let coeff: Vec<f32> = w.iter().map(|&wi| wi * inv_n).collect();
        let pass = backward_pass(&m.spec, &params, x, y, &coeff);
        // Eq. 2 with the manifest's optimizer: g' = g + wd·θ;
        // v <- μ·v + g'; θ <- θ - lr·v.
        let mut params = params;
        for ((pt, vt), gt) in params.iter_mut().zip(mom.iter_mut()).zip(&pass.grads) {
            for ((pv, vv), &gv) in pt.iter_mut().zip(vt.iter_mut()).zip(gt) {
                let g = gv + self.weight_decay * *pv;
                *vv = self.momentum * *vv + g;
                *pv -= lr * *vv;
            }
        }
        state.params = lits4(&m.info, params)?;
        state.mom = lits4(&m.info, mom)?;
        state.step += 1;
        Ok(StepOutput {
            loss: pass.weighted_loss as f32,
            loss_vec: pass.loss_vec,
            scores: pass.scores,
        })
    }

    fn fwd_scores(
        &self,
        state: &ModelState,
        x: &HostTensor,
        y: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let m = self.model(&state.model)?;
        let n = self.check_batch(m, x, y)?;
        let [w1, b1, w2, b2] = host4(&state.params, "parameter")?;
        let (h, c) = (m.spec.hidden, m.spec.num_classes);
        let mut loss_vec = Vec::with_capacity(n);
        let mut scores = Vec::with_capacity(n);
        for r in 0..n {
            let (_, probs) = mlp_row_forward(&w1, &b1, &w2, &b2, x.row(r), h, c);
            let yy = (y[r] as usize).min(c - 1);
            loss_vec.push(row_loss(&probs, yy));
            scores.push(row_score(&probs, yy));
        }
        Ok((loss_vec, scores))
    }

    fn eval_metrics(&self, state: &ModelState, x: &HostTensor, y: &[i32]) -> Result<(f64, i64)> {
        let m = self.model(&state.model)?;
        let n = self.check_batch(m, x, y)?;
        let [w1, b1, w2, b2] = host4(&state.params, "parameter")?;
        let (h, c) = (m.spec.hidden, m.spec.num_classes);
        let mut sum_loss = 0.0f64;
        let mut correct = 0i64;
        for r in 0..n {
            let (_, probs) = mlp_row_forward(&w1, &b1, &w2, &b2, x.row(r), h, c);
            let yy = (y[r] as usize).min(c - 1);
            sum_loss += row_loss(&probs, yy) as f64;
            let argmax = probs
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(k, _)| k)
                .unwrap_or(0);
            if argmax == yy {
                correct += 1;
            }
        }
        Ok((sum_loss, correct))
    }

    fn grad_norms(&self, state: &ModelState, x: &HostTensor, y: &[i32]) -> Result<Vec<f32>> {
        let m = self.model(&state.model)?;
        let n = self.check_batch(m, x, y)?;
        let [w1, b1, w2, b2] = host4(&state.params, "parameter")?;
        let (h, c) = (m.spec.hidden, m.spec.num_classes);
        // Per-sample gradient norm of the 2-layer MLP, exactly:
        //   ‖∇θ lossᵢ‖² = ‖gz‖²(1 + ‖h‖²) + ‖dh‖²(1 + ‖x‖²)
        // using ‖a ⊗ b‖_F = ‖a‖·‖b‖ for the outer-product weight grads.
        let mut out = Vec::with_capacity(n);
        for r in 0..n {
            let xr = x.row(r);
            let (hid, probs) = mlp_row_forward(&w1, &b1, &w2, &b2, xr, h, c);
            let yy = (y[r] as usize).min(c - 1);
            let mut gz = probs;
            gz[yy] -= 1.0;
            let gz2: f32 = gz.iter().map(|g| g * g).sum();
            let h2: f32 = hid.iter().map(|v| v * v).sum();
            let x2: f32 = xr.iter().map(|v| v * v).sum();
            let mut dh2 = 0.0f32;
            for (j, &hj) in hid.iter().enumerate() {
                if hj > 0.0 {
                    let row = &w2[j * c..(j + 1) * c];
                    let dv: f32 = row.iter().zip(&gz).map(|(&wv, &g)| wv * g).sum();
                    dh2 += dv * dv;
                }
            }
            out.push((gz2 * (1.0 + h2) + dh2 * (1.0 + x2)).sqrt());
        }
        Ok(out)
    }

    fn grad(
        &self,
        model: &str,
        params: &[Literal],
        x: &HostTensor,
        y: &[i32],
    ) -> Result<(Vec<Literal>, f32)> {
        let m = self.model(model)?;
        let n = self.check_batch(m, x, y)?;
        let p = host4(params, "parameter")?;
        let coeff = vec![1.0 / n as f32; n];
        let pass = backward_pass(&m.spec, &p, x, y, &coeff);
        Ok((lits4(&m.info, pass.grads)?, pass.weighted_loss as f32))
    }

    fn weighted_grad(
        &self,
        state: &ModelState,
        x: &HostTensor,
        y: &[i32],
        w: &[f32],
    ) -> Result<(Vec<Literal>, f32)> {
        let m = self.model(&state.model)?;
        let n = self.check_batch(m, x, y)?;
        if w.len() != n {
            bail!("w length {} != batch {n}", w.len());
        }
        let p = host4(&state.params, "parameter")?;
        let inv_n = 1.0 / n as f32;
        let coeff: Vec<f32> = w.iter().map(|&wi| wi * inv_n).collect();
        let pass = backward_pass(&m.spec, &p, x, y, &coeff);
        Ok((lits4(&m.info, pass.grads)?, pass.weighted_loss as f32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::score::{SampleScorer, ScoreKind};

    fn tiny_engine() -> NativeEngine {
        let mut ne = NativeEngine::new();
        ne.register(NativeModelSpec::mlp("tiny", 6, 5, 3, 4, 8, vec![16]));
        ne
    }

    fn tiny_batch(n: usize, d: usize, c: usize) -> (HostTensor, Vec<i32>) {
        let mut x = HostTensor::zeros(vec![n, d]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = ((i * 29 + 13) % 71) as f32 / 71.0 - 0.5;
        }
        let y: Vec<i32> = (0..n).map(|i| (i % c) as i32).collect();
        (x, y)
    }

    #[test]
    fn init_is_deterministic_and_shaped() {
        let ne = tiny_engine();
        let a = ne.init_state("tiny", 7).unwrap();
        let b = ne.init_state("tiny", 7).unwrap();
        let c = ne.init_state("tiny", 8).unwrap();
        assert_eq!(a.params.len(), 4);
        assert_eq!(a.mom.len(), 4);
        let ah = host4(&a.params, "p").unwrap();
        let bh = host4(&b.params, "p").unwrap();
        let ch = host4(&c.params, "p").unwrap();
        assert_eq!(ah, bh);
        assert_ne!(ah[0], ch[0]);
        assert_eq!(ah[0].len(), 6 * 5);
        assert!(ah[1].iter().all(|&v| v == 0.0)); // b1 zeros
        assert!(ne.model_info("nope").is_err());
    }

    #[test]
    fn supports_and_prepare() {
        let ne = tiny_engine();
        for &entry in super::NATIVE_ENTRIES {
            assert!(ne.supports("tiny", entry, 1).unwrap(), "{entry}");
            assert!(ne.supports("tiny", entry, 9999).unwrap(), "{entry}");
            ne.prepare("tiny", entry, 33).unwrap();
        }
        assert!(!ne.supports("tiny", "svrg_step", 8).unwrap()); // default impl, not an entry
        assert!(ne.supports("missing", "train_step", 8).is_err());
        assert!(ne.prepare("tiny", "bogus", 8).is_err());
    }

    #[test]
    fn train_step_reduces_loss_on_a_fixed_batch() {
        let ne = tiny_engine();
        let mut state = ne.init_state("tiny", 1).unwrap();
        let (x, y) = tiny_batch(4, 6, 3);
        let w = [1.0f32; 4];
        let first = ne.train_step(&mut state, &x, &y, &w, 0.2).unwrap();
        assert_eq!(first.loss_vec.len(), 4);
        assert_eq!(first.scores.len(), 4);
        assert!(first.scores.iter().all(|s| s.is_finite() && *s >= 0.0));
        let mut last = first.loss;
        for _ in 0..60 {
            last = ne.train_step(&mut state, &x, &y, &w, 0.2).unwrap().loss;
        }
        assert!(last < first.loss * 0.5, "loss did not drop: {} -> {last}", first.loss);
        assert_eq!(state.step, 61);
    }

    #[test]
    fn weighted_grad_scales_linearly_in_weights() {
        // (1/n) Σ w·loss is linear in w: doubling every weight must double
        // the weighted loss (and, by the same linearity, the gradient).
        let ne = tiny_engine();
        let state = ne.init_state("tiny", 2).unwrap();
        let (x, y) = tiny_batch(4, 6, 3);
        let (_, l1) = ne.weighted_grad(&state, &x, &y, &[1.0; 4]).unwrap();
        let (_, l2) = ne.weighted_grad(&state, &x, &y, &[2.0; 4]).unwrap();
        assert!((l2 - 2.0 * l1).abs() < 1e-5, "{l2} vs 2*{l1}");
    }

    #[test]
    fn fwd_scores_agree_with_train_step_free_outputs() {
        let ne = tiny_engine();
        let mut state = ne.init_state("tiny", 3).unwrap();
        let (x, y) = tiny_batch(8, 6, 3);
        let (loss, scores) = ne.fwd_scores(&state, &x, &y).unwrap();
        let out = ne.train_step(&mut state, &x, &y, &[1.0; 8], 0.05).unwrap();
        // train_step's "free" vectors come from the same pre-update forward
        assert_eq!(out.loss_vec, loss);
        assert_eq!(out.scores, scores);
        let mean: f32 = loss.iter().sum::<f32>() / 8.0;
        assert!((out.loss - mean).abs() < 1e-5);
    }

    #[test]
    fn eval_metrics_match_fwd_scores_losses() {
        let ne = tiny_engine();
        let state = ne.init_state("tiny", 4).unwrap();
        let (x, y) = tiny_batch(8, 6, 3);
        let (sum_loss, correct) = ne.eval_metrics(&state, &x, &y).unwrap();
        let (loss, _) = ne.fwd_scores(&state, &x, &y).unwrap();
        let total: f64 = loss.iter().map(|&v| v as f64).sum();
        assert!((sum_loss - total).abs() < 1e-6, "{sum_loss} vs {total}");
        assert!((0..=8).contains(&correct));
    }

    #[test]
    fn scorer_matches_backend_scores_bitwise() {
        let ne = tiny_engine();
        let state = ne.init_state("tiny", 5).unwrap();
        let scorer = ne.scorer(&state).unwrap();
        let (x, y) = tiny_batch(16, 6, 3);
        let (loss, ub) = ne.fwd_scores(&state, &x, &y).unwrap();
        assert_eq!(scorer.score_chunk(&x, &y, ScoreKind::Loss).unwrap(), loss);
        assert_eq!(scorer.score_chunk(&x, &y, ScoreKind::UpperBound).unwrap(), ub);
    }

    #[test]
    fn shape_mismatches_are_rejected() {
        let ne = tiny_engine();
        let mut state = ne.init_state("tiny", 6).unwrap();
        let (x, y) = tiny_batch(4, 6, 3);
        let (bad_x, _) = tiny_batch(4, 5, 3);
        assert!(ne.fwd_scores(&state, &bad_x, &y).is_err());
        assert!(ne.train_step(&mut state, &x, &[0, 1], &[1.0; 4], 0.1).is_err());
        assert!(ne.train_step(&mut state, &x, &y, &[1.0; 3], 0.1).is_err());
        let empty = HostTensor::zeros(vec![0, 6]);
        assert!(ne.eval_metrics(&state, &empty, &[]).is_err());
    }

    #[test]
    fn default_models_are_registered() {
        let ne = NativeEngine::with_default_models();
        assert_eq!(ne.model_names(), vec!["mlp10".to_string(), "mlp100".to_string()]);
        let info = ne.model_info("mlp10").unwrap();
        assert_eq!(info.feature_dim, 64);
        assert_eq!(info.num_classes, 10);
        assert_eq!(info.batch, 128);
        assert_eq!(info.presample.iter().max(), Some(&1024));
    }

    #[test]
    fn grad_norms_are_finite_and_track_scores() {
        let ne = tiny_engine();
        let state = ne.init_state("tiny", 9).unwrap();
        let (x, y) = tiny_batch(32, 6, 3);
        let gn = ne.grad_norms(&state, &x, &y).unwrap();
        let (_, ub) = ne.fwd_scores(&state, &x, &y).unwrap();
        assert_eq!(gn.len(), 32);
        assert!(gn.iter().all(|v| v.is_finite() && *v >= 0.0));
        // the Eq.-20 bound is the last-layer factor of the true norm:
        // grad norm >= ||gz|| always (it multiplies sqrt(1 + ||h||²) >= 1)
        for (g, u) in gn.iter().zip(&ub) {
            assert!(*g >= *u - 1e-5, "grad norm {g} < upper-bound factor {u}");
        }
    }
}

//! [`NativeEngine`] — the pure-rust CPU training backend.
//!
//! Implements every entry point the coordinator uses (`train_step`,
//! `fwd_scores`, `eval_metrics`, `grad_norms`, `grad`, `weighted_grad`) for
//! **any [`LayerModel`] stack** (see [`super::layers`]): two-layer MLPs,
//! small 1-D convnets and token-sequence embedding-bag models all run
//! through the same generic forward/backward walk, with SGD + momentum +
//! weight decay matching the Eq.-2 update the AOT artifacts bake. No
//! artifacts, no PJRT runtime: this is what lets the full Algorithm-1
//! pipeline — warmup, τ switch, presample/score/resample, weighted update —
//! run and be tested end to end, on every figure architecture, in any build
//! of this repo.
//!
//! Design points:
//!
//! * Parameters live in the same [`ModelState`] (`xla::Literal` tensors) as
//!   the PJRT engine's, so checkpointing, SVRG snapshots and the analysis
//!   vecmath work identically across backends and across architectures (the
//!   SGD update and the chunk merges iterate parameter tensors generically).
//! * The per-row forward pass is *shared* with
//!   [`NativeScorer`] (both walk the same
//!   [`LayerModel`]), so native training, native scoring and the sharded
//!   scoring benches are bit-identical on the same parameters. The
//!   upper-bound score itself is the **architecture-agnostic** last-layer
//!   softmax-gradient norm of [`super::layers::row_score`] — the paper's
//!   Eq.-20, computed in one place for every stack.
//! * Every entry accepts any batch size ≥ 1 — [`Backend::supports`] is
//!   unconditional over the registry — which is why the trainer can
//!   evaluate exact partial test shards and the resampler can use any
//!   presample B natively.
//! * **Block-batched kernels**: every entry walks its rows through the
//!   cache-blocked microkernels of [`super::kernels`] in sub-blocks of up
//!   to [`MAX_BLOCK_ROWS`] rows — weight matrices stream once per block
//!   instead of once per sample, accumulators live in fixed register-lane
//!   tiles, and a score-only forward ([`LayerModel`]'s `scores_block`)
//!   never touches gradient scratch. The kernels are **bit-identical** to
//!   the scalar reference walk, so this is purely a throughput change.
//!   Chunk-sized arenas ([`super::pool::ObjectPool`]) persist across
//!   steps: the hot loop allocates nothing but its output vectors, and
//!   chunk plans are memoized per batch size.
//! * **Data parallelism** (`--train-workers N`, default one per core):
//!   every batch-level entry (`train_step`, `grad`, `weighted_grad`,
//!   `grad_norms`, `eval_metrics` — and through `grad`, the host-composed
//!   `svrg_step`) shards its batch over the engine's shared
//!   [`WorkerPool`], spawned once per engine rather than per step. The
//!   chunk plans and pool are architecture-independent, so conv and
//!   sequence models shard exactly like MLPs.
//! * **Determinism**: the shards come from [`train_chunk_plan`] (or
//!   [`grad_chunk_plan`], its chunk-count-capped variant for the
//!   gradient passes) — balanced contiguous chunks whose boundaries
//!   depend only on the batch size, never on the worker count — each
//!   chunk accumulates its rows serially
//!   in index order, and partials merge in chunk order. Every
//!   `--train-workers` value therefore produces bit-identical results
//!   (the train-side twin of the `--score-workers` scoring guarantee),
//!   and a fixed seed reproduces a trajectory bit for bit.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};
use xla::Literal;

use super::backend::Backend;
use super::engine::{ModelState, StepOutput};
use super::init;
use super::kernels::MAX_BLOCK_ROWS;
use super::layers::{row_loss, row_score, BlockScratch, Layer, LayerModel};
use super::manifest::{ModelInfo, Selfcheck};
use super::pool::{default_train_workers, ObjectPool, Task, WorkerPool};
use super::score::{split_rows, NativeScorer, ScoreKind, ScorePrecision};
use super::tensor::{f32_literal, literal_to_f32_vec, HostTensor};

/// Row granularity of the deterministic train-side chunk plan. Chunks are
/// fixed by batch size alone — never by worker count — so the partial-sum
/// merge order is identical for every `--train-workers` value. 8 rows
/// keeps ≥ 4-way parallelism at the paper's smallest training batch
/// (b = 32) while per-chunk work still dwarfs pool dispatch overhead.
pub const TRAIN_CHUNK_ROWS: usize = 8;

/// The worker-count-independent chunk plan for an `n`-row batch: balanced
/// contiguous chunks of ~[`TRAIN_CHUNK_ROWS`] rows, planned by the same
/// [`split_rows`] planner the sharded scoring backend uses. Used by the
/// entries whose per-chunk state is small (per-row outputs, scalar
/// metrics).
pub fn train_chunk_plan(n: usize) -> Vec<(usize, usize)> {
    split_rows(n, n.div_ceil(TRAIN_CHUNK_ROWS))
}

/// Chunk-count ceiling for gradient passes, whose per-chunk partial is a
/// full parameter-sized buffer: capping the count bounds the zero-fill +
/// dense-merge overhead at `MAX_GRAD_CHUNKS × params` regardless of the
/// batch size (a B = 640 `grad` call on mlp100 would otherwise churn 80
/// full gradient buffers), while leaving headroom above any realistic
/// core count.
pub const MAX_GRAD_CHUNKS: usize = 16;

/// The gradient-pass chunk plan: [`train_chunk_plan`] geometry, but with
/// the chunk count capped at [`MAX_GRAD_CHUNKS`]. Still a function of the
/// batch size alone — never of the worker count — so the fixed-order
/// partial merge stays bit-identical for every `--train-workers` value.
pub fn grad_chunk_plan(n: usize) -> Vec<(usize, usize)> {
    split_rows(n, n.div_ceil(TRAIN_CHUNK_ROWS).min(MAX_GRAD_CHUNKS))
}

/// Entries the native backend implements (any batch size).
const NATIVE_ENTRIES: &[&str] =
    &["train_step", "fwd_scores", "eval_metrics", "grad_norms", "grad", "weighted_grad"];

/// A registered native model: a [`LayerModel`] stack plus the default batch
/// geometry the figure harnesses and the trainer read.
#[derive(Debug, Clone)]
pub struct NativeModelSpec {
    pub name: String,
    /// The architecture — any layer stack; see [`super::layers`].
    pub model: LayerModel,
    /// default training batch b
    pub batch: usize,
    /// default evaluation shard size
    pub eval_batch: usize,
    /// presample sizes B advertised to the B-ablation harnesses (any size
    /// actually works natively; the max is the trainer's default)
    pub presample: Vec<usize>,
}

impl NativeModelSpec {
    /// Wrap an explicit [`LayerModel`] with batch geometry.
    pub fn new(
        name: &str,
        model: LayerModel,
        batch: usize,
        eval_batch: usize,
        presample: Vec<usize>,
    ) -> Self {
        assert!(batch > 0 && eval_batch > 0, "batch geometry must be positive");
        Self { name: name.to_string(), model, batch, eval_batch, presample }
    }

    /// Build a spec from a layer stack (panics on an invalid stack — specs
    /// are programmer-provided registry entries).
    pub fn with_layers(
        name: &str,
        in_dim: usize,
        layers: Vec<Layer>,
        batch: usize,
        eval_batch: usize,
        presample: Vec<usize>,
    ) -> Self {
        let model = LayerModel::new(in_dim, layers).expect("invalid layer stack");
        Self::new(name, model, batch, eval_batch, presample)
    }

    /// The classic two-layer MLP spec (the pre-layer-IR native registry) —
    /// `[Dense(hidden), Relu, Dense(num_classes)]`, numerically identical
    /// to the old fused implementation.
    pub fn mlp(
        name: &str,
        feature_dim: usize,
        hidden: usize,
        num_classes: usize,
        batch: usize,
        eval_batch: usize,
        presample: Vec<usize>,
    ) -> Self {
        let model = LayerModel::mlp(feature_dim, hidden, num_classes).expect("invalid mlp");
        Self::new(name, model, batch, eval_batch, presample)
    }

    /// The manifest-shaped description of this model. Entries are empty —
    /// native capability is expressed by [`Backend::supports`], not by an
    /// artifact inventory — and the selfcheck block is inert (selfchecks
    /// pin the *cross-language* contract, which only PJRT exercises).
    fn to_model_info(&self) -> ModelInfo {
        ModelInfo {
            name: self.name.clone(),
            feature_dim: self.model.in_dim(),
            num_classes: self.model.num_classes(),
            batch: self.batch,
            eval_batch: self.eval_batch,
            presample: self.presample.clone(),
            params: self.model.param_specs(),
            entries: vec![],
            selfcheck: Selfcheck {
                seed: 0,
                batch: 0,
                loss_head: vec![],
                ghat_head: vec![],
                mean_loss: f64::NAN,
                step_loss: f64::NAN,
                mean_loss_after_step: f64::NAN,
                param0_head: vec![],
            },
        }
    }
}

struct NativeModel {
    spec: NativeModelSpec,
    info: ModelInfo,
}

/// A memoized plan list: (batch size, shared chunk plan).
type PlanList = Vec<(usize, Arc<Vec<(usize, usize)>>)>;

/// Memoized chunk plans, keyed by batch size. One training run touches
/// only a handful of batch sizes (b, B, eval shards, tails), so a tiny
/// vec-map beats re-planning every step; entries are `Arc`ed so chunk
/// dispatch borrows a plan without holding the lock.
#[derive(Debug, Default)]
struct PlanCache {
    train: PlanList,
    grad: PlanList,
}

impl PlanCache {
    fn get(
        list: &mut PlanList,
        n: usize,
        plan: impl FnOnce(usize) -> Vec<(usize, usize)>,
    ) -> Arc<Vec<(usize, usize)>> {
        if let Some((_, p)) = list.iter().find(|(k, _)| *k == n) {
            return Arc::clone(p);
        }
        // a run only ever sees a few batch sizes; guard the degenerate
        // many-sizes case so the cache cannot grow without bound
        if list.len() >= 64 {
            list.clear();
        }
        let p = Arc::new(plan(n));
        list.push((n, Arc::clone(&p)));
        p
    }
}

/// Per-row gradient coefficient of a weighted pass, computed on the fly —
/// no per-call coefficient vector on the step loop. `Scaled` performs the
/// same single `w[r] * scale` multiply the old precomputed vector held,
/// so the change is bit-invisible.
#[derive(Clone, Copy)]
enum RowCoeff<'a> {
    /// Every row weighs the same (the mean gradient of `grad`: `1/n`).
    Uniform(f32),
    /// Row `r` weighs `w[r] * scale` (the Eq.-2 weighted estimators).
    Scaled { w: &'a [f32], scale: f32 },
}

impl RowCoeff<'_> {
    #[inline]
    fn at(self, r: usize) -> f32 {
        match self {
            RowCoeff::Uniform(c) => c,
            RowCoeff::Scaled { w, scale } => w[r] * scale,
        }
    }
}

/// The pure-rust training backend. See the module docs.
pub struct NativeEngine {
    models: BTreeMap<String, NativeModel>,
    /// SGD momentum (Eq. 2); matches the AOT manifest default.
    pub momentum: f32,
    /// L2 weight decay applied inside `train_step` (not in `grad`).
    pub weight_decay: f32,
    /// Batch-compute worker threads (`--train-workers`); any value is
    /// bit-identical (see module docs).
    train_workers: AtomicUsize,
    /// Presample scoring precision (`--score-precision`): 0 = f32,
    /// 1 = bf16 parameter storage. Only `fwd_scores` reads it — training,
    /// eval and the gradient-norm oracle always run f32.
    score_precision: AtomicU8,
    /// The shared pool, built lazily on first parallel use and rebuilt
    /// only when the worker count changes — never per step.
    pool: Mutex<Option<Arc<WorkerPool>>>,
    /// Persistent chunk-sized block-walk arenas — checked out per chunk,
    /// returned when the chunk completes, so the step loop allocates no
    /// activation/scratch buffers in steady state.
    arenas: ObjectPool<BlockScratch>,
    /// Persistent partial-gradient buffers for the gradient passes (one
    /// full parameter-sized buffer per in-flight chunk).
    grad_bufs: ObjectPool<Vec<Vec<f32>>>,
    /// Persistent per-row output buffers for entries whose loss/score
    /// vectors are internal scratch (`grad`, `weighted_grad`).
    row_bufs: ObjectPool<Vec<f32>>,
    /// Memoized train/grad chunk plans (see [`PlanCache`]).
    plans: Mutex<PlanCache>,
}

impl Default for NativeEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl NativeEngine {
    /// An empty registry (register specs with [`register`](Self::register)).
    pub fn new() -> Self {
        Self {
            models: BTreeMap::new(),
            momentum: 0.9,
            weight_decay: 5e-4,
            train_workers: AtomicUsize::new(default_train_workers()),
            score_precision: AtomicU8::new(0),
            pool: Mutex::new(None),
            arenas: ObjectPool::new(),
            grad_bufs: ObjectPool::new(),
            row_bufs: ObjectPool::new(),
            plans: Mutex::new(PlanCache::default()),
        }
    }

    /// The memoized [`train_chunk_plan`] for an `n`-row batch.
    fn train_plan(&self, n: usize) -> Arc<Vec<(usize, usize)>> {
        PlanCache::get(&mut self.plans.lock().unwrap().train, n, train_chunk_plan)
    }

    /// The memoized [`grad_chunk_plan`] for an `n`-row batch.
    fn grad_plan(&self, n: usize) -> Arc<Vec<(usize, usize)>> {
        PlanCache::get(&mut self.plans.lock().unwrap().grad, n, grad_chunk_plan)
    }

    /// Builder form of [`set_train_workers`](Self::set_train_workers).
    pub fn with_train_workers(self, workers: usize) -> Self {
        self.set_train_workers(workers);
        self
    }

    /// Set the batch-compute worker count (clamped to ≥ 1). Interior
    /// mutability so a shared backend can be retuned between runs; the
    /// pool is rebuilt at the new size on next use.
    pub fn set_train_workers(&self, workers: usize) {
        let workers = workers.max(1);
        if self.train_workers.swap(workers, Ordering::SeqCst) != workers {
            *self.pool.lock().unwrap() = None;
        }
    }

    pub fn train_workers(&self) -> usize {
        self.train_workers.load(Ordering::SeqCst)
    }

    /// Builder form of [`set_score_precision`](Self::set_score_precision).
    pub fn with_score_precision(self, precision: ScorePrecision) -> Self {
        self.set_score_precision(precision);
        self
    }

    /// Set the presample scoring precision (`--score-precision`).
    /// Interior-mutable like [`set_train_workers`](Self::set_train_workers);
    /// takes effect on the next `fwd_scores` call.
    pub fn set_score_precision(&self, precision: ScorePrecision) {
        self.score_precision.store(precision.code(), Ordering::SeqCst);
    }

    pub fn score_precision(&self) -> ScorePrecision {
        ScorePrecision::from_code(self.score_precision.load(Ordering::SeqCst))
            .unwrap_or(ScorePrecision::Bf16)
    }

    /// The shared pool at the current worker count (lazily spawned).
    fn pool(&self) -> Arc<WorkerPool> {
        let workers = self.train_workers();
        let mut guard = self.pool.lock().unwrap();
        if let Some(p) = guard.as_ref() {
            if p.workers() == workers {
                return Arc::clone(p);
            }
        }
        let p = Arc::new(WorkerPool::new(workers));
        *guard = Some(Arc::clone(&p));
        p
    }

    /// Run `f(start, len)` for every chunk of the plan and return the
    /// outputs **in chunk order**. One worker — or one chunk — runs
    /// inline on the caller's thread; otherwise chunks fan out to the
    /// shared pool. The output order (and therefore every downstream
    /// reduction) never depends on the worker count.
    fn run_chunks<T, F>(&self, chunks: &[(usize, usize)], f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize, usize) -> T + Sync,
    {
        if self.train_workers() <= 1 || chunks.len() <= 1 {
            return chunks.iter().map(|&(start, len)| f(start, len)).collect();
        }
        let fref = &f;
        let tasks: Vec<Task<'_, T>> = chunks
            .iter()
            .map(|&(start, len)| Box::new(move || fref(start, len)) as Task<'_, T>)
            .collect();
        self.pool().run(tasks)
    }

    /// Run pre-built per-chunk tasks and return their outputs in task
    /// order (same dispatch policy as [`run_chunks`](Self::run_chunks)).
    /// Used by the passes whose tasks carry disjoint `&mut` windows of a
    /// caller-owned output buffer — no per-chunk output vectors at all.
    fn run_tasks<'env, T: Send + 'env>(&self, tasks: Vec<Task<'env, T>>) -> Vec<T> {
        if self.train_workers() <= 1 || tasks.len() <= 1 {
            return tasks.into_iter().map(|t| t()).collect();
        }
        self.pool().run(tasks)
    }

    /// The stock registry, one native model per figure scenario:
    ///
    /// * `mlp10` / `mlp100` — the two-layer MLP stand-ins for the PJRT
    ///   mlp10 geometry and the CIFAR-100-ish §4.2 configuration
    ///   (bit-identical to the pre-layer-IR registry).
    /// * `conv10` — a small Conv1d image net (fig 3's native conv
    ///   scenario): two strided conv+relu stages, global average pooling
    ///   and a dense head over the 64-dim synthetic images.
    /// * `seq64` — an EmbeddingBag sequence net (fig 5's native scenario):
    ///   positional 16-bin quantization of the 64-step permuted rasters,
    ///   sum-pooled embeddings (`gain = T`) and a dense head.
    pub fn with_default_models() -> Self {
        let mut ne = Self::new();
        ne.register(NativeModelSpec::mlp("mlp10", 64, 128, 10, 128, 256, vec![384, 640, 1024]));
        ne.register(NativeModelSpec::mlp("mlp100", 768, 256, 100, 128, 512, vec![640, 1024]));
        ne.register(NativeModelSpec::with_layers(
            "conv10",
            64,
            vec![
                Layer::Conv1d { in_ch: 1, out_ch: 8, kernel: 5, stride: 2 },
                Layer::Relu,
                Layer::Conv1d { in_ch: 8, out_ch: 16, kernel: 3, stride: 2 },
                Layer::Relu,
                Layer::GlobalAvgPool { channels: 16 },
                Layer::Dense { out_dim: 32 },
                Layer::Relu,
                Layer::Dense { out_dim: 10 },
            ],
            128,
            256,
            vec![384, 640],
        ));
        ne.register(NativeModelSpec::with_layers(
            "seq64",
            64,
            vec![
                Layer::EmbeddingBag {
                    vocab: 16,
                    dim: 32,
                    lo: -3.0,
                    hi: 3.0,
                    positional: true,
                    gain: 64.0,
                },
                Layer::Dense { out_dim: 32 },
                Layer::Relu,
                Layer::Dense { out_dim: 10 },
            ],
            32,
            256,
            vec![128, 256],
        ));
        ne
    }

    /// Add (or replace) a model.
    pub fn register(&mut self, spec: NativeModelSpec) -> &mut Self {
        let info = spec.to_model_info();
        self.models.insert(spec.name.clone(), NativeModel { spec, info });
        self
    }

    pub fn model_names(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    fn model(&self, name: &str) -> Result<&NativeModel> {
        self.models.get(name).with_context(|| {
            format!(
                "unknown native model {name:?}; registered: {}",
                self.models.keys().cloned().collect::<Vec<_>>().join(", ")
            )
        })
    }

    /// The registered [`LayerModel`] stack of a model.
    pub fn layer_model(&self, name: &str) -> Result<&LayerModel> {
        Ok(&self.model(name)?.spec.model)
    }

    /// A [`NativeScorer`] over the state's current parameters — scores are
    /// bit-identical to this backend's `fwd_scores` (same layer walk).
    pub fn scorer(&self, state: &ModelState) -> Result<NativeScorer> {
        let m = self.model(&state.model)?;
        NativeScorer::from_model(m.spec.model.clone(), state.params_to_host()?)
    }

    fn check_batch(&self, m: &NativeModel, x: &HostTensor, y: &[i32]) -> Result<usize> {
        let d = m.spec.model.in_dim();
        if x.shape.len() != 2 || x.shape[1] != d {
            bail!(
                "x shape {:?} does not match native model {:?} expectation [n, {d}]",
                x.shape,
                m.spec.name
            );
        }
        let n = x.shape[0];
        if n == 0 {
            bail!("empty batch");
        }
        if y.len() != n {
            bail!("y length {} != batch {n}", y.len());
        }
        Ok(n)
    }

    /// Forward + backward over the whole batch, data-parallel over the
    /// memoized chunk plan. Each chunk walks its rows through the block
    /// kernels into a pooled partial-gradient buffer
    /// ([`backward_pass_range`]); partials then merge element-wise **in
    /// chunk order** — the fixed-order reduction that makes every worker
    /// count bit-identical (seeded with chunk 0's partial: no zero-filled
    /// accumulator, one fewer full add). Per-row losses and Eq.-20 scores
    /// land directly in the caller's `loss_out`/`score_out` through
    /// disjoint chunk windows — no per-chunk output vectors. Returns the
    /// merged gradient buffer (return it with `self.grad_bufs.put` when
    /// done) and the weighted loss `Σ coeffᵢ·lossᵢ`.
    #[allow(clippy::too_many_arguments)]
    fn batch_pass(
        &self,
        model: &LayerModel,
        p: &[Vec<f32>],
        x: &HostTensor,
        y: &[i32],
        coeff: RowCoeff<'_>,
        loss_out: &mut [f32],
        score_out: &mut [f32],
    ) -> (Vec<Vec<f32>>, f64) {
        let n = x.shape[0];
        let chunks = self.grad_plan(n);
        let loss_parts = split_chunk_slices(loss_out, &chunks);
        let score_parts = split_chunk_slices(score_out, &chunks);
        let mut tasks: Vec<Task<'_, (Vec<Vec<f32>>, f64)>> = Vec::with_capacity(chunks.len());
        for ((&(start, len), lp), sp) in chunks.iter().zip(loss_parts).zip(score_parts) {
            tasks.push(Box::new(move || {
                let mut arena = self.arenas.checkout_or(BlockScratch::new);
                let mut grads = self.grad_bufs.checkout_or(Vec::new);
                zero_grads_into(model, &mut grads);
                let wl = backward_pass_range(
                    model, p, x, y, coeff, start, len, &mut arena, &mut grads, lp, sp,
                );
                self.arenas.put(arena);
                (grads, wl)
            }));
        }
        let mut outs = self.run_tasks(tasks).into_iter();
        let (mut grads, mut weighted_loss) =
            outs.next().expect("chunk plan is never empty for n >= 1");
        for (g, wl) in outs {
            for (gt, ot) in grads.iter_mut().zip(&g) {
                for (gv, &ov) in gt.iter_mut().zip(ot) {
                    *gv += ov;
                }
            }
            self.grad_bufs.put(g);
            weighted_loss += wl;
        }
        (grads, weighted_loss)
    }
}

/// Split `buf` into per-chunk `&mut` windows matching a contiguous,
/// in-order chunk plan (which always covers `buf` exactly).
fn split_chunk_slices<'a>(mut buf: &'a mut [f32], chunks: &[(usize, usize)]) -> Vec<&'a mut [f32]> {
    let mut out = Vec::with_capacity(chunks.len());
    for &(_, len) in chunks {
        let (head, tail) = buf.split_at_mut(len);
        out.push(head);
        buf = tail;
    }
    out
}

/// Shape a pooled partial-gradient buffer for `model` and zero it (the
/// zero-fill is the same memset a fresh buffer would need; pooling removes
/// the per-chunk malloc/free on top of it).
fn zero_grads_into(model: &LayerModel, grads: &mut Vec<Vec<f32>>) {
    grads.resize_with(model.num_param_tensors(), Vec::new);
    for (g, &n) in grads.iter_mut().zip(model.param_elems()) {
        g.clear();
        g.resize(n, 0.0);
    }
}

/// Pull a literal list to host tensors, checking the expected count.
pub(crate) fn host_tensors(lits: &[Literal], expect: usize, what: &str) -> Result<Vec<Vec<f32>>> {
    if lits.len() != expect {
        bail!("native model expects {expect} {what} tensors, got {}", lits.len());
    }
    lits.iter().map(literal_to_f32_vec).collect()
}

/// Rebuild the literal list from host tensors, in manifest param order.
/// Borrows the tensors (the literal copies the data), so pooled buffers
/// can be recycled after conversion.
pub(crate) fn lits_from(info: &ModelInfo, tensors: &[Vec<f32>]) -> Result<Vec<Literal>> {
    info.params.iter().zip(tensors).map(|(spec, data)| f32_literal(&spec.shape, data)).collect()
}

/// Forward + backward over rows `start..start + len` of the batch, walked
/// in sub-blocks of at most [`MAX_BLOCK_ROWS`] rows through the block
/// kernels of `runtime::kernels`. `coeff.at(r)` scales row `r`'s gradient
/// contribution (`1/n` for a mean gradient, `wᵢ/n` for the Eq.-2 weighted
/// estimators). Rows accumulate in index order into the chunk's partial
/// gradient — one chunk of the fixed-order reduction of the module docs —
/// and the sub-block size is numerically invisible (every element's
/// accumulation chain is identical to the scalar row walk; see
/// `runtime::kernels`). Writes per-row losses/scores into the chunk-local
/// `loss_out`/`score_out` windows and returns the chunk's
/// `Σ coeffᵢ·lossᵢ`. The walk is the generic [`LayerModel`] one: the same
/// kernels train MLPs, convnets and sequence models.
#[allow(clippy::too_many_arguments)]
fn backward_pass_range(
    model: &LayerModel,
    p: &[Vec<f32>],
    x: &HostTensor,
    y: &[i32],
    coeff: RowCoeff<'_>,
    start: usize,
    len: usize,
    arena: &mut BlockScratch,
    grads: &mut [Vec<f32>],
    loss_out: &mut [f32],
    score_out: &mut [f32],
) -> f64 {
    let d = x.shape[1];
    let c = model.num_classes();
    let mut weighted_loss = 0.0f64;
    let mut done = 0usize;
    while done < len {
        let rows = (len - done).min(MAX_BLOCK_ROWS);
        let r0 = start + done;
        let xb = &x.data[r0 * d..(r0 + rows) * d];
        model.forward_block(p, xb, rows, arena);
        let mut any_nonzero = false;
        {
            let probs = arena.probs();
            for r in 0..rows {
                let yy = model.clamp_label(y[r0 + r]);
                let prow = &probs[r * c..(r + 1) * c];
                let loss = row_loss(prow, yy);
                loss_out[done + r] = loss;
                score_out[done + r] = row_score(prow, yy);
                let cf = coeff.at(r0 + r);
                weighted_loss += cf as f64 * loss as f64;
                any_nonzero |= cf != 0.0;
            }
        }
        // A fully masked block (every coefficient zero) contributes an
        // exactly-zero gradient: skip its backward walk, like the scalar
        // reference's per-row `cf == 0` skip. Mixed blocks keep their
        // zero-coefficient rows — their seeded gradient is exactly ±0.0,
        // which is bitwise invisible to every accumulator (see
        // `runtime::kernels`).
        if any_nonzero {
            let pm = arena.probs_mut();
            for r in 0..rows {
                let yy = model.clamp_label(y[r0 + r]);
                let cf = coeff.at(r0 + r);
                let gz = &mut pm[r * c..(r + 1) * c];
                gz[yy] -= 1.0;
                for g in gz.iter_mut() {
                    *g *= cf;
                }
            }
            model.backward_block(p, xb, rows, arena, grads);
        }
        done += rows;
    }
    weighted_loss
}

/// One chunk's partial results from [`grad_chunk`]: a full-parameter-shape
/// partial gradient, the chunk's `Σ coeffᵢ·lossᵢ` contribution, and the
/// per-row losses and Eq.-20 scores the forward pass produced for free.
#[derive(Debug, Clone)]
pub struct ChunkGrad {
    pub grads: Vec<Vec<f32>>,
    pub weighted_loss: f64,
    pub loss: Vec<f32>,
    pub scores: Vec<f32>,
}

/// Chunk-level validation for the standalone chunk entry points: `x` is
/// `[n, in_dim]`, labels match, and `params` matches the model's parameter
/// specs. These entries run on wire-fed inputs (the distributed data
/// plane), so they bail instead of trusting the caller.
fn check_chunk(
    model: &LayerModel,
    params: &[Vec<f32>],
    x: &HostTensor,
    y: &[i32],
) -> Result<usize> {
    if params.len() != model.num_param_tensors()
        || params.iter().zip(model.param_elems()).any(|(p, &e)| p.len() != e)
    {
        bail!("chunk params do not match the model's parameter shapes");
    }
    let d = model.in_dim();
    if x.shape.len() != 2 || x.shape[1] != d {
        bail!("chunk x shape {:?} does not match model expectation [n, {d}]", x.shape);
    }
    let n = x.shape[0];
    if n == 0 {
        bail!("empty chunk");
    }
    if y.len() != n {
        bail!("chunk y length {} != rows {n}", y.len());
    }
    Ok(n)
}

/// One gradient chunk as a standalone computation: forward + backward over
/// every row of `x` (a chunk already cut from its batch), scaling row `r`'s
/// gradient contribution by `w[r]·scale` (or by `scale` alone when `w` is
/// `None`). The body is exactly one chunk task of [`NativeEngine`]'s
/// `batch_pass` — the distributed data plane runs chunks through here on
/// workers and merges the partials in chunk order, bit-identical to the
/// in-process run. Allocates its own scratch (no engine pools), so it is
/// safe from any thread or process.
pub fn grad_chunk(
    model: &LayerModel,
    params: &[Vec<f32>],
    x: &HostTensor,
    y: &[i32],
    w: Option<&[f32]>,
    scale: f32,
) -> Result<ChunkGrad> {
    let n = check_chunk(model, params, x, y)?;
    let coeff = match w {
        Some(w) => {
            if w.len() != n {
                bail!("chunk w length {} != rows {n}", w.len());
            }
            RowCoeff::Scaled { w, scale }
        }
        None => RowCoeff::Uniform(scale),
    };
    let mut arena = BlockScratch::new();
    let mut grads = Vec::new();
    zero_grads_into(model, &mut grads);
    let mut loss = vec![0.0f32; n];
    let mut scores = vec![0.0f32; n];
    let weighted_loss = backward_pass_range(
        model, params, x, y, coeff, 0, n, &mut arena, &mut grads, &mut loss, &mut scores,
    );
    Ok(ChunkGrad { grads, weighted_loss, loss, scores })
}

/// Score-only chunk: per-row (loss, Eq.-20 score) via the same block walk
/// as `fwd_scores`. Pass `qparams` (from [`LayerModel::quantize_params`])
/// to walk the bf16 kernels — the caller owns the narrowing so it can be
/// cached per parameter version.
pub fn score_chunk(
    model: &LayerModel,
    params: &[Vec<f32>],
    qparams: Option<&[Vec<u16>]>,
    x: &HostTensor,
    y: &[i32],
) -> Result<(Vec<f32>, Vec<f32>)> {
    let n = check_chunk(model, params, x, y)?;
    let d = x.shape[1];
    let mut loss = vec![0.0f32; n];
    let mut scores = vec![0.0f32; n];
    let mut arena = BlockScratch::new();
    let mut start = 0usize;
    while start < n {
        let rows = (n - start).min(MAX_BLOCK_ROWS);
        let xb = &x.data[start * d..(start + rows) * d];
        let yb = &y[start..start + rows];
        let lw = &mut loss[start..start + rows];
        let uw = &mut scores[start..start + rows];
        if let Some(qp) = qparams {
            model.scores_block_bf16(qp, xb, yb, rows, &mut arena, lw, uw);
        } else {
            model.scores_block(params, xb, yb, rows, &mut arena, lw, uw);
        }
        start += rows;
    }
    Ok((loss, scores))
}

/// Evaluation chunk: (sum of losses, number of correct predictions) over
/// every row of `x` — one term of `eval_metrics`' fixed-order merge.
pub fn eval_chunk(
    model: &LayerModel,
    params: &[Vec<f32>],
    x: &HostTensor,
    y: &[i32],
) -> Result<(f64, i64)> {
    let n = check_chunk(model, params, x, y)?;
    let d = x.shape[1];
    let mut arena = BlockScratch::new();
    let mut sum_loss = 0.0f64;
    let mut correct = 0i64;
    let mut done = 0usize;
    while done < n {
        let rows = (n - done).min(MAX_BLOCK_ROWS);
        model.eval_block(
            params,
            &x.data[done * d..(done + rows) * d],
            &y[done..done + rows],
            rows,
            &mut arena,
            &mut sum_loss,
            &mut correct,
        );
        done += rows;
    }
    Ok((sum_loss, correct))
}

/// Gradient-norm chunk: the exact per-sample oracle over every row of `x`
/// — one disjoint window of `grad_norms`' output.
pub fn grad_norm_chunk(
    model: &LayerModel,
    params: &[Vec<f32>],
    x: &HostTensor,
    y: &[i32],
) -> Result<Vec<f32>> {
    let n = check_chunk(model, params, x, y)?;
    let d = x.shape[1];
    let mut arena = BlockScratch::new();
    let mut out = vec![0.0f32; n];
    for (r, o) in out.iter_mut().enumerate() {
        let row = &x.data[r * d..(r + 1) * d];
        *o = model.grad_norm_row(params, row, y[r], &mut arena);
    }
    Ok(out)
}

/// Eq. 2 with the manifest's optimizer: `g' = g + wd·θ; v ← μ·v + g';
/// θ ← θ - lr·v`, element-wise in parameter order. Factored out of
/// `train_step` so the distributed backend applies the byte-identical
/// update to its merged gradient.
pub fn sgd_update(
    params: &mut [Vec<f32>],
    mom: &mut [Vec<f32>],
    grads: &[Vec<f32>],
    lr: f32,
    momentum: f32,
    weight_decay: f32,
) {
    for ((pt, vt), gt) in params.iter_mut().zip(mom.iter_mut()).zip(grads) {
        for ((pv, vv), &gv) in pt.iter_mut().zip(vt.iter_mut()).zip(gt) {
            let g = gv + weight_decay * *pv;
            *vv = momentum * *vv + g;
            *pv -= lr * *vv;
        }
    }
}

impl Backend for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn set_train_workers(&self, workers: usize) {
        NativeEngine::set_train_workers(self, workers);
    }

    fn train_workers(&self) -> usize {
        NativeEngine::train_workers(self)
    }

    fn set_score_precision(&self, precision: ScorePrecision) {
        NativeEngine::set_score_precision(self, precision);
    }

    fn scores_sharded_internally(&self, kind: ScoreKind) -> bool {
        // Once `grad_norms` is chunk-parallel over the train pool, that
        // pool is the only real parallel layer — an outer `--score-workers`
        // shard on top would funnel its chunks into the same pool and
        // block. Forward-pass scoring is serial per call, so the outer
        // layer keeps its threads there.
        kind == ScoreKind::GradNorm && NativeEngine::train_workers(self) > 1
    }

    fn model_info(&self, model: &str) -> Result<&ModelInfo> {
        Ok(&self.model(model)?.info)
    }

    fn supports(&self, model: &str, entry: &str, batch: usize) -> Result<bool> {
        self.model(model)?;
        Ok(batch >= 1 && NATIVE_ENTRIES.contains(&entry))
    }

    fn prepare(&self, model: &str, entry: &str, batch: usize) -> Result<()> {
        if !self.supports(model, entry, batch)? {
            bail!("native backend does not implement {entry:?} (model {model:?})");
        }
        Ok(())
    }

    fn init_state(&self, model: &str, seed: u64) -> Result<ModelState> {
        init::init_state(&self.model(model)?.info, seed)
    }

    fn train_step(
        &self,
        state: &mut ModelState,
        x: &HostTensor,
        y: &[i32],
        w: &[f32],
        lr: f32,
    ) -> Result<StepOutput> {
        let m = self.model(&state.model)?;
        let n = self.check_batch(m, x, y)?;
        if w.len() != n {
            bail!("w length {} != batch {n}", w.len());
        }
        let nt = m.info.params.len();
        let mut params = host_tensors(&state.params, nt, "parameter")?;
        let mut mom = host_tensors(&state.mom, nt, "momentum")?;
        let inv_n = 1.0 / n as f32;
        let mut loss_vec = vec![0.0f32; n];
        let mut scores = vec![0.0f32; n];
        let (grads, weighted_loss) = self.batch_pass(
            &m.spec.model,
            &params,
            x,
            y,
            RowCoeff::Scaled { w, scale: inv_n },
            &mut loss_vec,
            &mut scores,
        );
        sgd_update(&mut params, &mut mom, &grads, lr, self.momentum, self.weight_decay);
        self.grad_bufs.put(grads);
        state.params = lits_from(&m.info, &params)?;
        state.mom = lits_from(&m.info, &mom)?;
        state.step += 1;
        Ok(StepOutput { loss: weighted_loss as f32, loss_vec, scores })
    }

    fn fwd_scores(
        &self,
        state: &ModelState,
        x: &HostTensor,
        y: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let m = self.model(&state.model)?;
        let n = self.check_batch(m, x, y)?;
        let p = host_tensors(&state.params, m.info.params.len(), "parameter")?;
        let model = &m.spec.model;
        // Score-only fast path: block forwards into a pooled arena — no
        // gradient scratch, no per-call activation allocation. Serial on
        // purpose: presample-scale parallelism is the scoring subsystem's
        // job (`--score-workers` shards the batch *across* fwd_scores
        // calls), so an inner pool layer would only add dispatch overhead.
        let d = x.shape[1];
        let mut loss_vec = vec![0.0f32; n];
        let mut scores = vec![0.0f32; n];
        let mut arena = self.arenas.checkout_or(BlockScratch::new);
        // `--score-precision bf16`: narrow the parameters once per call
        // (tiny next to the B-row forward) and walk the bf16 kernels.
        // Long-lived scoring loops that want to amortize the narrowing
        // use `NativeScorer::with_precision` instead.
        let qp = match self.score_precision() {
            ScorePrecision::F32 => None,
            ScorePrecision::Bf16 => Some(model.quantize_params(&p)),
        };
        let mut start = 0usize;
        while start < n {
            let rows = (n - start).min(MAX_BLOCK_ROWS);
            let xb = &x.data[start * d..(start + rows) * d];
            let yb = &y[start..start + rows];
            let lw = &mut loss_vec[start..start + rows];
            let uw = &mut scores[start..start + rows];
            if let Some(qp) = &qp {
                model.scores_block_bf16(qp, xb, yb, rows, &mut arena, lw, uw);
            } else {
                model.scores_block(&p, xb, yb, rows, &mut arena, lw, uw);
            }
            start += rows;
        }
        self.arenas.put(arena);
        Ok((loss_vec, scores))
    }

    fn eval_metrics(&self, state: &ModelState, x: &HostTensor, y: &[i32]) -> Result<(f64, i64)> {
        let m = self.model(&state.model)?;
        let n = self.check_batch(m, x, y)?;
        let p = host_tensors(&state.params, m.info.params.len(), "parameter")?;
        let model = &m.spec.model;
        let chunks = self.train_plan(n);
        let d = x.shape[1];
        let outs = self.run_chunks(&chunks, |start, len| {
            // Same score-only fast path as `fwd_scores`: `eval_block` is
            // one block forward per sub-block — no gradient scratch, no
            // per-call allocation beyond the pooled arena checkout.
            let mut arena = self.arenas.checkout_or(BlockScratch::new);
            let mut sum_loss = 0.0f64;
            let mut correct = 0i64;
            let mut done = 0usize;
            while done < len {
                let rows = (len - done).min(MAX_BLOCK_ROWS);
                let r0 = start + done;
                model.eval_block(
                    &p,
                    &x.data[r0 * d..(r0 + rows) * d],
                    &y[r0..r0 + rows],
                    rows,
                    &mut arena,
                    &mut sum_loss,
                    &mut correct,
                );
                done += rows;
            }
            self.arenas.put(arena);
            (sum_loss, correct)
        });
        // fixed-order (chunk index) merge: bit-identical for any workers
        let mut sum_loss = 0.0f64;
        let mut correct = 0i64;
        for (l, k) in outs {
            sum_loss += l;
            correct += k;
        }
        Ok((sum_loss, correct))
    }

    fn grad_norms(&self, state: &ModelState, x: &HostTensor, y: &[i32]) -> Result<Vec<f32>> {
        let m = self.model(&state.model)?;
        let n = self.check_batch(m, x, y)?;
        let p = host_tensors(&state.params, m.info.params.len(), "parameter")?;
        let model = &m.spec.model;
        // Exact per-sample gradient norm via the generic layer walk
        // (closed forms per layer where separable; see
        // `layers::Layer::grad_sq_norm`), one pooled arena per chunk.
        // Per-row outputs land in disjoint windows of one output buffer,
        // so chunked compute is trivially bit-identical for any worker
        // count.
        let chunks = self.train_plan(n);
        let d = x.shape[1];
        let pref = &p; // shared by every chunk task (references are Copy)
        let mut out = vec![0.0f32; n];
        let out_parts = split_chunk_slices(&mut out, &chunks);
        let mut tasks: Vec<Task<'_, ()>> = Vec::with_capacity(chunks.len());
        for (&(start, _), op) in chunks.iter().zip(out_parts) {
            tasks.push(Box::new(move || {
                let mut arena = self.arenas.checkout_or(BlockScratch::new);
                for (r, o) in op.iter_mut().enumerate() {
                    let row = &x.data[(start + r) * d..(start + r + 1) * d];
                    *o = model.grad_norm_row(pref, row, y[start + r], &mut arena);
                }
                self.arenas.put(arena);
            }));
        }
        self.run_tasks(tasks);
        Ok(out)
    }

    fn grad(
        &self,
        model: &str,
        params: &[Literal],
        x: &HostTensor,
        y: &[i32],
    ) -> Result<(Vec<Literal>, f32)> {
        let m = self.model(model)?;
        let n = self.check_batch(m, x, y)?;
        let p = host_tensors(params, m.info.params.len(), "parameter")?;
        // per-row losses/scores are internal scratch here: pooled buffers
        let mut loss_tmp = self.row_bufs.checkout_or(Vec::new);
        let mut score_tmp = self.row_bufs.checkout_or(Vec::new);
        resize_rows(&mut loss_tmp, n);
        resize_rows(&mut score_tmp, n);
        let (grads, wl) = self.batch_pass(
            &m.spec.model,
            &p,
            x,
            y,
            RowCoeff::Uniform(1.0 / n as f32),
            &mut loss_tmp,
            &mut score_tmp,
        );
        let lits = lits_from(&m.info, &grads)?;
        self.grad_bufs.put(grads);
        self.row_bufs.put(loss_tmp);
        self.row_bufs.put(score_tmp);
        Ok((lits, wl as f32))
    }

    fn weighted_grad(
        &self,
        state: &ModelState,
        x: &HostTensor,
        y: &[i32],
        w: &[f32],
    ) -> Result<(Vec<Literal>, f32)> {
        let m = self.model(&state.model)?;
        let n = self.check_batch(m, x, y)?;
        if w.len() != n {
            bail!("w length {} != batch {n}", w.len());
        }
        let p = host_tensors(&state.params, m.info.params.len(), "parameter")?;
        let inv_n = 1.0 / n as f32;
        let mut loss_tmp = self.row_bufs.checkout_or(Vec::new);
        let mut score_tmp = self.row_bufs.checkout_or(Vec::new);
        resize_rows(&mut loss_tmp, n);
        resize_rows(&mut score_tmp, n);
        let (grads, wl) = self.batch_pass(
            &m.spec.model,
            &p,
            x,
            y,
            RowCoeff::Scaled { w, scale: inv_n },
            &mut loss_tmp,
            &mut score_tmp,
        );
        let lits = lits_from(&m.info, &grads)?;
        self.grad_bufs.put(grads);
        self.row_bufs.put(loss_tmp);
        self.row_bufs.put(score_tmp);
        Ok((lits, wl as f32))
    }
}

/// Re-shape a pooled per-row buffer to `n` rows (reusing its capacity).
fn resize_rows(buf: &mut Vec<f32>, n: usize) {
    buf.clear();
    buf.resize(n, 0.0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::score::{SampleScorer, ScoreKind};

    fn tiny_engine() -> NativeEngine {
        let mut ne = NativeEngine::new();
        ne.register(NativeModelSpec::mlp("tiny", 6, 5, 3, 4, 8, vec![16]));
        ne
    }

    /// A conv+pool stack over [8 time, 2 ch] inputs — the quick in-module
    /// coverage that non-MLP stacks drive every entry point.
    fn conv_engine() -> NativeEngine {
        let mut ne = NativeEngine::new();
        ne.register(NativeModelSpec::with_layers(
            "cv",
            16,
            vec![
                Layer::Conv1d { in_ch: 2, out_ch: 4, kernel: 3, stride: 1 },
                Layer::Relu,
                Layer::GlobalAvgPool { channels: 4 },
                Layer::Dense { out_dim: 3 },
            ],
            4,
            8,
            vec![16],
        ));
        ne
    }

    fn tiny_batch(n: usize, d: usize, c: usize) -> (HostTensor, Vec<i32>) {
        let mut x = HostTensor::zeros(vec![n, d]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = ((i * 29 + 13) % 71) as f32 / 71.0 - 0.5;
        }
        let y: Vec<i32> = (0..n).map(|i| (i % c) as i32).collect();
        (x, y)
    }

    #[test]
    fn init_is_deterministic_and_shaped() {
        let ne = tiny_engine();
        let a = ne.init_state("tiny", 7).unwrap();
        let b = ne.init_state("tiny", 7).unwrap();
        let c = ne.init_state("tiny", 8).unwrap();
        assert_eq!(a.params.len(), 4);
        assert_eq!(a.mom.len(), 4);
        let ah = host_tensors(&a.params, 4, "p").unwrap();
        let bh = host_tensors(&b.params, 4, "p").unwrap();
        let ch = host_tensors(&c.params, 4, "p").unwrap();
        assert_eq!(ah, bh);
        assert_ne!(ah[0], ch[0]);
        assert_eq!(ah[0].len(), 6 * 5);
        assert!(ah[1].iter().all(|&v| v == 0.0)); // b1 zeros
        assert!(ne.model_info("nope").is_err());
    }

    #[test]
    fn supports_and_prepare() {
        let ne = tiny_engine();
        for &entry in super::NATIVE_ENTRIES {
            assert!(ne.supports("tiny", entry, 1).unwrap(), "{entry}");
            assert!(ne.supports("tiny", entry, 9999).unwrap(), "{entry}");
            ne.prepare("tiny", entry, 33).unwrap();
        }
        assert!(!ne.supports("tiny", "svrg_step", 8).unwrap()); // default impl, not an entry
        assert!(ne.supports("missing", "train_step", 8).is_err());
        assert!(ne.prepare("tiny", "bogus", 8).is_err());
    }

    #[test]
    fn train_step_reduces_loss_on_a_fixed_batch() {
        let ne = tiny_engine();
        let mut state = ne.init_state("tiny", 1).unwrap();
        let (x, y) = tiny_batch(4, 6, 3);
        let w = [1.0f32; 4];
        let first = ne.train_step(&mut state, &x, &y, &w, 0.2).unwrap();
        assert_eq!(first.loss_vec.len(), 4);
        assert_eq!(first.scores.len(), 4);
        assert!(first.scores.iter().all(|s| s.is_finite() && *s >= 0.0));
        let mut last = first.loss;
        for _ in 0..60 {
            last = ne.train_step(&mut state, &x, &y, &w, 0.2).unwrap().loss;
        }
        assert!(last < first.loss * 0.5, "loss did not drop: {} -> {last}", first.loss);
        assert_eq!(state.step, 61);
    }

    #[test]
    fn conv_train_step_reduces_loss_on_a_fixed_batch() {
        let ne = conv_engine();
        let mut state = ne.init_state("cv", 1).unwrap();
        assert_eq!(state.params.len(), 4); // conv w/b + dense w/b
        let (x, y) = tiny_batch(6, 16, 3);
        let w = [1.0f32; 6];
        let first = ne.train_step(&mut state, &x, &y, &w, 0.3).unwrap();
        let mut last = first.loss;
        for _ in 0..120 {
            last = ne.train_step(&mut state, &x, &y, &w, 0.3).unwrap().loss;
        }
        assert!(last < first.loss * 0.7, "conv loss did not drop: {} -> {last}", first.loss);
    }

    #[test]
    fn weighted_grad_scales_linearly_in_weights() {
        // (1/n) Σ w·loss is linear in w: doubling every weight must double
        // the weighted loss (and, by the same linearity, the gradient).
        let ne = tiny_engine();
        let state = ne.init_state("tiny", 2).unwrap();
        let (x, y) = tiny_batch(4, 6, 3);
        let (_, l1) = ne.weighted_grad(&state, &x, &y, &[1.0; 4]).unwrap();
        let (_, l2) = ne.weighted_grad(&state, &x, &y, &[2.0; 4]).unwrap();
        assert!((l2 - 2.0 * l1).abs() < 1e-5, "{l2} vs 2*{l1}");
    }

    #[test]
    fn fwd_scores_agree_with_train_step_free_outputs() {
        let ne = tiny_engine();
        let mut state = ne.init_state("tiny", 3).unwrap();
        let (x, y) = tiny_batch(8, 6, 3);
        let (loss, scores) = ne.fwd_scores(&state, &x, &y).unwrap();
        let out = ne.train_step(&mut state, &x, &y, &[1.0; 8], 0.05).unwrap();
        // train_step's "free" vectors come from the same pre-update forward
        assert_eq!(out.loss_vec, loss);
        assert_eq!(out.scores, scores);
        // detlint: allow(unordered-float-reduction) — test tolerance 1e-5 absorbs order
        let mean: f32 = loss.iter().sum::<f32>() / 8.0;
        assert!((out.loss - mean).abs() < 1e-5);
    }

    #[test]
    fn eval_metrics_match_fwd_scores_losses() {
        let ne = tiny_engine();
        let state = ne.init_state("tiny", 4).unwrap();
        let (x, y) = tiny_batch(8, 6, 3);
        let (sum_loss, correct) = ne.eval_metrics(&state, &x, &y).unwrap();
        let (loss, _) = ne.fwd_scores(&state, &x, &y).unwrap();
        let total: f64 = loss.iter().map(|&v| v as f64).sum();
        assert!((sum_loss - total).abs() < 1e-6, "{sum_loss} vs {total}");
        assert!((0..=8).contains(&correct));
    }

    #[test]
    fn scorer_matches_backend_scores_bitwise() {
        for ne in [tiny_engine(), conv_engine()] {
            let name = ne.model_names().remove(0);
            let state = ne.init_state(&name, 5).unwrap();
            let scorer = ne.scorer(&state).unwrap();
            let d = ne.layer_model(&name).unwrap().in_dim();
            let (x, y) = tiny_batch(16, d, 3);
            let (loss, ub) = ne.fwd_scores(&state, &x, &y).unwrap();
            assert_eq!(scorer.score_chunk(&x, &y, ScoreKind::Loss).unwrap(), loss);
            assert_eq!(scorer.score_chunk(&x, &y, ScoreKind::UpperBound).unwrap(), ub);
        }
    }

    #[test]
    fn shape_mismatches_are_rejected() {
        let ne = tiny_engine();
        let mut state = ne.init_state("tiny", 6).unwrap();
        let (x, y) = tiny_batch(4, 6, 3);
        let (bad_x, _) = tiny_batch(4, 5, 3);
        assert!(ne.fwd_scores(&state, &bad_x, &y).is_err());
        assert!(ne.train_step(&mut state, &x, &[0, 1], &[1.0; 4], 0.1).is_err());
        assert!(ne.train_step(&mut state, &x, &y, &[1.0; 3], 0.1).is_err());
        let empty = HostTensor::zeros(vec![0, 6]);
        assert!(ne.eval_metrics(&state, &empty, &[]).is_err());
    }

    #[test]
    fn default_models_are_registered() {
        let ne = NativeEngine::with_default_models();
        let names: Vec<String> =
            ["conv10", "mlp10", "mlp100", "seq64"].iter().map(|s| s.to_string()).collect();
        assert_eq!(ne.model_names(), names);
        let info = ne.model_info("mlp10").unwrap();
        assert_eq!(info.feature_dim, 64);
        assert_eq!(info.num_classes, 10);
        assert_eq!(info.batch, 128);
        assert_eq!(info.presample.iter().max(), Some(&1024));
        // the conv and sequence scenarios match the fig3/fig5 datasets
        let conv = ne.model_info("conv10").unwrap();
        assert_eq!((conv.feature_dim, conv.num_classes), (64, 10));
        let seq = ne.model_info("seq64").unwrap();
        assert_eq!((seq.feature_dim, seq.num_classes), (64, 10));
        assert!(seq.presample.contains(&128)); // fig5's B
    }

    #[test]
    fn train_chunk_plan_is_fixed_by_batch_size_alone() {
        for n in [1, 7, 8, 9, 32, 100, 640] {
            let plan = train_chunk_plan(n);
            let total: usize = plan.iter().map(|&(_, len)| len).sum();
            assert_eq!(total, n, "plan must cover all {n} rows");
            let mut next = 0;
            for &(start, len) in &plan {
                assert_eq!(start, next, "chunks must be contiguous and ordered");
                assert!((1..=TRAIN_CHUNK_ROWS).contains(&len), "chunk len {len}");
                next = start + len;
            }
        }
        assert_eq!(train_chunk_plan(1).len(), 1);
        assert_eq!(train_chunk_plan(32).len(), 4);
    }

    #[test]
    fn grad_chunk_plan_is_capped_and_covering() {
        for n in [1, 8, 32, 128, 129, 640, 5000] {
            let plan = grad_chunk_plan(n);
            assert_eq!(plan.iter().map(|&(_, len)| len).sum::<usize>(), n);
            assert!(plan.len() <= MAX_GRAD_CHUNKS, "{n} rows -> {} chunks", plan.len());
            let mut next = 0;
            for &(start, len) in &plan {
                assert_eq!(start, next);
                next = start + len;
            }
        }
        // below the cap the geometry matches the row-wise plan exactly
        assert_eq!(grad_chunk_plan(128), train_chunk_plan(128));
        assert_eq!(grad_chunk_plan(640).len(), MAX_GRAD_CHUNKS);
    }

    #[test]
    fn chunk_plans_are_memoized_per_batch_size() {
        let ne = tiny_engine();
        let a = ne.train_plan(37);
        let b = ne.train_plan(37);
        assert!(Arc::ptr_eq(&a, &b), "repeated plans must come from the cache");
        assert_eq!(*a, train_chunk_plan(37), "cached plan must equal the pure planner");
        let g = ne.grad_plan(640);
        assert_eq!(*g, grad_chunk_plan(640));
        assert!(Arc::ptr_eq(&g, &ne.grad_plan(640)));
        // distinct sizes get distinct plans
        assert_eq!(*ne.train_plan(9), train_chunk_plan(9));
    }

    #[test]
    fn hot_loop_arenas_are_recycled_across_steps() -> anyhow::Result<()> {
        // Serial engine: pool sizes are deterministic. grad_chunk_plan(20)
        // has 3 chunks, so the first step creates exactly 3 partial
        // buffers and 1 arena; every later call must recycle instead of
        // growing the pools.
        let ne = tiny_engine().with_train_workers(1);
        let mut state = ne.init_state("tiny", 1)?;
        let (x, y) = tiny_batch(20, 6, 3);
        let w = [1.0f32; 20];
        for _ in 0..3 {
            ne.train_step(&mut state, &x, &y, &w, 0.1)?;
            ne.fwd_scores(&state, &x, &y)?;
            ne.grad_norms(&state, &x, &y)?;
            ne.eval_metrics(&state, &x, &y)?;
            ne.weighted_grad(&state, &x, &y, &w)?;
        }
        assert_eq!(ne.arenas.idle(), 1, "serial runs cycle one arena");
        assert_eq!(ne.grad_bufs.idle(), 3, "one partial buffer per grad chunk");
        assert_eq!(ne.row_bufs.idle(), 2, "weighted_grad's loss/score scratch");
        let before = (ne.arenas.idle(), ne.grad_bufs.idle(), ne.row_bufs.idle());
        ne.train_step(&mut state, &x, &y, &w, 0.1)?;
        ne.fwd_scores(&state, &x, &y)?;
        assert_eq!(
            (ne.arenas.idle(), ne.grad_bufs.idle(), ne.row_bufs.idle()),
            before,
            "steady state must not allocate new arenas"
        );
        // the bf16 scoring path and the eval_block fast path recycle the
        // same pooled arenas — neither grows any pool in steady state
        ne.set_score_precision(ScorePrecision::Bf16);
        ne.fwd_scores(&state, &x, &y)?;
        ne.eval_metrics(&state, &x, &y)?;
        ne.set_score_precision(ScorePrecision::F32);
        assert_eq!(
            (ne.arenas.idle(), ne.grad_bufs.idle(), ne.row_bufs.idle()),
            before,
            "bf16 scoring and eval must recycle the pooled arenas too"
        );
        Ok(())
    }

    #[test]
    fn bf16_score_precision_switches_only_fwd_scores() -> anyhow::Result<()> {
        let ne = tiny_engine();
        let state = ne.init_state("tiny", 7)?;
        let (x, y) = tiny_batch(40, 6, 3);
        let (l32, s32) = ne.fwd_scores(&state, &x, &y)?;
        let eval32 = ne.eval_metrics(&state, &x, &y)?;
        let gn32 = ne.grad_norms(&state, &x, &y)?;

        ne.set_score_precision(ScorePrecision::Bf16);
        assert_eq!(ne.score_precision(), ScorePrecision::Bf16);
        let (lb, sb) = ne.fwd_scores(&state, &x, &y)?;
        // close to the f32 walk (storage rounding only perturbs weights)
        for (a, b) in lb.iter().zip(&l32).chain(sb.iter().zip(&s32)) {
            assert!(a.is_finite() && (a - b).abs() <= 0.15 * b.abs() + 0.02, "{a} vs {b}");
        }
        // deterministic: a second bf16 pass is bit-identical
        assert_eq!(ne.fwd_scores(&state, &x, &y)?, (lb, sb));
        // eval and the gradient-norm oracle ignore the flag entirely
        assert_eq!(ne.eval_metrics(&state, &x, &y)?, eval32);
        assert_eq!(ne.grad_norms(&state, &x, &y)?, gn32);

        // switching back restores the f32 walk bit-for-bit
        ne.set_score_precision(ScorePrecision::F32);
        assert_eq!(ne.fwd_scores(&state, &x, &y)?, (l32, s32));
        // builder form + default
        assert_eq!(tiny_engine().score_precision(), ScorePrecision::F32);
        let nb = tiny_engine().with_score_precision(ScorePrecision::Bf16);
        assert_eq!(nb.score_precision(), ScorePrecision::Bf16);
        Ok(())
    }

    #[test]
    fn train_workers_setter_clamps_and_rebuilds() {
        let ne = tiny_engine();
        assert!(ne.train_workers() >= 1);
        ne.set_train_workers(3);
        assert_eq!(ne.train_workers(), 3);
        ne.set_train_workers(0);
        assert_eq!(ne.train_workers(), 1);
        let ne2 = tiny_engine().with_train_workers(5);
        assert_eq!(ne2.train_workers(), 5);
        assert_eq!(Backend::train_workers(&ne2), 5);
    }

    #[test]
    fn parallel_entries_are_bit_identical_to_serial() {
        // Every batch-level entry, serial vs pooled, on a batch large
        // enough for several chunks (37 rows -> 5 chunks) — the quick
        // in-module version of the rust/tests/props.rs properties, run on
        // an MLP and on a conv stack (the chunk plans and merges are
        // architecture-independent).
        let specs: [fn() -> NativeEngine; 2] = [tiny_engine, conv_engine];
        for (mk, d) in specs.iter().zip([6usize, 16]) {
            let run = |workers: usize| {
                let ne = mk().with_train_workers(workers);
                let name = ne.model_names().remove(0);
                let mut state = ne.init_state(&name, 12).unwrap();
                let (x, y) = tiny_batch(37, d, 3);
                let w: Vec<f32> = (0..37).map(|i| 0.25 + (i % 5) as f32 * 0.5).collect();
                let (grads, wloss) = ne.weighted_grad(&state, &x, &y, &w).unwrap();
                let gh: Vec<Vec<f32>> =
                    grads.iter().map(|g| literal_to_f32_vec(g).unwrap()).collect();
                let gn = ne.grad_norms(&state, &x, &y).unwrap();
                let (el, ec) = ne.eval_metrics(&state, &x, &y).unwrap();
                let out = ne.train_step(&mut state, &x, &y, &w, 0.1).unwrap();
                let params = state.params_to_host().unwrap();
                (gh, wloss.to_bits(), gn, el.to_bits(), ec, out.loss.to_bits(), params)
            };
            let serial = run(1);
            for workers in [2, 3, 8] {
                assert_eq!(run(workers), serial, "{workers} workers diverged from serial");
            }
        }
    }

    #[test]
    fn grad_norms_are_finite_and_track_scores() {
        for ne in [tiny_engine(), conv_engine()] {
            let name = ne.model_names().remove(0);
            let d = ne.layer_model(&name).unwrap().in_dim();
            let state = ne.init_state(&name, 9).unwrap();
            let (x, y) = tiny_batch(32, d, 3);
            let gn = ne.grad_norms(&state, &x, &y).unwrap();
            let (_, ub) = ne.fwd_scores(&state, &x, &y).unwrap();
            assert_eq!(gn.len(), 32);
            assert!(gn.iter().all(|v| v.is_finite() && *v >= 0.0));
            // the head's bias gradient alone is the Eq.-20 score, so the
            // true norm dominates the upper-bound factor for every stack
            for (g, u) in gn.iter().zip(&ub) {
                assert!(*g >= *u - 1e-5, "grad norm {g} < upper-bound factor {u}");
            }
        }
    }
}

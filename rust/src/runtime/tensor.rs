//! Host-side tensors and conversions to/from `xla::Literal`.
//!
//! Everything the coordinator touches is f32 (features, params, scores) or
//! i32 (labels); this module keeps the conversion noise in one place.

use anyhow::{bail, Context, Result};
use xla::Literal;

/// A dense row-major f32 tensor on the host.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn elements(&self) -> usize {
        self.data.len()
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.shape.len(), 2);
        let d = self.shape[1];
        &self.data[i * d..(i + 1) * d]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        assert_eq!(self.shape.len(), 2);
        let d = self.shape[1];
        &mut self.data[i * d..(i + 1) * d]
    }

    pub fn to_literal(&self) -> Result<Literal> {
        f32_literal(&self.shape, &self.data)
    }

    pub fn from_literal(lit: &Literal) -> Result<Self> {
        let shape = lit.array_shape().context("literal has no array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>().context("literal is not f32")?;
        Ok(Self::new(dims, data))
    }
}

/// Build a shaped f32 literal from borrowed shape + data — the one
/// literal constructor [`HostTensor::to_literal`] and the native engine's
/// pooled-buffer conversions share, so the logic cannot drift.
pub fn f32_literal(shape: &[usize], data: &[f32]) -> Result<Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Literal::vec1(data).reshape(&dims).context("reshaping f32 literal")
}

/// Build a `f32[b]` literal (importance weights, per-sample vectors).
pub fn f32_vec_literal(v: &[f32]) -> Literal {
    Literal::vec1(v)
}

/// Build an `s32[b]` literal (labels).
pub fn i32_vec_literal(v: &[i32]) -> Literal {
    Literal::vec1(v)
}

/// Build an `f32[]` scalar literal (learning rate).
pub fn f32_scalar_literal(x: f32) -> Literal {
    Literal::scalar(x)
}

/// Read back a `f32[n]` literal.
pub fn literal_to_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("expected f32 literal")
}

/// Read back a scalar f32 (accepts rank-0 or single-element).
pub fn literal_to_f32_scalar(lit: &Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>().context("expected f32 literal")?;
    if v.is_empty() {
        bail!("empty literal where scalar expected");
    }
    Ok(v[0])
}

/// Read back a scalar i32.
pub fn literal_to_i32_scalar(lit: &Literal) -> Result<i32> {
    let v = lit.to_vec::<i32>().context("expected i32 literal")?;
    if v.is_empty() {
        bail!("empty literal where scalar expected");
    }
    Ok(v[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_2d() {
        let t = HostTensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
        assert_eq!(t.row(1), &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn scalar_roundtrip() {
        let lit = f32_scalar_literal(0.125);
        assert_eq!(literal_to_f32_scalar(&lit).unwrap(), 0.125);
    }

    #[test]
    fn i32_vec() {
        let lit = i32_vec_literal(&[3, 1, 4, 1, 5]);
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![3, 1, 4, 1, 5]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn shape_mismatch_panics() {
        HostTensor::new(vec![2, 2], vec![1.0]);
    }
}

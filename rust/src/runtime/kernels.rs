//! Cache-blocked, fixed-lane-accumulator compute microkernels — the hot
//! arithmetic of the native backend's block-batched forward/backward
//! passes.
//!
//! The scalar layer walk of [`super::layers`] re-streams every weight
//! matrix from memory once **per sample** and re-loads/stores its output
//! accumulators once per input feature. These kernels operate on a whole
//! block of rows at once so
//!
//! * weight traffic is amortized across the block (each weight row is
//!   loaded once and applied to every row lane), and
//! * accumulator tiles live in registers across the whole reduction (the
//!   fixed `MR × NR` lane grid), with unit-stride inner loops the
//!   autovectorizer can turn into SIMD.
//!
//! # Determinism contract (bit-identity with the scalar walk)
//!
//! Every kernel here preserves, **per output element**, the exact sequence
//! of f32 operations the scalar reference walk performs:
//!
//! * lanes are only ever spread across *independent* output elements
//!   (row × output-unit pairs), never across a reduction dimension;
//! * every reduction (over input features, over block rows, over
//!   convolution taps) runs strictly sequentially, in the same index order
//!   as the scalar walk, with one rounding per multiply and per add —
//!   no lane-split partial sums, no FMA contraction, no reassociation;
//! * tiles that accumulate into memory (`gemm_at_b_acc`, [`bias_acc`])
//!   load the current value, extend the very same accumulation chain in
//!   registers, and store it back — an exact f32 round trip — so splitting
//!   a batch into blocks of *any* size leaves every element's chain
//!   unchanged.
//!
//! The one intentional deviation: the scalar backward walks skip
//! multiply-accumulates whose input activation is exactly zero
//! (`if xv != 0.0`). The kernels include those terms. For finite data this
//! is bitwise invisible: the product is `±0.0`, and adding `±0.0` to an
//! accumulator that is not `-0.0` returns the accumulator unchanged —
//! and gradient accumulators can never become `-0.0` (they start at `+0.0`
//! and under round-to-nearest a sum only yields `-0.0` when both addends
//! are `-0.0`). `rust/tests/props.rs` pins the resulting block == scalar
//! bit-identity across random shapes, block splits and architectures.
//!
//! Consequently the block-batched passes are bit-identical to the
//! per-row scalar walk — numerics are a pure function of the model dims
//! and the row values, never of the internal block size, the chunk plan
//! or the worker count. The PR 3/4 parallel==serial guarantees and the
//! golden trajectories carry over unchanged.

/// Row lanes per microkernel tile (how many batch rows one register tile
/// covers). 4 row lanes × [`NR`] output lanes = 32 f32 accumulators — a
/// full register tile on SSE2, still comfortable on AVX.
pub const MR: usize = 4;

/// Output-unit lanes per microkernel tile (unit-stride, SIMD-friendly).
pub const NR: usize = 8;

/// Row count per internal sub-block of a batch-level pass. Bounds the
/// activation-arena footprint; has **no** effect on numerics (see the
/// module-level determinism contract).
pub const MAX_BLOCK_ROWS: usize = 32;

/// `c[r, o] += Σ_i a[r, i] · w[i, o]` for a `rows × k` row-major `a`, a
/// `k × n` row-major `w` and a `rows × n` row-major `c` (which the caller
/// pre-initializes — bias rows for a forward pass, zeros for a fresh
/// accumulation). Per element the reduction is `i`-ascending, extending
/// whatever value `c` already holds — exactly the scalar forward walk.
pub fn gemm_acc(a: &[f32], rows: usize, k: usize, w: &[f32], n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), rows * k, "gemm_acc: a shape");
    assert_eq!(w.len(), k * n, "gemm_acc: w shape");
    assert_eq!(c.len(), rows * n, "gemm_acc: c shape");
    let mut r0 = 0;
    while r0 < rows {
        let mr = (rows - r0).min(MR);
        let mut o0 = 0;
        while o0 < n {
            let nr = (n - o0).min(NR);
            if mr == MR && nr == NR {
                gemm_tile(a, r0, k, w, o0, n, c);
            } else {
                gemm_edge(a, r0, mr, k, w, o0, nr, n, c);
            }
            o0 += nr;
        }
        r0 += mr;
    }
}

/// The full `MR × NR` register tile of [`gemm_acc`].
#[inline]
fn gemm_tile(a: &[f32], r0: usize, k: usize, w: &[f32], o0: usize, n: usize, c: &mut [f32]) {
    let mut acc = [[0.0f32; NR]; MR];
    for (r, accr) in acc.iter_mut().enumerate() {
        accr.copy_from_slice(&c[(r0 + r) * n + o0..][..NR]);
    }
    let a0 = &a[r0 * k..][..k];
    let a1 = &a[(r0 + 1) * k..][..k];
    let a2 = &a[(r0 + 2) * k..][..k];
    let a3 = &a[(r0 + 3) * k..][..k];
    for (i, wrow) in w.chunks_exact(n).enumerate() {
        let wt = &wrow[o0..o0 + NR];
        let xs = [a0[i], a1[i], a2[i], a3[i]];
        for (accr, &xv) in acc.iter_mut().zip(&xs) {
            for (av, &wv) in accr.iter_mut().zip(wt) {
                *av += xv * wv;
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        c[(r0 + r) * n + o0..][..NR].copy_from_slice(accr);
    }
}

/// Partial-tile edge of [`gemm_acc`]: one row lane at a time with up to
/// [`NR`] output lanes in registers. The reduction stays `i`-outermost
/// with unit-stride `w` row reads — the rows = 1 case IS the gradient-norm
/// oracle's whole forward, so the edge path must stream `w` exactly like
/// the full tile (never walk its columns), and per element the chain is
/// still `i`-ascending.
#[allow(clippy::too_many_arguments)]
fn gemm_edge(
    a: &[f32],
    r0: usize,
    mr: usize,
    k: usize,
    w: &[f32],
    o0: usize,
    nr: usize,
    n: usize,
    c: &mut [f32],
) {
    let mut acc = [0.0f32; NR];
    for r in r0..r0 + mr {
        let arow = &a[r * k..][..k];
        let accs = &mut acc[..nr];
        accs.copy_from_slice(&c[r * n + o0..][..nr]);
        for (i, &xv) in arow.iter().enumerate() {
            let wrow = &w[i * n + o0..][..nr];
            for (av, &wv) in accs.iter_mut().zip(wrow) {
                *av += xv * wv;
            }
        }
        c[r * n + o0..][..nr].copy_from_slice(accs);
    }
}

/// `gw[i, o] += Σ_r x[r, i] · g[r, o]` — the weight-gradient outer-product
/// accumulation over a block of rows (`x` is `rows × k`, `g` is `rows × n`,
/// `gw` is `k × n`). Per element the reduction is `r`-ascending and extends
/// the value already in `gw`, so accumulating block after block reproduces
/// the scalar row-by-row backward walk bit for bit.
pub fn gemm_at_b_acc(x: &[f32], g: &[f32], rows: usize, k: usize, n: usize, gw: &mut [f32]) {
    assert_eq!(x.len(), rows * k, "gemm_at_b_acc: x shape");
    assert_eq!(g.len(), rows * n, "gemm_at_b_acc: g shape");
    assert_eq!(gw.len(), k * n, "gemm_at_b_acc: gw shape");
    let mut i0 = 0;
    while i0 < k {
        let mi = (k - i0).min(MR);
        let mut o0 = 0;
        while o0 < n {
            let no = (n - o0).min(NR);
            if mi == MR && no == NR {
                at_b_tile(x, g, rows, k, n, i0, o0, gw);
            } else {
                at_b_edge(x, g, rows, k, n, i0, mi, o0, no, gw);
            }
            o0 += no;
        }
        i0 += mi;
    }
}

/// The full `MR × NR` register tile of [`gemm_at_b_acc`].
#[allow(clippy::too_many_arguments)]
#[inline]
fn at_b_tile(
    x: &[f32],
    g: &[f32],
    rows: usize,
    k: usize,
    n: usize,
    i0: usize,
    o0: usize,
    gw: &mut [f32],
) {
    let mut acc = [[0.0f32; NR]; MR];
    for (ii, accr) in acc.iter_mut().enumerate() {
        accr.copy_from_slice(&gw[(i0 + ii) * n + o0..][..NR]);
    }
    for r in 0..rows {
        let xt = &x[r * k + i0..][..MR];
        let gt = &g[r * n + o0..][..NR];
        for (accr, &xv) in acc.iter_mut().zip(xt) {
            for (av, &gv) in accr.iter_mut().zip(gt) {
                *av += xv * gv;
            }
        }
    }
    for (ii, accr) in acc.iter().enumerate() {
        gw[(i0 + ii) * n + o0..][..NR].copy_from_slice(accr);
    }
}

/// Partial-tile edge of [`gemm_at_b_acc`], per element, `r`-ascending.
#[allow(clippy::too_many_arguments)]
fn at_b_edge(
    x: &[f32],
    g: &[f32],
    rows: usize,
    k: usize,
    n: usize,
    i0: usize,
    mi: usize,
    o0: usize,
    no: usize,
    gw: &mut [f32],
) {
    for ii in i0..i0 + mi {
        let grow = &mut gw[ii * n + o0..][..no];
        for (j, gv) in grow.iter_mut().enumerate() {
            let mut acc = *gv;
            for r in 0..rows {
                acc += x[r * k + ii] * g[r * n + o0 + j];
            }
            *gv = acc;
        }
    }
}

/// `gin[r, i] = Σ_o w[i, o] · g[r, o]` — the dense input gradient
/// (`g · Wᵀ`) for a block of rows, **assigned** (not accumulated). Per
/// element the reduction is `o`-ascending from `0.0` — exactly the scalar
/// `dense_input_grad` dot product — with the `w` row streamed once per
/// [`MR`] row lanes instead of once per row.
pub fn gemm_b_wt(g: &[f32], w: &[f32], rows: usize, k: usize, n: usize, gin: &mut [f32]) {
    assert_eq!(g.len(), rows * n, "gemm_b_wt: g shape");
    assert_eq!(w.len(), k * n, "gemm_b_wt: w shape");
    assert_eq!(gin.len(), rows * k, "gemm_b_wt: gin shape");
    let mut r0 = 0;
    while r0 < rows {
        let mr = (rows - r0).min(MR);
        if mr == MR {
            let g0 = &g[r0 * n..][..n];
            let g1 = &g[(r0 + 1) * n..][..n];
            let g2 = &g[(r0 + 2) * n..][..n];
            let g3 = &g[(r0 + 3) * n..][..n];
            for (i, wrow) in w.chunks_exact(n).enumerate() {
                let mut acc = [0.0f32; MR];
                for (o, &wv) in wrow.iter().enumerate() {
                    acc[0] += wv * g0[o];
                    acc[1] += wv * g1[o];
                    acc[2] += wv * g2[o];
                    acc[3] += wv * g3[o];
                }
                for (r, &av) in acc.iter().enumerate() {
                    gin[(r0 + r) * k + i] = av;
                }
            }
        } else {
            for r in r0..r0 + mr {
                let grow = &g[r * n..][..n];
                let ginr = &mut gin[r * k..][..k];
                for (i, gi) in ginr.iter_mut().enumerate() {
                    let wrow = &w[i * n..][..n];
                    *gi = wrow.iter().zip(grow).map(|(&wv, &gv)| wv * gv).sum();
                }
            }
        }
        r0 += mr;
    }
}

/// Copy the bias vector into every row of a `rows × b.len()` block — the
/// pre-initialization [`gemm_acc`] extends.
pub fn bias_init(b: &[f32], rows: usize, out: &mut [f32]) {
    assert_eq!(out.len(), rows * b.len(), "bias_init: out shape");
    for orow in out.chunks_exact_mut(b.len()) {
        orow.copy_from_slice(b);
    }
}

/// `gb[o] += Σ_r g[r, o]` — the bias gradient over a block of rows,
/// `r`-ascending per element, extending the value already in `gb`.
pub fn bias_acc(g: &[f32], rows: usize, n: usize, gb: &mut [f32]) {
    assert_eq!(g.len(), rows * n, "bias_acc: g shape");
    assert_eq!(gb.len(), n, "bias_acc: gb shape");
    for grow in g.chunks_exact(n) {
        for (b, &gv) in gb.iter_mut().zip(grow) {
            *b += gv;
        }
    }
}

/// Valid-1D-convolution patch extraction: for every row and output time
/// step, copy the `kernel × in_ch` input window into
/// `patch[(r·t_out + t), (k·in_ch + c)]`. Because the input layout is
/// `[time, ch]`, each window is **contiguous** — im2col is a strided
/// memcpy — and the patch matrix turns the convolution into the dense
/// [`gemm_acc`] / [`gemm_at_b_acc`] kernels with `k·in_ch` inputs, in the
/// exact `(k, c)`-ascending tap order of the scalar conv walk.
#[allow(clippy::too_many_arguments)]
pub fn im2col(
    input: &[f32],
    rows: usize,
    in_dim: usize,
    in_ch: usize,
    kernel: usize,
    stride: usize,
    t_out: usize,
    patch: &mut Vec<f32>,
) {
    assert_eq!(input.len(), rows * in_dim, "im2col: input shape");
    let kc = kernel * in_ch;
    // every element is overwritten below, so only fix the length (no
    // zero-fill pass over the hot path's largest scratch matrix)
    let want = rows * t_out * kc;
    if patch.len() != want {
        patch.clear();
        patch.resize(want, 0.0);
    }
    for (r, xrow) in input.chunks_exact(in_dim).enumerate() {
        for t in 0..t_out {
            let dst = &mut patch[(r * t_out + t) * kc..][..kc];
            dst.copy_from_slice(&xrow[t * stride * in_ch..][..kc]);
        }
    }
}

/// Scatter patch-space gradients back to input space:
/// `gin[r, (t·stride + k)·in_ch + c] += gpatch[(r·t_out + t), k·in_ch + c]`.
/// `gin` must be pre-zeroed. Per input element contributions arrive in
/// `t`-ascending window order — the scalar conv `input_grad` order.
#[allow(clippy::too_many_arguments)]
pub fn col2im_acc(
    gpatch: &[f32],
    rows: usize,
    in_dim: usize,
    in_ch: usize,
    kernel: usize,
    stride: usize,
    t_out: usize,
    gin: &mut [f32],
) {
    assert_eq!(gin.len(), rows * in_dim, "col2im_acc: gin shape");
    let kc = kernel * in_ch;
    assert_eq!(gpatch.len(), rows * t_out * kc, "col2im_acc: gpatch shape");
    for (r, grow) in gin.chunks_exact_mut(in_dim).enumerate() {
        for t in 0..t_out {
            let src = &gpatch[(r * t_out + t) * kc..][..kc];
            let dst = &mut grow[t * stride * in_ch..][..kc];
            for (d, &s) in dst.iter_mut().zip(src) {
                *d += s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic pseudo-random fill (no external RNG needed here).
    fn fill(v: &mut [f32], salt: usize) {
        for (i, x) in v.iter_mut().enumerate() {
            *x = (((i * 31 + salt * 17 + 7) % 113) as f32 / 113.0 - 0.5) * 1.7;
        }
    }

    /// Shapes crossing every tile edge: exact tiles, sub-tile remainders,
    /// single rows/cols.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (1, 64, 10),
        (3, 5, 7),
        (4, 8, 8),
        (5, 9, 17),
        (8, 64, 128),
        (13, 24, 10),
    ];

    #[test]
    fn gemm_acc_matches_scalar_reference_bitwise() {
        for &(rows, k, n) in SHAPES {
            let mut a = vec![0.0f32; rows * k];
            let mut w = vec![0.0f32; k * n];
            let mut c0 = vec![0.0f32; rows * n];
            fill(&mut a, 1);
            fill(&mut w, 2);
            fill(&mut c0, 3); // arbitrary pre-init (bias-like)
            let mut c = c0.clone();
            gemm_acc(&a, rows, k, &w, n, &mut c);
            // scalar reference: the layers.rs dense forward walk
            let mut r0 = c0.clone();
            for r in 0..rows {
                for (i, &xv) in a[r * k..][..k].iter().enumerate() {
                    for o in 0..n {
                        r0[r * n + o] += xv * w[i * n + o];
                    }
                }
            }
            assert_eq!(c, r0, "gemm_acc {rows}x{k}x{n}");
        }
    }

    #[test]
    fn gemm_at_b_acc_matches_scalar_reference_bitwise_and_chains_across_blocks() {
        for &(rows, k, n) in SHAPES {
            let mut x = vec![0.0f32; rows * k];
            let mut g = vec![0.0f32; rows * n];
            let mut gw0 = vec![0.0f32; k * n];
            fill(&mut x, 4);
            fill(&mut g, 5);
            fill(&mut gw0, 6); // pre-existing partial gradient
            let mut gw = gw0.clone();
            gemm_at_b_acc(&x, &g, rows, k, n, &mut gw);
            // scalar reference: row-by-row outer products, r-ascending
            let mut r0 = gw0.clone();
            for r in 0..rows {
                for i in 0..k {
                    let xv = x[r * k + i];
                    if xv != 0.0 {
                        for o in 0..n {
                            r0[i * n + o] += xv * g[r * n + o];
                        }
                    }
                }
            }
            assert_eq!(gw, r0, "gemm_at_b_acc {rows}x{k}x{n}");
            // splitting the rows into two blocks must not change a bit
            if rows > 1 {
                let half = rows / 2;
                let mut gw2 = gw0.clone();
                gemm_at_b_acc(&x[..half * k], &g[..half * n], half, k, n, &mut gw2);
                gemm_at_b_acc(&x[half * k..], &g[half * n..], rows - half, k, n, &mut gw2);
                assert_eq!(gw2, gw, "block split changed bits {rows}x{k}x{n}");
            }
        }
    }

    #[test]
    fn gemm_b_wt_matches_scalar_dot_bitwise() {
        for &(rows, k, n) in SHAPES {
            let mut g = vec![0.0f32; rows * n];
            let mut w = vec![0.0f32; k * n];
            fill(&mut g, 7);
            fill(&mut w, 8);
            let mut gin = vec![f32::NAN; rows * k]; // assignment must cover all
            gemm_b_wt(&g, &w, rows, k, n, &mut gin);
            for r in 0..rows {
                for i in 0..k {
                    let want: f32 = w[i * n..][..n]
                        .iter()
                        .zip(&g[r * n..][..n])
                        .map(|(&wv, &gv)| wv * gv)
                        .sum();
                    assert_eq!(gin[r * k + i], want, "gemm_b_wt {rows}x{k}x{n} r{r} i{i}");
                }
            }
        }
    }

    #[test]
    fn bias_kernels_match_reference() {
        let b = [0.5f32, -1.25, 2.0];
        let mut out = vec![0.0f32; 12];
        bias_init(&b, 4, &mut out);
        assert!(out.chunks_exact(3).all(|r| r == b.as_slice()));

        let mut g = vec![0.0f32; 12];
        fill(&mut g, 9);
        let mut gb = vec![0.25f32; 3];
        let mut want = gb.clone();
        for r in 0..4 {
            for o in 0..3 {
                want[o] += g[r * 3 + o];
            }
        }
        bias_acc(&g, 4, 3, &mut gb);
        assert_eq!(gb, want);
    }

    #[test]
    fn im2col_and_col2im_round_trip_the_conv_geometry() {
        // rows=2, t_in=7, ic=2, kernel=3, stride=2 -> t_out=3
        let (rows, t_in, ic, kernel, stride) = (2usize, 7usize, 2usize, 3usize, 2usize);
        let t_out = (t_in - kernel) / stride + 1;
        let in_dim = t_in * ic;
        let mut input = vec![0.0f32; rows * in_dim];
        fill(&mut input, 10);
        let mut patch = Vec::new();
        im2col(&input, rows, in_dim, ic, kernel, stride, t_out, &mut patch);
        assert_eq!(patch.len(), rows * t_out * kernel * ic);
        for r in 0..rows {
            for t in 0..t_out {
                for k in 0..kernel {
                    for c in 0..ic {
                        let got = patch[(r * t_out + t) * kernel * ic + k * ic + c];
                        let want = input[r * in_dim + (t * stride + k) * ic + c];
                        assert_eq!(got, want, "r{r} t{t} k{k} c{c}");
                    }
                }
            }
        }
        // col2im of an all-ones patch counts each input position's window
        // multiplicity
        let gpatch = vec![1.0f32; patch.len()];
        let mut gin = vec![0.0f32; rows * in_dim];
        col2im_acc(&gpatch, rows, in_dim, ic, kernel, stride, t_out, &mut gin);
        for r in 0..rows {
            for p in 0..t_in {
                let count = (0..t_out)
                    .filter(|&t| p >= t * stride && p < t * stride + kernel)
                    .count() as f32;
                for c in 0..ic {
                    assert_eq!(gin[r * in_dim + p * ic + c], count, "r{r} pos{p} ch{c}");
                }
            }
        }
    }

    #[test]
    fn lane_constants_are_sane() {
        assert!(MR >= 1 && NR >= 1);
        assert!(MAX_BLOCK_ROWS >= MR);
    }
}

//! Cache-blocked, fixed-lane-accumulator compute microkernels — the hot
//! arithmetic of the native backend's block-batched forward/backward
//! passes.
//!
//! The scalar layer walk of [`super::layers`] re-streams every weight
//! matrix from memory once **per sample** and re-loads/stores its output
//! accumulators once per input feature. These kernels operate on a whole
//! block of rows at once so
//!
//! * weight traffic is amortized across the block (each weight row is
//!   loaded once and applied to every row lane), and
//! * accumulator tiles live in registers across the whole reduction (the
//!   fixed `MR × NR` lane grid), with unit-stride inner loops.
//!
//! # Determinism contract (bit-identity with the scalar walk)
//!
//! Every kernel here preserves, **per output element**, the exact sequence
//! of f32 operations the scalar reference walk performs:
//!
//! * lanes are only ever spread across *independent* output elements
//!   (row × output-unit pairs), never across a reduction dimension;
//! * every reduction (over input features, over block rows, over
//!   convolution taps) runs strictly sequentially, in the same index order
//!   as the scalar walk, with one rounding per multiply and per add —
//!   no lane-split partial sums, no FMA contraction, no reassociation;
//! * tiles that accumulate into memory (`gemm_at_b_acc`, [`bias_acc`])
//!   load the current value, extend the very same accumulation chain in
//!   registers, and store it back — an exact f32 round trip — so splitting
//!   a batch into blocks of *any* size leaves every element's chain
//!   unchanged.
//!
//! The one intentional deviation: the scalar backward walks skip
//! multiply-accumulates whose input activation is exactly zero
//! (`if xv != 0.0`). The kernels include those terms. For finite data this
//! is bitwise invisible: the product is `±0.0`, and adding `±0.0` to an
//! accumulator that is not `-0.0` returns the accumulator unchanged —
//! and gradient accumulators can never become `-0.0` (they start at `+0.0`
//! and under round-to-nearest a sum only yields `-0.0` when both addends
//! are `-0.0`). `rust/tests/props.rs` pins the resulting block == scalar
//! bit-identity across random shapes, block splits and architectures.
//!
//! # SIMD dispatch
//!
//! The full `MR × NR` register tiles exist twice: as plain scalar loops
//! (the executable spec, and the fallback on every target) and as explicit
//! SSE2 implementations (`mod simd`, x86_64 only) that widen the *output*
//! lanes four at a time instead of waiting on the autovectorizer.
//! Dispatch is runtime, not compile-time: [`active_path`] resolves to
//! [`KernelPath::Simd`] when the host supports it, can be pinned
//! process-wide with the `ISAMPLE_FORCE_SCALAR` environment variable
//! (read once, the CI scalar-fallback leg), and can be overridden
//! in-process via [`set_forced_kernel_path`] (tests and benches). Every
//! dispatched kernel also has a `*_on(path, ..)` variant that selects a
//! path explicitly, ignoring the override.
//!
//! The SIMD tiles obey the exact same contract as the scalar tiles: SSE2
//! has no FMA contraction — `_mm_mul_ps`/`_mm_add_ps` perform one
//! IEEE-754 rounding per lane per op, just like the scalar `*`/`+` — and
//! lanes span only independent output elements while every reduction
//! stays sequential in the reference index order. Both paths are
//! therefore **bit-identical** and the dispatch choice is unobservable
//! (pinned by the in-module tests and `rust/tests/props.rs`); no goldens
//! move when the default flips. Edge tiles, [`bias_init`], [`im2col`] and
//! [`col2im_acc`] stay scalar: the latter three are pure data movement
//! (`copy_from_slice` lowers to memcpy — already optimal), and partial
//! tiles are cold by construction.
//!
//! # bf16 storage kernels
//!
//! [`gemm_acc_bf16`] / [`bias_init_bf16`] take the *parameters* in bf16
//! storage (`u16` bit patterns, [`crate::util::bf16`]), widen each value
//! to f32 on the fly (an exact `<< 16` bit extension — no rounding) and
//! accumulate in f32 with the same per-element chains as the f32 kernels.
//! They halve parameter memory traffic for the presample scoring fast
//! path. Results are NOT bit-comparable to the f32 kernels (the storage
//! narrowing rounds every weight once), but the Scalar and Simd paths of
//! the bf16 kernels are bit-identical to each other: the SSE2 widening is
//! the same `<< 16` the scalar helper performs.

use crate::util::bf16::bf16_to_f32;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Row lanes per microkernel tile (how many batch rows one register tile
/// covers). 4 row lanes × [`NR`] output lanes = 32 f32 accumulators — a
/// full register tile on SSE2, still comfortable on AVX.
pub const MR: usize = 4;

/// Output-unit lanes per microkernel tile (unit-stride, two 4-wide SSE2
/// vectors).
pub const NR: usize = 8;

/// Row count per internal sub-block of a batch-level pass. Bounds the
/// activation-arena footprint; has **no** effect on numerics (see the
/// module-level determinism contract).
pub const MAX_BLOCK_ROWS: usize = 32;

/// Which implementation of the full register tiles runs. Both paths are
/// bit-identical (see the module docs); the choice is purely about speed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelPath {
    /// Plain scalar loops — the executable spec, available everywhere.
    Scalar,
    /// Explicit SSE2 tiles on x86_64. On other targets this path is a
    /// *request* and resolves to the scalar tiles.
    Simd,
}

impl KernelPath {
    /// Stable name for logs and bench metric labels.
    pub fn name(self) -> &'static str {
        match self {
            KernelPath::Scalar => "scalar",
            KernelPath::Simd => "simd",
        }
    }
}

/// Both dispatchable paths, for tests and benches that sweep them.
pub const KERNEL_PATHS: [KernelPath; 2] = [KernelPath::Scalar, KernelPath::Simd];

/// In-process dispatch override: 0 = none, 1 = scalar, 2 = simd.
static FORCED_PATH: AtomicU8 = AtomicU8::new(0);

/// Force every dispatched kernel ([`gemm_acc`] & co — NOT the explicit
/// `*_on` variants) onto one path, or `None` to restore the default.
/// Process-global; safe to flip at any time because both paths are
/// bit-identical — a racing reader merely runs the other (equal) tiles.
pub fn set_forced_kernel_path(path: Option<KernelPath>) {
    let v = match path {
        None => 0,
        Some(KernelPath::Scalar) => 1,
        Some(KernelPath::Simd) => 2,
    };
    FORCED_PATH.store(v, Ordering::SeqCst);
}

/// True when the host can actually run the explicit SIMD tiles.
pub fn simd_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        // SSE2 is part of the x86_64 baseline ABI, so this is always
        // true in practice; the runtime check keeps the dispatch honest
        // and the pattern ready for wider tiles.
        is_x86_feature_detected!("sse2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

fn default_path() -> KernelPath {
    static DEFAULT: OnceLock<KernelPath> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        let forced_scalar =
            std::env::var_os("ISAMPLE_FORCE_SCALAR").is_some_and(|v| !v.is_empty() && v != "0");
        if !forced_scalar && simd_available() {
            KernelPath::Simd
        } else {
            KernelPath::Scalar
        }
    })
}

/// The path the argument-less kernels dispatch to right now: the
/// [`set_forced_kernel_path`] override if set, else the cached default
/// (`ISAMPLE_FORCE_SCALAR` environment flag, read once, then hardware
/// feature detection).
pub fn active_path() -> KernelPath {
    match FORCED_PATH.load(Ordering::Relaxed) {
        1 => KernelPath::Scalar,
        2 => KernelPath::Simd,
        _ => default_path(),
    }
}

#[inline]
fn take_simd(path: KernelPath) -> bool {
    path == KernelPath::Simd && simd_available()
}

/// `c[r, o] += Σ_i a[r, i] · w[i, o]` for a `rows × k` row-major `a`, a
/// `k × n` row-major `w` and a `rows × n` row-major `c` (which the caller
/// pre-initializes — bias rows for a forward pass, zeros for a fresh
/// accumulation). Per element the reduction is `i`-ascending, extending
/// whatever value `c` already holds — exactly the scalar forward walk.
/// Runs the [`active_path`] tiles; see [`gemm_acc_on`].
pub fn gemm_acc(a: &[f32], rows: usize, k: usize, w: &[f32], n: usize, c: &mut [f32]) {
    gemm_acc_on(active_path(), a, rows, k, w, n, c);
}

/// [`gemm_acc`] with explicit tile selection (ignores the dispatch
/// override — tests and benches use this to pin a path).
pub fn gemm_acc_on(
    path: KernelPath,
    a: &[f32],
    rows: usize,
    k: usize,
    w: &[f32],
    n: usize,
    c: &mut [f32],
) {
    assert_eq!(a.len(), rows * k, "gemm_acc: a shape");
    assert_eq!(w.len(), k * n, "gemm_acc: w shape");
    assert_eq!(c.len(), rows * n, "gemm_acc: c shape");
    let simd = take_simd(path);
    let mut r0 = 0;
    while r0 < rows {
        let mr = (rows - r0).min(MR);
        let mut o0 = 0;
        while o0 < n {
            let nr = (n - o0).min(NR);
            if mr == MR && nr == NR {
                if simd {
                    simd::gemm_tile(a, r0, k, w, o0, n, c);
                } else {
                    gemm_tile(a, r0, k, w, o0, n, c);
                }
            } else {
                gemm_edge(a, r0, mr, k, w, o0, nr, n, c);
            }
            o0 += nr;
        }
        r0 += mr;
    }
}

/// The full `MR × NR` register tile of [`gemm_acc`] (scalar spec).
#[inline]
fn gemm_tile(a: &[f32], r0: usize, k: usize, w: &[f32], o0: usize, n: usize, c: &mut [f32]) {
    let mut acc = [[0.0f32; NR]; MR];
    for (r, accr) in acc.iter_mut().enumerate() {
        accr.copy_from_slice(&c[(r0 + r) * n + o0..][..NR]);
    }
    let a0 = &a[r0 * k..][..k];
    let a1 = &a[(r0 + 1) * k..][..k];
    let a2 = &a[(r0 + 2) * k..][..k];
    let a3 = &a[(r0 + 3) * k..][..k];
    for (i, wrow) in w.chunks_exact(n).enumerate() {
        let wt = &wrow[o0..o0 + NR];
        let xs = [a0[i], a1[i], a2[i], a3[i]];
        for (accr, &xv) in acc.iter_mut().zip(&xs) {
            for (av, &wv) in accr.iter_mut().zip(wt) {
                *av += xv * wv;
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        c[(r0 + r) * n + o0..][..NR].copy_from_slice(accr);
    }
}

/// Partial-tile edge of [`gemm_acc`]: one row lane at a time with up to
/// [`NR`] output lanes in registers. The reduction stays `i`-outermost
/// with unit-stride `w` row reads — the rows = 1 case IS the gradient-norm
/// oracle's whole forward, so the edge path must stream `w` exactly like
/// the full tile (never walk its columns), and per element the chain is
/// still `i`-ascending.
#[allow(clippy::too_many_arguments)]
fn gemm_edge(
    a: &[f32],
    r0: usize,
    mr: usize,
    k: usize,
    w: &[f32],
    o0: usize,
    nr: usize,
    n: usize,
    c: &mut [f32],
) {
    let mut acc = [0.0f32; NR];
    for r in r0..r0 + mr {
        let arow = &a[r * k..][..k];
        let accs = &mut acc[..nr];
        accs.copy_from_slice(&c[r * n + o0..][..nr]);
        for (i, &xv) in arow.iter().enumerate() {
            let wrow = &w[i * n + o0..][..nr];
            for (av, &wv) in accs.iter_mut().zip(wrow) {
                *av += xv * wv;
            }
        }
        c[r * n + o0..][..nr].copy_from_slice(accs);
    }
}

/// [`gemm_acc`] with the weight matrix in bf16 storage: per element the
/// reduction is `i`-ascending over `a[r, i] · widen(w[i, o])`, where
/// `widen` is the exact bf16 → f32 bit extension and the accumulation is
/// f32 — NOT bit-comparable to the f32 kernel (storage rounds the
/// weights once), but bit-identical across [`KernelPath`]s.
pub fn gemm_acc_bf16(a: &[f32], rows: usize, k: usize, w: &[u16], n: usize, c: &mut [f32]) {
    gemm_acc_bf16_on(active_path(), a, rows, k, w, n, c);
}

/// [`gemm_acc_bf16`] with explicit tile selection.
pub fn gemm_acc_bf16_on(
    path: KernelPath,
    a: &[f32],
    rows: usize,
    k: usize,
    w: &[u16],
    n: usize,
    c: &mut [f32],
) {
    assert_eq!(a.len(), rows * k, "gemm_acc_bf16: a shape");
    assert_eq!(w.len(), k * n, "gemm_acc_bf16: w shape");
    assert_eq!(c.len(), rows * n, "gemm_acc_bf16: c shape");
    let simd = take_simd(path);
    let mut r0 = 0;
    while r0 < rows {
        let mr = (rows - r0).min(MR);
        let mut o0 = 0;
        while o0 < n {
            let nr = (n - o0).min(NR);
            if mr == MR && nr == NR {
                if simd {
                    simd::gemm_tile_bf16(a, r0, k, w, o0, n, c);
                } else {
                    gemm_tile_bf16(a, r0, k, w, o0, n, c);
                }
            } else {
                gemm_edge_bf16(a, r0, mr, k, w, o0, nr, n, c);
            }
            o0 += nr;
        }
        r0 += mr;
    }
}

/// The full `MR × NR` register tile of [`gemm_acc_bf16`] (scalar spec):
/// the weight row is widened into a stack tile once per `i`, then the
/// accumulation proceeds exactly like the f32 tile.
#[inline]
fn gemm_tile_bf16(a: &[f32], r0: usize, k: usize, w: &[u16], o0: usize, n: usize, c: &mut [f32]) {
    let mut acc = [[0.0f32; NR]; MR];
    for (r, accr) in acc.iter_mut().enumerate() {
        accr.copy_from_slice(&c[(r0 + r) * n + o0..][..NR]);
    }
    let a0 = &a[r0 * k..][..k];
    let a1 = &a[(r0 + 1) * k..][..k];
    let a2 = &a[(r0 + 2) * k..][..k];
    let a3 = &a[(r0 + 3) * k..][..k];
    for (i, wrow) in w.chunks_exact(n).enumerate() {
        let mut wt = [0.0f32; NR];
        for (wf, &wb) in wt.iter_mut().zip(&wrow[o0..o0 + NR]) {
            *wf = bf16_to_f32(wb);
        }
        let xs = [a0[i], a1[i], a2[i], a3[i]];
        for (accr, &xv) in acc.iter_mut().zip(&xs) {
            for (av, &wv) in accr.iter_mut().zip(&wt) {
                *av += xv * wv;
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        c[(r0 + r) * n + o0..][..NR].copy_from_slice(accr);
    }
}

/// Partial-tile edge of [`gemm_acc_bf16`], widening in the inner loop.
#[allow(clippy::too_many_arguments)]
fn gemm_edge_bf16(
    a: &[f32],
    r0: usize,
    mr: usize,
    k: usize,
    w: &[u16],
    o0: usize,
    nr: usize,
    n: usize,
    c: &mut [f32],
) {
    let mut acc = [0.0f32; NR];
    for r in r0..r0 + mr {
        let arow = &a[r * k..][..k];
        let accs = &mut acc[..nr];
        accs.copy_from_slice(&c[r * n + o0..][..nr]);
        for (i, &xv) in arow.iter().enumerate() {
            let wrow = &w[i * n + o0..][..nr];
            for (av, &wb) in accs.iter_mut().zip(wrow) {
                *av += xv * bf16_to_f32(wb);
            }
        }
        c[r * n + o0..][..nr].copy_from_slice(accs);
    }
}

/// `gw[i, o] += Σ_r x[r, i] · g[r, o]` — the weight-gradient outer-product
/// accumulation over a block of rows (`x` is `rows × k`, `g` is `rows × n`,
/// `gw` is `k × n`). Per element the reduction is `r`-ascending and extends
/// the value already in `gw`, so accumulating block after block reproduces
/// the scalar row-by-row backward walk bit for bit. Runs the
/// [`active_path`] tiles; see [`gemm_at_b_acc_on`].
pub fn gemm_at_b_acc(x: &[f32], g: &[f32], rows: usize, k: usize, n: usize, gw: &mut [f32]) {
    gemm_at_b_acc_on(active_path(), x, g, rows, k, n, gw);
}

/// [`gemm_at_b_acc`] with explicit tile selection.
pub fn gemm_at_b_acc_on(
    path: KernelPath,
    x: &[f32],
    g: &[f32],
    rows: usize,
    k: usize,
    n: usize,
    gw: &mut [f32],
) {
    assert_eq!(x.len(), rows * k, "gemm_at_b_acc: x shape");
    assert_eq!(g.len(), rows * n, "gemm_at_b_acc: g shape");
    assert_eq!(gw.len(), k * n, "gemm_at_b_acc: gw shape");
    let simd = take_simd(path);
    let mut i0 = 0;
    while i0 < k {
        let mi = (k - i0).min(MR);
        let mut o0 = 0;
        while o0 < n {
            let no = (n - o0).min(NR);
            if mi == MR && no == NR {
                if simd {
                    simd::at_b_tile(x, g, rows, k, n, i0, o0, gw);
                } else {
                    at_b_tile(x, g, rows, k, n, i0, o0, gw);
                }
            } else {
                at_b_edge(x, g, rows, k, n, i0, mi, o0, no, gw);
            }
            o0 += no;
        }
        i0 += mi;
    }
}

/// The full `MR × NR` register tile of [`gemm_at_b_acc`] (scalar spec).
#[allow(clippy::too_many_arguments)]
#[inline]
fn at_b_tile(
    x: &[f32],
    g: &[f32],
    rows: usize,
    k: usize,
    n: usize,
    i0: usize,
    o0: usize,
    gw: &mut [f32],
) {
    let mut acc = [[0.0f32; NR]; MR];
    for (ii, accr) in acc.iter_mut().enumerate() {
        accr.copy_from_slice(&gw[(i0 + ii) * n + o0..][..NR]);
    }
    for r in 0..rows {
        let xt = &x[r * k + i0..][..MR];
        let gt = &g[r * n + o0..][..NR];
        for (accr, &xv) in acc.iter_mut().zip(xt) {
            for (av, &gv) in accr.iter_mut().zip(gt) {
                *av += xv * gv;
            }
        }
    }
    for (ii, accr) in acc.iter().enumerate() {
        gw[(i0 + ii) * n + o0..][..NR].copy_from_slice(accr);
    }
}

/// Partial-tile edge of [`gemm_at_b_acc`], per element, `r`-ascending.
#[allow(clippy::too_many_arguments)]
fn at_b_edge(
    x: &[f32],
    g: &[f32],
    rows: usize,
    k: usize,
    n: usize,
    i0: usize,
    mi: usize,
    o0: usize,
    no: usize,
    gw: &mut [f32],
) {
    for ii in i0..i0 + mi {
        let grow = &mut gw[ii * n + o0..][..no];
        for (j, gv) in grow.iter_mut().enumerate() {
            let mut acc = *gv;
            for r in 0..rows {
                acc += x[r * k + ii] * g[r * n + o0 + j];
            }
            *gv = acc;
        }
    }
}

/// `gin[r, i] = Σ_o w[i, o] · g[r, o]` — the dense input gradient
/// (`g · Wᵀ`) for a block of rows, **assigned** (not accumulated). Per
/// element the reduction is `o`-ascending from `0.0` — exactly the scalar
/// `dense_input_grad` dot product — with the `w` row streamed once per
/// [`MR`] row lanes instead of once per row. Runs the [`active_path`]
/// tiles; see [`gemm_b_wt_on`].
pub fn gemm_b_wt(g: &[f32], w: &[f32], rows: usize, k: usize, n: usize, gin: &mut [f32]) {
    gemm_b_wt_on(active_path(), g, w, rows, k, n, gin);
}

/// [`gemm_b_wt`] with explicit tile selection.
pub fn gemm_b_wt_on(
    path: KernelPath,
    g: &[f32],
    w: &[f32],
    rows: usize,
    k: usize,
    n: usize,
    gin: &mut [f32],
) {
    assert_eq!(g.len(), rows * n, "gemm_b_wt: g shape");
    assert_eq!(w.len(), k * n, "gemm_b_wt: w shape");
    assert_eq!(gin.len(), rows * k, "gemm_b_wt: gin shape");
    let simd = take_simd(path);
    let mut r0 = 0;
    while r0 < rows {
        let mr = (rows - r0).min(MR);
        if mr == MR {
            if simd {
                simd::b_wt_full(g, w, r0, k, n, gin);
            } else {
                b_wt_full(g, w, r0, k, n, gin);
            }
        } else {
            b_wt_edge(g, w, r0, mr, k, n, gin);
        }
        r0 += mr;
    }
}

/// The full-[`MR`] row band of [`gemm_b_wt`] (scalar spec): four
/// independent per-row accumulators, one sequential `o`-reduction.
#[inline]
fn b_wt_full(g: &[f32], w: &[f32], r0: usize, k: usize, n: usize, gin: &mut [f32]) {
    let g0 = &g[r0 * n..][..n];
    let g1 = &g[(r0 + 1) * n..][..n];
    let g2 = &g[(r0 + 2) * n..][..n];
    let g3 = &g[(r0 + 3) * n..][..n];
    for (i, wrow) in w.chunks_exact(n).enumerate() {
        let mut acc = [0.0f32; MR];
        for (o, &wv) in wrow.iter().enumerate() {
            acc[0] += wv * g0[o];
            acc[1] += wv * g1[o];
            acc[2] += wv * g2[o];
            acc[3] += wv * g3[o];
        }
        for (r, &av) in acc.iter().enumerate() {
            gin[(r0 + r) * k + i] = av;
        }
    }
}

/// Partial row band of [`gemm_b_wt`]: plain per-row dot products.
fn b_wt_edge(g: &[f32], w: &[f32], r0: usize, mr: usize, k: usize, n: usize, gin: &mut [f32]) {
    for r in r0..r0 + mr {
        let grow = &g[r * n..][..n];
        let ginr = &mut gin[r * k..][..k];
        for (i, gi) in ginr.iter_mut().enumerate() {
            let wrow = &w[i * n..][..n];
            *gi = wrow.iter().zip(grow).map(|(&wv, &gv)| wv * gv).sum();
        }
    }
}

/// Copy the bias vector into every row of a `rows × b.len()` block — the
/// pre-initialization [`gemm_acc`] extends. Pure data movement
/// (`copy_from_slice` lowers to memcpy), so there is no SIMD variant.
pub fn bias_init(b: &[f32], rows: usize, out: &mut [f32]) {
    assert_eq!(out.len(), rows * b.len(), "bias_init: out shape");
    for orow in out.chunks_exact_mut(b.len()) {
        orow.copy_from_slice(b);
    }
}

/// [`bias_init`] with the bias vector in bf16 storage: widen once into
/// the first row, then replicate — after the exact bit extension this is
/// the same memcpy pattern as the f32 variant.
pub fn bias_init_bf16(b: &[u16], rows: usize, out: &mut [f32]) {
    assert_eq!(out.len(), rows * b.len(), "bias_init_bf16: out shape");
    if rows == 0 || b.is_empty() {
        return;
    }
    let (first, rest) = out.split_at_mut(b.len());
    for (o, &bb) in first.iter_mut().zip(b) {
        *o = bf16_to_f32(bb);
    }
    for orow in rest.chunks_exact_mut(b.len()) {
        orow.copy_from_slice(first);
    }
}

/// `gb[o] += Σ_r g[r, o]` — the bias gradient over a block of rows,
/// `r`-ascending per element, extending the value already in `gb`. Runs
/// the [`active_path`] tiles; see [`bias_acc_on`].
pub fn bias_acc(g: &[f32], rows: usize, n: usize, gb: &mut [f32]) {
    bias_acc_on(active_path(), g, rows, n, gb);
}

/// [`bias_acc`] with explicit tile selection.
pub fn bias_acc_on(path: KernelPath, g: &[f32], rows: usize, n: usize, gb: &mut [f32]) {
    assert_eq!(g.len(), rows * n, "bias_acc: g shape");
    assert_eq!(gb.len(), n, "bias_acc: gb shape");
    if take_simd(path) {
        simd::bias_acc(g, n, gb);
    } else {
        bias_acc_scalar(g, n, gb);
    }
}

/// Scalar spec of [`bias_acc`]: rows outer, outputs inner — per element
/// `gb[o]` the adds arrive in `r`-ascending order.
fn bias_acc_scalar(g: &[f32], n: usize, gb: &mut [f32]) {
    for grow in g.chunks_exact(n) {
        for (b, &gv) in gb.iter_mut().zip(grow) {
            *b += gv;
        }
    }
}

/// Valid-1D-convolution patch extraction: for every row and output time
/// step, copy the `kernel × in_ch` input window into
/// `patch[(r·t_out + t), (k·in_ch + c)]`. Because the input layout is
/// `[time, ch]`, each window is **contiguous** — im2col is a strided
/// memcpy (already optimal data movement, no SIMD variant) — and the
/// patch matrix turns the convolution into the dense [`gemm_acc`] /
/// [`gemm_at_b_acc`] kernels with `k·in_ch` inputs, in the exact
/// `(k, c)`-ascending tap order of the scalar conv walk.
#[allow(clippy::too_many_arguments)]
pub fn im2col(
    input: &[f32],
    rows: usize,
    in_dim: usize,
    in_ch: usize,
    kernel: usize,
    stride: usize,
    t_out: usize,
    patch: &mut Vec<f32>,
) {
    assert_eq!(input.len(), rows * in_dim, "im2col: input shape");
    let kc = kernel * in_ch;
    // every element is overwritten below, so only fix the length (no
    // zero-fill pass over the hot path's largest scratch matrix)
    let want = rows * t_out * kc;
    if patch.len() != want {
        patch.clear();
        patch.resize(want, 0.0);
    }
    for (r, xrow) in input.chunks_exact(in_dim).enumerate() {
        for t in 0..t_out {
            let dst = &mut patch[(r * t_out + t) * kc..][..kc];
            dst.copy_from_slice(&xrow[t * stride * in_ch..][..kc]);
        }
    }
}

/// Scatter patch-space gradients back to input space:
/// `gin[r, (t·stride + k)·in_ch + c] += gpatch[(r·t_out + t), k·in_ch + c]`.
/// `gin` must be pre-zeroed. Per input element contributions arrive in
/// `t`-ascending window order — the scalar conv `input_grad` order.
#[allow(clippy::too_many_arguments)]
pub fn col2im_acc(
    gpatch: &[f32],
    rows: usize,
    in_dim: usize,
    in_ch: usize,
    kernel: usize,
    stride: usize,
    t_out: usize,
    gin: &mut [f32],
) {
    assert_eq!(gin.len(), rows * in_dim, "col2im_acc: gin shape");
    let kc = kernel * in_ch;
    assert_eq!(gpatch.len(), rows * t_out * kc, "col2im_acc: gpatch shape");
    for (r, grow) in gin.chunks_exact_mut(in_dim).enumerate() {
        for t in 0..t_out {
            let src = &gpatch[(r * t_out + t) * kc..][..kc];
            let dst = &mut grow[t * stride * in_ch..][..kc];
            for (d, &s) in dst.iter_mut().zip(src) {
                *d += s;
            }
        }
    }
}

/// Explicit SSE2 register tiles (x86_64 only). Each function mirrors its
/// scalar twin exactly: lanes span only *independent* output elements,
/// every reduction runs in the reference index order, and SSE2
/// `_mm_mul_ps` / `_mm_add_ps` perform one IEEE-754 rounding per lane per
/// op with no FMA contraction — so each tile is bit-identical to its
/// scalar spec (pinned by the in-module tests and `rust/tests/props.rs`).
#[cfg(target_arch = "x86_64")]
mod simd {
    use super::{MR, NR};
    use std::arch::x86_64::{
        _mm_add_ps, _mm_castsi128_ps, _mm_loadu_ps, _mm_loadu_si128, _mm_mul_ps, _mm_set1_ps,
        _mm_set_ps, _mm_setzero_ps, _mm_setzero_si128, _mm_storeu_ps, _mm_unpackhi_epi16,
        _mm_unpacklo_epi16,
    };

    /// SSE2 twin of the scalar `gemm_tile`: [`MR`] broadcast lanes ×
    /// two 4-wide output vectors, `i`-reduction sequential.
    pub(super) fn gemm_tile(
        a: &[f32],
        r0: usize,
        k: usize,
        w: &[f32],
        o0: usize,
        n: usize,
        c: &mut [f32],
    ) {
        let a0 = &a[r0 * k..][..k];
        let a1 = &a[(r0 + 1) * k..][..k];
        let a2 = &a[(r0 + 2) * k..][..k];
        let a3 = &a[(r0 + 3) * k..][..k];
        // SAFETY: SSE2 is unconditionally available on x86_64 (baseline
        // ABI). Every `loadu`/`storeu` below reads or writes 4 f32s
        // through `.as_ptr()`/`.as_mut_ptr()` of a slice bounds-checked
        // to exactly NR = 8 elements (offsets 0 and 4), so all pointer
        // accesses stay in bounds; the `u` variants carry no alignment
        // requirement.
        unsafe {
            let mut acc = [[_mm_setzero_ps(); 2]; MR];
            for (r, accr) in acc.iter_mut().enumerate() {
                let crow = &c[(r0 + r) * n + o0..][..NR];
                accr[0] = _mm_loadu_ps(crow.as_ptr());
                accr[1] = _mm_loadu_ps(crow.as_ptr().add(4));
            }
            for (i, wrow) in w.chunks_exact(n).enumerate() {
                let wt = &wrow[o0..o0 + NR];
                let w01 = _mm_loadu_ps(wt.as_ptr());
                let w23 = _mm_loadu_ps(wt.as_ptr().add(4));
                let xs = [a0[i], a1[i], a2[i], a3[i]];
                for (accr, &xv) in acc.iter_mut().zip(&xs) {
                    let xb = _mm_set1_ps(xv);
                    accr[0] = _mm_add_ps(accr[0], _mm_mul_ps(xb, w01));
                    accr[1] = _mm_add_ps(accr[1], _mm_mul_ps(xb, w23));
                }
            }
            for (r, accr) in acc.iter().enumerate() {
                let crow = &mut c[(r0 + r) * n + o0..][..NR];
                _mm_storeu_ps(crow.as_mut_ptr(), accr[0]);
                _mm_storeu_ps(crow.as_mut_ptr().add(4), accr[1]);
            }
        }
    }

    /// SSE2 twin of the scalar `gemm_tile_bf16`: the bf16 → f32 widening
    /// is a 16-bit zero-interleave (each u32 lane becomes `w << 16`) —
    /// the exact bit extension `bf16_to_f32` performs, so this path and
    /// the scalar path compute identical products.
    pub(super) fn gemm_tile_bf16(
        a: &[f32],
        r0: usize,
        k: usize,
        w: &[u16],
        o0: usize,
        n: usize,
        c: &mut [f32],
    ) {
        let a0 = &a[r0 * k..][..k];
        let a1 = &a[(r0 + 1) * k..][..k];
        let a2 = &a[(r0 + 2) * k..][..k];
        let a3 = &a[(r0 + 3) * k..][..k];
        // SAFETY: as in `gemm_tile` for the f32 loads/stores; the one
        // integer load reads 8 u16s (16 bytes) through `.as_ptr()` of a
        // slice bounds-checked to exactly NR = 8 elements, unaligned
        // load, in bounds.
        unsafe {
            let mut acc = [[_mm_setzero_ps(); 2]; MR];
            for (r, accr) in acc.iter_mut().enumerate() {
                let crow = &c[(r0 + r) * n + o0..][..NR];
                accr[0] = _mm_loadu_ps(crow.as_ptr());
                accr[1] = _mm_loadu_ps(crow.as_ptr().add(4));
            }
            let z = _mm_setzero_si128();
            for (i, wrow) in w.chunks_exact(n).enumerate() {
                let wt = &wrow[o0..o0 + NR];
                let wb = _mm_loadu_si128(wt.as_ptr().cast());
                // interleaving zeros below the u16s yields u32 lanes of
                // `w << 16` == the bf16 widening, low then high half
                let w01 = _mm_castsi128_ps(_mm_unpacklo_epi16(z, wb));
                let w23 = _mm_castsi128_ps(_mm_unpackhi_epi16(z, wb));
                let xs = [a0[i], a1[i], a2[i], a3[i]];
                for (accr, &xv) in acc.iter_mut().zip(&xs) {
                    let xb = _mm_set1_ps(xv);
                    accr[0] = _mm_add_ps(accr[0], _mm_mul_ps(xb, w01));
                    accr[1] = _mm_add_ps(accr[1], _mm_mul_ps(xb, w23));
                }
            }
            for (r, accr) in acc.iter().enumerate() {
                let crow = &mut c[(r0 + r) * n + o0..][..NR];
                _mm_storeu_ps(crow.as_mut_ptr(), accr[0]);
                _mm_storeu_ps(crow.as_mut_ptr().add(4), accr[1]);
            }
        }
    }

    /// SSE2 twin of the scalar `at_b_tile`: gradient lanes vectorized,
    /// `r`-reduction sequential.
    #[allow(clippy::too_many_arguments)]
    pub(super) fn at_b_tile(
        x: &[f32],
        g: &[f32],
        rows: usize,
        k: usize,
        n: usize,
        i0: usize,
        o0: usize,
        gw: &mut [f32],
    ) {
        // SAFETY: SSE2 baseline as in `gemm_tile`; every vector load and
        // store covers 4 f32s at offsets 0/4 of a slice bounds-checked
        // to exactly NR = 8 elements — in bounds, unaligned ok.
        unsafe {
            let mut acc = [[_mm_setzero_ps(); 2]; MR];
            for (ii, accr) in acc.iter_mut().enumerate() {
                let grow = &gw[(i0 + ii) * n + o0..][..NR];
                accr[0] = _mm_loadu_ps(grow.as_ptr());
                accr[1] = _mm_loadu_ps(grow.as_ptr().add(4));
            }
            for r in 0..rows {
                let xt = &x[r * k + i0..][..MR];
                let gt = &g[r * n + o0..][..NR];
                let g01 = _mm_loadu_ps(gt.as_ptr());
                let g23 = _mm_loadu_ps(gt.as_ptr().add(4));
                for (accr, &xv) in acc.iter_mut().zip(xt) {
                    let xb = _mm_set1_ps(xv);
                    accr[0] = _mm_add_ps(accr[0], _mm_mul_ps(xb, g01));
                    accr[1] = _mm_add_ps(accr[1], _mm_mul_ps(xb, g23));
                }
            }
            for (ii, accr) in acc.iter().enumerate() {
                let grow = &mut gw[(i0 + ii) * n + o0..][..NR];
                _mm_storeu_ps(grow.as_mut_ptr(), accr[0]);
                _mm_storeu_ps(grow.as_mut_ptr().add(4), accr[1]);
            }
        }
    }

    /// SSE2 twin of the scalar `b_wt_full`: one 4-lane accumulator whose
    /// lanes are the [`MR`] independent rows, `o`-reduction sequential
    /// via a per-`o` row gather.
    pub(super) fn b_wt_full(g: &[f32], w: &[f32], r0: usize, k: usize, n: usize, gin: &mut [f32]) {
        let g0 = &g[r0 * n..][..n];
        let g1 = &g[(r0 + 1) * n..][..n];
        let g2 = &g[(r0 + 2) * n..][..n];
        let g3 = &g[(r0 + 3) * n..][..n];
        // SAFETY: SSE2 baseline as in `gemm_tile`. All reads go through
        // safe slice indexing; the only raw-pointer op is the 4-f32
        // store into `out`, a local array of exactly MR = 4 f32s.
        unsafe {
            for (i, wrow) in w.chunks_exact(n).enumerate() {
                let mut acc = _mm_setzero_ps();
                for (o, &wv) in wrow.iter().enumerate() {
                    // lane r holds g_r[o] (`set_ps` lists high-to-low)
                    let gv = _mm_set_ps(g3[o], g2[o], g1[o], g0[o]);
                    acc = _mm_add_ps(acc, _mm_mul_ps(_mm_set1_ps(wv), gv));
                }
                let mut out = [0.0f32; MR];
                _mm_storeu_ps(out.as_mut_ptr(), acc);
                for (r, &av) in out.iter().enumerate() {
                    gin[(r0 + r) * k + i] = av;
                }
            }
        }
    }

    /// SSE2 twin of the scalar `bias_acc_scalar`: lanes across outputs,
    /// rows strictly sequential per lane — per element `gb[o]` the adds
    /// arrive in the same `r`-ascending order as the scalar walk (its
    /// rows-outer/outputs-inner loop touches each `gb[o]` in exactly
    /// that sequence).
    pub(super) fn bias_acc(g: &[f32], n: usize, gb: &mut [f32]) {
        let lanes = n - n % 4;
        // SAFETY: SSE2 baseline as in `gemm_tile`; vector loads/stores
        // cover offsets `o .. o + 4` with `o + 4 <= lanes <= n`, inside
        // both `gb` (len n, caller-asserted) and each `grow` (len n by
        // `chunks_exact`). The tail past `lanes` is safe scalar code.
        unsafe {
            let mut o = 0;
            while o < lanes {
                let mut acc = _mm_loadu_ps(gb.as_ptr().add(o));
                for grow in g.chunks_exact(n) {
                    acc = _mm_add_ps(acc, _mm_loadu_ps(grow.as_ptr().add(o)));
                }
                _mm_storeu_ps(gb.as_mut_ptr().add(o), acc);
                o += 4;
            }
        }
        for (o, b) in gb.iter_mut().enumerate().skip(lanes) {
            for grow in g.chunks_exact(n) {
                *b += grow[o];
            }
        }
    }
}

/// Non-x86_64 fallback: the `Simd` path is a *request* — on targets
/// without explicit tiles it resolves to the scalar twins so every
/// dispatch call site compiles everywhere, while [`simd_available`]
/// reports `false` and the default path stays `Scalar`.
#[cfg(not(target_arch = "x86_64"))]
mod simd {
    pub(super) use super::{
        at_b_tile, b_wt_full, bias_acc_scalar as bias_acc, gemm_tile, gemm_tile_bf16,
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bf16::f32_to_bf16;

    /// Deterministic pseudo-random fill (no external RNG needed here).
    fn fill(v: &mut [f32], salt: usize) {
        for (i, x) in v.iter_mut().enumerate() {
            *x = (((i * 31 + salt * 17 + 7) % 113) as f32 / 113.0 - 0.5) * 1.7;
        }
    }

    /// Shapes crossing every tile edge: exact tiles, sub-tile remainders,
    /// single rows/cols.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (1, 64, 10),
        (3, 5, 7),
        (4, 8, 8),
        (5, 9, 17),
        (8, 64, 128),
        (13, 24, 10),
    ];

    #[test]
    fn gemm_acc_matches_scalar_reference_bitwise_on_both_paths() {
        for &(rows, k, n) in SHAPES {
            let mut a = vec![0.0f32; rows * k];
            let mut w = vec![0.0f32; k * n];
            let mut c0 = vec![0.0f32; rows * n];
            fill(&mut a, 1);
            fill(&mut w, 2);
            fill(&mut c0, 3); // arbitrary pre-init (bias-like)
            // scalar reference: the layers.rs dense forward walk
            let mut want = c0.clone();
            for r in 0..rows {
                for (i, &xv) in a[r * k..][..k].iter().enumerate() {
                    for o in 0..n {
                        want[r * n + o] += xv * w[i * n + o];
                    }
                }
            }
            for path in KERNEL_PATHS {
                let mut c = c0.clone();
                gemm_acc_on(path, &a, rows, k, &w, n, &mut c);
                assert_eq!(c, want, "gemm_acc[{}] {rows}x{k}x{n}", path.name());
            }
            let mut c = c0.clone();
            gemm_acc(&a, rows, k, &w, n, &mut c);
            assert_eq!(c, want, "gemm_acc dispatched {rows}x{k}x{n}");
        }
    }

    #[test]
    fn gemm_acc_bf16_matches_the_widened_scalar_walk_bitwise_on_both_paths() {
        for &(rows, k, n) in SHAPES {
            let mut a = vec![0.0f32; rows * k];
            let mut wf = vec![0.0f32; k * n];
            let mut c0 = vec![0.0f32; rows * n];
            fill(&mut a, 11);
            fill(&mut wf, 12);
            fill(&mut c0, 13);
            let wq: Vec<u16> = wf.iter().map(|&x| f32_to_bf16(x)).collect();
            // reference: scalar walk over the exactly-widened weights
            let mut want = c0.clone();
            for r in 0..rows {
                for (i, &xv) in a[r * k..][..k].iter().enumerate() {
                    for o in 0..n {
                        want[r * n + o] += xv * bf16_to_f32(wq[i * n + o]);
                    }
                }
            }
            for path in KERNEL_PATHS {
                let mut c = c0.clone();
                gemm_acc_bf16_on(path, &a, rows, k, &wq, n, &mut c);
                assert_eq!(c, want, "gemm_acc_bf16[{}] {rows}x{k}x{n}", path.name());
            }
            let mut c = c0.clone();
            gemm_acc_bf16(&a, rows, k, &wq, n, &mut c);
            assert_eq!(c, want, "gemm_acc_bf16 dispatched {rows}x{k}x{n}");
        }
    }

    #[test]
    fn gemm_at_b_acc_matches_scalar_reference_bitwise_and_chains_across_blocks() {
        for &(rows, k, n) in SHAPES {
            let mut x = vec![0.0f32; rows * k];
            let mut g = vec![0.0f32; rows * n];
            let mut gw0 = vec![0.0f32; k * n];
            fill(&mut x, 4);
            fill(&mut g, 5);
            fill(&mut gw0, 6); // pre-existing partial gradient
            // scalar reference: row-by-row outer products, r-ascending
            let mut want = gw0.clone();
            for r in 0..rows {
                for i in 0..k {
                    let xv = x[r * k + i];
                    if xv != 0.0 {
                        for o in 0..n {
                            want[i * n + o] += xv * g[r * n + o];
                        }
                    }
                }
            }
            for path in KERNEL_PATHS {
                let mut gw = gw0.clone();
                gemm_at_b_acc_on(path, &x, &g, rows, k, n, &mut gw);
                assert_eq!(gw, want, "gemm_at_b_acc[{}] {rows}x{k}x{n}", path.name());
                // splitting the rows into two blocks must not change a bit
                if rows > 1 {
                    let half = rows / 2;
                    let mut gw2 = gw0.clone();
                    gemm_at_b_acc_on(path, &x[..half * k], &g[..half * n], half, k, n, &mut gw2);
                    gemm_at_b_acc_on(
                        path,
                        &x[half * k..],
                        &g[half * n..],
                        rows - half,
                        k,
                        n,
                        &mut gw2,
                    );
                    assert_eq!(gw2, gw, "block split changed bits {rows}x{k}x{n}");
                }
            }
        }
    }

    #[test]
    fn gemm_b_wt_matches_scalar_dot_bitwise_on_both_paths() {
        for &(rows, k, n) in SHAPES {
            let mut g = vec![0.0f32; rows * n];
            let mut w = vec![0.0f32; k * n];
            fill(&mut g, 7);
            fill(&mut w, 8);
            for path in KERNEL_PATHS {
                let mut gin = vec![f32::NAN; rows * k]; // assignment must cover all
                gemm_b_wt_on(path, &g, &w, rows, k, n, &mut gin);
                for r in 0..rows {
                    for i in 0..k {
                        let want: f32 = w[i * n..][..n]
                            .iter()
                            .zip(&g[r * n..][..n])
                            .map(|(&wv, &gv)| wv * gv)
                            .sum();
                        let p = path.name();
                        assert_eq!(gin[r * k + i], want, "gemm_b_wt[{p}] {rows}x{k}x{n} r{r} i{i}");
                    }
                }
            }
        }
    }

    #[test]
    fn bias_kernels_match_reference_on_both_paths() {
        let b = [0.5f32, -1.25, 2.0];
        let mut out = vec![0.0f32; 12];
        bias_init(&b, 4, &mut out);
        assert!(out.chunks_exact(3).all(|r| r == b.as_slice()));

        // a width crossing the 4-lane boundary so the SIMD tail runs too
        for n in [3usize, 8, 11] {
            let rows = 5;
            let mut g = vec![0.0f32; rows * n];
            fill(&mut g, 9);
            let gb0 = vec![0.25f32; n];
            let mut want = gb0.clone();
            for r in 0..rows {
                for o in 0..n {
                    want[o] += g[r * n + o];
                }
            }
            for path in KERNEL_PATHS {
                let mut gb = gb0.clone();
                bias_acc_on(path, &g, rows, n, &mut gb);
                assert_eq!(gb, want, "bias_acc[{}] n={n}", path.name());
            }
        }
    }

    #[test]
    fn bias_init_bf16_replicates_the_widened_bias() {
        let bf = [0.5f32, -1.25, 2.0, 0.3337]; // last one rounds in bf16
        let bq: Vec<u16> = bf.iter().map(|&x| f32_to_bf16(x)).collect();
        let widened: Vec<f32> = bq.iter().map(|&b| bf16_to_f32(b)).collect();
        let mut out = vec![f32::NAN; 12];
        bias_init_bf16(&bq, 3, &mut out);
        assert!(out.chunks_exact(4).all(|r| r == widened.as_slice()));
        // rows = 0 is a no-op, not a panic
        bias_init_bf16(&bq, 0, &mut []);
    }

    #[test]
    fn im2col_and_col2im_round_trip_the_conv_geometry() {
        // rows=2, t_in=7, ic=2, kernel=3, stride=2 -> t_out=3
        let (rows, t_in, ic, kernel, stride) = (2usize, 7usize, 2usize, 3usize, 2usize);
        let t_out = (t_in - kernel) / stride + 1;
        let in_dim = t_in * ic;
        let mut input = vec![0.0f32; rows * in_dim];
        fill(&mut input, 10);
        let mut patch = Vec::new();
        im2col(&input, rows, in_dim, ic, kernel, stride, t_out, &mut patch);
        assert_eq!(patch.len(), rows * t_out * kernel * ic);
        for r in 0..rows {
            for t in 0..t_out {
                for k in 0..kernel {
                    for c in 0..ic {
                        let got = patch[(r * t_out + t) * kernel * ic + k * ic + c];
                        let want = input[r * in_dim + (t * stride + k) * ic + c];
                        assert_eq!(got, want, "r{r} t{t} k{k} c{c}");
                    }
                }
            }
        }
        // col2im of an all-ones patch counts each input position's window
        // multiplicity
        let gpatch = vec![1.0f32; patch.len()];
        let mut gin = vec![0.0f32; rows * in_dim];
        col2im_acc(&gpatch, rows, in_dim, ic, kernel, stride, t_out, &mut gin);
        for r in 0..rows {
            for p in 0..t_in {
                let count = (0..t_out)
                    .filter(|&t| p >= t * stride && p < t * stride + kernel)
                    .count() as f32;
                for c in 0..ic {
                    assert_eq!(gin[r * in_dim + p * ic + c], count, "r{r} pos{p} ch{c}");
                }
            }
        }
    }

    #[test]
    fn forced_path_override_controls_dispatch() {
        // safe to run concurrently with the other lib tests: a racing
        // reader just runs the other, bit-identical tiles
        set_forced_kernel_path(Some(KernelPath::Scalar));
        assert_eq!(active_path(), KernelPath::Scalar);
        set_forced_kernel_path(Some(KernelPath::Simd));
        assert_eq!(active_path(), KernelPath::Simd);
        set_forced_kernel_path(None);
        assert!(KERNEL_PATHS.contains(&active_path()));
        if cfg!(target_arch = "x86_64") {
            assert!(simd_available(), "SSE2 is baseline on x86_64");
        } else {
            assert!(!simd_available());
        }
    }

    #[test]
    fn lane_constants_are_sane() {
        assert!(MR >= 1 && NR >= 1);
        assert!(MAX_BLOCK_ROWS >= MR);
        assert_eq!(KernelPath::Scalar.name(), "scalar");
        assert_eq!(KernelPath::Simd.name(), "simd");
    }
}

//! Checkpointing: save/restore a [`ModelState`] to a small self-describing
//! binary format (magic, version, model name, per-tensor shape + f32 data,
//! checksum trailer). No external serialization crates are available
//! offline, so the format is hand-rolled and covered by round-trip tests.
//!
//! Crash safety: [`save`] writes a `<file>.tmp` sibling, fsyncs it, and
//! atomically renames it into place — a crash mid-save leaves either the
//! previous checkpoint or a stray `.tmp`, never a half-written file under
//! the real name. The v2 format ends with the [`state_checksum`] of the
//! serialized state; [`load`] recomputes it and fails with a descriptive
//! error (never a panic) on corrupt or truncated files.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};
use xla::Literal;

use super::engine::ModelState;
use super::tensor::HostTensor;
use crate::util::digest::{fnv1a64, fnv1a64_from};

const MAGIC: &[u8; 8] = b"ISAMPLE\x02";
const MAGIC_V1: &[u8; 8] = b"ISAMPLE\x01";

/// Order-sensitive checksum over everything [`save`] serializes (model
/// name, step counter, parameter and momentum tensors by bit pattern).
/// The "final state" fingerprint the golden determinism tests and the
/// train bench pin: two states with equal checksums trained identically,
/// bit for bit. Hashes in streaming form — no whole-state word buffer.
pub fn state_checksum(state: &ModelState) -> Result<u64> {
    let mut h = fnv1a64(state.model.as_bytes().iter().map(|&b| b as u64));
    h = fnv1a64_from(h, [state.step]);
    for group in [&state.params, &state.mom] {
        h = fnv1a64_from(h, [group.len() as u64]);
        for lit in group {
            let t = HostTensor::from_literal(lit)?;
            h = fnv1a64_from(h, t.shape.iter().map(|&d| d as u64));
            h = fnv1a64_from(h, t.data.iter().map(|v| v.to_bits() as u64));
        }
    }
    Ok(h)
}

/// `<file>.tmp` sibling [`save`] writes before renaming into place.
fn tmp_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

/// Serialize params + momentum + step counter, crash-safely: the bytes
/// (including the checksum trailer) land in `<file>.tmp`, are fsynced, and
/// only then renamed over `path`.
pub fn save(state: &ModelState, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    let checksum = state_checksum(state)?;
    let tmp = tmp_path(path);
    {
        let mut f =
            std::fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?;
        f.write_all(MAGIC)?;
        write_str(&mut f, &state.model)?;
        f.write_all(&state.step.to_le_bytes())?;
        for group in [&state.params, &state.mom] {
            f.write_all(&(group.len() as u32).to_le_bytes())?;
            for lit in group {
                let t = HostTensor::from_literal(lit)?;
                f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
                for &d in &t.shape {
                    f.write_all(&(d as u32).to_le_bytes())?;
                }
                let bytes: Vec<u8> = t.data.iter().flat_map(|v| v.to_le_bytes()).collect();
                f.write_all(&(bytes.len() as u64).to_le_bytes())?;
                f.write_all(&bytes)?;
            }
        }
        f.write_all(&checksum.to_le_bytes())?;
        // fsync before the rename: the rename must never expose bytes the
        // kernel has not durably accepted
        f.sync_all().with_context(|| format!("syncing {tmp:?}"))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {tmp:?} over {path:?}"))?;
    // best-effort directory sync so the rename itself survives a crash
    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        if let Ok(d) = std::fs::File::open(dir) {
            d.sync_all().ok();
        }
    }
    Ok(())
}

/// One tensor group (params or momentum) of the serialized body.
fn read_group(f: &mut impl Read) -> Result<Vec<Literal>> {
    let count = read_u32(f)? as usize;
    let mut lits = Vec::with_capacity(count.min(1 << 16));
    for _ in 0..count {
        let rank = read_u32(f)? as usize;
        if rank > 16 {
            bail!("unreasonable tensor rank {rank} in checkpoint");
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(read_u32(f)? as usize);
        }
        let nbytes = read_u64(f)? as usize;
        if nbytes != shape.iter().product::<usize>() * 4 {
            bail!("checkpoint tensor size mismatch");
        }
        let mut buf = vec![0u8; nbytes];
        f.read_exact(&mut buf).context("checkpoint truncated mid-tensor")?;
        let data: Vec<f32> =
            buf.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect();
        lits.push(HostTensor::new(shape, data).to_literal()?);
    }
    Ok(lits)
}

/// Restore a state saved by [`save`], verifying the checksum trailer: a
/// corrupt or truncated file is a descriptive `Err`, never a panic.
pub fn load(path: impl AsRef<Path>) -> Result<ModelState> {
    let path = path.as_ref();
    let mut f =
        std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic).context("checkpoint truncated before its magic")?;
    if &magic == MAGIC_V1 {
        bail!("checkpoint {path:?} is the pre-checksum v1 format; re-create it with this build");
    }
    if &magic != MAGIC {
        bail!("not an isample checkpoint: bad magic");
    }
    let model = read_str(&mut f)?;
    let step = read_u64(&mut f)?;
    let params = read_group(&mut f)?;
    let mom = read_group(&mut f)?;
    let expect = read_u64(&mut f).context("checkpoint truncated before its checksum trailer")?;
    let state = ModelState { model, params, mom, step };
    let got = state_checksum(&state)?;
    if got != expect {
        bail!(
            "checkpoint {path:?} failed its checksum (stored {expect:#018x}, recomputed \
             {got:#018x}): the file is corrupt"
        );
    }
    Ok(state)
}

fn write_str(f: &mut impl Write, s: &str) -> Result<()> {
    f.write_all(&(s.len() as u32).to_le_bytes())?;
    f.write_all(s.as_bytes())?;
    Ok(())
}

fn read_str(f: &mut impl Read) -> Result<String> {
    let len = read_u32(f)? as usize;
    if len > 1 << 16 {
        bail!("unreasonable string length in checkpoint");
    }
    let mut buf = vec![0u8; len];
    f.read_exact(&mut buf).context("checkpoint truncated mid-string")?;
    String::from_utf8(buf).context("invalid utf8 in checkpoint")
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b).context("checkpoint truncated")?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b).context("checkpoint truncated")?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_state() -> ModelState {
        ModelState {
            model: "test".into(),
            params: vec![
                HostTensor::new(vec![2, 2], vec![1.0, -2.0, 3.5, 0.0]).to_literal().unwrap(),
                HostTensor::new(vec![3], vec![0.1, 0.2, 0.3]).to_literal().unwrap(),
            ],
            mom: vec![
                HostTensor::zeros(vec![2, 2]).to_literal().unwrap(),
                HostTensor::new(vec![3], vec![9.0, 8.0, 7.0]).to_literal().unwrap(),
            ],
            step: 1234,
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("isample_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ckpt");
        let state = tiny_state();
        save(&state, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.model, "test");
        assert_eq!(back.step, 1234);
        for (a, b) in state.params.iter().zip(&back.params) {
            assert_eq!(HostTensor::from_literal(a).unwrap(), HostTensor::from_literal(b).unwrap());
        }
        for (a, b) in state.mom.iter().zip(&back.mom) {
            assert_eq!(HostTensor::from_literal(a).unwrap(), HostTensor::from_literal(b).unwrap());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn state_checksum_tracks_content_and_survives_roundtrip() {
        let state = tiny_state();
        let base = state_checksum(&state).unwrap();
        assert_eq!(base, state_checksum(&tiny_state()).unwrap(), "checksum must be deterministic");

        let mut stepped = tiny_state();
        stepped.step += 1;
        assert_ne!(base, state_checksum(&stepped).unwrap());

        let mut perturbed = tiny_state();
        let mut t = HostTensor::from_literal(&perturbed.params[0]).unwrap();
        t.data[0] += 1e-7;
        perturbed.params[0] = t.to_literal().unwrap();
        assert_ne!(base, state_checksum(&perturbed).unwrap());

        let dir = std::env::temp_dir().join(format!("isample_ckpt_c_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.ckpt");
        save(&state, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(base, state_checksum(&back).unwrap(), "save/load must preserve the checksum");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_is_atomic_and_corruption_fails_with_a_clear_error() -> Result<()> {
        let dir = std::env::temp_dir().join(format!("isample_ckpt_a_{}", std::process::id()));
        std::fs::create_dir_all(&dir)?;
        let path = dir.join("t.ckpt");
        save(&tiny_state(), &path)?;
        // the scratch file was renamed into place, not left behind
        assert!(!tmp_path(&path).exists());
        let bytes = std::fs::read(&path)?;
        // flip one bit of tensor payload (just before the 8-byte trailer)
        let mut bad = bytes.clone();
        let k = bad.len() - 12;
        bad[k] ^= 0x40;
        std::fs::write(&path, &bad)?;
        let err = match load(&path) {
            Err(e) => format!("{e:#}"),
            Ok(_) => String::new(),
        };
        assert!(err.contains("checksum"), "corruption must fail loudly, got {err:?}");
        std::fs::remove_dir_all(&dir).ok();
        Ok(())
    }

    #[test]
    fn truncated_checkpoints_error_instead_of_panicking() -> Result<()> {
        let dir = std::env::temp_dir().join(format!("isample_ckpt_t_{}", std::process::id()));
        std::fs::create_dir_all(&dir)?;
        let path = dir.join("t.ckpt");
        save(&tiny_state(), &path)?;
        let bytes = std::fs::read(&path)?;
        // cut mid-body and mid-trailer: both must surface as descriptive
        // errors, and a v2 file shorn of its trailer must never load
        for cut in [bytes.len() / 2, bytes.len() - 4] {
            std::fs::write(&path, &bytes[..cut])?;
            let err = match load(&path) {
                Err(e) => format!("{e:#}"),
                Ok(_) => String::new(),
            };
            assert!(err.contains("truncated"), "cut={cut}: {err:?}");
        }
        std::fs::remove_dir_all(&dir).ok();
        Ok(())
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("isample_ckpt_g_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Checkpointing: save/restore a [`ModelState`] to a small self-describing
//! binary format (magic, version, model name, per-tensor shape + f32 data).
//! No external serialization crates are available offline, so the format is
//! hand-rolled and covered by round-trip tests.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::engine::ModelState;
use super::tensor::HostTensor;
use crate::util::digest::{fnv1a64, fnv1a64_from};

const MAGIC: &[u8; 8] = b"ISAMPLE\x01";

/// Order-sensitive checksum over everything [`save`] serializes (model
/// name, step counter, parameter and momentum tensors by bit pattern).
/// The "final state" fingerprint the golden determinism tests and the
/// train bench pin: two states with equal checksums trained identically,
/// bit for bit. Hashes in streaming form — no whole-state word buffer.
pub fn state_checksum(state: &ModelState) -> Result<u64> {
    let mut h = fnv1a64(state.model.as_bytes().iter().map(|&b| b as u64));
    h = fnv1a64_from(h, [state.step]);
    for group in [&state.params, &state.mom] {
        h = fnv1a64_from(h, [group.len() as u64]);
        for lit in group {
            let t = HostTensor::from_literal(lit)?;
            h = fnv1a64_from(h, t.shape.iter().map(|&d| d as u64));
            h = fnv1a64_from(h, t.data.iter().map(|v| v.to_bits() as u64));
        }
    }
    Ok(h)
}

/// Serialize params + momentum + step counter.
pub fn save(state: &ModelState, path: impl AsRef<Path>) -> Result<()> {
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    f.write_all(MAGIC)?;
    write_str(&mut f, &state.model)?;
    f.write_all(&state.step.to_le_bytes())?;
    for group in [&state.params, &state.mom] {
        f.write_all(&(group.len() as u32).to_le_bytes())?;
        for lit in group {
            let t = HostTensor::from_literal(lit)?;
            f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for &d in &t.shape {
                f.write_all(&(d as u32).to_le_bytes())?;
            }
            let bytes: Vec<u8> = t.data.iter().flat_map(|v| v.to_le_bytes()).collect();
            f.write_all(&(bytes.len() as u64).to_le_bytes())?;
            f.write_all(&bytes)?;
        }
    }
    Ok(())
}

/// Restore a state saved by [`save`].
pub fn load(path: impl AsRef<Path>) -> Result<ModelState> {
    let mut f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an isample checkpoint: bad magic");
    }
    let model = read_str(&mut f)?;
    let step = read_u64(&mut f)?;
    let mut groups = Vec::with_capacity(2);
    for _ in 0..2 {
        let count = read_u32(&mut f)? as usize;
        let mut lits = Vec::with_capacity(count);
        for _ in 0..count {
            let rank = read_u32(&mut f)? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(read_u32(&mut f)? as usize);
            }
            let nbytes = read_u64(&mut f)? as usize;
            if nbytes != shape.iter().product::<usize>() * 4 {
                bail!("checkpoint tensor size mismatch");
            }
            let mut buf = vec![0u8; nbytes];
            f.read_exact(&mut buf)?;
            let data: Vec<f32> = buf
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            lits.push(HostTensor::new(shape, data).to_literal()?);
        }
        groups.push(lits);
    }
    let mom = groups.pop().unwrap();
    let params = groups.pop().unwrap();
    Ok(ModelState { model, params, mom, step })
}

fn write_str(f: &mut impl Write, s: &str) -> Result<()> {
    f.write_all(&(s.len() as u32).to_le_bytes())?;
    f.write_all(s.as_bytes())?;
    Ok(())
}

fn read_str(f: &mut impl Read) -> Result<String> {
    let len = read_u32(f)? as usize;
    if len > 1 << 16 {
        bail!("unreasonable string length in checkpoint");
    }
    let mut buf = vec![0u8; len];
    f.read_exact(&mut buf)?;
    String::from_utf8(buf).context("invalid utf8 in checkpoint")
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_state() -> ModelState {
        ModelState {
            model: "test".into(),
            params: vec![
                HostTensor::new(vec![2, 2], vec![1.0, -2.0, 3.5, 0.0]).to_literal().unwrap(),
                HostTensor::new(vec![3], vec![0.1, 0.2, 0.3]).to_literal().unwrap(),
            ],
            mom: vec![
                HostTensor::zeros(vec![2, 2]).to_literal().unwrap(),
                HostTensor::new(vec![3], vec![9.0, 8.0, 7.0]).to_literal().unwrap(),
            ],
            step: 1234,
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("isample_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ckpt");
        let state = tiny_state();
        save(&state, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.model, "test");
        assert_eq!(back.step, 1234);
        for (a, b) in state.params.iter().zip(&back.params) {
            assert_eq!(HostTensor::from_literal(a).unwrap(), HostTensor::from_literal(b).unwrap());
        }
        for (a, b) in state.mom.iter().zip(&back.mom) {
            assert_eq!(HostTensor::from_literal(a).unwrap(), HostTensor::from_literal(b).unwrap());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn state_checksum_tracks_content_and_survives_roundtrip() {
        let state = tiny_state();
        let base = state_checksum(&state).unwrap();
        assert_eq!(base, state_checksum(&tiny_state()).unwrap(), "checksum must be deterministic");

        let mut stepped = tiny_state();
        stepped.step += 1;
        assert_ne!(base, state_checksum(&stepped).unwrap());

        let mut perturbed = tiny_state();
        let mut t = HostTensor::from_literal(&perturbed.params[0]).unwrap();
        t.data[0] += 1e-7;
        perturbed.params[0] = t.to_literal().unwrap();
        assert_ne!(base, state_checksum(&perturbed).unwrap());

        let dir = std::env::temp_dir().join(format!("isample_ckpt_c_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.ckpt");
        save(&state, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(base, state_checksum(&back).unwrap(), "save/load must preserve the checksum");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("isample_ckpt_g_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}

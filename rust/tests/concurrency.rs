//! Bounded stress tests for the concurrency core, shaped for the dynamic
//! analyses CI runs on top of the ordinary test pass:
//!
//! * **Miri** (`cargo +nightly miri test -p isample --test concurrency`)
//!   checks the `WorkerPool::run` lifetime-erasing transmute and the shard
//!   cache's `Mutex`/`Condvar` in-flight protocol for undefined behavior.
//!   Sizes collapse to near-trivial under `cfg!(miri)` so the interpreter
//!   finishes in minutes.
//! * **ThreadSanitizer** (`RUSTFLAGS=-Zsanitizer=thread`) runs the same
//!   tests on real threads at full size and flags data races the type
//!   system cannot see.
//!
//! `ISAMPLE_STRESS=<k>` scales iteration counts (default 4, ignored under
//! Miri); every test stays bounded — no timing loops, no unbounded queues.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use isample::coordinator::cache::ScoreCache;
use isample::data::shard::{write_dataset, ShardedDataset};
use isample::data::synthetic::SyntheticImages;
use isample::data::Dataset;
use isample::runtime::pool::Task;
use isample::runtime::WorkerPool;

fn stress_scale() -> usize {
    if cfg!(miri) {
        return 1;
    }
    std::env::var("ISAMPLE_STRESS").ok().and_then(|v| v.parse().ok()).unwrap_or(4)
}

/// `WorkerPool::run` hands caller-borrowed closures to persistent threads
/// through a lifetime-erasing transmute; the completion barrier is what
/// makes that sound. Run many rounds of borrowed-chunk reductions so Miri
/// sees the borrow window open and close repeatedly and TSan sees the
/// handoff happen across real threads.
#[test]
fn pool_run_rounds_return_borrowed_chunk_sums_in_order() {
    let scale = stress_scale();
    let pool = WorkerPool::new(3);
    let data: Vec<u64> = (0..(64 * scale as u64)).collect();
    for round in 0..(2 * scale) {
        let chunks: Vec<&[u64]> = data.chunks(7 + round % 5).collect();
        let tasks: Vec<Task<u64>> =
            chunks.iter().map(|c| Box::new(move || c.iter().sum()) as Task<u64>).collect();
        let want: Vec<u64> = chunks.iter().map(|c| c.iter().sum()).collect();
        assert_eq!(pool.run(tasks), want, "round {round}");
    }
}

/// A panicking task must not leak borrows: the barrier collects every
/// completion first, then re-raises on the caller, and the pool keeps
/// serving afterwards.
#[test]
fn pool_panics_reraise_after_the_barrier_and_pool_stays_usable() {
    let pool = WorkerPool::new(2);
    for round in 0..3usize {
        let done = AtomicUsize::new(0);
        let tasks: Vec<Task<u32>> = (0..6usize)
            .map(|i| {
                let done = &done;
                Box::new(move || {
                    done.fetch_add(1, Ordering::Relaxed);
                    assert!(i != round, "task {i} exploding on purpose");
                    i as u32
                }) as Task<u32>
            })
            .collect();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| pool.run(tasks)));
        assert!(caught.is_err(), "round {round} must re-raise the task panic");
        // the barrier ran every task to completion before re-raising
        assert_eq!(done.load(Ordering::Relaxed), 6);
    }
    let ok: Vec<Task<u32>> = vec![Box::new(|| 11)];
    assert_eq!(pool.run(ok), vec![11]);
}

/// `submit` is fire-and-forget, but `Drop` only closes the channel — the
/// mpsc queue still delivers everything already sent, so every submitted
/// job runs before `drop` returns, and an advisory job's panic is
/// swallowed inside the wrapper instead of poisoning a worker.
#[test]
fn submitted_jobs_drain_before_drop_and_panics_are_swallowed() {
    let n = 16 * stress_scale();
    let count = Arc::new(AtomicUsize::new(0));
    let pool = WorkerPool::new(2);
    for i in 0..n {
        let count = Arc::clone(&count);
        pool.submit(move || {
            count.fetch_add(1, Ordering::Relaxed);
            assert!(i % 5 != 0, "advisory job {i} exploding on purpose");
        });
    }
    drop(pool);
    assert_eq!(count.load(Ordering::Relaxed), n);
}

/// A panicking advisory job must not poison later `run` rounds: the
/// `submit` wrapper swallows the unwind on the worker thread (counting it
/// in `panicked_jobs`), and the *same* thread then serves borrowed-task
/// rounds correctly. One worker + the FIFO job channel make this
/// deterministic without timing loops: the first `run` round's tasks queue
/// behind every submitted job, so its return is a barrier proving all the
/// panics already unwound and were contained.
#[test]
fn panicking_submitted_jobs_do_not_poison_later_run_rounds() {
    let scale = stress_scale();
    let n = 3 * scale;
    let pool = WorkerPool::new(1);
    let ran = Arc::new(AtomicUsize::new(0));
    for i in 0..n {
        let ran = Arc::clone(&ran);
        pool.submit(move || {
            ran.fetch_add(1, Ordering::Relaxed);
            panic!("advisory job {i} exploding on purpose");
        });
    }
    let data: Vec<u64> = (0..(16 * scale as u64)).collect();
    for round in 0..3usize {
        let chunks: Vec<&[u64]> = data.chunks(5).collect();
        let tasks: Vec<Task<u64>> =
            chunks.iter().map(|c| Box::new(move || c.iter().sum()) as Task<u64>).collect();
        let want: Vec<u64> = chunks.iter().map(|c| c.iter().sum()).collect();
        assert_eq!(pool.run(tasks), want, "round {round} after swallowed panics");
    }
    assert_eq!(pool.panicked_jobs(), n, "every advisory panic is counted, none escaped");
    assert_eq!(ran.load(Ordering::Relaxed), n);
}

/// Concurrent strided readers over a shard store with a resident budget of
/// one — constant eviction — plus background readahead racing the readers
/// through the `Mutex`/`Condvar` in-flight protocol. The determinism
/// contract says reordered IO never changes results, so every thread must
/// see bytes identical to the source dataset.
#[test]
fn shard_store_streams_identically_under_concurrent_eviction_and_readahead() {
    let d = 6usize;
    let n = if cfg!(miri) { 24 } else { 96 * stress_scale() };
    let ds = SyntheticImages::builder(d, 3).samples(n).seed(11).build();
    let dir = std::env::temp_dir().join(format!("isample_conc_{}_{n}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    write_dataset(&dir, &ds, 8).unwrap();
    let sharded = ShardedDataset::open(&dir).unwrap().with_resident_shards(1).with_readahead(2);
    let threads = if cfg!(miri) { 2 } else { 4 };
    std::thread::scope(|s| {
        for t in 0..threads {
            let (sharded, ds) = (&sharded, &ds);
            s.spawn(move || {
                let mut got = vec![0.0f32; d];
                let mut want = vec![0.0f32; d];
                // stride by thread id so readers pull different shards at once
                let mut i = t;
                while i < n {
                    assert_eq!(sharded.label(i), ds.label(i), "label {i}");
                    sharded.write_features(i, 0, &mut got);
                    ds.write_features(i, 0, &mut want);
                    assert_eq!(got, want, "features {i}");
                    i += threads;
                }
            });
        }
    });
    std::fs::remove_dir_all(&dir).ok();
}

fn score_of(i: usize, step: u64) -> f32 {
    (i as f32 + 1.0) * 0.25 + step as f32
}

/// The trainer owns its `ScoreCache` mutably, so the cache itself is not
/// synchronized; a future multi-process coordinator would share it behind
/// a lock. Check the two determinism-contract properties that sharing
/// relies on: the stale schedule is a pure function of (stamp table, step),
/// and records over disjoint position sets commute — any interleaving
/// lands in the same final state as the sequential reference.
#[test]
fn score_cache_records_commute_across_threads() {
    let steps = 3 * stress_scale() as u64;
    let n = 40usize;
    let threads = 4usize;
    let shared = Arc::new(Mutex::new(ScoreCache::new(n, Some(1))));
    let mut reference = ScoreCache::new(n, Some(1));
    let indices: Vec<usize> = (0..n).collect();

    for step in 0..steps {
        let stale = reference.stale_positions(&indices, step);
        let fresh: Vec<f32> = stale.iter().map(|&p| score_of(indices[p], step)).collect();
        reference.record(&indices, &stale, &fresh, step);

        let stale_shared = shared.lock().unwrap().stale_positions(&indices, step);
        assert_eq!(stale_shared, stale, "stale schedule must be a pure function of step");
        std::thread::scope(|s| {
            for t in 0..threads {
                let (shared, indices) = (&shared, &indices);
                let part: Vec<usize> =
                    stale_shared.iter().copied().filter(|&p| p % threads == t).collect();
                s.spawn(move || {
                    let fresh: Vec<f32> =
                        part.iter().map(|&p| score_of(indices[p], step)).collect();
                    shared.lock().unwrap().record(indices, &part, &fresh, step);
                });
            }
        });
        if !stale.is_empty() {
            assert_eq!(
                shared.lock().unwrap().lookup(&indices),
                reference.lookup(&indices),
                "step {step}: interleaved records diverged from the sequential reference"
            );
        }
    }
    // `reused` differs by construction (each thread's record sees the full
    // batch), but total re-scored rows must match exactly.
    assert_eq!(shared.lock().unwrap().counters().0, reference.counters().0);
}

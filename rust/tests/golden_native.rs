//! Golden determinism + resumability tests for the data-parallel native
//! training backend (ISSUE 3):
//!
//! * a fixed-seed 200-step Algorithm-1 run (uniform warmup → τ switch →
//!   presample/score/resample → weighted updates) pins one digest of its
//!   loss trajectory and one checksum of its final state, asserted
//!   identical across `--train-workers` 1, 2 and 4, and across repeated
//!   runs — any future nondeterministic reduction trips it immediately;
//! * a `runtime::checkpoint` save taken mid-run restores into the engine
//!   and continues **bit-identically** to the uninterrupted run, locking
//!   in resumability before longer-run features land.

use isample::coordinator::trainer::{Trainer, TrainerConfig};
use isample::data::synthetic::SyntheticImages;
use isample::data::Dataset;
use isample::runtime::checkpoint::{self, state_checksum};
use isample::runtime::{Backend, Layer, ModelState, NativeEngine, NativeModelSpec};
use isample::util::digest::digest_f64;
use isample::util::rng::SplitMix64;

fn gold_engine() -> NativeEngine {
    let mut ne = NativeEngine::new();
    ne.register(NativeModelSpec::mlp("gold", 32, 24, 4, 32, 64, vec![128]));
    ne
}

/// A conv+pool stack on the same data — the layer-IR twin of `gold`: the
/// `--train-workers` determinism guarantee is architecture-independent, so
/// the golden harness pins it for a non-MLP stack too (ISSUE 4).
fn conv_gold_engine() -> NativeEngine {
    let mut ne = NativeEngine::new();
    ne.register(NativeModelSpec::with_layers(
        "cgold",
        32,
        vec![
            Layer::Conv1d { in_ch: 2, out_ch: 6, kernel: 3, stride: 2 },
            Layer::Relu,
            Layer::GlobalAvgPool { channels: 6 },
            Layer::Dense { out_dim: 16 },
            Layer::Relu,
            Layer::Dense { out_dim: 4 },
        ],
        32,
        64,
        vec![128],
    ));
    ne
}

fn gold_split() -> isample::data::Split<SyntheticImages> {
    SyntheticImages::builder(32, 4).samples(2_048).test_samples(256).seed(11).split()
}

/// One fixed-seed 200-step upper-bound run at `train_workers`; returns
/// (loss-trajectory digest, final-state checksum).
fn golden_run(train_workers: usize) -> (u64, u64) {
    let ne = gold_engine();
    let split = gold_split();
    // τ ≥ 1 by construction, so τ_th = 0.95 switches importance sampling
    // on at step 2 deterministically — the weighted presample/resample
    // path (the one a nondeterministic reduction would corrupt) is then
    // exercised for 199 of the 200 steps.
    let cfg = TrainerConfig::upper_bound("gold")
        .with_steps(200)
        .with_presample(128)
        .with_tau_th(0.95)
        .with_seed(5)
        .with_score_workers(2)
        .with_train_workers(train_workers);
    let mut tr = Trainer::new(&ne, cfg).unwrap();
    let report = tr.run(&split.train, None).unwrap();
    assert_eq!(report.steps, 200);
    assert_eq!(report.is_switch_step, Some(2), "IS must engage right after warmup");
    let traj = digest_f64(report.log.rows.iter().map(|r| r.train_loss));
    (traj, state_checksum(&tr.state).unwrap())
}

#[test]
fn golden_trajectory_is_bit_identical_across_worker_counts() {
    let serial = golden_run(1);
    assert_eq!(golden_run(1), serial, "serial golden run must be reproducible");
    for workers in [2, 4] {
        let got = golden_run(workers);
        assert_eq!(
            got, serial,
            "{workers}-worker golden run diverged from serial \
             (trajectory {:#x} vs {:#x}, state {:#x} vs {:#x})",
            got.0, serial.0, got.1, serial.1
        );
    }
}

/// The conv variant of [`golden_run`]: a shorter fixed-seed upper-bound
/// run on the layer-IR conv stack; (trajectory digest, state checksum).
fn conv_golden_run(train_workers: usize) -> (u64, u64) {
    let ne = conv_gold_engine();
    let split = gold_split();
    let cfg = TrainerConfig::upper_bound("cgold")
        .with_steps(120)
        .with_presample(128)
        .with_tau_th(0.95)
        .with_seed(5)
        .with_score_workers(2)
        .with_train_workers(train_workers);
    let mut tr = Trainer::new(&ne, cfg).unwrap();
    let report = tr.run(&split.train, None).unwrap();
    assert_eq!(report.steps, 120);
    assert_eq!(report.is_switch_step, Some(2), "IS must engage right after warmup");
    let traj = digest_f64(report.log.rows.iter().map(|r| r.train_loss));
    (traj, state_checksum(&tr.state).unwrap())
}

#[test]
fn conv_golden_trajectory_is_bit_identical_across_worker_counts() {
    let serial = conv_golden_run(1);
    assert_eq!(conv_golden_run(1), serial, "serial conv golden run must be reproducible");
    for workers in [2, 4] {
        let got = conv_golden_run(workers);
        assert_eq!(got, serial, "{workers}-worker conv golden run diverged from serial");
    }
}

#[test]
fn checkpoint_resume_is_bit_identical_to_uninterrupted_run() {
    // Engine-level resumability: batches are keyed off the state's own
    // step counter, so a restored checkpoint draws exactly the batches
    // the uninterrupted run would have drawn from that step on.
    let ne = gold_engine().with_train_workers(4);
    let ds = gold_split().train;
    let b = 32;
    let step_batch = |step: u64| {
        let mut r = SplitMix64::tensor_stream(0xC0FFEE, step);
        let idx: Vec<usize> = (0..b).map(|_| r.below(ds.len())).collect();
        ds.batch(&idx, 0)
    };
    let w = vec![1.0f32; b];
    let advance = |state: &mut ModelState, steps: u64| {
        for _ in 0..steps {
            let (x, y) = step_batch(state.step);
            ne.train_step(state, &x, &y, &w, 0.1).unwrap();
        }
    };

    let mut full = ne.init_state("gold", 7).unwrap();
    advance(&mut full, 120);

    let mut first_half = ne.init_state("gold", 7).unwrap();
    advance(&mut first_half, 60);
    let dir = std::env::temp_dir().join(format!("isample_gold_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mid.ckpt");
    checkpoint::save(&first_half, &path).unwrap();
    let mut resumed = checkpoint::load(&path).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    assert_eq!(resumed.step, 60, "checkpoint must carry the step counter");
    // the restore really is a round trip, not the same object
    assert_eq!(state_checksum(&resumed).unwrap(), state_checksum(&first_half).unwrap());

    advance(&mut resumed, 60);
    assert_eq!(resumed.step, full.step);
    assert_eq!(
        state_checksum(&resumed).unwrap(),
        state_checksum(&full).unwrap(),
        "resumed trajectory diverged from the uninterrupted run"
    );
    // checksum equality is the contract; spot-check the raw tensors too
    assert_eq!(resumed.params_to_host().unwrap(), full.params_to_host().unwrap());
}

//! Property-based tests over coordinator invariants (no artifacts needed —
//! these cover the pure-rust layers under randomized inputs, with failing
//! seeds reported for replay).

use isample::coordinator::resample::{importance_weights, AliasSampler, CumulativeSampler};
use isample::coordinator::sampler::resample_from_scores;
use isample::coordinator::tau::{cost_model, TauEstimator};
use isample::data::sequence::PermutedSequences;
use isample::data::synthetic::SyntheticImages;
use isample::data::Dataset;
use isample::util::json::Json;
use isample::util::prop::{check, Gen};
use isample::util::rng::SplitMix64;
use isample::util::stats::normalize_probs;

#[test]
fn prop_alias_and_cdf_agree_in_distribution() {
    // Both backends sample the same target distribution: compare empirical
    // frequencies on small supports with many draws.
    check("alias==cdf in distribution", 25, |g: &mut Gen| {
        let n = g.usize_in(2..12);
        let scores: Vec<f32> = (0..n).map(|_| g.f32_in(0.0..1.0)).collect();
        let probs = normalize_probs(&scores);
        let draws = 40_000;
        let mut ca = vec![0f64; n];
        let mut cc = vec![0f64; n];
        let alias = AliasSampler::new(&probs);
        let cdf = CumulativeSampler::new(&probs);
        for _ in 0..draws {
            ca[alias.draw(&mut g.rng)] += 1.0;
            cc[cdf.draw(&mut g.rng)] += 1.0;
        }
        for i in 0..n {
            let (fa, fc) = (ca[i] / draws as f64, cc[i] / draws as f64);
            assert!(
                (fa - fc).abs() < 0.02,
                "backend disagreement at {i}: alias {fa} vs cdf {fc} (p={})",
                probs[i]
            );
        }
    });
}

#[test]
fn prop_weighted_estimator_is_unbiased() {
    // E_p[w f] == mean(f) for w = 1/(B p): the core unbiasedness identity
    // behind Eq. 2. Tested empirically over random score vectors.
    check("unbiased importance estimator", 10, |g: &mut Gen| {
        let n = g.usize_in(8..64);
        let scores: Vec<f32> = (0..n).map(|_| g.f32_in(0.01..2.0)).collect();
        let f: Vec<f64> = (0..n).map(|_| g.f64_in(-3.0..3.0)).collect();
        let probs = normalize_probs(&scores);
        let s = AliasSampler::new(&probs);
        let draws: Vec<usize> = s.sample(&mut g.rng, 300_000);
        let w = importance_weights(&probs, &draws);
        let est = draws.iter().zip(&w).map(|(&i, &wi)| wi as f64 * f[i]).sum::<f64>()
            / draws.len() as f64;
        let truth = f.iter().sum::<f64>() / n as f64;
        assert!((est - truth).abs() < 0.05, "estimate {est} vs {truth}");
    });
}

#[test]
fn prop_tau_threshold_consistency() {
    // guaranteed_speedup(B, b, tau) <=> tau > tau_threshold(B, b)
    check("cost model consistency", 300, |g: &mut Gen| {
        let b = g.usize_in(1..512);
        let big = b + g.usize_in(0..4096);
        let tau = g.f64_in(1.0..50.0);
        let th = cost_model::tau_threshold(big, b);
        assert_eq!(cost_model::guaranteed_speedup(big, b, tau), tau > th);
        // the threshold is always > 1 (scoring is never free)
        assert!(th > 1.0);
        // max variance reduction is positive whenever B > b
        if big > b {
            assert!(cost_model::max_variance_reduction(big, b) > 0.0);
        }
    });
}

#[test]
fn prop_tau_detects_concentration() {
    // one dominant score among n uniform ones must raise tau strictly
    check("tau detects outliers", 200, |g: &mut Gen| {
        let n = g.usize_in(4..256);
        let base = g.f32_in(0.01..1.0);
        let mut scores = vec![base; n];
        let uniform_tau = TauEstimator::tau_from_scores(&scores);
        scores[g.usize_in(0..n)] = base * g.f32_in(20.0..100.0);
        let concentrated_tau = TauEstimator::tau_from_scores(&scores);
        assert!((uniform_tau - 1.0).abs() < 1e-6);
        assert!(concentrated_tau > uniform_tau + 0.05, "tau {concentrated_tau}");
    });
}

#[test]
fn prop_resample_positions_within_presample() {
    check("resample positions bounded", 300, |g: &mut Gen| {
        let scores = g.scores(1..512);
        let b = g.usize_in(1..256);
        let use_alias = g.bool();
        let plan = resample_from_scores(&scores, b, &mut g.rng, use_alias);
        assert!(plan.positions.iter().all(|&p| p < scores.len()));
        assert!(plan.weights.iter().all(|&w| w.is_finite() && w > 0.0));
    });
}

#[test]
fn prop_dataset_determinism_and_bounds() {
    check("dataset generators deterministic", 60, |g: &mut Gen| {
        let d = g.usize_in(4..64);
        let c = g.usize_in(2..20);
        let n = g.usize_in(10..500);
        let seed = g.rng.next_u64();
        let ds = SyntheticImages::builder(d, c).samples(n).seed(seed).build();
        let i = g.usize_in(0..n);
        let mut a = vec![0.0f32; d];
        let mut b = vec![0.0f32; d];
        ds.write_features(i, 0, &mut a);
        ds.write_features(i, 0, &mut b);
        assert_eq!(a, b);
        assert!((0..c as i32).contains(&ds.label(i)));
        assert!(a.iter().all(|v| v.is_finite()));
    });
}

#[test]
fn prop_sequences_are_permutation_consistent() {
    check("sequence generator", 40, |g: &mut Gen| {
        let t = g.usize_in(8..128);
        let c = g.usize_in(2..10);
        let seed = g.rng.next_u64();
        let ds = PermutedSequences::builder(t, c).samples(64).seed(seed).build();
        let mut a = vec![0.0f32; t];
        ds.write_features(g.usize_in(0..64), 0, &mut a);
        assert!(a.iter().all(|v| v.is_finite()));
    });
}

#[test]
fn prop_json_roundtrips_numbers() {
    check("json number roundtrip", 500, |g: &mut Gen| {
        let x = g.f64_in(-1e9..1e9);
        let text = format!("{x:.9}");
        let v = Json::parse(&text).unwrap();
        let back = v.as_f64().unwrap();
        assert!((back - x).abs() <= 1e-8 * x.abs().max(1.0), "{x} vs {back}");
    });
}

#[test]
fn prop_json_never_panics_on_garbage() {
    // fuzz: random bytes must produce Ok or Err, never a panic
    check("json fuzz", 2000, |g: &mut Gen| {
        let len = g.usize_in(0..64);
        const CHARSET: &[u8] = b" {}[]\",:0123456789truefalsenul\\eE+-.";
        let bytes: Vec<u8> = (0..len).map(|_| CHARSET[g.usize_in(0..CHARSET.len())]).collect();
        let s = String::from_utf8_lossy(&bytes).to_string();
        let _ = Json::parse(&s);
    });
}

#[test]
fn prop_splitmix_streams_do_not_collide() {
    check("rng stream separation", 100, |g: &mut Gen| {
        let seed = g.rng.next_u64();
        let a: Vec<u64> = {
            let mut r = SplitMix64::tensor_stream(seed, 0);
            (0..16).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = SplitMix64::tensor_stream(seed, 1);
            (0..16).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a, b);
    });
}

#[test]
fn prop_normalize_probs_is_distribution() {
    check("normalize_probs", 500, |g: &mut Gen| {
        let scores = g.scores(1..512);
        let p = normalize_probs(&scores);
        assert_eq!(p.len(), scores.len());
        let total: f64 = p.iter().map(|&x| x as f64).sum();
        assert!((total - 1.0).abs() < 1e-4, "sum {total}");
        assert!(p.iter().all(|&x| (0.0..=1.0).contains(&x)));
    });
}

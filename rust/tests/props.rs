//! Property-based tests over coordinator invariants (no artifacts needed —
//! these cover the pure-rust layers under randomized inputs, with failing
//! seeds reported for replay — see `util::prop`'s module docs).
//!
//! The `prop_native_*` block pins the `--train-workers` determinism
//! contract: every data-parallel batch entry of the native backend must be
//! bit-identical to its serial run over randomized shapes, weights and
//! worker counts (1..=8), including the degenerate regimes — batch 1,
//! batch smaller than the worker count, and all-zero weight vectors.
//! ISSUE 4 extends the sweep beyond MLPs: randomized Conv1d and
//! EmbeddingBag layer stacks run the same properties (the chunk plans and
//! merges are architecture-independent), and a dominance property pins the
//! paper's Eq.-1/2 claim — the last-layer upper-bound score bounds the
//! true per-sample gradient norm up to a provable per-row constant — per
//! architecture. ISSUE 5 adds the block-kernel contract on top: the
//! block-batched forward/score/backward passes must be **bit-identical**
//! to the scalar reference walk across random shapes and block splits,
//! which is what carries every worker-count guarantee over to the
//! cache-blocked hot path. ISSUE 6 adds the score-cache determinism
//! property: the staleness refresh schedule must be a pure function of
//! (step, seed), never of the score values themselves. ISSUE 8 extends
//! that contract to the Fenwick resampler: the amortized rebuild schedule
//! (`resample::rebuild_policy`) must be a pure function of
//! (step, seed, dirty-count, pool size) — monotone in the dirty count,
//! never firing on a clean tree, always firing on a fully-dirty one.

use isample::coordinator::cache::ScoreCache;
use isample::coordinator::resample::{
    importance_weights, rebuild_policy, AliasSampler, CumulativeSampler, SamplerKind,
};
use isample::coordinator::sampler::resample_from_scores;
use isample::coordinator::tau::{cost_model, TauEstimator};
use isample::data::sequence::PermutedSequences;
use isample::data::synthetic::SyntheticImages;
use isample::data::Dataset;
use isample::runtime::checkpoint::state_checksum;
use isample::runtime::init::init_params;
use isample::runtime::tensor::HostTensor;
use isample::runtime::{
    set_forced_kernel_path, Backend, Layer, NativeEngine, NativeModelSpec, KERNEL_PATHS,
};
use isample::util::digest::digest_f32;
use isample::util::json::Json;
use isample::util::prop::{check, Gen};
use isample::util::rng::SplitMix64;
use isample::util::stats::normalize_probs;

/// A fresh engine with one random-geometry MLP and `workers` batch-compute
/// threads (the model's default batch sizes are irrelevant: the native
/// entries take any batch).
fn native_engine(d: usize, h: usize, c: usize, workers: usize) -> NativeEngine {
    let mut ne = NativeEngine::new().with_train_workers(workers);
    ne.register(NativeModelSpec::mlp("p", d, h, c, 8, 8, vec![]));
    ne
}

/// Random batch: features in [-1, 1), labels in 0..c.
fn native_batch(g: &mut Gen, n: usize, d: usize, c: usize) -> (HostTensor, Vec<i32>) {
    let data: Vec<f32> = (0..n * d).map(|_| g.f32_in(-1.0..1.0)).collect();
    let y: Vec<i32> = (0..n).map(|_| g.usize_in(0..c) as i32).collect();
    (HostTensor::new(vec![n, d], data), y)
}

/// Random dims + batch + worker count for the parallel-vs-serial props.
/// `n` spans 1..40, deliberately crossing batch == 1, batch < workers and
/// batch < chunk size; `workers` spans 2..=8 (1 is the reference side).
fn parallel_case(g: &mut Gen) -> (usize, usize, usize, usize, usize, u64) {
    let d = g.usize_in(2..24);
    let h = g.usize_in(2..16);
    let c = g.usize_in(2..8);
    let n = g.usize_in(1..40);
    let workers = g.usize_in(2..9);
    let seed = g.rng.next_u64();
    (d, h, c, n, workers, seed)
}

fn literal_digests(lits: &[xla::Literal]) -> Vec<u64> {
    lits.iter().map(|l| digest_f32(&HostTensor::from_literal(l).unwrap().data)).collect()
}

/// Random Conv1d spec: a `[t, ic]` signal through a strided conv + relu,
/// optionally global-avg-pooled, into a dense head.
fn conv_spec(g: &mut Gen) -> NativeModelSpec {
    let ic = g.usize_in(1..3);
    let t = g.usize_in(6..16);
    let kernel = g.usize_in(2..5);
    let stride = g.usize_in(1..3);
    let oc = g.usize_in(1..5);
    let c = g.usize_in(2..5);
    let mut layers = vec![Layer::Conv1d { in_ch: ic, out_ch: oc, kernel, stride }, Layer::Relu];
    if g.bool() {
        layers.push(Layer::GlobalAvgPool { channels: oc });
    }
    layers.push(Layer::Dense { out_dim: c });
    NativeModelSpec::with_layers("p", t * ic, layers, 8, 8, vec![])
}

/// Random EmbeddingBag sequence spec over `t` quantized scalars.
fn seq_spec(g: &mut Gen) -> NativeModelSpec {
    let t = g.usize_in(4..16);
    let vocab = g.usize_in(3..9);
    let dim = g.usize_in(2..7);
    let h = g.usize_in(2..8);
    let c = g.usize_in(2..5);
    let positional = g.bool();
    let gain = g.f32_in(1.0..8.0);
    let bag = Layer::EmbeddingBag { vocab, dim, lo: -1.0, hi: 1.0, positional, gain };
    let layers = vec![bag, Layer::Dense { out_dim: h }, Layer::Relu, Layer::Dense { out_dim: c }];
    NativeModelSpec::with_layers("p", t, layers, 8, 8, vec![])
}

#[test]
fn prop_native_train_step_parallel_is_bit_identical() {
    check("train_step parallel==serial", 15, |g: &mut Gen| {
        let (d, h, c, n, workers, seed) = parallel_case(g);
        let (x, y) = native_batch(g, n, d, c);
        let mut w = g.weights(n..n + 1);
        if g.rng.below(6) == 0 {
            w = vec![0.0; n]; // fully masked batch: update is decay-only
        }
        let lr = g.f32_in(0.01..0.3);
        let run = |workers: usize| {
            let ne = native_engine(d, h, c, workers);
            let mut state = ne.init_state("p", seed).unwrap();
            let out1 = ne.train_step(&mut state, &x, &y, &w, lr).unwrap();
            // a second step so momentum feeds back through the merge too
            let out2 = ne.train_step(&mut state, &x, &y, &w, lr).unwrap();
            (
                state_checksum(&state).unwrap(),
                out1.loss.to_bits(),
                digest_f32(&out1.loss_vec),
                digest_f32(&out1.scores),
                out2.loss.to_bits(),
            )
        };
        assert_eq!(run(1), run(workers), "n={n} workers={workers}");
    });
}

#[test]
fn prop_native_weighted_grad_and_svrg_parallel_is_bit_identical() {
    check("weighted_grad/svrg parallel==serial", 15, |g: &mut Gen| {
        let (d, h, c, n, workers, seed) = parallel_case(g);
        let (x, y) = native_batch(g, n, d, c);
        let mut w = g.weights(n..n + 1);
        if g.rng.below(6) == 0 {
            w = vec![0.0; n];
        }
        let run = |workers: usize| {
            let ne = native_engine(d, h, c, workers);
            let state = ne.init_state("p", seed).unwrap();
            let (grads, wloss) = ne.weighted_grad(&state, &x, &y, &w).unwrap();
            // the host-composed svrg_step runs two parallel `grad` calls;
            // reuse the weighted grads as the control-variate term mu
            let mut params = state.clone_params().unwrap();
            let snap = state.clone_params().unwrap();
            let sloss = ne.svrg_step("p", &mut params, &snap, &grads, &x, &y, 0.05).unwrap();
            (literal_digests(&grads), wloss.to_bits(), literal_digests(&params), sloss.to_bits())
        };
        assert_eq!(run(1), run(workers), "n={n} workers={workers}");
    });
}

#[test]
fn prop_native_grad_norms_and_eval_parallel_is_bit_identical() {
    check("grad_norms/eval parallel==serial", 15, |g: &mut Gen| {
        let (d, h, c, n, workers, seed) = parallel_case(g);
        let (x, y) = native_batch(g, n, d, c);
        let run = |workers: usize| {
            let ne = native_engine(d, h, c, workers);
            let state = ne.init_state("p", seed).unwrap();
            let gn = ne.grad_norms(&state, &x, &y).unwrap();
            let (sum_loss, correct) = ne.eval_metrics(&state, &x, &y).unwrap();
            (digest_f32(&gn), sum_loss.to_bits(), correct)
        };
        assert_eq!(run(1), run(workers), "n={n} workers={workers}");
    });
}

#[test]
fn prop_native_conv_and_seq_parallel_is_bit_identical() {
    // The train-workers determinism contract, on randomized non-MLP layer
    // stacks: every batch-level entry of a conv spec and a sequence spec
    // must be bit-identical to its serial run (same degenerate regimes as
    // the MLP props: batch 1, batch < workers, all-zero weights).
    check("conv/seq parallel==serial", 10, |g: &mut Gen| {
        for arch in 0..2 {
            let spec = if arch == 0 { conv_spec(g) } else { seq_spec(g) };
            let d = spec.model.in_dim();
            let c = spec.model.num_classes();
            let n = g.usize_in(1..40);
            let workers = g.usize_in(2..9);
            let seed = g.rng.next_u64();
            let (x, y) = native_batch(g, n, d, c);
            let mut w = g.weights(n..n + 1);
            if g.rng.below(6) == 0 {
                w = vec![0.0; n];
            }
            let lr = g.f32_in(0.01..0.3);
            let run = |workers: usize| {
                let mut ne = NativeEngine::new().with_train_workers(workers);
                ne.register(spec.clone());
                let mut state = ne.init_state("p", seed).unwrap();
                let out = ne.train_step(&mut state, &x, &y, &w, lr).unwrap();
                let (grads, wloss) = ne.weighted_grad(&state, &x, &y, &w).unwrap();
                let gn = ne.grad_norms(&state, &x, &y).unwrap();
                let (el, ec) = ne.eval_metrics(&state, &x, &y).unwrap();
                (
                    state_checksum(&state).unwrap(),
                    out.loss.to_bits(),
                    digest_f32(&out.scores),
                    literal_digests(&grads),
                    wloss.to_bits(),
                    digest_f32(&gn),
                    el.to_bits(),
                    ec,
                )
            };
            assert_eq!(run(1), run(workers), "arch {arch} n={n} workers={workers}");
        }
    });
}

#[test]
fn prop_block_kernels_match_the_scalar_reference_bitwise() {
    // The ISSUE 5 kernel-refactor contract: the block-batched passes
    // (`forward_block`/`scores_block`/`backward_block`, built on
    // `runtime::kernels`) must be **bit-identical** to the canonical
    // scalar row walk for every architecture, batch size and internal
    // block split — including rows whose gradient coefficient is exactly
    // zero (the scalar walk skips them; the block walk includes their
    // ±0.0 contributions, which must be bitwise invisible). This is what
    // lets the PR 3/4 worker-count bit-identity guarantees carry over to
    // the kernel path by construction.
    check("block kernels == scalar walk", 10, |g: &mut Gen| {
        let mlp = {
            let d = g.usize_in(2..20);
            let h = g.usize_in(2..12);
            let c = g.usize_in(2..6);
            NativeModelSpec::mlp("p", d, h, c, 8, 8, vec![])
        };
        for spec in [mlp, conv_spec(g), seq_spec(g)] {
            let m = spec.model.clone();
            let params = init_params(g.rng.next_u64(), &m.param_specs());
            let (d, c) = (m.in_dim(), m.num_classes());
            let n = g.usize_in(1..40);
            let (x, y) = native_batch(g, n, d, c);
            let coeff: Vec<f32> = (0..n)
                .map(|_| if g.rng.below(5) == 0 { 0.0 } else { g.f32_in(0.0..2.0) })
                .collect();

            // canonical scalar reference: row-by-row walk with cf==0 skip
            let mut s = m.scratch();
            let mut grads_ref = m.zero_grads();
            let mut loss_ref = Vec::with_capacity(n);
            let mut score_ref = Vec::with_capacity(n);
            for r in 0..n {
                let xr = x.row(r);
                let (l, u) = m.row_scores(&params, xr, y[r], &mut s);
                loss_ref.push(l);
                score_ref.push(u);
                if coeff[r] != 0.0 {
                    let yy = m.clamp_label(y[r]);
                    let gz = s.probs_mut();
                    gz[yy] -= 1.0;
                    for gv in gz.iter_mut() {
                        *gv *= coeff[r];
                    }
                    m.backward_row(&params, xr, &mut s, &mut grads_ref);
                }
            }

            // block path, split into random-size blocks (1..=32 rows).
            // Run once per dispatch path — the ISSUE 9 SIMD tiles must be
            // bit-identical to the scalar tiles, so both legs compare
            // against the same scalar-row reference. (Forcing the global
            // path is process-wide, but that is harmless to concurrent
            // tests precisely because the paths are bit-identical.)
            for path in KERNEL_PATHS {
                set_forced_kernel_path(Some(path));
                let mut bs = m.block_scratch();
                let mut grads = m.zero_grads();
                let mut loss = vec![0.0f32; n];
                let mut score = vec![0.0f32; n];
                let mut start = 0usize;
                while start < n {
                    let rows = g.usize_in(1..(n - start + 1).min(33));
                    let xb = &x.data[start * d..(start + rows) * d];
                    m.scores_block(
                        &params,
                        xb,
                        &y[start..start + rows],
                        rows,
                        &mut bs,
                        &mut loss[start..start + rows],
                        &mut score[start..start + rows],
                    );
                    let pm = bs.probs_mut();
                    for r in 0..rows {
                        let yy = m.clamp_label(y[start + r]);
                        let gz = &mut pm[r * c..(r + 1) * c];
                        gz[yy] -= 1.0;
                        for gv in gz.iter_mut() {
                            *gv *= coeff[start + r];
                        }
                    }
                    m.backward_block(&params, xb, rows, &mut bs, &mut grads);
                    start += rows;
                }
                let pname = path.name();
                assert_eq!(loss, loss_ref, "losses diverged (n={n}, path={pname})");
                assert_eq!(score, score_ref, "scores diverged (n={n}, path={pname})");
                assert_eq!(grads, grads_ref, "gradients diverged (n={n}, path={pname})");
            }
            set_forced_kernel_path(None);
        }
    });
}

#[test]
fn prop_upper_bound_dominates_true_grad_norm_per_architecture() {
    // Paper Eq. 1-2 / Eq. 20: for a fixed state the last-layer score
    // ‖probs − onehot‖ bounds the per-sample gradient norm up to an
    // architecture-dependent constant. The layer IR computes a provable
    // per-row constant ρ, so the exact norm must sit between the score
    // itself (the head-bias gradient alone) and ρ x score — for MLP, conv
    // and sequence stacks alike.
    check("score dominance", 8, |g: &mut Gen| {
        let mlp = {
            let d = g.usize_in(2..16);
            let h = g.usize_in(2..12);
            let c = g.usize_in(2..6);
            NativeModelSpec::mlp("p", d, h, c, 8, 8, vec![])
        };
        for spec in [mlp, conv_spec(g), seq_spec(g)] {
            let model = spec.model.clone();
            let (d, c) = (model.in_dim(), model.num_classes());
            let mut ne = NativeEngine::new();
            ne.register(spec);
            let state = ne.init_state("p", g.rng.next_u64()).unwrap();
            let p = state.params_to_host().unwrap();
            let n = g.usize_in(1..24);
            let (x, y) = native_batch(g, n, d, c);
            let gn = ne.grad_norms(&state, &x, &y).unwrap();
            let (_, ub) = ne.fwd_scores(&state, &x, &y).unwrap();
            for r in 0..n {
                let rho = model.grad_norm_bound_factor(&p, x.row(r)).unwrap();
                let (gnr, ubr) = (gn[r] as f64, ub[r] as f64);
                assert!(gnr >= ubr - 1e-5, "row {r}: gn {gnr} < score {ubr}");
                assert!(gnr <= rho * ubr * 1.001 + 1e-6, "row {r}: gn {gnr} > {rho} x {ubr}");
            }
        }
    });
}

#[test]
fn prop_alias_and_cdf_agree_in_distribution() {
    // Both backends sample the same target distribution: compare empirical
    // frequencies on small supports with many draws.
    check("alias==cdf in distribution", 25, |g: &mut Gen| {
        let n = g.usize_in(2..12);
        let scores: Vec<f32> = (0..n).map(|_| g.f32_in(0.0..1.0)).collect();
        let probs = normalize_probs(&scores);
        let draws = 40_000;
        let mut ca = vec![0f64; n];
        let mut cc = vec![0f64; n];
        let alias = AliasSampler::new(&probs);
        let cdf = CumulativeSampler::new(&probs);
        for _ in 0..draws {
            ca[alias.draw(&mut g.rng)] += 1.0;
            cc[cdf.draw(&mut g.rng)] += 1.0;
        }
        for i in 0..n {
            let (fa, fc) = (ca[i] / draws as f64, cc[i] / draws as f64);
            assert!(
                (fa - fc).abs() < 0.02,
                "backend disagreement at {i}: alias {fa} vs cdf {fc} (p={})",
                probs[i]
            );
        }
    });
}

#[test]
fn prop_weighted_estimator_is_unbiased() {
    // E_p[w f] == mean(f) for w = 1/(B p): the core unbiasedness identity
    // behind Eq. 2. Tested empirically over random score vectors.
    check("unbiased importance estimator", 10, |g: &mut Gen| {
        let n = g.usize_in(8..64);
        let scores: Vec<f32> = (0..n).map(|_| g.f32_in(0.01..2.0)).collect();
        let f: Vec<f64> = (0..n).map(|_| g.f64_in(-3.0..3.0)).collect();
        let probs = normalize_probs(&scores);
        let s = AliasSampler::new(&probs);
        let draws: Vec<usize> = s.sample(&mut g.rng, 300_000);
        let w = importance_weights(&probs, &draws);
        let est = draws.iter().zip(&w).map(|(&i, &wi)| wi as f64 * f[i]).sum::<f64>()
            / draws.len() as f64;
        let truth = f.iter().sum::<f64>() / n as f64;
        assert!((est - truth).abs() < 0.05, "estimate {est} vs {truth}");
    });
}

#[test]
fn prop_tau_threshold_consistency() {
    // guaranteed_speedup(B, b, tau) <=> tau > tau_threshold(B, b)
    check("cost model consistency", 300, |g: &mut Gen| {
        let b = g.usize_in(1..512);
        let big = b + g.usize_in(0..4096);
        let tau = g.f64_in(1.0..50.0);
        let th = cost_model::tau_threshold(big, b);
        assert_eq!(cost_model::guaranteed_speedup(big, b, tau), tau > th);
        // the threshold is always > 1 (scoring is never free)
        assert!(th > 1.0);
        // max variance reduction is positive whenever B > b
        if big > b {
            assert!(cost_model::max_variance_reduction(big, b) > 0.0);
        }
    });
}

#[test]
fn prop_tau_detects_concentration() {
    // one dominant score among n uniform ones must raise tau strictly
    check("tau detects outliers", 200, |g: &mut Gen| {
        let n = g.usize_in(4..256);
        let base = g.f32_in(0.01..1.0);
        let mut scores = vec![base; n];
        let uniform_tau = TauEstimator::tau_from_scores(&scores);
        scores[g.usize_in(0..n)] = base * g.f32_in(20.0..100.0);
        let concentrated_tau = TauEstimator::tau_from_scores(&scores);
        assert!((uniform_tau - 1.0).abs() < 1e-6);
        assert!(concentrated_tau > uniform_tau + 0.05, "tau {concentrated_tau}");
    });
}

#[test]
fn prop_resample_positions_within_presample() {
    check("resample positions bounded", 300, |g: &mut Gen| {
        let scores = g.scores(1..512);
        let b = g.usize_in(1..256);
        let kind =
            [SamplerKind::Alias, SamplerKind::Cumulative, SamplerKind::Fenwick][g.usize_in(0..3)];
        let plan = resample_from_scores(&scores, b, &mut g.rng, kind);
        assert!(plan.positions.iter().all(|&p| p < scores.len()));
        assert!(plan.weights.iter().all(|&w| w.is_finite() && w > 0.0));
    });
}

#[test]
fn prop_rebuild_schedule_is_pure_and_monotone_in_dirty_count() {
    // ISSUE 8 determinism contract: the Fenwick amortized-rebuild decision
    // is a pure function of (step, seed, dirty, n) — same inputs, same
    // answer, regardless of score values or call history — and is monotone
    // in the dirty count: more staleness never flips rebuild -> update.
    // The endpoints are pinned: a clean tree never rebuilds, a fully
    // dirty tree always does.
    check("rebuild schedule pure + monotone", 300, |g: &mut Gen| {
        let n = g.usize_in(1..1 << 20);
        let step = g.rng.next_u64();
        let seed = g.rng.next_u64();
        let dirty = g.usize_in(0..n + 1);

        // pure: re-asking must give the same answer
        let d = rebuild_policy::should_rebuild(step, seed, dirty, n);
        assert_eq!(d, rebuild_policy::should_rebuild(step, seed, dirty, n));

        // endpoints
        assert!(!rebuild_policy::should_rebuild(step, seed, 0, n), "rebuilt a clean tree");
        assert!(rebuild_policy::should_rebuild(step, seed, n, n), "fully dirty must rebuild");

        // monotone in dirty for fixed (step, seed, n)
        if dirty > 0 {
            let less = rebuild_policy::should_rebuild(step, seed, dirty - 1, n);
            assert!(d || !less, "decision flipped true->false from dirty {} to {dirty}", dirty - 1);
        }
        if dirty < n {
            let more = rebuild_policy::should_rebuild(step, seed, dirty + 1, n);
            assert!(more || !d, "decision flipped true->false from dirty {dirty} to {}", dirty + 1);
        }

        // the periodic forced rebuild fires on every seed-offset step
        // (the decision depends on the step only through step % PERIOD)
        if dirty > 0 {
            let offset_step = seed % rebuild_policy::REBUILD_PERIOD;
            assert!(rebuild_policy::should_rebuild(offset_step, seed, dirty, n));
        }
    });
}

#[test]
fn prop_dataset_determinism_and_bounds() {
    check("dataset generators deterministic", 60, |g: &mut Gen| {
        let d = g.usize_in(4..64);
        let c = g.usize_in(2..20);
        let n = g.usize_in(10..500);
        let seed = g.rng.next_u64();
        let ds = SyntheticImages::builder(d, c).samples(n).seed(seed).build();
        let i = g.usize_in(0..n);
        let mut a = vec![0.0f32; d];
        let mut b = vec![0.0f32; d];
        ds.write_features(i, 0, &mut a);
        ds.write_features(i, 0, &mut b);
        assert_eq!(a, b);
        assert!((0..c as i32).contains(&ds.label(i)));
        assert!(a.iter().all(|v| v.is_finite()));
    });
}

#[test]
fn prop_sequences_are_permutation_consistent() {
    check("sequence generator", 40, |g: &mut Gen| {
        let t = g.usize_in(8..128);
        let c = g.usize_in(2..10);
        let seed = g.rng.next_u64();
        let ds = PermutedSequences::builder(t, c).samples(64).seed(seed).build();
        let mut a = vec![0.0f32; t];
        ds.write_features(g.usize_in(0..64), 0, &mut a);
        assert!(a.iter().all(|v| v.is_finite()));
    });
}

#[test]
fn prop_json_roundtrips_numbers() {
    check("json number roundtrip", 500, |g: &mut Gen| {
        let x = g.f64_in(-1e9..1e9);
        let text = format!("{x:.9}");
        let v = Json::parse(&text).unwrap();
        let back = v.as_f64().unwrap();
        assert!((back - x).abs() <= 1e-8 * x.abs().max(1.0), "{x} vs {back}");
    });
}

#[test]
fn prop_json_never_panics_on_garbage() {
    // fuzz: random bytes must produce Ok or Err, never a panic
    check("json fuzz", 2000, |g: &mut Gen| {
        let len = g.usize_in(0..64);
        const CHARSET: &[u8] = b" {}[]\",:0123456789truefalsenul\\eE+-.";
        let bytes: Vec<u8> = (0..len).map(|_| CHARSET[g.usize_in(0..CHARSET.len())]).collect();
        let s = String::from_utf8_lossy(&bytes).to_string();
        let _ = Json::parse(&s);
    });
}

#[test]
fn prop_splitmix_streams_do_not_collide() {
    check("rng stream separation", 100, |g: &mut Gen| {
        let seed = g.rng.next_u64();
        let a: Vec<u64> = {
            let mut r = SplitMix64::tensor_stream(seed, 0);
            (0..16).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = SplitMix64::tensor_stream(seed, 1);
            (0..16).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a, b);
    });
}

#[test]
fn prop_normalize_probs_is_distribution() {
    check("normalize_probs", 500, |g: &mut Gen| {
        let scores = g.scores(1..512);
        let p = normalize_probs(&scores);
        assert_eq!(p.len(), scores.len());
        let total: f64 = p.iter().map(|&x| x as f64).sum();
        assert!((total - 1.0).abs() < 1e-4, "sum {total}");
        assert!(p.iter().all(|&x| (0.0..=1.0).contains(&x)));
    });
}

#[test]
fn prop_refresh_schedule_depends_only_on_step_and_seed() {
    // ISSUE 6 determinism contract: the score cache's refresh schedule is
    // a pure function of the (seeded) index stream and the step counter —
    // replaying one stream with completely different recorded score VALUES
    // must produce identical stale sets on every cycle.
    check("refresh schedule score-independent", 50, |g: &mut Gen| {
        let n = g.usize_in(4..200);
        let budget = if g.bool() { Some(g.usize_in(0..12) as u64) } else { None };
        let seed = g.rng.next_u64();
        let cycles = g.usize_in(2..10);
        let batch = g.usize_in(1..24);
        let mut rng = SplitMix64::new(seed);
        let steps: Vec<u64> = (0..cycles).map(|c| 1 + 3 * c as u64).collect();
        let batches: Vec<Vec<usize>> =
            (0..cycles).map(|_| (0..batch).map(|_| rng.below(n)).collect()).collect();

        let schedule = |salt: f32| -> Vec<Vec<usize>> {
            let mut cache = ScoreCache::new(n, budget);
            batches
                .iter()
                .zip(&steps)
                .map(|(idx, &step)| {
                    let stale = cache.stale_positions(idx, step);
                    let fresh: Vec<f32> = stale.iter().map(|&p| salt + idx[p] as f32).collect();
                    cache.record(idx, &stale, &fresh, step);
                    stale
                })
                .collect()
        };
        let a = schedule(0.25);
        assert_eq!(a, schedule(1.0e6), "refresh schedule depended on score values");
        if budget.is_none() {
            // unlimited budget: every cycle re-scores every position
            for (stale, idx) in a.iter().zip(&batches) {
                assert_eq!(stale, &(0..idx.len()).collect::<Vec<_>>());
            }
        }
    });
}

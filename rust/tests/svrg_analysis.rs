//! Integration tests for the SVRG baselines and the Fig-1/Fig-2 analyses.
//! Like `integration.rs`, these run on the PJRT engine when AOT artifacts
//! are present and on the native CPU backend otherwise — `cargo test`
//! exercises them for real in every build.

use isample::analysis::correlation::correlation_at_state;
use isample::analysis::variance::{measure_at_state, VarianceConfig};
use isample::baselines::svrg::{run_svrg, SvrgConfig};
use isample::coordinator::trainer::{Trainer, TrainerConfig};
use isample::data::synthetic::SyntheticImages;
use isample::runtime::{Backend, Engine, NativeEngine};

const ARTIFACTS_DIR: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn with_backend(f: impl FnOnce(&dyn Backend)) {
    thread_local! {
        static BACKEND: Box<dyn Backend> =
            if std::path::Path::new(ARTIFACTS_DIR).join("manifest.json").exists() {
                Box::new(
                    Engine::load(ARTIFACTS_DIR)
                        .expect("artifacts present but engine failed to load"),
                )
            } else {
                Box::new(NativeEngine::with_default_models())
            };
    }
    BACKEND.with(|b| f(b.as_ref()));
}

fn mlp_split() -> isample::data::Split<SyntheticImages> {
    SyntheticImages::builder(64, 10).samples(2_048).test_samples(1_024).seed(4).split()
}

#[test]
fn svrg_takes_steps_and_stays_finite() {
    with_backend(|backend| {
        let split = mlp_split();
        let mut cfg = SvrgConfig::svrg("mlp10");
        cfg.inner_steps = 10;
        cfg.max_outer = Some(2);
        let report = run_svrg(backend, &cfg, &split.train, Some(&split.test)).unwrap();
        assert_eq!(report.steps, 20);
        assert!(report.final_train_loss.is_finite());
        assert!(report.final_test_err.is_finite());
    });
}

#[test]
fn scsg_grows_its_large_batch_and_runs() {
    with_backend(|backend| {
        let split = mlp_split();
        let mut cfg = SvrgConfig::scsg("mlp10", 256);
        cfg.max_outer = Some(3);
        let report = run_svrg(backend, &cfg, &split.train, None).unwrap();
        // inner steps: 256/128=2, then 384/128=3, then 576/128=4
        assert_eq!(report.steps, 2 + 3 + 4);
    });
}

#[test]
fn katyusha_coupling_runs_and_learns() {
    with_backend(|backend| {
        let split = mlp_split();
        let mut cfg = SvrgConfig::katyusha("mlp10");
        cfg.inner_steps = 15;
        cfg.max_outer = Some(2);
        cfg.lr = 0.02;
        let report = run_svrg(backend, &cfg, &split.train, None).unwrap();
        assert_eq!(report.steps, 30);
        assert!(report.final_train_loss.is_finite());
        let first = report.log.rows.first().unwrap().train_loss;
        assert!(
            report.final_train_loss < first * 1.2,
            "katyusha diverged: {first} -> {}",
            report.final_train_loss
        );
    });
}

#[test]
fn variance_analysis_shows_upper_bound_beats_loss_late_in_training() {
    with_backend(|backend| {
        let split = mlp_split();
        // train a while so scores disperse (paper: late-stage behaviour)
        let cfg = TrainerConfig::uniform("mlp10").with_steps(400);
        let mut tr = Trainer::new(backend, cfg).unwrap();
        let _ = tr.run(&split.train, None).unwrap();

        let vcfg = VarianceConfig { presample: 1024, batch: 128, repeats: 5, seed: 3 };
        let p = measure_at_state(backend, &tr.state, &split.train, &vcfg, 400).unwrap();
        assert_eq!(p.uniform, 1.0);
        // the paper's core claims, in miniature:
        assert!(
            p.upper_bound < 1.0,
            "upper-bound must reduce variance vs uniform: {}",
            p.upper_bound
        );
        assert!(
            p.upper_bound <= p.grad_norm * 1.35,
            "upper-bound ({}) should be close to the grad-norm oracle ({})",
            p.upper_bound,
            p.grad_norm
        );
        assert!(p.tau >= 1.0);
    });
}

#[test]
fn correlation_analysis_upper_bound_dominates_loss() {
    with_backend(|backend| {
        let split = mlp_split();
        let cfg = TrainerConfig::uniform("mlp10").with_steps(400);
        let mut tr = Trainer::new(backend, cfg).unwrap();
        let _ = tr.run(&split.train, None).unwrap();

        let rep = correlation_at_state(backend, &tr.state, &split.train, 2048, 1024, 7).unwrap();
        assert_eq!(rep.points.len(), 2048);
        // §4.1: the upper bound's probabilities track the gradient-norm
        // probabilities far better than the loss's do.
        assert!(
            rep.sse_upper_bound < rep.sse_loss,
            "SSE(ub) {} !< SSE(loss) {}",
            rep.sse_upper_bound,
            rep.sse_loss
        );
        assert!(
            rep.spearman_upper_bound > rep.spearman_loss,
            "spearman(ub) {} !> spearman(loss) {}",
            rep.spearman_upper_bound,
            rep.spearman_loss
        );
        assert!(rep.spearman_upper_bound > 0.9, "{}", rep.spearman_upper_bound);
    });
}

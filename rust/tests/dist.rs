//! Golden determinism + fault-tolerance tests for the distributed engine
//! (ISSUE 10 tentpole): the fixed-seed 200-step Algorithm-1 run pinned by
//! `golden_native.rs` must produce the *same* loss-trajectory digest and
//! final-state checksum when its chunk work is farmed out to 1, 2 or 4
//! workers over the wire protocol — and when deterministic fault injection
//! kills, stalls and silences workers mid-run. Faults may change
//! scheduling (who computes which chunk, and when); they may never change
//! results (fixed chunk plan + ordered merge).

use std::path::Path;
use std::sync::OnceLock;

use isample::coordinator::trainer::{Trainer, TrainerConfig};
use isample::data::synthetic::SyntheticImages;
use isample::dist::{DistEngine, FaultPlan, ENV_FAULT_PLAN};
use isample::runtime::checkpoint::state_checksum;
use isample::runtime::{Backend, HostTensor, NativeEngine, NativeModelSpec};
use isample::util::digest::digest_f64;

fn gold_engine() -> NativeEngine {
    let mut ne = NativeEngine::new();
    ne.register(NativeModelSpec::mlp("gold", 32, 24, 4, 32, 64, vec![128]));
    ne
}

fn gold_split() -> isample::data::Split<SyntheticImages> {
    SyntheticImages::builder(32, 4).samples(2_048).test_samples(256).seed(11).split()
}

fn gold_config() -> TrainerConfig {
    TrainerConfig::upper_bound("gold")
        .with_steps(200)
        .with_presample(128)
        .with_tau_th(0.95)
        .with_seed(5)
        .with_score_workers(2)
        .with_train_workers(1)
}

/// Run the pinned 200-step golden config on `backend`; returns the
/// (loss-trajectory digest, final-state checksum) fingerprint plus the
/// operational events the run logged.
fn fingerprint(backend: &dyn Backend) -> ((u64, u64), Vec<(u64, String)>) {
    let split = gold_split();
    let mut tr = Trainer::new(backend, gold_config()).unwrap();
    let report = tr.run(&split.train, None).unwrap();
    assert_eq!(report.steps, 200);
    assert_eq!(report.is_switch_step, Some(2), "IS must engage right after warmup");
    let traj = digest_f64(report.log.rows.iter().map(|r| r.train_loss));
    ((traj, state_checksum(&tr.state).unwrap()), report.log.events)
}

/// The in-process serial reference, computed once per test binary.
fn serial_golden() -> (u64, u64) {
    static SERIAL: OnceLock<(u64, u64)> = OnceLock::new();
    *SERIAL.get_or_init(|| fingerprint(&gold_engine()).0)
}

/// Golden run over `workers` in-process thread workers (same wire
/// protocol, coordinator and chunk leases as process mode) with the given
/// fault plan; returns the fingerprint and events.
fn dist_run(workers: usize, lease_ms: u64, plan: &str) -> ((u64, u64), Vec<(u64, String)>) {
    let engine = DistEngine::new(gold_engine(), lease_ms).unwrap();
    let plan = FaultPlan::parse(plan).unwrap();
    engine.spawn_thread_workers(workers, &plan);
    engine.wait_for_workers(workers).unwrap();
    fingerprint(&engine)
}

#[test]
fn dist_golden_matches_serial_w1() {
    assert_eq!(dist_run(1, 2_000, "").0, serial_golden());
}

#[test]
fn dist_golden_matches_serial_w2() {
    assert_eq!(dist_run(2, 2_000, "").0, serial_golden());
}

#[test]
fn dist_golden_matches_serial_w4() {
    assert_eq!(dist_run(4, 2_000, "").0, serial_golden());
}

/// Deterministic fault injection: a worker killed mid-run, another stalled
/// past nothing (50ms, within the lease), a third silently dropping a
/// reply (which *must* blow the lease and requeue). The digest may not
/// move by a single bit.
#[test]
fn dist_golden_survives_fault_injection() {
    let (got, _) = dist_run(4, 250, "kill@80:1:0,stall@40:2:1:50,drop@120:3:0");
    assert_eq!(got, serial_golden(), "faults changed the trajectory — determinism broken");
}

/// Degradation ladder, bottom rung: the only worker dies and the
/// coordinator finishes the run on the in-process engine, logging the
/// transition — and the digest still matches serial exactly.
#[test]
fn all_workers_lost_falls_back_in_process() {
    let (got, events) = dist_run(1, 250, "kill@50:0:0");
    assert_eq!(got, serial_golden());
    assert!(
        events.iter().any(|(_, m)| m.contains("all remote workers lost")),
        "degradation to in-process compute must be logged; events: {events:?}"
    );
}

/// CI's env-driven fault leg: when `ISAMPLE_FAULT_PLAN` is set, rerun the
/// golden under that plan and require the fault-free digest. A plain
/// `cargo test` (no env) skips — the deterministic plans above already
/// cover the library-level contract.
#[test]
fn ci_env_fault_plan_reproduces_digest() {
    let Ok(spec) = std::env::var(ENV_FAULT_PLAN) else {
        return;
    };
    let (got, _) = dist_run(2, 500, &spec);
    assert_eq!(got, serial_golden(), "fault plan {spec:?} changed the golden digest");
}

/// A deterministic pseudo-random batch sized for `model` on `backend`.
fn demo_batch(backend: &dyn Backend, model: &str, n: usize) -> (HostTensor, Vec<i32>) {
    let info = backend.model_info(model).unwrap();
    let d = info.feature_dim;
    let mut x = vec![0.0f32; n * d];
    for (i, v) in x.iter_mut().enumerate() {
        *v = ((i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 40) as f32 / 16_777_216.0;
    }
    let y = (0..n).map(|i| (i % info.num_classes) as i32).collect();
    (HostTensor::new(vec![n, d], x), y)
}

/// Real subprocess workers (the `isample worker` mode CI's dist-smoke
/// exercises): two processes serve train/score/eval/grad-norm chunks, one
/// is killed by fault injection on the second step, and every output stays
/// bit-identical to a pure in-process engine.
#[test]
fn process_workers_are_bit_identical_and_survive_kill() {
    let reference = NativeEngine::with_default_models();
    let dist = DistEngine::new(NativeEngine::with_default_models(), 1_500).unwrap();
    let exe = Path::new(env!("CARGO_BIN_EXE_isample"));
    let plan = FaultPlan::parse("kill@1:1:0").unwrap();
    dist.spawn_process_workers(2, exe, &plan).unwrap();
    dist.wait_for_workers(2).unwrap();

    let model = "mlp10";
    let (x, y) = demo_batch(&reference, model, 48);
    let w = vec![1.0f32; 48];
    let mut rs = reference.init_state(model, 9).unwrap();
    let mut ds = dist.init_state(model, 9).unwrap();
    for step in 0..4 {
        let a = reference.train_step(&mut rs, &x, &y, &w, 0.05).unwrap();
        let b = dist.train_step(&mut ds, &x, &y, &w, 0.05).unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {step} loss");
        assert_eq!(a.loss_vec, b.loss_vec, "step {step} loss vector");
        assert_eq!(a.scores, b.scores, "step {step} scores");
    }
    assert_eq!(
        state_checksum(&rs).unwrap(),
        state_checksum(&ds).unwrap(),
        "post-kill parameter state diverged from in-process"
    );
    assert_eq!(reference.fwd_scores(&rs, &x, &y).unwrap(), dist.fwd_scores(&ds, &x, &y).unwrap());
    assert_eq!(
        reference.eval_metrics(&rs, &x, &y).unwrap(),
        dist.eval_metrics(&ds, &x, &y).unwrap()
    );
    assert_eq!(reference.grad_norms(&rs, &x, &y).unwrap(), dist.grad_norms(&ds, &x, &y).unwrap());
}

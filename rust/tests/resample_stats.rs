//! Statistical coverage for `coordinator::resample` plus the sharded
//! scoring determinism contract — none of it needs AOT artifacts.
//!
//! * The three resampling backends ([`AliasSampler`], [`CumulativeSampler`],
//!   [`FenwickSampler`]) must recover the same empirical distribution
//!   (chi-square tolerance) on a fixed-seed SplitMix64 stream.
//! * A Fenwick tree maintained by `update()` must be **bitwise** identical
//!   to one rebuilt from scratch on the same leaves — total mass and the
//!   full draw stream (the partial-update determinism contract).
//! * Parallel (`ScoreBackend::Threaded`) and serial scoring must produce
//!   bit-identical score vectors, and therefore bit-identical sampled
//!   indices for a fixed seed.
//! * The staleness-aware `ScoreCache` (ISSUE 6) serves the recorded bits
//!   verbatim inside the refresh budget, rebuilds the exact same
//!   distribution at refresh boundaries (deterministic scorer, unchanged
//!   rows), and sampling from the cached distribution stays on the same
//!   distribution the fresh scores define (chi-square).

use isample::coordinator::cache::ScoreCache;
use isample::coordinator::resample::{AliasSampler, CumulativeSampler, FenwickSampler, SamplerKind};
use isample::coordinator::sampler::resample_from_scores;
use isample::data::synthetic::SyntheticImages;
use isample::data::Dataset;
use isample::runtime::score::{NativeScorer, ScoreBackend, ScoreKind};
use isample::util::rng::SplitMix64;
use isample::util::stats::normalize_probs;

/// Pearson chi-square statistic of observed counts against expected
/// probabilities (zero-probability bins must stay empty and are skipped).
fn chi_square_vs_expected(counts: &[u64], probs: &[f32], draws: u64) -> f64 {
    let mut chi2 = 0.0;
    for (&c, &p) in counts.iter().zip(probs) {
        let expected = p as f64 * draws as f64;
        if expected == 0.0 {
            assert_eq!(c, 0, "zero-probability bin was drawn");
            continue;
        }
        let d = c as f64 - expected;
        chi2 += d * d / expected;
    }
    chi2
}

/// Two-sample chi-square: do two count vectors come from one distribution?
fn chi_square_two_sample(a: &[u64], b: &[u64]) -> f64 {
    let mut chi2 = 0.0;
    for (&ca, &cb) in a.iter().zip(b) {
        let total = (ca + cb) as f64;
        if total == 0.0 {
            continue;
        }
        let d = ca as f64 - cb as f64;
        chi2 += d * d / total;
    }
    chi2
}

fn empirical_counts(probs: &[f32], draws: u64, kind: SamplerKind, seed: u64) -> Vec<u64> {
    let mut rng = SplitMix64::new(seed);
    let mut counts = vec![0u64; probs.len()];
    match kind {
        SamplerKind::Alias => {
            let s = AliasSampler::new(probs);
            for _ in 0..draws {
                counts[s.draw(&mut rng)] += 1;
            }
        }
        SamplerKind::Cumulative => {
            let s = CumulativeSampler::new(probs);
            for _ in 0..draws {
                counts[s.draw(&mut rng)] += 1;
            }
        }
        SamplerKind::Fenwick => {
            let s = FenwickSampler::new(probs);
            for _ in 0..draws {
                counts[s.draw(&mut rng)] += 1;
            }
        }
    }
    counts
}

#[test]
fn all_backends_agree_in_distribution_chi_square() {
    // 16-bin support incl. a zero-probability bin and a heavy tail.
    let mut scores: Vec<f32> = (0..16).map(|i| 0.05 + ((i * 7) % 11) as f32 / 11.0).collect();
    scores[3] = 0.0;
    scores[11] = 8.0; // heavy bin
    let probs = normalize_probs(&scores);
    let draws = 200_000u64;

    let alias = empirical_counts(&probs, draws, SamplerKind::Alias, 0xC0FFEE);
    let cdf = empirical_counts(&probs, draws, SamplerKind::Cumulative, 0xC0FFEE ^ 1);
    let fenwick = empirical_counts(&probs, draws, SamplerKind::Fenwick, 0xC0FFEE ^ 2);

    // df = 14 (15 live bins − 1): the 99.9% quantile is ~36.1. On a fixed
    // seed anything in that region is a sampler bug, not bad luck.
    let chi_alias = chi_square_vs_expected(&alias, &probs, draws);
    let chi_cdf = chi_square_vs_expected(&cdf, &probs, draws);
    let chi_fen = chi_square_vs_expected(&fenwick, &probs, draws);
    assert!(chi_alias < 40.0, "alias off-distribution: chi2 {chi_alias}");
    assert!(chi_cdf < 40.0, "cumulative off-distribution: chi2 {chi_cdf}");
    assert!(chi_fen < 40.0, "fenwick off-distribution: chi2 {chi_fen}");

    // and pairwise against each other (df = 14 again, homogeneity tests)
    let chi_ac = chi_square_two_sample(&alias, &cdf);
    let chi_af = chi_square_two_sample(&alias, &fenwick);
    assert!(chi_ac < 40.0, "alias vs cumulative disagree: chi2 {chi_ac}");
    assert!(chi_af < 40.0, "alias vs fenwick disagree: chi2 {chi_af}");
}

#[test]
fn fenwick_update_matches_rebuild_bitwise_across_draw_stream() {
    // The partial-update determinism contract at integration scale: after
    // scattered `update()`s the tree must equal a from-scratch build on
    // the same leaves — same total mass to the bit, same 200k-draw stream.
    let n = 4_096usize;
    let mut leaves: Vec<f32> = (0..n).map(|i| 0.01 + ((i * 131) % 997) as f32 / 997.0).collect();
    let mut updated = FenwickSampler::new(&leaves);
    for k in 0..700 {
        let i = (k * 53) % n;
        let v = ((k * 17) % 29) as f32 / 7.0; // hits 0.0 too (zeroed leaves)
        leaves[i] = v;
        updated.update(i, v);
    }
    let fresh = FenwickSampler::new(&leaves);
    assert_eq!(
        updated.total_mass().to_bits(),
        fresh.total_mass().to_bits(),
        "total mass diverged bitwise after 700 partial updates"
    );
    let mut rng_u = SplitMix64::new(0xFE11);
    let mut rng_f = SplitMix64::new(0xFE11);
    for d in 0..200_000u64 {
        let a = updated.draw(&mut rng_u);
        let b = fresh.draw(&mut rng_f);
        assert_eq!(a, b, "draw {d} diverged: updated {a} vs fresh {b}");
        assert!(leaves[a] > 0.0, "draw {d} selected a zero-weight leaf {a}");
    }
}

#[test]
fn chi_square_rejects_a_wrong_distribution() {
    // sanity: the statistic actually has power — compare uniform draws
    // against a skewed expectation and require a loud rejection.
    let skewed = normalize_probs(&(1..=8).map(|i| i as f32).collect::<Vec<_>>());
    let uniform = normalize_probs(&[1.0; 8]);
    let counts = empirical_counts(&uniform, 50_000, true, 7);
    assert!(chi_square_vs_expected(&counts, &skewed, 50_000) > 1_000.0);
}

#[test]
fn cached_distribution_matches_fresh_rebuild_at_refresh_boundaries() {
    let ds = SyntheticImages::builder(64, 10).samples(4_096).seed(5).build();
    let scorer = NativeScorer::new(64, 32, 10, 9);
    let backend = ScoreBackend::from_workers(3);
    let mut rng = SplitMix64::new(0xBEEF);
    let mut cache = ScoreCache::new(ds.len(), Some(3));

    // warm the cache at step 10 on one presample batch
    let indices: Vec<usize> = (0..256).map(|_| rng.below(ds.len())).collect();
    let (x, y) = ds.batch(&indices, 0);
    let stale = cache.stale_positions(&indices, 10);
    assert_eq!(stale.len(), indices.len(), "cold cache re-scores everything");
    let fresh = backend.score_subset(&scorer, &x, &y, ScoreKind::UpperBound, &stale).unwrap();
    cache.record(&indices, &stale, &fresh, 10);

    // inside the budget (age 2 <= 3) the recorded bits are served verbatim
    assert!(cache.stale_positions(&indices, 12).is_empty(), "age 2 must be fresh");
    let served = cache.lookup(&indices);
    assert_eq!(served, fresh, "cached scores must be the recorded bits");

    // at the refresh boundary (age 4 > 3) everything ages out together and
    // the full re-score rebuilds the exact same distribution: the scorer
    // is deterministic and the rows did not change
    let stale2 = cache.stale_positions(&indices, 14);
    assert_eq!(stale2.len(), indices.len(), "everything recorded together ages out together");
    let rebuilt = backend.score_subset(&scorer, &x, &y, ScoreKind::UpperBound, &stale2).unwrap();
    assert_eq!(rebuilt, served, "boundary refresh must reproduce the cached bits");
    cache.record(&indices, &stale2, &rebuilt, 14);

    // identical scores + identically-seeded rngs => identical resample
    // plans, so a cached presample cycle selects exactly the rows a full
    // re-scoring cycle would have selected
    let mut rng_c = SplitMix64::new(123);
    let mut rng_f = SplitMix64::new(123);
    let plan_c = resample_from_scores(&cache.lookup(&indices), 64, &mut rng_c, SamplerKind::Alias);
    let plan_f = resample_from_scores(&rebuilt, 64, &mut rng_f, SamplerKind::Alias);
    assert_eq!(plan_c.positions, plan_f.positions);
    assert_eq!(plan_c.weights, plan_f.weights);
    assert_eq!(plan_c.probs, plan_f.probs);
}

#[test]
fn cached_distribution_sampling_stays_on_distribution_chi_square() {
    // a presample batch served fully from the cache: draws from the cached
    // distribution must match the distribution the fresh scores define
    let ds = SyntheticImages::builder(32, 5).samples(1_024).seed(8).build();
    let scorer = NativeScorer::new(32, 16, 5, 3);
    let mut rng = SplitMix64::new(0xCAFE);
    let indices: Vec<usize> = (0..64).map(|_| rng.below(ds.len())).collect();
    let (x, y) = ds.batch(&indices, 0);
    let fresh = ScoreBackend::Serial.score(&scorer, &x, &y, ScoreKind::UpperBound).unwrap();

    let mut cache = ScoreCache::new(ds.len(), Some(5));
    let all: Vec<usize> = (0..indices.len()).collect();
    cache.record(&indices, &all, &fresh, 1);
    let probs = normalize_probs(&cache.lookup(&indices));
    let draws = 200_000u64;
    let counts = empirical_counts(&probs, draws, SamplerKind::Alias, 0xD1CE);
    // df = 63: the 99.9% quantile is ~104. Fixed seed — exceeding the
    // padded bound means the cached path corrupted the distribution.
    let chi2 = chi_square_vs_expected(&counts, &probs, draws);
    assert!(chi2 < 120.0, "cached-distribution draws off-distribution: chi2 {chi2}");

    // homogeneity against a draw stream from the freshly-computed probs
    let counts_fresh = empirical_counts(&normalize_probs(&fresh), draws, SamplerKind::Alias, 0xF00D);
    let chi_pair = chi_square_two_sample(&counts, &counts_fresh);
    assert!(chi_pair < 120.0, "cached vs fresh draw streams disagree: chi2 {chi_pair}");
}

#[test]
fn parallel_and_serial_scoring_yield_identical_sampled_indices() {
    let ds = SyntheticImages::builder(64, 10).samples(4_096).seed(5).build();
    let idx: Vec<usize> = (0..640).collect();
    let (x, y) = ds.batch(&idx, 0);
    let scorer = NativeScorer::new(64, 32, 10, 9);

    let serial = ScoreBackend::Serial.score(&scorer, &x, &y, ScoreKind::UpperBound).unwrap();
    assert_eq!(serial.len(), 640);

    for workers in [2usize, 3, 4, 7] {
        let par = ScoreBackend::from_workers(workers)
            .score(&scorer, &x, &y, ScoreKind::UpperBound)
            .unwrap();
        assert_eq!(par, serial, "scores diverged with {workers} workers");

        // identical scores + identically-seeded rng => identical resample
        let mut rng_s = SplitMix64::new(123);
        let mut rng_p = SplitMix64::new(123);
        let plan_s = resample_from_scores(&serial, 128, &mut rng_s, SamplerKind::Alias);
        let plan_p = resample_from_scores(&par, 128, &mut rng_p, SamplerKind::Alias);
        assert_eq!(plan_s.positions, plan_p.positions, "{workers} workers");
        assert_eq!(plan_s.weights, plan_p.weights, "{workers} workers");
        assert_eq!(plan_s.probs, plan_p.probs, "{workers} workers");
    }
}

#[test]
fn scoring_determinism_holds_for_every_kind_and_the_cdf_backend() {
    let ds = SyntheticImages::builder(32, 5).samples(1_024).seed(2).build();
    let idx: Vec<usize> = (0..384).collect();
    let (x, y) = ds.batch(&idx, 0);
    let scorer = NativeScorer::new(32, 16, 5, 4);

    for kind in [ScoreKind::UpperBound, ScoreKind::Loss, ScoreKind::GradNorm] {
        let serial = ScoreBackend::Serial.score(&scorer, &x, &y, kind).unwrap();
        let par = ScoreBackend::from_workers(4).score(&scorer, &x, &y, kind).unwrap();
        assert_eq!(par, serial, "kind {}", kind.name());

        let mut rng_s = SplitMix64::new(77);
        let mut rng_p = SplitMix64::new(77);
        let plan_s = resample_from_scores(&serial, 64, &mut rng_s, SamplerKind::Cumulative);
        let plan_p = resample_from_scores(&par, 64, &mut rng_p, SamplerKind::Cumulative);
        assert_eq!(plan_s.positions, plan_p.positions, "kind {}", kind.name());
    }
}

//! End-to-end integration tests over a real execution backend.
//!
//! When AOT artifacts are present (`make artifacts`) these run on the PJRT
//! engine — the rust half of the cross-language contract. Without
//! artifacts they run on the **native CPU backend**, so `cargo test`
//! always exercises real Algorithm-1 training end to end (warmup, τ
//! switch, presample/score/resample, weighted updates) instead of
//! self-skipping. Only the manifest selfcheck stays PJRT-gated: it pins
//! Python-baked numerics that exist only with artifacts. Backend
//! construction is shared through a thread-local so each test thread
//! builds (and for PJRT, compiles) the backend once.

use isample::coordinator::trainer::{Trainer, TrainerConfig};
use isample::coordinator::StrategyKind;
use isample::data::synthetic::SyntheticImages;
use isample::data::Dataset;
use isample::runtime::{checkpoint, selfcheck, Backend, Engine, NativeEngine};

const ARTIFACTS_DIR: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn have_artifacts() -> bool {
    std::path::Path::new(ARTIFACTS_DIR).join("manifest.json").exists()
}

fn with_backend(f: impl FnOnce(&dyn Backend)) {
    thread_local! {
        static BACKEND: Box<dyn Backend> = if have_artifacts() {
            Box::new(
                Engine::load(ARTIFACTS_DIR).expect("artifacts present but engine failed to load"),
            )
        } else {
            Box::new(NativeEngine::with_default_models())
        };
    }
    BACKEND.with(|b| f(b.as_ref()));
}

fn mlp_split() -> isample::data::Split<SyntheticImages> {
    SyntheticImages::builder(64, 10).samples(4_096).test_samples(1_024).seed(9).split()
}

#[test]
fn selfcheck_every_model_matches_python_numerics() {
    // PJRT-only: the selfcheck numbers are baked by Python at AOT time.
    if !have_artifacts() {
        eprintln!("skipping: no AOT artifacts under {ARTIFACTS_DIR} (run `make artifacts`)");
        return;
    }
    let engine = Engine::load(ARTIFACTS_DIR).expect("engine load");
    for model in engine.manifest.models.keys() {
        selfcheck::run(&engine, model).unwrap_or_else(|e| panic!("{model}: {e:#}"));
    }
}

#[test]
fn training_reduces_loss_and_importance_sampling_switches_on() {
    with_backend(|backend| {
        let split = mlp_split();
        let cfg = TrainerConfig::upper_bound("mlp10")
            .with_steps(300)
            .with_presample(384)
            .with_tau_th(1.2);
        let mut tr = Trainer::new(backend, cfg).unwrap();
        let report = tr.run(&split.train, Some(&split.test)).unwrap();
        assert_eq!(report.steps, 300);
        let first = report.log.rows.first().unwrap().train_loss;
        assert!(
            report.final_train_loss < first * 0.5,
            "loss did not halve: {first} -> {}",
            report.final_train_loss
        );
        assert!(report.is_switch_step.is_some(), "IS never switched on");
        assert!(report.final_test_err < 0.5, "test err {}", report.final_test_err);
        // tau is observed every step
        assert!(tr.tau.observations() >= 300);
    });
}

#[test]
fn uniform_strategy_never_activates_is() {
    with_backend(|backend| {
        let split = mlp_split();
        let cfg = TrainerConfig::uniform("mlp10").with_steps(50);
        let mut tr = Trainer::new(backend, cfg).unwrap();
        let report = tr.run(&split.train, None).unwrap();
        assert_eq!(report.is_switch_step, None);
        assert!(report.log.rows.iter().all(|r| !r.is_active));
    });
}

#[test]
fn high_tau_threshold_keeps_sampling_uniform() {
    with_backend(|backend| {
        let split = mlp_split();
        // tau can never exceed sqrt(B) = ~19.6; a threshold of 100 keeps
        // Algorithm 1 in its warmup branch forever.
        let cfg = TrainerConfig::upper_bound("mlp10")
            .with_steps(60)
            .with_presample(384)
            .with_tau_th(100.0);
        let mut tr = Trainer::new(backend, cfg).unwrap();
        let report = tr.run(&split.train, None).unwrap();
        assert_eq!(report.is_switch_step, None);
    });
}

#[test]
fn loss_and_gradnorm_strategies_run() {
    with_backend(|backend| {
        let split = mlp_split();
        for cfg in [
            TrainerConfig::loss("mlp10").with_steps(40).with_presample(384).with_tau_th(1.1),
            TrainerConfig::grad_norm("mlp10")
                .with_steps(40)
                .with_presample(1024)
                .with_tau_th(1.1),
        ] {
            let name = cfg.strategy.name();
            let mut tr = Trainer::new(backend, cfg).unwrap();
            let report = tr.run(&split.train, None).unwrap();
            assert_eq!(report.steps, 40, "{name}");
            assert!(report.final_train_loss.is_finite(), "{name}");
        }
    });
}

#[test]
fn history_baselines_run_and_learn() {
    with_backend(|backend| {
        let split = mlp_split();
        for cfg in [
            TrainerConfig::loshchilov_hutter("mlp10").with_steps(120),
            TrainerConfig::schaul("mlp10").with_steps(120),
        ] {
            let name = cfg.strategy.name();
            let mut tr = Trainer::new(backend, cfg).unwrap();
            let report = tr.run(&split.train, None).unwrap();
            let first = report.log.rows.first().unwrap().train_loss;
            assert!(
                report.final_train_loss < first,
                "{name}: {first} -> {}",
                report.final_train_loss
            );
        }
    });
}

#[test]
fn lh_full_recompute_path_is_exercised() {
    with_backend(|backend| {
        let split = SyntheticImages::builder(64, 10).samples(512).seed(3).split();
        let mut cfg = TrainerConfig::base(
            "mlp10",
            StrategyKind::LoshchilovHutter { s: 10.0, recompute_every: 20, sort_every: 5 },
        );
        cfg = cfg.with_steps(45);
        let mut tr = Trainer::new(backend, cfg).unwrap();
        let _ = tr.run(&split.train, None).unwrap();
        // 45 steps with recompute_every=20 -> recompute at steps 20 and 40,
        // each scanning ceil(512/128) = 4 shards
        let recomputes = tr.timers.count("recompute");
        assert!(recomputes >= 8, "recompute ran {recomputes}");
    });
}

#[test]
fn deterministic_given_seed() {
    with_backend(|backend| {
        let run = || {
            let split = mlp_split();
            // determinism contract: a single prefetch worker (multi-worker
            // channel arrival order is racy by design) + unaugmented data
            let mut cfg = TrainerConfig::upper_bound("mlp10")
                .with_steps(30)
                .with_presample(384)
                .with_tau_th(1.2)
                .with_seed(7);
            cfg.prefetch_threads = 1;
            let mut tr = Trainer::new(backend, cfg).unwrap();
            tr.run(&split.train, None).unwrap().final_train_loss
        };
        let (a, b) = (run(), run());
        assert_eq!(a, b, "same seed must give identical trajectories");
    });
}

#[test]
fn different_seeds_differ() {
    with_backend(|backend| {
        let run = |seed| {
            let split = mlp_split();
            let cfg = TrainerConfig::uniform("mlp10").with_steps(20).with_seed(seed);
            let mut tr = Trainer::new(backend, cfg).unwrap();
            tr.run(&split.train, None).unwrap().final_train_loss
        };
        assert_ne!(run(1), run(2));
    });
}

#[test]
fn checkpoint_roundtrip_preserves_training_state() {
    with_backend(|backend| {
        let split = mlp_split();
        let cfg = TrainerConfig::uniform("mlp10").with_steps(25);
        let mut tr = Trainer::new(backend, cfg).unwrap();
        let _ = tr.run(&split.train, None).unwrap();

        let dir = std::env::temp_dir().join(format!("isample_it_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state.ckpt");
        checkpoint::save(&tr.state, &path).unwrap();
        let restored = checkpoint::load(&path).unwrap();
        assert_eq!(restored.step, tr.state.step);

        // restored params must produce identical scores
        let (x, y) = split.train.batch(&(0..128).collect::<Vec<_>>(), 0);
        let (l1, g1) = backend.fwd_scores(&tr.state, &x, &y).unwrap();
        let (l2, g2) = backend.fwd_scores(&restored, &x, &y).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(g1, g2);
        std::fs::remove_dir_all(&dir).ok();
    });
}

#[test]
fn wrong_dataset_dimension_is_rejected() {
    with_backend(|backend| {
        let bad = SyntheticImages::builder(32, 10).samples(256).seed(1).build(); // 32 != 64
        let cfg = TrainerConfig::uniform("mlp10").with_steps(5);
        let mut tr = Trainer::new(backend, cfg).unwrap();
        assert!(tr.run(&bad, None).is_err());
    });
}

#[test]
fn presample_capability_is_checked_at_construction() {
    with_backend(|backend| {
        let cfg = TrainerConfig::upper_bound("mlp10").with_presample(999);
        if backend.name() == "pjrt" {
            // no baked fwd_scores artifact at B=999
            assert!(Trainer::new(backend, cfg).is_err());
        } else {
            // the native backend scores any B — arbitrary presamples are a
            // feature, not an error
            assert!(Trainer::new(backend, cfg).is_ok());
        }
    });
}

#[test]
fn unknown_model_is_rejected() {
    with_backend(|backend| {
        assert!(Trainer::new(backend, TrainerConfig::uniform("nope")).is_err());
    });
}

#[test]
fn eval_metrics_agree_with_scores() {
    with_backend(|backend| {
        // mean test loss from eval_metrics must match the mean of the
        // per-sample losses from fwd_scores on the same shard
        let split = mlp_split();
        let state = backend.init_state("mlp10", 5).unwrap();
        let info = backend.model_info("mlp10").unwrap();
        let idx: Vec<usize> = (0..info.eval_batch).collect();
        let (x, y) = split.test.batch(&idx, 0);
        let (sum_loss, correct) = backend.eval_metrics(&state, &x, &y).unwrap();
        // same shard through fwd_scores at eval_batch is not baked; use b-
        // sized chunks instead
        let b = info.batch;
        let mut total = 0.0f64;
        for c in 0..(info.eval_batch / b) {
            let sub: Vec<usize> = (c * b..(c + 1) * b).collect();
            let (xs, ys) = split.test.batch(&sub, 0);
            let (l, _) = backend.fwd_scores(&state, &xs, &ys).unwrap();
            total += l.iter().map(|&v| v as f64).sum::<f64>();
        }
        assert!((total - sum_loss).abs() < 1e-2 * sum_loss.abs().max(1.0), "{total} vs {sum_loss}");
        assert!((0..=info.eval_batch as i64).contains(&correct));
    });
}

#[test]
fn adaptive_lr_extension_runs_and_learns() {
    // §5 future-work feature: lr scaled by min(tau, cap) while IS is active.
    with_backend(|backend| {
        let split = mlp_split();
        let cfg = TrainerConfig::upper_bound("mlp10")
            .with_steps(200)
            .with_presample(384)
            .with_tau_th(1.2)
            .with_adaptive_lr(2.0);
        let mut tr = Trainer::new(backend, cfg).unwrap();
        let report = tr.run(&split.train, None).unwrap();
        assert!(report.is_switch_step.is_some());
        let first = report.log.rows.first().unwrap().train_loss;
        assert!(
            report.final_train_loss < first * 0.7,
            "adaptive-lr run diverged: {first} -> {}",
            report.final_train_loss
        );
    });
}

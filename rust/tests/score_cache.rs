//! End-to-end coverage for the ISSUE 6 streaming data plane: the
//! staleness-aware score cache and the out-of-core shard store, both run
//! through the real `Trainer` on the native backend (zero artifacts).
//!
//! Test-name prefixes are load-bearing — CI's train-smoke matrix selects
//! disjoint subsets by libtest filter:
//!
//! * `cache_inf_`    — the unlimited-budget leg: `--score-refresh-budget
//!   inf` (and its `Some(0)` twin) must reproduce the uncached trainer's
//!   loss trajectory and final state **bit-for-bit**;
//! * `cache_finite_` — the finite-budget leg: serving stale scores is a
//!   throughput knob, so an equal-step run must stay reproducible and
//!   inside a loss tolerance of the full re-scoring run;
//! * `shard_`        — training from a [`ShardedDataset`] must be
//!   bit-identical to training from the in-memory dataset it was
//!   materialized from, under eviction pressure and readahead races.

use isample::coordinator::trainer::{Trainer, TrainerConfig};
use isample::data::shard::{write_dataset, ShardedDataset};
use isample::data::synthetic::SyntheticImages;
use isample::data::Dataset;
use isample::runtime::checkpoint::state_checksum;
use isample::runtime::score::{NativeScorer, ScoreBackend, ScoreKind};
use isample::runtime::{NativeEngine, NativeModelSpec};
use isample::util::digest::digest_f64;
use isample::util::rng::SplitMix64;

fn cache_engine() -> NativeEngine {
    let mut ne = NativeEngine::new();
    ne.register(NativeModelSpec::mlp("gold", 32, 24, 4, 32, 64, vec![128]));
    ne
}

fn cache_pool() -> SyntheticImages {
    SyntheticImages::builder(32, 4).samples(2_048).seed(11).build()
}

fn cache_cfg(steps: u64, budget: Option<u64>) -> TrainerConfig {
    // τ ≥ 1 by construction and τ_th = 0.95, so importance sampling (the
    // only path the cache touches) runs for all but the first step.
    TrainerConfig::upper_bound("gold")
        .with_steps(steps)
        .with_presample(128)
        .with_tau_th(0.95)
        .with_seed(5)
        .with_score_workers(2)
        .with_score_refresh_budget(budget)
}

/// Fixed-seed upper-bound run over `train` with the given staleness
/// budget; returns (trajectory digest, state checksum, trailing loss).
fn budget_run<D: Dataset + Sync>(train: &D, budget: Option<u64>, steps: u64) -> (u64, u64, f64) {
    let ne = cache_engine();
    let mut tr = Trainer::new(&ne, cache_cfg(steps, budget)).unwrap();
    let report = tr.run(train, None).unwrap();
    assert_eq!(report.steps, steps);
    assert_eq!(report.is_switch_step, Some(2), "IS must engage right after warmup");
    let traj = digest_f64(report.log.rows.iter().map(|r| r.train_loss));
    let tail = report.log.trailing_train_loss(4).expect("run logged no metrics rows");
    (traj, state_checksum(&tr.state).unwrap(), tail)
}

#[test]
fn score_subset_matches_full_scoring_bitwise() {
    let ds = SyntheticImages::builder(32, 5).samples(1_024).seed(2).build();
    let idx: Vec<usize> = (0..384).collect();
    let (x, y) = ds.batch(&idx, 0);
    let scorer = NativeScorer::new(32, 16, 5, 4);

    for backend in [ScoreBackend::Serial, ScoreBackend::from_workers(3)] {
        let full = backend.score(&scorer, &x, &y, ScoreKind::UpperBound).unwrap();
        let sub = |positions: &[usize]| {
            backend.score_subset(&scorer, &x, &y, ScoreKind::UpperBound, positions).unwrap()
        };
        // identity subset short-circuits to the full scoring pass
        let all: Vec<usize> = (0..y.len()).collect();
        assert_eq!(sub(&all), full, "identity subset must equal the full pass");
        assert!(sub(&[]).is_empty(), "empty subset must score nothing");
        // proper subsets gather rows; row-wise determinism means every
        // gathered score carries exactly the full pass's bits, including
        // duplicated and unsorted positions
        let mut rng = SplitMix64::new(31);
        let subset: Vec<usize> = (0..97).map(|_| rng.below(y.len())).collect();
        let want: Vec<f32> = subset.iter().map(|&p| full[p]).collect();
        assert_eq!(sub(&subset), want, "gathered subset diverged from the full pass");
    }
}

#[test]
fn cache_inf_budget_is_bit_identical_to_the_uncached_trainer() {
    let pool = cache_pool();
    let uncached = budget_run(&pool, None, 160);
    assert_eq!(budget_run(&pool, None, 160), uncached, "uncached run must be reproducible");
    // Some(0) runs the full cache bookkeeping — stale-set computation,
    // record, lookup — on every cycle (any cached score has age ≥ 1 > 0),
    // and must not move a single bit of the trajectory or final state.
    assert_eq!(budget_run(&pool, Some(0), 160), uncached, "zero budget must match unlimited");
}

#[test]
fn cache_finite_budget_stays_within_loss_tolerance() {
    let pool = cache_pool();
    let steps = 160;
    let full = budget_run(&pool, None, steps);
    let cached = budget_run(&pool, Some(48), steps);
    assert_eq!(budget_run(&pool, Some(48), steps), cached, "cached run must be reproducible");
    // Stale scores reorder the curriculum, so the trajectories legitimately
    // differ — but at equal step count the cached run must still converge
    // comparably on the same pool (trailing mean over the last rows, with
    // generous headroom: this is a quality floor, not a golden digest).
    let (f_tail, c_tail) = (full.2, cached.2);
    assert!(f_tail.is_finite() && f_tail > 0.0, "full-rescore trailing loss {f_tail}");
    assert!(c_tail.is_finite() && c_tail > 0.0, "cached trailing loss {c_tail}");
    assert!(
        c_tail <= 2.0 * f_tail + 0.1,
        "stale-score run converged much worse: cached {c_tail} vs full {f_tail}"
    );
}

#[test]
fn shard_store_trains_bit_identically_to_in_memory() {
    let pool = cache_pool();
    let dir = std::env::temp_dir().join(format!("isample_shard_train_{}", std::process::id()));
    // 100-row shards: 20 full + one 48-row tail; presample batches span
    // many shards, so a resident budget of 3 forces eviction every cycle
    // while readahead races the trainer's own fetches
    write_dataset(&dir, &pool, 100).unwrap();
    let sharded = ShardedDataset::open(&dir).unwrap().with_resident_shards(3).with_readahead(2);

    // same steps both ways; the shard store serves pre-materialized rows,
    // so the pool must not use epoch-dependent augmentation (it doesn't:
    // SyntheticImages augmentation is opt-in)
    let want = budget_run(&pool, None, 80);
    let got = budget_run(&sharded, None, 80);
    std::fs::remove_dir_all(&dir).ok();
    assert_eq!(
        (got.0, got.1),
        (want.0, want.1),
        "streaming trajectory diverged from the in-memory run"
    );

    // and the cached path composes with streaming: reproducible end to end
    let dir2 = std::env::temp_dir().join(format!("isample_shard_cache_{}", std::process::id()));
    write_dataset(&dir2, &pool, 256).unwrap();
    let s2 = ShardedDataset::open(&dir2).unwrap().with_resident_shards(2).with_readahead(1);
    let a = budget_run(&s2, Some(32), 80);
    let b = budget_run(&s2, Some(32), 80);
    std::fs::remove_dir_all(&dir2).ok();
    assert_eq!(a, b, "cached streaming run must be reproducible");
}

//! End-to-end coverage for the native CPU training backend — the ISSUE 2
//! acceptance tests, extended by ISSUE 4 (layer IR) to every architecture
//! the native backend now trains:
//!
//! * analytic gradients vs central finite differences, swept over every
//!   `Layer` variant (Dense / Relu / Conv1d / GlobalAvgPool /
//!   EmbeddingBag) on tiny specs — since ISSUE 5 this sweep runs on the
//!   block-kernel path (`weighted_grad` routes through
//!   `runtime::kernels`), plus an explicit kernel-vs-scalar-reference
//!   cross-check,
//! * native scoring parity through the sharded scoring subsystem,
//! * real Algorithm-1 runs with zero AOT artifacts: uniform warmup,
//!   τ crossing τ_th, importance sampling switching on, and the
//!   upper-bound strategy beating uniform train loss at an equal step
//!   count on fixed-seed separable tasks — for the MLP, a Conv1d image
//!   net (fig 3's native scenario) and an EmbeddingBag sequence net
//!   (fig 5's native scenario),
//! * the trainer-level bugfixes of ISSUE 2 (exact switch step, test-set
//!   tail evaluation) exercised through the native backend.

use anyhow::Result;
use isample::coordinator::trainer::{Trainer, TrainerConfig};
use isample::data::sequence::PermutedSequences;
use isample::data::synthetic::SyntheticImages;
use isample::data::Dataset;
use isample::runtime::score::{BackendScorer, ScoreBackend, ScoreKind};
use isample::runtime::{Backend, HostTensor, Layer, ModelState, NativeEngine, NativeModelSpec};
use xla::Literal;

/// Small, fast model used across these tests (any-batch native entries).
fn sep_engine() -> NativeEngine {
    let mut ne = NativeEngine::new();
    ne.register(NativeModelSpec::mlp("sep", 32, 32, 4, 32, 64, vec![128, 256]));
    ne
}

/// A small Conv1d image net over the same 32-dim separable images (dense
/// head after the conv keeps the boundary tier learnable at this scale).
fn conv_sep_engine() -> NativeEngine {
    let mut ne = NativeEngine::new();
    ne.register(NativeModelSpec::with_layers(
        "csep",
        32,
        vec![
            Layer::Conv1d { in_ch: 1, out_ch: 6, kernel: 5, stride: 2 },
            Layer::Relu,
            Layer::Dense { out_dim: 32 },
            Layer::Relu,
            Layer::Dense { out_dim: 4 },
        ],
        32,
        64,
        vec![128, 256],
    ));
    ne
}

/// An EmbeddingBag sequence net over 32-step permuted rasters: positional
/// 12-bin quantization, sum-pooled embeddings (`gain = T`), dense head.
fn seq_sep_engine() -> NativeEngine {
    let mut ne = NativeEngine::new();
    ne.register(NativeModelSpec::with_layers(
        "ssep",
        32,
        vec![
            Layer::EmbeddingBag {
                vocab: 12,
                dim: 24,
                lo: -3.0,
                hi: 3.0,
                positional: true,
                gain: 32.0,
            },
            Layer::Dense { out_dim: 24 },
            Layer::Relu,
            Layer::Dense { out_dim: 4 },
        ],
        32,
        64,
        vec![128, 256],
    ));
    ne
}

/// Strongly separable task: most samples are near-noiseless prototypes
/// (learned in the first epochs — the "could be ignored" mass), a 12%
/// boundary tier keeps producing informative gradients. No outliers, so
/// every sample is learnable and importance sampling pays off cleanly.
fn sep_split() -> isample::data::Split<SyntheticImages> {
    SyntheticImages::builder(32, 4)
        .samples(2_048)
        .test_samples(256)
        .seed(11)
        .tiers(0.88, 0.12)
        .noise(0.03, 1.0)
        .split()
}

/// The sequence twin of [`sep_split`]: mostly-easy permuted rasters with
/// a 12% boundary tier (no outliers), on fig 5's dataset family.
fn seq_sep_split() -> isample::data::Split<PermutedSequences> {
    PermutedSequences::builder(32, 4)
        .samples(2_048)
        .test_samples(256)
        .seed(11)
        .tiers(0.88, 0.12)
        .split()
}

fn full_train_loss<D: Dataset>(ne: &NativeEngine, state: &ModelState, ds: &D) -> f64 {
    let idx: Vec<usize> = (0..ds.len()).collect();
    let (x, y) = ds.batch(&idx, 0);
    let (loss, _) = ne.fwd_scores(state, &x, &y).unwrap();
    loss.iter().map(|&l| l as f64).sum::<f64>() / loss.len() as f64
}

/// Shared body of the equal-steps acceptance runs: train uniform and
/// upper-bound with identical budgets on `split`, assert Algorithm 1 ran
/// for real (warmup then τ switch), and assert the paper's core claim —
/// importance sampling reaches a lower full-train loss at equal steps.
fn assert_upper_bound_beats_uniform<D: Dataset + Sync>(
    ne: &NativeEngine,
    model: &str,
    split: &isample::data::Split<D>,
    steps: u64,
    lr: f32,
) {
    let run = |cfg: TrainerConfig| {
        let cfg = cfg.with_steps(steps).with_seed(13).with_lr(lr);
        let mut tr = Trainer::new(ne, cfg).unwrap();
        let report = tr.run(&split.train, None).unwrap();
        assert_eq!(report.steps, steps);
        (full_train_loss(ne, &tr.state, &split.train), report)
    };
    let (uni_loss, _) = run(TrainerConfig::uniform(model));
    let (ub_loss, ub_report) =
        run(TrainerConfig::upper_bound(model).with_presample(256).with_tau_th(1.1));

    // Algorithm 1 ran for real: uniform warmup first, then τ > τ_th.
    let switch = ub_report.is_switch_step.expect("importance sampling never switched on");
    assert!(switch >= 2, "step 1 must be a warmup step (switch at {switch})");
    assert!(!ub_report.log.rows.first().unwrap().is_active, "first logged row must be warmup");
    assert!(ub_report.log.rows.iter().any(|r| r.is_active), "no active rows logged");

    println!(
        "[{model}] full-train loss: uniform {uni_loss:.5} vs upper-bound {ub_loss:.5} \
         (IS@{switch})"
    );
    assert!(
        ub_loss < uni_loss,
        "[{model}] upper-bound ({ub_loss}) did not beat uniform ({uni_loss}) at {steps} steps"
    );
    assert!(ub_loss.is_finite() && uni_loss.is_finite());
}

#[test]
fn upper_bound_beats_uniform_at_equal_step_count() {
    assert_upper_bound_beats_uniform(&sep_engine(), "sep", &sep_split(), 400, 0.1);
}

#[test]
fn conv_upper_bound_beats_uniform_at_equal_step_count() {
    // fig 3's native conv scenario on its fixed-seed separable image task
    assert_upper_bound_beats_uniform(&conv_sep_engine(), "csep", &sep_split(), 600, 0.15);
}

#[test]
fn seq_upper_bound_beats_uniform_at_equal_step_count() {
    // fig 5's native sequence scenario on its fixed-seed permuted rasters
    assert_upper_bound_beats_uniform(&seq_sep_engine(), "ssep", &seq_sep_split(), 600, 0.1);
}

#[test]
fn fenwick_mixture_path_is_no_worse_than_alias_at_equal_steps() {
    // ISSUE 8 acceptance: the `--sampler fenwick` pool-sized live
    // distribution (partial updates + λ-mixture draws) must keep the
    // paper's equal-step claim on the acceptance task — beat uniform, and
    // land no worse than the alias-based presample scheme (small tolerance:
    // the two paths draw from deliberately different distributions, so
    // exact loss equality is not expected). The path must also be a pure
    // function of the seed, like every other trainer configuration.
    use isample::coordinator::sampler::SamplerKind;
    let ne = sep_engine();
    let split = sep_split();
    let steps = 400u64;
    let run = |cfg: TrainerConfig| {
        let cfg = cfg.with_steps(steps).with_seed(13).with_lr(0.1);
        let mut tr = Trainer::new(&ne, cfg).unwrap();
        let report = tr.run(&split.train, None).unwrap();
        assert_eq!(report.steps, steps);
        (full_train_loss(&ne, &tr.state, &split.train), report)
    };
    let ub = || TrainerConfig::upper_bound("sep").with_presample(256).with_tau_th(1.1);
    let (uni_loss, _) = run(TrainerConfig::uniform("sep"));
    let (ali_loss, _) = run(ub().with_sampler(SamplerKind::Alias));
    let (fen_loss, fen_report) = run(ub().with_sampler(SamplerKind::Fenwick));

    let switch = fen_report.is_switch_step.expect("fenwick path never switched IS on");
    assert!(switch >= 2, "step 1 must be a warmup step (switch at {switch})");
    println!(
        "[sep] full-train loss at {steps} steps: uniform {uni_loss:.5}, \
         alias {ali_loss:.5}, fenwick {fen_loss:.5} (IS@{switch})"
    );
    assert!(fen_loss.is_finite());
    assert!(fen_loss < uni_loss, "fenwick ({fen_loss}) did not beat uniform ({uni_loss})");
    assert!(
        fen_loss <= ali_loss * 1.15 + 0.02,
        "fenwick ({fen_loss}) worse than alias ({ali_loss}) beyond tolerance"
    );

    // determinism: an identical fenwick run reproduces the loss exactly
    let (fen_again, _) = run(ub().with_sampler(SamplerKind::Fenwick));
    assert_eq!(fen_loss.to_bits(), fen_again.to_bits(), "fenwick path not seed-deterministic");
}

#[test]
fn switch_step_is_recorded_exactly_not_log_quantized() {
    // τ ≥ 1 always, so τ_th = 0.5 makes the switch happen at step 2 — the
    // first step after the mandatory warmup observation. With
    // log_every = 10 the first *logged* active row is step 10; the report
    // must still carry the exact step.
    let ne = sep_engine();
    let split = sep_split();
    let mut cfg =
        TrainerConfig::upper_bound("sep").with_steps(30).with_presample(128).with_tau_th(0.5);
    cfg.log_every = 10;
    let mut tr = Trainer::new(&ne, cfg).unwrap();
    let report = tr.run(&split.train, None).unwrap();
    assert_eq!(report.is_switch_step, Some(2), "switch step must be exact");
    assert_eq!(report.log.is_switch_on_step(), Some(10), "rows are log_every-quantized");
}

/// Central-difference check of `weighted_grad` for one spec: three entries
/// of every parameter tensor against the numeric gradient of the weighted
/// mean loss.
fn check_gradients(spec: NativeModelSpec) {
    let name = spec.name.clone();
    let d = spec.model.in_dim();
    let mut ne = NativeEngine::new();
    ne.register(spec);
    let state = ne.init_state(&name, 3).unwrap();
    let n = 8;
    let mut x = HostTensor::zeros(vec![n, d]);
    for (i, v) in x.data.iter_mut().enumerate() {
        *v = ((i * 37 + 11) % 83) as f32 / 83.0 - 0.5;
    }
    let y: Vec<i32> = (0..n).map(|i| (i % 3) as i32).collect();
    let w = [0.5f32, 1.5, 1.0, 2.0, 0.3, 1.0, 0.7, 1.2];

    let (grads, loss0) = ne.weighted_grad(&state, &x, &y, &w).unwrap();
    assert!(loss0.is_finite());

    let weighted_loss = |params: &[Literal]| -> f64 {
        let s = ModelState { model: name.clone(), params: params.to_vec(), mom: vec![], step: 0 };
        let (loss, _) = ne.fwd_scores(&s, &x, &y).unwrap();
        loss.iter().zip(&w).map(|(&l, &wi)| l as f64 * wi as f64).sum::<f64>() / n as f64
    };
    let perturbed = |t: usize, idx: usize, eps: f32| -> Vec<Literal> {
        state
            .params
            .iter()
            .enumerate()
            .map(|(i, lit)| {
                let mut ht = HostTensor::from_literal(lit).unwrap();
                if i == t {
                    ht.data[idx] += eps;
                }
                ht.to_literal().unwrap()
            })
            .collect()
    };

    let eps = 1e-2f32;
    let mut checked = 0;
    for (t, g) in grads.iter().enumerate() {
        let gh = HostTensor::from_literal(g).unwrap();
        let len = gh.data.len();
        for &idx in &[0, len / 3, len - 1] {
            let up = weighted_loss(&perturbed(t, idx, eps));
            let down = weighted_loss(&perturbed(t, idx, -eps));
            let numeric = (up - down) / (2.0 * eps as f64);
            let analytic = gh.data[idx] as f64;
            assert!(
                (numeric - analytic).abs() < 2e-3 + 2e-2 * analytic.abs(),
                "{name} tensor {t} idx {idx}: analytic {analytic} vs numeric {numeric}"
            );
            checked += 1;
        }
    }
    assert_eq!(checked, 3 * grads.len(), "{name}: three entries per tensor");
}

#[test]
fn gradient_check_against_finite_differences_per_layer_variant() {
    // every `Layer` variant appears in at least one swept spec: Dense and
    // Relu in all three, Conv1d + GlobalAvgPool in the conv stack, and
    // EmbeddingBag (positional quantization) in the sequence stack
    let dense = NativeModelSpec::mlp("tiny", 6, 5, 3, 8, 16, vec![16]);
    let conv = NativeModelSpec::with_layers(
        "tconv",
        12,
        vec![
            Layer::Conv1d { in_ch: 1, out_ch: 3, kernel: 3, stride: 2 },
            Layer::Relu,
            Layer::GlobalAvgPool { channels: 3 },
            Layer::Dense { out_dim: 5 },
            Layer::Relu,
            Layer::Dense { out_dim: 3 },
        ],
        8,
        16,
        vec![16],
    );
    let bag =
        Layer::EmbeddingBag { vocab: 5, dim: 4, lo: -0.6, hi: 0.6, positional: true, gain: 6.0 };
    let seq = NativeModelSpec::with_layers(
        "tseq",
        6,
        vec![bag, Layer::Dense { out_dim: 4 }, Layer::Relu, Layer::Dense { out_dim: 3 }],
        8,
        16,
        vec![16],
    );
    for spec in [dense, conv, seq] {
        check_gradients(spec);
    }
}

#[test]
fn kernel_path_matches_the_scalar_reference_walk() {
    // ISSUE 5 cross-check at the engine level: the backend entries now run
    // the block kernels, so (a) per-row outputs (`fwd_scores`) must equal
    // the scalar reference walk bit for bit, and (b) `weighted_grad` must
    // match a whole-batch scalar accumulation to tight tolerance (exact
    // equality is not expected there: the engine's canonical reduction is
    // the PR 3 *chunked* merge, while the reference below folds the whole
    // batch in one chain).
    for (mk, name) in [(sep_engine as fn() -> NativeEngine, "sep"), (conv_sep_engine, "csep")] {
        let ne = mk();
        let state = ne.init_state(name, 29).unwrap();
        let m = ne.layer_model(name).unwrap().clone();
        let p = state.params_to_host().unwrap();
        let n = 37usize;
        let d = m.in_dim();
        let mut x = HostTensor::zeros(vec![n, d]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = ((i * 53 + 19) % 97) as f32 / 97.0 - 0.5;
        }
        let y: Vec<i32> = (0..n).map(|i| (i % m.num_classes()) as i32).collect();
        let w: Vec<f32> = (0..n).map(|i| 0.2 + (i % 4) as f32 * 0.6).collect();

        // (a) per-row outputs: bitwise
        let (loss, ub) = ne.fwd_scores(&state, &x, &y).unwrap();
        let mut s = m.scratch();
        for r in 0..n {
            let (l, u) = m.row_scores(&p, x.row(r), y[r], &mut s);
            assert_eq!((loss[r], ub[r]), (l, u), "{name} row {r}: fwd_scores diverged");
        }

        // (b) gradients: whole-batch scalar walk, tolerance-level
        let (grads, wloss) = ne.weighted_grad(&state, &x, &y, &w).unwrap();
        let mut grads_ref = m.zero_grads();
        let mut wl_ref = 0.0f64;
        let inv_n = 1.0 / n as f32;
        for r in 0..n {
            let xr = x.row(r);
            let (l, _) = m.row_scores(&p, xr, y[r], &mut s);
            let cf = w[r] * inv_n;
            wl_ref += cf as f64 * l as f64;
            let yy = m.clamp_label(y[r]);
            let gz = s.probs_mut();
            gz[yy] -= 1.0;
            for gv in gz.iter_mut() {
                *gv *= cf;
            }
            m.backward_row(&p, xr, &mut s, &mut grads_ref);
        }
        assert!((wloss as f64 - wl_ref).abs() < 1e-5, "{name}: {wloss} vs {wl_ref}");
        for (t, (got, want)) in grads.iter().zip(&grads_ref).enumerate() {
            let gh = HostTensor::from_literal(got).unwrap();
            for (i, (&gv, &rv)) in gh.data.iter().zip(want).enumerate() {
                assert!(
                    (gv - rv).abs() <= 1e-5 + 1e-4 * rv.abs(),
                    "{name} tensor {t} elem {i}: kernel {gv} vs scalar {rv}"
                );
            }
        }
    }
}

#[test]
fn sharded_scoring_is_bit_identical_through_the_trainer_scorer() {
    // The exact scorer+backend combination the trainer's hot path uses.
    let ne = sep_engine();
    let state = ne.init_state("sep", 21).unwrap();
    let split = sep_split();
    let idx: Vec<usize> = (0..300).collect();
    let (x, y) = split.train.batch(&idx, 0);
    let scorer = BackendScorer { backend: &ne, state: &state };
    for kind in [ScoreKind::UpperBound, ScoreKind::Loss, ScoreKind::GradNorm] {
        let serial = ScoreBackend::Serial.score(&scorer, &x, &y, kind).unwrap();
        for workers in [2, 4, 11] {
            let par = ScoreBackend::from_workers(workers).score(&scorer, &x, &y, kind).unwrap();
            assert_eq!(par, serial, "workers={workers}");
        }
    }
}

#[test]
fn bf16_scoring_preserves_the_resampling_decisions() {
    // ISSUE 9 acceptance: bf16 presample scoring is a *ranking-fidelity*
    // contract, not a bitwise one. At a fixed seed, (a) the bf16 score
    // walk must be deterministic, (b) the scores must track the f32 walk
    // in relative terms, and (c) the resample plan drawn from bf16 scores
    // must overlap the f32 plan above a pinned floor. The Cumulative
    // sampler makes (c) boundary-stable: a tiny score perturbation only
    // moves draws that land right on a CDF edge.
    use isample::coordinator::sampler::{resample_from_scores, SamplerKind};
    use isample::runtime::ScorePrecision;
    use isample::util::rng::SplitMix64;

    let ne = sep_engine();
    let state = ne.init_state("sep", 17).unwrap();
    let split = sep_split();
    let idx: Vec<usize> = (0..640).collect();
    let (x, y) = split.train.batch(&idx, 0);

    let (_, s32) = ne.fwd_scores(&state, &x, &y).unwrap();
    ne.set_score_precision(ScorePrecision::Bf16);
    let (_, s16) = ne.fwd_scores(&state, &x, &y).unwrap();
    let (_, s16b) = ne.fwd_scores(&state, &x, &y).unwrap();
    ne.set_score_precision(ScorePrecision::F32);
    assert_eq!(s16, s16b, "bf16 scoring must be deterministic");

    // (b) relative fidelity of the raw scores
    let mean_rel = s32
        .iter()
        .zip(&s16)
        .map(|(&a, &b)| ((a - b).abs() / a.abs().max(1e-6)) as f64)
        .sum::<f64>()
        / s32.len() as f64;
    assert!(mean_rel < 0.1, "mean relative score deviation {mean_rel} too large");

    // (c) sampled-index overlap at a fixed resampling seed (B=640 -> b=128)
    let plan32 = resample_from_scores(&s32, 128, &mut SplitMix64::new(7), SamplerKind::Cumulative);
    let plan16 = resample_from_scores(&s16, 128, &mut SplitMix64::new(7), SamplerKind::Cumulative);
    let same = plan32.positions.iter().zip(&plan16.positions).filter(|(a, b)| a == b).count();
    let overlap = same as f64 / plan32.positions.len() as f64;
    println!("bf16/f32 resample overlap {overlap:.3} (mean rel dev {mean_rel:.4})");
    assert!(overlap >= 0.7, "sampled-index overlap {overlap:.3} below the 0.7 acceptance floor");
}

/// A native backend whose `eval_metrics` only accepts one batch size —
/// the shape of a PJRT engine with a single baked eval artifact. Forces
/// `Trainer::evaluate` down its wrapped-tail path.
struct FixedEvalBatch<'a> {
    inner: &'a NativeEngine,
    eval_batch: usize,
}

impl Backend for FixedEvalBatch<'_> {
    fn name(&self) -> &'static str {
        "native-fixed-eval"
    }

    fn model_info(&self, model: &str) -> Result<&isample::runtime::ModelInfo> {
        self.inner.model_info(model)
    }

    fn supports(&self, model: &str, entry: &str, batch: usize) -> Result<bool> {
        if entry == "eval_metrics" {
            self.inner.model_info(model)?;
            return Ok(batch == self.eval_batch);
        }
        self.inner.supports(model, entry, batch)
    }

    fn prepare(&self, model: &str, entry: &str, batch: usize) -> Result<()> {
        if entry == "eval_metrics" {
            return Ok(());
        }
        self.inner.prepare(model, entry, batch)
    }

    fn init_state(&self, model: &str, seed: u64) -> Result<ModelState> {
        self.inner.init_state(model, seed)
    }

    fn train_step(
        &self,
        state: &mut ModelState,
        x: &HostTensor,
        y: &[i32],
        w: &[f32],
        lr: f32,
    ) -> Result<isample::runtime::engine::StepOutput> {
        self.inner.train_step(state, x, y, w, lr)
    }

    fn fwd_scores(
        &self,
        state: &ModelState,
        x: &HostTensor,
        y: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        self.inner.fwd_scores(state, x, y)
    }

    fn eval_metrics(&self, state: &ModelState, x: &HostTensor, y: &[i32]) -> Result<(f64, i64)> {
        assert_eq!(x.shape[0], self.eval_batch, "partial shard reached a fixed-batch backend");
        self.inner.eval_metrics(state, x, y)
    }

    fn grad_norms(&self, state: &ModelState, x: &HostTensor, y: &[i32]) -> Result<Vec<f32>> {
        self.inner.grad_norms(state, x, y)
    }

    fn grad(
        &self,
        model: &str,
        params: &[Literal],
        x: &HostTensor,
        y: &[i32],
    ) -> Result<(Vec<Literal>, f32)> {
        self.inner.grad(model, params, x, y)
    }

    fn weighted_grad(
        &self,
        state: &ModelState,
        x: &HostTensor,
        y: &[i32],
        w: &[f32],
    ) -> Result<(Vec<Literal>, f32)> {
        self.inner.weighted_grad(state, x, y, w)
    }
}

#[test]
fn evaluate_covers_the_test_set_tail() {
    // 100 samples with eval_batch 64: the seed dropped the 36-sample tail.
    let mut ne = NativeEngine::new();
    ne.register(NativeModelSpec::mlp("evm", 8, 8, 3, 16, 64, vec![64]));
    let test = SyntheticImages::builder(8, 3).samples(100).seed(5).build();

    // exact path (native supports any batch): must equal the one-shot
    // whole-set evaluation
    let mut tr = Trainer::new(&ne, TrainerConfig::uniform("evm")).unwrap();
    let (loss, err) = tr.evaluate(&test).unwrap();
    let idx: Vec<usize> = (0..test.len()).collect();
    let (x, y) = test.batch(&idx, 0);
    let (sum, correct) = ne.eval_metrics(&tr.state, &x, &y).unwrap();
    let (exact_loss, exact_err) = (sum / 100.0, 1.0 - correct as f64 / 100.0);
    assert!((loss - exact_loss).abs() < 1e-9, "{loss} vs {exact_loss}");
    assert!((err - exact_err).abs() < 1e-9, "{err} vs {exact_err}");

    // wrapped-weighted path (fixed-batch backend): approximate but close,
    // and every tail sample now counts toward `seen`
    let fixed = FixedEvalBatch { inner: &ne, eval_batch: 64 };
    let mut tr2 = Trainer::new(&fixed, TrainerConfig::uniform("evm")).unwrap();
    let (wloss, werr) = tr2.evaluate(&test).unwrap();
    assert!(
        (wloss - exact_loss).abs() < 0.25 * exact_loss.abs().max(0.1),
        "wrapped tail mean {wloss} too far from exact {exact_loss}"
    );
    assert!((0.0..=1.0).contains(&werr));
    assert!((werr - exact_err).abs() < 0.25, "wrapped err {werr} vs exact {exact_err}");

    // a test set smaller than the eval batch no longer bails
    let small = SyntheticImages::builder(8, 3).samples(40).seed(6).build();
    let (sloss, serr) = tr.evaluate(&small).unwrap();
    assert!(sloss.is_finite() && (0.0..=1.0).contains(&serr));
    let (wsloss, wserr) = tr2.evaluate(&small).unwrap();
    assert!(wsloss.is_finite() && (0.0..=1.0).contains(&wserr));
}
